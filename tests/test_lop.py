"""LOP surrogate, features, comparison-free top-K (paper §III-A).

Deterministic cases only — the hypothesis property-based companions live
in test_hypothesis_props.py (skipped when hypothesis is not installed).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lop import (block_reduce_scores, comparison_free_topk,
                            exact_topk, kv_traffic_bytes, leading_one,
                            lop_features, pack_features)


def test_leading_one_exact():
    for v in range(-127, 128):
        lo = int(leading_one(jnp.int8(v)))
        if v == 0:
            assert lo == 7
        else:
            assert lo == int(np.floor(np.log2(abs(v))))


def test_feature_cache_is_half_bytes(rng):
    k = jnp.asarray(rng.integers(-127, 128, (64, 128)), jnp.int8)
    packed = pack_features(lop_features(k))
    assert packed.size * packed.dtype.itemsize == k.size // 2


def _rank_flat_reference(s, k, n_buckets):
    """The pre-retile flat-vector-op selector (rank-3 one-hot histogram +
    plain jnp.cumsum) — kept verbatim as the oracle the Mosaic-tiled
    implementation in core.lop must match bitwise."""
    m = s.shape[-1]
    finite = jnp.isfinite(s)
    smin = jnp.min(jnp.where(finite, s, jnp.inf), -1, keepdims=True)
    smax = jnp.max(jnp.where(finite, s, -jnp.inf), -1, keepdims=True)
    span = jnp.maximum(smax - smin, 1e-9)
    bucket = jnp.clip(((s - smin) / span * n_buckets).astype(jnp.int32),
                      0, n_buckets - 1)
    bucket = jnp.where(finite, bucket, -1)
    bins = jax.lax.broadcasted_iota(jnp.int32, (1, 1, n_buckets), 2)
    hist = jnp.sum((bucket[:, :, None] == bins).astype(jnp.int32), axis=1)
    cum_hi = jnp.cumsum(hist[:, ::-1], -1)[:, ::-1]
    reach = cum_hi >= k
    bin_ids = jax.lax.broadcasted_iota(jnp.int32, reach.shape, 1)
    cut = jnp.where(jnp.any(reach, -1, keepdims=True),
                    jnp.max(jnp.where(reach, bin_ids, -1), -1, keepdims=True),
                    0)
    above = bucket > cut
    at_cut = bucket == cut
    n_above = jnp.sum(above.astype(jnp.int32), -1, keepdims=True)
    rank_above = jnp.cumsum(above.astype(jnp.int32), -1) - 1
    rank_cut = n_above + jnp.cumsum(at_cut.astype(jnp.int32), -1) - 1
    big = m + k + 1
    rank = jnp.where(above, rank_above, jnp.where(at_cut, rank_cut, big))
    return jnp.where(rank < k, rank, big).astype(jnp.int32)


def test_retiled_rank_matches_flat_reference(rng):
    """The (sublane, lane) 2-D retile of comparison_free_rank — per-bucket
    lane-reduction histogram + triangular-dot prefix sums — must emit
    bitwise the ranks of the flat-op version it replaced (the kernel and
    the jnp oracle both derive their candidate sets from it)."""
    from repro.core.lop import comparison_free_rank
    for r, m, k in [(1, 64, 8), (6, 128, 5), (8, 256, 32), (3, 128, 128)]:
        s = rng.standard_normal((r, m)).astype(np.float32) * 10
        s[rng.random((r, m)) < 0.1] = -np.inf       # invalid entries
        s[0, : m // 4] = s[0, 0]                    # heavy ties
        got = comparison_free_rank(jnp.asarray(s), k)
        want = _rank_flat_reference(jnp.asarray(s), k, 64)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # fully-invalid rows select nothing
    s = np.full((2, 64), -np.inf, np.float32)
    got = np.asarray(comparison_free_rank(jnp.asarray(s), 4))
    assert (got > 64).all()


def test_comparison_free_topk_recall(rng):
    hits = 0
    trials = 20
    k = 32
    for t in range(trials):
        s = jnp.asarray(rng.standard_normal(512).astype(np.float32))
        idx, gate = comparison_free_topk(s, k, n_buckets=64)
        got = set(np.asarray(idx)[np.asarray(gate)].tolist())
        exact = set(np.asarray(exact_topk(s, k)).tolist())
        hits += len(got & exact)
    recall = hits / (trials * k)
    assert recall > 0.9, recall         # bucketized ≈ exact on random data


def test_topk_respects_validity(rng):
    s = jnp.asarray(rng.standard_normal(128).astype(np.float32)) + 100
    valid = jnp.arange(128) < 40
    idx, gate = comparison_free_topk(s, 16, valid=valid)
    sel = np.asarray(idx)[np.asarray(gate)]
    assert (sel < 40).all()


def test_topk_exact_when_k_equals_m(rng):
    s = jnp.asarray(rng.standard_normal(64).astype(np.float32))
    idx, gate = comparison_free_topk(s, 64)
    assert np.asarray(gate).all()
    assert set(np.asarray(idx).tolist()) == set(range(64))


def test_block_reduce(rng):
    s = jnp.asarray(rng.standard_normal((2, 64)).astype(np.float32))
    b = block_reduce_scores(s, 16)
    assert b.shape == (2, 4)
    assert np.allclose(np.asarray(b)[0, 0],
                       np.asarray(s)[0, :16].max())


def test_kv_traffic_model():
    m, d, keep = 32768, 128, 1 / 8
    k = int(m * keep)
    dense = kv_traffic_bytes(m, d, k, with_lop=False)
    lop = kv_traffic_bytes(m, d, k, with_lop=True)
    assert dense == 2 * m * d
    assert lop == m * d // 2 + 2 * k * d
    # paper Fig 8 regime (features on-chip → only K/V fetches counted)
    assert dense / (2 * k * d) == m / k
