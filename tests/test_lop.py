"""LOP surrogate, features, comparison-free top-K (paper §III-A).

Deterministic cases only — the hypothesis property-based companions live
in test_hypothesis_props.py (skipped when hypothesis is not installed).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lop import (block_reduce_scores, comparison_free_topk,
                            exact_topk, kv_traffic_bytes, leading_one,
                            lop_features, pack_features)


def test_leading_one_exact():
    for v in range(-127, 128):
        lo = int(leading_one(jnp.int8(v)))
        if v == 0:
            assert lo == 7
        else:
            assert lo == int(np.floor(np.log2(abs(v))))


def test_feature_cache_is_half_bytes(rng):
    k = jnp.asarray(rng.integers(-127, 128, (64, 128)), jnp.int8)
    packed = pack_features(lop_features(k))
    assert packed.size * packed.dtype.itemsize == k.size // 2


def test_comparison_free_topk_recall(rng):
    hits = 0
    trials = 20
    k = 32
    for t in range(trials):
        s = jnp.asarray(rng.standard_normal(512).astype(np.float32))
        idx, gate = comparison_free_topk(s, k, n_buckets=64)
        got = set(np.asarray(idx)[np.asarray(gate)].tolist())
        exact = set(np.asarray(exact_topk(s, k)).tolist())
        hits += len(got & exact)
    recall = hits / (trials * k)
    assert recall > 0.9, recall         # bucketized ≈ exact on random data


def test_topk_respects_validity(rng):
    s = jnp.asarray(rng.standard_normal(128).astype(np.float32)) + 100
    valid = jnp.arange(128) < 40
    idx, gate = comparison_free_topk(s, 16, valid=valid)
    sel = np.asarray(idx)[np.asarray(gate)]
    assert (sel < 40).all()


def test_topk_exact_when_k_equals_m(rng):
    s = jnp.asarray(rng.standard_normal(64).astype(np.float32))
    idx, gate = comparison_free_topk(s, 64)
    assert np.asarray(gate).all()
    assert set(np.asarray(idx).tolist()) == set(range(64))


def test_block_reduce(rng):
    s = jnp.asarray(rng.standard_normal((2, 64)).astype(np.float32))
    b = block_reduce_scores(s, 16)
    assert b.shape == (2, 4)
    assert np.allclose(np.asarray(b)[0, 0],
                       np.asarray(s)[0, :16].max())


def test_kv_traffic_model():
    m, d, keep = 32768, 128, 1 / 8
    k = int(m * keep)
    dense = kv_traffic_bytes(m, d, k, with_lop=False)
    lop = kv_traffic_bytes(m, d, k, with_lop=True)
    assert dense == 2 * m * d
    assert lop == m * d // 2 + 2 * k * d
    # paper Fig 8 regime (features on-chip → only K/V fetches counted)
    assert dense / (2 * k * d) == m / k
