"""benchmarks/run.py trajectory gate: unit tests on synthetic payloads.

The gate has a static half — every ``BENCH_*.json`` numeric leaf must
map to a declared kernel+metric through the ``COVERAGE`` registry, and
the autotune table must validate — and a noisy half: each module runs
``--repeats`` times so every leaf yields a sample set, compared against
the previous run's value with a band that is the larger of a
per-metric-kind relative floor and ``MAD_Z`` normalized MADs of the
fresh samples. Both halves are pinned here without running a real
benchmark module.
"""
import json
import subprocess
from pathlib import Path

import pytest

from benchmarks import run as tr

GIT = ["git", "-c", "user.email=t@t", "-c", "user.name=t"]


# ---------------------------------------------------------------------------
# Leaf flattening + the coverage registry
# ---------------------------------------------------------------------------

def test_numeric_leaves_flattening():
    payload = {"a": 1, "b": {"c": 2.5, "d": [3, 4.5]}, "flag": True,
               "s": "text", "nested": [{"x": 7}], "none": None}
    assert tr._numeric_leaves(payload) == {
        "a": 1.0, "b.c": 2.5, "b.d.0": 3.0, "b.d.1": 4.5, "nested.0.x": 7.0}
    assert tr._numeric_leaves({}) == {}


def test_leaf_rule_first_match_wins():
    assert tr._leaf_rule("BENCH_prefix.json", "trace.n_requests") == \
        ("prefill", "workload", "info")
    # the cached_len carve-out matches before the broader per-request glob
    assert tr._leaf_rule("BENCH_prefix.json",
                         "ttft_per_request.cached_len.3") == \
        ("prefill", "count", "info")
    assert tr._leaf_rule("BENCH_prefix.json",
                         "ttft_per_request.cache_on.0") == \
        ("prefill", "time", "info")
    assert tr._leaf_rule("BENCH_proj.json", "proj_layer_step_fused_us") == \
        ("qlinear", "time", "lower")
    assert tr._leaf_rule("BENCH_proj.json", "mystery") is None
    assert tr._leaf_rule("BENCH_unknown.json", "x") is None


def test_committed_bench_files_fully_covered(monkeypatch):
    """The registry maps every leaf of every committed BENCH payload."""
    root = Path(tr.__file__).resolve().parent.parent
    monkeypatch.chdir(root)
    payloads = tr._read_bench()
    assert set(payloads) >= {"BENCH_prefix.json", "BENCH_spec.json"}
    assert tr._coverage_problems(payloads) == []


def test_coverage_problems_synthetic():
    probs = tr._coverage_problems(
        {"BENCH_proj.json": {"proj_dispatches_fused": 1.0, "mystery": 2.0}})
    assert probs == ["BENCH_proj.json:mystery matches no coverage pattern"]
    probs = tr._coverage_problems({"BENCH_unknown.json": {"x": 1.0}})
    assert probs == ["BENCH_unknown.json: no coverage declared"]
    assert tr._coverage_problems({}) == []


# ---------------------------------------------------------------------------
# Noise band + per-leaf verdicts
# ---------------------------------------------------------------------------

def test_noise_band_floors_and_mad():
    # deterministic counts: 5% relative floor, zero MAD
    assert tr._noise_band(100.0, [100.0] * 3, "count") == pytest.approx(5.0)
    # wall-clock kinds get the wide floor
    assert tr._noise_band(100.0, [100.0] * 3, "time") == pytest.approx(35.0)
    # noisy samples widen the band beyond the floor (5σ of 1.4826·MAD)
    band = tr._noise_band(100.0, [150.0, 90.0, 200.0], "time")
    assert band == pytest.approx(tr.MAD_Z * 1.4826 * 50.0)


def test_compare_leaf_verdicts():
    # unchanged → no verdict at all
    assert tr._compare_leaf(10.0, [10.0] * 3, "count", "lower") is None
    # a 20% count move with zero spread is a confirmed regression...
    _, s = tr._compare_leaf(100.0, [120.0] * 3, "count", "lower")
    assert s == "regression"
    # ...an improvement when higher is better...
    _, s = tr._compare_leaf(100.0, [120.0] * 3, "count", "higher")
    assert s == "improved"
    # ...and only informational for workload descriptors
    _, s = tr._compare_leaf(100.0, [120.0] * 3, "count", "info")
    assert s == "moved"
    # the same move on a time leaf sits inside the 35% floor
    _, s = tr._compare_leaf(100.0, [120.0] * 3, "time", "lower")
    assert s == "ok"
    # small count move inside the 5% floor
    _, s = tr._compare_leaf(100.0, [104.0] * 3, "count", "lower")
    assert s == "ok"
    # a big move with matching repeat-to-repeat noise is NOT confirmed
    _, s = tr._compare_leaf(100.0, [150.0, 90.0, 200.0], "time", "lower")
    assert s == "ok"


def test_trajectory_report_regression_new_gone(capsys):
    before = {"BENCH_proj.json": {"proj_dispatches_fused": 10.0,
                                  "proj_dispatches_legacy": 30.0}}
    samples = {"BENCH_proj.json": {
        "proj_dispatches_fused": [20.0, 20.0, 20.0],   # count, lower: bad
        "shapes.d_model": [256.0],                     # not in before
    }}
    n = tr._trajectory_report(before, samples)
    out = capsys.readouterr().out
    assert n == 1
    assert "proj_dispatches_fused 10 -> 20 (+100.0%) REGRESSION" in out
    assert "proj_dispatches_legacy GONE (was 30)" in out
    assert "shapes.d_model NEW = 256" in out


def test_trajectory_report_improvement_not_counted(capsys):
    before = {"BENCH_proj.json": {"proj_dispatches_fused": 20.0}}
    samples = {"BENCH_proj.json": {"proj_dispatches_fused": [10.0] * 3}}
    assert tr._trajectory_report(before, samples) == 0
    assert "improved" in capsys.readouterr().out


def test_trajectory_report_new_file(capsys):
    n = tr._trajectory_report({}, {"BENCH_proj.json":
                                   {"proj_dispatches_fused": [1.0]}})
    assert n == 0
    assert "is new" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# Snapshot: committed version preferred, working tree as fallback
# ---------------------------------------------------------------------------

def test_bench_snapshot_prefers_committed(tmp_path, monkeypatch):
    monkeypatch.chdir(tmp_path)
    path = "BENCH_proj.json"
    (tmp_path / path).write_text(json.dumps({"proj_dispatches_fused": 10}))
    subprocess.run(["git", "init", "-q"], check=True)
    subprocess.run(GIT + ["add", path], check=True)
    subprocess.run(GIT + ["commit", "-qm", "seed"], check=True)
    (tmp_path / path).write_text(json.dumps({"proj_dispatches_fused": 99}))
    snap = tr._bench_snapshot([path])
    assert snap[path]["proj_dispatches_fused"] == 10.0
    # _read_bench always sees the working tree
    assert tr._read_bench([path])[path]["proj_dispatches_fused"] == 99.0


def test_bench_snapshot_working_tree_fallback(tmp_path, monkeypatch):
    monkeypatch.chdir(tmp_path)   # no git repo here → git show fails
    (tmp_path / "BENCH_proj.json").write_text(
        json.dumps({"proj_dispatches_fused": 7}))
    (tmp_path / "BENCH_bad.json").write_text("{not json")
    snap = tr._bench_snapshot(["BENCH_proj.json", "BENCH_bad.json",
                               "BENCH_absent.json"])
    assert snap == {"BENCH_proj.json": {"proj_dispatches_fused": 7.0}}


# ---------------------------------------------------------------------------
# The static gate (--check)
# ---------------------------------------------------------------------------

def test_check_passes_on_covered_payloads(tmp_path, monkeypatch, capsys):
    monkeypatch.chdir(tmp_path)
    monkeypatch.setenv("REPRO_TUNE_TABLE", str(tmp_path / "TUNE_none.json"))
    (tmp_path / "BENCH_proj.json").write_text(json.dumps(
        {"proj_dispatches_fused": 4, "shapes": {"d_model": 64}}))
    assert tr._check() == 0
    assert "OK (0 problem(s))" in capsys.readouterr().out


def test_check_fails_on_uncovered_leaf(tmp_path, monkeypatch, capsys):
    monkeypatch.chdir(tmp_path)
    monkeypatch.setenv("REPRO_TUNE_TABLE", str(tmp_path / "TUNE_none.json"))
    (tmp_path / "BENCH_proj.json").write_text(json.dumps({"mystery": 1}))
    assert tr._check() == 1
    assert "matches no coverage pattern" in capsys.readouterr().out


def test_check_fails_on_invalid_tuning_table(tmp_path, monkeypatch, capsys):
    monkeypatch.chdir(tmp_path)
    bad = tmp_path / "TUNE_kernels.json"
    bad.write_text("{not json")
    monkeypatch.setenv("REPRO_TUNE_TABLE", str(bad))
    assert tr._check() == 1
    assert "tuning table" in capsys.readouterr().out
