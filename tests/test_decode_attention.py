"""Fused batched decode kernel (interpret mode) vs the jnp oracle.

Covers the ``ops.decode_attention`` contract across GQA shapes (MHA and
4-way grouping), shared-select on/off, dense vs LOP, SWA windows, slot
pools with retired lanes (``new_len == 0`` lanes must emit exactly zero),
the SP shard contract (``pos_offset`` + unnormalized stats merge), and the
engine-level flag→config migration (``gqa_shared_select``/``int8_logits``
as ModelConfig fields).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.lop import lop_features, pack_features
from repro.kernels import autotune, ops

rng = np.random.default_rng(7)


def _setup(b, h, hkv, m, dh):
    qi = jnp.asarray(rng.integers(-60, 61, (b, h, dh)), jnp.int8)
    qs = jnp.asarray(rng.uniform(0.005, 0.02, (b, h, 1)), jnp.float32)
    k = jnp.asarray(rng.integers(-60, 61, (b, hkv, m, dh)), jnp.int8)
    v = jnp.asarray(rng.integers(-60, 61, (b, hkv, m, dh)), jnp.int8)
    ks = jnp.asarray(rng.uniform(0.005, 0.02, (b, hkv, m)), jnp.float32)
    vs = jnp.asarray(rng.uniform(0.005, 0.02, (b, hkv, m)), jnp.float32)
    feat = pack_features(lop_features(k))
    return qi, qs, k, v, ks, vs, feat


@pytest.mark.parametrize("h,hkv", [(4, 4), (8, 2)])
@pytest.mark.parametrize("shared", [False, True])
@pytest.mark.parametrize("window", [0, 48])
def test_fused_lop_matches_ref(h, hkv, shared, window):
    b, m, dh, block, k_keep = 2, 256, 32, 32, 3
    args = _setup(b, h, hkv, m, dh)
    new_len = jnp.asarray([197, 64], jnp.int32)
    kw = dict(block=block, k_keep=k_keep, window=window, use_lop=True,
              shared_select=shared)
    o_k = ops.decode_attention(*args, new_len, impl="pallas", **kw)
    o_r = ops.decode_attention(*args, new_len, impl="ref", **kw)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=1e-4)


@pytest.mark.parametrize("h,hkv", [(4, 4), (8, 2)])
@pytest.mark.parametrize("window", [0, 48])
def test_fused_dense_matches_ref(h, hkv, window):
    b, m, dh, block = 2, 256, 32, 32
    args = _setup(b, h, hkv, m, dh)
    new_len = jnp.asarray([211, 32], jnp.int32)
    kw = dict(block=block, k_keep=4, window=window, use_lop=False)
    o_k = ops.decode_attention(*args, new_len, impl="pallas", **kw)
    o_r = ops.decode_attention(*args, new_len, impl="ref", **kw)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=1e-4)


def test_lop_at_full_keep_equals_dense():
    """K = NB candidates → the sparse pipeline is exact (paper's K=M)."""
    b, h, hkv, m, dh, block = 2, 8, 2, 256, 32, 32
    args = _setup(b, h, hkv, m, dh)
    new_len = jnp.asarray([222, 100], jnp.int32)
    o_lop = ops.decode_attention(*args, new_len, block=block,
                                 k_keep=m // block, use_lop=True,
                                 impl="pallas")
    o_dense = ops.decode_attention(*args, new_len, block=block, k_keep=1,
                                   use_lop=False, impl="pallas")
    np.testing.assert_allclose(np.asarray(o_lop), np.asarray(o_dense),
                               atol=1e-4)


@pytest.mark.parametrize("use_lop", [True, False])
@pytest.mark.parametrize("shared", [False, True])
def test_retired_lanes_emit_exact_zero(use_lop, shared):
    """Slot-pool contract: a lane with new_len == 0 (retired / never
    occupied) produces bitwise-zero attention output on BOTH impls, no
    matter what stale bytes its cache rows hold."""
    b, h, hkv, m, dh, block = 3, 8, 2, 128, 32, 32
    args = _setup(b, h, hkv, m, dh)
    new_len = jnp.asarray([90, 0, 0], jnp.int32)     # lanes 1, 2 retired
    kw = dict(block=block, k_keep=2, use_lop=use_lop, shared_select=shared)
    for impl in ("pallas", "ref"):
        out = ops.decode_attention(*args, new_len, impl=impl, **kw)
        assert np.isfinite(np.asarray(out)).all(), impl
        assert (np.asarray(out[1:]) == 0.0).all(), impl
        assert np.abs(np.asarray(out[0])).max() > 0.0, impl


@pytest.mark.parametrize("use_lop", [True, False])
def test_shard_stats_merge_matches_global(use_lop):
    """The SP contract: per-shard calls with pos_offset + return_stats
    merge flash-decoding style into the unsharded result. Dense is exact;
    LOP at full keep (quota K/2 per half) is exact too since every valid
    block still gets selected."""
    b, h, hkv, m, dh, block = 2, 8, 2, 256, 32, 32
    args = _setup(b, h, hkv, m, dh)
    qi, qs, k, v, ks, vs, feat = args
    new_len = jnp.asarray([230, 120], jnp.int32)
    nb = m // block
    o_g = ops.decode_attention(*args, new_len, block=block, k_keep=nb,
                               use_lop=use_lop, impl="pallas")
    half = m // 2
    parts = []
    for sh in range(2):
        sl = slice(sh * half, (sh + 1) * half)
        parts.append(ops.decode_attention(
            qi, qs, k[:, :, sl], v[:, :, sl], ks[:, :, sl], vs[:, :, sl],
            feat[:, :, sl], new_len, block=block, k_keep=nb // 2,
            use_lop=use_lop, pos_offset=sh * half, return_stats=True,
            impl="pallas"))
    (o0, m0, l0), (o1, m1, l1) = parts
    m_g = jnp.maximum(m0, m1)
    w0, w1 = jnp.exp(m0 - m_g), jnp.exp(m1 - m_g)
    l_g = l0 * w0 + l1 * w1
    acc = o0 * (l0 * w0) + o1 * (l1 * w1)
    merged = acc / jnp.maximum(l_g, 1e-20)
    np.testing.assert_allclose(np.asarray(merged), np.asarray(o_g),
                               atol=1e-4)


def test_stats_agree_between_impls():
    b, h, hkv, m, dh, block = 2, 4, 4, 128, 32, 32
    args = _setup(b, h, hkv, m, dh)
    new_len = jnp.asarray([100, 0], jnp.int32)
    kw = dict(block=block, k_keep=2, use_lop=True, return_stats=True)
    o_k, m_k, l_k = ops.decode_attention(*args, new_len, impl="pallas", **kw)
    o_r, m_r, l_r = ops.decode_attention(*args, new_len, impl="ref", **kw)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=1e-4)
    np.testing.assert_allclose(np.asarray(m_k), np.asarray(m_r), atol=1e-4)
    np.testing.assert_allclose(np.asarray(l_k), np.asarray(l_r), rtol=1e-4)
    # retired lane: no live candidates → ℓ = 0 on both impls
    assert (np.asarray(l_k[1]) == 0.0).all()
    assert (np.asarray(l_r[1]) == 0.0).all()


# ---------------------------------------------------------------------------
# Autotune tiling matrix (DESIGN.md §Autotuning)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_slots", [1, 3, 4])
@pytest.mark.parametrize("shared", [False, True])
def test_decode_n_slots_matrix_bitwise(n_slots, shared):
    """Swept candidate-DMA slot counts are pure pipelining: BITWISE the
    default n_slots=2 launch and allclose the ref oracle — at the
    capacity-boundary K-slot (k_keep == n_blocks, every block kept)."""
    b, h, hkv, m, dh, block = 2, 8, 2, 128, 32, 32
    assert autotune.valid_params(
        "decode", {"bhg": b * hkv, "g": h // hkv, "d": dh, "m": m,
                   "block": block, "k_keep": m // block},
        {"n_slots": n_slots})
    args = _setup(b, h, hkv, m, dh)
    new_len = jnp.asarray([97, 64], jnp.int32)
    kw = dict(block=block, k_keep=m // block, use_lop=True,
              shared_select=shared)
    o_ref = ops.decode_attention(*args, new_len, impl="ref", **kw)
    o_def = ops.decode_attention(*args, new_len, impl="pallas", **kw)
    with autotune.override("decode", n_slots=n_slots):
        o_t = ops.decode_attention(*args, new_len, impl="pallas", **kw)
    np.testing.assert_array_equal(np.asarray(o_t), np.asarray(o_def))
    np.testing.assert_allclose(np.asarray(o_t), np.asarray(o_ref),
                               atol=1e-4)


def test_decode_single_row_lane_n_slots():
    """bm = 1 decode: a single-lane, single-group (B=1, MHA h=1) launch
    stays exact across every slot count."""
    b, h, hkv, m, dh, block = 1, 1, 1, 64, 32, 32
    args = _setup(b, h, hkv, m, dh)
    new_len = jnp.asarray([39], jnp.int32)
    kw = dict(block=block, k_keep=2, use_lop=True)
    o_ref = ops.decode_attention(*args, new_len, impl="ref", **kw)
    for ns in (1, 2, 3):
        with autotune.override("decode", n_slots=ns):
            o_t = ops.decode_attention(*args, new_len, impl="pallas", **kw)
        np.testing.assert_allclose(np.asarray(o_t), np.asarray(o_ref),
                                   atol=1e-4, err_msg=f"n_slots={ns}")


# ---------------------------------------------------------------------------
# Engine-level: flag→config migration
# ---------------------------------------------------------------------------

def _engine_cell(cfg):
    from repro.models.transformer import init_params
    from repro.serving.engine import prefill, serve_step
    from repro.serving.quantize import quantize_params
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    qp = quantize_params(cfg, params)
    r = np.random.default_rng(9)
    tokens = jnp.asarray(r.integers(0, cfg.vocab, (2, 21)), jnp.int32)
    logits_full, _ = prefill(cfg, qp, tokens, max_len=24)
    _, cache = prefill(cfg, qp, tokens[:, :20], max_len=24)
    logits_dec, _ = serve_step(cfg, qp, cache, tokens[:, 20:21])
    return logits_full, logits_dec


def test_config_fields_replace_env_flags():
    """gqa_shared_select / int8_logits as ModelConfig fields steer the
    decode path without any env var: shared selection at keep=1.0 stays
    exact, and integer-domain prefill logits match the f32 path."""
    from tests.test_models_smoke import _reduced
    cfg = _reduced("mistral-nemo-12b").replace(lop_keep=1.0)
    base_full, base_dec = _engine_cell(
        cfg.replace(gqa_shared_select=False, int8_logits=False))
    flag_full, flag_dec = _engine_cell(
        cfg.replace(gqa_shared_select=True, int8_logits=True))
    rel_dec = float(jnp.max(jnp.abs(flag_dec - base_dec))
                    / (jnp.max(jnp.abs(base_dec)) + 1e-9))
    rel_full = float(jnp.linalg.norm(flag_full - base_full)
                     / (jnp.linalg.norm(base_full) + 1e-9))
    assert rel_dec < 1e-5, rel_dec
    assert rel_full < 1e-4, rel_full


def test_resolve_decode_flags_pins_fields(monkeypatch):
    from repro.configs.base import resolve_decode_flags
    from tests.test_models_smoke import _reduced
    cfg = _reduced("stablelm-1.6b")
    assert cfg.gqa_shared_select is None and cfg.int8_logits is None
    monkeypatch.setenv("REPRO_GQA_SHARED_SELECT", "1")
    monkeypatch.delenv("REPRO_INT8_LOGITS", raising=False)
    r = resolve_decode_flags(cfg)
    assert r.gqa_shared_select is True and r.int8_logits is False
    # explicit fields win over the env
    r2 = resolve_decode_flags(cfg.replace(gqa_shared_select=False,
                                          int8_logits=True))
    assert r2.gqa_shared_select is False and r2.int8_logits is True
    # already-pinned configs pass through untouched
    assert resolve_decode_flags(r2) is r2
