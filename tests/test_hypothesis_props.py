"""Property-based cases for core LOP / ternary / quantization invariants.

Split out of test_lop.py / test_ternary.py / test_quantization.py so those
modules' deterministic tests collect even when ``hypothesis`` is absent —
this whole module skips instead of killing collection.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")

import hypothesis.extra.numpy as hnp   # noqa: E402
import hypothesis.strategies as st     # noqa: E402
import jax                             # noqa: E402
import jax.numpy as jnp                # noqa: E402
import numpy as np                     # noqa: E402

from repro.core.lop import (features_to_pot, lop_features,  # noqa: E402
                            lop_scores, pack_features, pot, unpack_features)
from repro.core.quantization import dequantize, quantize    # noqa: E402
from repro.core.ternary import (pack_ternary, ternary_quantize,  # noqa: E402
                                unpack_ternary)

int8_vecs = hnp.arrays(np.int8, st.tuples(st.integers(2, 16).map(
    lambda d: 2 * d),), elements=st.integers(-127, 127))

ternary_mats = hnp.arrays(
    np.int8,
    st.tuples(st.integers(1, 16).map(lambda k: 4 * k), st.integers(1, 24)),
    elements=st.sampled_from([-1, 0, 1]))

finite_vecs = hnp.arrays(
    np.float32, hnp.array_shapes(min_dims=1, max_dims=3, min_side=1,
                                 max_side=32),
    elements=st.floats(-1e4, 1e4, width=32))


# ---------------------------------------------------------------------------
# LOP (paper §III-A)
# ---------------------------------------------------------------------------

@hypothesis.given(int8_vecs)
@hypothesis.settings(max_examples=50, deadline=None)
def test_surrogate_equals_pot_dot(x):
    """ŝ(q,k) = Σ sgn·sgn·2^(LO+LO) ≡ dot(pot(q), pot(k)) — the key
    TPU-mapping identity."""
    q = jnp.asarray(x)
    k = jnp.asarray(np.roll(x, 1))[None]
    s = int(lop_scores(q, k)[0])
    manual = sum(
        int(np.sign(a) * np.sign(b)) *
        2 ** (int(np.floor(np.log2(abs(a)))) + int(np.floor(np.log2(abs(b)))))
        for a, b in zip(np.asarray(q).tolist(), np.roll(x, 1).tolist())
        if a != 0 and b != 0)
    assert s == manual


@hypothesis.given(int8_vecs)
@hypothesis.settings(max_examples=50, deadline=None)
def test_feature_roundtrip(x):
    k = jnp.asarray(x)[None]
    f = lop_features(k)
    assert (np.asarray(features_to_pot(f)) == np.asarray(pot(k))).all()
    assert (np.asarray(unpack_features(pack_features(f))) ==
            np.asarray(f)).all()


# ---------------------------------------------------------------------------
# Ternary packing (BitNet b1.58)
# ---------------------------------------------------------------------------

@hypothesis.given(ternary_mats)
@hypothesis.settings(max_examples=50, deadline=None)
def test_pack_unpack_roundtrip(wt):
    packed = pack_ternary(jnp.asarray(wt))
    assert packed.shape == (wt.shape[0] // 4, wt.shape[1])
    back = np.asarray(unpack_ternary(packed, wt.shape[0]))
    assert (back == wt).all()


@hypothesis.given(hnp.arrays(np.float32, (8, 12),
                             elements=st.floats(-10, 10, width=32)))
@hypothesis.settings(max_examples=50, deadline=None)
def test_ternary_quantize_values(w):
    wt, gamma = ternary_quantize(jnp.asarray(w))
    vals = np.unique(np.asarray(wt))
    assert set(vals.tolist()) <= {-1, 0, 1}
    assert float(np.asarray(gamma).squeeze()) > 0   # γ is [1,1] (keepdims)


# ---------------------------------------------------------------------------
# Absmax quantization barrier
# ---------------------------------------------------------------------------

@hypothesis.given(finite_vecs)
@hypothesis.settings(max_examples=50, deadline=None)
def test_quantize_error_bound(x):
    """|dequant(quant(x)) − x| ≤ scale/2 (+eps) — the absmax contract."""
    qt = quantize(jnp.asarray(x))
    err = np.abs(np.asarray(dequantize(qt)) - x)
    bound = np.asarray(qt.scale) * 0.5 + 1e-6
    assert (err <= np.broadcast_to(bound, err.shape) + 1e-6).all()


@hypothesis.given(finite_vecs)
@hypothesis.settings(max_examples=25, deadline=None)
def test_quantize_int8_range(x):
    qt = quantize(jnp.asarray(x))
    v = np.asarray(qt.values)
    assert v.dtype == np.int8
    assert v.min() >= -127 and v.max() <= 127
