"""Property-based cases for core LOP / ternary / quantization invariants.

Split out of test_lop.py / test_ternary.py / test_quantization.py so those
modules' deterministic tests collect even when ``hypothesis`` is absent —
this whole module skips instead of killing collection.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")

import hypothesis.extra.numpy as hnp   # noqa: E402
import hypothesis.strategies as st     # noqa: E402
import jax                             # noqa: E402
import jax.numpy as jnp                # noqa: E402
import numpy as np                     # noqa: E402

from repro.core.lop import (features_to_pot, lop_features,  # noqa: E402
                            lop_scores, pack_features, pot, unpack_features)
from repro.core.quantization import (EPS, INT8_MAX,         # noqa: E402
                                     dequantize, quantize)
from repro.core.ternary import (pack_ternary, ternary_quantize,  # noqa: E402
                                unpack_ternary)

int8_vecs = hnp.arrays(np.int8, st.tuples(st.integers(2, 16).map(
    lambda d: 2 * d),), elements=st.integers(-127, 127))

ternary_mats = hnp.arrays(
    np.int8,
    st.tuples(st.integers(1, 16).map(lambda k: 4 * k), st.integers(1, 24)),
    elements=st.sampled_from([-1, 0, 1]))

finite_vecs = hnp.arrays(
    np.float32, hnp.array_shapes(min_dims=1, max_dims=3, min_side=1,
                                 max_side=32),
    elements=st.floats(-1e4, 1e4, width=32))


# ---------------------------------------------------------------------------
# LOP (paper §III-A)
# ---------------------------------------------------------------------------

@hypothesis.given(int8_vecs)
@hypothesis.settings(max_examples=50, deadline=None)
def test_surrogate_equals_pot_dot(x):
    """ŝ(q,k) = Σ sgn·sgn·2^(LO+LO) ≡ dot(pot(q), pot(k)) — the key
    TPU-mapping identity."""
    q = jnp.asarray(x)
    k = jnp.asarray(np.roll(x, 1))[None]
    s = int(lop_scores(q, k)[0])
    manual = sum(
        int(np.sign(a) * np.sign(b)) *
        2 ** (int(np.floor(np.log2(abs(a)))) + int(np.floor(np.log2(abs(b)))))
        for a, b in zip(np.asarray(q).tolist(), np.roll(x, 1).tolist())
        if a != 0 and b != 0)
    assert s == manual


@hypothesis.given(int8_vecs)
@hypothesis.settings(max_examples=50, deadline=None)
def test_feature_roundtrip(x):
    k = jnp.asarray(x)[None]
    f = lop_features(k)
    assert (np.asarray(features_to_pot(f)) == np.asarray(pot(k))).all()
    assert (np.asarray(unpack_features(pack_features(f))) ==
            np.asarray(f)).all()


# ---------------------------------------------------------------------------
# Ternary packing (BitNet b1.58)
# ---------------------------------------------------------------------------

@hypothesis.given(ternary_mats)
@hypothesis.settings(max_examples=50, deadline=None)
def test_pack_unpack_roundtrip(wt):
    packed = pack_ternary(jnp.asarray(wt))
    assert packed.shape == (wt.shape[0] // 4, wt.shape[1])
    back = np.asarray(unpack_ternary(packed, wt.shape[0]))
    assert (back == wt).all()


@hypothesis.given(hnp.arrays(np.float32, (8, 12),
                             elements=st.floats(-10, 10, width=32)))
@hypothesis.settings(max_examples=50, deadline=None)
def test_ternary_quantize_values(w):
    wt, gamma = ternary_quantize(jnp.asarray(w))
    vals = np.unique(np.asarray(wt))
    assert set(vals.tolist()) <= {-1, 0, 1}
    assert float(np.asarray(gamma).squeeze()) > 0   # γ is [1,1] (keepdims)


# ---------------------------------------------------------------------------
# Absmax quantization barrier
# ---------------------------------------------------------------------------

@hypothesis.given(finite_vecs)
@hypothesis.settings(max_examples=50, deadline=None)
def test_quantize_error_bound(x):
    """|dequant(quant(x)) − x| ≤ scale/2 (+eps) — the absmax contract."""
    qt = quantize(jnp.asarray(x))
    err = np.abs(np.asarray(dequantize(qt)) - x)
    bound = np.asarray(qt.scale) * 0.5 + 1e-6
    assert (err <= np.broadcast_to(bound, err.shape) + 1e-6).all()


@hypothesis.given(finite_vecs)
@hypothesis.settings(max_examples=25, deadline=None)
def test_quantize_int8_range(x):
    qt = quantize(jnp.asarray(x))
    v = np.asarray(qt.values)
    assert v.dtype == np.int8
    assert v.min() >= -127 and v.max() <= 127


# ---------------------------------------------------------------------------
# Autotuned tiling variants (DESIGN.md §Autotuning)
# ---------------------------------------------------------------------------

@hypothesis.given(st.data())
@hypothesis.settings(max_examples=50, deadline=None)
def test_ktiled_absmax_equals_single_pass_bitwise(data):
    """The two-pass k-tiled barrier (kernels/qlinear.py, bkq > 0): fold
    per-tile absmax maxima, freeze the scale, then quantize tile-by-tile
    — BITWISE the single-pass absmax quantize for EVERY (k, bk) split,
    because f32 max is exact and round/clip are elementwise against the
    frozen scale."""
    k = data.draw(st.integers(1, 24).map(lambda d: 4 * d), label="k")
    bk = data.draw(st.sampled_from(
        [d for d in range(1, k + 1) if k % d == 0]), label="bk")
    m = data.draw(st.integers(1, 6), label="m")
    x = data.draw(hnp.arrays(np.float32, (m, k),
                             elements=st.floats(-1e4, 1e4, width=32)))
    want = quantize(jnp.asarray(x))
    am = jnp.zeros((m, 1), jnp.float32)
    for j in range(k // bk):
        tile = jnp.asarray(x[:, j * bk:(j + 1) * bk])
        am = jnp.maximum(am, jnp.max(jnp.abs(tile), axis=-1, keepdims=True))
    scale = jnp.maximum(am, EPS).astype(jnp.float32) / INT8_MAX
    tiles = [jnp.clip(jnp.round(jnp.asarray(x[:, j * bk:(j + 1) * bk])
                                .astype(jnp.float32) / scale),
                      -INT8_MAX, INT8_MAX).astype(jnp.int8)
             for j in range(k // bk)]
    assert (np.asarray(scale) == np.asarray(want.scale)).all()
    assert (np.asarray(jnp.concatenate(tiles, -1)) ==
            np.asarray(want.values)).all()


@hypothesis.given(st.data())
@hypothesis.settings(max_examples=10, deadline=None)
def test_prefill_query_row_tiling_bitwise(data):
    """The prefill kernel's third grid axis (bq query-row tiles): every
    legal bq is BITWISE the untiled launch — the kv gate is loose enough
    to be row-independent, so masked folds are exact no-ops."""
    from repro.kernels.prefill_attention import fused_prefill_attention
    r, d, m, block, chunk = 16, 8, 32, 16, 8
    seed = data.draw(st.integers(0, 2 ** 16), label="seed")
    bq = data.draw(st.sampled_from([1, 2, 4, 8, 16]), label="bq")
    kv_len_v = data.draw(st.integers(0, m), label="kv_len")
    r_ = np.random.default_rng(seed)
    qi = jnp.asarray(r_.integers(-127, 128, (1, r, d)), jnp.int8)
    qsc = jnp.asarray(r_.uniform(0.005, 0.02, (1, r, 1)), jnp.float32)
    kc = jnp.asarray(r_.integers(-127, 128, (1, m, d)), jnp.int8)
    vc = jnp.asarray(r_.integers(-127, 128, (1, m, d)), jnp.int8)
    ks = jnp.asarray(r_.uniform(0.005, 0.02, (1, m, 1)), jnp.float32)
    vs = jnp.asarray(r_.uniform(0.005, 0.02, (1, m, 1)), jnp.float32)
    kv_len = jnp.asarray([kv_len_v], jnp.int32)
    po = jnp.zeros((1,), jnp.int32)
    kw = dict(hkv=1, chunk=chunk, block=block, causal=True, window=0,
              softmax_scale=d ** -0.5, interpret=True)
    whole = fused_prefill_attention(qi, qsc, kc, vc, ks, vs, kv_len, po,
                                    bq=0, **kw)
    tiled = fused_prefill_attention(qi, qsc, kc, vc, ks, vs, kv_len, po,
                                    bq=bq, **kw)
    assert (np.asarray(tiled) == np.asarray(whole)).all()
