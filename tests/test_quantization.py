"""Absmax quantization barrier: deterministic cases + STE.

The hypothesis property-based companions live in test_hypothesis_props.py
(skipped when hypothesis is not installed).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantization import (dequantize, fake_quantize, int8_matmul,
                                     online_softmax_stats, quantize, rmsnorm,
                                     ste_quantize)


def test_quantize_error_bound_deterministic(rng):
    """|dequant(quant(x)) − x| ≤ scale/2 (+eps) — the absmax contract."""
    x = (rng.standard_normal((4, 16, 32)) * 1e3).astype(np.float32)
    qt = quantize(jnp.asarray(x))
    v = np.asarray(qt.values)
    assert v.dtype == np.int8 and v.min() >= -127 and v.max() <= 127
    err = np.abs(np.asarray(dequantize(qt)) - x)
    bound = np.asarray(qt.scale) * 0.5 + 1e-6
    assert (err <= np.broadcast_to(bound, err.shape) + 1e-6).all()


def test_ste_gradient_is_identity_shaped(rng):
    x = jnp.asarray(rng.standard_normal((4, 16)).astype(np.float32))
    g = jax.grad(lambda a: jnp.sum(ste_quantize(a) ** 2))(x)
    # STE: d/dx sum(fq(x)^2) ≈ 2*fq(x) (straight-through)
    expect = 2 * fake_quantize(x)
    assert np.allclose(np.asarray(g), np.asarray(expect), atol=1e-5)


def test_int8_matmul_matches_dequantized(rng):
    x = jnp.asarray(rng.standard_normal((8, 32)).astype(np.float32))
    wq = jnp.asarray(rng.integers(-127, 128, (32, 16)), jnp.int8)
    w_scale = jnp.float32(0.01)
    xq = quantize(x)
    y = int8_matmul(xq, wq, w_scale)
    y_ref = dequantize(xq) @ (wq.astype(np.float32) * 0.01)
    assert np.allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-5,
                       atol=1e-5)


def test_rmsnorm_f32_reduction(rng):
    x = jnp.asarray(rng.standard_normal((2, 8, 64)).astype(np.float32)) * 10
    g = jnp.ones((64,))
    y = rmsnorm(x, g)
    ms = np.mean(np.square(np.asarray(y)), -1)
    assert np.allclose(ms, 1.0, rtol=1e-3)


def test_online_softmax_stats(rng):
    logits = jnp.asarray(rng.standard_normal((4, 128)).astype(np.float32))
    m, s = online_softmax_stats(logits)
    p = np.exp(np.asarray(logits) - np.asarray(m)) / np.asarray(s)
    assert np.allclose(p.sum(-1), 1.0, rtol=1e-5)
    assert np.allclose(p, np.asarray(jax.nn.softmax(logits, -1)), atol=1e-6)
