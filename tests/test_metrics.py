"""Observability core: registry semantics, Prometheus rendering, the
shared percentile helper, StageTimer spans, and the scheduler's metric
families (DESIGN.md §Serving-metrics).

The registry is the ONE definition of every serving metric name —
``launch/serve.py`` summaries and the HTTP server's ``/metrics`` scrape
both read it, so a driver run and a live server are diffable.
"""

import math

import numpy as np
import pytest

from repro.serving import metrics
from repro.serving.metrics import (MetricsRegistry, StageTimer, percentile,
                                   summarize)

# ---------------------------------------------------------------------------
# percentile / summarize — the dedupe target
# ---------------------------------------------------------------------------


def test_percentile_matches_numpy_linear_interpolation():
    rng = np.random.default_rng(0)
    xs = rng.standard_normal(37).tolist()
    for q in (0, 25, 50, 90, 99, 100):
        assert percentile(xs, q) == float(np.percentile(xs, q))


def test_percentile_empty_is_nan():
    assert math.isnan(percentile([], 50))


def test_summarize_keys_and_prefix():
    out = summarize([1.0, 2.0, 3.0], (50, 99), prefix="ttft_")
    assert set(out) == {"ttft_p50", "ttft_p99"}
    assert out["ttft_p50"] == 2.0


# ---------------------------------------------------------------------------
# Registry: counters / gauges / histograms, labels, merge, render
# ---------------------------------------------------------------------------


def test_counter_gauge_histogram_roundtrip():
    reg = MetricsRegistry()
    c = reg.counter("t_total", "help", ("k",))
    c.labels(k="a").inc()
    c.labels(k="a").inc(2)
    c.labels(k="b").inc()
    g = reg.gauge("t_depth", "help")
    g.set(7)
    g.dec(3)
    h = reg.histogram("t_seconds", "help", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    assert reg.value("t_total", {"k": "a"}) == 3
    assert reg.value("t_total", {"k": "b"}) == 1
    assert reg.value("t_depth") == 4
    assert reg.value("t_seconds") == pytest.approx(5.55)  # _sum


def test_reregistration_is_idempotent_but_kind_checked():
    reg = MetricsRegistry()
    a = reg.counter("x_total", "help")
    b = reg.counter("x_total", "other help")
    assert a is b
    with pytest.raises(AssertionError):
        reg.gauge("x_total", "now a gauge")


def test_histogram_buckets_cumulative_in_render():
    reg = MetricsRegistry()
    h = reg.histogram("lat_seconds", "h", buckets=(0.1, 1.0))
    for v in (0.05, 0.05, 0.5, 5.0):
        h.observe(v)
    text = reg.render()
    assert 'lat_seconds_bucket{le="0.1"} 2' in text
    assert 'lat_seconds_bucket{le="1"} 3' in text
    assert 'lat_seconds_bucket{le="+Inf"} 4' in text
    assert "lat_seconds_count 4" in text
    assert "# TYPE lat_seconds histogram" in text


def test_render_prometheus_text_shape():
    reg = MetricsRegistry()
    reg.counter("a_total", "things done", ("mode",)).labels(
        mode="fast").inc()
    reg.gauge("b_now", "current").set(2.5)
    text = reg.render()
    assert "# HELP a_total things done" in text
    assert "# TYPE a_total counter" in text
    assert 'a_total{mode="fast"} 1' in text
    assert "# TYPE b_now gauge" in text
    assert "b_now 2.5" in text
    # families render sorted — stable scrape diffs
    names = [l.split()[2] for l in text.splitlines()
             if l.startswith("# TYPE")]
    assert names == sorted(names)


def test_merge_adds_counters_and_histograms():
    a, b = MetricsRegistry(), MetricsRegistry()
    for reg, n in ((a, 2), (b, 3)):
        c = reg.counter("m_total", "h", ("k",))
        c.labels(k="x").inc(n)
        h = reg.histogram("m_seconds", "h", buckets=(1.0,))
        h.observe(0.5)
        reg.gauge("m_depth", "h").set(n)
    a.merge(b)
    assert a.value("m_total", {"k": "x"}) == 5
    assert "m_seconds_count 2" in a.render()
    assert a.value("m_depth") == 3          # gauges take the newer value


def test_histogram_quantile_estimate_brackets_truth():
    reg = MetricsRegistry()
    h = reg.histogram("q_seconds", "h", buckets=(0.01, 0.1, 1.0, 10.0))
    rng = np.random.default_rng(1)
    xs = rng.uniform(0.02, 0.9, 500)
    for v in xs:
        h.observe(float(v))
    est = h.labels().quantile(0.5)
    assert 0.01 <= est <= 1.0               # within the bracketing buckets


# ---------------------------------------------------------------------------
# StageTimer
# ---------------------------------------------------------------------------


def test_stage_timer_spans():
    ticks = iter([0.0, 1.0, 1.0, 3.0, 3.0, 6.0])
    t = StageTimer(clock=lambda: next(ticks))
    t.enter("queue")
    t.to("prefill")
    t.to("decode")
    spans = t.finish()
    assert spans == {"queue": 1.0, "prefill": 2.0, "decode": 3.0}


def test_stage_timer_reentry_accumulates():
    ticks = iter([0.0, 1.0, 1.0, 2.0, 2.0, 5.0])
    t = StageTimer(clock=lambda: next(ticks))
    t.enter("decode")
    t.to("prefill")
    t.to("decode")
    assert t.finish()["decode"] == 1.0 + 3.0


# ---------------------------------------------------------------------------
# Scheduler integration: families exist and move on a real trace
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def served():
    import jax

    from repro.models.transformer import init_params
    from repro.serving.api import GenerateRequest
    from repro.serving.quantize import quantize_params
    from repro.serving.scheduler import Scheduler

    from tests.test_models_smoke import _reduced

    cfg = _reduced("bitnet-3b")
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    qp = quantize_params(cfg, params)
    reg = MetricsRegistry()
    sched = Scheduler(cfg, qp, n_slots=2, max_len=63, metrics=reg)
    rng = np.random.default_rng(3)
    for rid, n in enumerate((9, 14, 11)):
        sched.submit(GenerateRequest(
            rid=rid, prompt=rng.integers(0, cfg.vocab, (n,)).astype(
                np.int32), max_new_tokens=5))
    sched.run_to_completion()
    return sched, reg


def test_scheduler_publishes_request_outcomes(served):
    sched, reg = served
    assert reg.value("repro_requests_total", {"outcome": "length"}) == 3
    assert reg.value("repro_tokens_generated_total") == 15
    assert reg.value("repro_requests_shed_total") == 0


def test_scheduler_publishes_stage_and_latency_histograms(served):
    _, reg = served
    text = reg.render()
    for stage in ("queue", "prefill", "decode"):
        assert f'repro_request_stage_seconds_bucket{{stage="{stage}"' \
            in text, stage
    assert "repro_request_ttft_seconds_count 3" in text
    assert "repro_request_e2e_seconds_count 3" in text
    # 5 tokens/request -> 4 inter-token gaps each
    assert "repro_request_itl_seconds_count 12" in text


def test_scheduler_counts_prefill_token_provenance(served):
    sched, reg = served
    computed = reg.value("repro_prefill_tokens_total",
                         {"source": "computed"})
    assert computed == sched.prefill_tokens_computed > 0


def test_default_registry_is_process_wide():
    from repro.serving.metrics import REGISTRY
    assert isinstance(REGISTRY, MetricsRegistry)
    c = REGISTRY.counter("test_selfcheck_total", "scratch")
    before = REGISTRY.value("test_selfcheck_total")
    c.inc()
    assert REGISTRY.value("test_selfcheck_total") == before + 1
