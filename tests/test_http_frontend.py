"""HTTP serving front-end: token exactness over real sockets, SSE
framing, disconnect cancellation, overload 429s, deadline 504s and the
/metrics scrape (DESIGN.md §Serving-frontend).

The load-bearing guarantee: the transport adds NOTHING to sampling —
tokens streamed over loopback HTTP are byte-identical to
:func:`repro.serving.scheduler.lockstep_generate` for greedy AND seeded
sampled requests. Runs under both REPRO_KERNEL_IMPL arms via
scripts/ci_tier1.sh.
"""

import json
import socket
import time
from contextlib import contextmanager

import jax
import numpy as np
import pytest

from repro.models.transformer import init_params
from repro.serving.api import PooledEngine
from repro.serving.frontend import serve_threaded
from repro.serving.metrics import MetricsRegistry
from repro.serving.quantize import quantize_params
from repro.serving.scheduler import Scheduler, lockstep_generate

from tests.test_models_smoke import _reduced

MAX_LEN = 63


@pytest.fixture(scope="module")
def stack():
    cfg = _reduced("bitnet-3b")
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    qp = quantize_params(cfg, params)
    engine = PooledEngine(cfg, qp, max_len=MAX_LEN)
    return cfg, qp, engine


class _SlowDecode:
    """Engine proxy that stretches every decode step — makes the
    disconnect/overload races deterministic without touching timings
    anywhere else."""

    def __init__(self, inner, delay_s=0.02):
        self._inner = inner
        self._delay = delay_s

    def __getattr__(self, name):
        return getattr(self._inner, name)

    def decode_step(self, *a, **kw):
        time.sleep(self._delay)
        return self._inner.decode_step(*a, **kw)


@contextmanager
def _server(cfg, qp, engine=None, *, n_slots=2, **sched_kw):
    reg = MetricsRegistry()
    sched = Scheduler(cfg, qp, n_slots=n_slots, max_len=MAX_LEN,
                      engine=engine, metrics=reg, **sched_kw)
    srv = serve_threaded(sched, model_name="bitnet-test", registry=reg)
    try:
        yield srv, sched, reg
    finally:
        srv.close()


def _prompt(cfg, n=9, seed=3):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab, (n,)).astype(np.int32)


def _post(port, body, *, path="/v1/completions", method="POST",
          timeout=120):
    """One request, response fully read. Returns (status, headers, body)."""
    payload = json.dumps(body).encode() if body is not None else b""
    s = socket.create_connection(("127.0.0.1", port), timeout=timeout)
    s.sendall(f"{method} {path} HTTP/1.1\r\nHost: t\r\n"
              f"Content-Length: {len(payload)}\r\n\r\n".encode() + payload)
    raw = b""
    while True:
        chunk = s.recv(65536)
        if not chunk:
            break
        raw += chunk
    s.close()
    head, _, body = raw.partition(b"\r\n\r\n")
    lines = head.decode("latin-1").split("\r\n")
    status = int(lines[0].split(" ")[1])
    headers = dict(l.split(": ", 1) for l in lines[1:] if ": " in l)
    return status, headers, body


def _sse_tokens(body: bytes):
    """Parse an SSE byte stream -> (tokens, saw_done, error_frames)."""
    tokens, done, errors = [], False, []
    for frame in body.decode().split("\n\n"):
        is_error = any(l.strip() == "event: error"
                       for l in frame.split("\n"))
        for line in frame.split("\n"):
            if not line.startswith("data: "):
                continue
            data = line[6:]
            if data == "[DONE]":
                done = True
            elif is_error:
                errors.append(json.loads(data))
            else:
                tokens.append(json.loads(data)["choices"][0]["token"])
    return tokens, done, errors


# ---------------------------------------------------------------------------
# Token exactness over the wire — the acceptance criterion
# ---------------------------------------------------------------------------


def test_greedy_stream_matches_lockstep_bitwise(stack):
    cfg, qp, engine = stack
    p = _prompt(cfg)
    with _server(cfg, qp, engine) as (srv, _, _):
        status, _, body = _post(srv.port, {
            "prompt": [int(t) for t in p], "max_tokens": 6,
            "stream": True})
    assert status == 200
    tokens, done, errors = _sse_tokens(body)
    assert done and not errors
    ref = lockstep_generate(cfg, qp, p, 6, max_len=MAX_LEN, engine=engine)
    assert tokens == list(ref)


def test_sampled_seeded_stream_matches_lockstep(stack):
    from repro.serving.api import SamplingParams

    cfg, qp, engine = stack
    p = _prompt(cfg, n=12, seed=7)
    sp = SamplingParams(temperature=0.9, top_k=8, seed=13)
    with _server(cfg, qp, engine) as (srv, _, _):
        status, _, body = _post(srv.port, {
            "prompt": [int(t) for t in p], "max_tokens": 6, "stream": True,
            "temperature": 0.9, "top_k": 8, "seed": 13})
    assert status == 200
    tokens, done, _ = _sse_tokens(body)
    assert done
    ref = lockstep_generate(cfg, qp, p, 6, max_len=MAX_LEN, sampling=sp,
                            engine=engine)
    assert tokens == list(ref)


def test_unary_completion_matches_lockstep_with_usage(stack):
    cfg, qp, engine = stack
    p = _prompt(cfg, n=10, seed=5)
    with _server(cfg, qp, engine) as (srv, _, _):
        status, _, body = _post(srv.port, {
            "prompt": [int(t) for t in p], "max_tokens": 5})
    assert status == 200
    obj = json.loads(body)
    ref = lockstep_generate(cfg, qp, p, 5, max_len=MAX_LEN, engine=engine)
    assert obj["choices"][0]["tokens"] == list(ref)
    assert obj["choices"][0]["finish_reason"] == "length"
    assert obj["usage"] == {"prompt_tokens": 10, "completion_tokens": 5,
                            "cached_prompt_tokens": 0}


def test_concurrent_streams_each_match_lockstep(stack):
    import threading

    cfg, qp, engine = stack
    prompts = [_prompt(cfg, n=n, seed=s)
               for n, s in ((9, 1), (14, 2), (11, 3), (8, 4))]
    outs = [{} for _ in prompts]

    def go(i):
        status, _, body = _post(srv.port, {
            "prompt": [int(t) for t in prompts[i]], "max_tokens": 5,
            "stream": True})
        outs[i].update(status=status, body=body)

    with _server(cfg, qp, engine) as (srv, _, _):
        threads = [threading.Thread(target=go, args=(i,))
                   for i in range(len(prompts))]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=300)
    for p, out in zip(prompts, outs):
        assert out["status"] == 200
        tokens, done, _ = _sse_tokens(out["body"])
        assert done
        ref = lockstep_generate(cfg, qp, p, 5, max_len=MAX_LEN,
                                engine=engine)
        assert tokens == list(ref)


# ---------------------------------------------------------------------------
# Disconnect -> cancel, overload -> 429, deadline -> 504
# ---------------------------------------------------------------------------


def test_mid_stream_disconnect_cancels_lane_and_frees_slot(stack):
    cfg, qp, engine = stack
    slow = _SlowDecode(engine, delay_s=0.02)
    p = _prompt(cfg)
    with _server(cfg, qp, slow) as (srv, sched, reg):
        body = json.dumps({"prompt": [int(t) for t in p],
                           "max_tokens": 50, "stream": True}).encode()
        s = socket.create_connection(("127.0.0.1", srv.port), timeout=120)
        s.sendall(b"POST /v1/completions HTTP/1.1\r\nHost: t\r\n"
                  b"Content-Length: %d\r\n\r\n%s" % (len(body), body))
        got = b""
        while got.count(b"\n\n") < 2:          # ~2 tokens of a 50-token run
            got += s.recv(4096)
        s.close()                              # client walks away

        deadline = time.monotonic() + 30
        while sched.has_work() or not sched.results:
            assert time.monotonic() < deadline, "lane never retired"
            time.sleep(0.02)
        res = sched.results[-1]
        assert res.finish_reason == "cancelled"
        assert len(res.tokens) < 50            # cut off mid-stream
        assert sched.n_active == 0             # lane retired...
        assert len(sched._free) == 2           # ...and the slot is back
        assert reg.value("repro_requests_total",
                         {"outcome": "cancelled"}) == 1


def test_overload_returns_429_with_retry_after(stack):
    import threading

    cfg, qp, engine = stack
    slow = _SlowDecode(engine, delay_s=0.02)
    outs = {}

    def go(name, n_tokens):
        outs[name] = _post(srv.port, {
            "prompt": [int(t) for t in _prompt(cfg, seed=ord(name[0]))],
            "max_tokens": n_tokens, "stream": True})

    with _server(cfg, qp, slow, n_slots=1, max_queue=1) as (srv, _, reg):
        a = threading.Thread(target=go, args=("a", 30))
        a.start()
        deadline = time.monotonic() + 30
        while not srv.frontend.sched.n_active:   # a holds the only lane
            assert time.monotonic() < deadline
            time.sleep(0.01)
        b = threading.Thread(target=go, args=("b", 3))
        b.start()
        deadline = time.monotonic() + 30
        while not len(srv.frontend.sched.queue):  # b parked in the queue
            assert time.monotonic() < deadline
            time.sleep(0.005)
        status, headers, body = _post(srv.port, {
            "prompt": [int(t) for t in _prompt(cfg, seed=9)],
            "max_tokens": 3})                  # queue full -> shed
        a.join(timeout=300)
        b.join(timeout=300)
    assert status == 429
    assert headers.get("Retry-After") == "1"
    assert json.loads(body)["error"]["code"] == 429
    assert reg.value("repro_requests_shed_total") == 1
    assert outs["a"][0] == 200 and outs["b"][0] == 200


def test_expired_deadline_is_504_not_a_hang(stack):
    cfg, qp, engine = stack
    p = _prompt(cfg)
    with _server(cfg, qp, engine) as (srv, _, reg):
        status, _, body = _post(srv.port, {
            "prompt": [int(t) for t in p], "max_tokens": 5,
            "deadline_ms": 0.001, "stream": True})
        assert status == 504
        assert json.loads(body)["error"]["type"] == "deadline_expired"
        status2, _, body2 = _post(srv.port, {
            "prompt": [int(t) for t in p], "max_tokens": 5,
            "deadline_ms": 0.001})
        assert status2 == 504
    assert reg.value("repro_deadline_expired_total") == 2


# ---------------------------------------------------------------------------
# Validation, routing, observability endpoints
# ---------------------------------------------------------------------------


def test_validation_rejects_before_touching_the_scheduler(stack):
    cfg, qp, engine = stack
    with _server(cfg, qp, engine) as (srv, sched, _):
        cases = [
            {"prompt": "text"},                          # not token ids
            {"prompt": []},                              # empty
            {"prompt": [1, 2], "max_tokens": 0},         # no budget
            {"prompt": [1, 2], "max_tokens": MAX_LEN + 60},  # > capacity
            {"prompt": [int(cfg.vocab) + 5]},            # out of vocab
            {"prompt": [1, 2], "temperature": -1.0},
            {"prompt": [1, 2], "deadline_ms": -5},
        ]
        for body in cases:
            status, _, raw = _post(srv.port, body)
            assert status == 400, body
            assert "error" in json.loads(raw), body
        assert not sched.results                # nothing ever submitted
        status, _, _ = _post(srv.port, None, path="/nope", method="GET")
        assert status == 404
        status, _, _ = _post(srv.port, None, method="GET")
        assert status == 405


def test_healthz_and_models(stack):
    cfg, qp, engine = stack
    with _server(cfg, qp, engine) as (srv, _, _):
        status, _, body = _post(srv.port, None, path="/healthz",
                                method="GET")
        assert status == 200
        health = json.loads(body)
        assert health["status"] == "ok"
        assert health["active_lanes"] == 0
        status, _, body = _post(srv.port, None, path="/v1/models",
                                method="GET")
        assert status == 200
        models = json.loads(body)
        assert models["data"][0]["id"] == "bitnet-test"


def test_metrics_endpoint_exports_stage_histograms_and_counters(stack):
    cfg, qp, engine = stack
    p = _prompt(cfg)
    with _server(cfg, qp, engine) as (srv, _, _):
        _post(srv.port, {"prompt": [int(t) for t in p], "max_tokens": 4})
        status, headers, body = _post(srv.port, None, path="/metrics",
                                      method="GET")
    assert status == 200
    assert headers["Content-Type"].startswith("text/plain")
    text = body.decode()
    for stage in ("queue", "prefill", "decode"):
        assert f'repro_request_stage_seconds_bucket{{stage="{stage}"' \
            in text
    assert 'repro_requests_total{outcome="length"} 1' in text
    assert "repro_request_ttft_seconds_count 1" in text
    assert 'repro_http_requests_total{route="/v1/completions",' \
        'code="200"} 1' in text
