"""Fused TINT projection path (DESIGN.md §TINT-projection-fusion).

Every case pins the tentpole contract: the one-dispatch fused entries
(`ops.qlinear_fused` / `ops.ffn_fused`, barrier + packed-ternary GEMM +
epilogue in one kernel) are **bitwise** the unfused chain they replaced
(jnp absmax quantize → `ops.ternary_matmul` → jnp dequant/bias/act),
under BOTH dispatch arms, across the shapes the engine actually runs:
decode GEMV rows (b = 1..4), prefill chunk rows, fused-QKV segment
splits, whole-FFN gated/ungated, and grouped expert stacks.

All comparisons run jitted end to end: XLA compiles the absmax division
differently inside a fused computation than as a standalone eager op
(1-ulp scale difference), so bitwise equality is defined — as in the
engine — under jit.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantization import quantize
from repro.core.ternary import TernaryWeight, make_ternary_weight
from repro.kernels import autotune, ops
from repro.kernels.qlinear import apply_act

rng = np.random.default_rng(7)

ARMS = ("ref", "pallas")


def _node(k, n, scale=0.02):
    w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32) * scale
    return make_ternary_weight(w)


def _unfused(tw, x, bias=None, act=None):
    """The pre-fusion chain, written out (the equivalence oracle)."""
    xq = quantize(x)
    acc = ops.ternary_matmul(xq.values, tw, impl="ref")
    y = acc.astype(jnp.float32) * xq.scale * jnp.asarray(
        tw.scale, jnp.float32).reshape(())
    if bias is not None:
        y = y + bias
    return apply_act(y, act)


@pytest.mark.parametrize("m", [1, 2, 3, 4, 48, 130])
@pytest.mark.parametrize("impl", ARMS)
def test_qlinear_fused_bitwise_vs_unfused(m, impl):
    """Decode GEMV rows (m = B ≤ 4) and prefill-chunk rows (m = B·C)."""
    k, n = 128, 96
    tw = _node(k, n)
    b = jnp.asarray(rng.standard_normal((n,)), jnp.float32) * 0.1
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    want = jax.jit(lambda x: _unfused(tw, x, bias=b))(x)
    got = jax.jit(lambda x: ops.qlinear_fused(
        x, tw.packed, jnp.asarray(tw.scale).reshape(1, 1), b,
        impl=impl))(x)
    assert (np.asarray(got) == np.asarray(want)).all()


@pytest.mark.parametrize("impl", ARMS)
def test_qlinear_fused_leading_dims(impl):
    """Engine shapes are [B, S, k] — lead dims flatten inside the op."""
    k, n = 64, 128
    tw = _node(k, n)
    x = jnp.asarray(rng.standard_normal((2, 5, k)), jnp.float32)
    want = jax.jit(lambda x: _unfused(tw, x))(x)
    got = jax.jit(lambda x: ops.qlinear_fused(
        x, tw.packed, jnp.asarray(tw.scale).reshape(1, 1), impl=impl))(x)
    assert got.shape == (2, 5, n)
    assert (np.asarray(got) == np.asarray(want)).all()


@pytest.mark.parametrize("impl", ARMS)
@pytest.mark.parametrize("m", [1, 4, 16])
def test_fused_qkv_segments_bitwise(m, impl):
    """One fused QKV dispatch == three per-projection dispatches, per
    segment, bitwise — the per-column γ row carries each segment's scalar."""
    k, nq, nkv = 128, 96, 32
    tws = [_node(k, n) for n in (nq, nkv, nkv)]
    packed = jnp.concatenate([t.packed for t in tws], -1)
    scale = jnp.concatenate(
        [jnp.broadcast_to(jnp.asarray(t.scale, jnp.float32).reshape(1, 1),
                          (1, t.shape[1])) for t in tws], -1)
    bias = jnp.asarray(rng.standard_normal((nq + 2 * nkv,)),
                       jnp.float32) * 0.1
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)

    got = jax.jit(lambda x: ops.qlinear_fused(x, packed, scale, bias,
                                              impl=impl))(x)
    off = 0
    for tw, n in zip(tws, (nq, nkv, nkv)):
        want = jax.jit(
            lambda x, tw=tw, o=off, n=n: _unfused(tw, x,
                                                  bias=bias[o:o + n]))(x)
        assert (np.asarray(got[..., off:off + n]) ==
                np.asarray(want)).all(), (off, n)
        off += n


@pytest.mark.parametrize("impl", ARMS)
@pytest.mark.parametrize("gated,act", [(True, "silu"), (False, "gelu"),
                                       (True, "squared_relu")])
def test_ffn_fused_bitwise_vs_unfused(gated, act, impl):
    """Whole-FFN fusion: act(x·Wg)·(x·Wu) → hidden absmax barrier → ·Wd
    in one dispatch == the three-dispatch unfused chain, bitwise."""
    d, f, m = 128, 192, 5
    twu, twd = _node(d, f, 0.05), _node(f, d, 0.05)
    twg = _node(d, f, 0.05) if gated else None

    def unfused(x):
        if gated:
            h = apply_act(_unfused(twg, x), act) * _unfused(twu, x)
        else:
            h = apply_act(_unfused(twu, x), act)
        return _unfused(twd, h)

    if gated:
        gu_packed = jnp.concatenate([twg.packed, twu.packed], -1)
        gu_scale = jnp.concatenate(
            [jnp.broadcast_to(jnp.asarray(t.scale).reshape(1, 1), (1, f))
             for t in (twg, twu)], -1)
    else:
        gu_packed = twu.packed
        gu_scale = jnp.asarray(twu.scale).reshape(1, 1)

    x = jnp.asarray(rng.standard_normal((m, d)), jnp.float32)
    want = jax.jit(unfused)(x)
    got = jax.jit(lambda x: ops.ffn_fused(
        x, gu_packed, gu_scale, twd.packed,
        jnp.asarray(twd.scale).reshape(1, 1), gated=gated, act=act,
        impl=impl))(x)
    assert (np.asarray(got) == np.asarray(want)).all()


def _expert_stack(e, k, n, scale=0.05):
    packs, scales = [], []
    for _ in range(e):
        tw = _node(k, n, scale)
        packs.append(tw.packed)
        scales.append(jnp.asarray(tw.scale).reshape(1, 1))
    return jnp.stack(packs), jnp.stack(scales)


@pytest.mark.parametrize("impl", ARMS)
def test_grouped_expert_qlinear_bitwise(impl):
    """Expert-as-grid-axis grouped GEMM == the per-expert vmap chain."""
    e, c, k, n = 4, 6, 64, 96
    packed, scale = _expert_stack(e, k, n)
    x = jnp.asarray(rng.standard_normal((e, c, k)), jnp.float32)

    def per_expert(x):
        def one(xe, pe, se):
            tw = TernaryWeight(packed=pe, scale=1.0, shape=(k, n))
            xq = quantize(xe)
            acc = ops.ternary_matmul(xq.values, tw, impl="ref")
            return acc.astype(jnp.float32) * xq.scale * se.reshape(())
        return jax.vmap(one)(x, packed, scale)

    want = jax.jit(per_expert)(x)
    got = jax.jit(lambda x: ops.qlinear_fused(x, packed, scale,
                                              impl=impl))(x)
    assert (np.asarray(got) == np.asarray(want)).all()


@pytest.mark.parametrize("impl", ARMS)
def test_grouped_expert_ffn_bitwise(impl):
    """A whole MoE layer's expert FFNs as ONE dispatch == the per-expert
    per-projection chain, bitwise."""
    e, c, d, f = 3, 5, 64, 128
    gp, gs = _expert_stack(e, d, f)
    up, us = _expert_stack(e, d, f)
    dp_, ds = _expert_stack(e, f, d)
    gu_packed = jnp.concatenate([gp, up], -1)
    gu_scale = jnp.concatenate([jnp.broadcast_to(gs, (e, 1, f)),
                                jnp.broadcast_to(us, (e, 1, f))], -1)
    x = jnp.asarray(rng.standard_normal((e, c, d)), jnp.float32)

    def per_expert(x):
        def one(xe, a, b_, c_, ga, gb, gc):
            def lin(p, g, h):
                tw = TernaryWeight(packed=p, scale=1.0,
                                   shape=(p.shape[0] * 4, p.shape[1]))
                hq = quantize(h)
                acc = ops.ternary_matmul(hq.values, tw, impl="ref")
                return acc.astype(jnp.float32) * hq.scale * g.reshape(())
            h = jax.nn.silu(lin(a, ga, xe)) * lin(b_, gb, xe)
            return lin(c_, gc, h)
        return jax.vmap(one)(x, gp, up, dp_, gs, us, ds)

    want = jax.jit(per_expert)(x)
    got = jax.jit(lambda x: ops.ffn_fused(x, gu_packed, gu_scale, dp_, ds,
                                          gated=True, act="silu",
                                          impl=impl))(x)
    assert (np.asarray(got) == np.asarray(want)).all()


# ---------------------------------------------------------------------------
# Autotune tiling matrix (DESIGN.md §Autotuning): every swept block shape
# is a pure tiling choice — bitwise the same oracle, under both arms
# ---------------------------------------------------------------------------

QLINEAR_TILINGS = [
    # (bm, bn, bkq, eg): single-pass barrier at small tiles; the two-pass
    # k-tiled barrier; k-tiling + expert grouping; whole-e group with
    # bkq == k (one k-tile, degenerate two-pass)
    (8, 32, 0, 1),
    (8, 96, 16, 1),
    (16, 96, 32, 2),
    (8, 48, 64, 4),
]


@pytest.mark.parametrize("impl", ARMS)
@pytest.mark.parametrize("bm,bn,bkq,eg", QLINEAR_TILINGS)
def test_qlinear_tiling_matrix_bitwise(bm, bn, bkq, eg, impl):
    """Every swept (bm, bn, bkq, eg) — including the two-pass k-tiled
    absmax barrier — dispatches bitwise-equal to the per-expert unfused
    oracle under an autotune.override, both arms."""
    e, c, k, n = 4, 6, 64, 96
    params = {"bm": bm, "bn": bn, "bkq": bkq, "eg": eg}
    assert autotune.valid_params(
        "qlinear", {"e": e, "m": c, "k": k, "n": n}, params)
    packed, scale = _expert_stack(e, k, n)
    x = jnp.asarray(rng.standard_normal((e, c, k)), jnp.float32)

    def per_expert(x):
        def one(xe, pe, se):
            tw = TernaryWeight(packed=pe, scale=1.0, shape=(k, n))
            xq = quantize(xe)
            acc = ops.ternary_matmul(xq.values, tw, impl="ref")
            return acc.astype(jnp.float32) * xq.scale * se.reshape(())
        return jax.vmap(one)(x, packed, scale)

    want = jax.jit(per_expert)(x)
    with autotune.override("qlinear", **params):
        got = jax.jit(lambda x: ops.qlinear_fused(x, packed, scale,
                                                  impl=impl))(x)
    assert (np.asarray(got) == np.asarray(want)).all()


FFN_TILINGS = [
    # (bm, bf, bn, bkq): default-ish; fine hidden tiles + k-tiled
    # barrier; coarse everything with bkq == k
    (8, 64, 32, 0),
    (8, 192, 64, 16),
    (16, 96, 16, 64),
]


@pytest.mark.parametrize("impl", ARMS)
@pytest.mark.parametrize("bm,bf,bn,bkq", FFN_TILINGS)
def test_ffn_tiling_matrix_bitwise(bm, bf, bn, bkq, impl):
    """Swept FFN tilings (incl. the k-tiled input barrier) == the
    three-dispatch unfused chain, bitwise, both arms."""
    d, f, m = 64, 192, 5
    params = {"bm": bm, "bf": bf, "bn": bn, "bkq": bkq}
    assert autotune.valid_params(
        "ffn", {"e": 1, "m": m, "k": d, "f": f, "n": d}, params)
    twu, twd = _node(d, f, 0.05), _node(f, d, 0.05)
    twg = _node(d, f, 0.05)

    def unfused(x):
        h = apply_act(_unfused(twg, x), "silu") * _unfused(twu, x)
        return _unfused(twd, h)

    gu_packed = jnp.concatenate([twg.packed, twu.packed], -1)
    gu_scale = jnp.concatenate(
        [jnp.broadcast_to(jnp.asarray(t.scale).reshape(1, 1), (1, f))
         for t in (twg, twu)], -1)
    x = jnp.asarray(rng.standard_normal((m, d)), jnp.float32)
    want = jax.jit(unfused)(x)
    with autotune.override("ffn", **params):
        got = jax.jit(lambda x: ops.ffn_fused(
            x, gu_packed, gu_scale, twd.packed,
            jnp.asarray(twd.scale).reshape(1, 1), gated=True, act="silu",
            impl=impl))(x)
    assert (np.asarray(got) == np.asarray(want)).all()


def test_both_arms_agree():
    """ref and pallas arms of the fused entries are interchangeable."""
    k, n = 256, 128
    tw = _node(k, n)
    sc = jnp.asarray(tw.scale).reshape(1, 1)
    x = jnp.asarray(rng.standard_normal((4, k)), jnp.float32)
    a = jax.jit(lambda x: ops.qlinear_fused(x, tw.packed, sc,
                                            impl="ref"))(x)
    b = jax.jit(lambda x: ops.qlinear_fused(x, tw.packed, sc,
                                            impl="pallas"))(x)
    assert (np.asarray(a) == np.asarray(b)).all()


# ---------------------------------------------------------------------------
# Engine-level: fused serving tree == legacy per-projection tree
# ---------------------------------------------------------------------------

def _logits_check(lf, ll):
    """Fused-vs-legacy logits equality, arm-aware.

    The ref arm (the production CPU dispatch) is bitwise. Under the
    interpret-mode pallas arm the FFN's transcendentals (exp inside
    silu/gelu) take shape-dependent SIMD paths on CPU — the in-kernel
    [bm, bf] tile vs the legacy [B, S, f] array — and repeated absmax
    requantization amplifies that 1-ulp drift into an occasional int8
    flip across layers (the knife-edge kernels/ref.py documents). There
    the contract is greedy-token equality plus tightly-close logits; the
    bitwise guarantee at the op level is pinned by the tests above.
    """
    import os
    arm = os.environ.get("REPRO_KERNEL_IMPL") or \
        ("pallas" if jax.default_backend() == "tpu" else "ref")
    a, b = np.asarray(lf), np.asarray(ll)
    if arm == "ref":
        assert (a == b).all()
    else:
        assert (np.argmax(a, -1) == np.argmax(b, -1)).all()
        np.testing.assert_allclose(a, b, atol=0.1, rtol=0.02)


@pytest.mark.parametrize("arch", ["bitnet-3b", "granite-moe-1b-a400m"])
def test_engine_fused_tree_matches_legacy(arch):
    """quantize_params(fuse=True) serves the same tokens (bitwise logits
    under the ref arm) as the legacy one-node-per-projection tree,
    through prefill AND decode — the end-to-end guarantee behind the
    dispatch-count drop."""
    from tests.test_models_smoke import _reduced
    from repro.models.transformer import init_params
    from repro.serving.engine import prefill, serve_step
    from repro.serving.quantize import quantize_params

    cfg = _reduced(arch)
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    qp = quantize_params(cfg, params)
    qp_legacy = quantize_params(cfg, params, fuse=False)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)), jnp.int32)

    pf = jax.jit(lambda qp, t: prefill(cfg, qp, t, max_len=24))
    lf, cache_f = pf(qp, toks)
    ll, cache_l = pf(qp_legacy, toks)
    _logits_check(lf, ll)

    step = jax.jit(lambda qp, c, t: serve_step(cfg, qp, c, t))
    tok = jnp.argmax(lf, -1)[:, None].astype(jnp.int32)
    for _ in range(3):
        lf, cache_f = step(qp, cache_f, tok)
        ll, cache_l = step(qp_legacy, cache_l, tok)
        _logits_check(lf, ll)
        tok = jnp.argmax(lf, -1)[:, None].astype(jnp.int32)


def test_fused_qkv_node_layout():
    """quantize_params packs QKV codes [k//4, nq+2nkv] with per-segment
    per-column γ, and the whole-FFN node carries gate‖up + down streams."""
    from tests.test_models_smoke import _reduced
    from repro.models.transformer import init_params
    from repro.serving.quantize import quantize_params

    cfg = _reduced("bitnet-3b")
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    qp = quantize_params(cfg, params)
    wqkv = qp["layers"]["attn"]["wqkv"]
    n = cfg.q_dim + 2 * cfg.kv_dim
    assert wqkv["packed"].dtype == jnp.uint8
    assert wqkv["packed"].shape[-2:] == (cfg.d_model // 4, n)
    assert wqkv["scale"].shape[-2:] == (1, n)
    # each segment's γ row is constant (one scalar per code stream)
    seg = np.asarray(wqkv["scale"])[0]
    for lo, hi in ((0, cfg.q_dim), (cfg.q_dim, cfg.q_dim + cfg.kv_dim)):
        assert (seg[..., lo:hi] == seg[..., lo:lo + 1]).all()
    ffn = qp["layers"]["ffn"]
    assert ffn["gu_packed"].shape[-1] == 2 * cfg.d_ff
    assert ffn["down_packed"].shape[-2:] == (cfg.d_ff // 4, cfg.d_model)
