"""Head-level streaming schedule ≡ materialized schedule (paper §III-B)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.schedule import (materialized_mha, standard_softmax_attention,
                                 streamed_mha)

rng = np.random.default_rng(3)


@pytest.mark.parametrize("group", [1, 2, 4])
def test_streamed_equals_materialized(group):
    b, s, d, h, hd = 2, 16, 64, 4, 16
    x = jnp.asarray(rng.standard_normal((b, s, d)), jnp.float32)
    ws = [jnp.asarray(rng.standard_normal((d, h * hd)), jnp.float32) * 0.1
          for _ in range(3)]
    wo = jnp.asarray(rng.standard_normal((h * hd, d)), jnp.float32) * 0.1
    y1 = materialized_mha(x, *ws, wo, n_heads=h, head_dim=hd,
                          attn_fn=standard_softmax_attention)
    y2 = streamed_mha(x, *ws, wo, n_heads=h, head_dim=hd,
                      attn_fn=standard_softmax_attention, group=group)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)


def test_chunked_attention_matches_reference():
    from repro.models.attention import chunked_attention
    b, s, h, hkv, dh = 2, 48, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((b, s, h, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, dh)), jnp.float32)
    o = chunked_attention(q, k, v, causal=True, chunk=16)
    # reference with GQA repeat
    kr = jnp.repeat(k, h // hkv, axis=2)
    vr = jnp.repeat(v, h // hkv, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, kr) / np.sqrt(dh)
    mask = jnp.tril(jnp.ones((s, s), bool))
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jnp.exp(logits - logits.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    o_ref = jnp.einsum("bhqk,bkhd->bqhd", p, vr)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=1e-4)


def test_chunked_attention_swa_window():
    from repro.models.attention import chunked_attention
    b, s, h, dh, w = 1, 64, 2, 8, 16
    q = jnp.asarray(rng.standard_normal((b, s, h, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, h, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, h, dh)), jnp.float32)
    o_w = chunked_attention(q, k, v, causal=True, window=w, chunk=16)
    # position s-1 must ignore keys < s-w
    logits = jnp.einsum("hd,khd->hk", q[0, -1], k[0]) / np.sqrt(dh)
    kpos = jnp.arange(s)
    keep = (kpos <= s - 1) & (s - 1 - kpos < w)
    logits = jnp.where(keep[None], logits, -1e30)
    p = jax_softmax = jnp.exp(logits - logits.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    o_ref = jnp.einsum("hk,khd->hd", p, v[0])
    np.testing.assert_allclose(np.asarray(o_w[0, -1]), np.asarray(o_ref),
                               atol=1e-4)
