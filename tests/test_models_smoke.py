"""Per-architecture smoke: reduced config, one forward + one train step on
CPU, asserting output shapes and no NaNs (brief requirement)."""
import importlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import all_configs, get_config
from repro.models.transformer import forward_train, init_params
from repro.training.optimizer import adamw_init
from repro.training.train import make_train_step

ARCH_MODULES = {
    "mixtral-8x22b": "mixtral_8x22b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "whisper-small": "whisper_small",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "llava-next-34b": "llava_next_34b",
    "qwen1.5-32b": "qwen1_5_32b",
    "stablelm-1.6b": "stablelm_1_6b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "qwen1.5-110b": "qwen1_5_110b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "bitnet-3b": "bitnet_3b",
}


def _reduced(arch):
    return importlib.import_module(
        f"repro.configs.{ARCH_MODULES[arch]}").REDUCED


def _batch(cfg, b=2, t=16, key=0):
    rng = np.random.default_rng(key)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, t)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (b, t)), jnp.int32),
    }
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, 2 * t, cfg.d_model)), jnp.float32) * 0.02
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.standard_normal((b, cfg.n_img_tokens, cfg.d_model)),
            jnp.float32) * 0.02
    return batch


@pytest.mark.parametrize("arch", sorted(ARCH_MODULES))
def test_forward_shapes_and_finite(arch):
    cfg = _reduced(arch)
    params, pspecs = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux = forward_train(cfg, params, batch["tokens"],
                                frames=batch.get("frames"),
                                patches=batch.get("patches"))
    b, t = batch["tokens"].shape
    assert logits.shape == (b, t, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(float(aux))
    # pspec tree mirrors params exactly
    pl = jax.tree.leaves(params)
    sl = jax.tree.leaves(pspecs, is_leaf=lambda x: isinstance(x, tuple))
    assert len(pl) == len(sl)
    for arr, spec in zip(pl, sl):
        assert len(spec) == arr.ndim


@pytest.mark.parametrize("arch", sorted(ARCH_MODULES))
def test_one_train_step(arch):
    cfg = _reduced(arch)
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, total_steps=10))
    p2, opt2, metrics = step(params, opt, _batch(cfg))
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    delta = max(float(jnp.max(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(p2)))
    assert delta > 0


def test_full_configs_registered():
    cfgs = all_configs()
    from repro.configs.base import ASSIGNED
    for arch in ASSIGNED:
        assert arch in cfgs, arch
    assert "bitnet-3b" in cfgs
    # exact brief numbers spot-check
    mx = get_config("mixtral-8x22b")
    assert (mx.n_layers, mx.d_model, mx.n_heads, mx.n_kv_heads,
            mx.d_ff, mx.vocab, mx.n_experts, mx.top_k) == (
        56, 6144, 48, 8, 16384, 32768, 8, 2)
    qw = get_config("qwen1.5-110b")
    assert (qw.n_layers, qw.d_model, qw.n_heads, qw.n_kv_heads, qw.d_ff,
            qw.vocab) == (80, 8192, 64, 8, 49152, 152064)
    assert qw.qkv_bias
    jm = get_config("jamba-1.5-large-398b")
    assert jm.family == "hybrid" and jm.attn_every == 8
    rw = get_config("rwkv6-1.6b")
    assert rw.family == "ssm" and not rw.use_lop
