"""Serving engine: prefill/decode consistency, LOP exactness, generation,
slot-paged cache pool semantics."""
import importlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.transformer import init_params
from repro.serving.cache import (cache_pspecs, evict_slot, free_slots,
                                 init_cache, init_cache_pool, insert_slot,
                                 pool_capacity)
from repro.serving.engine import prefill, serve_step
from repro.serving.quantize import quantize_params

from tests.test_models_smoke import ARCH_MODULES, _reduced

CONSISTENCY_ARCHS = ["mixtral-8x22b", "whisper-small",
                     "jamba-1.5-large-398b", "llava-next-34b",
                     "rwkv6-1.6b", "bitnet-3b"]


def _inputs(cfg, b, s, key=1):
    rng = np.random.default_rng(key)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    kw = {}
    if cfg.family == "encdec":
        kw["frames"] = jnp.asarray(rng.standard_normal((b, 48, cfg.d_model)),
                                   jnp.float32) * 0.05
    if cfg.family == "vlm":
        kw["patches"] = jnp.asarray(
            rng.standard_normal((b, cfg.n_img_tokens, cfg.d_model)),
            jnp.float32) * 0.05
    return tokens, kw


@pytest.mark.parametrize("arch", CONSISTENCY_ARCHS)
def test_prefill_plus_decode_equals_full_prefill(arch):
    """With lop_keep=1.0 the sparse decode path is exact: prefill(S) +
    serve_step == prefill(S+1) (the paper's no-retraining guarantee at
    K=M)."""
    cfg = _reduced(arch).replace(lop_keep=1.0, capacity_factor=8.0)
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    qp = quantize_params(cfg, params)
    B, S = 2, 20
    tokens, kw = _inputs(cfg, B, S + 1)

    logits_full, _ = prefill(cfg, qp, tokens, max_len=S + 2, **kw)
    _, cache = prefill(cfg, qp, tokens[:, :S], max_len=S + 2, **kw)
    logits_dec, cache2 = serve_step(cfg, qp, cache, tokens[:, S:S + 1])

    ref = float(jnp.max(jnp.abs(logits_full))) + 1e-9
    err = float(jnp.max(jnp.abs(logits_dec - logits_full)))
    assert err / ref < 2e-2, (arch, err, ref)
    assert np.isfinite(np.asarray(logits_dec)).all()


def test_sparse_decode_finite_and_close():
    cfg = _reduced("bitnet-3b").replace(lop_keep=0.5)
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    qp = quantize_params(cfg, params)
    tokens, kw = _inputs(cfg, 2, 21)
    logits_full, _ = prefill(cfg, qp, tokens, max_len=24, **kw)
    _, cache = prefill(cfg, qp, tokens[:, :20], max_len=24, **kw)
    logits_sp, _ = serve_step(cfg, qp, cache, tokens[:, 20:21])
    rel = float(jnp.linalg.norm(logits_sp - logits_full)
                / (jnp.linalg.norm(logits_full) + 1e-9))
    assert np.isfinite(np.asarray(logits_sp)).all()
    assert rel < 0.5, rel


def test_greedy_generation_deterministic():
    cfg = _reduced("stablelm-1.6b")
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    qp = quantize_params(cfg, params)
    tokens, _ = _inputs(cfg, 2, 8)

    def gen():
        logits, cache = prefill(cfg, qp, tokens, max_len=8 + 8)
        out = []
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        for _ in range(8):
            out.append(np.asarray(tok))
            logits, cache = serve_step(cfg, qp, cache, tok)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        return np.concatenate(out, 1)

    a, b = gen(), gen()
    assert (a == b).all()
    assert (a >= 0).all() and (a < cfg.vocab_padded).all()


def test_swa_window_limits_decode_attention():
    """Mixtral SWA: tokens beyond the (depth-stacked) receptive field must
    not affect decode. With 1 layer + window W, the decode step at position
    S sees K/V from [S−W, S), which themselves depend on tokens ≥ S−2W."""
    cfg = _reduced("mixtral-8x22b").replace(lop_keep=1.0, swa_window=16,
                                            capacity_factor=8.0, n_layers=1)
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    qp = quantize_params(cfg, params)
    S = 40
    cut = S - 2 * cfg.swa_window  # = 8: outside the receptive field
    tokens, _ = _inputs(cfg, 1, S + 1)
    tok2 = tokens.at[:, :cut].set((tokens[:, :cut] + 1) % cfg.vocab)
    _, c1 = prefill(cfg, qp, tokens[:, :S], max_len=S + 2)
    _, c2 = prefill(cfg, qp, tok2[:, :S], max_len=S + 2)
    l1, _ = serve_step(cfg, qp, c1, tokens[:, S:S + 1])
    l2, _ = serve_step(cfg, qp, c2, tokens[:, S:S + 1])
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-4)
    # sanity: in-window changes DO affect the logits
    tok3 = tokens.at[:, S - 4].set((tokens[:, S - 4] + 1) % cfg.vocab)
    _, c3 = prefill(cfg, qp, tok3[:, :S], max_len=S + 2)
    l3, _ = serve_step(cfg, qp, c3, tokens[:, S:S + 1])
    assert float(jnp.max(jnp.abs(l3 - l1))) > 1e-3


def test_init_cache_shapes():
    cfg = _reduced("jamba-1.5-large-398b")
    cache = init_cache(cfg, 2, 100)
    n_sb = cfg.n_layers // cfg.attn_every
    cap = -(-101 // cfg.lop_block) * cfg.lop_block
    assert cache["blocks"]["attn"]["k"].shape == (
        n_sb, 2, cfg.n_kv_heads, cap, cfg.hd)
    assert cache["blocks"]["mamba"]["ssm"].shape == (
        n_sb, cfg.attn_every - 1, 2, cfg.d_inner, cfg.mamba_d_state)
    assert cache["blocks"]["attn"]["feat"].shape[-1] == cfg.hd // 2


# ---------------------------------------------------------------------------
# Slot-paged pool
# ---------------------------------------------------------------------------

MAX_LEN = 63          # capacity 64 with the reduced lop_block of 32


def _pool_setup(arch="bitnet-3b", **over):
    cfg = _reduced(arch)
    if over:
        cfg = cfg.replace(**over)
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, quantize_params(cfg, params)


def _solo_tokens(cfg, qp, prompt, gen, use_lop=True):
    logits, cache = prefill(cfg, qp, prompt[None], max_len=MAX_LEN,
                            use_lop=use_lop)
    toks = [int(jnp.argmax(logits[0]))]
    for _ in range(gen):
        tok = jnp.asarray([[toks[-1]]], jnp.int32)
        logits, cache = serve_step(cfg, qp, cache, tok, use_lop=use_lop)
        toks.append(int(jnp.argmax(logits[0])))
    return toks


def _pool_decode(cfg, qp, pool, first_toks, gen, use_lop=True):
    """Greedy-decode every active lane of ``pool`` together."""
    n = pool["lengths"].shape[0]
    tok = np.zeros((n, 1), np.int32)
    out = {s: [t] for s, t in first_toks.items()}
    for s, t in first_toks.items():
        tok[s, 0] = t
    for _ in range(gen):
        logits, pool = serve_step(cfg, qp, pool, jnp.asarray(tok),
                                  use_lop=use_lop)
        for s in out:
            t = int(jnp.argmax(logits[s]))
            out[s].append(t)
            tok[s, 0] = t
    return out, pool


def test_variable_length_pool_matches_per_request_lockstep():
    """Lanes with different lengths decode together exactly as each request
    does alone — the slot-paged engine's core invariant."""
    cfg, qp = _pool_setup()
    rng = np.random.default_rng(11)
    prompts = {0: rng.integers(0, cfg.vocab, (13,)).astype(np.int32),
               2: rng.integers(0, cfg.vocab, (29,)).astype(np.int32)}
    pool = init_cache_pool(cfg, 3, MAX_LEN)          # lane 1 stays empty
    first = {}
    for slot, p in prompts.items():
        logits, req_cache = prefill(cfg, qp, p[None], max_len=MAX_LEN)
        pool = insert_slot(pool, jnp.int32(slot), req_cache)
        first[slot] = int(jnp.argmax(logits[0]))
    assert free_slots(pool) == [1]
    out, pool = _pool_decode(cfg, qp, pool, first, gen=6)
    np.testing.assert_array_equal(np.asarray(pool["lengths"]),
                                  [13 + 6, 0, 29 + 6])
    for slot, p in prompts.items():
        assert out[slot] == _solo_tokens(cfg, qp, p, 6), slot


def test_evict_insert_reuse_matches_fresh_cache():
    cfg, qp = _pool_setup()
    rng = np.random.default_rng(12)
    a = rng.integers(0, cfg.vocab, (45,)).astype(np.int32)
    b = rng.integers(0, cfg.vocab, (10,)).astype(np.int32)
    pool = init_cache_pool(cfg, 2, MAX_LEN)
    la, ca = prefill(cfg, qp, a[None], max_len=MAX_LEN)
    pool = insert_slot(pool, jnp.int32(0), ca)
    out, pool = _pool_decode(cfg, qp, pool,
                             {0: int(jnp.argmax(la[0]))}, gen=5)
    pool = evict_slot(pool, jnp.int32(0))
    assert free_slots(pool) == [0, 1]
    lb, cb = prefill(cfg, qp, b[None], max_len=MAX_LEN)
    pool = insert_slot(pool, jnp.int32(0), cb)
    reused, _ = _pool_decode(cfg, qp, pool,
                             {0: int(jnp.argmax(lb[0]))}, gen=5)
    fresh_pool = insert_slot(init_cache_pool(cfg, 2, MAX_LEN),
                             jnp.int32(0), cb)
    fresh, _ = _pool_decode(cfg, qp, fresh_pool,
                            {0: int(jnp.argmax(lb[0]))}, gen=5)
    assert reused[0] == fresh[0]


def test_slot_paged_lop_agrees_with_dense_at_full_keep():
    """use_lop=True at keep=1.0 must match the dense baseline on the
    slot-paged path (the paper's K=M exactness, now with masked lanes)."""
    cfg, qp = _pool_setup(lop_keep=1.0)
    rng = np.random.default_rng(13)
    p = rng.integers(0, cfg.vocab, (21,)).astype(np.int32)

    def run(use_lop):
        pool = init_cache_pool(cfg, 2, MAX_LEN)
        logits, rc = prefill(cfg, qp, p[None], max_len=MAX_LEN,
                             use_lop=use_lop)
        pool = insert_slot(pool, jnp.int32(1), rc)
        tok = np.zeros((2, 1), np.int32)
        tok[1, 0] = int(jnp.argmax(logits[0]))
        logits2, _ = serve_step(cfg, qp, pool, jnp.asarray(tok),
                                use_lop=use_lop)
        return logits2[1]

    lop, dense = run(True), run(False)
    ref = float(jnp.max(jnp.abs(dense))) + 1e-9
    err = float(jnp.max(jnp.abs(lop - dense)))
    assert err / ref < 2e-2, (err, ref)


def test_pool_tree_matches_lockstep_cache_plus_active():
    """The pool is init_cache + per-lane active (so serve_step, cache_pspecs
    and the dryrun cells all keep working), and insert sets length/active."""
    for arch in ("jamba-1.5-large-398b", "whisper-small", "rwkv6-1.6b"):
        cfg = _reduced(arch)
        pool = init_cache_pool(cfg, 2, 60)
        base = init_cache(cfg, 2, 60)
        assert set(pool) == set(base) | {"active", "seed", "sample_step"}
        assert not np.asarray(pool["active"]).any()
        specs = cache_pspecs(cfg, pool)
        assert specs["active"] == (None,)
        assert specs["seed"] == (None,)
        assert specs["sample_step"] == (None,)
        if cfg.family != "ssm":
            assert pool_capacity(pool) > 0


def test_inactive_lanes_do_not_drift():
    """Decoding with every lane inactive must leave lengths untouched and
    produce finite logits (masked screen/top-K/write paths)."""
    cfg, qp = _pool_setup()
    pool = init_cache_pool(cfg, 2, MAX_LEN)
    before = jax.tree.map(np.asarray, pool)
    logits, after = serve_step(cfg, qp, pool,
                               jnp.zeros((2, 1), jnp.int32))
    assert np.isfinite(np.asarray(logits)).all()
    np.testing.assert_array_equal(np.asarray(after["lengths"]),
                                  before["lengths"])
    np.testing.assert_array_equal(np.asarray(after["active"]),
                                  before["active"])
    for la, lb in zip(jax.tree.leaves(jax.tree.map(np.asarray, after)),
                      jax.tree.leaves(before)):
        np.testing.assert_array_equal(la, lb)


def test_extract_insert_round_trip_bit_exact():
    """Property test: extract_slot → partial insert_slot(active=) is a
    bit-exact round trip for KV pages, scales, packed LOP feature rows
    AND lengths, at lengths straddling block boundaries — the invariant
    bulk_insert (prefix cloning) relies on."""
    from repro.serving.cache import extract_slot

    cfg, qp = _pool_setup()
    rng = np.random.default_rng(21)
    # lengths below / at / above the lop_block=32 boundary
    for plen, active in [(13, True), (32, False), (33, True), (45, False)]:
        p = rng.integers(0, cfg.vocab, (plen,)).astype(np.int32)
        pool = init_cache_pool(cfg, 3, MAX_LEN)
        _, rc = prefill(cfg, qp, p[None], max_len=MAX_LEN)
        pool = insert_slot(pool, jnp.int32(1), rc, active=active)
        before = jax.tree.map(np.asarray, pool)
        lane = extract_slot(pool, jnp.int32(1))
        assert int(lane["lengths"][0]) == plen
        again = insert_slot(pool, jnp.int32(1), lane, active=active)
        after = jax.tree.map(np.asarray, again)
        for la, lb in zip(jax.tree.leaves(after), jax.tree.leaves(before)):
            np.testing.assert_array_equal(la, lb)


def test_evict_zeroes_lop_feature_rows():
    """Regression: evict_slot must zero the lane's packed LOP feature rows
    (not just lengths/active) so a later prefix-clone into the lane
    screens against exactly what a fresh pool would — no ghost features
    from the previous occupant."""
    cfg, qp = _pool_setup()
    rng = np.random.default_rng(22)
    p = rng.integers(0, cfg.vocab, (40,)).astype(np.int32)
    pool = init_cache_pool(cfg, 2, MAX_LEN)
    fresh_feat = np.asarray(pool["layers"]["feat"])
    la, rc = prefill(cfg, qp, p[None], max_len=MAX_LEN)
    pool = insert_slot(pool, jnp.int32(0), rc)
    out, pool = _pool_decode(cfg, qp, pool,
                             {0: int(jnp.argmax(la[0]))}, gen=3)
    assert np.asarray(pool["layers"]["feat"][:, 0]).any()
    pool = evict_slot(pool, jnp.int32(0))
    np.testing.assert_array_equal(np.asarray(pool["layers"]["feat"]),
                                  fresh_feat)
    # K/V bytes may stay stale — only the feature rows must reset
    assert int(pool["lengths"][0]) == 0 and not bool(pool["active"][0])


def test_quantize_params_packs_linears():
    cfg = _reduced("bitnet-3b")
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    qp = quantize_params(cfg, params)
    # QKV fuses into ONE packed weight with a per-column γ row
    wqkv = qp["layers"]["attn"]["wqkv"]
    assert "packed" in wqkv and wqkv["packed"].dtype == jnp.uint8
    # packed is 4x smaller on the reduction dim
    assert wqkv["packed"].shape[-2] * 4 == params["layers"]["attn"]["wq"][
        "w"].shape[-2]
    assert wqkv["packed"].shape[-1] == cfg.q_dim + 2 * cfg.kv_dim
    assert wqkv["scale"].shape[-2:] == (1, cfg.q_dim + 2 * cfg.kv_dim)
    # the FFN becomes one whole-FFN node (gate‖up stream + down stream)
    ffn = qp["layers"]["ffn"]
    assert ffn["gu_packed"].shape[-1] == 2 * cfg.d_ff
    assert ffn["down_packed"].shape[-2] * 4 == cfg.d_ff
    # head/embed stay fp
    assert "w" in qp["head"] and "table" in qp["embed"]
    # fuse=False keeps the legacy one-node-per-projection format
    qp_legacy = quantize_params(cfg, params, fuse=False)
    attn = qp_legacy["layers"]["attn"]["wq"]
    assert "packed" in attn and attn["scale"].shape[-2:] == (1, 1)
    # bf16 config keeps everything fp
    qp_fp = quantize_params(cfg.replace(quant="bf16"), params)
    assert "w" in qp_fp["layers"]["attn"]["wq"]
