"""Serving engine: prefill/decode consistency, LOP exactness, generation."""
import importlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.transformer import init_params
from repro.serving.cache import init_cache
from repro.serving.engine import prefill, serve_step
from repro.serving.quantize import quantize_params

from tests.test_models_smoke import ARCH_MODULES, _reduced

CONSISTENCY_ARCHS = ["mixtral-8x22b", "whisper-small",
                     "jamba-1.5-large-398b", "llava-next-34b",
                     "rwkv6-1.6b", "bitnet-3b"]


def _inputs(cfg, b, s, key=1):
    rng = np.random.default_rng(key)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    kw = {}
    if cfg.family == "encdec":
        kw["frames"] = jnp.asarray(rng.standard_normal((b, 48, cfg.d_model)),
                                   jnp.float32) * 0.05
    if cfg.family == "vlm":
        kw["patches"] = jnp.asarray(
            rng.standard_normal((b, cfg.n_img_tokens, cfg.d_model)),
            jnp.float32) * 0.05
    return tokens, kw


@pytest.mark.parametrize("arch", CONSISTENCY_ARCHS)
def test_prefill_plus_decode_equals_full_prefill(arch):
    """With lop_keep=1.0 the sparse decode path is exact: prefill(S) +
    serve_step == prefill(S+1) (the paper's no-retraining guarantee at
    K=M)."""
    cfg = _reduced(arch).replace(lop_keep=1.0, capacity_factor=8.0)
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    qp = quantize_params(cfg, params)
    B, S = 2, 20
    tokens, kw = _inputs(cfg, B, S + 1)

    logits_full, _ = prefill(cfg, qp, tokens, max_len=S + 2, **kw)
    _, cache = prefill(cfg, qp, tokens[:, :S], max_len=S + 2, **kw)
    logits_dec, cache2 = serve_step(cfg, qp, cache, tokens[:, S:S + 1])

    ref = float(jnp.max(jnp.abs(logits_full))) + 1e-9
    err = float(jnp.max(jnp.abs(logits_dec - logits_full)))
    assert err / ref < 2e-2, (arch, err, ref)
    assert np.isfinite(np.asarray(logits_dec)).all()


def test_sparse_decode_finite_and_close():
    cfg = _reduced("bitnet-3b").replace(lop_keep=0.5)
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    qp = quantize_params(cfg, params)
    tokens, kw = _inputs(cfg, 2, 21)
    logits_full, _ = prefill(cfg, qp, tokens, max_len=24, **kw)
    _, cache = prefill(cfg, qp, tokens[:, :20], max_len=24, **kw)
    logits_sp, _ = serve_step(cfg, qp, cache, tokens[:, 20:21])
    rel = float(jnp.linalg.norm(logits_sp - logits_full)
                / (jnp.linalg.norm(logits_full) + 1e-9))
    assert np.isfinite(np.asarray(logits_sp)).all()
    assert rel < 0.5, rel


def test_greedy_generation_deterministic():
    cfg = _reduced("stablelm-1.6b")
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    qp = quantize_params(cfg, params)
    tokens, _ = _inputs(cfg, 2, 8)

    def gen():
        logits, cache = prefill(cfg, qp, tokens, max_len=8 + 8)
        out = []
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        for _ in range(8):
            out.append(np.asarray(tok))
            logits, cache = serve_step(cfg, qp, cache, tok)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        return np.concatenate(out, 1)

    a, b = gen(), gen()
    assert (a == b).all()
    assert (a >= 0).all() and (a < cfg.vocab_padded).all()


def test_swa_window_limits_decode_attention():
    """Mixtral SWA: tokens beyond the (depth-stacked) receptive field must
    not affect decode. With 1 layer + window W, the decode step at position
    S sees K/V from [S−W, S), which themselves depend on tokens ≥ S−2W."""
    cfg = _reduced("mixtral-8x22b").replace(lop_keep=1.0, swa_window=16,
                                            capacity_factor=8.0, n_layers=1)
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    qp = quantize_params(cfg, params)
    S = 40
    cut = S - 2 * cfg.swa_window  # = 8: outside the receptive field
    tokens, _ = _inputs(cfg, 1, S + 1)
    tok2 = tokens.at[:, :cut].set((tokens[:, :cut] + 1) % cfg.vocab)
    _, c1 = prefill(cfg, qp, tokens[:, :S], max_len=S + 2)
    _, c2 = prefill(cfg, qp, tok2[:, :S], max_len=S + 2)
    l1, _ = serve_step(cfg, qp, c1, tokens[:, S:S + 1])
    l2, _ = serve_step(cfg, qp, c2, tokens[:, S:S + 1])
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-4)
    # sanity: in-window changes DO affect the logits
    tok3 = tokens.at[:, S - 4].set((tokens[:, S - 4] + 1) % cfg.vocab)
    _, c3 = prefill(cfg, qp, tok3[:, :S], max_len=S + 2)
    l3, _ = serve_step(cfg, qp, c3, tokens[:, S:S + 1])
    assert float(jnp.max(jnp.abs(l3 - l1))) > 1e-3


def test_init_cache_shapes():
    cfg = _reduced("jamba-1.5-large-398b")
    cache = init_cache(cfg, 2, 100)
    n_sb = cfg.n_layers // cfg.attn_every
    cap = -(-101 // cfg.lop_block) * cfg.lop_block
    assert cache["blocks"]["attn"]["k"].shape == (
        n_sb, 2, cfg.n_kv_heads, cap, cfg.hd)
    assert cache["blocks"]["mamba"]["ssm"].shape == (
        n_sb, cfg.attn_every - 1, 2, cfg.d_inner, cfg.mamba_d_state)
    assert cache["blocks"]["attn"]["feat"].shape[-1] == cfg.hd // 2


def test_quantize_params_packs_linears():
    cfg = _reduced("bitnet-3b")
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    qp = quantize_params(cfg, params)
    attn = qp["layers"]["attn"]["wq"]
    assert "packed" in attn and attn["packed"].dtype == jnp.uint8
    # packed is 4x smaller on the reduction dim
    assert attn["packed"].shape[-2] * 4 == params["layers"]["attn"]["wq"][
        "w"].shape[-2]
    # head/embed stay fp
    assert "w" in qp["head"] and "table" in qp["embed"]
    # bf16 config keeps everything fp
    qp_fp = quantize_params(cfg.replace(quant="bf16"), params)
    assert "w" in qp_fp["layers"]["attn"]["wq"]
