"""Multi-device behaviour via subprocesses (main test process must keep
exactly 1 device per the brief) + in-process fault-tolerance units."""
import os
import subprocess
import sys
import time

import pytest

SUBPROC = os.path.join(os.path.dirname(__file__), "subproc")
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + ":" + REPO
    env["TF_CPP_MIN_LOG_LEVEL"] = "2"
    env.pop("XLA_FLAGS", None)     # script sets its own device count
    out = subprocess.run(
        [sys.executable, os.path.join(SUBPROC, script)],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert out.returncode == 0, f"{script}\n{out.stdout}\n{out.stderr}"
    return out.stdout


@pytest.mark.slow
def test_sp_decode_subprocess():
    out = _run("sp_decode_check.py")
    assert "SP_DECODE_CHECK_OK" in out


@pytest.mark.slow
def test_collectives_subprocess():
    out = _run("collectives_check.py")
    assert "COLLECTIVES_CHECK_OK" in out


@pytest.mark.slow
def test_fsdp_train_subprocess():
    out = _run("fsdp_train_check.py")
    assert "FSDP_TRAIN_CHECK_OK" in out


@pytest.mark.slow
def test_tp_ffn_subprocess():
    """f-sharded fused serving FFN (shard_map over the model axis)
    agrees with the single-launch kernel — the ROADMAP TP-restoration
    item for the fused FFN."""
    out = _run("tp_ffn_check.py")
    assert "TP_FFN_CHECK_OK" in out


# ---- in-process units (no extra devices needed) ----

def test_straggler_monitor_flags_outliers():
    from repro.distributed.fault_tolerance import StragglerMonitor
    mon = StragglerMonitor(window=20, threshold_sigma=3.0, min_steps=10)
    flagged = []
    for i in range(30):
        dt = 0.1 + 0.001 * (i % 3)
        if i == 25:
            dt = 2.0
        if mon.record(dt):
            flagged.append(i)
    assert flagged == [25]
    assert mon.summary()["flagged"][0][1] == 2.0


def test_plan_elastic_mesh():
    from repro.distributed.fault_tolerance import plan_elastic_mesh
    assert plan_elastic_mesh(256, model=16) == (16, 16)
    assert plan_elastic_mesh(255, model=16) == (15, 16)   # lost one chip
    assert plan_elastic_mesh(512, model=16, pod=2) == (2, 16, 16)
    assert plan_elastic_mesh(496, model=16, pod=2) == (2, 15, 16)
    with pytest.raises(RuntimeError):
        plan_elastic_mesh(8, model=16)


def test_preemption_handler():
    import signal

    from repro.distributed.fault_tolerance import PreemptionHandler
    h = PreemptionHandler(signals=(signal.SIGUSR1,))
    assert not h.preempted
    os.kill(os.getpid(), signal.SIGUSR1)
    time.sleep(0.1)
    assert h.preempted
    h.restore()


def test_logical_axes_resolution():
    from jax.sharding import PartitionSpec as P

    from repro.distributed.partitioning import logical_to_pspec
    # without a mesh, dp/fsdp resolve to single-pod axes
    assert logical_to_pspec(("fsdp", "tp")) == P(("data",), "model")
    assert logical_to_pspec((None, "tp")) == P(None, "model")


def test_tp_ffn_optin_routing_single_device():
    """The f-sharded FFN route engages only under the use_ffn_tp opt-in
    with an active mesh; on a size-1 model axis it is bitwise the
    single-launch dispatch (nothing splits, psum over 1)."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh

    from repro.configs.bitnet_3b import REDUCED
    from repro.core.qlinear import ffn_node_apply
    from repro.distributed.partitioning import use_mesh
    from repro.distributed.tp_ffn import maybe_shard_f, use_ffn_tp
    from repro.models.transformer import init_params
    from repro.serving.quantize import quantize_params

    cfg = REDUCED
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    qp = quantize_params(cfg, params)
    ffn0 = jax.tree.map(lambda a: a[0], qp["layers"]["ffn"])
    x = jnp.asarray(np.random.default_rng(0).standard_normal(
        (3, cfg.d_model)), jnp.float32)

    # no opt-in → route declines regardless of mesh
    assert maybe_shard_f(ffn0, x, gated=cfg.gated_ffn, act="silu") is None
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    with use_mesh(mesh):
        assert maybe_shard_f(ffn0, x, gated=cfg.gated_ffn,
                             act="silu") is None
    # opt-in without a mesh → still the plain dispatch
    with use_ffn_tp("model"):
        assert maybe_shard_f(ffn0, x, gated=cfg.gated_ffn,
                             act="silu") is None

    ref = jax.jit(lambda xx: ffn_node_apply(ffn0, xx, gated=cfg.gated_ffn,
                                            act="silu"))(x)
    with use_mesh(mesh), use_ffn_tp("model"):
        out = jax.jit(lambda xx: ffn_node_apply(
            ffn0, xx, gated=cfg.gated_ffn, act="silu"))(x)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
