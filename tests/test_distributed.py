"""Multi-device behaviour via subprocesses (main test process must keep
exactly 1 device per the brief) + in-process fault-tolerance units."""
import os
import subprocess
import sys
import time

import pytest

SUBPROC = os.path.join(os.path.dirname(__file__), "subproc")
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + ":" + REPO
    env["TF_CPP_MIN_LOG_LEVEL"] = "2"
    env.pop("XLA_FLAGS", None)     # script sets its own device count
    out = subprocess.run(
        [sys.executable, os.path.join(SUBPROC, script)],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert out.returncode == 0, f"{script}\n{out.stdout}\n{out.stderr}"
    return out.stdout


@pytest.mark.slow
def test_sp_decode_subprocess():
    out = _run("sp_decode_check.py")
    assert "SP_DECODE_CHECK_OK" in out


@pytest.mark.slow
def test_collectives_subprocess():
    out = _run("collectives_check.py")
    assert "COLLECTIVES_CHECK_OK" in out


@pytest.mark.slow
def test_fsdp_train_subprocess():
    out = _run("fsdp_train_check.py")
    assert "FSDP_TRAIN_CHECK_OK" in out


# ---- in-process units (no extra devices needed) ----

def test_straggler_monitor_flags_outliers():
    from repro.distributed.fault_tolerance import StragglerMonitor
    mon = StragglerMonitor(window=20, threshold_sigma=3.0, min_steps=10)
    flagged = []
    for i in range(30):
        dt = 0.1 + 0.001 * (i % 3)
        if i == 25:
            dt = 2.0
        if mon.record(dt):
            flagged.append(i)
    assert flagged == [25]
    assert mon.summary()["flagged"][0][1] == 2.0


def test_plan_elastic_mesh():
    from repro.distributed.fault_tolerance import plan_elastic_mesh
    assert plan_elastic_mesh(256, model=16) == (16, 16)
    assert plan_elastic_mesh(255, model=16) == (15, 16)   # lost one chip
    assert plan_elastic_mesh(512, model=16, pod=2) == (2, 16, 16)
    assert plan_elastic_mesh(496, model=16, pod=2) == (2, 15, 16)
    with pytest.raises(RuntimeError):
        plan_elastic_mesh(8, model=16)


def test_preemption_handler():
    import signal

    from repro.distributed.fault_tolerance import PreemptionHandler
    h = PreemptionHandler(signals=(signal.SIGUSR1,))
    assert not h.preempted
    os.kill(os.getpid(), signal.SIGUSR1)
    time.sleep(0.1)
    assert h.preempted
    h.restore()


def test_logical_axes_resolution():
    from jax.sharding import PartitionSpec as P

    from repro.distributed.partitioning import logical_to_pspec
    # without a mesh, dp/fsdp resolve to single-pod axes
    assert logical_to_pspec(("fsdp", "tp")) == P(("data",), "model")
    assert logical_to_pspec((None, "tp")) == P(None, "model")
