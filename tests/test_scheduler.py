"""Continuous-batching scheduler: lifecycle + lockstep token equivalence.

The core guarantee of the slot-paged engine: a request decodes the *same
greedy tokens* whether it shares the pool with other requests (staggered
arrivals, mixed prompt lengths, lane reuse) or runs alone through the
lockstep prefill+decode path at the same cache capacity.
"""
import jax
import numpy as np
import pytest

from repro.launch.serve import serve_loop
from repro.models.transformer import init_params
from repro.serving.quantize import quantize_params
from repro.serving.scheduler import (Request, Scheduler, lockstep_generate,
                                     pow2_bucket)

from tests.test_models_smoke import _reduced

MAX_LEN = 63          # pool capacity 64 with the reduced lop_block of 32


def _setup(arch="bitnet-3b", **over):
    cfg = _reduced(arch).replace(**over) if over else _reduced(arch)
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, quantize_params(cfg, params)


def _prompts(cfg, lens, seed=3):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, (n,)).astype(np.int32) for n in lens]


def test_pow2_bucketing_bounds_compiles():
    assert pow2_bucket(3, lo=16) == 16
    assert pow2_bucket(16, lo=16) == 16
    assert pow2_bucket(17, lo=16) == 32
    assert pow2_bucket(100, lo=16, hi=63) == 63
    # every length in [1, 64] lands in one of 3 buckets
    assert {pow2_bucket(n, lo=16, hi=64) for n in range(1, 65)} == {16, 32,
                                                                    64}


def test_staggered_mixed_length_equals_lockstep():
    """Requests admitted into a live pool at different steps emit the same
    greedy tokens as solo lockstep runs (the acceptance criterion)."""
    cfg, qp = _setup()
    prompts = _prompts(cfg, [12, 27, 9, 33, 17])
    sched = Scheduler(cfg, qp, n_slots=2, max_len=MAX_LEN)
    for rid, p in enumerate(prompts):
        sched.submit(Request(rid=rid, prompt=p, max_new_tokens=6))
    results = sched.run_to_completion()
    assert len(results) == len(prompts)
    assert sched.prefill_compiles <= 3          # buckets, not lengths
    for rid, p in enumerate(prompts):
        got = next(r for r in results if r.rid == rid)
        ref = lockstep_generate(cfg, qp, p, 6, max_len=MAX_LEN)
        assert got.tokens == ref, (rid, got.tokens, ref)
        assert got.finish_reason == "length"


def test_lane_reuse_after_evict_matches_fresh():
    """A lane that served a long request is reused for a new one; stale
    bytes above the new length must not leak (same tokens as fresh run)."""
    cfg, qp = _setup()
    long_p, short_p = _prompts(cfg, [40, 8], seed=5)
    sched = Scheduler(cfg, qp, n_slots=1, max_len=MAX_LEN)
    sched.submit(Request(rid=0, prompt=long_p, max_new_tokens=8))
    sched.submit(Request(rid=1, prompt=short_p, max_new_tokens=8))
    results = sched.run_to_completion()
    reused = next(r for r in results if r.rid == 1)
    fresh = Scheduler(cfg, qp, n_slots=1, max_len=MAX_LEN)
    fresh.submit(Request(rid=1, prompt=short_p, max_new_tokens=8))
    assert reused.tokens == fresh.run_to_completion()[0].tokens


def test_eos_early_exit_frees_lane():
    cfg, qp = _setup()
    (p,) = _prompts(cfg, [10])
    ref = lockstep_generate(cfg, qp, p, 12, max_len=MAX_LEN)
    eos = ref[3]                                 # force an early EOS hit
    sched = Scheduler(cfg, qp, n_slots=1, max_len=MAX_LEN)
    sched.submit(Request(rid=0, prompt=p, max_new_tokens=12, eos_id=eos))
    res = sched.run_to_completion()[0]
    assert res.finish_reason == "eos"
    assert res.tokens == ref[:res.tokens.index(eos) + 1]
    assert sched.n_active == 0 and len(sched.queue) == 0


def test_first_token_eos_finishes_lane_immediately():
    """Regression (ISSUE 5 satellite): the first-token EOS predicate was
    evaluated twice in ``_start_lane`` to pick the finish reason; the
    single-evaluation rewrite must still finish a request whose FIRST
    sampled token is EOS with reason "eos", exactly one token, and an
    immediately reusable lane — through the chunked-prefill activation
    path (``_start_lane`` called from ``_step_prefill``)."""
    cfg, qp = _setup()
    (p,) = _prompts(cfg, [10])
    ref = lockstep_generate(cfg, qp, p, 4, max_len=MAX_LEN)
    sched = Scheduler(cfg, qp, n_slots=1, max_len=MAX_LEN)
    assert sched.chunked
    sched.submit(Request(rid=0, prompt=p, max_new_tokens=4, eos_id=ref[0]))
    res = sched.run_to_completion()[0]
    assert res.finish_reason == "eos"
    assert res.tokens == [ref[0]]
    assert sched.n_active == 0 and len(sched._free) == 1


def test_max_new_tokens_one_edge_cases():
    """max_new_tokens=1: the budget is spent on the prefill-seeded first
    token — reason "length" when it is not EOS, "eos" (taking precedence)
    when it is; both through the pooled path and the lockstep
    reference."""
    cfg, qp = _setup()
    (p,) = _prompts(cfg, [12], seed=17)
    ref = lockstep_generate(cfg, qp, p, 1, max_len=MAX_LEN)
    assert len(ref) == 1

    sched = Scheduler(cfg, qp, n_slots=1, max_len=MAX_LEN)
    sched.submit(Request(rid=0, prompt=p, max_new_tokens=1))
    res = sched.run_to_completion()[0]
    assert res.tokens == ref and res.finish_reason == "length"

    sched2 = Scheduler(cfg, qp, n_slots=1, max_len=MAX_LEN)
    sched2.submit(Request(rid=0, prompt=p, max_new_tokens=1,
                          eos_id=ref[0]))
    res2 = sched2.run_to_completion()[0]
    assert res2.tokens == ref and res2.finish_reason == "eos"
    # the lockstep reference stops at the same single token either way
    assert lockstep_generate(cfg, qp, p, 1, max_len=MAX_LEN,
                             eos_id=ref[0]) == ref


def test_capacity_guard_rejects_oversized_request():
    cfg, qp = _setup()
    (p,) = _prompts(cfg, [60])
    sched = Scheduler(cfg, qp, n_slots=1, max_len=MAX_LEN)
    with pytest.raises(AssertionError):
        sched.submit(Request(rid=0, prompt=p, max_new_tokens=30))


def test_moe_exact_length_prefill_matches_lockstep():
    """MoE routers rank tokens per group for expert capacity, so pad
    tokens entering the router shift who gets dropped — ``_bucket`` must
    use exact lengths for moe like the recurrent families (ROADMAP open
    item from the PR 2 review). 17 would land in the pow2 bucket 32 and
    pad; with the fix it compiles at exactly 17 and the pooled run stays
    token-identical to the unpadded lockstep reference."""
    cfg, qp = _setup("granite-moe-1b-a400m")
    prompts = _prompts(cfg, [17, 23], seed=11)
    sched = Scheduler(cfg, qp, n_slots=2, max_len=MAX_LEN)
    assert not sched.chunked          # router caveat: run-to-completion
    for rid, p in enumerate(prompts):
        sched.submit(Request(rid=rid, prompt=p, max_new_tokens=5))
    results = sched.run_to_completion()
    assert sched.prefill_compiles == 2           # exact lengths, no buckets
    for rid, p in enumerate(prompts):
        got = next(r for r in results if r.rid == rid)
        ref = lockstep_generate(cfg, qp, p, 5, max_len=MAX_LEN)
        assert got.tokens == ref, (rid, got.tokens, ref)


def test_recurrent_family_uses_exact_length_prefill():
    """rwkv6 state integrates every position — the scheduler must not pad
    its prompts, and pooled decode must still match the solo path."""
    cfg, qp = _setup("rwkv6-1.6b")
    prompts = _prompts(cfg, [11, 19], seed=7)
    sched = Scheduler(cfg, qp, n_slots=2, max_len=MAX_LEN)
    for rid, p in enumerate(prompts):
        sched.submit(Request(rid=rid, prompt=p, max_new_tokens=5))
    results = sched.run_to_completion()
    assert sched.prefill_compiles == 2           # one per distinct length
    for rid, p in enumerate(prompts):
        got = next(r for r in results if r.rid == rid)
        assert got.tokens == lockstep_generate(cfg, qp, p, 5,
                                               max_len=MAX_LEN)


def test_encdec_requests_carry_frames():
    """Whisper-style requests travel with their encoder frames and still
    match the solo lockstep run (regression: the first driver rewrite
    dropped frames/patches support)."""
    cfg, qp = _setup("whisper-small")
    rng = np.random.default_rng(9)
    sched = Scheduler(cfg, qp, n_slots=2, max_len=40)
    reqs = []
    for rid, plen in enumerate([6, 9]):
        p = rng.integers(0, cfg.vocab, (plen,)).astype(np.int32)
        f = rng.standard_normal((4 * plen, cfg.d_model)).astype(
            np.float32) * 0.02
        reqs.append(Request(rid=rid, prompt=p, max_new_tokens=4, frames=f))
        sched.submit(reqs[-1])
    results = sched.run_to_completion()
    for req in reqs:
        got = next(r for r in results if r.rid == req.rid)
        ref = lockstep_generate(cfg, qp, req.prompt, 4, max_len=40,
                                frames=req.frames)
        assert got.tokens == ref, req.rid
    # oversized encoder input is rejected up front, not at insert time
    with pytest.raises(AssertionError):
        sched.submit(Request(rid=9, prompt=reqs[0].prompt, max_new_tokens=2,
                             frames=np.zeros((cfg.cross_ctx + 33,
                                              cfg.d_model), np.float32)))


@pytest.mark.slow
def test_serve_loop_driver_reports_latency_and_verifies():
    cfg = _reduced("bitnet-3b")
    out = serve_loop(cfg, n_slots=2, n_requests=4, min_prompt=6,
                     max_prompt=20, gen=5, verify=True)
    assert out["verified"], out["mismatched_rids"]
    assert len(out["results"]) == 4
    assert out["tokens_per_s"] > 0
    assert out["latency_p99"] >= out["latency_p50"] > 0
    assert all(r.ttft <= r.latency for r in out["results"])
