"""Hillclimb flags must preserve exactness (§Perf beyond-paper variants).

The decode variants are ModelConfig fields now (``gqa_shared_select``,
``int8_logits``), resolved once per engine entry by
:func:`repro.configs.base.resolve_decode_flags`; the env vars exercised
here remain as fallbacks for unset fields — both spellings must steer the
same code path (checked below and in tests/test_decode_attention.py).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.transformer import init_params
from repro.serving.engine import prefill, serve_step
from repro.serving.quantize import quantize_params

from tests.test_models_smoke import _reduced


@pytest.fixture
def flag_env():
    keys = ("REPRO_GQA_SHARED_SELECT", "REPRO_INT8_LOGITS",
            "REPRO_BF16_EXPERT_ACC")
    old = {k: os.environ.get(k) for k in keys}
    yield
    for k, v in old.items():
        if v is None:
            os.environ.pop(k, None)
        else:
            os.environ[k] = v


def _run_cell(cfg, qp, tokens):
    logits_full, _ = prefill(cfg, qp, tokens, max_len=24)
    _, cache = prefill(cfg, qp, tokens[:, :20], max_len=24)
    logits_dec, _ = serve_step(cfg, qp, cache, tokens[:, 20:21])
    return logits_full, logits_dec


def test_shared_select_exact_at_keep_one(flag_env):
    """Group-shared selection (beyond-paper) keeps the keep=1.0 exactness
    guarantee — the candidate union still covers every valid block."""
    cfg = _reduced("mistral-nemo-12b").replace(lop_keep=1.0)
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    qp = quantize_params(cfg, params)
    rng = np.random.default_rng(5)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (2, 21)), jnp.int32)

    base_full, base_dec = _run_cell(cfg, qp, tokens)
    os.environ["REPRO_GQA_SHARED_SELECT"] = "1"
    _, flag_dec = _run_cell(cfg, qp, tokens)
    rel = float(jnp.max(jnp.abs(flag_dec - base_dec))
                / (jnp.max(jnp.abs(base_dec)) + 1e-9))
    assert rel < 1e-5, rel
    # the config-field spelling takes the identical path as the env flag
    del os.environ["REPRO_GQA_SHARED_SELECT"]
    _, field_dec = _run_cell(cfg.replace(gqa_shared_select=True), qp, tokens)
    np.testing.assert_array_equal(np.asarray(field_dec),
                                  np.asarray(flag_dec))


def test_int8_logits_matches_f32_path(flag_env):
    """Integer-domain QKᵀ (BoothFlex-faithful) ≡ dequantized-f32 einsum up
    to f32 rounding of the scale product."""
    cfg = _reduced("stablelm-1.6b")
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    qp = quantize_params(cfg, params)
    rng = np.random.default_rng(6)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (2, 21)), jnp.int32)

    base_full, _ = _run_cell(cfg, qp, tokens)
    os.environ["REPRO_INT8_LOGITS"] = "1"
    flag_full, _ = _run_cell(cfg, qp, tokens)
    rel = float(jnp.linalg.norm(flag_full - base_full)
                / (jnp.linalg.norm(base_full) + 1e-9))
    assert rel < 1e-4, rel


def test_bf16_expert_acc_close(flag_env):
    """bf16 expert accumulation stays within bf16 tolerance of f32."""
    from repro.models.moe import moe_apply, moe_init
    cfg = _reduced("granite-moe-1b-a400m").replace(quant="bf16",
                                                   capacity_factor=8.0)
    p, _ = moe_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal((2, 16, cfg.d_model)), jnp.float32)
    y0, _ = moe_apply(cfg, p, x)
    os.environ["REPRO_BF16_EXPERT_ACC"] = "1"
    y1, _ = moe_apply(cfg, p, x)
    rel = float(jnp.linalg.norm(y1 - y0) / (jnp.linalg.norm(y0) + 1e-9))
    assert rel < 0.05, rel
