"""Ternary (BitNet b1.58) weights + 2-bit packing.

Deterministic cases only — the hypothesis property-based companions live
in test_hypothesis_props.py (skipped when hypothesis is not installed).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ternary import (bitlinear_qat, bitlinear_ref,
                                make_ternary_weight, memory_footprint_bytes,
                                ste_ternary, ternary_quantize)


def test_pack_unpack_roundtrip_deterministic(rng):
    from repro.core.ternary import pack_ternary, unpack_ternary
    wt = rng.integers(-1, 2, (32, 24)).astype(np.int8)
    packed = pack_ternary(jnp.asarray(wt))
    assert packed.shape == (8, 24)
    assert (np.asarray(unpack_ternary(packed, 32)) == wt).all()


def test_absmean_scale(rng):
    w = rng.standard_normal((64, 64)).astype(np.float32)
    _, gamma = ternary_quantize(jnp.asarray(w))
    assert np.isclose(float(np.asarray(gamma).squeeze()),
                      np.abs(w).mean(), rtol=1e-5)


def test_bitlinear_correlates_with_fp(rng):
    x = jnp.asarray(rng.standard_normal((16, 128)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((128, 64)).astype(np.float32)) * 0.05
    tw = make_ternary_weight(w)
    y = np.asarray(bitlinear_ref(x, tw))
    y_fp = np.asarray(x @ w)
    cos = (y * y_fp).sum() / (np.linalg.norm(y) * np.linalg.norm(y_fp))
    assert cos > 0.80, cos


def test_qat_gradients_flow(rng):
    x = jnp.asarray(rng.standard_normal((4, 32)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((32, 16)).astype(np.float32))
    g = jax.grad(lambda w_: jnp.sum(bitlinear_qat(x, w_) ** 2))(w)
    assert np.isfinite(np.asarray(g)).all()
    assert float(jnp.max(jnp.abs(g))) > 0


def test_ste_ternary_forward_equals_quantized(rng):
    w = jnp.asarray(rng.standard_normal((32, 16)).astype(np.float32))
    wt, gamma = ternary_quantize(w)
    assert np.allclose(np.asarray(ste_ternary(w)),
                       np.asarray(wt.astype(jnp.float32) * gamma), atol=1e-6)


def test_memory_footprint_ratios():
    shape = (4096, 4096)
    bf16 = memory_footprint_bytes(shape, "bf16")
    packed = memory_footprint_bytes(shape, "ternary_packed")
    assert 7.5 < bf16 / packed < 8.1       # the paper's ~8× claim
