"""Fused prefill-attention kernel + chunked prefill: exactness pins.

Three layers of guarantees (DESIGN.md §Chunked-prefill):

  1. kernel vs oracle — the Pallas kernel and ``prefill_attention_ref``
     agree across GQA shapes, windows, cross (non-causal) masks, partial
     ``kv_len`` and ``q_offset``; a lane with ``kv_len == 0`` emits
     exactly zero.
  2. chunk-carry — splitting the query stream into chunks against the
     same capacity-padded cache is *bitwise* identical to one
     whole-prompt call, at the op level and through the full engine
     (``prefill_chunk`` chain vs ``prefill``).
  3. scheduling — the chunked-interleaved scheduler emits token-exact
     greedy output vs the run-to-completion scheduler and the lockstep
     reference, including prompts not divisible by the chunk size and
     the vlm image-prefix chunk.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune, ops
from repro.models.transformer import init_params
from repro.serving.cache import init_cache
from repro.serving.engine import prefill, prefill_chunk, serve_step
from repro.serving.quantize import quantize_params
from repro.serving.scheduler import Request, Scheduler, lockstep_generate

from tests.test_models_smoke import _reduced

MAX_LEN = 63          # pool capacity 64 with the reduced lop_block of 32


def _rand_inputs(rng, b, h, hkv, c, dh, m):
    qi = jnp.asarray(rng.integers(-127, 128, (b, h, c, dh)), jnp.int8)
    qsc = jnp.asarray(rng.random((b, h, c)) * 0.1 + 0.01, jnp.float32)
    ki = jnp.asarray(rng.integers(-127, 128, (b, hkv, m, dh)), jnp.int8)
    vi = jnp.asarray(rng.integers(-127, 128, (b, hkv, m, dh)), jnp.int8)
    ks = jnp.asarray(rng.random((b, hkv, m)) * 0.1 + 0.01, jnp.float32)
    vs = jnp.asarray(rng.random((b, hkv, m)) * 0.1 + 0.01, jnp.float32)
    return qi, qsc, ki, vi, ks, vs


@pytest.mark.parametrize("hkv,window,causal,int8_logits", [
    (4, 0, True, False),      # MHA causal
    (2, 0, True, False),      # GQA causal
    (2, 12, True, True),      # GQA + SWA window, integer-domain logits
    (2, 0, False, False),     # cross / encoder (non-causal, kv_len mask)
])
def test_prefill_kernel_vs_ref(hkv, window, causal, int8_logits):
    rng = np.random.default_rng(0)
    b, h, c, dh, m = 2, 4, 8, 32, 64
    qi, qsc, ki, vi, ks, vs = _rand_inputs(rng, b, h, hkv, c, dh, m)
    kv_len = jnp.asarray([40, 0], jnp.int32)   # lane 1 retired/empty
    kw = dict(q_offset=32, causal=causal, window=window,
              int8_logits=int8_logits)
    o_ref = ops.prefill_attention(qi, qsc, ki, vi, ks, vs, kv_len,
                                  impl="ref", **kw)
    o_ker = ops.prefill_attention(qi, qsc, ki, vi, ks, vs, kv_len,
                                  impl="pallas", **kw)
    np.testing.assert_allclose(np.asarray(o_ker), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)
    # empty lane emits exactly zero in both arms
    assert bool(jnp.all(o_ref[1] == 0)) and bool(jnp.all(o_ker[1] == 0))


@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_int8_logits_is_bitwise_on_cpu(impl):
    """Both QKᵀ branches dequantize after the dot; int8 products summed
    in f32 are exact below 2²⁴, so the branches cannot knife-edge apart
    under repeated absmax requantization (the avalanche regression)."""
    rng = np.random.default_rng(1)
    qi, qsc, ki, vi, ks, vs = _rand_inputs(rng, 1, 4, 2, 8, 32, 64)
    kv_len = jnp.asarray([40], jnp.int32)
    a = ops.prefill_attention(qi, qsc, ki, vi, ks, vs, kv_len,
                              causal=True, int8_logits=False, impl=impl)
    b = ops.prefill_attention(qi, qsc, ki, vi, ks, vs, kv_len,
                              causal=True, int8_logits=True, impl=impl)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_chunked_rows_bitwise_equal_whole(impl):
    """Chunk-carry invariant at the op level: per query row, a chunked
    call folds the same tiles with the same masks, so chunked == whole
    BITWISE over the same capacity-padded cache."""
    rng = np.random.default_rng(2)
    b, h, hkv, c, dh, m = 1, 4, 2, 16, 32, 64
    qi, qsc, ki, vi, ks, vs = _rand_inputs(rng, b, h, hkv, c, dh, m)
    whole = ops.prefill_attention(qi, qsc, ki, vi, ks, vs,
                                  jnp.asarray([48], jnp.int32),
                                  q_offset=32, causal=True, impl=impl)
    parts = []
    for i in range(4):                     # 4 chunks of 4 query rows
        sl = slice(i * 4, (i + 1) * 4)
        parts.append(ops.prefill_attention(
            qi[:, :, sl], qsc[:, :, sl], ki, vi, ks, vs,
            jnp.asarray([32 + (i + 1) * 4], jnp.int32),
            q_offset=32 + i * 4, causal=True, impl=impl))
    np.testing.assert_array_equal(np.asarray(whole),
                                  np.asarray(jnp.concatenate(parts, 2)))


# ---------------------------------------------------------------------------
# Autotune tiling matrix (DESIGN.md §Autotuning)
# ---------------------------------------------------------------------------

PREFILL_TILINGS = [
    # (block, bq): kv-tile sweeps and query-row (third grid axis) tiles;
    # kv_len below is NOT a multiple of any of these blocks
    (16, 0),
    (64, 8),
    (32, 1),
    (16, 4),
]


@pytest.mark.parametrize("block,bq", PREFILL_TILINGS)
def test_prefill_tiling_matrix(block, bq):
    """Swept (block, bq) under autotune.override: allclose vs the ref
    oracle on ragged kv_len (not a multiple of the kv block), and every
    bq variant BITWISE vs the untiled launch at the same kv block."""
    rng = np.random.default_rng(11)
    b, h, hkv, c, dh, m = 2, 4, 2, 8, 32, 64
    r = (h // hkv) * c
    assert autotune.valid_params(
        "prefill", {"bhg": b * hkv, "r": r, "d": dh, "m": m, "chunk": c},
        {"block": block, "bq": bq})
    qi, qsc, ki, vi, ks, vs = _rand_inputs(rng, b, h, hkv, c, dh, m)
    kv_len = jnp.asarray([41, 33], jnp.int32)
    kw = dict(q_offset=25, causal=True, window=0)
    o_ref = ops.prefill_attention(qi, qsc, ki, vi, ks, vs, kv_len,
                                  impl="ref", **kw)
    with autotune.override("prefill", block=block, bq=bq):
        o_t = ops.prefill_attention(qi, qsc, ki, vi, ks, vs, kv_len,
                                    impl="pallas", **kw)
    np.testing.assert_allclose(np.asarray(o_t), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)
    with autotune.override("prefill", block=block, bq=0):
        o_b = ops.prefill_attention(qi, qsc, ki, vi, ks, vs, kv_len,
                                    impl="pallas", **kw)
    np.testing.assert_array_equal(np.asarray(o_t), np.asarray(o_b))


def _setup(arch="bitnet-3b", **over):
    cfg = _reduced(arch).replace(**over) if over else _reduced(arch)
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, quantize_params(cfg, params)


def test_engine_chunked_prefill_bitwise_equals_whole():
    """prefill_chunk chain == whole-prompt prefill: final logits, cache
    contents over the valid region, and the next decode step, bitwise."""
    cfg, qp = _setup()
    rng = np.random.default_rng(3)
    plen, c = 27, 16                       # 27 % 16 != 0 → padded tail
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (1, plen)), jnp.int32)
    logits_w, cache_w = prefill(cfg, qp, prompt, max_len=MAX_LEN)

    cache = init_cache(cfg, 1, MAX_LEN)
    for k in range(2):
        lo, hi = k * c, min(plen, (k + 1) * c)
        buf = np.zeros((1, c), np.int32)
        buf[0, :hi - lo] = np.asarray(prompt[0, lo:hi])
        logits, cache = prefill_chunk(cfg, qp, jnp.asarray(buf), cache,
                                      start=jnp.int32(lo),
                                      seq_end=jnp.int32(hi))
    np.testing.assert_array_equal(np.asarray(logits_w), np.asarray(logits))
    np.testing.assert_array_equal(
        np.asarray(cache_w["layers"]["k"][..., :plen, :]),
        np.asarray(cache["layers"]["k"][..., :plen, :]))
    d1, _ = serve_step(cfg, qp, cache_w, jnp.asarray([[7]], jnp.int32))
    d2, _ = serve_step(cfg, qp, cache, jnp.asarray([[7]], jnp.int32))
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))


def test_scheduler_chunked_matches_lockstep_at_chunk_boundaries():
    """Chunked-interleaved scheduling is token-exact vs the lockstep
    reference for prompts below / at / straddling chunk multiples, with
    ONE chunk-shape compile covering every prompt."""
    cfg, qp = _setup()
    rng = np.random.default_rng(4)
    lens = [9, 16, 17, 32, 33, 45]        # <C, ==C, C+1, 2C, 2C+1, ...
    prompts = [rng.integers(0, cfg.vocab, (n,)).astype(np.int32)
               for n in lens]
    sched = Scheduler(cfg, qp, n_slots=2, max_len=MAX_LEN, chunk_tokens=16)
    assert sched.chunked
    for rid, p in enumerate(prompts):
        sched.submit(Request(rid=rid, prompt=p, max_new_tokens=5))
    results = sched.run_to_completion()
    assert sched.prefill_compiles == 1    # one fixed chunk shape
    assert sched.interleaved_decode_steps > 0
    for rid, p in enumerate(prompts):
        got = next(r for r in results if r.rid == rid)
        ref = lockstep_generate(cfg, qp, p, 5, max_len=MAX_LEN)
        assert got.tokens == ref, (rid, got.tokens, ref)


def test_scheduler_chunked_matches_run_to_completion():
    """Interleaving is a pure scheduling change: same tokens as the
    legacy run-to-completion scheduler on the same traffic."""
    cfg, qp = _setup()
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab, (n,)).astype(np.int32)
               for n in [12, 40, 21]]

    def run(chunked):
        s = Scheduler(cfg, qp, n_slots=2, max_len=MAX_LEN, chunked=chunked)
        for rid, p in enumerate(prompts):
            s.submit(Request(rid=rid, prompt=p, max_new_tokens=6))
        return {r.rid: r.tokens for r in s.run_to_completion()}, s

    toks_c, sc = run(True)
    toks_l, sl = run(False)
    assert toks_c == toks_l
    assert sc.full_prefill_stalls == 0    # chunked never blocks a batch
    assert sl.full_prefill_stalls > 0     # legacy does (slots were busy)


def test_vlm_image_prefix_rides_first_chunk():
    """llava-style requests chunk the [patches ‖ text] stream; the first
    chunk carries the patch embeds and later chunks shift by the prefix."""
    cfg, qp = _setup("llava-next-34b")
    rng = np.random.default_rng(6)
    max_len = 60
    sched = Scheduler(cfg, qp, n_slots=2, max_len=max_len, chunk_tokens=16)
    assert sched.chunked
    reqs = []
    for rid, plen in enumerate([7, 19]):   # 19 → two chunks past prefix
        p = rng.integers(0, cfg.vocab, (plen,)).astype(np.int32)
        patches = (rng.standard_normal((cfg.n_img_tokens, cfg.d_model))
                   .astype(np.float32) * 0.02)
        reqs.append(Request(rid=rid, prompt=p, max_new_tokens=4,
                            patches=patches))
        sched.submit(reqs[-1])
    results = sched.run_to_completion()
    for req in reqs:
        got = next(r for r in results if r.rid == req.rid)
        ref = lockstep_generate(cfg, qp, req.prompt, 4, max_len=max_len,
                                patches=req.patches)
        assert got.tokens == ref, req.rid


def test_float_path_chunk_carry_matches_full_stream():
    """models/attention + transformer chunk-carry: a suffix chunk scored
    against the full stream equals the same rows of a full-stream call
    (the training/eval mirror of engine chunked prefill)."""
    from repro.models.attention import attention_apply
    from repro.models.transformer import decoder_layer_apply

    cfg = _reduced("bitnet-3b")
    params, _ = init_params(cfg, jax.random.PRNGKey(1))
    lp = jax.tree.map(lambda a: a[0], params["layers"])
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal((2, 24, cfg.d_model)), jnp.float32)
    c = 8
    full = attention_apply(cfg, lp["attn"], x)
    part = attention_apply(cfg, lp["attn"], x[:, -c:], kv_x=x,
                           chunk_carry=True, q_offset=24 - c)
    np.testing.assert_allclose(np.asarray(full[:, -c:]), np.asarray(part),
                               rtol=1e-5, atol=1e-5)

    pos = jnp.arange(24)[None, :]
    yf, _ = decoder_layer_apply(cfg, lp, x, positions=pos)
    yc, _ = decoder_layer_apply(cfg, lp, x[:, -c:], positions=pos[:, -c:],
                                chunk_ctx=x)
    np.testing.assert_allclose(np.asarray(yf[:, -c:]), np.asarray(yc),
                               rtol=1e-5, atol=1e-5)
