import numpy as np
import pytest

# NOTE: no XLA_FLAGS here — tests must see exactly ONE device (the brief);
# multi-device behaviour is tested via subprocesses (tests/subproc/).


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
