"""Self-speculative decoding: exactness, rollback, capacity clamping.

The mode's core guarantee (DESIGN.md §Speculative-decoding): speculation
is a PURE perf optimization — a degraded-cost draft proposes γ tokens,
ONE chunk-shaped verify launch scores all γ+1 positions exactly, and the
emitted stream is the non-speculative stream:

  * greedy speculative decode is token-identical to ``lockstep_generate``
    (dense and vlm, pinned with the dense-attention decode path the
    verify chunk is bitwise-pinned against),
  * sampled speculative decode emits the same-seed non-speculative
    stream (draft token i and its verify row share one lane-local key),
  * ``rollback_slot`` is the exact inverse of speculative cache writes —
    pool bitwise-identical to never having speculated (hypothesis
    property),
  * γ shrinks at the slot-capacity boundary (off-by-γ overflow guard)
    and eos/stop fire inside an accepted window,
  * engines without the ``supports_speculative`` capability degrade to
    plain decode.

Runs under both REPRO_KERNEL_IMPL arms via scripts/ci_tier1.sh.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.transformer import init_params
from repro.serving import cache as _cache
from repro.serving.api import (CancelToken, ExistingPrefix, GenerateRequest,
                               PooledEngine, SamplingParams)
from repro.serving.quantize import quantize_params
from repro.serving.scheduler import Scheduler, lockstep_generate

from tests.test_models_smoke import _reduced

MAX_LEN = 63          # pool capacity 64 with the reduced lop_block of 32


@pytest.fixture(scope="module")
def setup():
    cfg = _reduced("bitnet-3b")
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, quantize_params(cfg, params)


def _prompts(cfg, lens, seed=3):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, (n,)).astype(np.int32) for n in lens]


def _run_sched(cfg, qp, reqs, *, spec, gamma=4, n_slots=2, use_lop=False,
               max_len=MAX_LEN, **kw):
    sched = Scheduler(cfg, qp, n_slots=n_slots, max_len=max_len,
                      use_lop=use_lop, spec_decode=spec, gamma=gamma, **kw)
    for r in reqs:
        sched.submit(r)
    results = {r.rid: r for r in sched.run_to_completion()}
    return sched, results


# ---------------------------------------------------------------------------
# Token-identity pins (the exactness proof)
# ---------------------------------------------------------------------------
# use_lop=False pins against the dense decode path: the verify chunk's
# logits are argmax-identical to dense decode by the chunk-carry contract.
# With LOP on, speculation emits the exact-attention stream while plain
# decode emits the screened-attention stream — see
# test_spec_with_lop_on_completes below and DESIGN.md §Speculative-decoding.


def test_greedy_spec_matches_lockstep_dense(setup):
    cfg, qp = setup
    prompts = _prompts(cfg, (9, 21))
    reqs = [GenerateRequest(rid=i, prompt=p, max_new_tokens=12)
            for i, p in enumerate(prompts)]
    sched, res = _run_sched(cfg, qp, reqs, spec=True, gamma=4)
    assert sched.spec and sched.spec_rounds > 0 \
        and sched.spec_verify_launches > 0
    for i, p in enumerate(prompts):
        ref = lockstep_generate(cfg, qp, p, 12, max_len=MAX_LEN,
                                use_lop=False)
        assert res[i].tokens == ref, f"rid {i} diverged from lockstep"


def test_greedy_spec_matches_lockstep_vlm():
    cfg = _reduced("llava-next-34b")
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    qp = quantize_params(cfg, params)
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab, (8,)).astype(np.int32)
    patches = (rng.standard_normal((cfg.n_img_tokens, cfg.d_model))
               .astype(np.float32) * 0.02)
    req = GenerateRequest(rid=0, prompt=prompt, max_new_tokens=10,
                          patches=patches)
    sched, res = _run_sched(cfg, qp, [req], spec=True, gamma=3, n_slots=1)
    assert sched.spec and sched.spec_rounds > 0
    ref = lockstep_generate(cfg, qp, prompt, 10, max_len=MAX_LEN,
                            use_lop=False, patches=patches)
    assert res[0].tokens == ref


def test_sampled_spec_matches_lockstep(setup):
    """A seeded sampled request emits its non-speculative stream: draft
    i and verify row i draw from the SAME emission-indexed lane key, and
    accepted tokens are always the verifier's draws."""
    cfg, qp = setup
    prompts = _prompts(cfg, (9, 21))
    sp = SamplingParams(temperature=0.9, top_k=8, seed=5)
    reqs = [GenerateRequest(rid=i, prompt=p, max_new_tokens=10, sampling=sp)
            for i, p in enumerate(prompts)]
    sched, res = _run_sched(cfg, qp, reqs, spec=True, gamma=3)
    for i, p in enumerate(prompts):
        ref = lockstep_generate(cfg, qp, p, 10, max_len=MAX_LEN,
                                use_lop=False, sampling=sp)
        assert res[i].tokens == ref, f"rid {i} diverged from lockstep"


def test_spec_matches_nonspec_scheduler(setup):
    """Speculative and plain scheduling emit identical streams while the
    speculative run amortizes full-model launches over accepted drafts."""
    cfg, qp = setup
    prompts = _prompts(cfg, (12, 30), seed=11)
    mk = lambda: [GenerateRequest(rid=i, prompt=p, max_new_tokens=8)
                  for i, p in enumerate(prompts)]
    spec_sched, spec_res = _run_sched(cfg, qp, mk(), spec=True, gamma=4)
    plain_sched, plain_res = _run_sched(cfg, qp, mk(), spec=False)
    for i in range(len(prompts)):
        assert spec_res[i].tokens == plain_res[i].tokens
    assert spec_sched.spec_verify_launches > 0
    assert spec_sched.decode_launches < plain_sched.decode_launches
    assert plain_sched.spec_rounds == 0


def test_spec_with_lop_on_completes(setup):
    """With the LOP screen live, speculation still serves every request to
    its budget — the emitted stream is the verifier's exact-attention
    stream (documented divergence from screened plain decode), and the
    telemetry stays consistent."""
    cfg, qp = setup
    prompts = _prompts(cfg, (9, 21))
    reqs = [GenerateRequest(rid=i, prompt=p, max_new_tokens=9)
            for i, p in enumerate(prompts)]
    sched, res = _run_sched(cfg, qp, reqs, spec=True, gamma=3, use_lop=True)
    for i in range(len(prompts)):
        assert len(res[i].tokens) == 9
        assert res[i].finish_reason == "length"
    # every token is the prefill seed, a plain-decode emission, or a
    # spec-round emission — the counters must close the books
    emitted = sum(len(r.tokens) for r in res.values())
    assert len(reqs) + sched.spec_emitted <= emitted
    assert sched.spec_accepted <= sched.spec_drafted


# ---------------------------------------------------------------------------
# Capacity clamp + finish-inside-window
# ---------------------------------------------------------------------------


def test_gamma_shrinks_at_capacity_boundary(setup):
    """Off-by-γ overflow guard: a request sized to land its last token on
    the final capacity position must decode correctly under a γ that
    would otherwise write past ``max_len`` — γ shrinks per round and the
    tail falls back to plain decode."""
    cfg, qp = setup
    (prompt,) = _prompts(cfg, (40,), seed=13)
    gen = 64 - 40            # need == pool capacity exactly
    req = GenerateRequest(rid=0, prompt=prompt, max_new_tokens=gen)
    sched, res = _run_sched(cfg, qp, [req], spec=True, gamma=8, n_slots=1)
    ref = lockstep_generate(cfg, qp, prompt, gen, max_len=MAX_LEN,
                            use_lop=False)
    assert res[0].tokens == ref
    assert res[0].finish_reason == "length"
    # the final lane state never exceeded capacity (evict zeroed it) and
    # some round actually ran with a shrunken γ or plain-decode fallback
    assert sched.decode_launches > 0 or sched.spec_rounds > 0


def test_eos_inside_accepted_window(setup):
    """An eos landing inside an accepted speculative window finishes the
    lane there — tokens past it are dropped exactly as plain decode
    would never have generated them."""
    cfg, qp = setup
    (prompt,) = _prompts(cfg, (15,), seed=17)
    ref = lockstep_generate(cfg, qp, prompt, 12, max_len=MAX_LEN,
                            use_lop=False)
    # pick an eos that first appears mid-stream (position >= 2) so it can
    # only fire inside a γ=4 window
    eos, cut = None, None
    for k in range(2, len(ref)):
        if ref[k] not in ref[:k]:
            eos, cut = ref[k], k
            break
    if eos is None:
        pytest.skip("reference stream has no unique mid-stream token")
    req = GenerateRequest(rid=0, prompt=prompt, max_new_tokens=12,
                          eos_id=eos)
    sched, res = _run_sched(cfg, qp, [req], spec=True, gamma=4, n_slots=1)
    assert res[0].tokens == ref[:cut + 1]
    assert res[0].finish_reason == "eos"


def test_stop_sequence_inside_accepted_window(setup):
    cfg, qp = setup
    (prompt,) = _prompts(cfg, (15,), seed=17)
    ref = lockstep_generate(cfg, qp, prompt, 12, max_len=MAX_LEN,
                            use_lop=False)
    cut = 3                         # stop on the first 4 emitted tokens
    req = GenerateRequest(rid=0, prompt=prompt, max_new_tokens=12,
                          stop=(tuple(ref[:cut + 1]),))
    sched, res = _run_sched(cfg, qp, [req], spec=True, gamma=4, n_slots=1)
    assert res[0].tokens == ref[:cut + 1]
    assert res[0].finish_reason == "stop"


def test_spec_degrades_without_capability(setup):
    """spec_decode=True on an engine that does not declare
    ``supports_speculative`` falls back to plain decode wholesale."""
    cfg, qp = setup
    engine = PooledEngine(cfg, qp, max_len=MAX_LEN, use_lop=False)
    engine.supports_speculative = False
    prompts = _prompts(cfg, (9,))
    reqs = [GenerateRequest(rid=0, prompt=prompts[0], max_new_tokens=6)]
    sched = Scheduler(cfg, qp, n_slots=1, max_len=MAX_LEN,
                      spec_decode=True, gamma=4, engine=engine)
    assert not sched.spec
    for r in reqs:
        sched.submit(r)
    res = {r.rid: r for r in sched.run_to_completion()}
    ref = lockstep_generate(cfg, qp, prompts[0], 6, max_len=MAX_LEN,
                            use_lop=False)
    assert res[0].tokens == ref
    assert sched.spec_rounds == 0


def test_gamma_validation(setup):
    cfg, qp = setup
    with pytest.raises(AssertionError):
        Scheduler(cfg, qp, n_slots=1, max_len=MAX_LEN, spec_decode=True,
                  gamma=0)


# ---------------------------------------------------------------------------
# Rollback property: speculative writes are exactly invertible
# ---------------------------------------------------------------------------


def _flat(pool):
    leaves = jax.tree_util.tree_flatten_with_path(pool)[0]
    return [(jax.tree_util.keystr(path), np.asarray(leaf))
            for path, leaf in leaves]


def _decode_n(engine, pool, toks_seq, temps, tks, tps):
    for t in toks_seq:
        _, pool = engine.decode_step(pool, np.asarray([[t]], np.int32),
                                     temps, tks, tps)
    return pool


@pytest.fixture(scope="module")
def rollback_rig(setup):
    """Shared engine + a prefilled batch-1 cache + a ``build(n)`` that
    inserts the lane and decodes ``n`` predetermined sampled tokens —
    the speculative write sequence the rollback must invert."""
    cfg, qp = setup
    engine = PooledEngine(cfg, qp, max_len=MAX_LEN, use_lop=False)
    (prompt,) = _prompts(cfg, (10,), seed=23)
    _, req_cache = engine.prefill(prompt[None], len(prompt), {})
    temps = np.asarray([0.8], np.float32)
    tks = np.asarray([0], np.int32)
    tps = np.asarray([1.0], np.float32)
    feed = np.random.default_rng(29).integers(
        0, cfg.vocab, (8,)).astype(np.int32)

    def build(n_steps):
        pool = engine.init_pool(1)
        pool = engine.insert(pool, 0, req_cache)
        pool = engine.set_sampling_state(pool, 0, 5, 1)
        return _decode_n(engine, pool, feed[:n_steps], temps, tks, tps)

    return engine, build


def _assert_pools_bitwise_equal(rolled, ref):
    a, b = _flat(rolled), _flat(ref)
    assert [k for k, _ in a] == [k for k, _ in b]
    for (key, va), (_, vb) in zip(a, b):
        assert va.dtype == vb.dtype and va.shape == vb.shape, key
        np.testing.assert_array_equal(va, vb, err_msg=key)


@pytest.mark.parametrize("gamma,j", [(1, 0), (1, 1), (3, 1), (4, 4),
                                     (6, 2)])
def test_rollback_inverts_decode_writes(rollback_rig, gamma, j):
    """insert → γ decode steps → rollback(j) is bitwise the pool that
    decoded only γ−j tokens: lengths, K/V, scales, LOP feature rows AND
    the PRNG seed/step leaves (deterministic grid; the hypothesis twin
    below widens the search where hypothesis is installed)."""
    engine, build = rollback_rig
    _assert_pools_bitwise_equal(engine.rollback(build(gamma), 0, j),
                                build(gamma - j))


def test_rollback_property(rollback_rig):
    """Hypothesis-driven version of the invariant above (skips when
    hypothesis is absent — the parametrized grid still runs)."""
    hypothesis = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")
    engine, build = rollback_rig

    @hypothesis.given(st.integers(1, 6), st.data())
    @hypothesis.settings(max_examples=8, deadline=None)
    def prop(gamma, data):
        j = data.draw(st.integers(0, gamma))
        _assert_pools_bitwise_equal(engine.rollback(build(gamma), 0, j),
                                    build(gamma - j))

    prop()


def test_cancel_token_fired_mid_spec_round(setup):
    """Regression (ISSUE 9 satellite): a CancelToken fired from the
    ``on_token`` callback BETWEEN a speculative round's emissions retires
    the lane inside ``_spec_round`` — the delivered tokens are a clean
    prefix of the lockstep stream, the unemitted verify window is rewound,
    and the freed lane then serves a fresh request token-exactly (the
    rewind accounting left no residue)."""
    cfg, qp = setup
    p0, p1 = _prompts(cfg, (15, 9), seed=37)
    ref = lockstep_generate(cfg, qp, p0, 12, max_len=MAX_LEN,
                            use_lop=False)
    tok = CancelToken()

    def on_token(sr):
        if sr.index >= 1:
            tok.cancel()

    sched = Scheduler(cfg, qp, n_slots=1, max_len=MAX_LEN, use_lop=False,
                      spec_decode=True, gamma=4)
    sched.submit(GenerateRequest(rid=0, prompt=p0, max_new_tokens=12,
                                 cancel=tok, on_token=on_token))
    res = {r.rid: r for r in sched.run_to_completion()}
    assert sched.spec_rounds >= 1
    assert res[0].finish_reason == "cancelled"
    assert 2 <= len(res[0].tokens) < 12
    assert res[0].tokens == ref[:len(res[0].tokens)]
    assert sched.n_active == 0 and len(sched._free) == 1
    # the rewound pool is coherent: the SAME lane serves the next request
    # bitwise-exactly
    sched.submit(GenerateRequest(rid=1, prompt=p1, max_new_tokens=8))
    res = {r.rid: r for r in sched.run_to_completion()}
    assert res[1].tokens == lockstep_generate(cfg, qp, p1, 8,
                                              max_len=MAX_LEN,
                                              use_lop=False)


def test_rollback_into_interned_prefix_leaves_store_pages_intact(setup):
    """Property (ISSUE 9 satellite, alongside the PR 7 rollback grid
    above): ``rollback_slot`` into a region cloned from ref-counted
    interned blocks mutates only the lane's pool copy — the store's
    pages, re-assembled afterwards, are bitwise identical, so a later
    sharer of the same prefix is unaffected."""
    cfg, qp = setup
    engine = PooledEngine(cfg, qp, max_len=MAX_LEN, use_lop=False)
    store = _cache.PrefixStore(engine.prefix_block)
    rng = np.random.default_rng(43)
    prompt = rng.integers(0, cfg.vocab, (40,)).astype(np.int32)
    _, c = engine.prefill(prompt[None], len(prompt), {})
    n = (len(prompt) // store.block) * store.block
    node = store.insert(prompt[:n], c)
    assert node is not None and node.n_tokens == n
    snap = [(jax.tree_util.keystr(path), np.asarray(leaf).copy())
            for path, leaf in jax.tree_util.tree_flatten_with_path(
                store.assemble(node))[0]]

    pool = engine.init_pool(1)
    prefix = ExistingPrefix(cache=store.assemble(node), common_len=n)
    pool = engine.bulk_insert(pool, np.asarray([0], np.int32), prefix)
    pool = engine.rollback(pool, 0, 5)      # back INTO the interned region
    assert int(pool["lengths"][0]) == n - 5

    after = jax.tree_util.tree_flatten_with_path(store.assemble(node))[0]
    assert len(after) == len(snap)
    for (key, a), (path, b) in zip(snap, after):
        assert key == jax.tree_util.keystr(path)
        np.testing.assert_array_equal(a, np.asarray(b), err_msg=key)
    store.check_invariants()


def test_rollback_slot_targets_one_lane(setup):
    """Rolling back one lane leaves every other lane untouched."""
    cfg, qp = setup
    engine = PooledEngine(cfg, qp, max_len=MAX_LEN, use_lop=False)
    p0, p1 = _prompts(cfg, (10, 14), seed=31)
    _, c0 = engine.prefill(p0[None], len(p0), {})
    _, c1 = engine.prefill(p1[None], len(p1), {})
    pool = engine.init_pool(2)
    pool = engine.insert(pool, 0, c0)
    pool = engine.insert(pool, 1, c1)
    lane1_before = jax.tree.map(np.asarray,
                                _cache.extract_slot(pool, 1))
    pool = engine.rollback(pool, 0, 3)
    assert int(pool["lengths"][0]) == len(p0) - 3
    assert int(pool["lengths"][1]) == len(p1)
    lane1_after = jax.tree.map(np.asarray, _cache.extract_slot(pool, 1))
    for (pa, a), (_, b) in zip(
            jax.tree_util.tree_flatten_with_path(lane1_before)[0],
            jax.tree_util.tree_flatten_with_path(lane1_after)[0]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=jax.tree_util.keystr(pa))
