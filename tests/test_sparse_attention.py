"""Predictive sparse attention system behaviour (paper §III-A)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.lop import lop_features
from repro.core.sparse_attention import (dense_reference_attention,
                                         predictive_sparse_attention)

rng = np.random.default_rng(1)


def _setup(b=2, h=4, hkv=2, m=256, d=32):
    q = jnp.asarray(rng.integers(-40, 41, (b, h, d)), jnp.int8)
    k = jnp.asarray(rng.integers(-40, 41, (b, hkv, m, d)), jnp.int8)
    v = jnp.asarray(rng.integers(-40, 41, (b, hkv, m, d)), jnp.int8)
    feat = lop_features(k)
    valid = jnp.broadcast_to(jnp.arange(m)[None], (b, m)) < jnp.asarray(
        [m - 56, m])[:, None]
    return q, k, v, feat, valid


def test_keep_all_equals_dense():
    q, k, v, feat, valid = _setup()
    o_sparse = predictive_sparse_attention(q, k, v, feat, valid,
                                           k_blocks=256 // 64, block=64)
    o_dense = dense_reference_attention(q, k, v, valid)
    np.testing.assert_allclose(np.asarray(o_sparse), np.asarray(o_dense),
                               atol=1e-2)


def test_error_decreases_with_k():
    q, k, v, feat, valid = _setup()
    o_dense = np.asarray(dense_reference_attention(q, k, v, valid))
    errs = []
    for kb in (1, 2, 4):
        o = np.asarray(predictive_sparse_attention(q, k, v, feat, valid,
                                                   k_blocks=kb, block=64))
        errs.append(np.linalg.norm(o - o_dense) / np.linalg.norm(o_dense))
    assert errs[-1] <= errs[0] + 1e-6, errs
    assert errs[-1] < 1e-2                      # K=all is exact


def test_no_retraining_needed_high_recall_regime():
    """With peaked score distributions (realistic attention), small K
    captures most of the mass — logit error stays small."""
    b, h, hkv, m, d = 1, 2, 1, 512, 64
    k = rng.integers(-8, 9, (b, hkv, m, d)).astype(np.int8)
    # plant strong keys in one block
    k[:, :, 128:160] *= 8
    q = (k[:, 0, 140] // 2).astype(np.int8).reshape(b, 1, d)
    q = np.repeat(q, h, axis=1)
    kj, vj = jnp.asarray(k), jnp.asarray(
        rng.integers(-40, 41, (b, hkv, m, d)).astype(np.int8))
    feat = lop_features(kj)
    valid = jnp.ones((b, m), bool)
    o_dense = np.asarray(dense_reference_attention(jnp.asarray(q), kj, vj,
                                                   valid))
    o_k2 = np.asarray(predictive_sparse_attention(
        jnp.asarray(q), kj, vj, feat, valid, k_blocks=2, block=32))
    rel = np.linalg.norm(o_k2 - o_dense) / np.linalg.norm(o_dense)
    assert rel < 0.05, rel
