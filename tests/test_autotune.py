"""kernels/autotune.py: candidates, table I/O, and the dispatch lookup.

The autotuning contract (DESIGN.md §Autotuning) has three legs:

  1. ``candidates()`` only emits LEGAL tilings (the divisibility screen)
     whose one-grid-step footprint fits the roofline VMEM budget, with
     the hardcoded default always candidate 0 — a sweep can never
     regress dispatch below the status quo;
  2. ``lookup()`` precedence: override context → table entry
     (``REPRO_TUNE=0`` disables) → ``{}``; a stale/illegal table entry
     falls through to ``{}`` instead of crashing dispatch;
  3. ``validate_table()`` is the CI gate's static half: structural
     problems in a persisted ``TUNE_*.json`` surface as strings, and a
     missing table is fine (the fallback IS the contract).

Plus the wiring: ``ops`` consults ``lookup()`` at dispatch, so an
``override`` context changes a real dispatch's tiling without changing
its bits.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.roofline import vmem_budget
from repro.core.ternary import make_ternary_weight
from repro.kernels import autotune, ops

DIMS = {
    "ternary_matmul": {"m": 8, "k": 256, "n": 256},
    "qlinear": {"e": 2, "m": 8, "k": 256, "n": 256},
    "ffn": {"e": 1, "m": 8, "k": 256, "f": 512, "n": 256},
    "prefill": {"bhg": 2, "r": 64, "d": 64, "m": 256, "chunk": 32},
    "decode": {"bhg": 2, "g": 2, "d": 64, "m": 256, "block": 64,
               "k_keep": 2},
}


# ---------------------------------------------------------------------------
# Candidate generation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kernel", autotune.KERNELS)
def test_candidates_legal_unique_within_budget(kernel):
    dims = DIMS[kernel]
    cands = autotune.candidates(kernel, dims, max_candidates=12)
    assert 1 <= len(cands) <= 12
    seen = []
    for p in cands:
        assert set(p) == set(autotune.KERNEL_PARAMS[kernel]), p
        assert autotune.valid_params(kernel, dims, p), p
        assert p not in seen, p
        seen.append(p)
    # every swept (non-default) candidate fits the roofline VMEM budget
    for p in cands[1:]:
        assert autotune._tile_footprint(kernel, dims, p) <= vmem_budget()


def test_candidate_zero_is_the_hardcoded_default():
    """The sweep always times the status-quo tiling first."""
    q = autotune.candidates("qlinear", DIMS["qlinear"])[0]
    assert q == {"bm": 8, "bn": 128, "bkq": 0, "eg": 1}
    d = autotune.candidates("decode", DIMS["decode"])[0]
    assert d == {"n_slots": 2}
    p = autotune.candidates("prefill", DIMS["prefill"])[0]
    assert p == {"block": 128, "bq": 0}


def test_max_candidates_caps_the_sweep():
    cands = autotune.candidates("ffn", DIMS["ffn"], max_candidates=3)
    assert len(cands) == 3


# ---------------------------------------------------------------------------
# Keys and the legality screen
# ---------------------------------------------------------------------------

def test_shape_key_uses_declared_dim_order():
    key = autotune.shape_key("qlinear", {"n": 4, "k": 3, "m": 2, "e": 1})
    assert key == "e=1,m=2,k=3,n=4"
    with pytest.raises(AssertionError):
        autotune.shape_key("qlinear", {"m": 2})


def test_config_key_tracks_backend():
    ck = autotune.config_key()
    if jax.default_backend() == "tpu":
        assert ck == "tpu"
    else:
        assert ck.endswith("-interpret")


@pytest.mark.parametrize("kernel,params,ok", [
    ("qlinear", {"bm": 8, "bn": 32, "bkq": 32, "eg": 2}, True),
    ("qlinear", {"bm": 12, "bn": 32}, False),       # bm not a multiple of 8
    ("qlinear", {"bn": 7}, False),                  # 7 does not divide n
    ("qlinear", {"bkq": 24}, False),                # 24 does not divide k
    ("qlinear", {"eg": 3}, False),                  # 3 does not divide e
    ("qlinear", {"nope": 1}, False),                # unknown knob
    ("qlinear", "bm=8", False),                     # not a dict
    ("ffn", {"bm": 8, "bf": 64, "bn": 32, "bkq": 0}, True),
    ("ffn", {"bf": 7}, False),
    ("prefill", {"block": 32, "bq": 8}, True),
    ("prefill", {"block": 32, "bq": 7}, False),     # 7 does not divide r
    ("prefill", {"block": 0}, False),
    ("decode", {"n_slots": 4}, True),
    ("decode", {"n_slots": 0}, False),
    ("ternary_matmul", {"bm": 8, "bk": 64, "bn": 32}, True),
    ("ternary_matmul", {"bk": 7}, False),
])
def test_valid_params_screen(kernel, params, ok):
    dims = {k: v for k, v in DIMS[kernel].items()}
    dims.update({"e": 2} if kernel == "qlinear" else {})
    assert autotune.valid_params(kernel, dims, params) is ok


# ---------------------------------------------------------------------------
# Table I/O + lookup precedence
# ---------------------------------------------------------------------------

QDIMS = {"e": 1, "m": 8, "k": 64, "n": 64}


def _mk_table(tmp_path, monkeypatch, params, *, us=1.0):
    path = tmp_path / "TUNE_test.json"
    monkeypatch.setenv("REPRO_TUNE_TABLE", str(path))
    table = {"version": autotune.TABLE_VERSION,
             "configs": {autotune.config_key(): {"qlinear": {
                 autotune.shape_key("qlinear", QDIMS):
                     {"params": params, "us": us}}}}}
    autotune.save_table(table, path)
    return path


def test_lookup_hits_table_misses_other_shapes(tmp_path, monkeypatch):
    _mk_table(tmp_path, monkeypatch, {"bm": 8, "bn": 32, "bkq": 32, "eg": 1})
    assert autotune.lookup("qlinear", QDIMS) == \
        {"bm": 8, "bn": 32, "bkq": 32, "eg": 1}
    other = dict(QDIMS, m=16)
    assert autotune.lookup("qlinear", other) == {}
    assert autotune.lookup("ffn", DIMS["ffn"]) == {}


def test_repro_tune_0_disables_the_table(tmp_path, monkeypatch):
    _mk_table(tmp_path, monkeypatch, {"bn": 32})
    monkeypatch.setenv("REPRO_TUNE", "0")
    assert autotune.lookup("qlinear", QDIMS) == {}


def test_override_beats_table_and_restores(tmp_path, monkeypatch):
    _mk_table(tmp_path, monkeypatch, {"bn": 32})
    with autotune.override("qlinear", bm=8, bn=64):
        assert autotune.lookup("qlinear", QDIMS) == {"bm": 8, "bn": 64}
        with autotune.override("qlinear", bn=16):
            assert autotune.lookup("qlinear", QDIMS) == {"bn": 16}
        assert autotune.lookup("qlinear", QDIMS) == {"bm": 8, "bn": 64}
    assert autotune.lookup("qlinear", QDIMS) == {"bn": 32}


def test_illegal_override_falls_through_to_defaults(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_TABLE", str(tmp_path / "absent.json"))
    with autotune.override("qlinear", bn=7):     # 7 does not divide n=64
        assert autotune.lookup("qlinear", QDIMS) == {}


def test_stale_table_entry_falls_through_and_is_flagged(tmp_path,
                                                        monkeypatch):
    path = _mk_table(tmp_path, monkeypatch, {"bn": 7})
    assert autotune.lookup("qlinear", QDIMS) == {}
    problems = autotune.validate_table(path)
    assert len(problems) == 1 and "illegal params" in problems[0]


def test_load_table_missing_and_reload_on_save(tmp_path):
    path = tmp_path / "TUNE_x.json"
    assert autotune.load_table(path) == {}
    t1 = {"version": 1, "configs": {}}
    autotune.save_table(t1, path)
    assert autotune.load_table(path) == t1
    t2 = {"version": 1, "configs": {"cpu-interpret": {}}}
    autotune.save_table(t2, path)
    assert autotune.load_table(path) == t2
    path.write_text("{not json")
    assert autotune.load_table(path) == {}


def test_validate_table_structural_problems(tmp_path):
    assert autotune.validate_table(tmp_path / "absent.json") == []
    path = tmp_path / "TUNE_bad.json"
    path.write_text("{not json")
    assert len(autotune.validate_table(path)) == 1
    bad = {"version": 99, "configs": {"cpu-interpret": {
        "nope": {"m=8": {"params": {}}},
        "qlinear": {
            "m=x": {"params": {}},                     # unparseable key
            "m=8": {"params": {}},                     # wrong dims
            "e=1,m=8,k=64,n=64": {"params": {"bn": 7}},  # illegal params
        }}}}
    path.write_text(json.dumps(bad))
    problems = autotune.validate_table(path)
    assert len(problems) == 5
    joined = "\n".join(problems)
    for frag in ("version", "unknown kernel", "bad shape key", "dims !=",
                 "illegal params"):
        assert frag in joined, frag


# ---------------------------------------------------------------------------
# Dispatch wiring: an override changes the tiling, never the bits
# ---------------------------------------------------------------------------

def test_override_retiles_qlinear_dispatch_bitwise():
    rng = np.random.default_rng(3)
    k, n = 64, 64
    tw = make_ternary_weight(
        jnp.asarray(rng.standard_normal((k, n)), jnp.float32) * 0.02)
    sc = jnp.asarray(tw.scale).reshape(1, 1)
    x = jnp.asarray(rng.standard_normal((5, k)), jnp.float32)
    base = ops.qlinear_fused(x, tw.packed, sc, impl="pallas")
    with autotune.override("qlinear", bm=8, bn=32, bkq=32, eg=1):
        tuned = ops.qlinear_fused(x, tw.packed, sc, impl="pallas")
    assert (np.asarray(base) == np.asarray(tuned)).all()


def test_override_retiles_ternary_matmul_dispatch_bitwise():
    rng = np.random.default_rng(4)
    k, n = 64, 64
    tw = make_ternary_weight(
        jnp.asarray(rng.standard_normal((k, n)), jnp.float32) * 0.02)
    xq = jnp.asarray(rng.integers(-127, 128, (8, k)), jnp.int8)
    base = ops.ternary_matmul(xq, tw, impl="pallas")
    with autotune.override("ternary_matmul", bm=8, bk=32, bn=32):
        tuned = ops.ternary_matmul(xq, tw, impl="pallas")
    ref = ops.ternary_matmul(xq, tw, impl="ref")
    assert (np.asarray(base) == np.asarray(tuned)).all()
    assert (np.asarray(tuned) == np.asarray(ref)).all()


# ---------------------------------------------------------------------------
# Log-and-sweep sidecar: dispatch shapes -> JSON -> --from-log sweep set
# ---------------------------------------------------------------------------

def test_shape_log_records_dedupes_and_loads(tmp_path):
    path = tmp_path / "shapes.json"
    autotune.start_shape_log(path)
    try:
        dims = {"m": 8, "k": 64, "n": 128}
        autotune.observe("ternary_matmul", dims)
        autotune.observe("ternary_matmul", dims)          # dedup'd
        autotune.observe("qlinear", {"e": 1, "m": 4, "k": 64, "n": 64})
        autotune.observe("not_a_kernel", {"m": 1})        # unknown: no-op
    finally:
        autotune.stop_shape_log()
    raw = json.loads(path.read_text())
    assert raw["version"] == autotune.SHAPE_LOG_VERSION
    assert raw["shapes"]["ternary_matmul"] == [
        autotune.shape_key("ternary_matmul", dims)]
    loaded = autotune.load_shape_log(path)
    assert loaded == {"ternary_matmul": [dims],
                      "qlinear": [{"e": 1, "m": 4, "k": 64, "n": 64}]}


def test_shape_log_survives_restart_and_unions(tmp_path):
    """A second enable (fresh ``seen`` set, e.g. a new server process)
    appends to the same sidecar instead of clobbering it."""
    path = tmp_path / "shapes.json"
    autotune.start_shape_log(path)
    autotune.observe("ternary_matmul", {"m": 8, "k": 64, "n": 128})
    autotune.stop_shape_log()
    autotune.start_shape_log(path)
    autotune.observe("ternary_matmul", {"m": 8, "k": 64, "n": 128})
    autotune.observe("ternary_matmul", {"m": 16, "k": 64, "n": 128})
    autotune.stop_shape_log()
    loaded = autotune.load_shape_log(path)
    assert len(loaded["ternary_matmul"]) == 2
    assert {"m": 8, "k": 64, "n": 128} in loaded["ternary_matmul"]
    assert {"m": 16, "k": 64, "n": 128} in loaded["ternary_matmul"]


def test_merged_shapes_grows_defaults_without_duplicates(tmp_path):
    path = tmp_path / "shapes.json"
    autotune.start_shape_log(path)
    known = autotune.DEFAULT_SHAPES["ternary_matmul"][0]
    novel = {"m": 3, "k": 64, "n": 128}
    assert novel not in autotune.DEFAULT_SHAPES["ternary_matmul"]
    autotune.observe("ternary_matmul", known)             # already swept
    autotune.observe("ternary_matmul", novel)
    autotune.stop_shape_log()
    merged = autotune.merged_shapes(path)
    base = autotune.DEFAULT_SHAPES["ternary_matmul"]
    assert merged["ternary_matmul"][:len(base)] == base
    assert merged["ternary_matmul"].count(known) == 1
    assert novel in merged["ternary_matmul"]


def test_shape_log_env_off_by_default(tmp_path, monkeypatch):
    monkeypatch.delenv(autotune.SHAPE_LOG_ENV, raising=False)
    autotune.stop_shape_log()
    assert autotune.shape_log_path() is None
    monkeypatch.setenv(autotune.SHAPE_LOG_ENV, "0")
    assert autotune.shape_log_path() is None
    monkeypatch.setenv(autotune.SHAPE_LOG_ENV, str(tmp_path / "s.json"))
    assert autotune.shape_log_path() == tmp_path / "s.json"


def test_ops_dispatch_feeds_the_shape_log(tmp_path):
    """A real kernel call while logging is armed lands its dims in the
    sidecar — the PooledEngine(shape_log=...) wiring minus the engine."""
    path = tmp_path / "shapes.json"
    rng = np.random.default_rng(5)
    k, n = 64, 64
    tw = make_ternary_weight(
        jnp.asarray(rng.standard_normal((k, n)), jnp.float32) * 0.02)
    xq = jnp.asarray(rng.integers(-127, 128, (8, k)), jnp.int8)
    autotune.start_shape_log(path)
    try:
        ops.ternary_matmul(xq, tw)
    finally:
        autotune.stop_shape_log()
    loaded = autotune.load_shape_log(path)
    assert {"m": 8, "k": k, "n": n} in loaded["ternary_matmul"]
