"""Prefix caching: hash-chain store, bulk clone, and hit/miss scheduling.

The acceptance bar (DESIGN.md §Prefix-caching): a prefix-hit request —
its prompt's cached blocks cloned via ``bulk_insert`` and chunked prefill
resumed at the block boundary — decodes token-identically to the same
request prefilled cold with the cache disabled, alongside arbitrary
cold traffic.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.cache import (PrefixStore, bulk_insert, evict_slot,
                                 extract_slot, init_cache_pool, insert_slot)
from repro.serving.engine import prefill
from repro.models.transformer import init_params
from repro.serving.quantize import quantize_params
from repro.serving.scheduler import Request, Scheduler

from tests.test_models_smoke import _reduced

MAX_LEN = 63          # pool capacity 64 with the reduced lop_block of 32


def _setup(arch="bitnet-3b", **over):
    cfg = _reduced(arch).replace(**over) if over else _reduced(arch)
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, quantize_params(cfg, params)


def _prompts(cfg, lens, seed=3):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, (n,)).astype(np.int32) for n in lens]


def _tree_equal(a, b):
    for la, lb in zip(jax.tree.leaves(jax.tree.map(np.asarray, a)),
                      jax.tree.leaves(jax.tree.map(np.asarray, b))):
        np.testing.assert_array_equal(la, lb)


# ---------------------------------------------------------------------------
# PrefixStore host-side semantics (no model needed for most of these)
# ---------------------------------------------------------------------------


def _fake_cache(cfg, n_tokens):
    """Batch-1 positional cache with recognizable per-position bytes."""
    cache = {
        "lengths": jnp.full((1,), n_tokens, jnp.int32),
        "layers": {
            "k": jnp.broadcast_to(
                jnp.arange(n_tokens, dtype=jnp.int8)[None, None, :, None],
                (1, cfg.n_kv_heads, n_tokens, cfg.hd)),
            "k_scale": jnp.broadcast_to(
                jnp.arange(n_tokens, dtype=jnp.float32)[None, None, :],
                (1, cfg.n_kv_heads, n_tokens)),
        },
    }
    return cache


def test_store_match_is_strict_prefix_and_token_checked():
    cfg = _reduced("bitnet-3b")
    rng = np.random.default_rng(7)
    store = PrefixStore(32)
    toks = rng.integers(0, cfg.vocab, (64,)).astype(np.int32)
    assert store.match(toks) == (0, None)            # empty store: miss
    store.insert(toks, _fake_cache(cfg, 64))
    assert store.cached_tokens == 64
    # exact-length prompt matches only the STRICT prefix (one block)
    n, node = store.match(toks)
    assert n == 32 and node.n_tokens == 32
    # a longer prompt sharing both blocks matches the full chain
    longer = np.concatenate([toks, toks[:5]])
    n, node = store.match(longer)
    assert n == 64 and node.n_tokens == 64
    # first-block divergence misses even though later blocks agree
    div = toks.copy()
    div[0] = (div[0] + 1) % cfg.vocab
    assert store.match(np.concatenate([div, toks[:5]])) == (0, None)
    # second-block divergence matches one block
    div2 = toks.copy()
    div2[40] = (div2[40] + 1) % cfg.vocab
    n, _ = store.match(np.concatenate([div2, toks[:5]]))
    assert n == 32
    # missing() flips once the chain is fully interned
    assert not store.missing(toks)
    assert store.missing(np.concatenate([toks, toks[:32]]))


def test_store_assemble_round_trips_pages():
    cfg = _reduced("bitnet-3b")
    rng = np.random.default_rng(8)
    store = PrefixStore(32)
    toks = rng.integers(0, cfg.vocab, (64,)).astype(np.int32)
    cache = _fake_cache(cfg, 64)
    store.insert(toks, cache)
    _, node = store.match(np.concatenate([toks, toks[:1]]))
    out = store.assemble(node)
    _tree_equal(out, cache)


def test_store_lru_eviction_is_ref_counted_leaf_first():
    cfg = _reduced("bitnet-3b")
    rng = np.random.default_rng(9)
    store = PrefixStore(32, max_tokens=96)
    a = rng.integers(0, cfg.vocab, (64,)).astype(np.int32)      # chain A: 2
    b = rng.integers(0, cfg.vocab, (32,)).astype(np.int32)      # chain B: 1
    store.insert(a, _fake_cache(cfg, 64))
    store.insert(b, _fake_cache(cfg, 32))
    assert store.cached_tokens == 96
    # touch B so A's leaf is the coldest childless node
    store.match(np.concatenate([b, b[:1]]))
    c = rng.integers(0, cfg.vocab, (32,)).astype(np.int32)
    store.insert(c, _fake_cache(cfg, 32))                       # over budget
    assert store.cached_tokens == 96 and store.evictions == 1
    # A's LEAF went (ref-counted: the root-side block had a child), so A
    # still matches one block; B is intact
    n, _ = store.match(np.concatenate([a, a[:1]]))
    assert n == 32
    n, _ = store.match(np.concatenate([b, b[:1]]))
    assert n == 32


def test_bulk_insert_clones_one_prefill_into_many_lanes():
    """bulk_insert == insert_slot per lane, in one scatter: K/V pages,
    scales, LOP feature rows and lengths all land bit-identically, and
    per-lane sampling state is untouched."""
    cfg, qp = _setup()
    (p,) = _prompts(cfg, [37], seed=11)
    _, rc = prefill(cfg, qp, p[None], max_len=MAX_LEN)
    pool = init_cache_pool(cfg, 4, MAX_LEN)
    pool = dict(pool)
    pool["seed"] = jnp.arange(4, dtype=jnp.int32)       # must survive clone
    bulk = bulk_insert(pool, jnp.asarray([1, 3], jnp.int32), rc,
                       active=False)
    ref = insert_slot(pool, jnp.int32(1), rc, active=False)
    ref = insert_slot(ref, jnp.int32(3), rc, active=False)
    _tree_equal(bulk, ref)
    np.testing.assert_array_equal(np.asarray(bulk["seed"]), [0, 1, 2, 3])
    assert not np.asarray(bulk["active"]).any()


def test_bulk_insert_into_evicted_lane_matches_fresh_pool():
    """Clone into a lane a previous occupant dirtied == clone into a fresh
    pool (the evict feat-zeroing invariant, end to end)."""
    cfg, qp = _setup()
    dirty_p, p = _prompts(cfg, [45, 33], seed=12)
    _, dirty_rc = prefill(cfg, qp, dirty_p[None], max_len=MAX_LEN)
    _, rc = prefill(cfg, qp, p[None], max_len=MAX_LEN)
    pool = init_cache_pool(cfg, 2, MAX_LEN)
    pool = insert_slot(pool, jnp.int32(0), dirty_rc)
    pool = evict_slot(pool, jnp.int32(0))
    reused = bulk_insert(pool, jnp.asarray([0], jnp.int32), rc,
                         active=False)
    fresh = bulk_insert(init_cache_pool(cfg, 2, MAX_LEN),
                        jnp.asarray([0], jnp.int32), rc, active=False)
    # K/V may keep stale bytes above lengths (masked); the lane the LOP
    # screen actually reads — the feature rows — must be identical
    _tree_equal(reused["layers"]["feat"], fresh["layers"]["feat"])
    _tree_equal(extract_slot(reused, jnp.int32(0))["layers"]["feat"],
                extract_slot(fresh, jnp.int32(0))["layers"]["feat"])
    np.testing.assert_array_equal(np.asarray(reused["lengths"]),
                                  np.asarray(fresh["lengths"]))


# ---------------------------------------------------------------------------
# Scheduler end-to-end: mixed hit/miss traffic == cache-off solo runs
# ---------------------------------------------------------------------------


def test_scheduler_mixed_hit_miss_matches_cache_off_solo():
    """A prefix-hit request admitted alongside cold requests decodes
    token-identically to the same request run ALONE with caching
    disabled — the PR's acceptance criterion."""
    cfg, qp = _setup()
    rng = np.random.default_rng(13)
    shared = rng.integers(0, cfg.vocab, (32,)).astype(np.int32)
    suffixes = _prompts(cfg, [9, 14, 5], seed=14)
    cold = _prompts(cfg, [11, 26], seed=15)
    prompts = [np.concatenate([shared, s]) for s in suffixes] + cold
    # 1 lane → sharers admit strictly after the first one interned
    sched = Scheduler(cfg, qp, n_slots=1, max_len=MAX_LEN)
    assert sched.prefix_store is not None
    for rid, p in enumerate(prompts):
        sched.submit(Request(rid=rid, prompt=p, max_new_tokens=6))
    results = {r.rid: r for r in sched.run_to_completion()}
    assert sched.prefix_hits == 2
    assert sched.prefix_hit_tokens == 64
    assert results[1].cached_len == 32 and results[2].cached_len == 32
    assert results[0].cached_len == 0 and results[3].cached_len == 0
    # skipped chunks are real: computed < served by exactly the hits
    assert sched.prefill_tokens_served \
        == sched.prefill_tokens_computed + 64
    for rid, p in enumerate(prompts):
        solo = Scheduler(cfg, qp, n_slots=1, max_len=MAX_LEN,
                         prefix_cache=False)
        solo.submit(Request(rid=rid, prompt=p, max_new_tokens=6))
        ref = solo.run_to_completion()[0]
        assert results[rid].tokens == ref.tokens, rid
        assert ref.cached_len == 0


def test_scheduler_same_sweep_sharers_hit_after_interning():
    """Sharers admitted in ONE sweep all miss an empty store (no in-flight
    reservation sharing), but a later wave hits the interned prefix and
    the bulk clone lands every hit in the same admit call."""
    cfg, qp = _setup()
    rng = np.random.default_rng(16)
    shared = rng.integers(0, cfg.vocab, (32,)).astype(np.int32)
    prompts = [np.concatenate([shared, s])
               for s in _prompts(cfg, [7, 10, 8, 12], seed=17)]
    sched = Scheduler(cfg, qp, n_slots=2, max_len=MAX_LEN)
    for rid, p in enumerate(prompts):
        sched.submit(Request(rid=rid, prompt=p, max_new_tokens=5))
    results = {r.rid: r for r in sched.run_to_completion()}
    # wave 1 (rids 0,1): both cold; wave 2 (rids 2,3): both hit
    assert sched.prefix_hits == 2
    assert [results[r].cached_len for r in range(4)] == [0, 0, 32, 32]
    for rid, p in enumerate(prompts):
        solo = Scheduler(cfg, qp, n_slots=1, max_len=MAX_LEN,
                         prefix_cache=False)
        solo.submit(Request(rid=rid, prompt=p, max_new_tokens=5))
        assert results[rid].tokens == solo.run_to_completion()[0].tokens
