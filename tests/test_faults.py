"""Fault-tolerant serving: injection, recovery, deadlines, admission.

The robustness contract (DESIGN.md §Fault-tolerance): under a
deterministic seeded :class:`repro.serving.faults.FaultPlan` the serving
stack degrades instead of corrupting or hanging —

  * non-finite decode logits are detected in-graph, the poisoned append
    is rewound bitwise (``rollback``) and the token recomputed once with
    the LOP screen off; only a sticky fault retires the lane (reason
    ``"fault"``),
  * a recovered lane's stream is the un-faulted stream (use_lop=False
    pins retry == plain decode), bitwise across two runs of one plan,
  * corrupted interned prefix pages fail their checksum at the next
    match and degrade to a cold prefill; store lookup outages likewise,
  * deadlines are enforced at admit, between prefill chunks and per
    decode sweep (reason ``"deadline"``); a bounded queue load-sheds
    reject-newest (reason ``"shed"``),
  * a zero-accept speculative lane trips the drafting watchdog,
  * the 200-request chaos trace completes within a step budget with
    every request in a terminal state and the invariant checker
    (``REPRO_PARANOID=1``) live on every cycle.

Runs under both REPRO_KERNEL_IMPL arms via scripts/ci_tier1.sh.
"""
import jax
import numpy as np
import pytest

from repro.models.transformer import init_params
from repro.serving import faults
from repro.serving.api import (CancelToken, GenerateRequest, PooledEngine,
                               SamplingParams)
from repro.serving.quantize import quantize_params
from repro.serving.scheduler import Scheduler, lockstep_generate

from tests.test_models_smoke import _reduced

MAX_LEN = 63          # pool capacity 64 with the reduced lop_block of 32


@pytest.fixture(scope="module")
def setup():
    cfg = _reduced("bitnet-3b")
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, quantize_params(cfg, params)


@pytest.fixture(scope="module")
def engine(setup):
    """One shared no-LOP engine: every scheduler in this module reuses
    its jit caches (including the lazily-compiled recovery retry)."""
    cfg, qp = setup
    return PooledEngine(cfg, qp, max_len=MAX_LEN, use_lop=False)


def _prompts(cfg, lens, seed=3):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, (n,)).astype(np.int32) for n in lens]


def _sched(cfg, qp, eng, **kw):
    return Scheduler(cfg, qp, n_slots=kw.pop("n_slots", 2),
                     max_len=MAX_LEN, engine=eng, **kw)


def _ref(cfg, qp, p, n, **kw):
    return lockstep_generate(cfg, qp, p, n, max_len=MAX_LEN, use_lop=False,
                             **kw)


# ---------------------------------------------------------------------------
# The plan itself: seeded, frozen, non-nesting
# ---------------------------------------------------------------------------


def test_fault_plan_random_is_deterministic():
    mk = lambda s: faults.FaultPlan.random(
        s, n_decode_calls=50, n_lanes=4, nan_events=3, sticky_lanes=1,
        page_flips=2, lookup_fails=2, slow_steps=2, slow_s=0.001)
    assert mk(7) == mk(7)
    assert mk(7) != mk(8)
    p = mk(7)
    assert len(p.nan_logits) == 3 and len(p.sticky_nan_lanes) == 1


def test_inject_scopes_and_rejects_nesting():
    assert faults.active() is None
    plan = faults.FaultPlan(nan_logits=frozenset({(0, 0)}))
    with faults.inject(plan) as st:
        assert faults.active() is plan
        with pytest.raises(AssertionError):
            with faults.inject(plan):
                pass
        add = faults.decode_fault_add(2)
        assert np.isnan(add[0]) and np.isfinite(add[1])
        assert faults.decode_fault_add(2) is not None
        assert not np.isnan(faults.decode_fault_add(2)).any()
        assert st.decode_calls == 3 and st.injected_nan == 1
    assert faults.active() is None
    assert faults.decode_fault_add(2) is None     # production fast path


def test_counter_keyed_injection_points():
    plan = faults.FaultPlan(seed=11, page_bitflips=frozenset({1}),
                            lookup_failures=frozenset({2}))
    with faults.inject(plan) as st:
        assert faults.page_corruption_rng() is None
        r1 = faults.page_corruption_rng()
        assert r1 is not None
        assert [faults.lookup_fails() for _ in range(4)] == [
            False, False, True, False]
        assert st.injected_flips == 1 and st.injected_lookup_failures == 1
    # same plan, fresh scope: the chosen bit is the same bit
    with faults.inject(plan):
        faults.page_corruption_rng()
        r2 = faults.page_corruption_rng()
    assert list(r1.integers(0, 1 << 30, 4)) == \
        list(r2.integers(0, 1 << 30, 4))


# ---------------------------------------------------------------------------
# NaN-logit detection → rollback → retry
# ---------------------------------------------------------------------------


def test_transient_nan_recovers_lockstep_exact(setup, engine):
    """A transient NaN on an active lane is detected, rewound and retried
    — the delivered stream is exactly the un-faulted stream (the retry
    recomputes with use_lop=False, which IS the plain path here)."""
    cfg, qp = setup
    prompts = _prompts(cfg, [12, 27, 9])
    plan = faults.FaultPlan(nan_logits=frozenset({(2, 0), (4, 1)}))
    with faults.inject(plan) as st:
        sched = _sched(cfg, qp, engine)
        for rid, p in enumerate(prompts):
            sched.submit(GenerateRequest(rid=rid, prompt=p,
                                         max_new_tokens=6))
        res = {r.rid: r for r in sched.run_to_completion()}
        assert st.injected_nan >= 1
    assert sched.fault_events >= 1
    assert sched.fault_recoveries == sched.fault_events
    assert sched.fault_finishes == 0
    for rid, p in enumerate(prompts):
        assert res[rid].finish_reason == "length"
        assert res[rid].tokens == _ref(cfg, qp, p, 6), rid


def test_sticky_nan_lane_finishes_with_fault(setup, engine):
    """A fault that survives the retry retires the lane with reason
    "fault", delivering the tokens emitted before the fault; the slot is
    reusable and a follow-up request on it is unaffected."""
    cfg, qp = setup
    p0, p1 = _prompts(cfg, [12, 9], seed=7)
    with faults.inject(faults.FaultPlan(sticky_nan_lanes=frozenset({0}))):
        sched = _sched(cfg, qp, engine, n_slots=1)
        sched.submit(GenerateRequest(rid=0, prompt=p0, max_new_tokens=6))
        res = {r.rid: r for r in sched.run_to_completion()}
    assert res[0].finish_reason == "fault"
    assert len(res[0].tokens) >= 1             # the prefill-seeded token
    assert sched.fault_finishes == 1
    assert sched.n_active == 0 and len(sched._free) == 1
    # same scheduler, fault scope closed: the lane serves cleanly again
    sched.submit(GenerateRequest(rid=1, prompt=p1, max_new_tokens=5))
    res = {r.rid: r for r in sched.run_to_completion()}
    assert res[1].tokens == _ref(cfg, qp, p1, 5)


def test_sampled_recovery_reproduces_unfaulted_stream(setup, engine):
    """A sampled lane's recovery nets sample_step to exactly its emission
    count, so the retried token and every later draw match the un-faulted
    same-seed stream."""
    cfg, qp = setup
    (p,) = _prompts(cfg, [12])
    sp = SamplingParams(temperature=0.8, top_k=20, seed=7)
    runs = []
    for plan in (None, faults.FaultPlan(nan_logits=frozenset({(1, 0)}))):
        ctx = faults.inject(plan) if plan else None
        if ctx:
            ctx.__enter__()
        sched = _sched(cfg, qp, engine, n_slots=1)
        sched.submit(GenerateRequest(rid=0, prompt=p, max_new_tokens=6,
                                     sampling=sp))
        runs.append(sched.run_to_completion()[0].tokens)
        if ctx:
            ctx.__exit__(None, None, None)
    assert runs[0] == runs[1]
    assert sched.fault_recoveries == 1


def test_foreign_engine_without_guard_is_untouched(setup, engine):
    """An engine that never publishes ``last_ok`` (the protocol's
    fault-contract default) serves normally — the scheduler treats every
    lane as healthy rather than probing engine internals."""
    cfg, qp = setup
    (p,) = _prompts(cfg, [10], seed=9)

    class NoGuard:
        def __init__(self, eng):
            self._eng = eng

        def __getattr__(self, name):
            if name == "last_ok":
                raise AttributeError(name)
            return getattr(self._eng, name)

        def decode_step(self, *a, **kw):
            toks, pool = self._eng.decode_step(*a, **kw)
            return toks, pool

    sched = Scheduler(cfg, qp, n_slots=1, max_len=MAX_LEN,
                      engine=NoGuard(engine))
    sched.submit(GenerateRequest(rid=0, prompt=p, max_new_tokens=5))
    res = sched.run_to_completion()[0]
    assert res.tokens == _ref(cfg, qp, p, 5)
    assert sched.fault_events == 0


# ---------------------------------------------------------------------------
# Prefix-store faults: checksums + lookup outages
# ---------------------------------------------------------------------------


def test_corrupted_prefix_page_fails_checksum_and_falls_back(setup, engine):
    """A bit flipped in an interned page after intern (post-intern rot) is
    caught by the per-page checksum at the next match: the corrupt
    subtree is dropped, the request cold-prefills, and the re-interned
    prefix serves later hits cleanly — tokens are never wrong."""
    cfg, qp = setup
    (p,) = _prompts(cfg, [40], seed=13)    # >= one 32-token block
    plan = faults.FaultPlan(seed=3, page_bitflips=frozenset({0}))
    with faults.inject(plan) as st:
        sched = _sched(cfg, qp, engine, n_slots=1)
        for rid in range(3):
            sched.submit(GenerateRequest(rid=rid, prompt=p,
                                         max_new_tokens=4))
        res = {r.rid: r for r in sched.run_to_completion()}
        assert st.injected_flips == 1
    store = sched.prefix_store
    assert store is not None
    assert store.checksum_failures == 1
    # rid 1 hit the corrupt node -> cold prefill + re-intern; rid 2 hits
    # the clean re-interned chain
    assert sched.prefix_hits == 1
    ref = _ref(cfg, qp, p, 4)
    for rid in range(3):
        assert res[rid].tokens == ref, rid
    store.check_invariants()


def test_lookup_failure_degrades_to_cold_prefill(setup, engine):
    cfg, qp = setup
    (p,) = _prompts(cfg, [40], seed=15)
    plan = faults.FaultPlan(lookup_failures=frozenset({1}))
    with faults.inject(plan):
        sched = _sched(cfg, qp, engine, n_slots=1)
        for rid in range(3):
            sched.submit(GenerateRequest(rid=rid, prompt=p,
                                         max_new_tokens=4))
        res = {r.rid: r for r in sched.run_to_completion()}
    assert sched.prefix_lookup_failures == 1
    assert sched.prefix_hits == 1              # rid 2 still hits
    ref = _ref(cfg, qp, p, 4)
    for rid in range(3):
        assert res[rid].tokens == ref, rid


# ---------------------------------------------------------------------------
# Deadlines + admission control
# ---------------------------------------------------------------------------


def test_deadline_expired_in_queue_never_takes_a_lane(setup, engine):
    cfg, qp = setup
    p0, p1 = _prompts(cfg, [10, 10], seed=17)
    t = [0.0]
    sched = _sched(cfg, qp, engine, n_slots=1, clock=lambda: t[0])
    sched.submit(GenerateRequest(rid=0, prompt=p0, max_new_tokens=4,
                                 deadline_ms=50.0))
    sched.submit(GenerateRequest(rid=1, prompt=p1, max_new_tokens=4))
    t[0] = 0.2                                 # rid 0 expired while queued
    res = {r.rid: r for r in sched.run_to_completion()}
    assert res[0].finish_reason == "deadline" and res[0].tokens == []
    assert res[1].finish_reason == "length"
    assert sched.deadline_count == 1


def test_deadline_mid_decode_delivers_partial_stream(setup, engine):
    """Deadline enforcement per decode sweep: the lane retires with the
    tokens emitted inside its budget — a PREFIX of the lockstep stream,
    not a corrupted one."""
    cfg, qp = setup
    (p,) = _prompts(cfg, [10], seed=19)
    t = [0.0]
    sched = _sched(cfg, qp, engine, n_slots=1, clock=lambda: t[0])
    sched.submit(GenerateRequest(rid=0, prompt=p, max_new_tokens=10,
                                 deadline_ms=45.0))
    steps = 0
    while sched.has_work():
        sched.admit()
        sched.step()
        t[0] += 0.01
        steps += 1
        assert steps < 50, "deadline never fired"
    res = sched.results[0]
    assert res.finish_reason == "deadline"
    ref = _ref(cfg, qp, p, 10)
    assert 1 <= len(res.tokens) < 10
    assert res.tokens == ref[:len(res.tokens)]
    assert sched.n_active == 0 and len(sched._free) == 1


def test_deadline_between_prefill_chunks_frees_reserved_lane(setup):
    cfg, qp = setup
    (p,) = _prompts(cfg, [40], seed=21)
    t = [0.0]
    # a private engine: chunk_tokens=8 -> the 40-token prompt needs 5
    # chunk cycles, so a 25 ms budget at 10 ms/cycle dies mid-prefill
    eng = PooledEngine(cfg, qp, max_len=MAX_LEN, use_lop=False,
                       chunk_tokens=8)
    sched = Scheduler(cfg, qp, n_slots=1, max_len=MAX_LEN, engine=eng,
                      clock=lambda: t[0])
    sched.submit(GenerateRequest(rid=0, prompt=p, max_new_tokens=4,
                                 deadline_ms=25.0))
    steps = 0
    while sched.has_work():
        sched.admit()
        sched.step()
        t[0] += 0.01
        steps += 1
        assert steps < 50, "deadline never fired"
    res = sched.results[0]
    assert res.finish_reason == "deadline" and res.tokens == []
    assert steps < 6                       # died before the chunks ran out
    assert sched.n_prefilling == 0 and len(sched._free) == 1
    assert sched.deadline_count == 1


def test_bounded_queue_sheds_newest(setup, engine):
    cfg, qp = setup
    prompts = _prompts(cfg, [10, 12, 9, 11], seed=23)
    sched = _sched(cfg, qp, engine, n_slots=1, max_queue=3)
    oks = [sched.submit(GenerateRequest(rid=i, prompt=p, max_new_tokens=3))
           for i, p in enumerate(prompts)]
    assert oks == [True, True, True, False]
    assert sched.shed_count == 1 and sched.queue_depth_peak == 3
    res = {r.rid: r for r in sched.run_to_completion()}
    assert res[3].finish_reason == "shed" and res[3].tokens == []
    assert all(res[i].finish_reason == "length" for i in range(3))


# ---------------------------------------------------------------------------
# Speculative watchdog
# ---------------------------------------------------------------------------


def test_spec_watchdog_disables_hopeless_drafting(setup):
    """A lane whose drafts never match verify trips the watchdog after
    ``spec_watchdog`` zero-accept rounds and finishes via plain decode —
    the stream stays lockstep-exact throughout (round emissions are the
    verifier's own tokens)."""
    cfg, qp = setup
    (p,) = _prompts(cfg, [10], seed=25)
    eng = PooledEngine(cfg, qp, max_len=MAX_LEN, use_lop=False)
    orig = eng.draft

    def bad_draft(pool, tokens, temps, tks, tps):
        toks, pool = orig(pool, tokens, temps, tks, tps)
        return (toks + 1) % cfg.vocab, pool     # always-wrong proposals

    eng.draft = bad_draft
    sched = Scheduler(cfg, qp, n_slots=1, max_len=MAX_LEN, engine=eng,
                      spec_decode=True, gamma=3, spec_watchdog=2)
    sched.submit(GenerateRequest(rid=0, prompt=p, max_new_tokens=10))
    res = sched.run_to_completion()[0]
    assert sched.spec_watchdog_trips == 1
    assert sched.spec_rounds == 2              # the two zero-accept rounds
    assert sched.spec_accepted == 0
    assert sched.decode_launches > 0           # plain-decode tail
    assert res.tokens == _ref(cfg, qp, p, 10)


# ---------------------------------------------------------------------------
# Chaos: 200 requests, seeded fault plan, paranoid invariants, 2x bitwise
# ---------------------------------------------------------------------------

_TERMINAL = {"eos", "stop", "length", "cancelled", "deadline", "shed",
             "fault"}


def _chaos_trace(cfg):
    """200 requests: mixed lengths, a shared 32-token prefix every 10th
    request (exercises intern/clone under corruption), a handful of tight
    deadlines and mid-stream cancels. Deterministic by construction."""
    rng = np.random.default_rng(41)
    shared = rng.integers(0, cfg.vocab, (32,)).astype(np.int32)
    reqs, cancels = [], {}
    for rid in range(200):
        plen = int(rng.integers(6, 15))
        prompt = rng.integers(0, cfg.vocab, (plen,)).astype(np.int32)
        if rid % 10 == 0:
            prompt = np.concatenate([shared, prompt])
        deadline = 150.0 if rid % 23 == 5 else None
        tok = CancelToken() if rid % 41 == 3 else None
        if tok is not None:
            cancels[rid] = tok
        reqs.append(GenerateRequest(rid=rid, prompt=prompt,
                                    max_new_tokens=3, deadline_ms=deadline,
                                    cancel=tok))
    return reqs, cancels


def _run_chaos(cfg, qp, eng, plan):
    reqs, cancels = _chaos_trace(cfg)
    t = [0.0]
    sched = Scheduler(cfg, qp, n_slots=4, max_len=MAX_LEN, engine=eng,
                      max_queue=150, clock=lambda: t[0])
    with faults.inject(plan) as st:
        for r in reqs:
            sched.submit(r)
        steps = 0
        while sched.has_work():
            sched.admit()
            sched.step()
            # deterministic virtual time; cancels fire on emission count
            t[0] += 0.01
            for rid, tok in cancels.items():
                lane = next((l for l in sched.lanes
                             if l is not None and l.req.rid == rid), None)
                if lane is not None and len(lane.tokens) >= 2:
                    tok.cancel()
            steps += 1
            assert steps < 2000, "chaos run exceeded its step budget (hang)"
    return sched, {r.rid: r for r in sched.results}, st


def test_chaos_200_requests_terminal_deterministic_and_exact(
        setup, engine, monkeypatch):
    cfg, qp = setup
    monkeypatch.setenv("REPRO_PARANOID", "1")
    plan = faults.FaultPlan.random(31, n_decode_calls=160, n_lanes=4,
                                   nan_events=6, page_flips=1,
                                   lookup_fails=2)
    sched, res, st = _run_chaos(cfg, qp, engine, plan)

    # every request reached a terminal state, nothing hung or vanished
    assert len(res) == 200
    assert {r.finish_reason for r in res.values()} <= _TERMINAL
    by_reason = {}
    for r in res.values():
        by_reason[r.finish_reason] = by_reason.get(r.finish_reason, 0) + 1
    assert by_reason.get("shed", 0) == 50          # 200 into a 150 bound
    assert by_reason.get("deadline", 0) >= 1
    assert by_reason.get("cancelled", 0) >= 1
    assert by_reason.get("length", 0) >= 100
    assert sched.fault_events >= 1                 # the plan actually bit
    assert sched.fault_recoveries >= 1
    assert st.injected_nan >= 1

    # un-faulted AND recovered length-finished lanes are lockstep-exact
    # (use_lop=False makes the no-LOP retry recompute the identical token)
    reqs, _ = _chaos_trace(cfg)
    for req in reqs:
        r = res[req.rid]
        if r.finish_reason == "length":
            assert r.tokens == _ref(cfg, qp, req.prompt, 3), req.rid

    # bitwise determinism: the same plan over the same trace reproduces
    # every stream and every terminal reason, including retried tokens
    sched2, res2, _ = _run_chaos(cfg, qp, engine, plan)
    for rid in res:
        assert res[rid].tokens == res2[rid].tokens, rid
        assert res[rid].finish_reason == res2[rid].finish_reason, rid
    assert sched2.fault_events == sched.fault_events
    assert sched2.fault_recoveries == sched.fault_recoveries

    # the paranoid invariant checker was live the whole run
    assert sched.paranoid and sched2.paranoid
    sched.check_invariants()
