"""End-to-end integration: QAT-train → quantize to deployment format →
serve with LOP decode (the paper's full lifecycle)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.serve import serve_loop
from repro.launch.train import train_loop
from repro.serving.engine import prefill, serve_step
from repro.serving.quantize import quantize_params

from tests.test_models_smoke import _reduced


@pytest.mark.slow
def test_train_quantize_serve_lifecycle():
    cfg = _reduced("bitnet-3b").replace(n_layers=2, vocab=256)
    out = train_loop(cfg, steps=40, global_batch=8, seq_len=32,
                     peak_lr=3e-3, log_every=1000)
    assert np.mean(out["losses"][-5:]) < np.mean(out["losses"][:5])

    qp = quantize_params(cfg, out["params"])
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)), jnp.int32)
    logits, cache = prefill(cfg, qp, prompts, max_len=24)
    assert np.isfinite(np.asarray(logits)).all()
    for _ in range(4):
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        logits, cache = serve_step(cfg, qp, cache, tok)
        assert np.isfinite(np.asarray(logits)).all()
    # trained quantized model beats chance on its own bigram structure
    assert int(cache["lengths"][0]) == 20


@pytest.mark.slow
def test_serve_loop_driver():
    cfg = _reduced("granite-moe-1b-a400m")
    out = serve_loop(cfg, n_slots=2, n_requests=3, min_prompt=8,
                     max_prompt=16, gen=6)
    assert len(out["results"]) == 3
    assert all(len(r.tokens) == 6 for r in out["results"])
    assert out["tokens_per_s"] > 0
    assert out["latency_p50"] > 0 and out["ttft_p50"] > 0
