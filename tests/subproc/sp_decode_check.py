"""Subprocess: SP quota-sharded decode ≡ local decode (8 host devices).

Checks, on a (data=2, model=4) mesh:
  1. keep=1.0 → SP decode output == local dense decode (exactness),
  2. cache write lands on the owner shard only,
  3. quota selection (keep<1) recall vs global top-K selection ≥ 70%.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.bitnet_3b import REDUCED
from repro.core.lop import lop_features, pack_features
from repro.distributed.partitioning import use_mesh
from repro.launch.mesh import make_host_mesh
from repro.serving.engine import lop_decode_attention
from repro.distributed.sp_decode import sp_decode_attention

rng = np.random.default_rng(0)
cfg = REDUCED.replace(lop_keep=1.0, lop_block=32)
B, H, Hkv, dh = 4, cfg.n_heads, cfg.n_kv_heads, cfg.hd
M = 512   # 16 blocks; 4 blocks per model shard

qi = jnp.asarray(rng.integers(-60, 61, (B, H, dh)), jnp.int8)
qsc = jnp.asarray(rng.uniform(0.005, 0.02, (B, H, 1)), jnp.float32)
ki = jnp.asarray(rng.integers(-60, 61, (B, Hkv, dh)), jnp.int8)
vi = jnp.asarray(rng.integers(-60, 61, (B, Hkv, dh)), jnp.int8)
ksc = jnp.asarray(rng.uniform(0.005, 0.02, (B, Hkv, 1)), jnp.float32)
vsc = jnp.asarray(rng.uniform(0.005, 0.02, (B, Hkv, 1)), jnp.float32)
feat_new = pack_features(lop_features(ki))

cl = {
    "k": jnp.asarray(rng.integers(-60, 61, (B, Hkv, M, dh)), jnp.int8),
    "v": jnp.asarray(rng.integers(-60, 61, (B, Hkv, M, dh)), jnp.int8),
    "k_scale": jnp.asarray(rng.uniform(0.005, 0.02, (B, Hkv, M)),
                           jnp.float32),
    "v_scale": jnp.asarray(rng.uniform(0.005, 0.02, (B, Hkv, M)),
                           jnp.float32),
}
cl["feat"] = pack_features(lop_features(cl["k"]))
lengths = jnp.full((B,), M - 40, jnp.int32)

mesh = make_host_mesh((2, 4), ("data", "model"))
with use_mesh(mesh):
    out_sp, cl_sp = jax.jit(lambda q, qs, c, ln: sp_decode_attention(
        cfg, q, qs, ki, vi, ksc, vsc, feat_new, c, ln, window=0,
        use_lop=True, sp_axes=("model",)))(qi, qsc, cl, lengths)

# local reference: write + dense attention (keep=1 → LOP is exact)
from repro.serving.engine import _write_token
cl_local = _write_token(dict(cl), ki, vi, ksc, vsc, feat_new, lengths)
out_local = lop_decode_attention(cfg, qi, qsc, cl_local, lengths + 1,
                                 window=0, use_lop=False)

err = float(jnp.max(jnp.abs(out_sp - out_local)))
ref = float(jnp.max(jnp.abs(out_local))) + 1e-9
assert err / ref < 1e-3, (err, ref)
print("sp==local exactness ok", err / ref)

# the write landed identically
for key in ("k", "v", "k_scale", "v_scale", "feat"):
    assert (np.asarray(cl_sp[key]) == np.asarray(cl_local[key])).all(), key
print("sp cache write ok")

# quota-sharded recall vs global selection at keep=0.25
cfg2 = cfg.replace(lop_keep=0.25)
with use_mesh(mesh):
    out_q, _ = jax.jit(lambda q, qs, c, ln: sp_decode_attention(
        cfg2, q, qs, ki, vi, ksc, vsc, feat_new, c, ln, window=0,
        use_lop=True, sp_axes=("model",)))(qi, qsc, cl, lengths)
out_g = lop_decode_attention(cfg2, qi, qsc, cl_local, lengths + 1,
                             window=0, use_lop=True)
out_d = out_local
rel_q = float(jnp.linalg.norm(out_q - out_d) / jnp.linalg.norm(out_d))
rel_g = float(jnp.linalg.norm(out_g - out_d) / jnp.linalg.norm(out_d))
print(f"keep=0.25: quota-sharded rel err {rel_q:.3f}, global rel err "
      f"{rel_g:.3f}")
assert rel_q < max(2.5 * rel_g, 0.35), (rel_q, rel_g)
print("SP_DECODE_CHECK_OK")
