"""Subprocess: grad compression, ring collective matmul, EP MoE
(8 host devices)."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.distributed.collective_matmul import (allgather_matmul,
                                                 ring_reduce_matmul)
from repro.distributed.compression import compressed_psum, init_error_state
from repro.distributed.partitioning import shard_map
from repro.launch.mesh import make_host_mesh

rng = np.random.default_rng(0)
mesh = make_host_mesh((8,), ("data",))

# ---- int8 compressed psum with error feedback ----
x = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)


def one_round(x, err):
    return compressed_psum(x, "data", err)


f = shard_map(one_round, mesh=mesh, in_specs=(P("data"), P("data")),
                  out_specs=(P("data"), P("data")), check_vma=False)
err0 = jnp.zeros_like(x)
total, err1 = f(x, err0)
exact = jnp.sum(x, axis=0, keepdims=True)
rel = float(jnp.max(jnp.abs(total[:1] - exact)) / (jnp.max(jnp.abs(exact))
                                                   + 1e-9))
assert rel < 0.02, rel                      # one-shot int8 ≈ 1% error
print("compressed psum one-shot rel err", rel)

# error feedback: the RUNNING MEAN of compressed sums converges to the
# exact sum (per-round error oscillates; the residual re-enters the next
# round, so the time-averaged estimate is unbiased)
carry = err0
running = np.zeros_like(np.asarray(exact))
mean_err = []
for i in range(1, 17):
    total, carry = f(x, carry)
    running += np.asarray(total[:1])
    mean_err.append(float(np.max(np.abs(running / i - np.asarray(exact)))))
assert mean_err[-1] < mean_err[0] * 0.5, mean_err
print("error-feedback running mean converges",
      [f"{a:.4f}" for a in mean_err[::4]])

# ---- ring reduce matmul == psum(x @ w) ----
B, K, N = 4, 64, 32
x_loc = jnp.asarray(rng.standard_normal((8, B, K // 8)), jnp.float32)
w_loc = jnp.asarray(rng.standard_normal((8, K // 8, N)), jnp.float32)


def ring(xl, wl):
    return ring_reduce_matmul(xl[0], wl[0], "data", chunks=4)[None]


g = shard_map(ring, mesh=mesh, in_specs=(P("data"), P("data")),
                  out_specs=P("data"), check_vma=False)
y_ring = g(x_loc, w_loc)[0]
y_ref = sum(np.asarray(x_loc[i]) @ np.asarray(w_loc[i]) for i in range(8))
np.testing.assert_allclose(np.asarray(y_ring), y_ref, rtol=1e-4, atol=1e-4)
print("ring reduce matmul ok")

# ---- allgather matmul (x batch-sharded, w replicated) ----
w_full = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)
x_batch = jnp.asarray(rng.standard_normal((8 * B, K)), jnp.float32)


def ag(xl, wl):
    return allgather_matmul(xl, wl, "data")


h = shard_map(ag, mesh=mesh, in_specs=(P("data"), P(None, None)),
                  out_specs=P(None, None), check_vma=False)
y_ag = h(x_batch, w_full)
y_exp = np.asarray(x_batch) @ np.asarray(w_full)
np.testing.assert_allclose(np.asarray(y_ag), y_exp, rtol=1e-4, atol=1e-4)
print("allgather matmul ok")

# ---- EP MoE == reference dense-dispatch MoE ----
from repro.configs.granite_moe_1b_a400m import REDUCED as GRANITE
from repro.distributed.expert_parallel import ep_moe_apply
from repro.models.moe import moe_apply, moe_init

# bf16 mode: ep_moe_apply takes pre-prepared weights (no STE inside), so
# the equivalence check compares pure dispatch logic
cfg = GRANITE.replace(capacity_factor=8.0, moe_group=64, quant="bf16")
mesh2 = make_host_mesh((2, 4), ("data", "model"))
p, _ = moe_init(jax.random.PRNGKey(0), cfg)
xs = jnp.asarray(rng.standard_normal((4, 16, cfg.d_model)), jnp.float32)

y_ep = ep_moe_apply(cfg, p, xs, mesh2, axis="model")
# reference: same routing with group == local token count (2 ranks × 32 tok)
y_ref, _ = moe_apply(cfg.replace(moe_group=32), p, xs)
np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_ref), rtol=2e-3,
                           atol=2e-3)
print("ep moe matches reference")
print("COLLECTIVES_CHECK_OK")
