"""Subprocess: f-sharded fused FFN ≡ single-launch FFN (8 host devices).

Checks, on a (data=2, model=4) mesh:
  1. the f-axis shard_map wrapper agrees with the unsharded fused FFN to
     int8 quantization noise (the per-rank hidden re-barrier is a finer
     absmax grouping — DESIGN.md §Serving-API numerics caveat),
  2. the wired-in path (`use_ffn_tp` opt-in around the serving
     `ffn_apply` → `ffn_node_apply` route) picks up the sharded dispatch
     and stays close to the unsharded apply,
  3. a model-axis slice of ONE rank (n=1 mesh) is bitwise the unsharded
     kernel (the grouping caveat vanishes when nothing splits).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.bitnet_3b import REDUCED
from repro.distributed.partitioning import use_mesh
from repro.distributed.tp_ffn import ffn_fused_tp, use_ffn_tp
from repro.kernels import ops
from repro.launch.mesh import make_host_mesh
from repro.models.transformer import init_params
from repro.serving.quantize import quantize_params

cfg = REDUCED
params, _ = init_params(cfg, jax.random.PRNGKey(0))
qp = quantize_params(cfg, params)
ffn0 = jax.tree.map(lambda a: a[0], qp["layers"]["ffn"])   # layer-0 node

rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((5, cfg.d_model)), jnp.float32)

# jitted like the sharded calls — XLA compiles the in-kernel absmax
# division differently eager vs inside a compiled computation (the 1-ulp
# knife-edge DESIGN.md §TINT-projection-fusion records)
y_ref = jax.jit(lambda xx: ops.ffn_fused(
    xx, ffn0["gu_packed"], ffn0["gu_scale"], ffn0["down_packed"],
    ffn0["down_scale"], gated=cfg.gated_ffn, act="silu"))(x)

mesh = make_host_mesh((2, 4), ("data", "model"))
with use_mesh(mesh):
    y_tp = jax.jit(lambda xx: ffn_fused_tp(
        xx, ffn0["gu_packed"], ffn0["gu_scale"], ffn0["down_packed"],
        ffn0["down_scale"], gated=cfg.gated_ffn, act="silu",
        axis="model"))(x)
rel = float(jnp.linalg.norm(y_tp - y_ref) / (jnp.linalg.norm(y_ref) + 1e-9))
print(f"f-sharded vs single-launch FFN rel err {rel:.2e} (model=4)")
assert np.isfinite(np.asarray(y_tp)).all()
assert rel < 5e-2, rel
print("tp ffn node agreement ok")

# wired-in path: the serving ffn_apply routes through ffn_node_apply,
# which must pick up the opt-in and dispatch the sharded launch
from repro.models.moe import ffn_apply

h = jnp.asarray(rng.standard_normal((2, 1, cfg.d_model)), jnp.float32)
y_apply_ref = ffn_apply(cfg, ffn0, h)
with use_mesh(mesh), use_ffn_tp("model"):
    y_apply_tp = jax.jit(lambda hh: ffn_apply(cfg, ffn0, hh))(h)
rel_a = float(jnp.linalg.norm(y_apply_tp - y_apply_ref)
              / (jnp.linalg.norm(y_apply_ref) + 1e-9))
print(f"ffn_apply rel err under f-sharded opt-in {rel_a:.2e}")
assert np.isfinite(np.asarray(y_apply_tp)).all()
assert rel_a < 5e-2, rel_a
print("tp ffn wired-in path ok")

# n=1 model axis: nothing splits → bitwise the single-launch kernel
mesh1 = make_host_mesh((1, 1), ("data", "model"))
with use_mesh(mesh1):
    y_1 = jax.jit(lambda xx: ffn_fused_tp(
        xx, ffn0["gu_packed"], ffn0["gu_scale"], ffn0["down_packed"],
        ffn0["down_scale"], gated=cfg.gated_ffn, act="silu",
        axis="model"))(x)
assert (np.asarray(y_1) == np.asarray(y_ref)).all()
print("n=1 bitwise identity ok")
print("TP_FFN_CHECK_OK")
