"""Subprocess: FSDP/TP sharded training == single-device training
(8 host devices), plus elastic re-mesh restart."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.stablelm_1_6b import REDUCED
from repro.distributed.fault_tolerance import (make_elastic_mesh,
                                               plan_elastic_mesh)
from repro.distributed.partitioning import use_mesh
from repro.launch.mesh import make_host_mesh
from repro.models.transformer import init_params
from repro.training.optimizer import adamw_init
from repro.training.train import make_train_step

cfg = REDUCED.replace(n_layers=2, act_dtype="float32")
rng = np.random.default_rng(0)
params, pspecs = init_params(cfg, jax.random.PRNGKey(0))
opt = adamw_init(params)
batch = {
    "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)), jnp.int32),
    "labels": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)), jnp.int32),
}
step = make_train_step(cfg, total_steps=10)

# single-device reference
p_ref, _, m_ref = jax.jit(step)(params, opt, batch)

# sharded run on (data=2, model=4)
mesh = make_host_mesh((2, 4), ("data", "model"))
from repro.launch.dryrun import _shardings

with use_mesh(mesh):
    p_sh = jax.device_put(params, _shardings(mesh, pspecs, params))
    o_sh = jax.device_put(opt, _shardings(
        mesh, type(opt)(step=(), m=pspecs, v=pspecs), opt))
    b_sh = jax.device_put(batch, _shardings(
        mesh, {k: ("dp",) + (None,) * (v.ndim - 1) for k, v in batch.items()},
        batch))
    p2, _, m2 = jax.jit(step)(p_sh, o_sh, b_sh)

assert np.isclose(float(m_ref["loss"]), float(m2["loss"]), rtol=1e-4), (
    float(m_ref["loss"]), float(m2["loss"]))
for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p2)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5)
print("fsdp/tp sharded step == single-device step")

# ---- elastic re-mesh: lose 3 devices, keep model axis ----
assert plan_elastic_mesh(8, model=4) == (2, 4)
assert plan_elastic_mesh(5, model=4) == (1, 4)      # 1 spare dropped
mesh_small = make_elastic_mesh(jax.devices()[:5], model=4)
assert mesh_small.devices.shape == (1, 4)
with use_mesh(mesh_small):
    p_sh = jax.device_put(params, _shardings(mesh_small, pspecs, params))
    o_sh = jax.device_put(opt, _shardings(
        mesh_small, type(opt)(step=(), m=pspecs, v=pspecs), opt))
    b_sh = jax.device_put(batch, _shardings(
        mesh_small,
        {k: ("dp",) + (None,) * (v.ndim - 1) for k, v in batch.items()},
        batch))
    p3, _, m3 = jax.jit(step)(p_sh, o_sh, b_sh)
assert np.isclose(float(m_ref["loss"]), float(m3["loss"]), rtol=1e-4)
print("elastic re-mesh (8→5 devices → 1×4 mesh) step matches")
print("FSDP_TRAIN_CHECK_OK")
