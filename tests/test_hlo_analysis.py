"""Fused-HBM traffic model + differential-costing helpers."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlo import collective_bytes, hbm_bytes


def test_hbm_model_counts_fusions_and_dots():
    hlo = """
ENTRY %main (p0: f32[128,64]) -> f32[128,32] {
  %p0 = f32[128,64]{1,0} parameter(0)
  %c = f32[64,32]{1,0} constant({...})
  %fusion.1 = f32[128,64]{1,0} fusion(%p0), kind=kLoop, calls=%fused_computation
  %dot.2 = f32[128,32]{1,0} dot(%fusion.1, %c), lhs_contracting_dims={1}
  ROOT %exp = f32[128,32]{1,0} exponential(%dot.2)
}
%fused_computation (param_0: f32[128,64]) -> f32[128,64] {
  %param_0 = f32[128,64]{1,0} parameter(0)
  %big = f32[128,64]{1,0} multiply(%param_0, %param_0)
  ROOT %r = f32[128,64]{1,0} add(%big, %big)
}
"""
    b = hbm_bytes(hlo)
    fusion = 2 * 128 * 64 * 4             # operand + result
    dot = 128 * 64 * 4 + 64 * 32 * 4 + 128 * 32 * 4
    # bare exponential assumed fused (elementwise); fused-computation
    # internals excluded
    assert b == fusion + dot, (b, fusion + dot)


def test_hbm_model_in_place_dus():
    hlo = """
ENTRY %main (p0: s8[4,1024,128]) -> s8[4,1024,128] {
  %p0 = s8[4,1024,128]{2,1,0} parameter(0)
  %upd = s8[4,1,128]{2,1,0} parameter(1)
  %i = s32[] parameter(2)
  ROOT %dus = s8[4,1024,128]{2,1,0} dynamic-update-slice(%p0, %upd, %i, %i, %i)
}
"""
    # only the update (+ scalar indices) counts — buffer donation aliases
    # the big cache operand in place
    assert hbm_bytes(hlo) == 4 * 1 * 128 + 3 * 4


def test_real_compiled_module_parses():
    def f(x, w):
        return jax.nn.relu(x @ w) @ w.T

    xs = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    compiled = jax.jit(f).lower(xs, ws).compile()
    txt = compiled.as_text()
    b = hbm_bytes(txt)
    assert b > 0
    # two dots touch at least their operands/results once
    assert b >= 2 * (64 * 128 + 128 * 128 + 64 * 128) * 4 * 0.5
    coll = collective_bytes(txt)
    assert coll["total"] == 0              # single device: no collectives
