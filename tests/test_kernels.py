"""Pallas kernels (interpret mode) vs pure-jnp oracles — shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.lop import lop_features, pack_features
from repro.core.ternary import make_ternary_weight
from repro.kernels import ops, ref

rng = np.random.default_rng(42)


@pytest.mark.parametrize("m,k,n", [
    (8, 128, 128), (48, 512, 256), (130, 1024, 128), (1, 256, 512),
])
def test_ternary_matmul_exact(m, k, n):
    x = jnp.asarray(rng.integers(-127, 128, (m, k)), jnp.int8)
    w = jnp.asarray(rng.standard_normal((k, n)), np.float32) * 0.02
    tw = make_ternary_weight(w)
    y_k = ops.ternary_matmul(x, tw, impl="pallas")
    y_r = ops.ternary_matmul(x, tw, impl="ref")
    assert y_k.dtype == jnp.int32
    assert (np.asarray(y_k) == np.asarray(y_r)).all()


def test_ternary_matmul_leading_dims():
    x = jnp.asarray(rng.integers(-50, 51, (2, 3, 256)), jnp.int8)
    w = jnp.asarray(rng.standard_normal((256, 128)), np.float32) * 0.02
    tw = make_ternary_weight(w)
    y = ops.ternary_matmul(x, tw, impl="pallas")
    assert y.shape == (2, 3, 128)
    assert (np.asarray(y) ==
            np.asarray(ops.ternary_matmul(x, tw, impl="ref"))).all()


@pytest.mark.parametrize("g,m,d", [(12, 1024, 128), (1, 512, 64),
                                   (40, 2048, 128)])
def test_lop_scores_kernel(g, m, d):
    q = jnp.asarray(rng.integers(-127, 128, (g, d)), jnp.int8)
    kc = jnp.asarray(rng.integers(-127, 128, (m, d)), jnp.int8)
    feat = pack_features(lop_features(kc))
    s_k = ops.lop_screen(q, feat, impl="pallas")
    s_r = ops.lop_screen(q, feat, impl="ref")
    assert (np.asarray(s_k) == np.asarray(s_r)).all()


@pytest.mark.parametrize("s,d,causal,window", [
    (256, 64, True, 0), (512, 128, True, 0), (512, 128, False, 0),
    (512, 64, True, 128),
])
def test_flash_prefill_kernel(s, d, causal, window):
    q = jnp.asarray(rng.integers(-60, 61, (s, d)), jnp.int8)
    k = jnp.asarray(rng.integers(-60, 61, (s, d)), jnp.int8)
    v = jnp.asarray(rng.integers(-60, 61, (s, d)), jnp.int8)
    sc = [jnp.asarray(rng.uniform(0.005, 0.02, (s, 1)), jnp.float32)
          for _ in range(3)]
    sm = 1.0 / np.sqrt(d)
    o_k = ops.flash_prefill(q, k, v, *sc, softmax_scale=sm, causal=causal,
                            window=window, impl="pallas")
    o_r = ops.flash_prefill(q, k, v, *sc, softmax_scale=sm, causal=causal,
                            window=window, impl="ref")
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=1e-4)


@pytest.mark.parametrize("g,nb,block", [(6, 4, 128), (1, 2, 64), (8, 8, 32)])
def test_sparse_decode_kernel(g, nb, block):
    m, d = 16 * block, 64
    kc = jnp.asarray(rng.integers(-60, 61, (m, d)), jnp.int8)
    vc = jnp.asarray(rng.integers(-60, 61, (m, d)), jnp.int8)
    ks = jnp.asarray(rng.uniform(0.005, 0.02, (m, 1)), jnp.float32)
    vs = jnp.asarray(rng.uniform(0.005, 0.02, (m, 1)), jnp.float32)
    q = jnp.asarray(rng.integers(-60, 61, (g, d)), jnp.int8)
    qs = jnp.asarray(rng.uniform(0.005, 0.02, (g, 1)), jnp.float32)
    bidx = jnp.asarray(rng.choice(16, nb, replace=False), jnp.int32)
    gate = np.ones(nb, np.int32)
    gate[-1] = 0                                     # one gated-off block
    end = rng.integers(1, block + 1, nb).astype(np.int32)
    start = np.minimum(rng.integers(0, block, nb), end - 1).astype(np.int32)
    gt = jnp.asarray(np.concatenate([gate, end, start]), jnp.int32)
    sm = 1.0 / np.sqrt(d)
    o_k = ops.sparse_decode(q, kc, vc, qs, ks, vs, bidx, gt, block=block,
                            softmax_scale=sm, impl="pallas")
    o_r = ops.sparse_decode(q, kc, vc, qs, ks, vs, bidx, gt, block=block,
                            softmax_scale=sm, impl="ref")
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=1e-4)


def test_sparse_decode_equals_dense_when_all_blocks():
    """Sparse kernel over ALL blocks == dense attention (exactness)."""
    m, d, block = 512, 64, 64
    nb = m // block
    kc = jnp.asarray(rng.integers(-60, 61, (m, d)), jnp.int8)
    vc = jnp.asarray(rng.integers(-60, 61, (m, d)), jnp.int8)
    ks = jnp.asarray(rng.uniform(0.005, 0.02, (m, 1)), jnp.float32)
    vs = jnp.asarray(rng.uniform(0.005, 0.02, (m, 1)), jnp.float32)
    q = jnp.asarray(rng.integers(-60, 61, (4, d)), jnp.int8)
    qs = jnp.asarray(rng.uniform(0.005, 0.02, (4, 1)), jnp.float32)
    bidx = jnp.arange(nb, dtype=jnp.int32)
    gt = jnp.asarray(np.concatenate([np.ones(nb), np.full(nb, block),
                                     np.zeros(nb)]).astype(np.int32))
    sm = 1.0 / np.sqrt(d)
    o = ops.sparse_decode(q, kc, vc, qs, ks, vs, bidx, gt, block=block,
                          softmax_scale=sm, impl="pallas")
    logits = (q.astype(np.int32) @ np.asarray(kc, np.int32).T
              ).astype(np.float32)
    logits = logits * np.asarray(qs) * np.asarray(ks).T * sm
    p = jax.nn.softmax(jnp.asarray(logits), -1)
    o_dense = np.asarray(p) @ (np.asarray(vc, np.float32) * np.asarray(vs))
    np.testing.assert_allclose(np.asarray(o), o_dense, atol=1e-4)
