"""Typed serving API: sampler determinism, pool-vs-lockstep equivalence
per SamplingParams, stop sequences, cancellation, streaming order.

The API's core guarantees (DESIGN.md §Serving-API):
  * greedy SamplingParams reproduce the argmax tokens bitwise through the
    new API (pool and lockstep reference),
  * a seeded sampled request decodes the same tokens whether it runs
    alone or shares the continuous-batching pool (lane-local PRNG keys),
  * stop sequences and cancellation retire lanes mid-flight,
  * on_token streams every token in emission order,
  * the scheduler dispatches on engine capabilities only — no model
    family name checks outside the engine's declarations.

Runs under both REPRO_KERNEL_IMPL arms via scripts/ci_tier1.sh.
"""
import inspect

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.transformer import init_params
from repro.serving.api import (CancelToken, GenerateRequest, InferenceEngine,
                               PooledEngine, SamplingParams, StepResult)
from repro.serving.quantize import quantize_params
from repro.serving.sampling import lane_keys, sample_tokens
from repro.serving.scheduler import Scheduler, lockstep_generate

from tests.test_models_smoke import _reduced

MAX_LEN = 63          # pool capacity 64 with the reduced lop_block of 32


@pytest.fixture(scope="module")
def setup():
    cfg = _reduced("bitnet-3b")
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, quantize_params(cfg, params)


def _prompts(cfg, lens, seed=3):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, (n,)).astype(np.int32) for n in lens]


# ---------------------------------------------------------------------------
# Sampler units
# ---------------------------------------------------------------------------


def test_greedy_lane_is_bitwise_argmax():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.standard_normal((4, 40)), jnp.float32)
    keys = lane_keys(jnp.arange(4), jnp.zeros(4, jnp.int32))
    toks = sample_tokens(logits, keys, jnp.zeros(4), jnp.zeros(4, jnp.int32),
                         jnp.ones(4))
    np.testing.assert_array_equal(np.asarray(toks),
                                  np.asarray(jnp.argmax(logits, -1)))


def test_same_key_same_draw_different_key_varies():
    rng = np.random.default_rng(1)
    logits = jnp.asarray(np.tile(rng.standard_normal((1, 64)), (128, 1)),
                         jnp.float32)
    temps = jnp.ones(128)
    tks = jnp.zeros(128, jnp.int32)
    tps = jnp.ones(128)
    same = lane_keys(jnp.full(128, 7), jnp.full(128, 3))
    a = np.asarray(sample_tokens(logits, same, temps, tks, tps))
    assert (a == a[0]).all()                    # identical keys, one draw
    varied = lane_keys(jnp.full(128, 7), jnp.arange(128))
    b = np.asarray(sample_tokens(logits, varied, temps, tks, tps))
    assert len(np.unique(b)) > 1                # the schedule actually moves


def test_top_k_restricts_support():
    """With top_k=3, only the 3 largest logits may ever be drawn, and the
    empirical frequencies rank like the underlying probabilities."""
    logits_row = np.zeros(32, np.float32)
    logits_row[[4, 11, 27]] = [3.0, 2.5, 2.0]   # clear top-3
    n = 512
    logits = jnp.asarray(np.tile(logits_row, (n, 1)))
    keys = lane_keys(jnp.zeros(n, jnp.int32), jnp.arange(n))
    toks = np.asarray(sample_tokens(logits, keys, jnp.ones(n),
                                    jnp.full(n, 3, jnp.int32), jnp.ones(n)))
    assert set(np.unique(toks)) <= {4, 11, 27}
    counts = {t: int((toks == t).sum()) for t in (4, 11, 27)}
    assert counts[4] > counts[27]               # p(4) ≈ 2.7× p(27)


def test_top_p_restricts_support():
    """A sharply peaked distribution under top_p=0.5 keeps only the peak
    (its mass alone crosses p), so nucleus sampling is deterministic."""
    logits_row = np.zeros(16, np.float32)
    logits_row[5] = 8.0                         # p(5) ≈ 0.997
    n = 256
    logits = jnp.asarray(np.tile(logits_row, (n, 1)))
    keys = lane_keys(jnp.zeros(n, jnp.int32), jnp.arange(n))
    toks = np.asarray(sample_tokens(logits, keys, jnp.ones(n),
                                    jnp.zeros(n, jnp.int32),
                                    jnp.full(n, 0.5)))
    assert (toks == 5).all()


# ---------------------------------------------------------------------------
# Pool vs lockstep per SamplingParams
# ---------------------------------------------------------------------------


def test_greedy_api_matches_lockstep_bitwise(setup):
    """Default (greedy) SamplingParams through the new API reproduce the
    lockstep reference token-for-token — the acceptance criterion."""
    cfg, qp = setup
    prompts = _prompts(cfg, [12, 27, 9])
    sched = Scheduler(cfg, qp, n_slots=2, max_len=MAX_LEN)
    for rid, p in enumerate(prompts):
        sched.submit(GenerateRequest(rid=rid, prompt=p, max_new_tokens=6))
    results = sched.run_to_completion()
    for rid, p in enumerate(prompts):
        got = next(r for r in results if r.rid == rid)
        ref = lockstep_generate(cfg, qp, p, 6, max_len=MAX_LEN)
        assert got.tokens == ref, (rid, got.tokens, ref)


def test_sampled_fixed_seed_pool_equals_lockstep(setup):
    """A seeded sampled request decodes identical tokens alone or sharing
    the pool with other (greedy AND sampled) requests — the lane-local
    key-schedule guarantee, exercised through the chunked-prefill pool."""
    cfg, qp = setup
    prompts = _prompts(cfg, [14, 25, 8], seed=21)
    sps = [SamplingParams(temperature=0.8, top_k=8, seed=5),
           SamplingParams(),                     # greedy lane in the mix
           SamplingParams(temperature=1.2, top_p=0.9, seed=99)]
    sched = Scheduler(cfg, qp, n_slots=2, max_len=MAX_LEN)
    for rid, (p, sp) in enumerate(zip(prompts, sps)):
        sched.submit(GenerateRequest(rid=rid, prompt=p, max_new_tokens=6,
                                     sampling=sp))
    results = sched.run_to_completion()
    for rid, (p, sp) in enumerate(zip(prompts, sps)):
        got = next(r for r in results if r.rid == rid)
        ref = lockstep_generate(cfg, qp, p, 6, max_len=MAX_LEN, sampling=sp)
        assert got.tokens == ref, (rid, sp, got.tokens, ref)
    # rerunning the same seeded request alone is reproducible
    again = lockstep_generate(cfg, qp, prompts[0], 6, max_len=MAX_LEN,
                              sampling=sps[0])
    assert again == next(r for r in results if r.rid == 0).tokens


def test_sampled_tokens_actually_differ_from_greedy(setup):
    """Temperature sampling with a hot distribution must not collapse to
    argmax for every step (sanity that the sampled path is live)."""
    cfg, qp = setup
    (p,) = _prompts(cfg, [10], seed=4)
    greedy = lockstep_generate(cfg, qp, p, 12, max_len=MAX_LEN)
    draws = {tuple(lockstep_generate(
        cfg, qp, p, 12, max_len=MAX_LEN,
        sampling=SamplingParams(temperature=5.0, seed=s)))
        for s in range(3)}
    assert any(d != tuple(greedy) for d in draws), (greedy, draws)


# ---------------------------------------------------------------------------
# Stop sequences, cancellation, streaming
# ---------------------------------------------------------------------------


def test_stop_sequence_mid_decode(setup):
    cfg, qp = setup
    (p,) = _prompts(cfg, [11], seed=6)
    ref = lockstep_generate(cfg, qp, p, 10, max_len=MAX_LEN)
    stop = (tuple(ref[2:4]),)                   # hit after the 4th token
    sched = Scheduler(cfg, qp, n_slots=1, max_len=MAX_LEN)
    sched.submit(GenerateRequest(rid=0, prompt=p, max_new_tokens=10,
                                 stop=stop))
    res = sched.run_to_completion()[0]
    assert res.finish_reason == "stop"
    assert res.tokens == ref[:4]                # matched suffix stays
    # the lockstep reference honors the same stop contract
    assert lockstep_generate(cfg, qp, p, 10, max_len=MAX_LEN,
                             stop=stop) == ref[:4]


def test_cancellation_mid_decode_and_while_queued(setup):
    cfg, qp = setup
    pa, pb = _prompts(cfg, [13, 9], seed=8)
    tok_a = CancelToken()
    tok_b = CancelToken()
    seen = []

    def cancel_after_three(sr: StepResult):
        seen.append(sr.token)
        if sr.index == 2:
            tok_a.cancel()

    sched = Scheduler(cfg, qp, n_slots=1, max_len=MAX_LEN)
    sched.submit(GenerateRequest(rid=0, prompt=pa, max_new_tokens=12,
                                 on_token=cancel_after_three,
                                 cancel=tok_a))
    sched.submit(GenerateRequest(rid=1, prompt=pb, max_new_tokens=12,
                                 cancel=tok_b))
    tok_b.cancel()                               # cancelled while queued
    results = sched.run_to_completion()
    ra = next(r for r in results if r.rid == 0)
    rb = next(r for r in results if r.rid == 1)
    assert ra.finish_reason == "cancelled"
    assert len(ra.tokens) == 3 and ra.tokens == seen
    assert rb.finish_reason == "cancelled" and rb.tokens == []
    # the lane freed by the cancellation is reusable
    sched.submit(GenerateRequest(rid=2, prompt=pb, max_new_tokens=4))
    r2 = [r for r in sched.run_to_completion() if r.rid == 2][0]
    assert r2.tokens == lockstep_generate(cfg, qp, pb, 4, max_len=MAX_LEN)


def test_streaming_callback_ordering(setup):
    """on_token delivers every token in emission order with contiguous
    indices; finished=True exactly on the final token."""
    cfg, qp = setup
    prompts = _prompts(cfg, [10, 22], seed=9)
    streams: dict = {0: [], 1: []}

    def on_token(sr: StepResult):
        streams[sr.rid].append(sr)

    sched = Scheduler(cfg, qp, n_slots=2, max_len=MAX_LEN)
    for rid, p in enumerate(prompts):
        sched.submit(GenerateRequest(rid=rid, prompt=p, max_new_tokens=5,
                                     on_token=on_token))
    results = sched.run_to_completion()
    for rid, p in enumerate(prompts):
        srs = streams[rid]
        res = next(r for r in results if r.rid == rid)
        assert [sr.index for sr in srs] == list(range(len(res.tokens)))
        assert [sr.token for sr in srs] == res.tokens
        assert [sr.finished for sr in srs] == \
            [False] * (len(srs) - 1) + [True]
        assert srs[-1].finish_reason == res.finish_reason
        # per-token timestamps back the ITL telemetry
        assert len(res.token_times) == len(res.tokens)
        assert all(b >= a for a, b in zip(res.token_times,
                                          res.token_times[1:]))
        assert len(res.itl) == len(res.tokens) - 1


# ---------------------------------------------------------------------------
# Protocol discipline
# ---------------------------------------------------------------------------


def test_pooled_engine_satisfies_protocol(setup):
    cfg, qp = setup
    eng = PooledEngine(cfg, qp, max_len=MAX_LEN)
    assert isinstance(eng, InferenceEngine)
    assert eng.supports_chunked and not eng.exact_length_prefill
    assert eng.state_kind == "paged-kv" and not eng.has_image_prefix
    moe = _reduced("granite-moe-1b-a400m")
    eng_moe = PooledEngine(moe, qp, max_len=MAX_LEN)
    assert eng_moe.exact_length_prefill and not eng_moe.supports_chunked


def test_scheduler_has_no_family_name_checks():
    """Acceptance criterion: the scheduler dispatches on declared engine
    capabilities only — `cfg.family` never appears in its source."""
    import repro.serving.scheduler as sched_mod
    src = inspect.getsource(sched_mod)
    assert ".family" not in src
    for fam in ("\"dense\"", "'dense'", "\"vlm\"", "'vlm'",
                "CHUNKED_FAMILIES"):
        assert fam not in src, fam
