"""Checkpoint store: roundtrip, atomicity, restart, garbage collection."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import (latest_step, load_checkpoint,
                                    save_checkpoint)
from repro.launch.train import train_loop
from repro.training.optimizer import adamw_init

from tests.test_models_smoke import _reduced


def _tree(rng):
    return {"a": jnp.asarray(rng.standard_normal((4, 8)), jnp.float32),
            "nested": {"b": jnp.arange(10, dtype=jnp.int32)}}


def test_roundtrip(tmp_path, rng):
    tree = _tree(rng)
    save_checkpoint(str(tmp_path), 7, tree, extra={"note": "x"})
    out, step, extra = load_checkpoint(str(tmp_path), tree)
    assert step == 7 and extra == {"note": "x"}
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert (np.asarray(a) == np.asarray(b)).all()


def test_latest_and_gc(tmp_path, rng):
    tree = _tree(rng)
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(str(tmp_path), s, tree, keep=3)
    assert latest_step(str(tmp_path)) == 5
    kept = sorted(os.listdir(tmp_path))
    assert kept == ["step_00000003", "step_00000004", "step_00000005"]


def test_incomplete_checkpoint_ignored(tmp_path, rng):
    """A .tmp dir (crash mid-save) must be invisible to restore."""
    tree = _tree(rng)
    save_checkpoint(str(tmp_path), 1, tree)
    os.makedirs(tmp_path / "step_00000002.tmp")
    # also a committed dir without manifest = garbage
    os.makedirs(tmp_path / "step_00000003")
    assert latest_step(str(tmp_path)) == 1
    _, step, _ = load_checkpoint(str(tmp_path), tree)
    assert step == 1


def test_structure_mismatch_raises(tmp_path, rng):
    save_checkpoint(str(tmp_path), 1, _tree(rng))
    with pytest.raises(ValueError):
        load_checkpoint(str(tmp_path), {"different": jnp.zeros(3)})


def test_shape_mismatch_raises(tmp_path, rng):
    tree = _tree(rng)
    save_checkpoint(str(tmp_path), 1, tree)
    bad = dict(tree)
    bad["a"] = jnp.zeros((2, 2))
    with pytest.raises(ValueError):
        load_checkpoint(str(tmp_path), bad)


class _PreemptAt:
    """Fake preemption signal firing after N recorded steps."""

    def __init__(self, at):
        self.at = at
        self.n = 0

    @property
    def preempted(self):
        self.n += 1
        return self.n > self.at


@pytest.mark.slow
def test_train_restart_resumes_identically(tmp_path):
    """checkpoint/restart: 20 straight steps == preempt@10 + restart + 10.

    Both runs use the SAME 20-step schedule (lr depends on total steps);
    the first run is cut by a simulated preemption, which checkpoints."""
    cfg = _reduced("stablelm-1.6b").replace(n_layers=2)
    straight = train_loop(cfg, steps=20, global_batch=4, seq_len=16,
                          peak_lr=1e-3, log_every=1000)
    part1 = train_loop(cfg, steps=20, global_batch=4, seq_len=16,
                       peak_lr=1e-3, ckpt_dir=str(tmp_path), ckpt_every=100,
                       log_every=1000, preemption=_PreemptAt(10))
    assert part1["last_step"] < 20          # actually preempted
    part2 = train_loop(cfg, steps=20, global_batch=4, seq_len=16,
                       peak_lr=1e-3, ckpt_dir=str(tmp_path), ckpt_every=100,
                       log_every=1000, resume=True)
    assert part2["last_step"] == 20
    for a, b in zip(jax.tree.leaves(straight["params"]),
                    jax.tree.leaves(part2["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
