"""Dry-run machinery: lower+compile on the production meshes (subprocess
so the 512-device override never leaks into this process), plus unit tests
for the analysis layer."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import jax
from repro.configs.base import ShapeConfig
from repro.configs.qwen1_5_32b import REDUCED
from repro.distributed.partitioning import use_mesh
from repro.launch.dryrun import (build_decode_cell, build_prefill_cell,
                                 build_train_cell)
from repro.launch.mesh import make_production_mesh

cfg = REDUCED.replace(d_model=256, n_heads=8, n_kv_heads=4, head_dim=32,
                      d_ff=512, vocab=2048)
shapes = {
    "train": ShapeConfig("train_4k", 256, 32, "train"),
    "prefill": ShapeConfig("prefill_32k", 512, 32, "prefill"),
    "decode": ShapeConfig("decode_32k", 2048, 32, "decode"),
}
for multi_pod in (False, True):
    mesh = make_production_mesh(multi_pod=multi_pod)
    assert mesh.devices.size == (512 if multi_pod else 256)
    with use_mesh(mesh):
        for kind, shape in shapes.items():
            if kind == "train":
                fn, args, _ = build_train_cell(cfg, shape, mesh)
            elif kind == "prefill":
                fn, args, _ = build_prefill_cell(cfg, shape, mesh)
            else:
                fn, args, _ = build_decode_cell(cfg, shape, mesh)
            compiled = fn.lower(*args).compile()
            mem = compiled.memory_analysis()
            ca = compiled.cost_analysis()
            if isinstance(ca, (list, tuple)):   # older jax: list of dicts
                ca = ca[0]
            assert ca.get("flops", 0) > 0
            print(kind, multi_pod, "ok", mem.temp_size_in_bytes)
print("DRYRUN_SMOKE_OK")
"""


@pytest.mark.slow
def test_dryrun_machinery_both_meshes(tmp_path):
    script = tmp_path / "dryrun_smoke.py"
    script.write_text(SCRIPT)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["TF_CPP_MIN_LOG_LEVEL"] = "2"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, str(script)], capture_output=True,
                         text=True, timeout=1800, env=env)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "DRYRUN_SMOKE_OK" in out.stdout


def test_collective_bytes_parser():
    from repro.analysis.hlo import collective_bytes
    hlo = """
  %ag = f32[8,128]{1,0} all-gather(%x), replica_groups=...
  %ar.1 = bf16[64]{0} all-reduce(%y), to_apply=%sum
  %cp = (s8[4,4]{1,0}, u32[]) collective-permute-start(%z)
  %cpd = s8[4,4]{1,0} collective-permute-done(%cp)
  %rs = f32[16]{0} reduce-scatter(%w), dimensions={0}
  %a2a = f32[2,8]{1,0} all-to-all(%v), dimensions={0}
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 8 * 128 * 4
    assert out["all-reduce"] == 64 * 2
    assert out["collective-permute"] == 16 + 4      # tuple incl. u32[]
    assert out["reduce-scatter"] == 64
    assert out["all-to-all"] == 64
    assert out["counts"]["collective-permute"] == 1   # -done not re-counted


def test_roofline_terms_dominance():
    from repro.analysis.roofline import roofline_terms
    t = roofline_terms({"flops": 197e12, "bytes accessed": 819e9 / 2},
                       {"total": 0})
    assert t["dominant"] == "compute_s"
    assert abs(t["compute_s"] - 1.0) < 1e-6
    t2 = roofline_terms({"flops": 1e9, "bytes accessed": 819e9},
                        {"total": 50e9 * 3})
    assert t2["dominant"] == "collective_s"
    assert abs(t2["collective_s"] - 3.0) < 1e-6


def test_model_flops_conventions():
    from repro.analysis.roofline import model_flops
    from repro.configs.base import SHAPES, get_config
    cfg = get_config("qwen1.5-32b")
    n = 32_000_000_000
    mf_train = model_flops(cfg, SHAPES["train_4k"], n, n)
    assert mf_train == 6.0 * n * 256 * 4096
    mf_dec = model_flops(cfg, SHAPES["decode_32k"], n, n)
    assert mf_dec == 2.0 * n * 128
