"""Training stack: optimizer correctness, accumulation, convergence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import SyntheticDataset
from repro.launch.train import train_loop
from repro.models.transformer import init_params
from repro.training.optimizer import (adamw_init, adamw_update,
                                      clip_by_global_norm, warmup_cosine)
from repro.training.train import make_train_step

from tests.test_models_smoke import _batch, _reduced


def test_adamw_matches_numpy_reference(rng):
    p = {"w": jnp.asarray(rng.standard_normal((4, 3)), jnp.float32)}
    g = {"w": jnp.asarray(rng.standard_normal((4, 3)), jnp.float32)}
    state = adamw_init(p)
    lr, b1, b2, eps, wd = 0.01, 0.9, 0.95, 1e-8, 0.1
    p2, s2 = adamw_update(g, state, p, lr=lr, b1=b1, b2=b2, eps=eps,
                          weight_decay=wd)
    # numpy reference, step 1
    m = (1 - b1) * np.asarray(g["w"])
    v = (1 - b2) * np.square(np.asarray(g["w"]))
    mhat = m / (1 - b1)
    vhat = v / (1 - b2)
    expect = np.asarray(p["w"]) - lr * (mhat / (np.sqrt(vhat) + eps)
                                        + wd * np.asarray(p["w"]))
    np.testing.assert_allclose(np.asarray(p2["w"]), expect, rtol=1e-5)
    assert int(s2.step) == 1


def test_grad_clip(rng):
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) > 1.0
    assert np.isclose(float(jnp.linalg.norm(clipped["a"])), 1.0, rtol=1e-3)


def test_warmup_cosine_schedule():
    lrs = [float(warmup_cosine(jnp.int32(s), peak_lr=1.0, warmup=10,
                               total=100)) for s in range(0, 100, 5)]
    assert lrs[0] == 0.0
    assert max(lrs) <= 1.0 + 1e-6
    assert lrs[-1] < lrs[4]          # decayed below the peak


def test_grad_accumulation_equivalence():
    """n_micro=2 must give the same update as n_micro=1 on the same data."""
    cfg = _reduced("stablelm-1.6b")
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, b=4, t=12)
    opt = adamw_init(params)

    s1 = make_train_step(cfg, n_micro=1, total_steps=10)
    s2 = make_train_step(cfg, n_micro=2, total_steps=10)
    p1, _, m1 = s1(params, opt, batch)
    p2, _, m2 = s2(params, opt, batch)
    assert np.isclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-4)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


@pytest.mark.slow
def test_loss_decreases_qat():
    """BitNet QAT actually learns the synthetic bigram structure."""
    cfg = _reduced("bitnet-3b").replace(n_layers=2, vocab=256)
    out = train_loop(cfg, steps=60, global_batch=8, seq_len=32,
                     peak_lr=3e-3, log_every=1000)
    first = np.mean(out["losses"][:5])
    last = np.mean(out["losses"][-5:])
    assert last < first - 0.3, (first, last)


def test_synthetic_data_deterministic_and_sharded():
    cfg = _reduced("bitnet-3b")
    d0 = SyntheticDataset(cfg, seq_len=16, global_batch=8, seed=1,
                          process_index=0, process_count=2)
    d0b = SyntheticDataset(cfg, seq_len=16, global_batch=8, seed=1,
                           process_index=0, process_count=2)
    d1 = SyntheticDataset(cfg, seq_len=16, global_batch=8, seed=1,
                          process_index=1, process_count=2)
    a, b, c = d0.batch(3), d0b.batch(3), d1.batch(3)
    assert (a["tokens"] == b["tokens"]).all()          # deterministic
    assert not (a["tokens"] == c["tokens"]).all()      # per-host shards
    assert a["tokens"].shape == (4, 16)                # local batch
    assert (a["labels"][:, :-1] == a["tokens"][:, 1:]).all()
