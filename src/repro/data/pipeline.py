"""Synthetic sharded token pipeline.

Deterministic per (seed, step, host): every host materializes only its own
batch shard (``process_index``/``process_count``), so the loader scales to
multi-host pods without a central feeder. Sequences follow a Zipf-ish token
distribution with induced bigram structure so a real model actually has
something learnable (loss decreases — used by the convergence tests).

Modality stubs per the brief: ``frames`` (whisper) and ``patches`` (llava)
are deterministic pseudo-embeddings, standing in for the conv frontend /
vision tower.
"""

from __future__ import annotations

import numpy as np

from repro.configs.base import text_len


class SyntheticDataset:
    def __init__(self, cfg, *, seq_len: int, global_batch: int,
                 seed: int = 0, process_index: int = 0,
                 process_count: int = 1):
        assert global_batch % process_count == 0
        self.cfg = cfg
        self.seq_len = seq_len
        self.local_batch = global_batch // process_count
        self.seed = seed
        self.process_index = process_index

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.seed * 1_000_003 + step) * 4096 + self.process_index)

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = self._rng(step)
        b = self.local_batch
        t = text_len(cfg, self.seq_len, "train")
        # Zipf-ish unigram + deterministic bigram successor structure
        base = rng.zipf(1.3, size=(b, t + 1)) % cfg.vocab
        succ = (np.arange(cfg.vocab) * 31 + 7) % cfg.vocab
        mask = rng.random((b, t)) < 0.5
        base[:, 1:][mask] = succ[base[:, :-1][mask]]
        tokens = base[:, :t].astype(np.int32)
        labels = base[:, 1:].astype(np.int32)
        out = {"tokens": tokens, "labels": labels}
        if cfg.family == "encdec":
            out["frames"] = rng.standard_normal(
                (b, self.seq_len, cfg.d_model)).astype(np.float32) * 0.02
        if cfg.family == "vlm":
            out["patches"] = rng.standard_normal(
                (b, cfg.n_img_tokens, cfg.d_model)).astype(np.float32) * 0.02
        return out
