"""Data pipeline: synthetic sharded token streams."""

from repro.data.pipeline import SyntheticDataset
