"""QAT training step: BitNet STE forward, CE loss, grad accumulation.

``make_train_step`` builds the jit-able step used by both the real trainer
(:mod:`repro.launch.train`) and the dry-run lowering — microbatch gradient
accumulation via scan, global-norm clipping, AdamW, optional int8 gradient
compression (:mod:`repro.distributed.compression`).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.transformer import forward_train
from repro.training.optimizer import (adamw_update, clip_by_global_norm,
                                      global_norm, warmup_cosine)

AUX_WEIGHT = 0.01           # MoE load-balance loss weight


def loss_fn(cfg, params, batch, *, remat: bool = True):
    """Causal-LM cross entropy (+ MoE aux). batch: tokens/labels [B, T]."""
    logits, aux = forward_train(
        cfg, params, batch["tokens"],
        frames=batch.get("frames"), patches=batch.get("patches"),
        remat=remat)
    # mask vocab padding out of the softmax
    vmask = jnp.arange(cfg.vocab_padded) < cfg.vocab
    logits = jnp.where(vmask, logits, -1e30)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, batch["labels"][..., None],
                             axis=-1)[..., 0]
    ce = -jnp.mean(ll)
    return ce + AUX_WEIGHT * aux, {"ce": ce, "aux": aux}


def _microbatches(batch, n_micro: int):
    return jax.tree.map(
        lambda a: a.reshape(n_micro, a.shape[0] // n_micro, *a.shape[1:]),
        batch)


def make_train_step(cfg, *, n_micro: int = 1, peak_lr: float = 3e-4,
                    warmup: int = 100, total_steps: int = 10_000,
                    max_grad_norm: float = 1.0, compress_grads=None,
                    remat: bool = True):
    """Returns ``train_step(params, opt_state, batch) → (params, state,
    metrics)``.

    ``compress_grads`` is an optional hook (gradient tree → gradient tree),
    e.g. int8 all-reduce compression with error feedback.
    """

    def grads_of(params, mb):
        (loss, parts), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, mb, remat=remat), has_aux=True)(params)
        return loss, parts, grads

    def train_step(params, opt_state, batch):
        if n_micro == 1:
            loss, parts, grads = grads_of(params, batch)
        else:
            mbs = _microbatches(batch, n_micro)

            def acc_body(acc, mb):
                loss, parts, grads = grads_of(params, mb)
                acc = jax.tree.map(jnp.add, acc,
                                   {"loss": loss, "grads": grads})
                return acc, parts

            zero = {"loss": jnp.float32(0),
                    "grads": jax.tree.map(
                        lambda p: jnp.zeros(p.shape, jnp.float32), params)}
            acc, parts = jax.lax.scan(acc_body, zero, mbs)
            loss = acc["loss"] / n_micro
            grads = jax.tree.map(lambda g: g / n_micro, acc["grads"])
            parts = jax.tree.map(lambda x: jnp.mean(x), parts)

        if compress_grads is not None:
            grads = compress_grads(grads)
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        # schedule indexed by the step being TAKEN (1-based: first step
        # gets peak/warmup, not zero)
        lr = warmup_cosine(opt_state.step + 1, peak_lr=peak_lr,
                           warmup=warmup, total=total_steps)
        params, opt_state = adamw_update(grads, opt_state, params, lr=lr)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr,
                   "param_norm": global_norm(params), **parts}
        return params, opt_state, metrics

    return train_step
