"""Training stack: AdamW, QAT train step, grad accumulation, schedules."""

from repro.training.optimizer import adamw_init, adamw_update
from repro.training.train import loss_fn, make_train_step
