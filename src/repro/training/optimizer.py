"""AdamW, implemented from scratch as a pytree transform.

Moments are f32 regardless of param dtype (BitNet QAT trains latent master
weights; the STE forward quantizes, the optimizer never sees quantization).
Optimizer state shards exactly like the parameters (ZeRO-3: same pspecs),
so FSDP covers params, grads, m and v uniformly.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array          # int32 scalar
    m: dict                  # first moment (f32, param-shaped)
    v: dict                  # second moment (f32, param-shaped)


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def adamw_update(grads, state: AdamWState, params, *, lr, b1: float = 0.9,
                 b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1):
    """→ (new_params, new_state). ``lr`` may be a traced scalar (schedule)."""
    step = state.step + 1
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(
            jnp.float32)
        return (p - lr * delta.astype(p.dtype)).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m,
                                                 flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def warmup_cosine(step, *, peak_lr: float, warmup: int, total: int,
                  floor: float = 0.1):
    """Linear warmup → cosine decay to ``floor``·peak."""
    step = step.astype(jnp.float32)
    warm = peak_lr * step / max(warmup, 1)
    frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
    return jnp.where(step < warmup, warm, cos)
