"""Public jit'd wrappers around the Pallas kernels.

Dispatch policy
---------------
Every op has two interchangeable implementations with identical semantics:

  * ``pallas``  — the TPU kernel (``interpret=True`` on CPU, where the kernel
    body executes in Python; this is the validation mode mandated for this
    container).
  * ``ref``     — the pure-jnp oracle in :mod:`repro.kernels.ref`. XLA lowers
    it to the same MXU int8 dots on TPU; it is also what the full-size
    dry-run traces (interpret-mode Pallas unrolls its grid at trace time,
    which would explode the HLO for production shapes).

``impl="auto"`` resolves to ``pallas`` on TPU and ``ref`` elsewhere, so the
same model code runs in tests (small shapes, interpret kernels), in the
dry-run (full shapes, ref path), and on real hardware (kernels).

All wrappers pad to the kernel block sizes and slice back.

Block shapes come from a three-step precedence chain
(:mod:`repro.kernels.autotune`, DESIGN.md §Autotuning): an active
``autotune.override`` context, then the swept ``TUNE_kernels.json``
table keyed by backend config and workload shape, then the hardcoded
defaults below — so with no table on disk every dispatch is bitwise the
pre-autotune behavior, and every swept knob is a pure tiling choice
pinned against the same oracles.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.core.lop import pot
from repro.core.ternary import TernaryWeight
from repro.kernels import autotune as _tune
from repro.kernels import decode_attention as _dec
from repro.kernels import int8_attention as _attn
from repro.kernels import lop_scores as _lop
from repro.kernels import prefill_attention as _pf
from repro.kernels import qlinear as _ql
from repro.kernels import ref as _ref
from repro.kernels import ternary_matmul as _tmm


def _resolve(impl: str) -> str:
    if impl != "auto":
        return impl
    env = os.environ.get("REPRO_KERNEL_IMPL")
    if env:
        return env
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _lead_rows(shape) -> int:
    """Row count after flattening leading dims (static Python ints)."""
    rows = 1
    for s in shape:
        rows *= int(s)
    return rows


def _pad_to(x: jax.Array, mult: int, axis: int):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


# ---------------------------------------------------------------------------
# TINT: packed-ternary × int8 GEMM
# ---------------------------------------------------------------------------

def ternary_matmul(x: jax.Array, tw: TernaryWeight, *,
                   impl: str = "auto") -> jax.Array:
    """int8 activations [..., k] × packed ternary weight → int32 [..., n].

    Output is the raw integer accumulator; the caller applies the absmax-
    barrier dequantization (one multiply by activation-scale × γ).
    """
    k, n = tw.shape
    lead = x.shape[:-1]
    x2 = x.reshape(-1, k)
    # log-and-sweep (DESIGN.md §Autotuning): shapes are static at trace
    # time, so each distinct dispatch shape is observed once per compile
    _tune.observe("ternary_matmul", {"m": x2.shape[0], "k": k, "n": n})
    if _resolve(impl) == "ref":
        out = _ref.ternary_matmul_ref(x2, tw.packed, k)
        return out.reshape(*lead, n)

    tuned = _tune.lookup("ternary_matmul",
                         {"m": x2.shape[0], "k": k, "n": n})
    bm = tuned.get("bm", min(_tmm.DEFAULT_BM, max(8, x2.shape[0])))
    bk = tuned.get("bk", min(_tmm.DEFAULT_BK, k))
    bn = tuned.get("bn", min(_tmm.DEFAULT_BN, n))
    xp, m0 = _pad_to(x2, bm, 0)
    assert k % bk == 0 and n % bn == 0, (k, n, bk, bn)
    out = _tmm.ternary_matmul(xp, tw.packed, k, bm=bm, bk=bk, bn=bn,
                              interpret=_interpret())
    return out[:m0].reshape(*lead, n)


# ---------------------------------------------------------------------------
# Fused TINT projections — THE linear entry points (DESIGN.md
# §TINT-projection-fusion): the absmax barrier, the packed-ternary GEMM
# and the dequant/bias/activation epilogue run as ONE dispatch.
# ---------------------------------------------------------------------------

def _pick_block(n: int, target: int = 128) -> int:
    """Largest divisor of n that is ≤ target (no weight-column padding)."""
    if n <= target:
        return n
    for b in range(target, 0, -1):
        if n % b == 0:
            return b
    return 1


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def _col_scale(scale: jax.Array, n: int) -> jax.Array:
    """Per-node γ (scalar or per-column row) → per-column f32 row [.., 1, n]."""
    return jnp.broadcast_to(scale.astype(jnp.float32),
                            scale.shape[:-2] + (1, n))


def qlinear_fused(x: jax.Array, packed: jax.Array, scale: jax.Array,
                  bias: jax.Array | None = None, *, act: str | None = None,
                  impl: str = "auto") -> jax.Array:
    """f32/bf16 activations [..., k] × packed ternary [k//4, n] → f32 [..., n].

    One dispatch replaces the quantize → ``ternary_matmul`` → dequant
    chain: the absmax row-quantize runs in VMEM inside the same kernel
    (the barrier), the epilogue fuses dequant by (x-scale · γ), bias and
    the optional activation. A 3-D ``packed`` [E, k//4, n] with x
    [E, C, k] runs the grouped-expert form — expert is a grid axis of
    the same launch, not a vmap of launches. ``scale`` is the node's γ:
    scalar [.., 1, 1] or per-column row [.., 1, n] (fused QKV).
    """
    expert = packed.ndim == 3
    k = packed.shape[-2] * 4
    n = packed.shape[-1]
    scale_row = _col_scale(scale, n)
    _tune.observe("qlinear", {"e": x.shape[0] if expert else 1,
                              "m": (x.shape[1] if expert
                                    else _lead_rows(x.shape[:-1])),
                              "k": k, "n": n})
    if _resolve(impl) == "ref":
        return _ref.qlinear_ref(x, packed, scale_row, bias, act=act)

    if expert:
        assert x.ndim == 3, x.shape
        x3, p3, s3 = x.astype(jnp.float32), packed, scale_row
        b3 = None if bias is None else bias.reshape(bias.shape[0], 1, n)
    else:
        x3 = x.reshape(-1, k).astype(jnp.float32)[None]
        p3, s3 = packed[None], scale_row[None]
        b3 = None if bias is None else bias.reshape(1, 1, n)
    m0 = x3.shape[1]
    tuned = _tune.lookup("qlinear", {"e": x3.shape[0], "m": m0,
                                     "k": k, "n": n})
    bm = tuned.get("bm", min(_tmm.DEFAULT_BM, _round_up(max(m0, 1), 8)))
    pad = (-m0) % bm
    if pad:
        x3 = jnp.pad(x3, ((0, 0), (0, pad), (0, 0)))
    out = _ql.fused_qlinear(x3, p3, s3, b3, bm=bm,
                            bn=tuned.get("bn", _pick_block(n)),
                            bkq=tuned.get("bkq", 0),
                            eg=tuned.get("eg", 1),
                            act=act, interpret=_interpret())[:, :m0]
    if expert:
        return out
    return out.reshape(*x.shape[:-1], n)


def ffn_fused(x: jax.Array, gu_packed: jax.Array, gu_scale: jax.Array,
              down_packed: jax.Array, down_scale: jax.Array, *,
              gated: bool, act: str, impl: str = "auto") -> jax.Array:
    """The whole FFN — act(x·Wg)·(x·Wu) → absmax barrier → ·Wd — as ONE
    dispatch. x [..., d]; gu_packed [(E,) d//4, 2f] (gate ‖ up columns;
    [(E,) d//4, f] ungated); down_packed [(E,) f//4, d_out]. A leading
    expert dim with x [E, C, d] runs every expert of a MoE layer in the
    same launch (expert = third grid axis). → f32 [..., d_out].
    """
    expert = gu_packed.ndim == 3
    k = gu_packed.shape[-2] * 4
    f = down_packed.shape[-2] * 4
    d_out = down_packed.shape[-1]
    gu_row = _col_scale(gu_scale, gu_packed.shape[-1])
    down_row = _col_scale(down_scale, d_out)
    _tune.observe("ffn", {"e": x.shape[0] if expert else 1,
                          "m": (x.shape[1] if expert
                                else _lead_rows(x.shape[:-1])),
                          "k": k, "f": f, "n": d_out})
    if _resolve(impl) == "ref":
        return _ref.ffn_fused_ref(x, gu_packed, gu_row, down_packed,
                                  down_row, gated=gated, act=act)

    if expert:
        assert x.ndim == 3, x.shape
        x3, gu3, gs3 = x.astype(jnp.float32), gu_packed, gu_row
        d3, ds3 = down_packed, down_row
    else:
        x3 = x.reshape(-1, k).astype(jnp.float32)[None]
        gu3, gs3 = gu_packed[None], gu_row[None]
        d3, ds3 = down_packed[None], down_row[None]
    m0 = x3.shape[1]
    tuned = _tune.lookup("ffn", {"e": x3.shape[0], "m": m0, "k": k,
                                 "f": f, "n": d_out})
    bm = tuned.get("bm", min(_tmm.DEFAULT_BM, _round_up(max(m0, 1), 8)))
    pad = (-m0) % bm
    if pad:
        x3 = jnp.pad(x3, ((0, 0), (0, pad), (0, 0)))
    out = _ql.fused_ffn(x3, gu3, gs3, d3, ds3, bm=bm,
                        bf=tuned.get("bf", _pick_block(f)),
                        bn=tuned.get("bn", _pick_block(d_out)),
                        bkq=tuned.get("bkq", 0), act=act, gated=gated,
                        interpret=_interpret())[:, :m0]
    if expert:
        return out
    return out.reshape(*x.shape[:-1], d_out)


# ---------------------------------------------------------------------------
# LOP screen: surrogate scores from the packed feature cache
# ---------------------------------------------------------------------------

def lop_screen(q: jax.Array, feat_packed: jax.Array, *,
               impl: str = "auto") -> jax.Array:
    """int8 queries [..., d] × packed (sgn‖LO) cache [m, d//2] → int32 [..., m].

    Applies pot() rounding to q internally (the cache is already rounded).
    """
    d = q.shape[-1]
    m = feat_packed.shape[0]
    lead = q.shape[:-1]
    qp = pot(q).reshape(-1, d)
    if _resolve(impl) == "ref":
        out = _ref.lop_scores_ref(qp, feat_packed)
        return out.reshape(*lead, m)

    bq = min(_lop.DEFAULT_BQ, max(8, qp.shape[0]))
    bm = min(_lop.DEFAULT_BM, m)
    qpp, g0 = _pad_to(qp, bq, 0)
    assert m % bm == 0, (m, bm)
    out = _lop.lop_scores_kernel(qpp, feat_packed, bq=bq, bm=bm,
                                 interpret=_interpret())
    return out[:g0].reshape(*lead, m)


# ---------------------------------------------------------------------------
# Int8 flash attention (prefill) and LOP block-sparse decode
# ---------------------------------------------------------------------------

def flash_prefill(q, k, v, q_scale, k_scale, v_scale, *,
                  softmax_scale: float, causal: bool = True, window: int = 0,
                  impl: str = "auto") -> jax.Array:
    """Single-head int8 flash attention; see kernel docstring for shapes."""
    if _resolve(impl) == "ref":
        return _ref.flash_prefill_ref(q, k, v, q_scale, k_scale, v_scale,
                                      softmax_scale=softmax_scale,
                                      causal=causal, window=window)
    s = q.shape[0]
    bq = min(_attn.DEFAULT_BQ, s)
    bk = min(_attn.DEFAULT_BK, s)
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    return _attn.int8_flash_prefill(q, k, v, q_scale, k_scale, v_scale,
                                    softmax_scale=softmax_scale,
                                    causal=causal, window=window, bq=bq,
                                    bk=bk, interpret=_interpret())


def sparse_decode(q, k_cache, v_cache, q_scale, k_scale, v_scale,
                  block_idx, gate_tokens, *, block: int,
                  softmax_scale: float, impl: str = "auto") -> jax.Array:
    """Single-kv-head LOP-sparse decode micro-kernel.

    Kept as a standalone building block (microbenchmarks, kernel tests,
    the legacy-dispatch baseline in benchmarks/fig8_lop.py); the serving
    decode path dispatches through :func:`decode_attention` instead.
    """
    if _resolve(impl) == "ref":
        return _ref.sparse_decode_attention_ref(
            q, k_cache, v_cache, q_scale, k_scale, v_scale, block_idx,
            gate_tokens, block=block, softmax_scale=softmax_scale)
    return _attn.sparse_decode_attention(
        q, k_cache, v_cache, q_scale, k_scale, v_scale, block_idx,
        gate_tokens, block=block, softmax_scale=softmax_scale,
        interpret=_interpret())


# ---------------------------------------------------------------------------
# Fused batched prefill attention — THE prefill entry point
# ---------------------------------------------------------------------------

def prefill_attention(qi, qsc, k_cache, v_cache, k_scale, v_scale, kv_len, *,
                      q_offset=None, causal: bool = True, window: int = 0,
                      softmax_scale: float | None = None,
                      int8_logits: bool = False, impl: str = "auto"):
    """Single entry for every prefill-attention flavour (DESIGN.md
    §Chunked-prefill): whole-prompt prefill, chunked prefill, encoder
    self-attention (``causal=False``) and decoder cross-attention all
    route through this one op, so the chunked scheduler and the lockstep
    reference compute bit-identical rows under either dispatch arm.

    qi        int8  [B, H, C, dh]   chunk (or whole-prompt) queries
    qsc       f32   [B, H, C]       per-token-head absmax query scales
    k/v_cache int8  [B, Hkv, M, dh] caches with K/V written at [0, kv_len)
    k/v_scale f32   [B, Hkv, M]     per-token absmax scales
    kv_len    int32 [B]             valid cache tokens (incl. this chunk)
    q_offset       traced int32 scalar or None — global position of query
                   column 0 (chunked prefill passes its chunk start)
    → f32 [B, H, C, dh]

    ``impl="pallas"`` runs the fused kernel
    (:mod:`repro.kernels.prefill_attention`): one ``pallas_call`` whose
    grid spans (B·Hkv, kv-block stream) with f32 online-softmax carry in
    VMEM scratch. ``impl="ref"`` runs the jnp oracle, streamed over query
    chunks so dry-run traces stay memory-bounded. The wrapper pads M to
    the kernel block size; padded tokens sit beyond ``kv_len`` and fold
    as bitwise no-ops.
    """
    b, h, c, dh = qi.shape
    hkv, m = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    assert h == g * hkv, (h, hkv)
    if softmax_scale is None:
        softmax_scale = dh ** -0.5
    kv_len = kv_len.astype(jnp.int32)
    _tune.observe("prefill", {"bhg": b * hkv, "r": g * c, "d": dh,
                              "m": m, "chunk": c})

    if _resolve(impl) == "ref":
        return _ref.prefill_attention_ref(
            qi, qsc, k_cache, v_cache, k_scale, v_scale, kv_len,
            0 if q_offset is None else q_offset, causal=causal,
            window=window, softmax_scale=softmax_scale,
            int8_logits=int8_logits)

    tuned = _tune.lookup("prefill", {"bhg": b * hkv, "r": g * c, "d": dh,
                                     "m": m, "chunk": c})
    bk = tuned.get("block", min(_pf.DEFAULT_BK, m))
    pad = (-m) % bk
    if pad:
        widths = [(0, 0), (0, 0), (0, pad)]
        k_cache = jnp.pad(k_cache, widths + [(0, 0)])
        v_cache = jnp.pad(v_cache, widths + [(0, 0)])
        k_scale = jnp.pad(k_scale, widths)
        v_scale = jnp.pad(v_scale, widths)
        m += pad

    # flatten (B, Hkv) → the kernel's batched lane axis; rows g-major
    bh = b * hkv
    qig = qi.reshape(b, hkv, g, c, dh).reshape(bh, g * c, dh)
    qsg = qsc.reshape(b, hkv, g, c).reshape(bh, g * c, 1)
    po = jnp.full((1,), 0 if q_offset is None else q_offset, jnp.int32)
    out = _pf.fused_prefill_attention(
        qig, qsg, k_cache.reshape(bh, m, dh), v_cache.reshape(bh, m, dh),
        k_scale.reshape(bh, m, 1), v_scale.reshape(bh, m, 1), kv_len, po,
        hkv=hkv, chunk=c, block=bk, bq=tuned.get("bq", 0), causal=causal,
        window=window, softmax_scale=softmax_scale,
        int8_logits=int8_logits, interpret=_interpret())
    return out.reshape(b, h, c, dh)


# ---------------------------------------------------------------------------
# Fused batched decode attention — THE decode entry point
# ---------------------------------------------------------------------------

def decode_attention(qi, qsc, k_cache, v_cache, k_scale, v_scale, feat,
                     new_len, *, block: int, k_keep: int, window: int = 0,
                     softmax_scale: float | None = None,
                     use_lop: bool = True, shared_select: bool = False,
                     pos_offset=None, return_stats: bool = False,
                     impl: str = "auto"):
    """Single entry for every decode-attention flavour (DESIGN.md
    §Fused-decode-kernel).

    Serves the dense baseline (``use_lop=False``), the LOP-sparse path,
    group-shared selection (``shared_select``) and the SP-sharded path
    (``pos_offset`` + ``return_stats``) from one call:

    qi        int8  [B, H, dh]     new-token queries
    qsc       f32   [B, H, 1]      per-head absmax query scales
    k/v_cache int8  [B, Hkv, M, dh]
    k/v_scale f32   [B, Hkv, M]
    feat      uint8 [B, Hkv, M, dh//2]  packed (sgn‖LO) feature cache
    new_len   int32 [B]            valid tokens per lane; 0 = retired
                                   slot-pool lane (emits exactly zero)
    pos_offset     traced int32 scalar or None — global token position of
                   cache row 0 (the SP quota-sharded path passes its
                   shard offset; must be a multiple of ``block``)
    return_stats   also return the unnormalized softmax stats (m, ℓ)
                   f32 [B, H, 1] for the flash-decoding shard merge

    → f32 [B, H, dh]  (or ``(out, m, ℓ)`` with ``return_stats``).

    ``impl="pallas"`` runs the fused kernel
    (:mod:`repro.kernels.decode_attention`): one ``pallas_call`` whose
    grid spans (B·Hkv, stream) — screen, comparison-free top-K, and
    DMA-gathered exact attention in a single launch. ``impl="ref"`` runs
    the jnp oracle, which XLA fuses well enough for the dry-run traces.
    """
    b, h, dh = qi.shape
    hkv, m = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    assert h == g * hkv, (h, hkv)
    assert m % block == 0, (m, block)
    if softmax_scale is None:
        softmax_scale = dh ** -0.5
    _tune.observe("decode", {"bhg": b * hkv, "g": g, "d": dh, "m": m,
                             "block": block, "k_keep": k_keep})

    if _resolve(impl) == "ref":
        return _ref.decode_attention_ref(
            qi, qsc, k_cache, v_cache, k_scale, v_scale, feat, new_len,
            block=block, k_keep=k_keep, window=window,
            softmax_scale=softmax_scale, use_lop=use_lop,
            shared_select=shared_select, pos_offset=pos_offset,
            return_stats=return_stats)

    # flatten (B, Hkv) → the kernel's batched lane axis
    bh = b * hkv
    qig = qi.reshape(b, hkv, g, dh).reshape(bh, g, dh)
    qsg = qsc.reshape(b, hkv, g, 1).reshape(bh, g, 1)
    kf = k_cache.reshape(bh, m, dh)
    vf = v_cache.reshape(bh, m, dh)
    ksf = k_scale.reshape(bh, m, 1)
    vsf = v_scale.reshape(bh, m, 1)
    featf = feat.reshape(bh, m, dh // 2)
    po = jnp.full((1,), 0 if pos_offset is None else pos_offset, jnp.int32)
    tuned = _tune.lookup("decode", {"bhg": bh, "g": g, "d": dh, "m": m,
                                    "block": block, "k_keep": k_keep})
    out = _dec.fused_decode_attention(
        qig, qsg, kf, vf, ksf, vsf, featf, new_len.astype(jnp.int32), po,
        hkv=hkv, block=block, k_keep=k_keep, window=window,
        softmax_scale=softmax_scale, use_lop=use_lop,
        shared_select=shared_select, return_stats=return_stats,
        n_slots=tuned.get("n_slots", 2), interpret=_interpret())
    if return_stats:
        o, ms, ls = out
        return (o.reshape(b, h, dh), ms.reshape(b, h, 1),
                ls.reshape(b, h, 1))
    return out.reshape(b, h, dh)
