"""Int8 attention Pallas kernels (paper §II-B / §III adaptation).

Two kernels share the BoothFlex idea's transferable half — one integer
datapath serves both attention and projections, so the int8 layout/scale
conventions established by the absmax barrier flow through attention without
format churn:

  * ``int8_flash_prefill`` — blocked causal flash attention over int8 Q/K/V
    with per-token f32 scales and f32 online-softmax reductions (the paper's
    "nonlinear reductions overlap with linear tiles": running max / sum-exp
    accumulate in VMEM scratch while the MXU produces logit tiles).
  * ``sparse_decode_attention`` — the LOP-selected block-sparse decode step:
    a scalar-prefetch grid walks ONLY the K candidate KV blocks (contiguous
    reads, paper Fig. 4), doing exact int8 attention over them.

HW-codesign notes:
  * int8 operands keep MXU throughput at 2× bf16 and HBM traffic at ½.
  * KV blocks are 128-token aligned — the ASIC's "short contiguous reads"
    become TPU-aligned HBM bursts.
  * f32 accumulators/reductions live in VMEM scratch across the key-streaming
    grid axis (output-stationary, like the paper's OS dataflow).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BQ = 128
DEFAULT_BK = 128


# ---------------------------------------------------------------------------
# Blocked int8 causal flash attention (prefill)
# ---------------------------------------------------------------------------

def _flash_prefill_kernel(q_ref, k_ref, v_ref, qs_ref, ks_ref, vs_ref,
                          o_ref, m_ref, l_ref, acc_ref, *,
                          n_k: int, bq: int, bk: int, softmax_scale: float,
                          causal: bool, window: int):
    """Grid (q-tile i, k-tile j); j is the sequential streaming axis."""
    i, j = pl.program_id(0), pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal: tiles strictly above the diagonal contribute nothing;
    # SWA: tiles entirely below the window band are skipped too
    run = True
    if causal:
        run = j * bk <= i * bq + bq - 1
        if window:
            run = jnp.logical_and(run, (j + 1) * bk - 1 > i * bq - window)

    @pl.when(run)
    def _tile():
        q = q_ref[...]                                    # [bq, d] int8
        k = k_ref[...]                                    # [bk, d] int8
        s = jax.lax.dot_general(                          # int32 logits
            q, k, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.int32).astype(jnp.float32)
        # absmax-barrier dequant: logits scaled by per-token q/k scales
        s = s * qs_ref[...] * ks_ref[...].reshape(1, bk) * softmax_scale

        if causal:
            q_pos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
            if window:
                s = jnp.where(q_pos - k_pos < window, s, NEG_INF)

        m_prev = m_ref[...]                               # [bq, 128] (lanes ==)
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)        # [bq, 1]
        m_new = jnp.maximum(m_prev, m_cur)                # broadcast → [bq,128]
        alpha = jnp.exp(m_prev - m_new)                   # rescale factor
        p = jnp.exp(s - m_new[:, :1])                     # [bq, bk]
        l_new = l_prev * alpha + jnp.sum(p, -1, keepdims=True)
        # accumulate P·(V·v_scale) in f32 (V dequantized in-tile)
        v = v_ref[...].astype(jnp.float32) * vs_ref[...]  # [bk, d]
        acc_ref[...] = acc_ref[...] * alpha[:, :1] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(j == n_k - 1)
    def _flush():
        o_ref[...] = (acc_ref[...] / l_ref[:, :1]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("softmax_scale", "causal",
                                             "window", "bq", "bk",
                                             "interpret"))
def int8_flash_prefill(q, k, v, q_scale, k_scale, v_scale, *,
                       softmax_scale: float, causal: bool = True,
                       window: int = 0, bq: int = DEFAULT_BQ,
                       bk: int = DEFAULT_BK,
                       interpret: bool = False) -> jax.Array:
    """q/k/v int8 [s, d]; *_scale f32 [s, 1] → f32 [s, d].

    s must be a multiple of the block sizes (ops.py pads); scales are the
    per-token absmax scales from the quantization barrier. ``window > 0``
    adds a sliding-window causal mask (SWA).
    """
    s, d = q.shape
    assert s % bq == 0 and s % bk == 0
    n_q, n_k = s // bq, s // bk

    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"))

    return pl.pallas_call(
        functools.partial(_flash_prefill_kernel, n_k=n_k, bq=bq, bk=bk,
                          softmax_scale=softmax_scale, causal=causal,
                          window=window),
        grid=(n_q, n_k),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bk, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bk, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bq, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bk, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((bk, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, d), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),   # running max (lanes equal)
            pltpu.VMEM((bq, 128), jnp.float32),   # running sum-exp
            pltpu.VMEM((bq, d), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
        **kwargs,
    )(q, k, v, q_scale, k_scale, v_scale)


# ---------------------------------------------------------------------------
# LOP block-sparse decode attention (scalar-prefetch candidate walk)
# ---------------------------------------------------------------------------

def _sparse_decode_kernel(idx_ref, gate_ref, q_ref, k_ref, v_ref, qs_ref,
                          ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref, *,
                          n_blocks: int, block: int, softmax_scale: float):
    """Grid (candidate-block b,): walks ONLY the selected KV blocks."""
    b = pl.program_id(0)

    @pl.when(b == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(gate_ref[b] > 0)
    def _tile():
        q = q_ref[...]                                    # [g, d] int8
        k = k_ref[...]                                    # [block, d] int8
        s = jax.lax.dot_general(
            q, k, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.int32).astype(jnp.float32)
        s = s * qs_ref[...] * ks_ref[...].reshape(1, block) * softmax_scale
        # in-block interval mask: [start, end) covers tokens both inside the
        # cache length (suffix cut) and inside the SWA window (prefix cut)
        end = gate_ref[n_blocks + b]
        start = gate_ref[2 * n_blocks + b]
        t = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where((t >= start) & (t < end), s, NEG_INF)

        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, :1])
        l_ref[...] = l_prev * alpha + jnp.sum(p, -1, keepdims=True)
        v = v_ref[...].astype(jnp.float32) * vs_ref[...]
        acc_ref[...] = acc_ref[...] * alpha[:, :1] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(b == n_blocks - 1)
    def _flush():
        l = l_ref[:, :1]
        o_ref[...] = (acc_ref[...] /
                      jnp.where(l > 0, l, 1.0)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "softmax_scale",
                                             "interpret"))
def sparse_decode_attention(q, k_cache, v_cache, q_scale, k_scale, v_scale,
                            block_idx, gate_tokens, *, block: int,
                            softmax_scale: float,
                            interpret: bool = False) -> jax.Array:
    """One-token decode over the LOP-selected candidate blocks.

    q           int8  [g, d]        (g = q-heads sharing this kv head)
    k/v_cache   int8  [m, d]        (m = cache capacity, block-aligned)
    q_scale     f32   [g, 1]        per-head absmax scale of the new query
    k/v_scale   f32   [m, 1]        per-token absmax scales
    block_idx   int32 [nb]          selected block ids (from comparison-free
                                    top-K); walked in-order by the grid
    gate_tokens int32 [3*nb]        [gate(0/1) ‖ end ‖ start] per block —
                                    scalar-prefetch operand; tokens
                                    [start, end) inside each block are live
    → f32 [g, d]
    """
    g, d = q.shape
    m = k_cache.shape[0]
    nb = block_idx.shape[0]
    assert m % block == 0

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((g, d), lambda b, idx, gt: (0, 0)),
            pl.BlockSpec((block, d), lambda b, idx, gt: (idx[b], 0)),
            pl.BlockSpec((block, d), lambda b, idx, gt: (idx[b], 0)),
            pl.BlockSpec((g, 1), lambda b, idx, gt: (0, 0)),
            pl.BlockSpec((block, 1), lambda b, idx, gt: (idx[b], 0)),
            pl.BlockSpec((block, 1), lambda b, idx, gt: (idx[b], 0)),
        ],
        out_specs=pl.BlockSpec((g, d), lambda b, idx, gt: (0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_sparse_decode_kernel, n_blocks=nb, block=block,
                          softmax_scale=softmax_scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((g, d), jnp.float32),
        interpret=interpret,
    )(block_idx, gate_tokens, q, k_cache, v_cache, q_scale, k_scale, v_scale)
