"""Pure-jnp oracles for every Pallas kernel in this package."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.lop import features_to_pot, pot, unpack_features
from repro.core.ternary import unpack_ternary

NEG_INF = -1e30


def ternary_matmul_ref(x: jax.Array, packed: jax.Array,
                       k: int) -> jax.Array:
    """int8 x [m, k] @ packed-2bit ternary w [k//4, n] → int32 [m, n]."""
    w = unpack_ternary(packed, k)
    return jax.lax.dot(x, w, preferred_element_type=jnp.int32)


def lop_scores_ref(q_pot: jax.Array, packed_feat: jax.Array) -> jax.Array:
    """Surrogate scores from the packed feature cache.

    q_pot int8 [g, d] (already pot-rounded); packed_feat uint8 [m, d//2]
    → int32 [g, m].
    """
    kp = features_to_pot(unpack_features(packed_feat))       # [m, d] int8
    return jax.lax.dot(q_pot, kp.T, preferred_element_type=jnp.int32)


def flash_prefill_ref(q, k, v, q_scale, k_scale, v_scale, *,
                      softmax_scale: float, causal: bool = True,
                      window: int = 0) -> jax.Array:
    """Dense (causal/SWA) int8 attention oracle with per-token absmax scales.

    q/k/v int8 [s, d]; scales f32 [s, 1] → f32 [s, d].
    """
    s = q.shape[0]
    logits = jax.lax.dot(q, k.T,
                         preferred_element_type=jnp.int32).astype(jnp.float32)
    logits = logits * q_scale * k_scale.reshape(1, s) * softmax_scale
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    if causal:
        logits = jnp.where(qpos >= kpos, logits, NEG_INF)
        if window:
            logits = jnp.where(qpos - kpos < window, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.dot(p, v.astype(jnp.float32) * v_scale)


def sparse_decode_attention_ref(q, k_cache, v_cache, q_scale, k_scale,
                                v_scale, block_idx, gate_tokens, *,
                                block: int, softmax_scale: float) -> jax.Array:
    """Block-sparse decode attention oracle (mirrors the kernel contract).

    q int8 [g, d]; caches int8 [m, d]; scales f32 per the kernel;
    block_idx int32 [nb]; gate_tokens int32 [3*nb] = [gate ‖ end ‖ start].
    Exact softmax over the union of gated, in-interval tokens.
    """
    m, d = k_cache.shape
    nb = block_idx.shape[0]
    gate = gate_tokens[:nb] > 0
    end = gate_tokens[nb:2 * nb]
    start = gate_tokens[2 * nb:]
    kb = k_cache.reshape(m // block, block, d)
    vb = v_cache.reshape(m // block, block, d)
    ksb = k_scale.reshape(m // block, block, 1)
    vsb = v_scale.reshape(m // block, block, 1)
    k_sel = kb[block_idx].reshape(nb * block, d)
    v_sel = vb[block_idx].reshape(nb * block, d)
    ks_sel = ksb[block_idx].reshape(nb * block, 1)
    vs_sel = vsb[block_idx].reshape(nb * block, 1)
    t = jnp.arange(block)[None, :]
    tok_in = (t >= start[:, None]) & (t < end[:, None])
    valid = (tok_in & gate[:, None]).reshape(nb * block)

    logits = jax.lax.dot(q, k_sel.T,
                         preferred_element_type=jnp.int32).astype(jnp.float32)
    logits = logits * q_scale * ks_sel.reshape(1, -1) * softmax_scale
    logits = jnp.where(valid[None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.dot(p, v_sel.astype(jnp.float32) * vs_sel)
