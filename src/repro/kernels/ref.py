"""Pure-jnp oracles for every Pallas kernel in this package."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.lop import features_to_pot, pot, unpack_features
from repro.core.ternary import unpack_ternary

NEG_INF = -1e30
INT32_MIN = jnp.iinfo(jnp.int32).min


def ternary_matmul_ref(x: jax.Array, packed: jax.Array,
                       k: int) -> jax.Array:
    """int8 x [m, k] @ packed-2bit ternary w [k//4, n] → int32 [m, n]."""
    w = unpack_ternary(packed, k)
    return jax.lax.dot(x, w, preferred_element_type=jnp.int32)


def _unpack_any(packed: jax.Array, k: int) -> jax.Array:
    """unpack_ternary over optional leading (expert/layer) dims."""
    if packed.ndim == 2:
        return unpack_ternary(packed, k)
    lead = packed.shape[:-2]
    flat = packed.reshape((-1,) + packed.shape[-2:])
    w = jax.vmap(lambda p: unpack_ternary(p, k))(flat)
    return w.reshape(lead + (k, packed.shape[-1]))


def qlinear_ref(x, packed, scale, bias=None, *, act=None):
    """Oracle of the fused TINT projection (kernels/qlinear.fused_qlinear).

    The unfused chain written out: absmax barrier → integer GEMM →
    dequant by (x-scale · per-column γ) → bias → activation. x f32/bf16
    [..., k] with packed [k//4, n], or the grouped-expert form x
    [E, C, k] with packed [E, k//4, n] — the latter replaces the
    per-expert vmap with one batched contraction. scale f32 [..., 1, n]
    per-column γ row. → f32 [..., n].
    """
    from repro.core.quantization import quantize
    from repro.kernels.qlinear import apply_act

    k = packed.shape[-2] * 4
    xq = quantize(x)
    w = _unpack_any(packed, k)
    if packed.ndim == 2:
        acc = jax.lax.dot_general(
            xq.values, w,
            dimension_numbers=(((x.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
    else:
        acc = jnp.einsum("eck,ekn->ecn", xq.values, w,
                         preferred_element_type=jnp.int32)
    y = acc.astype(jnp.float32) * xq.scale * scale
    if bias is not None:
        y = y + bias
    return apply_act(y, act)


def ffn_fused_ref(x, gu_packed, gu_scale, down_packed, down_scale, *,
                  gated: bool, act: str):
    """Oracle of the one-launch FFN (kernels/qlinear.fused_ffn).

    h = act(x·Wg)·(x·Wu) (or act(x·Wu) ungated), then the hidden vector
    crosses its own absmax barrier before the down projection — exactly
    the unfused silu(qlinear(g,x))·qlinear(u,x) → qlinear(d,h) chain.
    """
    from repro.kernels.qlinear import apply_act

    f = down_packed.shape[-2] * 4
    h_all = qlinear_ref(x, gu_packed, gu_scale)
    if gated:
        h = apply_act(h_all[..., :f], act) * h_all[..., f:]
    else:
        h = apply_act(h_all, act)
    return qlinear_ref(h, down_packed, down_scale)


def lop_scores_ref(q_pot: jax.Array, packed_feat: jax.Array) -> jax.Array:
    """Surrogate scores from the packed feature cache.

    q_pot int8 [g, d] (already pot-rounded); packed_feat uint8 [m, d//2]
    → int32 [g, m].
    """
    kp = features_to_pot(unpack_features(packed_feat))       # [m, d] int8
    return jax.lax.dot(q_pot, kp.T, preferred_element_type=jnp.int32)


def flash_prefill_ref(q, k, v, q_scale, k_scale, v_scale, *,
                      softmax_scale: float, causal: bool = True,
                      window: int = 0) -> jax.Array:
    """Dense (causal/SWA) int8 attention oracle with per-token absmax scales.

    q/k/v int8 [s, d]; scales f32 [s, 1] → f32 [s, d].
    """
    s = q.shape[0]
    logits = jax.lax.dot(q, k.T,
                         preferred_element_type=jnp.int32).astype(jnp.float32)
    logits = logits * q_scale * k_scale.reshape(1, s) * softmax_scale
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    if causal:
        logits = jnp.where(qpos >= kpos, logits, NEG_INF)
        if window:
            logits = jnp.where(qpos - kpos < window, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.dot(p, v.astype(jnp.float32) * v_scale)


def prefill_attention_ref(qi, qsc, k_cache, v_cache, k_scale, v_scale,
                          kv_len, q_off=0, *, causal: bool = True,
                          window: int = 0, softmax_scale: float,
                          int8_logits: bool = False,
                          chunk: int = 256) -> jax.Array:
    """Batched GQA prefill-chunk attention oracle (streamed over q chunks).

    qi int8 [B, H, C, dh]; qsc f32 [B, H, C]; caches int8/f32
    [B, Hkv, M, ...]; kv_len int32 [B]; ``q_off`` (scalar, may be traced)
    is the global position of query column 0. → f32 [B, H, C, dh].

    Per query row the masked logits, the guarded softmax and the
    normalization are independent of C and of every other row, so running
    a prompt through this oracle in chunks against the same
    capacity-padded cache is *bitwise* identical to one whole-prompt call
    — the invariant the chunked scheduler's token-exactness rests on
    (DESIGN.md §Chunked-prefill). ``int8_logits`` keeps QKᵀ in the
    integer domain (int8×int8→int32, BoothFlex-faithful); the default
    dequantizes K once and streams f32 MXU dots. The inner scan over
    query chunks (``REPRO_ATTN_CHUNK`` raises it for accounting probes)
    bounds the materialized logits to [B, H, chunk, M] at dry-run shapes.
    """
    import os

    from repro.distributed.partitioning import shard
    from repro.models.attention import _model_axis_size
    from repro.models.scan_utils import accounting_unroll

    b, h, sq, dh = qi.shape
    hkv, m = k_cache.shape[1], k_cache.shape[2]
    chunk = min(int(os.environ.get("REPRO_ATTN_CHUNK", chunk)), sq)
    if hkv != h:
        rep = h // hkv
        # repeat K/V to the flat H dim so TP head sharding survives (see
        # models/attention.py); with non-divisible H the q chunks SP-shard
        k_cache = jnp.repeat(k_cache, rep, axis=1)
        v_cache = jnp.repeat(v_cache, rep, axis=1)
        k_scale = jnp.repeat(k_scale, rep, axis=1)
        v_scale = jnp.repeat(v_scale, rep, axis=1)
    head_sharded = h % _model_axis_size() == 0

    pad = (-sq) % chunk
    if pad:
        qi = jnp.pad(qi, ((0, 0), (0, 0), (0, pad), (0, 0)))
        qsc = jnp.pad(qsc, ((0, 0), (0, 0), (0, pad)))
    nc = qi.shape[2] // chunk
    qg = qi.reshape(b, h, nc, chunk, dh).transpose(2, 0, 1, 3, 4)
    qsg = qsc.reshape(b, h, nc, chunk).transpose(2, 0, 1, 3)

    kpos = jnp.arange(m)
    vf = v_cache.astype(jnp.float32) * v_scale[..., None]
    # Both QKᵀ branches dequantize AFTER the dot: int8 products summed in
    # f32 stay exact below 2²⁴ (|s| ≤ 127²·dh), so the f32 branch is
    # bitwise identical to the int32 branch on CPU — the flag only picks
    # the MXU datapath (int8 2× throughput) on real TPUs. Scaling before
    # the dot would differ at ~1e-7, which repeated absmax requantization
    # across layers can amplify into a rounding flip (knife-edge).
    kk = k_cache if int8_logits else k_cache.astype(jnp.float32)
    if head_sharded:
        kk = shard(kk, "dp", "tp", None, None)
        vf = shard(vf, "dp", "tp", None, None)

    def body(_, args):
        qc, qsc_c, ci = args                             # [B, H, C, dh]
        if head_sharded:
            qc = shard(qc, "dp", "tp", None, None)
        else:
            qc = shard(qc, "dp", None, "sp", None)
        if int8_logits:
            s = jnp.einsum("bhcd,bhmd->bhcm", qc, kk,
                           preferred_element_type=jnp.int32)
            s = s.astype(jnp.float32)
        else:
            s = jnp.einsum("bhcd,bhmd->bhcm", qc.astype(jnp.float32), kk,
                           preferred_element_type=jnp.float32)
        s = s * k_scale[:, :, None, :] * qsc_c[..., None] * softmax_scale
        qpos = q_off + ci * chunk + jnp.arange(chunk)
        mask = kpos[None, None, :] < kv_len[:, None, None]   # [B, C, M]
        if causal:
            mask &= qpos[None, :, None] >= kpos[None, None, :]
            if window:
                mask &= (qpos[None, :, None] - kpos[None, None, :]) < window
        s = jnp.where(mask[:, None], s, NEG_INF)
        # guarded softmax: fully-masked rows emit exact zero, matching the
        # kernel's ℓ > 0 flush guard
        mx = jnp.max(s, axis=-1, keepdims=True)
        p = jnp.exp(s - jnp.maximum(mx, -1e29))
        p = jnp.where(s <= NEG_INF / 2, 0.0, p)
        l = jnp.sum(p, axis=-1, keepdims=True)
        o = jnp.einsum("bhcm,bhmd->bhcd", p, vf)
        return None, o / jnp.where(l > 0, l, 1.0)

    _, oc = jax.lax.scan(body, None, (qg, qsg, jnp.arange(nc)),
                         unroll=accounting_unroll(nc))
    o = oc.transpose(1, 2, 0, 3, 4).reshape(b, h, nc * chunk, dh)
    return o[:, :, :sq]


def sparse_decode_attention_ref(q, k_cache, v_cache, q_scale, k_scale,
                                v_scale, block_idx, gate_tokens, *,
                                block: int, softmax_scale: float) -> jax.Array:
    """Block-sparse decode attention oracle (mirrors the kernel contract).

    q int8 [g, d]; caches int8 [m, d]; scales f32 per the kernel;
    block_idx int32 [nb]; gate_tokens int32 [3*nb] = [gate ‖ end ‖ start].
    Exact softmax over the union of gated, in-interval tokens.
    """
    m, d = k_cache.shape
    nb = block_idx.shape[0]
    gate = gate_tokens[:nb] > 0
    end = gate_tokens[nb:2 * nb]
    start = gate_tokens[2 * nb:]
    kb = k_cache.reshape(m // block, block, d)
    vb = v_cache.reshape(m // block, block, d)
    ksb = k_scale.reshape(m // block, block, 1)
    vsb = v_scale.reshape(m // block, block, 1)
    k_sel = kb[block_idx].reshape(nb * block, d)
    v_sel = vb[block_idx].reshape(nb * block, d)
    ks_sel = ksb[block_idx].reshape(nb * block, 1)
    vs_sel = vsb[block_idx].reshape(nb * block, 1)
    t = jnp.arange(block)[None, :]
    tok_in = (t >= start[:, None]) & (t < end[:, None])
    valid = (tok_in & gate[:, None]).reshape(nb * block)

    logits = jax.lax.dot(q, k_sel.T,
                         preferred_element_type=jnp.int32).astype(jnp.float32)
    logits = logits * q_scale * ks_sel.reshape(1, -1) * softmax_scale
    logits = jnp.where(valid[None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.dot(p, v_sel.astype(jnp.float32) * vs_sel)


# ---------------------------------------------------------------------------
# Fused batched decode attention (oracle for kernels/decode_attention.py)
# ---------------------------------------------------------------------------

def _gather_blocks(arr, idx, block):
    """arr [B,Hkv,M,...] , idx [B,Hkv,G',K] → [B,Hkv,G',K·block,...]."""
    b, hkv, m = arr.shape[:3]
    k = idx.shape[-1]
    blocks = arr.reshape(b, hkv, m // block, block, *arr.shape[3:])

    def per_bh(blocks_bh, idx_bh):                       # [NB,block,...],[G,K]
        return blocks_bh[idx_bh]                         # [G,K,block,...]

    out = jax.vmap(jax.vmap(per_bh))(blocks, idx)
    return out.reshape(b, hkv, idx.shape[2], k * block, *arr.shape[3:])


def _stats_to_out(m, l, acc, b, h, dh, return_stats):
    out = (acc / jnp.where(l > 0, l, 1.0)).reshape(b, h, dh)
    if return_stats:
        return out, m.reshape(b, h, 1), l.reshape(b, h, 1)
    return out


def decode_attention_ref(qi, qsc, k_cache, v_cache, k_scale, v_scale, feat,
                         new_len, *, block: int, k_keep: int, window: int,
                         softmax_scale: float, use_lop: bool = True,
                         shared_select: bool = False, pos_offset=None,
                         return_stats: bool = False):
    """Batched decode-attention oracle (screen → select → exact, or dense).

    qi int8 [B,H,dh]; qsc f32 [B,H,1]; caches int8/f32 [B,Hkv,M,...];
    feat uint8 [B,Hkv,M,dh//2]; new_len int32 [B] (0 = retired lane —
    those rows emit exactly zero); ``pos_offset`` maps cache row 0 to a
    global token position (SP shards; must be block-aligned).
    → f32 [B,H,dh]; with ``return_stats`` also the unnormalized softmax
    (m, ℓ) f32 [B,H,1] for the flash-decoding shard merge.
    """
    from repro.serving.lop_select import select_blocks, token_valid_mask

    b, h, dh = qi.shape
    hkv, m = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    po = 0 if pos_offset is None else pos_offset
    qg = qi.reshape(b, hkv, g, dh)
    qs = qsc.reshape(b, hkv, g, 1)

    if not use_lop:
        s = jnp.einsum("bhgd,bhmd->bhgm", qg, k_cache,
                       preferred_element_type=jnp.int32).astype(jnp.float32)
        s = s * qs * k_scale[:, :, None, :] * softmax_scale
        valid = token_valid_mask(m, new_len, window, pos_offset=po)
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
        mx = jnp.max(s, axis=-1, keepdims=True)
        p = jnp.exp(s - jnp.maximum(mx, -1e29))
        p = jnp.where(s <= NEG_INF / 2, 0.0, p)
        l = jnp.sum(p, axis=-1, keepdims=True)
        vf = v_cache.astype(jnp.float32) * v_scale[..., None]
        acc = jnp.einsum("bhgm,bhmd->bhgd", p, vf)
        return _stats_to_out(mx, l, acc, b, h, dh, return_stats)

    # 1./2. screen over the packed feature cache + comparison-free top-K
    kp = features_to_pot(unpack_features(feat))          # [B,Hkv,M,dh] int8
    scores = jnp.einsum("bhgd,bhmd->bhgm", pot(qg), kp,
                        preferred_element_type=jnp.int32)
    if shared_select:
        scores = jnp.max(scores, axis=2, keepdims=True)  # [B,Hkv,1,M]
    idx, gate_tokens = select_blocks(scores, new_len, block=block,
                                     k_keep=k_keep, window=window,
                                     block_offset=po // block)

    # 3./4. gather the candidate blocks + exact masked attention stats
    gsel = idx.shape[2]
    k_sel = _gather_blocks(k_cache, idx, block)          # [B,Hkv,G',K·bl,dh]
    v_sel = _gather_blocks(v_cache, idx, block)
    ks_sel = _gather_blocks(k_scale, idx, block)         # [B,Hkv,G',K·bl]
    vs_sel = _gather_blocks(v_scale, idx, block)

    if gsel == 1:
        s = jnp.einsum("bhgd,bhkd->bhgk", qg, k_sel[:, :, 0],
                       preferred_element_type=jnp.int32).astype(jnp.float32)
        s = s * qs * ks_sel[:, :, 0][:, :, None] * softmax_scale
    else:
        s = jnp.einsum("bhgd,bhgkd->bhgk", qg, k_sel,
                       preferred_element_type=jnp.int32).astype(jnp.float32)
        s = s * qs * ks_sel * softmax_scale

    kk = idx.shape[-1]
    gate = gate_tokens[..., :kk] > 0                     # [B,Hkv,G',K]
    end = gate_tokens[..., kk:2 * kk]
    start = gate_tokens[..., 2 * kk:]
    t = jnp.arange(block)[None, None, None, None, :]
    live = ((t >= start[..., None]) & (t < end[..., None])
            & gate[..., None])                           # [B,Hkv,G',K,block]
    live = live.reshape(b, hkv, gsel, kk * block)        # broadcasts G'=1
    s = jnp.where(live, s, NEG_INF)

    mx = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - jnp.maximum(mx, -1e29))
    p = jnp.where(s <= NEG_INF / 2, 0.0, p)
    l = jnp.sum(p, axis=-1, keepdims=True)
    vf = v_sel.astype(jnp.float32) * vs_sel[..., None]
    if gsel == 1:
        acc = jnp.einsum("bhgk,bhkd->bhgd", p, vf[:, :, 0])
    else:
        acc = jnp.einsum("bhgk,bhgkd->bhgd", p, vf)
    return _stats_to_out(mx, l, acc, b, h, dh, return_stats)
