"""Fused TINT projection kernels: absmax barrier → ternary GEMM → epilogue.

The standalone pipeline (jnp absmax quantize → ``ternary_matmul``
pallas_call → jnp dequant + bias + activation) round-trips HBM three
times per projection. The paper's system integration (§III) hinges on
exactly this seam: the absmax barrier *is* the cross-core interface, so
the quantize belongs inside the same kernel that consumes the int8
vector, and the nonlinear epilogue overlaps with the linear tiles. These
kernels run the whole chain in one ``pallas_call``:

``fused_qlinear``
    grid (E, m, n): at the first n-step of every (expert, m-block) the
    f32 activation tile is absmax-quantized **in VMEM** (bitwise
    :func:`repro.core.quantization.quantize` — the same function runs
    inside the kernel body, so kernel and oracle cannot drift); every
    n-step then unpacks a 2-bit code tile, runs the int8 MXU dot, and
    applies the fused epilogue — dequant by (x-scale · per-column γ),
    bias, optional activation — before the tile ever leaves VMEM.

``fused_ffn``
    grid (E, m, n_f + n_d): the whole FFN as ONE kernel. Steps j < n_f
    stream gate/up column blocks (two code streams over the same
    activation tile), apply act(gate)·up into a [bm, f] VMEM scratch;
    step j == n_f re-runs the absmax barrier on that scratch (the
    hidden vector's cross-core interface); steps j ≥ n_f stream the
    down-projection code blocks against the re-quantized hidden tile.
    No intermediate touches HBM.

Both kernels take a leading **expert grid axis** (E = 1 for plain
linears): MoE expert stacks ride the same packed-code stream with the
expert id as a third grid coordinate, replacing the one-pallas_call-per-
expert ``vmap`` dispatch.

Tiling is decode-shaped: ``bm`` follows the true row count (multiples of
8, not 128), so a GEMV-shaped decode step (m = B ≤ 8) stops padding its
batch rows to an MXU tile — the k-reduction runs as one full-width VMEM
dot per (m, n) cell, which is what makes the in-kernel barrier exact
(the row absmax needs the whole vector before any column block starts).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quantization import quantize
from repro.kernels.ternary_matmul import _unpack_codes

DEFAULT_BN = 128


def apply_act(y: jax.Array, act: str | None) -> jax.Array:
    """Fused epilogue nonlinearity (shared by kernel and oracle)."""
    if act is None:
        return y
    if act == "silu":
        return jax.nn.silu(y)
    if act == "gelu":
        return jax.nn.gelu(y)
    if act == "squared_relu":
        r = jnp.maximum(y, 0.0)
        return r * r
    raise ValueError(f"unknown activation {act!r}")


def _barrier(x, xq_ref, xs_ref):
    """In-VMEM absmax barrier — THE quantize, running inside the kernel."""
    qt = quantize(x)
    xq_ref[...] = qt.values
    xs_ref[...] = qt.scale


# ---------------------------------------------------------------------------
# fused_qlinear: quantize → GEMM → dequant(+bias)(+act), one pallas_call
# ---------------------------------------------------------------------------

def _qlinear_kernel(x_ref, wp_ref, sc_ref, *rest, k, act, has_bias):
    b_ref = rest[0] if has_bias else None
    o_ref, xq_ref, xs_ref = rest[-3:]
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _quantize_tile():
        _barrier(x_ref[0], xq_ref, xs_ref)

    w = _unpack_codes(wp_ref[0], k)                    # [k, bn] int8
    acc = jax.lax.dot(xq_ref[...], w, preferred_element_type=jnp.int32)
    y = acc.astype(jnp.float32) * xs_ref[...] * sc_ref[0]
    if has_bias:
        y = y + b_ref[0]
    o_ref[0] = apply_act(y, act)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "act", "interpret"))
def fused_qlinear(x: jax.Array, packed: jax.Array, scale: jax.Array,
                  bias: jax.Array | None = None, *, bm: int,
                  bn: int = DEFAULT_BN, act: str | None = None,
                  interpret: bool = False) -> jax.Array:
    """f32 x [E, m, k] × packed ternary [E, k//4, n] → f32 [E, m, n].

    ``scale`` f32 [E, 1, n] is the per-column weight γ row (a plain node
    broadcasts its scalar γ; a fused-QKV node carries one γ per segment);
    ``bias`` f32 [E, 1, n] or None. E = 1 for non-expert projections —
    the expert axis is the leading grid coordinate of one launch, not a
    vmap of launches. m and n must be multiples of (bm, bn); ops.py pads
    m and picks bn to divide n.
    """
    e, m, k = x.shape
    n = packed.shape[-1]
    assert packed.shape[-2] * 4 == k, (packed.shape, k)
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)

    has_bias = bias is not None
    in_specs = [
        pl.BlockSpec((1, bm, k), lambda e, i, j: (e, i, 0)),
        pl.BlockSpec((1, k // 4, bn), lambda e, i, j: (e, 0, j)),
        pl.BlockSpec((1, 1, bn), lambda e, i, j: (e, 0, j)),
    ]
    operands = [x, packed, scale]
    if has_bias:
        in_specs.append(pl.BlockSpec((1, 1, bn), lambda e, i, j: (e, 0, j)))
        operands.append(bias)

    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))

    return pl.pallas_call(
        functools.partial(_qlinear_kernel, k=k, act=act, has_bias=has_bias),
        grid=(e, m // bm, n // bn),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bm, bn), lambda e, i, j: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((e, m, n), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bm, k), jnp.int8),       # barriered activation tile
            pltpu.VMEM((bm, 1), jnp.float32),    # its absmax scales
        ],
        interpret=interpret,
        **kwargs,
    )(*operands)


# ---------------------------------------------------------------------------
# fused_ffn: act(x·Wg)·(x·Wu) → barrier → ·Wd, one pallas_call
# ---------------------------------------------------------------------------

def _ffn_kernel(x_ref, up_ref, usc_ref, *rest, k, f, bf, nf, nd, act,
                gated):
    if gated:
        g_ref, gsc_ref = rest[0], rest[1]
        rest = rest[2:]
    d_ref, dsc_ref = rest[0], rest[1]
    o_ref, xq_ref, xs_ref, h_ref, hq_ref, hs_ref = rest[2:]
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _quantize_x():
        _barrier(x_ref[0], xq_ref, xs_ref)

    # ---- gate/up phase: one hidden column block per step, into scratch ----
    @pl.when(j < nf)
    def _gate_up():
        uw = _unpack_codes(up_ref[0], k)
        u = jax.lax.dot(xq_ref[...], uw, preferred_element_type=jnp.int32)
        u = u.astype(jnp.float32) * xs_ref[...] * usc_ref[0]
        if gated:
            gw = _unpack_codes(g_ref[0], k)
            g = jax.lax.dot(xq_ref[...], gw,
                            preferred_element_type=jnp.int32)
            g = g.astype(jnp.float32) * xs_ref[...] * gsc_ref[0]
            hblk = apply_act(g, act) * u
        else:
            hblk = apply_act(u, act)
        h_ref[:, pl.ds(j * bf, bf)] = hblk

    # ---- the hidden vector's own absmax barrier, still in VMEM ----
    @pl.when(j == nf)
    def _quantize_h():
        _barrier(h_ref[...], hq_ref, hs_ref)

    # ---- down phase: re-quantized hidden tile × down code stream ----
    @pl.when(j >= nf)
    def _down():
        dw = _unpack_codes(d_ref[0], f)
        y = jax.lax.dot(hq_ref[...], dw, preferred_element_type=jnp.int32)
        o_ref[0] = y.astype(jnp.float32) * hs_ref[...] * dsc_ref[0]


@functools.partial(jax.jit, static_argnames=("bm", "bf", "bn", "act",
                                             "gated", "interpret"))
def fused_ffn(x: jax.Array, gu_packed: jax.Array, gu_scale: jax.Array,
              down_packed: jax.Array, down_scale: jax.Array, *, bm: int,
              bf: int, bn: int, act: str, gated: bool,
              interpret: bool = False) -> jax.Array:
    """The whole FFN as one launch: x [E, m, k] → f32 [E, m, d_out].

    gu_packed   uint8 [E, k//4, 2f] (gate cols ‖ up cols; [E, k//4, f]
                when not gated) — passed twice with offset index maps so
                a step's gate and up blocks stream from one array
    gu_scale    f32   [E, 1, 2f]   per-column γ rows (per-stream scalars
                broadcast at quantize_params time)
    down_packed uint8 [E, f//4, d_out]; down_scale f32 [E, 1, d_out]

    Grid (E, m//bm, f//bf + d_out//bn). The [bm, f] hidden scratch never
    leaves VMEM; its absmax barrier runs at the first down step.
    """
    e, m, k = x.shape
    f = down_packed.shape[-2] * 4
    d_out = down_packed.shape[-1]
    assert gu_packed.shape[-2] * 4 == k, (gu_packed.shape, k)
    assert gu_packed.shape[-1] == (2 * f if gated else f), \
        (gu_packed.shape, f, gated)
    assert m % bm == 0 and f % bf == 0 and d_out % bn == 0, \
        (m, f, d_out, bm, bf, bn)
    nf, nd = f // bf, d_out // bn

    def _up_idx(e, i, j):
        base = (f // bf) if gated else 0
        return (e, 0, base + jnp.minimum(j, nf - 1))

    def _down_idx(e, i, j):
        return (e, 0, jnp.clip(j - nf, 0, nd - 1))

    in_specs = [
        pl.BlockSpec((1, bm, k), lambda e, i, j: (e, i, 0)),
        pl.BlockSpec((1, k // 4, bf), _up_idx),
        pl.BlockSpec((1, 1, bf), _up_idx),
    ]
    operands = [x, gu_packed, gu_scale]
    if gated:
        gate_idx = lambda e, i, j: (e, 0, jnp.minimum(j, nf - 1))
        in_specs += [pl.BlockSpec((1, k // 4, bf), gate_idx),
                     pl.BlockSpec((1, 1, bf), gate_idx)]
        operands += [gu_packed, gu_scale]
    in_specs += [pl.BlockSpec((1, f // 4, bn), _down_idx),
                 pl.BlockSpec((1, 1, bn), _down_idx)]
    operands += [down_packed, down_scale]

    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))

    return pl.pallas_call(
        functools.partial(_ffn_kernel, k=k, f=f, bf=bf, nf=nf, nd=nd,
                          act=act, gated=gated),
        grid=(e, m // bm, nf + nd),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bm, bn), _down_idx),
        out_shape=jax.ShapeDtypeStruct((e, m, d_out), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bm, k), jnp.int8),       # barriered activation
            pltpu.VMEM((bm, 1), jnp.float32),
            pltpu.VMEM((bm, f), jnp.float32),    # hidden act(g)·u scratch
            pltpu.VMEM((bm, f), jnp.int8),       # its barriered form
            pltpu.VMEM((bm, 1), jnp.float32),
        ],
        interpret=interpret,
        **kwargs,
    )(*operands)
