"""Fused TINT projection kernels: absmax barrier → ternary GEMM → epilogue.

The standalone pipeline (jnp absmax quantize → ``ternary_matmul``
pallas_call → jnp dequant + bias + activation) round-trips HBM three
times per projection. The paper's system integration (§III) hinges on
exactly this seam: the absmax barrier *is* the cross-core interface, so
the quantize belongs inside the same kernel that consumes the int8
vector, and the nonlinear epilogue overlaps with the linear tiles. These
kernels run the whole chain in one ``pallas_call``:

``fused_qlinear``
    grid (E, m, n): at the first n-step of every (expert, m-block) the
    f32 activation tile is absmax-quantized **in VMEM** (bitwise
    :func:`repro.core.quantization.quantize` — the same function runs
    inside the kernel body, so kernel and oracle cannot drift); every
    n-step then unpacks a 2-bit code tile, runs the int8 MXU dot, and
    applies the fused epilogue — dequant by (x-scale · per-column γ),
    bias, optional activation — before the tile ever leaves VMEM.

``fused_ffn``
    grid (E, m, n_f + n_d): the whole FFN as ONE kernel. Steps j < n_f
    stream gate/up column blocks (two code streams over the same
    activation tile), apply act(gate)·up into a [bm, f] VMEM scratch;
    step j == n_f re-runs the absmax barrier on that scratch (the
    hidden vector's cross-core interface); steps j ≥ n_f stream the
    down-projection code blocks against the re-quantized hidden tile.
    No intermediate touches HBM.

Both kernels take a leading **expert grid axis** (E = 1 for plain
linears): MoE expert stacks ride the same packed-code stream with the
expert id as a third grid coordinate, replacing the one-pallas_call-per-
expert ``vmap`` dispatch.

Tiling is decode-shaped: ``bm`` follows the true row count (multiples of
8, not 128), so a GEMV-shaped decode step (m = B ≤ 8) stops padding its
batch rows to an MXU tile — the k-reduction runs as one full-width VMEM
dot per (m, n) cell, which is what makes the in-kernel barrier exact
(the row absmax needs the whole vector before any column block starts).

Sweepable variants (DESIGN.md §Autotuning)
------------------------------------------
``bkq`` — the two-pass k-tiled barrier. With ``bkq > 0`` the f32
activation tile is never fully VMEM-resident: the grid grows a leading
2·(k//bkq) streaming prefix whose first pass folds per-k-tile absmaxes
into the row maximum (f32 max is exact, so the tiled max IS the global
max) and whose second pass quantizes k-tiles into the int8 scratch the
GEMM steps consume. Because the scale and every rounded element are
identical, the variant is **bitwise** the single-pass barrier — it
lifts the d_model-beyond-VMEM limit ROADMAP carried since PR 4.
``eg`` — expert-group blocking: the expert grid axis steps ``eg``
experts per launch step (block (eg, bm, ·)), trading grid length for
per-step VMEM. Per-expert math is untouched, so any ``eg`` dividing E
is bitwise ``eg = 1``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quantization import EPS, INT8_MAX, quantize
from repro.kernels.ternary_matmul import _unpack_codes

DEFAULT_BN = 128


def apply_act(y: jax.Array, act: str | None) -> jax.Array:
    """Fused epilogue nonlinearity (shared by kernel and oracle)."""
    if act is None:
        return y
    if act == "silu":
        return jax.nn.silu(y)
    if act == "gelu":
        return jax.nn.gelu(y)
    if act == "squared_relu":
        r = jnp.maximum(y, 0.0)
        return r * r
    raise ValueError(f"unknown activation {act!r}")


def _barrier(x, xq_ref, xs_ref):
    """In-VMEM absmax barrier — THE quantize, running inside the kernel."""
    qt = quantize(x)
    xq_ref[...] = qt.values
    xs_ref[...] = qt.scale


def _tiled_barrier_phases(j, nk, bkq, x_tile, xq_ref, xs_ref, am_ref):
    """The two-pass k-tiled barrier, shared by both fused kernels.

    Steps j < nk fold per-tile absmaxes into the running row maximum
    (f32 max is exact, so the folded max IS ``absmax_scale``'s global
    reduction); step j == nk freezes the scale; steps nk ≤ j < 2·nk
    round each k-tile with that frozen scale — element for element the
    same divide/round/clip :func:`repro.core.quantization.quantize`
    runs, so the variant is bitwise the single-pass barrier.
    """
    @pl.when(j == 0)
    def _init_amax():
        am_ref[...] = jnp.zeros_like(am_ref)

    @pl.when(j < nk)
    def _fold_absmax():
        am_ref[...] = jnp.maximum(
            am_ref[...], jnp.max(jnp.abs(x_tile()), -1, keepdims=True))

    @pl.when(j == nk)
    def _freeze_scale():
        xs_ref[...] = (jnp.maximum(am_ref[...], EPS).astype(jnp.float32)
                       / INT8_MAX)

    @pl.when(jnp.logical_and(j >= nk, j < 2 * nk))
    def _quantize_tile():
        q = jnp.clip(jnp.round(x_tile().astype(jnp.float32) / xs_ref[...]),
                     -INT8_MAX, INT8_MAX)
        xq_ref[:, pl.ds((j - nk) * bkq, bkq)] = q.astype(jnp.int8)


# ---------------------------------------------------------------------------
# fused_qlinear: quantize → GEMM → dequant(+bias)(+act), one pallas_call
# ---------------------------------------------------------------------------

def _qlinear_kernel(x_ref, wp_ref, sc_ref, *rest, k, bkq, nk, eg, act,
                    has_bias):
    b_ref = rest[0] if has_bias else None
    if bkq:
        o_ref, xq_ref, xs_ref, am_ref = rest[-4:]
    else:
        o_ref, xq_ref, xs_ref = rest[-3:]
    j = pl.program_id(2)
    nk2 = 2 * nk

    if bkq:
        for t in range(eg):
            _tiled_barrier_phases(j, nk, bkq, lambda t=t: x_ref[t],
                                  xq_ref.at[t], xs_ref.at[t], am_ref.at[t])
    else:
        @pl.when(j == 0)
        def _quantize_tile():
            for t in range(eg):
                _barrier(x_ref[t], xq_ref.at[t], xs_ref.at[t])

    @pl.when(j >= nk2)
    def _gemm():
        for t in range(eg):
            w = _unpack_codes(wp_ref[t], k)                # [k, bn] int8
            acc = jax.lax.dot(xq_ref[t], w,
                              preferred_element_type=jnp.int32)
            y = acc.astype(jnp.float32) * xs_ref[t] * sc_ref[t]
            if has_bias:
                y = y + b_ref[t]
            o_ref[t] = apply_act(y, act)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bkq", "eg", "act",
                                             "interpret"))
def fused_qlinear(x: jax.Array, packed: jax.Array, scale: jax.Array,
                  bias: jax.Array | None = None, *, bm: int,
                  bn: int = DEFAULT_BN, bkq: int = 0, eg: int = 1,
                  act: str | None = None,
                  interpret: bool = False) -> jax.Array:
    """f32 x [E, m, k] × packed ternary [E, k//4, n] → f32 [E, m, n].

    ``scale`` f32 [E, 1, n] is the per-column weight γ row (a plain node
    broadcasts its scalar γ; a fused-QKV node carries one γ per segment);
    ``bias`` f32 [E, 1, n] or None. E = 1 for non-expert projections —
    the expert axis is the leading grid coordinate of one launch, not a
    vmap of launches. m and n must be multiples of (bm, bn); ops.py pads
    m and picks bn to divide n.

    ``bkq`` > 0 (a divisor of k) streams the barrier as the two-pass
    k-tiled variant — the f32 activation enters VMEM in [bm, bkq] tiles
    only; ``eg`` (a divisor of E) groups that many experts per grid
    step. Both are pure tiling knobs (DESIGN.md §Autotuning): any legal
    setting is bitwise ``bkq=0, eg=1``.
    """
    e, m, k = x.shape
    n = packed.shape[-1]
    assert packed.shape[-2] * 4 == k, (packed.shape, k)
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)
    assert e % eg == 0, (e, eg)
    assert bkq == 0 or k % bkq == 0, (k, bkq)
    nk = k // bkq if bkq else 0
    nk2 = 2 * nk
    nd = n // bn

    def _kidx(j):
        if not bkq:
            return 0
        return jnp.clip(jnp.where(j < nk, j, j - nk), 0, nk - 1)

    def _nidx(j):
        return jnp.clip(j - nk2, 0, nd - 1) if bkq else j

    has_bias = bias is not None
    in_specs = [
        pl.BlockSpec((eg, bm, bkq if bkq else k),
                     lambda e, i, j: (e, i, _kidx(j))),
        pl.BlockSpec((eg, k // 4, bn), lambda e, i, j: (e, 0, _nidx(j))),
        pl.BlockSpec((eg, 1, bn), lambda e, i, j: (e, 0, _nidx(j))),
    ]
    operands = [x, packed, scale]
    if has_bias:
        in_specs.append(pl.BlockSpec((eg, 1, bn),
                                     lambda e, i, j: (e, 0, _nidx(j))))
        operands.append(bias)

    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))

    scratch_shapes = [
        pltpu.VMEM((eg, bm, k), jnp.int8),       # barriered activation tile
        pltpu.VMEM((eg, bm, 1), jnp.float32),    # its absmax scales
    ]
    if bkq:
        scratch_shapes.append(pltpu.VMEM((eg, bm, 1), jnp.float32))

    return pl.pallas_call(
        functools.partial(_qlinear_kernel, k=k, bkq=bkq, nk=nk, eg=eg,
                          act=act, has_bias=has_bias),
        grid=(e // eg, m // bm, nk2 + nd),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((eg, bm, bn), lambda e, i, j: (e, i, _nidx(j))),
        out_shape=jax.ShapeDtypeStruct((e, m, n), jnp.float32),
        scratch_shapes=scratch_shapes,
        interpret=interpret,
        **kwargs,
    )(*operands)


# ---------------------------------------------------------------------------
# fused_ffn: act(x·Wg)·(x·Wu) → barrier → ·Wd, one pallas_call
# ---------------------------------------------------------------------------

def _ffn_kernel(x_ref, up_ref, usc_ref, *rest, k, f, bf, bkq, nk, nf, nd,
                act, gated):
    if gated:
        g_ref, gsc_ref = rest[0], rest[1]
        rest = rest[2:]
    d_ref, dsc_ref = rest[0], rest[1]
    if bkq:
        (o_ref, xq_ref, xs_ref, h_ref, hq_ref, hs_ref,
         am_ref) = rest[2:]
    else:
        o_ref, xq_ref, xs_ref, h_ref, hq_ref, hs_ref = rest[2:]
    j = pl.program_id(2)
    nk2 = 2 * nk

    if bkq:
        _tiled_barrier_phases(j, nk, bkq, lambda: x_ref[0],
                              xq_ref, xs_ref, am_ref)
    else:
        @pl.when(j == 0)
        def _quantize_x():
            _barrier(x_ref[0], xq_ref, xs_ref)

    # ---- gate/up phase: one hidden column block per step, into scratch ----
    @pl.when(jnp.logical_and(j >= nk2, j < nk2 + nf))
    def _gate_up():
        uw = _unpack_codes(up_ref[0], k)
        u = jax.lax.dot(xq_ref[...], uw, preferred_element_type=jnp.int32)
        u = u.astype(jnp.float32) * xs_ref[...] * usc_ref[0]
        if gated:
            gw = _unpack_codes(g_ref[0], k)
            g = jax.lax.dot(xq_ref[...], gw,
                            preferred_element_type=jnp.int32)
            g = g.astype(jnp.float32) * xs_ref[...] * gsc_ref[0]
            hblk = apply_act(g, act) * u
        else:
            hblk = apply_act(u, act)
        h_ref[:, pl.ds((j - nk2) * bf, bf)] = hblk

    # ---- the hidden vector's own absmax barrier, still in VMEM ----
    @pl.when(j == nk2 + nf)
    def _quantize_h():
        _barrier(h_ref[...], hq_ref, hs_ref)

    # ---- down phase: re-quantized hidden tile × down code stream ----
    @pl.when(j >= nk2 + nf)
    def _down():
        dw = _unpack_codes(d_ref[0], f)
        y = jax.lax.dot(hq_ref[...], dw, preferred_element_type=jnp.int32)
        o_ref[0] = y.astype(jnp.float32) * hs_ref[...] * dsc_ref[0]


@functools.partial(jax.jit, static_argnames=("bm", "bf", "bn", "bkq", "act",
                                             "gated", "interpret"))
def fused_ffn(x: jax.Array, gu_packed: jax.Array, gu_scale: jax.Array,
              down_packed: jax.Array, down_scale: jax.Array, *, bm: int,
              bf: int, bn: int, bkq: int = 0, act: str, gated: bool,
              interpret: bool = False) -> jax.Array:
    """The whole FFN as one launch: x [E, m, k] → f32 [E, m, d_out].

    gu_packed   uint8 [E, k//4, 2f] (gate cols ‖ up cols; [E, k//4, f]
                when not gated) — passed twice with offset index maps so
                a step's gate and up blocks stream from one array
    gu_scale    f32   [E, 1, 2f]   per-column γ rows (per-stream scalars
                broadcast at quantize_params time)
    down_packed uint8 [E, f//4, d_out]; down_scale f32 [E, 1, d_out]

    Grid (E, m//bm, f//bf + d_out//bn), with a 2·(k//bkq)-step two-pass
    barrier prefix when ``bkq`` > 0 (bitwise ``bkq=0``; the *hidden*
    barrier stays single-pass — its [bm, f] scratch lives in VMEM
    either way). The hidden scratch never leaves VMEM; its absmax
    barrier runs at the first down step.
    """
    e, m, k = x.shape
    f = down_packed.shape[-2] * 4
    d_out = down_packed.shape[-1]
    assert gu_packed.shape[-2] * 4 == k, (gu_packed.shape, k)
    assert gu_packed.shape[-1] == (2 * f if gated else f), \
        (gu_packed.shape, f, gated)
    assert m % bm == 0 and f % bf == 0 and d_out % bn == 0, \
        (m, f, d_out, bm, bf, bn)
    assert bkq == 0 or k % bkq == 0, (k, bkq)
    nf, nd = f // bf, d_out // bn
    nk = k // bkq if bkq else 0
    nk2 = 2 * nk

    def _x_idx(e, i, j):
        if not bkq:
            return (e, i, 0)
        return (e, i, jnp.clip(jnp.where(j < nk, j, j - nk), 0, nk - 1))

    def _up_idx(e, i, j):
        base = (f // bf) if gated else 0
        return (e, 0, base + jnp.clip(j - nk2, 0, nf - 1))

    def _down_idx(e, i, j):
        return (e, 0, jnp.clip(j - nk2 - nf, 0, nd - 1))

    in_specs = [
        pl.BlockSpec((1, bm, bkq if bkq else k), _x_idx),
        pl.BlockSpec((1, k // 4, bf), _up_idx),
        pl.BlockSpec((1, 1, bf), _up_idx),
    ]
    operands = [x, gu_packed, gu_scale]
    if gated:
        gate_idx = lambda e, i, j: (e, 0, jnp.clip(j - nk2, 0, nf - 1))
        in_specs += [pl.BlockSpec((1, k // 4, bf), gate_idx),
                     pl.BlockSpec((1, 1, bf), gate_idx)]
        operands += [gu_packed, gu_scale]
    in_specs += [pl.BlockSpec((1, f // 4, bn), _down_idx),
                 pl.BlockSpec((1, 1, bn), _down_idx)]
    operands += [down_packed, down_scale]

    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))

    scratch_shapes = [
        pltpu.VMEM((bm, k), jnp.int8),       # barriered activation
        pltpu.VMEM((bm, 1), jnp.float32),
        pltpu.VMEM((bm, f), jnp.float32),    # hidden act(g)·u scratch
        pltpu.VMEM((bm, f), jnp.int8),       # its barriered form
        pltpu.VMEM((bm, 1), jnp.float32),
    ]
    if bkq:
        scratch_shapes.append(pltpu.VMEM((bm, 1), jnp.float32))

    return pl.pallas_call(
        functools.partial(_ffn_kernel, k=k, f=f, bf=bf, bkq=bkq, nk=nk,
                          nf=nf, nd=nd, act=act, gated=gated),
        grid=(e, m // bm, nk2 + nf + nd),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bm, bn), _down_idx),
        out_shape=jax.ShapeDtypeStruct((e, m, d_out), jnp.float32),
        scratch_shapes=scratch_shapes,
        interpret=interpret,
        **kwargs,
    )(*operands)
