"""LOP surrogate-score Pallas kernel (paper §III-A, Fig. 4).

The ASIC's ExpAdd array accumulates ŝ(q,k) = Σ sgn·sgn·2^(LO+LO) with
barrel-shifted 1s. TPU adaptation: ŝ is exactly ``dot(pot(q), pot(k))``
(power-of-two rounding), so the screen is an int8 MXU matmul whose *key side
streams from the packed 4-bit feature cache* — (sgn‖LO) nibbles, two per
byte — halving screen-side HBM traffic vs int8 keys and ×4 vs bf16.

HW-codesign notes:
  * The feature tile enters VMEM packed (uint8, d/2 bytes per key) and is
    expanded nibble→pot-int8 *inside* VMEM; the MXU then performs the dot.
  * Grid is (q-tiles, m-tiles); the m axis is the streaming axis — each step
    scores one contiguous block of cached keys, matching the ASIC's
    streamed one-pass accumulation.
  * Default blocks (128 q × 512 keys) keep the working set ≈
    128·d + 512·d/2 + 128·512·4 bytes ≤ VMEM for d ≤ 256, MXU-aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ, DEFAULT_BM = 128, 512

LO_ZERO = 7


def _nibbles_to_pot(feat_packed: jax.Array, d: int) -> jax.Array:
    """uint8 [bm, d//2] packed (sgn‖LO) nibbles → int8 pot values [bm, d]."""
    lo_nib = feat_packed & 0xF
    hi_nib = (feat_packed >> 4) & 0xF
    nib = jnp.stack([lo_nib, hi_nib], axis=-1).reshape(feat_packed.shape[0], d)
    lo = (nib & 0x7).astype(jnp.int32)
    sgn = ((nib >> 3) & 0x1).astype(jnp.int32)
    mag = jnp.where(lo == LO_ZERO, 0, jnp.left_shift(1, jnp.minimum(lo, 6)))
    return ((1 - 2 * sgn) * mag).astype(jnp.int8)


def _lop_scores_kernel(qp_ref, feat_ref, o_ref):
    """Grid (q-tile i, key-tile j): one int8 MXU dot per (i, j)."""
    qp = qp_ref[...]                                     # [bq, d] int8 (pot)
    kp = _nibbles_to_pot(feat_ref[...], qp.shape[-1])    # [bm, d] int8 (pot)
    o_ref[...] = jax.lax.dot_general(
        qp, kp, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)


@functools.partial(jax.jit, static_argnames=("bq", "bm", "interpret"))
def lop_scores_kernel(q_pot: jax.Array, feat_packed: jax.Array, *,
                      bq: int = DEFAULT_BQ, bm: int = DEFAULT_BM,
                      interpret: bool = False) -> jax.Array:
    """pot(q) int8 [g, d] × packed features uint8 [m, d//2] → int32 [g, m].

    ``g`` and ``m`` must be multiples of the block sizes (ops.py pads).
    """
    g, d = q_pot.shape
    m = feat_packed.shape[0]
    assert feat_packed.shape[1] * 2 == d
    assert g % bq == 0 and m % bm == 0, (g, m, bq, bm)

    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel"))

    return pl.pallas_call(
        _lop_scores_kernel,
        grid=(g // bq, m // bm),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, d // 2), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((g, m), jnp.int32),
        interpret=interpret,
        **kwargs,
    )(q_pot, feat_packed)
