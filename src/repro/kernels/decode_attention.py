"""Fused batched decode attention — one Pallas kernel for the whole step.

This is the decode hot path of the paper (§III) as ONE ``pallas_call``
whose grid spans (batch × kv-head) with a sequential streaming axis,
replacing the previous per-head small-kernel dispatch (``lop_screen`` +
jnp block top-K + ``sparse_decode`` under a triple ``vmap``) that TeLLMe
v2 / HSA-style analyses identify as the utilization killer on edge
accelerators (PAPERS.md). Per (b, kv-head) lane the streaming axis runs
three fused phases back to back (DESIGN.md §Fused-decode-kernel):

  screen   steps ``j < NB`` stream the packed 4-bit (sgn‖LO) feature
           cache block by block: nibbles expand to pot-int8 in VMEM, one
           int8 MXU dot yields surrogate scores, invalid tokens mask to
           INT32_MIN, and the per-block maxima land in VMEM scratch
           (fully-masked blocks score −inf so they can never be picked).
  select   at step ``j == NB`` the comparison-free bucketized top-K
           (the ASIC's histogram + prefix-scan selector, mirroring
           :func:`repro.core.lop.comparison_free_topk` op for op) turns
           the block scores into an emission *rank* per block — no
           comparator tree, no sort.
  exact    steps ``j ≥ NB`` walk the K selected candidates in rank
           order. Each step resolves its block id from the rank scratch
           and DMAs ONLY that int8 K/V block (plus scales) from HBM into
           a double-buffered VMEM slot — candidate c+1's fetch starts
           *before* candidate c's wait-and-compute, so the HBM latency
           hides behind MXU work (the paper's head-level pipelining) —
           then folds it into f32 online-softmax state (m/ℓ/acc scratch,
           output-stationary like the paper's OS dataflow). Un-selected
           blocks are never fetched — the LOP traffic win survives
           fusion.

The final step normalizes with an ``ℓ > 0`` guard, so a lane with
``new_len == 0`` (a retired slot-pool lane) emits exactly zero.

Scalar-prefetch contract
------------------------
``new_len`` int32 [B] (per-lane valid length, 0 = retired lane) and
``pos_offset`` int32 [1] (global token position of this cache shard —
the SP quota-sharded path passes ``rank · M_local``) ride in SMEM ahead
of the grid. They drive validity masking, the in-block live interval
[start, end) of each candidate, and nothing else — all tensor operands
are addressed by the grid alone, which is what lets one compiled kernel
serve every lane population and every SP shard.

Modes (all static):

  * ``use_lop=False``  — dense baseline: the same grid streams every
    K/V block through the online-softmax phase (no screen, no DMA).
  * ``shared_select``  — one candidate set per kv head (group max of
    the surrogate scores) instead of per q-head: K DMA gathers instead
    of G·K.
  * ``return_stats``   — also emit the raw (m, ℓ) softmax stats so the
    SP path can merge shards flash-decoding style without recomputing.

Validated in interpret mode (the container's mandated mode). The
selection phase is tiled for real-TPU compilation: every op in
:func:`repro.core.lop.comparison_free_rank` keeps 2-D (sublane, lane)
shape — the histogram runs as per-bucket lane-reductions over [R, M]
broadcast-compares and the index-order prefix sums as f32 MXU dots
against a triangular ones matrix — with ranks bitwise the flat-op
implementation it replaced.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.lop import DEFAULT_N_BUCKETS, comparison_free_rank, pot
from repro.kernels.lop_scores import _nibbles_to_pot

NEG_INF = -1e30
INT32_MIN = jnp.iinfo(jnp.int32).min


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


# ---------------------------------------------------------------------------
# Shared online-softmax update
# ---------------------------------------------------------------------------

def _online_update(s, v_deq, rows, m_ref, l_ref, acc_ref):
    """Fold one [R, block] logit tile into the [rows] slice of the state."""
    m_prev = m_ref[rows, :]
    l_prev = l_ref[rows, :]
    m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, :1])
    l_ref[rows, :] = l_prev * alpha + jnp.sum(p, -1, keepdims=True)
    acc_ref[rows, :] = acc_ref[rows, :] * alpha[:, :1] + jnp.dot(
        p, v_deq, preferred_element_type=jnp.float32)
    m_ref[rows, :] = m_new


def _flush(o_ref, m_out, l_out, m_ref, l_ref, acc_ref, return_stats):
    l = l_ref[:, :1]
    o_ref[0] = acc_ref[...] / jnp.where(l > 0, l, 1.0)
    if return_stats:
        m_out[0] = m_ref[:, :1]
        l_out[0] = l


# ---------------------------------------------------------------------------
# Fused LOP kernel body
# ---------------------------------------------------------------------------

def _fused_lop_kernel(nl_ref, po_ref, qi_ref, qs_ref, feat_ref,
                      k_hbm, v_hbm, ks_hbm, vs_hbm,
                      o_ref, *rest, nb, g, hkv, block, k_keep, window,
                      softmax_scale, n_buckets, n_slots, shared_select,
                      return_stats):
    """Grid (b·hkv, NB + n_cand): screen → select → DMA'd exact attention."""
    if return_stats:
        m_out, l_out = rest[0], rest[1]
        rest = rest[2:]
    else:
        m_out = l_out = None
    (blk_ref, rank_ref, m_ref, l_ref, acc_ref,
     kb_ref, vb_ref, ksb_ref, vsb_ref, sem) = rest

    bh = pl.program_id(0)
    j = pl.program_id(1)
    nl = nl_ref[bh // hkv]
    po = po_ref[0]
    n_cand = k_keep if shared_select else g * k_keep

    @pl.when(j == 0)
    def _init():
        blk_ref[...] = jnp.full_like(blk_ref, -jnp.inf)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # ---- screen: surrogate block scores from the packed feature cache ----
    @pl.when(j < nb)
    def _screen():
        qp = pot(qi_ref[0])                              # [G, d] int8
        kp = _nibbles_to_pot(feat_ref[0], qp.shape[-1])  # [block, d] int8
        s = jax.lax.dot_general(
            qp, kp, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.int32)            # [G, block]
        tpos = po + j * block + jax.lax.broadcasted_iota(jnp.int32,
                                                         s.shape, 1)
        tvalid = tpos < nl
        if window:
            tvalid &= tpos >= nl - window
        s = jnp.where(tvalid, s, INT32_MIN)
        if shared_select:
            s = jnp.max(s, axis=0, keepdims=True)        # [1, block]
        score = jnp.max(s, -1, keepdims=True).astype(jnp.float32)
        # a block with no valid token must never be selectable
        any_valid = jnp.any(tvalid[:1])
        blk_ref[:, pl.ds(j, 1)] = jnp.where(any_valid, score, -jnp.inf)

    # ---- select: comparison-free top-K → emission ranks (once). The rank
    # computation is THE shared implementation from core.lop (also behind
    # the jnp oracle's comparison_free_topk), running here inside the
    # kernel body — kernel and oracle cannot drift apart. ----
    @pl.when(j == nb)
    def _select():
        rank_ref[...] = comparison_free_rank(blk_ref[...], k_keep,
                                             n_buckets)

    # ---- exact: slot-buffered candidate DMA + online softmax ----
    # Candidate c's K/V/scale blocks are fetched into slot c % n_slots;
    # the copy for c + n_slots − 1 starts BEFORE the wait-and-compute of
    # c, so up to n_slots − 1 fetches are in flight behind the MXU work
    # of the current candidate — the head-level pipelining the paper
    # overlaps in silicon. n_slots = 2 is classic double buffering (the
    # historical shape); the slot count only changes WHEN a block is
    # fetched, never which blocks fold or in what order, so every
    # n_slots ≥ 1 is bitwise n_slots = 2 (DESIGN.md §Autotuning).
    def _resolve(c):
        """Candidate number → (gated?, selected block id)."""
        if shared_select:
            rank_row = rank_ref[0:1, :]
            kc = c
        else:
            rank_row = rank_ref[pl.ds(c // k_keep, 1), :]
            kc = c % k_keep
        cols = jax.lax.broadcasted_iota(jnp.int32, rank_row.shape, 1)
        hit = rank_row == kc
        return jnp.any(hit), jnp.min(jnp.where(hit, cols, nb))

    def _copies(slot, idx):
        start = idx * block
        return [
            pltpu.make_async_copy(k_hbm.at[bh, pl.ds(start, block), :],
                                  kb_ref.at[slot], sem.at[slot, 0]),
            pltpu.make_async_copy(v_hbm.at[bh, pl.ds(start, block), :],
                                  vb_ref.at[slot], sem.at[slot, 1]),
            pltpu.make_async_copy(ks_hbm.at[bh, pl.ds(start, block), :],
                                  ksb_ref.at[slot], sem.at[slot, 2]),
            pltpu.make_async_copy(vs_hbm.at[bh, pl.ds(start, block), :],
                                  vsb_ref.at[slot], sem.at[slot, 3]),
        ]

    @pl.when(j >= nb)
    def _cand():
        c = j - nb
        slot = jax.lax.rem(c, n_slots)
        gate, idx = _resolve(c)

        # warmup: the first candidate step fills slots 0..n_slots−2
        for cc in range(min(n_slots - 1, n_cand)):
            gate_w, idx_w = _resolve(cc)

            @pl.when((c == 0) & gate_w)
            def _warmup(cc=cc, gate_w=gate_w, idx_w=idx_w):
                for cp in _copies(cc % n_slots, idx_w):
                    cp.start()

        if n_cand >= n_slots:
            @pl.when(c + n_slots - 1 < n_cand)
            def _prefetch_next():
                gate_n, idx_n = _resolve(c + n_slots - 1)

                @pl.when(gate_n)
                def _():
                    for cp in _copies(jax.lax.rem(c + n_slots - 1, n_slots),
                                      idx_n):
                        cp.start()

        @pl.when(gate)
        def _attend():
            for cp in _copies(slot, idx):
                cp.wait()
            kb = kb_ref[pl.ds(slot, 1)][0]               # [block, d]
            ksb = ksb_ref[pl.ds(slot, 1)][0]             # [block, 1]

            if shared_select:
                rows = slice(None)
                q = qi_ref[0]                            # [G, d]
                qs = qs_ref[0]                           # [G, 1]
            else:
                rows = pl.ds(c // k_keep, 1)
                q = qi_ref[0, rows, :]                   # [1, d]
                qs = qs_ref[0, rows, :]
            s = jax.lax.dot_general(
                q, kb, dimension_numbers=(((1,), (1,)), ((), ())),
                preferred_element_type=jnp.int32).astype(jnp.float32)
            s = s * qs * ksb.reshape(1, block) * softmax_scale
            # in-block live interval [start, end): suffix cut by the cache
            # length, prefix cut by the SWA window
            blk_start = po + idx * block
            end = jnp.clip(nl - blk_start, 0, block)
            tstart = jnp.clip(nl - window - blk_start, 0, block) if window \
                else 0
            t = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where((t >= tstart) & (t < end), s, NEG_INF)
            v_deq = (vb_ref[pl.ds(slot, 1)][0].astype(jnp.float32)
                     * vsb_ref[pl.ds(slot, 1)][0])
            _online_update(s, v_deq, rows, m_ref, l_ref, acc_ref)

    @pl.when(j == nb + n_cand - 1)
    def _finish():
        _flush(o_ref, m_out, l_out, m_ref, l_ref, acc_ref, return_stats)


# ---------------------------------------------------------------------------
# Fused dense kernel body (no-LOP baseline on the same grid layout)
# ---------------------------------------------------------------------------

def _fused_dense_kernel(nl_ref, po_ref, qi_ref, qs_ref, k_ref, v_ref,
                        ks_ref, vs_ref, o_ref, *rest, nb, hkv, block,
                        window, softmax_scale, return_stats):
    """Grid (b·hkv, NB): exact attention streamed over every K/V block."""
    if return_stats:
        m_out, l_out = rest[0], rest[1]
        rest = rest[2:]
    else:
        m_out = l_out = None
    m_ref, l_ref, acc_ref = rest

    bh = pl.program_id(0)
    j = pl.program_id(1)
    nl = nl_ref[bh // hkv]
    po = po_ref[0]

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    tpos0 = po + j * block + jax.lax.broadcasted_iota(jnp.int32,
                                                      (1, block), 1)
    tvalid0 = tpos0 < nl
    if window:
        tvalid0 &= tpos0 >= nl - window

    @pl.when(jnp.any(tvalid0))
    def _tile():
        s = jax.lax.dot_general(
            qi_ref[0], k_ref[0], dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.int32).astype(jnp.float32)
        s = s * qs_ref[0] * ks_ref[0].reshape(1, block) * softmax_scale
        s = jnp.where(tvalid0, s, NEG_INF)
        v_deq = v_ref[0].astype(jnp.float32) * vs_ref[0]
        _online_update(s, v_deq, slice(None), m_ref, l_ref, acc_ref)

    @pl.when(j == nb - 1)
    def _finish():
        _flush(o_ref, m_out, l_out, m_ref, l_ref, acc_ref, return_stats)


# ---------------------------------------------------------------------------
# Entry
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=(
    "hkv", "block", "k_keep", "window", "softmax_scale", "use_lop",
    "shared_select", "return_stats", "n_buckets", "n_slots", "interpret"))
def fused_decode_attention(qi, qsc, k_cache, v_cache, k_scale, v_scale,
                           feat, new_len, pos_off, *, hkv: int, block: int,
                           k_keep: int, window: int, softmax_scale: float,
                           use_lop: bool = True, shared_select: bool = False,
                           return_stats: bool = False,
                           n_buckets: int = DEFAULT_N_BUCKETS,
                           n_slots: int = 2,
                           interpret: bool = False):
    """One fused decode-attention step over every (batch, kv-head) lane.

    qi        int8   [BH, G, d]    new-token queries (BH = B·Hkv, grouped)
    qsc       f32    [BH, G, 1]    per-head absmax query scales
    k/v_cache int8   [BH, M, d]    exact caches (HBM-resident; only the
                                   selected candidate blocks are fetched)
    k/v_scale f32    [BH, M, 1]    per-token absmax scales
    feat      uint8  [BH, M, d/2]  packed (sgn‖LO) feature cache
    new_len   int32  [B]           valid tokens per lane (0 = retired slot)
    pos_off   int32  [1]           global position of cache row 0 (SP shard)
    n_slots   candidate DMA slots in VMEM (≥ 1; 2 = double buffering, the
              default; more slots deepen the fetch pipeline, bitwise)
    → f32 [BH, G, d]; with ``return_stats`` also (m, ℓ) f32 [BH, G, 1].
    """
    bhg, g, d = qi.shape
    m = k_cache.shape[1]
    assert m % block == 0, (m, block)
    assert n_slots >= 1, n_slots
    nb = m // block
    nbp = _round_up(nb, 128)                 # lane-padded score scratch
    g_sel = 1 if shared_select else g

    outs = [jax.ShapeDtypeStruct((bhg, g, d), jnp.float32)]
    out_specs = [pl.BlockSpec((1, g, d), lambda bh, j, nl, po: (bh, 0, 0))]
    if return_stats:
        outs += [jax.ShapeDtypeStruct((bhg, g, 1), jnp.float32)] * 2
        out_specs += [pl.BlockSpec((1, g, 1),
                                   lambda bh, j, nl, po: (bh, 0, 0))] * 2

    if not use_lop:
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(bhg, nb),
            in_specs=[
                pl.BlockSpec((1, g, d), lambda bh, j, nl, po: (bh, 0, 0)),
                pl.BlockSpec((1, g, 1), lambda bh, j, nl, po: (bh, 0, 0)),
                pl.BlockSpec((1, block, d),
                             lambda bh, j, nl, po: (bh, j, 0)),
                pl.BlockSpec((1, block, d),
                             lambda bh, j, nl, po: (bh, j, 0)),
                pl.BlockSpec((1, block, 1),
                             lambda bh, j, nl, po: (bh, j, 0)),
                pl.BlockSpec((1, block, 1),
                             lambda bh, j, nl, po: (bh, j, 0)),
            ],
            out_specs=out_specs if return_stats else out_specs[0],
            scratch_shapes=[
                pltpu.VMEM((g, 128), jnp.float32),
                pltpu.VMEM((g, 128), jnp.float32),
                pltpu.VMEM((g, d), jnp.float32),
            ],
        )
        out = pl.pallas_call(
            functools.partial(_fused_dense_kernel, nb=nb, hkv=hkv,
                              block=block, window=window,
                              softmax_scale=softmax_scale,
                              return_stats=return_stats),
            grid_spec=grid_spec,
            out_shape=outs if return_stats else outs[0],
            interpret=interpret,
        )(new_len, pos_off, qi, qsc, k_cache, v_cache, k_scale, v_scale)
        return out

    n_cand = k_keep * (1 if shared_select else g)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(bhg, nb + n_cand),
        in_specs=[
            pl.BlockSpec((1, g, d), lambda bh, j, nl, po: (bh, 0, 0)),
            pl.BlockSpec((1, g, 1), lambda bh, j, nl, po: (bh, 0, 0)),
            # feature stream (clamped once the candidate phase starts)
            pl.BlockSpec((1, block, d // 2),
                         lambda bh, j, nl, po: (bh, jnp.minimum(j, nb - 1),
                                                0)),
            # exact caches stay in HBM; candidates are DMA'd by block id
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=out_specs if return_stats else out_specs[0],
        scratch_shapes=[
            pltpu.VMEM((g_sel, nbp), jnp.float32),   # block scores
            pltpu.VMEM((g_sel, nbp), jnp.int32),     # emission ranks
            pltpu.VMEM((g, 128), jnp.float32),       # running max
            pltpu.VMEM((g, 128), jnp.float32),       # running sum-exp
            pltpu.VMEM((g, d), jnp.float32),         # output accumulator
            pltpu.VMEM((n_slots, block, d), jnp.int8),     # K block slots
            pltpu.VMEM((n_slots, block, d), jnp.int8),     # V block slots
            pltpu.VMEM((n_slots, block, 1), jnp.float32),  # K scale slots
            pltpu.VMEM((n_slots, block, 1), jnp.float32),  # V scale slots
            pltpu.SemaphoreType.DMA((n_slots, 4)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_fused_lop_kernel, nb=nb, g=g, hkv=hkv,
                          block=block, k_keep=k_keep, window=window,
                          softmax_scale=softmax_scale, n_buckets=n_buckets,
                          n_slots=n_slots, shared_select=shared_select,
                          return_stats=return_stats),
        grid_spec=grid_spec,
        out_shape=outs if return_stats else outs[0],
        interpret=interpret,
    )(new_len, pos_off, qi, qsc, feat, k_cache, v_cache, k_scale, v_scale)
