"""TINT-core Pallas kernel: packed-2-bit ternary × int8 GEMM (paper §II-A).

HW-codesign notes (16 nm ASIC → TPU v5e):
  * The ASIC streams packed 2-bit codes into a multiplier-free 8×8
    select-accumulate array. On TPU we keep the *packed code stream* — the
    weight tile enters VMEM as uint8 codes (4 weights/byte, 4× less HBM
    traffic than int8) — and unpack to int8 **inside VMEM** before feeding
    the MXU, which does int8×int8 natively (select-accumulate would waste
    the systolic array).
  * Output-stationary mapping: the int32 accumulator tile lives in VMEM
    scratch across the k-reduction grid axis, exactly the OS dataflow the
    paper uses to keep partial sums local.
  * Block shapes default to (128, 512, 128): MXU-aligned (multiples of 128)
    and sized so x-tile (64 KiB) + packed-w-tile (16 KiB) + acc (64 KiB)
    fit comfortably in VMEM (the paper's 120 KB SRAM budget maps to the
    per-buffer VMEM working set).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BM, DEFAULT_BK, DEFAULT_BN = 128, 512, 128


def _unpack_codes(wp: jax.Array, bk: int) -> jax.Array:
    """uint8 codes [bk//4, bn] → int8 ternary [bk, bn] (in-VMEM unpack)."""
    parts = [(wp >> (2 * j)) & 0x3 for j in range(4)]          # each [bk//4, bn]
    codes = jnp.stack(parts, axis=1).reshape(bk, wp.shape[-1])
    pos = (codes == 1).astype(jnp.int8)
    neg = (codes == 2).astype(jnp.int8)
    return pos - neg


def _ternary_matmul_kernel(x_ref, wp_ref, o_ref, acc_ref, *, n_k: int):
    """Grid (m, n, k); k is the sequential reduction axis (OS dataflow)."""
    kstep = pl.program_id(2)

    @pl.when(kstep == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]                                   # [bm, bk] int8
    w = _unpack_codes(wp_ref[...], x.shape[-1])      # [bk, bn] int8
    acc_ref[...] += jax.lax.dot(x, w, preferred_element_type=jnp.int32)

    @pl.when(kstep == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit,
                   static_argnames=("k", "bm", "bk", "bn", "interpret"))
def ternary_matmul(x: jax.Array, packed: jax.Array, k: int, *,
                   bm: int = DEFAULT_BM, bk: int = DEFAULT_BK,
                   bn: int = DEFAULT_BN, interpret: bool = False) -> jax.Array:
    """int8 x [m, k] @ packed ternary [k//4, n] → int32 [m, n].

    Shapes must be multiples of the block sizes (ops.py pads).
    """
    m = x.shape[0]
    n = packed.shape[1]
    assert x.shape[1] == k and packed.shape[0] * 4 == k
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (m, k, n, bm, bk, bn)
    n_k = k // bk

    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))

    return pl.pallas_call(
        functools.partial(_ternary_matmul_kernel, n_k=n_k),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk // 4, bn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
        **kwargs,
    )(x, packed)
