"""Fused batched prefill attention — causal int8 flash over token chunks.

The prefill counterpart of :mod:`repro.kernels.decode_attention`: ONE
``pallas_call`` whose grid spans (batch × kv-head) lanes with a sequential
KV-block streaming axis, computing causal (or cross) int8 attention for a
fixed-size *chunk* of queries against the capacity-padded cache — the
quantized K/V the engine wrote at [0, kv_len). This is what lets the
scheduler interleave one prefill chunk with the running decode batch per
``serve_step`` instead of stalling every lane behind a whole prompt
(DESIGN.md §Chunked-prefill): the chunk shape is FIXED, so prefill
compiles collapse from one-per-pow2-bucket to one shape, and TeLLMe-v2
style prefill acceleration rides the same ``ops.*`` interface the decode
kernel standardized.

Per (b, kv-head) lane the streaming axis walks every KV block ``j``:

  gate     blocks entirely beyond the lane's valid length, or entirely
           above the causal diagonal of the chunk, are skipped (their
           fold would be a bitwise no-op anyway — see below).
  logits   one MXU dot per block: either integer-domain
           (int8×int8→int32, BoothFlex-faithful, ``int8_logits``) or the
           dequantize-K-then-f32 form — both scaled by the per-token
           absmax scales of the quantization barrier.
  mask     query row r = g·chunk + t sits at global position
           ``q_off + t``; tokens outside [max(0, qpos-window+1), qpos]
           or ≥ kv_len mask to −∞. Fully-masked rows are guarded: their
           probability tile is zeroed explicitly so the online-softmax
           state never absorbs exp(−∞ − −∞) = 1 garbage.
  fold     f32 online-softmax (m/ℓ/acc VMEM scratch, output-stationary
           like the paper's OS dataflow); the final block normalizes
           with an ℓ > 0 guard so an empty lane emits exactly zero.

Chunk-carry exactness (the contract the scheduler relies on)
------------------------------------------------------------
A query row's fold sequence is independent of every other row in the
call: blocks it cannot see are either gated off or fully masked, and a
fully-masked fold is *bitwise* a no-op (max(m, −∞) = m, ℓ += 0, acc +=
0). Therefore running a prompt through this kernel in C-token chunks
(each attending the cache written so far, ``q_off`` = chunk start)
produces bit-identical rows to one whole-prompt call over the same
capacity-padded cache — no inter-chunk softmax state needs to leave the
kernel; the carry IS the cache plus ``(q_off, kv_len)``. The jnp oracle
(:func:`repro.kernels.ref.prefill_attention_ref`) holds the same
invariant, so token-exact chunked-vs-lockstep agreement survives both
``REPRO_KERNEL_IMPL`` arms.

Scalar-prefetch contract (mirrors the decode kernel)
----------------------------------------------------
``kv_len`` int32 [B] — tokens valid in each lane's cache *after* this
chunk's K/V were written (0 = nothing valid → zero output); ``q_off``
int32 [1] — global position of chunk row t = 0. They drive gating and
masking only; tensor operands are addressed by the grid alone.

Validated in interpret mode (the container's mandated mode). The R
query rows can be tiled over a third grid axis (``bq``, the
carried-forward ROADMAP.md item, now a sweepable knob of
DESIGN.md §Autotuning): each bq-row slab walks the same kv blocks the
untiled kernel walks, so any divisor of R is bitwise ``bq = R`` while
capping resident VMEM at bq·(256 + d) f32 — what makes whole-prompt
32k-row calls compilable on real hardware.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

DEFAULT_BK = 128


def _fused_prefill_kernel(kvl_ref, qo_ref, qi_ref, qs_ref, k_ref, v_ref,
                          ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref, *,
                          nb, hkv, chunk, block, bq, causal, window,
                          softmax_scale, int8_logits):
    """Grid (b·hkv, query-row tile qt, kv-block j); j streams sequentially.

    The second axis tiles the R query rows in ``bq``-row slabs (the
    carried-forward third grid axis: R never has to fit VMEM whole). A
    row's fold sequence is unchanged by the tiling — the kv gate stays
    the whole-chunk one, so a slab walks exactly the blocks the untiled
    kernel walks and masked folds remain bitwise no-ops — hence any
    ``bq`` is bitwise ``bq = R``.
    """
    bh = pl.program_id(0)
    qt = pl.program_id(1)
    j = pl.program_id(2)
    kvl = kvl_ref[bh // hkv]
    qo = qo_ref[0]
    r = qi_ref.shape[1]

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # gate: blocks beyond the valid cache, or entirely above the causal
    # diagonal of this chunk, contribute nothing
    run = j * block < kvl
    if causal:
        run = jnp.logical_and(run, j * block <= qo + chunk - 1)

    @pl.when(run)
    def _tile():
        k = k_ref[0]                                     # [block, d] int8
        ks = ks_ref[0]                                   # [block, 1] f32
        # both branches dequantize AFTER the dot (int8 products summed in
        # f32 are exact below 2²⁴), so int8_logits only picks the MXU
        # datapath — see prefill_attention_ref for the knife-edge this
        # avoids
        if int8_logits:
            s = jax.lax.dot_general(
                qi_ref[0], k, dimension_numbers=(((1,), (1,)), ((), ())),
                preferred_element_type=jnp.int32).astype(jnp.float32)
        else:
            s = jax.lax.dot_general(
                qi_ref[0].astype(jnp.float32), k.astype(jnp.float32),
                dimension_numbers=(((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)
        s = s * ks.reshape(1, block) * qs_ref[0] * softmax_scale

        kpos = j * block + jax.lax.broadcasted_iota(jnp.int32, (r, block), 1)
        mask = kpos < kvl
        if causal:
            # row qt·bq + i = g*chunk + t → in-chunk offset t → query pos
            t = jax.lax.rem(
                qt * bq +
                jax.lax.broadcasted_iota(jnp.int32, (r, block), 0), chunk)
            qpos = qo + t
            mask = jnp.logical_and(mask, kpos <= qpos)
            if window:
                mask = jnp.logical_and(mask, qpos - kpos < window)
        s = jnp.where(mask, s, NEG_INF)

        # online-softmax fold with an all-masked-row guard: rows whose
        # tile is fully −∞ while m is still −∞ must not absorb
        # exp(−∞ − −∞) = 1 per position
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, :1])
        p = jnp.where(s <= NEG_INF / 2, 0.0, p)
        l_ref[...] = l_prev * alpha + jnp.sum(p, -1, keepdims=True)
        v_deq = v_ref[0].astype(jnp.float32) * vs_ref[0]
        acc_ref[...] = acc_ref[...] * alpha[:, :1] + jnp.dot(
            p, v_deq, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == nb - 1)
    def _flush():
        l = l_ref[:, :1]
        o_ref[0] = acc_ref[...] / jnp.where(l > 0, l, 1.0)


@functools.partial(jax.jit, static_argnames=(
    "hkv", "chunk", "block", "bq", "causal", "window", "softmax_scale",
    "int8_logits", "interpret"))
def fused_prefill_attention(qi, qsc, k_cache, v_cache, k_scale, v_scale,
                            kv_len, pos_off, *, hkv: int, chunk: int,
                            block: int, bq: int = 0, causal: bool,
                            window: int, softmax_scale: float,
                            int8_logits: bool = False,
                            interpret: bool = False) -> jax.Array:
    """One fused prefill-chunk attention over every (batch, kv-head) lane.

    qi        int8  [BH, R, d]   chunk queries (BH = B·Hkv; R = G·chunk,
                                 rows g-major: row = g·chunk + t)
    qsc       f32   [BH, R, 1]   per-token-head absmax query scales
    k/v_cache int8  [BH, M, d]   capacity-padded caches (chunk K/V already
                                 written at [q_off, q_off + chunk))
    k/v_scale f32   [BH, M, 1]   per-token absmax scales
    kv_len    int32 [B]          valid tokens incl. this chunk (0 = none)
    pos_off   int32 [1]          global position of chunk row t = 0
    bq        query rows resident per grid step (0 → all R rows, the
              historical shape); any divisor of R is bitwise-equivalent
    → f32 [BH, R, d]
    """
    bhg, r, d = qi.shape
    assert r % chunk == 0, (r, chunk)
    m = k_cache.shape[1]
    assert m % block == 0, (m, block)
    nb = m // block
    if bq == 0:
        bq = r
    assert r % bq == 0, (r, bq)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(bhg, r // bq, nb),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qt, j, kvl, qo: (bh, qt, 0)),
            pl.BlockSpec((1, bq, 1), lambda bh, qt, j, kvl, qo: (bh, qt, 0)),
            pl.BlockSpec((1, block, d),
                         lambda bh, qt, j, kvl, qo: (bh, j, 0)),
            pl.BlockSpec((1, block, d),
                         lambda bh, qt, j, kvl, qo: (bh, j, 0)),
            pl.BlockSpec((1, block, 1),
                         lambda bh, qt, j, kvl, qo: (bh, j, 0)),
            pl.BlockSpec((1, block, 1),
                         lambda bh, qt, j, kvl, qo: (bh, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d),
                               lambda bh, qt, j, kvl, qo: (bh, qt, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),  # running max (lanes equal)
            pltpu.VMEM((bq, 128), jnp.float32),  # running sum-exp
            pltpu.VMEM((bq, d), jnp.float32),    # output accumulator
        ],
    )
    return pl.pallas_call(
        functools.partial(_fused_prefill_kernel, nb=nb, hkv=hkv, chunk=chunk,
                          block=block, bq=bq, causal=causal, window=window,
                          softmax_scale=softmax_scale,
                          int8_logits=int8_logits),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bhg, r, d), jnp.float32),
        interpret=interpret,
    )(kv_len, pos_off, qi, qsc, k_cache, v_cache, k_scale, v_scale)
