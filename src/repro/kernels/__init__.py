"""Pallas TPU kernels for the paper's compute hot-spots.

  * ``ternary_matmul``    — TINT core: packed-2bit ternary × int8 GEMM
  * ``qlinear``           — THE projection path: fused absmax barrier →
                            packed-ternary GEMM → dequant/bias/activation
                            epilogue (``fused_qlinear``), and the whole
                            gate·up → re-barrier → down FFN as one launch
                            (``fused_ffn``), both with an optional
                            grouped-expert grid axis
  * ``lop_scores``        — LOP screen over the packed 4-bit feature cache
  * ``int8_attention``    — int8 flash prefill + the single-kv-head
                            block-sparse decode micro-kernel
  * ``decode_attention``  — THE serving decode path: one fused batched
                            kernel (screen → comparison-free top-K →
                            DMA-gathered exact attention) whose grid spans
                            every (batch, kv-head) lane in one launch
  * ``prefill_attention`` — THE serving prefill path: one fused batched
                            causal int8 flash kernel over fixed-size token
                            chunks with online-softmax carry, shared by
                            whole-prompt, chunked, encoder and
                            cross-attention prefill

``ops`` exposes the jit'd public wrappers (pallas/ref dispatch, padding);
``ref`` holds the pure-jnp oracles used by the allclose tests and traced by
the full-size dry-run.
"""

from repro.kernels import ops, ref
from repro.kernels.ops import (decode_attention, ffn_fused, flash_prefill,
                               lop_screen, prefill_attention, qlinear_fused,
                               sparse_decode, ternary_matmul)
