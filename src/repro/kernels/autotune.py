"""Tile autotuning for the fused Pallas kernels (DESIGN.md §Autotuning).

Every kernel entry in :mod:`repro.kernels.ops` carries hardcoded block
shapes chosen by hand. This module makes them *swept*: per kernel and
per workload shape it generates tiling candidates from the roofline
bounds in :mod:`repro.analysis.roofline` (resident-VMEM budget +
arithmetic-intensity ranking), times each candidate on synthetic
operands, and persists the winner to a config-keyed ``TUNE_*.json``
table that ``ops.py`` consults at dispatch time.

Sweep space per kernel (every axis is a pure tiling knob — any legal
setting is bitwise the default, pinned by the kernel test matrix):

  ================  ==========================================
  kernel            swept parameters
  ================  ==========================================
  ternary_matmul    bm, bk, bn
  qlinear           bm, bn, bkq (two-pass k-tiled barrier),
                    eg (experts per grid step)
  ffn               bm, bf, bn, bkq
  prefill           block (kv tile), bq (query-row tile)
  decode            n_slots (candidate DMA slots)
  ================  ==========================================

Precedence at dispatch (``lookup``):

  1. an active :func:`override` context (tests / experiment flags);
  2. the tuning table — ``REPRO_TUNE_TABLE`` path env or the repo-root
     ``TUNE_kernels.json`` — under the current config key, entries
     validated against the workload's divisibility constraints;
  3. ``{}`` — the caller's hardcoded defaults. With no table on disk
     (and ``REPRO_TUNE=0`` forces this) dispatch is bitwise the
     pre-autotune code path.

The module is imported by ``ops.py`` at module scope, so everything here
stays import-light: jax / kernel modules load lazily inside the sweep
functions only.
"""

from __future__ import annotations

import argparse
import contextlib
import json
import os
import sys
import time
from pathlib import Path

from repro.analysis.roofline import (arithmetic_intensity, machine_balance,
                                     vmem_budget)

ROOT = Path(__file__).resolve().parents[3]
DEFAULT_TABLE = ROOT / "TUNE_kernels.json"
TABLE_VERSION = 1
DEFAULT_SHAPE_LOG = ROOT / "TUNE_shapes.json"
SHAPE_LOG_ENV = "REPRO_SHAPE_LOG"
SHAPE_LOG_VERSION = 1

KERNELS = ("ternary_matmul", "qlinear", "ffn", "prefill", "decode")

# dims each kernel's shape key is built from, and the params it sweeps
KERNEL_DIMS = {
    "ternary_matmul": ("m", "k", "n"),
    "qlinear": ("e", "m", "k", "n"),
    "ffn": ("e", "m", "k", "f", "n"),
    "prefill": ("bhg", "r", "d", "m", "chunk"),
    "decode": ("bhg", "g", "d", "m", "block", "k_keep"),
}
KERNEL_PARAMS = {
    "ternary_matmul": ("bm", "bk", "bn"),
    "qlinear": ("bm", "bn", "bkq", "eg"),
    "ffn": ("bm", "bf", "bn", "bkq"),
    "prefill": ("block", "bq"),
    "decode": ("n_slots",),
}


# ---------------------------------------------------------------------------
# Table I/O and dispatch lookup
# ---------------------------------------------------------------------------

_OVERRIDES: dict[str, dict] = {}
_CACHE: dict[str, tuple[float, dict]] = {}


def table_path() -> Path:
    return Path(os.environ.get("REPRO_TUNE_TABLE", DEFAULT_TABLE))


def config_key() -> str:
    """Backend the timings were taken on — a cpu-interpret sweep must not
    steer a real-TPU dispatch and vice versa."""
    import jax
    backend = jax.default_backend()
    return backend if backend == "tpu" else f"{backend}-interpret"


def shape_key(kernel: str, dims: dict) -> str:
    names = KERNEL_DIMS[kernel]
    assert set(dims) == set(names), (kernel, dims)
    return ",".join(f"{k}={int(dims[k])}" for k in names)


def load_table(path: str | Path | None = None) -> dict:
    """Parse the table (``{}`` when absent/unreadable), mtime-cached."""
    p = Path(path) if path is not None else table_path()
    try:
        mtime = p.stat().st_mtime
    except OSError:
        return {}
    key = str(p)
    cached = _CACHE.get(key)
    if cached and cached[0] == mtime:
        return cached[1]
    try:
        table = json.loads(p.read_text())
    except (OSError, ValueError):
        return {}
    if not isinstance(table, dict):
        table = {}
    _CACHE[key] = (mtime, table)
    return table


def save_table(table: dict, path: str | Path | None = None) -> Path:
    p = Path(path) if path is not None else table_path()
    p.write_text(json.dumps(table, indent=2, sort_keys=True) + "\n")
    _CACHE.pop(str(p), None)
    return p


@contextlib.contextmanager
def override(kernel: str, **params):
    """Force ``params`` for every ``lookup(kernel, ...)`` in the block —
    the flag override of the precedence chain (beats the table)."""
    assert kernel in KERNELS, kernel
    prev = _OVERRIDES.get(kernel)
    _OVERRIDES[kernel] = dict(params)
    try:
        yield
    finally:
        if prev is None:
            _OVERRIDES.pop(kernel, None)
        else:
            _OVERRIDES[kernel] = prev


def valid_params(kernel: str, dims: dict, params: dict) -> bool:
    """Divisibility / legality screen for a (possibly stale) table entry."""
    if not isinstance(params, dict):
        return False
    if not set(params) <= set(KERNEL_PARAMS[kernel]):
        return False
    try:
        p = {k: int(v) for k, v in params.items()}
    except (TypeError, ValueError):
        return False
    d = dims
    if kernel == "ternary_matmul":
        return (p.get("bm", 8) >= 1
                and d["k"] % p.get("bk", d["k"]) == 0
                and d["n"] % p.get("bn", d["n"]) == 0)
    if kernel == "qlinear":
        bkq = p.get("bkq", 0)
        return (p.get("bm", 8) % 8 == 0 and p.get("bm", 8) >= 8
                and d["n"] % p.get("bn", d["n"]) == 0
                and (bkq == 0 or d["k"] % bkq == 0)
                and d["e"] % p.get("eg", 1) == 0)
    if kernel == "ffn":
        bkq = p.get("bkq", 0)
        return (p.get("bm", 8) % 8 == 0 and p.get("bm", 8) >= 8
                and d["f"] % p.get("bf", d["f"]) == 0
                and d["n"] % p.get("bn", d["n"]) == 0
                and (bkq == 0 or d["k"] % bkq == 0))
    if kernel == "prefill":
        block = p.get("block", 0)
        bq = p.get("bq", 0)
        # the wrapper pads M up to `block`, so any block ≥ 1 is legal
        return block >= 1 and (bq == 0 or d["r"] % bq == 0)
    if kernel == "decode":
        return p.get("n_slots", 2) >= 1
    return False


def lookup(kernel: str, dims: dict) -> dict:
    """Tuned params for this kernel+shape, or ``{}`` (use the defaults).

    Checked in precedence order: override context → table entry (env
    ``REPRO_TUNE=0`` disables this leg) → ``{}``. Invalid/stale entries
    fall through to ``{}`` rather than crash dispatch.
    """
    ov = _OVERRIDES.get(kernel)
    if ov is not None:
        return dict(ov) if valid_params(kernel, dims, ov) else {}
    if os.environ.get("REPRO_TUNE", "1") == "0":
        return {}
    table = load_table()
    if not table:
        return {}
    entry = (table.get("configs", {}).get(config_key(), {})
             .get(kernel, {}).get(shape_key(kernel, dims)))
    if not isinstance(entry, dict):
        return {}
    params = entry.get("params", {})
    return dict(params) if valid_params(kernel, dims, params) else {}


# ---------------------------------------------------------------------------
# Shape log (log-and-sweep, DESIGN.md §Autotuning): the serving engine
# records every distinct kernel dispatch shape to a JSON sidecar, and a
# later sweep (on the real hardware) reads it back so the swept-shape
# set grows from the shapes production traffic actually dispatches —
# not just the DEFAULT_SHAPES guesses.
# ---------------------------------------------------------------------------

_SHAPE_LOG: dict = {"path": None, "seen": set()}


def shape_log_path() -> Path | None:
    """Active sidecar path: explicit ``start_shape_log`` wins, then the
    ``REPRO_SHAPE_LOG`` env (its value = the path, or ``1`` for the
    repo-root default). ``None`` = logging off (the default: dispatch
    must not grow file I/O unless asked)."""
    if _SHAPE_LOG["path"] is not None:
        return _SHAPE_LOG["path"]
    env = os.environ.get(SHAPE_LOG_ENV)
    if not env or env == "0":
        return None
    return DEFAULT_SHAPE_LOG if env == "1" else Path(env)


def start_shape_log(path: str | Path | None = None) -> Path:
    """Enable shape logging (e.g. ``PooledEngine(shape_log=...)``)."""
    p = Path(path) if path is not None else DEFAULT_SHAPE_LOG
    _SHAPE_LOG["path"] = p
    _SHAPE_LOG["seen"] = set()
    return p


def stop_shape_log() -> None:
    _SHAPE_LOG["path"] = None
    _SHAPE_LOG["seen"] = set()


def observe(kernel: str, dims: dict) -> None:
    """Record one dispatch shape to the sidecar (dedup'd, write-through).

    Called by every ``ops.py`` entry point at trace time — shapes are
    static Python ints, so a shape is observed once per compile, not per
    step; the in-memory ``seen`` set makes repeat traces free and the
    read-modify-write below keeps the file a union across processes.
    No-op unless logging is enabled.
    """
    p = shape_log_path()
    if p is None or kernel not in KERNEL_DIMS:
        return
    key = (str(p), kernel, shape_key(kernel, dims))
    if key in _SHAPE_LOG["seen"]:
        return
    _SHAPE_LOG["seen"].add(key)
    try:
        log = json.loads(p.read_text())
        assert isinstance(log, dict)
    except (OSError, ValueError, AssertionError):
        log = {}
    log.setdefault("version", SHAPE_LOG_VERSION)
    shapes = log.setdefault("shapes", {}).setdefault(kernel, [])
    skey = shape_key(kernel, dims)
    if skey not in shapes:
        shapes.append(skey)
        shapes.sort()
    p.write_text(json.dumps(log, indent=2, sort_keys=True) + "\n")


def load_shape_log(path: str | Path | None = None) -> dict:
    """Sidecar → ``{kernel: [dims, ...]}`` (malformed entries dropped)."""
    p = Path(path) if path is not None else (shape_log_path()
                                             or DEFAULT_SHAPE_LOG)
    try:
        log = json.loads(p.read_text())
    except (OSError, ValueError):
        return {}
    out: dict[str, list[dict]] = {}
    for kernel, skeys in (log.get("shapes") or {}).items():
        if kernel not in KERNELS or not isinstance(skeys, list):
            continue
        for skey in skeys:
            try:
                dims = {k: int(v) for k, v in
                        (kv.split("=") for kv in skey.split(","))}
            except (ValueError, AttributeError):
                continue
            if set(dims) != set(KERNEL_DIMS[kernel]):
                continue
            out.setdefault(kernel, []).append(dims)
    return out


def merged_shapes(path: str | Path | None = None) -> dict:
    """DEFAULT_SHAPES grown by the sidecar's logged shapes (dedup'd) —
    the sweep set of ``--from-log``."""
    out = {k: [dict(d) for d in v] for k, v in DEFAULT_SHAPES.items()}
    for kernel, shapes in load_shape_log(path).items():
        for dims in shapes:
            if dims not in out.setdefault(kernel, []):
                out[kernel].append(dims)
    return out


def validate_table(path: str | Path | None = None) -> list[str]:
    """Structural check for the CI gate: every entry must parse, name a
    known kernel, carry a well-formed shape key, and pass the legality
    screen against its own dims. Returns problem strings (empty = OK;
    a missing table is OK — the fallback is the contract)."""
    p = Path(path) if path is not None else table_path()
    if not p.exists():
        return []
    try:
        table = json.loads(p.read_text())
    except (OSError, ValueError) as e:
        return [f"{p}: unparseable ({e})"]
    problems = []
    if table.get("version") != TABLE_VERSION:
        problems.append(f"{p}: version {table.get('version')!r} "
                        f"!= {TABLE_VERSION}")
    for cfg, kernels in table.get("configs", {}).items():
        for kernel, entries in kernels.items():
            if kernel not in KERNELS:
                problems.append(f"{cfg}: unknown kernel {kernel!r}")
                continue
            for skey, entry in entries.items():
                try:
                    dims = {k: int(v) for k, v in
                            (kv.split("=") for kv in skey.split(","))}
                except ValueError:
                    problems.append(f"{cfg}/{kernel}: bad shape key {skey!r}")
                    continue
                if set(dims) != set(KERNEL_DIMS[kernel]):
                    problems.append(f"{cfg}/{kernel}: {skey!r} dims != "
                                    f"{KERNEL_DIMS[kernel]}")
                    continue
                if not valid_params(kernel, dims, entry.get("params")):
                    problems.append(
                        f"{cfg}/{kernel}/{skey}: illegal params "
                        f"{entry.get('params')!r}")
    return problems


# ---------------------------------------------------------------------------
# Candidate generation from the roofline bounds
# ---------------------------------------------------------------------------

def _divisors(n: int, lo: int = 1, hi: int | None = None) -> list[int]:
    hi = n if hi is None else min(hi, n)
    return [d for d in range(lo, hi + 1) if n % d == 0]


def _pow2_range(lo: int, hi: int) -> list[int]:
    out, v = [], lo
    while v <= hi:
        out.append(v)
        v *= 2
    return out


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def _tile_footprint(kernel: str, dims: dict, p: dict) -> int:
    """Resident VMEM bytes of one grid step (inputs + scratch + output).

    An estimate, not a Mosaic allocation — the point is to *rank and
    prune* candidates against :func:`repro.analysis.roofline.vmem_budget`
    before spending a compile on them.
    """
    d = dims
    if kernel == "ternary_matmul":
        bm, bk, bn = p["bm"], p["bk"], p["bn"]
        return bm * bk + bk // 4 * bn + 2 * bm * bn * 4
    if kernel == "qlinear":
        bm, bn, bkq, eg = p["bm"], p["bn"], p["bkq"], p["eg"]
        k = d["k"]
        x_tile = eg * bm * (bkq if bkq else k) * 4
        scratch = eg * bm * k + eg * bm * 8 + (eg * bm * 4 if bkq else 0)
        return (x_tile + eg * (k // 4) * bn + eg * bn * 4
                + eg * bm * bn * 4 + scratch)
    if kernel == "ffn":
        bm, bf, bn, bkq = p["bm"], p["bf"], p["bn"], p["bkq"]
        k, f = d["k"], d["f"]
        x_tile = bm * (bkq if bkq else k) * 4
        scratch = bm * k + bm * 8 + bm * f * 5 + (bm * 4 if bkq else 0)
        return (x_tile + 2 * (k // 4) * bf + f // 4 * bn
                + bm * bn * 4 + scratch)
    if kernel == "prefill":
        block = p["block"]
        bq = p["bq"] or d["r"]
        dh = d["d"]
        q_tiles = bq * dh + bq * 4                        # int8 q + f32 scale
        kv_tiles = 2 * block * dh + 2 * block * 4
        scratch = bq * 128 * 4 * 2 + bq * dh * 4
        return q_tiles + kv_tiles + bq * dh * 4 + scratch
    if kernel == "decode":
        ns = p["n_slots"]
        g, dh, block, m = d["g"], d["d"], d["block"], d["m"]
        nbp = _round_up(m // block, 128)
        slots = ns * (2 * block * dh + 2 * block * 4)
        scratch = 2 * g * nbp * 4 + g * 128 * 4 * 2 + g * dh * 4
        return g * dh + g * 4 + block * dh // 2 + slots + scratch
    raise ValueError(kernel)


def _tile_intensity(kernel: str, dims: dict, p: dict) -> float:
    """Arithmetic intensity of one grid step: MXU FLOPs over the HBM bytes
    the step's input windows stream in (output + resident scratch are
    amortized). Ranks candidates toward the roofline ridge."""
    d = dims
    if kernel == "ternary_matmul":
        bm, bk, bn = p["bm"], p["bk"], p["bn"]
        return arithmetic_intensity(2 * bm * bk * bn,
                                    bm * bk + bk // 4 * bn)
    if kernel == "qlinear":
        bm, bn, bkq, eg = p["bm"], p["bn"], p["bkq"], p["eg"]
        k = d["k"]
        flops = 2 * eg * bm * k * bn
        x_bytes = eg * bm * (bkq if bkq else k) * 4
        return arithmetic_intensity(flops, x_bytes + eg * (k // 4) * bn)
    if kernel == "ffn":
        bm, bf, bn, bkq = p["bm"], p["bf"], p["bn"], p["bkq"]
        k, f = d["k"], d["f"]
        flops = 2 * bm * k * bf * 2 + 2 * bm * f * bn
        x_bytes = bm * (bkq if bkq else k) * 4
        return arithmetic_intensity(flops, x_bytes + 2 * (k // 4) * bf
                                    + f // 4 * bn)
    if kernel == "prefill":
        block = p["block"]
        bq = p["bq"] or d["r"]
        dh = d["d"]
        flops = 2 * bq * block * dh * 2
        return arithmetic_intensity(flops, bq * dh + 2 * block * dh)
    if kernel == "decode":
        g, dh, block = d["g"], d["d"], d["block"]
        flops = 2 * g * block * dh * 2
        # deeper pipelines hide latency, not bytes; nudge the rank so the
        # sweep tries them in order
        return arithmetic_intensity(flops, 2 * block * dh) + p["n_slots"]
    raise ValueError(kernel)


def candidates(kernel: str, dims: dict, *,
               max_candidates: int = 12) -> list[dict]:
    """Legal tiling candidates, VMEM-pruned, AI-ranked (best first).

    The hardcoded default shape is always candidate 0 so a sweep can
    never regress dispatch below the status quo.
    """
    d = dims
    raw: list[dict] = []
    if kernel == "ternary_matmul":
        for bm in _pow2_range(8, min(256, _round_up(max(d["m"], 1), 8))):
            for bk in _divisors(d["k"], 32, 1024):
                for bn in _divisors(d["n"], 32, 512):
                    raw.append({"bm": bm, "bk": bk, "bn": bn})
        default = {"bm": min(128, _round_up(max(d["m"], 1), 8)),
                   "bk": min(512, d["k"]), "bn": min(128, d["n"])}
    elif kernel == "qlinear":
        for bm in _pow2_range(8, min(256, _round_up(max(d["m"], 1), 8))):
            for bn in _divisors(d["n"], 32, 512):
                for bkq in [0] + _divisors(d["k"], 128, 1024):
                    for eg in _divisors(d["e"], 1, 8):
                        raw.append({"bm": bm, "bn": bn, "bkq": bkq,
                                    "eg": eg})
        default = {"bm": min(128, _round_up(max(d["m"], 1), 8)),
                   "bn": _fallback_block(d["n"]), "bkq": 0, "eg": 1}
    elif kernel == "ffn":
        for bm in _pow2_range(8, min(256, _round_up(max(d["m"], 1), 8))):
            for bf in _divisors(d["f"], 32, 512):
                for bn in _divisors(d["n"], 32, 512):
                    for bkq in [0] + _divisors(d["k"], 128, 1024):
                        raw.append({"bm": bm, "bf": bf, "bn": bn,
                                    "bkq": bkq})
        default = {"bm": min(128, _round_up(max(d["m"], 1), 8)),
                   "bf": _fallback_block(d["f"]),
                   "bn": _fallback_block(d["n"]), "bkq": 0}
    elif kernel == "prefill":
        for block in _pow2_range(32, 512):
            for bq in [0] + _divisors(d["r"], 8, d["r"]):
                raw.append({"block": block, "bq": bq})
        default = {"block": min(128, d["m"]), "bq": 0}
    elif kernel == "decode":
        raw = [{"n_slots": ns} for ns in (1, 2, 3, 4, 6, 8)]
        default = {"n_slots": 2}
    else:
        raise ValueError(kernel)

    budget = vmem_budget()
    legal = [p for p in raw
             if valid_params(kernel, d, p)
             and _tile_footprint(kernel, d, p) <= budget]
    legal.sort(key=lambda p: _tile_intensity(kernel, d, p), reverse=True)
    out = [default] if valid_params(kernel, d, default) else []
    for p in legal:
        if p not in out:
            out.append(p)
        if len(out) >= max_candidates:
            break
    return out


def _fallback_block(n: int, target: int = 128) -> int:
    """ops._pick_block, restated here to describe the default candidate."""
    if n <= target:
        return n
    for b in range(target, 0, -1):
        if n % b == 0:
            return b
    return 1


# ---------------------------------------------------------------------------
# The sweep: time candidates on synthetic operands, keep the winner
# ---------------------------------------------------------------------------

def _bench(fn, repeats: int) -> float:
    """Median wall-µs of ``fn`` (one untimed warmup absorbs the compile)."""
    import jax
    jax.block_until_ready(fn())
    samples = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        samples.append((time.perf_counter() - t0) * 1e6)
    samples.sort()
    return samples[len(samples) // 2]


def _make_runner(kernel: str, dims: dict):
    """Synthetic operands for one workload shape → ``run(params) -> fn``."""
    import importlib
    import numpy as np
    import jax.numpy as jnp
    # the package __init__ re-exports ops wrappers under the same names,
    # shadowing the submodule attributes — bind the modules explicitly
    _dec = importlib.import_module("repro.kernels.decode_attention")
    _pf = importlib.import_module("repro.kernels.prefill_attention")
    _ql = importlib.import_module("repro.kernels.qlinear")
    _tmm = importlib.import_module("repro.kernels.ternary_matmul")

    interpret = _interpret()
    rng = np.random.default_rng(0)
    d = dims

    def i8(*shape):
        return jnp.asarray(rng.integers(-127, 128, size=shape), jnp.int8)

    def u8(*shape):
        return jnp.asarray(rng.integers(0, 256, size=shape), jnp.uint8)

    def f32(*shape, lo=0.01, hi=0.1):
        return jnp.asarray(rng.uniform(lo, hi, size=shape), jnp.float32)

    if kernel == "ternary_matmul":
        x = i8(_round_up(max(d["m"], 1), 8), d["k"])
        wp = u8(d["k"] // 4, d["n"])
        return lambda p: lambda: _tmm.ternary_matmul(
            x, wp, d["k"], bm=min(p["bm"], x.shape[0]), bk=p["bk"],
            bn=p["bn"], interpret=interpret)
    if kernel == "qlinear":
        m = _round_up(max(d["m"], 1), 8)
        x = f32(d["e"], m, d["k"], lo=-1.0, hi=1.0)
        wp = u8(d["e"], d["k"] // 4, d["n"])
        sc = f32(d["e"], 1, d["n"])
        return lambda p: lambda: _ql.fused_qlinear(
            x, wp, sc, None, bm=min(p["bm"], m), bn=p["bn"], bkq=p["bkq"],
            eg=p["eg"], act="silu", interpret=interpret)
    if kernel == "ffn":
        m = _round_up(max(d["m"], 1), 8)
        x = f32(d["e"], m, d["k"], lo=-1.0, hi=1.0)
        gup = u8(d["e"], d["k"] // 4, 2 * d["f"])
        gus = f32(d["e"], 1, 2 * d["f"])
        dp = u8(d["e"], d["f"] // 4, d["n"])
        ds = f32(d["e"], 1, d["n"])
        return lambda p: lambda: _ql.fused_ffn(
            x, gup, gus, dp, ds, bm=min(p["bm"], m), bf=p["bf"], bn=p["bn"],
            bkq=p["bkq"], act="silu", gated=True, interpret=interpret)
    if kernel == "prefill":
        qi = i8(d["bhg"], d["r"], d["d"])
        qsc = f32(d["bhg"], d["r"], 1)
        kv_len = jnp.full((d["bhg"],), d["m"], jnp.int32)
        po = jnp.zeros((1,), jnp.int32)

        def run(p):
            m = _round_up(d["m"], p["block"])
            kc, vc = i8(d["bhg"], m, d["d"]), i8(d["bhg"], m, d["d"])
            ks, vs = f32(d["bhg"], m, 1), f32(d["bhg"], m, 1)
            return lambda: _pf.fused_prefill_attention(
                qi, qsc, kc, vc, ks, vs, kv_len, po, hkv=1,
                chunk=d["chunk"], block=p["block"], bq=p["bq"], causal=True,
                window=0, softmax_scale=d["d"] ** -0.5, interpret=interpret)
        return run
    if kernel == "decode":
        qi = i8(d["bhg"], d["g"], d["d"])
        qsc = f32(d["bhg"], d["g"], 1)
        kc, vc = i8(d["bhg"], d["m"], d["d"]), i8(d["bhg"], d["m"], d["d"])
        ks, vs = f32(d["bhg"], d["m"], 1), f32(d["bhg"], d["m"], 1)
        feat = u8(d["bhg"], d["m"], d["d"] // 2)
        nl = jnp.full((d["bhg"],), d["m"], jnp.int32)
        po = jnp.zeros((1,), jnp.int32)
        return lambda p: lambda: _dec.fused_decode_attention(
            qi, qsc, kc, vc, ks, vs, feat, nl, po, hkv=1, block=d["block"],
            k_keep=d["k_keep"], window=0, softmax_scale=d["d"] ** -0.5,
            n_slots=p["n_slots"], interpret=interpret)
    raise ValueError(kernel)


def _interpret() -> bool:
    import jax
    return jax.default_backend() != "tpu"


def sweep_kernel(kernel: str, dims: dict, *, repeats: int = 3,
                 max_candidates: int = 12, log=None) -> dict:
    """Time the candidate set for one shape; return its table entry."""
    runner = _make_runner(kernel, dims)
    best = None
    for p in candidates(kernel, dims, max_candidates=max_candidates):
        us = _bench(runner(p), repeats)
        if log:
            log(f"  {kernel} {shape_key(kernel, dims)} {p} -> {us:.1f}us")
        if best is None or us < best["us"]:
            best = {"params": p, "us": round(us, 1)}
    return best


# serving-ish workload shapes swept by default (small enough for
# interpret mode; a TPU run sweeps the same keys under its own config)
DEFAULT_SHAPES: dict[str, list[dict]] = {
    "ternary_matmul": [{"m": 8, "k": 256, "n": 256}],
    "qlinear": [{"e": 1, "m": 8, "k": 256, "n": 256}],
    "ffn": [{"e": 1, "m": 8, "k": 256, "f": 512, "n": 256}],
    "prefill": [{"bhg": 2, "r": 64, "d": 64, "m": 256, "chunk": 32}],
    "decode": [{"bhg": 2, "g": 2, "d": 64, "m": 256, "block": 64,
                "k_keep": 2}],
}


def run_sweep(kernels=None, shapes=None, *, out_path=None, repeats: int = 3,
              max_candidates: int = 12, log=print) -> dict:
    """Sweep and merge winners into the table (other configs preserved)."""
    kernels = list(kernels or KERNELS)
    shapes = shapes or DEFAULT_SHAPES
    path = Path(out_path) if out_path is not None else table_path()
    table = load_table(path) or {}
    table.setdefault("version", TABLE_VERSION)
    cfg = table.setdefault("configs", {}).setdefault(config_key(), {})
    for kernel in kernels:
        for dims in shapes.get(kernel, []):
            entry = sweep_kernel(kernel, dims, repeats=repeats,
                                 max_candidates=max_candidates, log=log)
            if entry is not None:
                cfg.setdefault(kernel, {})[shape_key(kernel, dims)] = entry
                if log:
                    log(f"{kernel} {shape_key(kernel, dims)}: "
                        f"best {entry['params']} ({entry['us']}us)")
    save_table(table, path)
    return table


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--kernel", action="append", choices=KERNELS,
                    help="kernel(s) to sweep (default: all)")
    ap.add_argument("--out", default=None, help="table path "
                    "(default: REPRO_TUNE_TABLE or TUNE_kernels.json)")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--max-candidates", type=int, default=12)
    ap.add_argument("--from-log", nargs="?", const=True, default=None,
                    metavar="PATH",
                    help="grow the swept-shape set from a serving shape "
                         "log (PooledEngine shape_log= / REPRO_SHAPE_LOG "
                         "sidecar; default path TUNE_shapes.json)")
    ap.add_argument("--check", action="store_true",
                    help="validate the table instead of sweeping")
    args = ap.parse_args(argv)
    if args.check:
        problems = validate_table(args.out)
        for p in problems:
            print(f"autotune: {p}", file=sys.stderr)
        print(f"autotune: table "
              f"{'INVALID' if problems else 'OK'} ({table_path()})")
        return 1 if problems else 0
    shapes = None
    if args.from_log is not None:
        log_path = None if args.from_log is True else args.from_log
        shapes = merged_shapes(log_path)
        n_logged = sum(len(v) for v in load_shape_log(log_path).values())
        print(f"autotune: sweeping {n_logged} logged serving shape(s) "
              f"on top of the defaults")
    run_sweep(args.kernel, shapes, out_path=args.out, repeats=args.repeats,
              max_candidates=args.max_candidates)
    return 0


if __name__ == "__main__":
    sys.exit(main())
