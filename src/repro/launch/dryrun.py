import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this builds ShapeDtypeStruct stand-ins for every input (no
device allocation), jits the step with explicit in/out shardings on the
production mesh, ``.lower().compile()``s it, and records::

    memory_analysis()   — per-device bytes (proves it fits 16 GB HBM)
    cost_analysis()     — per-device HLO FLOPs / bytes (roofline terms)
    collective bytes    — parsed from compiled.as_text()  (§Roofline)

Usage:
    python -m repro.launch.dryrun --arch mixtral-8x22b --shape train_4k \
        [--multi-pod] [--out experiments/dryrun] [--n-micro 1]

Exit code 0 = compile succeeded (or the cell is a documented skip).
"""

import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.analysis.hlo import collective_bytes
from repro.analysis.roofline import (active_params, count_params,
                                     model_flops, roofline_terms)
from repro.configs.base import (SHAPES, all_configs, get_config, input_specs,
                                shape_applicable)
from repro.distributed.partitioning import (dp_axes, logical_to_pspec,
                                            tree_pspecs, use_mesh)
from repro.launch.mesh import make_production_mesh
from repro.models.transformer import init_params
from repro.serving.cache import cache_pspecs, init_cache
from repro.serving.engine import prefill, serve_step
from repro.serving.quantize import quantize_params
from repro.training.optimizer import adamw_init
from repro.training.train import make_train_step


# ---------------------------------------------------------------------------
# Abstract (allocation-free) param/state construction
# ---------------------------------------------------------------------------

def abstract_params(cfg):
    """(ShapeDtypeStruct params, logical pspecs) without allocating."""
    captured = {}

    def build(key):
        p, s = init_params(cfg, key)
        captured["specs"] = s
        return p

    p_sds = jax.eval_shape(build, jax.ShapeDtypeStruct((2,), jnp.uint32))
    return p_sds, captured["specs"]


def qparam_pspecs(pspecs, qparams_sds):
    """Map original param pspecs onto the quantized (packed/fused) tree.

    Fused serving nodes (DESIGN.md §TINT-projection-fusion) inherit the
    spec of a representative source projection: ``wqkv`` ← ``wq``,
    ``wkv`` ← ``wk``, the whole-FFN gu/down streams ← ``w_up``/
    ``w_down``. Scales replicate; segment sizes that no longer divide
    the mesh axis fall back to replicated via ``_shardings``.
    """
    def wspec(sp):
        return sp["w"] if isinstance(sp, dict) and "w" in sp else sp

    def walk(sp, qp):
        if isinstance(qp, dict) and "packed" in qp:
            out = {"packed": wspec(sp),
                   "scale": (None,) * qp["scale"].ndim}
            if "b" in qp:
                out["b"] = sp["b"] if isinstance(sp, dict) and "b" in sp \
                    else (None,) * qp["b"].ndim
            return out
        if isinstance(qp, dict) and "gu_packed" in qp:
            out = {"gu_packed": wspec(sp["w_up"]),
                   "gu_scale": (None,) * qp["gu_scale"].ndim,
                   "down_packed": wspec(sp["w_down"]),
                   "down_scale": (None,) * qp["down_scale"].ndim}
            for k, v in qp.items():
                if k not in out:
                    out[k] = walk(sp[k], v)
            return out
        if isinstance(qp, dict):
            src = {"wqkv": "wq", "wkv": "wk"}
            return {k: walk(sp[src.get(k, k)], v) for k, v in qp.items()}
        return sp

    return walk(pspecs, qparams_sds)


def _axis_size(mesh, entry) -> int:
    import math
    if entry is None:
        return 1
    if isinstance(entry, str):
        return int(mesh.shape[entry])
    return math.prod(int(mesh.shape[a]) for a in entry)


def _shardings(mesh, logical_tree, sds_tree=None):
    """Logical trees → NamedShardings; with ``sds_tree`` given, axes whose
    size doesn't divide the mesh extent are dropped to replicated (keeps
    reduced/smoke configs and odd head counts legal)."""
    from repro.distributed.partitioning import is_spec_leaf

    def one(axes, sds=None):
        spec = logical_to_pspec(axes, mesh)
        if sds is not None:
            entries = list(spec) + [None] * (sds.ndim - len(spec))
            fixed = [e if (e is None or sds.shape[i] % _axis_size(mesh, e)
                           == 0) else None
                     for i, e in enumerate(entries[:sds.ndim])]
            spec = jax.sharding.PartitionSpec(*fixed)
        return NamedSharding(mesh, spec)

    if sds_tree is None:
        return jax.tree.map(one, logical_tree, is_leaf=is_spec_leaf)
    flat_spec, treedef = jax.tree.flatten(logical_tree,
                                          is_leaf=is_spec_leaf)
    flat_sds = treedef.flatten_up_to(sds_tree)
    return treedef.unflatten([one(s, x) for s, x in zip(flat_spec,
                                                        flat_sds)])


# ---------------------------------------------------------------------------
# Cell builders
# ---------------------------------------------------------------------------

def build_train_cell(cfg, shape, mesh, *, n_micro: int = 1):
    p_sds, pspecs = abstract_params(cfg)
    opt_sds = jax.eval_shape(adamw_init, p_sds)
    opt_specs = type(opt_sds)(step=(), m=pspecs, v=pspecs)

    batch_sds = input_specs(cfg, shape)
    batch_specs = {k: ("dp",) + (None,) * (v.ndim - 1)
                   for k, v in batch_sds.items()}

    step = make_train_step(cfg, n_micro=n_micro)
    in_sh = (_shardings(mesh, pspecs, p_sds),
             _shardings(mesh, opt_specs, opt_sds),
             _shardings(mesh, batch_specs, batch_sds))
    out_sh = (in_sh[0], in_sh[1],
              jax.tree.map(lambda _: NamedSharding(mesh, P()),
                           {"loss": 0, "grad_norm": 0, "lr": 0,
                            "param_norm": 0, "ce": 0, "aux": 0}))
    fn = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                 donate_argnums=(0, 1))
    args = (p_sds, opt_sds, batch_sds)
    return fn, args, p_sds


def build_prefill_cell(cfg, shape, mesh):
    p_sds, pspecs = abstract_params(cfg)
    qp_sds = jax.eval_shape(lambda p: quantize_params(cfg, p), p_sds)
    qspecs = qparam_pspecs(pspecs, qp_sds)

    batch_sds = input_specs(cfg, shape)
    batch_specs = {k: ("dp",) + (None,) * (v.ndim - 1)
                   for k, v in batch_sds.items()}

    sp_axes, align, batch_ax = decode_sharding(cfg, mesh,
                                               shape.global_batch)

    def fn_prefill(qp, batch):
        return prefill(cfg, qp, batch["tokens"],
                       frames=batch.get("frames"),
                       patches=batch.get("patches"),
                       cache_align=align)

    cache_like = jax.eval_shape(
        lambda qp, b: fn_prefill(qp, b)[1], qp_sds, batch_sds)
    c_specs = cache_pspecs(cfg, cache_like, batch_axes=batch_ax,
                           seq_axes="sp")
    out_sh = (NamedSharding(mesh, logical_to_pspec(("dp", "tp"), mesh)),
              _shardings(mesh, c_specs, cache_like))
    fn = jax.jit(fn_prefill,
                 in_shardings=(_shardings(mesh, qspecs, qp_sds),
                               _shardings(mesh, batch_specs, batch_sds)),
                 out_shardings=out_sh)
    return fn, (qp_sds, batch_sds), p_sds


def decode_sharding(cfg, mesh, batch: int):
    """(sp_axes, capacity alignment, batch logical axis) for decode cells."""
    import math
    dp = math.prod(int(mesh.shape[a]) for a in dp_axes(mesh))
    if batch % dp == 0 and batch >= dp:
        sp_axes = ("model",)
        batch_ax = "dp"
    else:
        # batch too small to shard (long_500k B=1): fold data into SP
        sp_axes = ("data", "model")
        batch_ax = None
    nsh = math.prod(int(mesh.shape[a]) for a in sp_axes)
    return sp_axes, nsh * cfg.lop_block, batch_ax


def build_decode_cell(cfg, shape, mesh):
    p_sds, pspecs = abstract_params(cfg)
    qp_sds = jax.eval_shape(lambda p: quantize_params(cfg, p), p_sds)
    qspecs = qparam_pspecs(pspecs, qp_sds)

    b = shape.global_batch
    sp_axes, align, batch_ax = decode_sharding(cfg, mesh, b)
    cache_sds = jax.eval_shape(
        lambda: init_cache(cfg, b, shape.seq_len, align=align))
    c_specs = cache_pspecs(cfg, cache_sds, batch_axes=batch_ax,
                           seq_axes=sp_axes)
    tok_sds = input_specs(cfg, shape)["tokens"]
    tok_spec = (batch_ax, None)

    use_sp = cfg.family != "ssm"

    def fn_decode(qp, cache, tokens):
        return serve_step(cfg, qp, cache, tokens,
                          sp_axes=sp_axes if use_sp else None)

    cache_sh = _shardings(mesh, c_specs, cache_sds)
    fn = jax.jit(fn_decode,
                 in_shardings=(_shardings(mesh, qspecs, qp_sds), cache_sh,
                               NamedSharding(mesh,
                                             logical_to_pspec(tok_spec,
                                                              mesh))),
                 out_shardings=(
                     NamedSharding(mesh,
                                   logical_to_pspec((batch_ax, "tp"), mesh)),
                     cache_sh),
                 donate_argnums=(1,))
    return fn, (qp_sds, cache_sds, tok_sds), p_sds


# ---------------------------------------------------------------------------
# Run one cell
# ---------------------------------------------------------------------------

def _build_and_compile(cfg, shape, mesh, *, n_micro: int):
    with use_mesh(mesh):
        if shape.kind == "train":
            fn, args, p_sds = build_train_cell(cfg, shape, mesh,
                                               n_micro=n_micro)
        elif shape.kind == "prefill":
            fn, args, p_sds = build_prefill_cell(cfg, shape, mesh)
        else:
            fn, args, p_sds = build_decode_cell(cfg, shape, mesh)
        compiled = fn.lower(*args).compile()
    return compiled, p_sds


def _variant_cfg(cfg, n_units: int):
    """Depth-``n_units`` variant for differential costing."""
    kw = {}
    if cfg.family == "hybrid":
        kw["n_layers"] = n_units * cfg.attn_every
    else:
        kw["n_layers"] = n_units
    if cfg.family == "encdec":
        kw["n_encoder_layers"] = n_units
    return cfg.replace(**kw)


def _depth_units(cfg) -> int:
    return (cfg.n_layers // cfg.attn_every if cfg.family == "hybrid"
            else cfg.n_layers)


def _cost_dict(compiled) -> dict:
    from repro.analysis.hlo import hbm_bytes
    cost = compiled.cost_analysis()
    txt = compiled.as_text()
    coll = collective_bytes(txt)
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "hbm": float(hbm_bytes(txt)),
            "coll": float(coll["total"])}


def differential_cost(cfg, shape, mesh) -> dict:
    """Per-layer-exact cost via small *unrolled* variants.

    XLA's cost_analysis counts while bodies once, so the full-depth compile
    under-counts loop content. We compile depth-1/depth-2 variants with
    every structural scan unrolled (REPRO_UNROLL_SCANS=1) at up to two
    small batch sizes, then decompose each quantity into batch-FIXED
    (weight all-gathers, grad reductions, optimizer) and batch-LINEAR
    (activations) parts:

        Δ(B)   = u(2,B) − u(1,B)            one exact layer at batch B
        base(B)= u(1,B) − Δ(B)              embed/head/loss/opt overhead
        total  = base(B*) + L·Δ(B*)         linear in B between the probes

    Token-level recurrences (Mamba/RWKV) stay scanned — <1% of flops
    (audited in DESIGN.md §Roofline-accounting).
    """
    from repro.configs.base import ShapeConfig
    B = shape.global_batch
    # prefill has no optimizer → every quantity is batch-linear: one batch
    # point + linear scaling is exact for flops/hbm (weight all-gathers get
    # conservatively overestimated by the scaling; noted in EXPERIMENTS.md).
    # train/decode get the two-point fixed/linear decomposition.
    if B <= 16 or shape.kind == "prefill":
        b_points = [min(B, 16)]
    else:
        b_points = [16, 32]

    os.environ["REPRO_UNROLL_SCANS"] = "1"
    os.environ["REPRO_ATTN_CHUNK"] = "2048"
    u = {}
    try:
        for b in b_points:
            sh = ShapeConfig(shape.name, shape.seq_len, b, shape.kind)
            for units in (1, 2):
                t0 = time.time()
                c, _ = _build_and_compile(_variant_cfg(cfg, units), sh,
                                          mesh, n_micro=1)
                u[(units, b)] = _cost_dict(c)
                del c
                print(f"  [probe u{units} b{b}] {time.time()-t0:.0f}s",
                      flush=True)
    finally:
        os.environ.pop("REPRO_UNROLL_SCANS", None)
        os.environ.pop("REPRO_ATTN_CHUNK", None)

    ell = _depth_units(cfg)
    keys = ("flops", "bytes", "hbm", "coll")

    def interp(lo: dict, hi: dict | None, b_lo: int, b_hi: int | None):
        if hi is None:
            return lo
        return {k: lo[k] + (B - b_lo) * (hi[k] - lo[k]) / (b_hi - b_lo)
                for k in keys}

    if len(b_points) == 1:
        b0 = b_points[0]
        scale = B / b0
        delta = {k: (u[(2, b0)][k] - u[(1, b0)][k]) * scale for k in keys}
        base = {k: (u[(1, b0)][k]) * scale - delta[k] for k in keys}
    else:
        b_lo, b_hi = b_points
        d_lo = {k: u[(2, b_lo)][k] - u[(1, b_lo)][k] for k in keys}
        d_hi = {k: u[(2, b_hi)][k] - u[(1, b_hi)][k] for k in keys}
        base_lo = {k: u[(1, b_lo)][k] - d_lo[k] for k in keys}
        base_hi = {k: u[(1, b_hi)][k] - d_hi[k] for k in keys}
        delta = interp(d_lo, d_hi, b_lo, b_hi)
        base = interp(base_lo, base_hi, b_lo, b_hi)

    out = {k: base[k] + ell * delta[k] for k in keys}
    out["per_layer"] = delta
    out["base"] = base
    out["probes"] = {f"u{units}_b{b}": v for (units, b), v in u.items()}
    return out


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             n_micro: int | None = None, differential: bool = True,
             verbose: bool = True, cfg=None) -> dict:
    cfg = cfg or get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "kind": shape.kind}

    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        result["status"] = "skip"
        result["reason"] = reason
        return result

    if n_micro is None:
        n_micro = 8 if shape.kind == "train" else 1

    mesh = make_production_mesh(multi_pod=multi_pod)
    print(f"[{arch} × {shape_name} × {mesh_name}] compiling...", flush=True)
    t0 = time.time()
    compiled, p_sds = _build_and_compile(cfg, shape, mesh, n_micro=n_micro)
    t_compile = time.time() - t0
    print(f"  [real cell] {t_compile:.0f}s", flush=True)

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    txt = compiled.as_text()
    coll = collective_bytes(txt)
    raw = {"flops": float(cost.get("flops", 0.0)),
           "bytes": float(cost.get("bytes accessed", 0.0)),
           "coll": coll}
    del compiled, txt

    # differential costing (single-pod roofline table only — brief §Roofline)
    corrected = None
    if differential and not multi_pod:
        t1 = time.time()
        corrected = differential_cost(cfg, shape, mesh)
        corrected["variant_compile_s"] = round(time.time() - t1, 1)

    n_params = count_params(p_sds)
    n_active = active_params(cfg, n_params, p_sds)
    chips = 512 if multi_pod else 256
    mf_global = model_flops(cfg, shape, n_params, n_active)
    if corrected is not None:
        # memory term from the fused-HBM model; raw bytes kept as the
        # unfused upper bound (analysis/hlo.py)
        eff_cost = {"flops": corrected["flops"],
                    "bytes accessed": corrected["hbm"]}
        eff_coll = {"total": corrected["coll"]}
    else:
        eff_cost = {"flops": raw["flops"], "bytes accessed": raw["bytes"]}
        eff_coll = {"total": raw["coll"]["total"]}
    terms = roofline_terms(eff_cost, eff_coll,
                           model_flops_per_chip=mf_global / chips)
    if corrected is not None:
        terms["memory_s_raw_upper"] = corrected["bytes"] / 819e9

    result.update({
        "status": "ok",
        "chips": chips,
        "n_params": n_params,
        "n_active_params": n_active,
        "n_micro": n_micro,
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_estimate_bytes": (mem.argument_size_in_bytes
                                    + mem.output_size_in_bytes
                                    + mem.temp_size_in_bytes
                                    - mem.alias_size_in_bytes),
        },
        "roofline": terms,
        "raw_scan_cost": raw,
        "differential": corrected,
    })
    if verbose:
        print(f"[{arch} × {shape_name} × {mesh_name}] OK "
              f"compile {t_compile:.0f}s "
              f"dominant={terms['dominant']} bound={terms['bound_s']:.2e}s "
              f"peak/dev={result['memory']['peak_estimate_bytes']/2**30:.2f}"
              f"GiB")
        print("memory_analysis:", mem)
        print("cost_analysis flops:", cost.get("flops"),
              "bytes:", cost.get("bytes accessed"))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="architecture id (default: all)")
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None],
                    help="input shape (default: all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--n-micro", type=int, default=None,
                    help="default: 8 for train cells, 1 otherwise")
    ap.add_argument("--no-differential", action="store_true",
                    help="skip the costing probes (compile proof only)")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(all_configs())
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape_name}__{'mp' if mp else 'sp'}"
                try:
                    res = run_cell(arch, shape_name, multi_pod=mp,
                                   n_micro=args.n_micro,
                                   differential=not args.no_differential)
                except Exception as e:   # noqa: BLE001 — report, keep going
                    res = {"arch": arch, "shape": shape_name,
                           "mesh": "pod2x16x16" if mp else "pod16x16",
                           "status": "fail", "error": repr(e),
                           "traceback": traceback.format_exc()}
                    failures += 1
                    print(f"[{tag}] FAIL: {e}")
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(res, f, indent=1)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
