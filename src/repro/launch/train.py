"""End-to-end training driver (QAT BitNet) with fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch bitnet-3b --reduced \
        --steps 200 --global-batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Features exercised end-to-end: synthetic sharded data pipeline, QAT train
step (STE ternary + absmax int8), grad accumulation, warmup-cosine AdamW,
step-level checkpoint/restart (atomic manifests), preemption handling
(SIGTERM → checkpoint + clean exit), straggler monitoring.
"""

from __future__ import annotations

import argparse
import importlib
import time

import jax
import numpy as np

from repro.checkpoint.store import (latest_step, load_checkpoint,
                                    save_checkpoint)
from repro.configs.base import get_config
from repro.data.pipeline import SyntheticDataset
from repro.distributed.fault_tolerance import (PreemptionHandler,
                                               StragglerMonitor)
from repro.models.transformer import init_params
from repro.training.optimizer import adamw_init
from repro.training.train import make_train_step

_REDUCED_MODULES = {
    "mixtral-8x22b": "mixtral_8x22b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "whisper-small": "whisper_small",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "llava-next-34b": "llava_next_34b",
    "qwen1.5-32b": "qwen1_5_32b",
    "stablelm-1.6b": "stablelm_1_6b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "qwen1.5-110b": "qwen1_5_110b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "bitnet-3b": "bitnet_3b",
}


def resolve_config(arch: str, reduced: bool):
    if reduced:
        mod = importlib.import_module(
            f"repro.configs.{_REDUCED_MODULES[arch]}")
        return mod.REDUCED
    return get_config(arch)


def train_loop(cfg, *, steps: int, global_batch: int, seq_len: int,
               ckpt_dir: str | None = None, ckpt_every: int = 50,
               n_micro: int = 1, peak_lr: float = 1e-3, seed: int = 0,
               log_every: int = 10, preemption: PreemptionHandler | None
               = None, resume: bool = True, hooks=None) -> dict:
    """Returns {"losses": [...], "last_step": n, "straggler": summary}."""
    data = SyntheticDataset(cfg, seq_len=seq_len, global_batch=global_batch,
                            seed=seed)
    params, _ = init_params(cfg, jax.random.PRNGKey(seed))
    opt_state = adamw_init(params)
    start = 0
    if ckpt_dir and resume and latest_step(ckpt_dir) is not None:
        (params, opt_state), start, _ = load_checkpoint(
            ckpt_dir, (params, opt_state))
        print(f"resumed from step {start}")

    step_fn = jax.jit(make_train_step(cfg, n_micro=n_micro, peak_lr=peak_lr,
                                      warmup=max(steps // 10, 1),
                                      total_steps=steps),
                      donate_argnums=(0, 1))
    monitor = StragglerMonitor()
    losses = []
    n = start
    for n in range(start, steps):
        t0 = time.time()
        batch = {k: jax.numpy.asarray(v) for k, v in data.batch(n).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        straggle = monitor.record(time.time() - t0)
        if n % log_every == 0:
            print(f"step {n:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e}"
                  + ("  [straggler]" if straggle else ""))
        if hooks:
            for h in hooks:
                h(n, params, opt_state, metrics)
        if ckpt_dir and (n + 1) % ckpt_every == 0:
            save_checkpoint(ckpt_dir, n + 1, (params, opt_state))
        if preemption is not None and preemption.preempted:
            if ckpt_dir:
                save_checkpoint(ckpt_dir, n + 1, (params, opt_state))
            print(f"preempted at step {n + 1}: checkpointed, exiting")
            break
    if ckpt_dir:
        save_checkpoint(ckpt_dir, n + 1, (params, opt_state))
    return {"losses": losses, "last_step": n + 1,
            "straggler": monitor.summary(), "params": params}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="bitnet-3b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced config (CPU-scale)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = resolve_config(args.arch, args.reduced)
    print(f"training {cfg.name} ({cfg.family}) for {args.steps} steps, "
          f"batch {args.global_batch} × seq {args.seq}")
    pre = PreemptionHandler()
    out = train_loop(cfg, steps=args.steps, global_batch=args.global_batch,
                     seq_len=args.seq, ckpt_dir=args.ckpt_dir,
                     ckpt_every=args.ckpt_every, n_micro=args.n_micro,
                     peak_lr=args.lr, seed=args.seed, preemption=pre)
    first = np.mean(out["losses"][:10])
    last = np.mean(out["losses"][-10:])
    print(f"loss {first:.4f} → {last:.4f} over {out['last_step']} steps")


if __name__ == "__main__":
    main()
