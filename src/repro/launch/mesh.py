"""Production mesh builders (brief §MULTI-POD DRY-RUN).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state.
"""

from __future__ import annotations

import jax


def _mesh(shape, axes):
    """``jax.make_mesh`` across JAX versions.

    Newer JAX exposes ``jax.sharding.AxisType`` and ``make_mesh`` accepts
    ``axis_types``; older releases (e.g. 0.4.x) have neither — every axis
    is implicitly Auto there, so plain ``make_mesh`` is equivalent.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_host_mesh(shape=(2, 4), axes=("data", "model")):
    """Small host-device mesh for subprocess tests (8 CPU devices)."""
    return _mesh(shape, axes)
