"""Launch the production HTTP serving front-end.

    PYTHONPATH=src python -m repro.launch.server --arch bitnet-3b \
        --reduced --port 8000

then stream a completion (the wire speaks token ids — the repo has no
tokenizer):

    curl -N http://127.0.0.1:8000/v1/completions \
        -d '{"prompt": [17, 42, 99], "max_tokens": 8, "stream": true}'

``GET /metrics`` serves the Prometheus-text registry the scheduler
writes into (DESIGN.md §Serving-metrics) — the same metric names
``repro.launch.serve`` reports, so a driver run and a live server are
diffable dashboards. ``--shape-log`` arms the log-and-sweep sidecar:
every distinct kernel dispatch shape the engine traces lands in a JSON
file that ``python -m repro.kernels.autotune --from-log`` sweeps later.
"""

from __future__ import annotations

import argparse
import asyncio

import jax

from repro.launch.train import resolve_config
from repro.models.transformer import init_params
from repro.serving.api import PooledEngine
from repro.serving.frontend import HttpFrontend
from repro.serving.metrics import REGISTRY
from repro.serving.quantize import quantize_params
from repro.serving.scheduler import Scheduler


def build_scheduler(args) -> Scheduler:
    cfg = resolve_config(args.arch, args.reduced)
    params, _ = init_params(cfg, jax.random.PRNGKey(args.seed))
    qp = quantize_params(cfg, params)
    engine = PooledEngine(cfg, qp, max_len=args.max_len,
                          use_lop=not args.no_lop,
                          chunk_tokens=args.chunk_tokens,
                          draft_layers=args.draft_layers,
                          draft_k=args.draft_k,
                          shape_log=args.shape_log)
    return Scheduler(
        cfg, qp, n_slots=args.slots, max_len=args.max_len,
        chunked=not args.no_chunked,
        prefix_cache=not args.no_prefix_cache,
        spec_decode=args.spec_decode, gamma=args.gamma,
        max_queue=args.max_queue, engine=engine, metrics=REGISTRY)


async def amain(args) -> None:
    sched = build_scheduler(args)
    frontend = HttpFrontend(sched, model_name=args.model_name or args.arch,
                            registry=REGISTRY)
    port = await frontend.start(args.host, args.port)
    print(f"serving {args.arch}{' (reduced)' if args.reduced else ''} "
          f"on http://{args.host}:{port}")
    print(f"  curl -N http://{args.host}:{port}/v1/completions "
          "-d '{\"prompt\": [17, 42, 99], \"max_tokens\": 8, "
          "\"stream\": true}'")
    print(f"  curl http://{args.host}:{port}/metrics")
    await frontend.serve_forever()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="bitnet-3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8000,
                    help="0 picks a free port")
    ap.add_argument("--model-name", default=None,
                    help="name reported by /v1/models (default: --arch)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=2048,
                    help="per-slot KV capacity (prompt + generation)")
    ap.add_argument("--max-queue", type=int, default=64,
                    help="admission bound; beyond it requests get 429")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-lop", action="store_true")
    ap.add_argument("--no-chunked", action="store_true")
    ap.add_argument("--chunk-tokens", type=int, default=None)
    ap.add_argument("--no-prefix-cache", action="store_true")
    ap.add_argument("--spec-decode", action="store_true")
    ap.add_argument("--gamma", type=int, default=4)
    ap.add_argument("--draft-layers", type=int, default=None)
    ap.add_argument("--draft-k", type=int, default=None)
    ap.add_argument("--shape-log", default=None,
                    help="JSON sidecar recording kernel dispatch shapes "
                         "for `repro.kernels.autotune --from-log`")
    args = ap.parse_args()
    try:
        asyncio.run(amain(args))
    except KeyboardInterrupt:
        print("shutting down")


if __name__ == "__main__":
    main()
