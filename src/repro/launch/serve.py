"""Continuous-batching serving driver over the typed serving API.

    PYTHONPATH=src python -m repro.launch.serve --arch bitnet-3b --reduced \
        --slots 4 --requests 8 --min-prompt 8 --max-prompt 48 --gen 16

Synthesizes a stream of requests with *staggered arrivals* and *variable
prompt lengths*, drives the :class:`repro.serving.scheduler.Scheduler`
through the :class:`repro.serving.api.InferenceEngine` protocol
(admit → prefill → insert → decode → evict per lane), and reports
per-request latency percentiles — TTFT, end-to-end AND inter-token
latency (ITL p50/p99 over every decode gap) — alongside aggregate
tokens/s and the modeled LOP KV-traffic reduction.

Sampling is per-request (:class:`repro.serving.api.SamplingParams`):
``--temperature/--top-k/--top-p`` apply to every synthetic request (each
gets its own seed), the default being greedy. ``--verify`` replays every
request alone through the lockstep reference path *with the same
sampling params* and checks the continuous-batching run emitted
identical tokens — bitwise for greedy, same-seed identical for sampled.
``--stream`` prints tokens as each lane emits them (the ``on_token``
streaming callback).

Chunked prefill (DESIGN.md §Chunked-prefill) is ON by default when the
engine declares ``supports_chunked``: each serve cycle advances one
fixed-shape prefill chunk AND one decode step, so TTFT is measured
*under interleaving*. ``--no-chunked`` restores run-to-completion
prefill (the ablation baseline); ``--chunk-tokens`` overrides the chunk
size (default: the arch's ``lop_block``).

Self-speculative decoding (DESIGN.md §Speculative-decoding) is opt-in:
``--spec-decode --gamma 4`` drafts γ tokens per lane with a degraded-cost
pass (``--draft-layers`` of the stack, LOP selection pinched to
``--draft-k`` blocks) and verifies all γ+1 positions exactly in ONE
prefill-chunk launch, emitting the agreeing prefix plus the verifier's
bonus token; the report adds accept rate, tokens per verify launch, and
full-model launches per generated token. Greedy speculative runs emit
the plain-decode token stream (``--verify`` still holds).

Prefix caching (DESIGN.md §Prefix-caching) is likewise ON by default
under chunked prefill: ``--shared-prefix-tokens N --prefix-reuse-frac F``
synthesizes a trace where a fraction of requests share one N-token
prompt prefix (a system prompt / few-shot template); the scheduler
prefills it once and clones it into every later sharer, and the report
splits TTFT by prefix hit vs miss plus prefill tokens computed vs
served. ``--no-prefix-cache`` is the cold baseline.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.core.lop import kv_traffic_bytes
from repro.launch.train import resolve_config
from repro.models.transformer import init_params
from repro.serving import metrics as smetrics
from repro.serving.api import GenerateRequest, SamplingParams, StepResult
from repro.serving.quantize import quantize_params
from repro.serving.scheduler import Scheduler, lockstep_generate


def make_requests(cfg, *, n_requests: int, min_prompt: int, max_prompt: int,
                  gen: int, seed: int = 0,
                  sampling: SamplingParams | None = None,
                  shared_prefix_tokens: int = 0,
                  prefix_reuse_frac: float = 1.0,
                  deadline_ms: float | None = None,
                  on_token=None):
    """Synthetic traffic: variable prompt lengths, FIFO arrival order.
    With ``sampling`` given, request ``rid`` gets its params under seed
    ``sampling.seed + rid`` (distinct per-request streams).

    ``shared_prefix_tokens > 0`` models a shared system prompt / few-shot
    template: the first ``round(prefix_reuse_frac * n_requests)`` requests
    prepend ONE common ``shared_prefix_tokens``-token prefix to their
    (still per-request random) suffix; the rest stay fully cold. Prompt
    lengths become ``shared_prefix_tokens + [min_prompt, max_prompt]``
    for sharers."""
    if n_requests < 1:
        raise ValueError(f"--requests must be >= 1, got {n_requests}")
    if not 0 < min_prompt <= max_prompt:
        raise ValueError(f"need 0 < --min-prompt <= --max-prompt, got "
                         f"{min_prompt}..{max_prompt}")
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, cfg.vocab,
                          (shared_prefix_tokens,)).astype(np.int32)
    n_sharers = round(prefix_reuse_frac * n_requests) \
        if shared_prefix_tokens else 0
    reqs = []
    for rid in range(n_requests):
        plen = int(rng.integers(min_prompt, max_prompt + 1))
        prompt = rng.integers(0, cfg.vocab, (plen,)).astype(np.int32)
        if rid < n_sharers:
            prompt = np.concatenate([shared, prompt])
        frames = patches = None
        if cfg.family == "encdec":
            frames = (rng.standard_normal((4 * plen, cfg.d_model))
                      .astype(np.float32) * 0.02)
        if cfg.family == "vlm":
            patches = (rng.standard_normal((cfg.n_img_tokens, cfg.d_model))
                       .astype(np.float32) * 0.02)
        sp = SamplingParams() if sampling is None else \
            dataclasses.replace(sampling, seed=sampling.seed + rid)
        reqs.append(GenerateRequest(
            rid=rid, prompt=prompt, max_new_tokens=gen, sampling=sp,
            deadline_ms=deadline_ms, on_token=on_token, frames=frames,
            patches=patches))
    return reqs


def serve_loop(cfg, *, n_slots: int = 4, n_requests: int = 8,
               min_prompt: int = 8, max_prompt: int = 48, gen: int = 16,
               arrival_period: float = 0.0, seed: int = 0,
               use_lop: bool = True, verify: bool = False,
               chunked: bool | None = None,
               chunk_tokens: int | None = None,
               prefix_cache: bool | None = None,
               shared_prefix_tokens: int = 0,
               prefix_reuse_frac: float = 1.0,
               spec_decode: bool = False, gamma: int = 4,
               draft_layers: int | None = None,
               draft_k: int | None = None,
               sampling: SamplingParams | None = None,
               max_queue: int | None = None,
               deadline_ms: float | None = None,
               on_token=None, engine=None):
    """Continuous-batching run over staggered arrivals. → stats dict.

    ``arrival_period`` (seconds) spaces request arrivals; requests that
    have not arrived yet stay out of the queue, so lanes drain and refill
    mid-run exactly as a live server would. 0 = all arrive at t0 (arrival
    order still staggers admissions once lanes fill).

    ``shared_prefix_tokens``/``prefix_reuse_frac`` shape the trace (see
    :func:`make_requests`); ``prefix_cache`` gates the scheduler's prefix
    store (None = on when chunked). TTFT is reported split by prefix
    hit/miss. An injected ``engine`` is reused across calls (shared jit
    caches — the benchmark's cache-on vs cache-off arms).

    Robustness knobs (DESIGN.md §Fault-tolerance): ``max_queue`` bounds
    the admit queue — submits past the bound answer immediately with
    reason ``"shed"``; ``deadline_ms`` stamps every synthetic request
    with that latency budget, enforced at admit, between prefill chunks
    and per decode sweep (reason ``"deadline"``). The stats dict carries
    the scheduler's robustness telemetry (shed/deadline/fault counters,
    queue-depth peak, prefix checksum failures, watchdog trips).
    ``--verify`` checks token equivalence only for requests that ran to
    a natural finish — shed/deadline/cancelled/fault requests and lanes
    a fault recovery touched have no lockstep counterpart."""
    if engine is not None:
        cfg, qp = engine.cfg, engine.qp
    else:
        params, _ = init_params(cfg, jax.random.PRNGKey(seed))
        qp = quantize_params(cfg, params)
    reqs = make_requests(cfg, n_requests=n_requests, min_prompt=min_prompt,
                         max_prompt=max_prompt, gen=gen, seed=seed + 1,
                         sampling=sampling,
                         shared_prefix_tokens=shared_prefix_tokens,
                         prefix_reuse_frac=prefix_reuse_frac,
                         deadline_ms=deadline_ms, on_token=on_token)
    max_len = max_prompt + gen + shared_prefix_tokens
    if cfg.family == "vlm":
        max_len += cfg.n_img_tokens       # image prefix shares the cache
    # fresh per-run registry: the same metric families the HTTP server
    # exports from /metrics, so a driver run and a live server are
    # diffable dashboards (DESIGN.md §Serving-metrics)
    registry = smetrics.MetricsRegistry()
    sched = Scheduler(cfg, qp, n_slots=n_slots, max_len=max_len,
                      use_lop=use_lop, chunked=chunked,
                      chunk_tokens=None if engine is not None
                      else chunk_tokens,
                      prefix_cache=prefix_cache,
                      spec_decode=spec_decode, gamma=gamma,
                      draft_layers=None if engine is not None
                      else draft_layers,
                      draft_k=None if engine is not None else draft_k,
                      max_queue=max_queue, engine=engine,
                      metrics=registry)

    t0 = time.monotonic()
    pending = list(reqs)
    n_steps = 0
    while pending or sched.has_work():
        now = time.monotonic() - t0
        while pending and now >= pending[0].rid * arrival_period:
            req = pending.pop(0)
            sched.submit(dataclasses.replace(req,
                                             arrival=time.monotonic()))
            now = time.monotonic() - t0
        sched.admit()
        if sched.n_active or sched.n_prefilling:
            sched.step()
            n_steps += 1
        elif pending:
            # idle until the next arrival
            time.sleep(max(0.0,
                           pending[0].rid * arrival_period - now))
    wall = time.monotonic() - t0

    results = sorted(sched.results, key=lambda r: r.rid)
    total_toks = sum(len(r.tokens) for r in results)
    lat = [r.latency for r in results]
    ttft = [r.ttft for r in results]
    ttft_hit = [r.ttft for r in results if r.cached_len] or [np.nan]
    ttft_miss = [r.ttft for r in results if not r.cached_len] or [np.nan]
    itl = [g for r in results for g in r.itl] or [0.0]
    out = {
        "results": results,
        "tokens": {r.rid: np.asarray(r.tokens, np.int32) for r in results},
        "wall_s": wall,
        "decode_steps": n_steps,
        "tokens_per_s": total_toks / max(wall, 1e-9),
        "metrics": registry,
        **smetrics.summarize(lat, (50, 90, 99), prefix="latency_"),
        **smetrics.summarize(ttft, (50, 90, 99), prefix="ttft_"),
        **smetrics.summarize(itl, (50, 99), prefix="itl_"),
        **smetrics.summarize(ttft_hit, (50, 99), prefix="ttft_hit_"),
        **smetrics.summarize(ttft_miss, (50, 99), prefix="ttft_miss_"),
        "prefill_compiles": sched.prefill_compiles,
        "chunked": sched.chunked,
        "interleaved_decode_steps": sched.interleaved_decode_steps,
        "full_prefill_stalls": sched.full_prefill_stalls,
        "prefix_cache": sched.prefix_store is not None,
        "prefix_hits": sched.prefix_hits,
        "prefix_hit_tokens": sched.prefix_hit_tokens,
        "prefill_tokens_computed": sched.prefill_tokens_computed,
        "prefill_tokens_served": sched.prefill_tokens_served,
        "spec_decode": sched.spec,
        "spec_rounds": sched.spec_rounds,
        "spec_drafted": sched.spec_drafted,
        "spec_accepted": sched.spec_accepted,
        "spec_emitted": sched.spec_emitted,
        "spec_verify_launches": sched.spec_verify_launches,
        "draft_launches": sched.draft_launches,
        "decode_launches": sched.decode_launches,
        # draft acceptance rate and decode amortization: full-model
        # launches (plain decode + verify) per token actually generated —
        # < 1.0 is the speculative win
        "spec_accept_rate": (sched.spec_accepted
                             / max(1, sched.spec_drafted)),
        "spec_tokens_per_verify": (sched.spec_emitted
                                   / max(1, sched.spec_verify_launches)),
        "full_launches_per_token": ((sched.decode_launches
                                     + sched.spec_verify_launches)
                                    / max(1, total_toks)),
        # robustness telemetry (DESIGN.md §Fault-tolerance), read back
        # off the metrics registry — the same counters /metrics exports
        "max_queue": max_queue,
        "shed_count": int(registry.value("repro_requests_shed_total")),
        "queue_depth_peak": sched.queue_depth_peak,
        "deadline_ms": deadline_ms,
        "deadline_count": int(
            registry.value("repro_deadline_expired_total")),
        "fault_events": int(registry.value("repro_fault_events_total")),
        "fault_recoveries": int(
            registry.value("repro_fault_recoveries_total")),
        "fault_finishes": int(
            registry.value("repro_fault_finishes_total")),
        "prefix_lookup_failures": sched.prefix_lookup_failures,
        "checksum_failures": (sched.prefix_store.checksum_failures
                              if sched.prefix_store is not None else 0),
        "spec_watchdog_trips": sched.spec_watchdog_trips,
    }

    if verify:
        # only naturally-finished requests have a lockstep counterpart:
        # a shed/deadline/cancelled/fault request was cut off mid-stream,
        # and a lane a fault recovery touched is exact only by the
        # recovery contract, which the chaos test checks separately
        reason = {r.rid: r.finish_reason for r in results}
        mismatches, skipped = [], []
        for req in reqs:
            if reason.get(req.rid) not in ("eos", "stop", "length") \
                    or req.rid in sched.fault_rids:
                skipped.append(req.rid)
                continue
            ref = lockstep_generate(cfg, qp, req.prompt, req.max_new_tokens,
                                    max_len=max_len, use_lop=use_lop,
                                    frames=req.frames, patches=req.patches,
                                    sampling=req.sampling, engine=engine)
            if list(out["tokens"][req.rid]) != ref:
                mismatches.append(req.rid)
        out["verified"] = not mismatches
        out["mismatched_rids"] = mismatches
        out["verify_skipped_rids"] = skipped
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="bitnet-3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--min-prompt", type=int, default=8)
    ap.add_argument("--max-prompt", type=int, default=48)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--arrival-period", type=float, default=0.0,
                    help="seconds between request arrivals (staggered)")
    ap.add_argument("--no-lop", action="store_true")
    ap.add_argument("--no-chunked", action="store_true",
                    help="run-to-completion prefill (disable chunked "
                         "prefill/decode interleaving)")
    ap.add_argument("--chunk-tokens", type=int, default=None,
                    help="prefill chunk size (default: arch lop_block)")
    ap.add_argument("--shared-prefix-tokens", type=int, default=0,
                    help="length of ONE common prompt prefix (a shared "
                         "system prompt) prepended to sharing requests")
    ap.add_argument("--prefix-reuse-frac", type=float, default=1.0,
                    help="fraction of requests sharing the common prefix")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable the scheduler's prefix store (every "
                         "prompt prefills cold)")
    ap.add_argument("--spec-decode", action="store_true",
                    help="self-speculative decoding: draft cheap tokens "
                         "(truncated layer stack + degraded LOP budget), "
                         "verify γ+1 positions in one prefill-chunk "
                         "launch, accept the agreeing prefix")
    ap.add_argument("--gamma", type=int, default=4,
                    help="speculative draft length per verify launch")
    ap.add_argument("--draft-layers", type=int, default=None,
                    help="decoder layers the draft pass runs (default: "
                         "n_layers // 2)")
    ap.add_argument("--draft-k", type=int, default=None,
                    help="LOP blocks the draft attention keeps "
                         "(default: 1)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="per-request sampling temperature (0 = greedy)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="per-request top-k filter (0 = off)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="per-request nucleus filter (1 = off)")
    ap.add_argument("--sample-seed", type=int, default=0,
                    help="base PRNG seed; request rid samples under "
                         "seed+rid")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="admission bound: submits past this queue depth "
                         "are load-shed (reason \"shed\") instead of "
                         "queued unboundedly")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request latency budget from arrival; "
                         "expired requests retire with reason "
                         "\"deadline\" at admit, between prefill chunks "
                         "or mid-decode")
    ap.add_argument("--stream", action="store_true",
                    help="print tokens as lanes emit them (on_token "
                         "streaming callback)")
    ap.add_argument("--verify", action="store_true",
                    help="replay each request alone (lockstep, same "
                         "SamplingParams) and check token-exact agreement")
    args = ap.parse_args()

    cfg = resolve_config(args.arch, args.reduced)
    sampling = SamplingParams(temperature=args.temperature,
                              top_k=args.top_k, top_p=args.top_p,
                              seed=args.sample_seed)
    mode_s = "greedy" if sampling.greedy else (
        f"T={sampling.temperature} top_k={sampling.top_k} "
        f"top_p={sampling.top_p}")
    print(f"serving {cfg.name}: {args.slots} slots, {args.requests} requests"
          f" (prompts {args.min_prompt}-{args.max_prompt}, gen {args.gen}),"
          f" lop={'off' if args.no_lop else 'on'}, sampling {mode_s}")

    on_token = None
    if args.stream:
        def on_token(sr: StepResult):
            flag = f" <{sr.finish_reason}>" if sr.finished else ""
            print(f"  [rid {sr.rid}] #{sr.index} -> {sr.token}{flag}")

    out = serve_loop(cfg, n_slots=args.slots, n_requests=args.requests,
                     min_prompt=args.min_prompt, max_prompt=args.max_prompt,
                     gen=args.gen, arrival_period=args.arrival_period,
                     use_lop=not args.no_lop, verify=args.verify,
                     chunked=not args.no_chunked,
                     chunk_tokens=args.chunk_tokens,
                     prefix_cache=not args.no_prefix_cache,
                     shared_prefix_tokens=args.shared_prefix_tokens,
                     prefix_reuse_frac=args.prefix_reuse_frac,
                     spec_decode=args.spec_decode, gamma=args.gamma,
                     draft_layers=args.draft_layers, draft_k=args.draft_k,
                     sampling=None if sampling.greedy else sampling,
                     max_queue=args.max_queue, deadline_ms=args.deadline_ms,
                     on_token=on_token)

    print(f"{'rid':>4} {'plen':>5} {'hit':>5} {'toks':>5} {'ttft_ms':>8} "
          f"{'latency_ms':>10}  finish")
    for r in out["results"]:
        print(f"{r.rid:>4} {r.prompt_len:>5} {r.cached_len:>5} "
              f"{len(r.tokens):>5} "
              f"{r.ttft * 1e3:>8.1f} {r.latency * 1e3:>10.1f}  "
              f"{r.finish_reason}")
    mode = ("chunked prefill (interleaved; "
            f"{out['interleaved_decode_steps']} decode steps taken while "
            "a prompt was mid-prefill)" if out["chunked"] else
            f"run-to-completion prefill ({out['full_prefill_stalls']} "
            "full-batch stalls)")
    print(f"wall {out['wall_s']:.2f}s, {out['decode_steps']} serve cycles, "
          f"{out['tokens_per_s']:.1f} tok/s, "
          f"{out['prefill_compiles']} prefill compiles, {mode}")
    print(f"latency p50/p90/p99: {out['latency_p50'] * 1e3:.1f} / "
          f"{out['latency_p90'] * 1e3:.1f} / "
          f"{out['latency_p99'] * 1e3:.1f} ms; "
          f"ttft p50/p90: {out['ttft_p50'] * 1e3:.1f} / "
          f"{out['ttft_p90'] * 1e3:.1f} ms; "
          f"itl p50/p99: {out['itl_p50'] * 1e3:.1f} / "
          f"{out['itl_p99'] * 1e3:.1f} ms")
    if out["spec_decode"]:
        print(f"speculative decode: {out['spec_rounds']} rounds, "
              f"accept rate {out['spec_accept_rate']:.2f} "
              f"({out['spec_accepted']}/{out['spec_drafted']} drafts), "
              f"{out['spec_tokens_per_verify']:.2f} tokens/verify launch, "
              f"{out['full_launches_per_token']:.2f} full-model launches "
              f"per token ({out['decode_launches']} decode + "
              f"{out['spec_verify_launches']} verify)")
    if out["prefix_cache"]:
        print(f"prefix cache: {out['prefix_hits']} hits "
              f"({out['prefix_hit_tokens']} tokens served from interned "
              f"pages), prefill tokens computed/served: "
              f"{out['prefill_tokens_computed']}/"
              f"{out['prefill_tokens_served']}; "
              f"ttft p50 hit/miss: {out['ttft_hit_p50'] * 1e3:.1f} / "
              f"{out['ttft_miss_p50'] * 1e3:.1f} ms")
    if args.max_queue is not None or args.deadline_ms is not None \
            or out["shed_count"] or out["deadline_count"] \
            or out["fault_events"]:
        n_req = len(out["results"])
        print(f"robustness: queue peak {out['queue_depth_peak']}"
              f"{f' (bound {args.max_queue})' if args.max_queue else ''}, "
              f"{out['shed_count']} shed, "
              f"{out['deadline_count']} deadline-expired "
              f"(deadline-hit ratio "
              f"{1.0 - out['deadline_count'] / max(1, n_req):.2f}), "
              f"{out['fault_events']} fault events "
              f"({out['fault_recoveries']} recovered, "
              f"{out['fault_finishes']} gave up), "
              f"{out['prefix_lookup_failures']} prefix-lookup failures, "
              f"{out['checksum_failures']} checksum failures, "
              f"{out['spec_watchdog_trips']} spec-watchdog trips")
    if args.verify:
        status = "OK" if out["verified"] else \
            f"MISMATCH rids={out['mismatched_rids']}"
        if out.get("verify_skipped_rids"):
            status += (f" ({len(out['verify_skipped_rids'])} requests "
                       "skipped: no natural finish)")
        print(f"continuous-batching vs lockstep token equivalence: {status}")

    m = args.max_prompt + args.gen
    full = kv_traffic_bytes(m, cfg.hd, m, with_lop=False)
    lop = kv_traffic_bytes(m, cfg.hd, int(m * cfg.lop_keep), with_lop=True)
    print(f"modeled KV traffic/head/query: {full} B dense → {lop} B with LOP"
          f" ({full / lop:.1f}× reduction at keep={cfg.lop_keep})")


if __name__ == "__main__":
    main()
