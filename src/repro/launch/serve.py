"""Batched serving driver: continuous-batching style loop on the engine.

    PYTHONPATH=src python -m repro.launch.serve --arch bitnet-3b --reduced \
        --batch 4 --prompt-len 32 --gen 32

Runs quantized-weight prefill for a batch of synthetic prompts, then greedy
decode with the LOP screen; reports tokens/s and the modeled KV-traffic
reduction for the configured keep fraction.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lop import kv_traffic_bytes
from repro.launch.train import resolve_config
from repro.models.transformer import init_params
from repro.serving.engine import prefill, serve_step
from repro.serving.quantize import quantize_params


def serve_loop(cfg, *, batch: int, prompt_len: int, gen: int, seed: int = 0,
               use_lop: bool = True, greedy: bool = True):
    params, _ = init_params(cfg, jax.random.PRNGKey(seed))
    qp = quantize_params(cfg, params)
    rng = np.random.default_rng(seed)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (batch, prompt_len)),
                          jnp.int32)
    kwargs = {}
    if cfg.family == "encdec":
        kwargs["frames"] = jnp.asarray(
            rng.standard_normal((batch, 4 * prompt_len, cfg.d_model)),
            jnp.float32) * 0.02
    if cfg.family == "vlm":
        kwargs["patches"] = jnp.asarray(
            rng.standard_normal((batch, cfg.n_img_tokens, cfg.d_model)),
            jnp.float32) * 0.02

    prefill_fn = jax.jit(lambda qp, t, kw: prefill(
        cfg, qp, t, max_len=prompt_len + gen, use_lop=use_lop, **kw))
    step_fn = jax.jit(lambda qp, c, t: serve_step(cfg, qp, c, t,
                                                  use_lop=use_lop),
                      donate_argnums=(1,))

    t0 = time.time()
    logits, cache = jax.block_until_ready(prefill_fn(qp, prompts, kwargs))
    t_prefill = time.time() - t0

    out_tokens = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    t0 = time.time()
    for _ in range(gen):
        out_tokens.append(np.asarray(tok))
        logits, cache = step_fn(qp, cache, tok)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    jax.block_until_ready(logits)
    t_decode = time.time() - t0

    toks_per_s = batch * gen / t_decode
    return {
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "tokens_per_s": toks_per_s,
        "tokens": np.concatenate(out_tokens, axis=1),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="bitnet-3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--no-lop", action="store_true")
    args = ap.parse_args()

    cfg = resolve_config(args.arch, args.reduced)
    print(f"serving {cfg.name}: batch {args.batch}, prompt {args.prompt_len},"
          f" gen {args.gen}, lop={'off' if args.no_lop else 'on'}")
    out = serve_loop(cfg, batch=args.batch, prompt_len=args.prompt_len,
                     gen=args.gen, use_lop=not args.no_lop)
    print(f"prefill {out['prefill_s']:.2f}s  decode {out['decode_s']:.2f}s "
          f"({out['tokens_per_s']:.1f} tok/s on CPU semantics)")
    m = args.prompt_len + args.gen
    full = kv_traffic_bytes(m, cfg.hd, m, with_lop=False)
    lop = kv_traffic_bytes(m, cfg.hd,
                           int(m * cfg.lop_keep), with_lop=True)
    print(f"modeled KV traffic/head/query: {full} B dense → {lop} B with LOP"
          f" ({full/lop:.1f}× reduction at keep={cfg.lop_keep})")


if __name__ == "__main__":
    main()
