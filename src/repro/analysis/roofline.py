"""Roofline terms from the compiled dry-run artifact (TPU v5e targets).

    compute term    = HLO_FLOPs  / peak_FLOP/s          (per chip)
    memory term     = HLO_bytes  / HBM_bw               (per chip)
    collective term = coll_bytes / link_bw              (per chip)

``cost_analysis()`` FLOPs/bytes on the CPU backend are already
per-partition (post-SPMD), so no division by chip count is needed; the
mandated formulas (X / (chips × peak)) are equivalent with global sums.
Collective bytes use the payload (result-shape) convention — a ring
all-reduce moves ≈2× payload on the wire, so the collective term is a
lower bound within 2×.

Hardware constants (per brief): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI. int8 MXU throughput is 2× bf16 (394 TOPS) — reported as
``compute_s_int8`` where the quantized flow applies.
"""

from __future__ import annotations

PEAK_FLOPS_BF16 = 197e12
PEAK_FLOPS_INT8 = 394e12
HBM_BW = 819e9
ICI_BW = 50e9

# Per-core VMEM (TPU v5e ~16 MiB). Kernel tile sweeps
# (kernels/autotune.py) budget against a fraction of this — Mosaic needs
# headroom for spills and the double-buffered input windows.
VMEM_BYTES = 16 * 1024 * 1024
VMEM_BUDGET_FRACTION = 0.5


def vmem_budget() -> int:
    """Bytes a kernel's resident working set may claim (tile sweep bound)."""
    return int(VMEM_BYTES * VMEM_BUDGET_FRACTION)


def arithmetic_intensity(flops: float, bytes_accessed: float) -> float:
    """FLOPs per HBM byte — the roofline x-axis."""
    return flops / bytes_accessed if bytes_accessed > 0 else 0.0


def machine_balance(int8: bool = False) -> float:
    """The roofline ridge point (FLOPs/byte): tiles whose arithmetic
    intensity sits below this are HBM-bound no matter how good the
    schedule; the tile sweep ranks candidates by distance above it."""
    peak = PEAK_FLOPS_INT8 if int8 else PEAK_FLOPS_BF16
    return peak / HBM_BW


def roofline_terms(cost: dict, coll: dict, *, model_flops_per_chip: float
                   = 0.0) -> dict:
    """cost = compiled.cost_analysis(); coll = hlo.collective_bytes(...)."""
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    coll_bytes = float(coll.get("total", 0.0))

    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = bytes_accessed / HBM_BW
    collective_s = coll_bytes / ICI_BW
    terms = {
        "hlo_flops": flops,
        "hlo_bytes": bytes_accessed,
        "collective_bytes": coll_bytes,
        "compute_s": compute_s,
        "compute_s_int8": flops / PEAK_FLOPS_INT8,
        "memory_s": memory_s,
        "collective_s": collective_s,
    }
    dominant = max(("compute_s", "memory_s", "collective_s"),
                   key=lambda k: terms[k])
    terms["dominant"] = dominant
    terms["bound_s"] = terms[dominant]
    if model_flops_per_chip:
        terms["model_flops"] = model_flops_per_chip
        terms["useful_fraction"] = (model_flops_per_chip / flops
                                    if flops else 0.0)
        # roofline fraction: useful model FLOPs per wall-second implied by
        # the dominant term, as a fraction of peak
        if terms["bound_s"] > 0:
            terms["roofline_fraction"] = (
                model_flops_per_chip / terms["bound_s"] / PEAK_FLOPS_BF16)
    return terms


def count_params(tree) -> int:
    import jax
    import numpy as np
    return int(sum(np.prod(x.shape) for x in jax.tree.leaves(tree)))


def model_flops(cfg, shape, n_params: int, n_active: int) -> float:
    """Analytic MODEL_FLOPS for the cell (global, all chips).

    train: 6·N_active·D tokens; prefill: 2·N_active·D; decode: 2·N_active·B
    (one token per sequence).
    """
    from repro.configs.base import text_len
    if shape.kind == "train":
        d = shape.global_batch * text_len(cfg, shape.seq_len, "train")
        return 6.0 * n_active * d
    if shape.kind == "prefill":
        d = shape.global_batch * text_len(cfg, shape.seq_len, "prefill")
        return 2.0 * n_active * d
    return 2.0 * n_active * shape.global_batch


def active_params(cfg, n_params: int, params_tree=None) -> int:
    """N_active: MoE expert params scaled by top_k/E."""
    if cfg.n_experts == 0:
        return n_params
    import jax
    import numpy as np
    if params_tree is None:
        return n_params
    expert = 0
    flat = jax.tree_util.tree_flatten_with_path(params_tree)[0]
    for path, leaf in flat:
        ks = jax.tree_util.keystr(path)
        if any(n in ks for n in ("w_gate", "w_up", "w_down", "gu_packed",
                                 "gu_scale", "down_packed", "down_scale")) \
           and "moe" in ks:
            expert += int(np.prod(leaf.shape))
    dense = n_params - expert
    return int(dense + expert * cfg.top_k / cfg.n_experts)
