"""Collective-bytes extraction from post-SPMD HLO text.

``cost_analysis()`` has no collective accounting, so we parse the optimized
module: every ``all-gather`` / ``all-reduce`` / ``reduce-scatter`` /
``all-to-all`` / ``collective-permute`` op contributes its *result* shape
bytes (per-partition, since post-SPMD shapes are per-device). Async pairs
(``-start``/``-done``) are counted once via the ``-start`` op.
"""

from __future__ import annotations

import re

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

# e.g.:  %all-reduce.5 = f32[8,128]{1,0} all-reduce(...)
#        %ag = (s8[4,2]{...}, s8[8]{...}) all-gather-start(...)
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


# ---------------------------------------------------------------------------
# Fused-HBM traffic model
# ---------------------------------------------------------------------------
# `cost_analysis()['bytes accessed']` sums operand+result bytes of EVERY op
# — unfused elementwise chains (QAT fake-quant is ~6 ops per weight) count
# their full tensors repeatedly, wildly overestimating HBM traffic on a
# real TPU where they fuse. This model counts only ops that genuinely touch
# HBM (fusions, dots, reductions, gathers/scatters, data movement) and
# treats bare elementwise ops as fused (they would be, on TPU). The true
# traffic lies between this estimate and the raw figure; both are reported.

_HBM_OPS = {
    "fusion", "dot", "convolution", "reduce", "reduce-window", "gather",
    "scatter", "dynamic-slice", "dynamic-update-slice", "transpose",
    "concatenate", "pad", "reverse", "sort", "select-and-scatter",
    "rng", "rng-bit-generator", "cholesky", "triangular-solve",
}
# `copy` excluded: XLA:CPU materializes aliasing copies that buffer
# donation elides on TPU (donated caches update in place).

_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w.-]+)\s*=\s*"
    r"(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+([a-z-]+)"
    r"(?:-start|-done)?\((.*?)\)", re.M)
_OPERAND_RE = re.compile(r"%[\w.-]+")
_COMP_RE = re.compile(r"^(ENTRY\s+)?(%?[\w.-]+)\s*\([^)]*\)\s*->", re.M)


def hbm_bytes(hlo_text: str) -> int:
    """Fused-model HBM bytes for one execution of the module (per device)."""
    # symbol table: instruction name → result bytes
    sizes = {}
    for m in _DEF_RE.finditer(hlo_text):
        sizes[m.group(1)] = _shape_bytes(m.group(2))

    total = 0
    # walk line by line, tracking whether we're inside a fused computation
    in_fused = False
    for line in hlo_text.splitlines():
        comp = _COMP_RE.match(line)
        if comp:
            in_fused = "fused_computation" in comp.group(2)
            continue
        if in_fused:
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, shape_str, op, operands = m.groups()
        if op not in _HBM_OPS:
            continue
        ops_list = _OPERAND_RE.findall(operands)
        if op == "dynamic-update-slice":
            # in-place on TPU (buffer aliasing): traffic = the update
            # operand only, not the full cache buffer
            total += sum(sizes.get(o, 0) for o in ops_list[1:])
            continue
        total += _shape_bytes(shape_str)
        for o in ops_list:
            total += sizes.get(o, 0)
    return total


def collective_bytes(hlo_text: str) -> dict:
    """→ {kind: per-device bytes moved, ..., "total": ...} (+ op counts)."""
    out = {k: 0 for k in COLLECTIVE_KINDS}
    counts = {k: 0 for k in COLLECTIVE_KINDS}
    for m in _OP_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        # `-done` ops don't match (no shape before them in def position
        # with -start suffix captured separately); count each op once
        out[kind] += _shape_bytes(shape_str)
        counts[kind] += 1
    # avoid double counting: async pairs appear as `-start` (matched) and
    # `-done` whose result repeats the shape; `-done` defs match the plain
    # kind name with no '(' — our regex requires '(' right after, and
    # `-done(` lines match kind + "-done(" → not matched by (-start)? group.
    total = sum(out.values())
    return {**out, "total": total, "counts": counts}
