"""Compiled-artifact analysis: collective-bytes parsing + roofline terms."""

from repro.analysis.hlo import collective_bytes
from repro.analysis.roofline import roofline_terms
