"""Typed serving API: sampling params, requests, results, engine protocol.

The serving stack's cross-layer contract (DESIGN.md §Serving-API), in the
spirit of the paper's absmax barrier: one standardized interface so the
control plane (:mod:`repro.serving.scheduler`), the compute engine
(:mod:`repro.serving.engine` wrapped by :class:`PooledEngine`) and the
drivers (``launch/serve.py``, examples, benchmarks) compose without
bespoke glue or per-model-family branches. The shape follows JetStream's
``engine_api`` (prefill / insert / generate + declared capabilities):

  * :class:`SamplingParams` — frozen per-request decode policy
    (greedy / temperature / top-k / top-p + PRNG seed). The sampling
    contract lives in :mod:`repro.serving.sampling`: greedy is bitwise
    argmax, and a seeded request decodes the same tokens pooled or
    alone.
  * :class:`GenerateRequest` — frozen request envelope: prompt, budget,
    eos, stop token sequences, an optional streaming ``on_token``
    callback and a mutable :class:`CancelToken` handle.
  * :class:`StepResult` — one streamed token (what ``on_token``
    receives, in emission order, ``finished`` on the last).
  * :class:`FinishedRequest` — the completed request: tokens, finish
    reason, and the full latency breakdown including per-token
    timestamps (inter-token-latency telemetry).
  * :class:`ExistingPrefix` — a computed, interned prefill prefix
    (block-aligned cache pages + the token count they cover) that
    ``bulk_insert`` clones into many lanes at once; chunked prefill then
    resumes from the cached block boundary (JetStream's
    ``ExistingPrefix`` / ``bulk_insert`` shape — DESIGN.md
    §Prefix-caching).
  * :class:`InferenceEngine` — the protocol the scheduler speaks:
    ``prefill`` / ``prefill_chunk`` / ``insert`` / ``bulk_insert`` /
    ``decode_step`` / ``evict`` plus *declared capabilities*
    (``supports_chunked``, ``exact_length_prefill``, ``state_kind``,
    ``has_image_prefix``, ``prefix_block``).
    Model-family names appear ONLY in capability declarations —
    :class:`PooledEngine` is the one place that maps family → behaviour;
    the scheduler dispatches on capabilities alone.

Sampling state lives IN the pool (``seed`` / ``sample_step`` cache
leaves): ``decode_step`` reads each lane's PRNG schedule in-graph and
advances it with the lane, so cloned or migrated lanes keep same-seed
bitwise reproducibility with no host round-trip
(``set_sampling_state`` seeds a lane once, at activation).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Protocol, runtime_checkable

import jax
import numpy as np

from repro.serving import cache as _cache
from repro.serving import faults as _faults
from repro.serving.engine import (draft_step, guard_logits, prefill,
                                  prefill_chunk, serve_step)
from repro.serving.sampling import sample_with_seed

# ---------------------------------------------------------------------------
# Request-side dataclasses
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SamplingParams:
    """Per-request decode policy. ``temperature <= 0`` is the greedy fast
    path (bitwise argmax — reproduces the pre-API scheduler tokens);
    ``top_k <= 0`` and ``top_p >= 1`` disable their filters. ``seed``
    drives the lane-local key schedule
    (:func:`repro.serving.sampling.lane_keys`), so two runs of the same
    request with the same seed draw identical tokens regardless of what
    else shares the pool. ``gamma`` is the request's speculative draft
    length — tokens proposed per verify launch when the scheduler runs
    in speculative mode (``0`` defers to the scheduler's default γ;
    ignored outside speculative mode)."""
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0
    gamma: int = 0

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0


GREEDY = SamplingParams()


class CancelToken:
    """Mutable cancellation handle carried by a frozen request.

    The submitter keeps a reference and calls :meth:`cancel`; the
    scheduler observes it at the next serve cycle and retires the
    request mid-flight (queued, mid-prefill, or mid-decode) with
    ``finish_reason="cancelled"``.
    """

    __slots__ = ("_cancelled",)

    def __init__(self) -> None:
        self._cancelled = False

    def cancel(self) -> None:
        self._cancelled = True

    @property
    def cancelled(self) -> bool:
        return self._cancelled

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        return f"CancelToken(cancelled={self._cancelled})"


@dataclass(frozen=True)
class StepResult:
    """One streamed token, delivered to ``on_token`` as it decodes.

    ``index`` is the 0-based position in the generated stream (the
    prefill-seeded first token is index 0). ``finished`` marks the
    request's final token, with ``finish_reason`` set to
    ``"eos" | "stop" | "length"`` (a cancellation emits no token, so a
    cancelled request's last delivered StepResult has
    ``finished=False``)."""
    rid: int
    token: int
    index: int
    finished: bool
    finish_reason: str = ""


@dataclass(frozen=True, eq=False)
class GenerateRequest:
    """One generation request entering the queue (frozen envelope).

    ``stop`` holds token *sequences*: decoding finishes with reason
    ``"stop"`` as soon as the generated stream ends with any of them
    (the matched suffix stays in ``tokens`` — callers trim if they want
    it hidden). ``on_token`` streams every emitted token in order;
    ``cancel`` is the mid-flight abort handle. ``arrival`` is stamped at
    submit when left None (the scheduler re-creates the frozen record
    via ``dataclasses.replace``). ``deadline_ms`` is the request's
    latency budget measured from ``arrival``: the scheduler enforces it
    at admit, between prefill chunks, and per decode sweep, retiring the
    request with reason ``"deadline"`` — an expired request never holds
    a lane another request could use (DESIGN.md §Fault-tolerance)."""
    rid: int
    prompt: np.ndarray                 # int32 [prompt_len]
    max_new_tokens: int
    eos_id: int | None = None
    sampling: SamplingParams = GREEDY
    stop: tuple = ()                   # tuple[tuple[int, ...], ...]
    on_token: Callable[[StepResult], None] | None = None
    cancel: CancelToken | None = None
    arrival: float | None = None       # driver-set; submit() stamps None
    deadline_ms: float | None = None   # latency budget from arrival
    frames: np.ndarray | None = None   # encdec audio frames [S_enc, D]
    patches: np.ndarray | None = None  # vlm patch embeds [n_img, D]

    def __post_init__(self):
        # canonicalize stop sequences to hashable int tuples (accepts any
        # iterable-of-iterables; drops empty sequences)
        stop = tuple(tuple(int(t) for t in seq) for seq in self.stop)
        object.__setattr__(self, "stop", tuple(s for s in stop if s))

    @property
    def cancelled(self) -> bool:
        return self.cancel is not None and self.cancel.cancelled


@dataclass(frozen=True, eq=False)
class FinishedRequest:
    """Completed request: emitted tokens + full latency breakdown.

    ``token_times`` stamps each token's host-visible emission (index 0
    == ``t_first``), the raw series behind inter-token-latency
    percentiles. A request cancelled before its first token finishes
    with empty ``tokens`` and ``t_first == t_done``."""
    rid: int
    prompt_len: int
    tokens: list                       # list[int], emission order
    finish_reason: str                 # "eos" | "stop" | "length" |
    #                                    "cancelled" | "deadline" |
    #                                    "shed" | "fault"
    t_arrival: float = 0.0
    t_admit: float = 0.0               # prefill started (lane granted)
    t_first: float = 0.0               # first token emitted (TTFT end)
    t_done: float = 0.0
    token_times: list = field(default_factory=list)
    cached_len: int = 0                # prompt tokens served from the
    #                                    prefix cache (0 = cold prefill)

    @property
    def ttft(self) -> float:
        return self.t_first - self.t_arrival

    @property
    def latency(self) -> float:
        return self.t_done - self.t_arrival

    @property
    def itl(self) -> list:
        """Inter-token latencies (seconds), one per token after the first."""
        return [b - a for a, b in zip(self.token_times, self.token_times[1:])]


@dataclass(frozen=True)
class ExistingPrefix:
    """A computed prefill prefix to resume from (JetStream-style).

    ``cache`` is a batch-1 pytree of block-aligned prefix pages — the
    K/V, scales AND packed LOP feature rows for the first ``common_len``
    stream positions, plus ``lengths == [common_len]`` — normally
    assembled by :meth:`repro.serving.cache.PrefixStore.assemble`.
    ``engine.bulk_insert`` clones it into many lanes at once; each lane
    then resumes chunked prefill at ``start = common_len`` through the
    bitwise ``(start, kv_len)`` chunk-carry contract, so a prefix-hit
    request decodes token-identically to a cold one."""
    cache: dict
    common_len: int


# ---------------------------------------------------------------------------
# Engine protocol
# ---------------------------------------------------------------------------


@runtime_checkable
class InferenceEngine(Protocol):
    """What the scheduler requires of a compute engine.

    Capabilities (attributes) replace the scheduler's old model-family
    name checks — an engine *declares* how it must be driven:

      ``supports_chunked``     prompts may split into fixed-size chunks
                               interleaved with decode (causal attention
                               with split-invariant per-token compute).
      ``exact_length_prefill`` prompts must prefill at their exact
                               length — no pow2 pad buckets (recurrent
                               state integrates every position, MoE
                               routers rank per forward call, encdec
                               compiles against its encoder frames).
      ``has_image_prefix``     requests may carry ``patches`` that
                               occupy cache positions before the text.
      ``state_kind``           what a lane holds: ``"paged-kv"``,
                               ``"recurrent"``, ``"hybrid"`` or
                               ``"paged-kv+cross"`` (informational).
      ``chunk_tokens``         the fixed chunk width of the chunked
                               regime.
      ``prefix_block``         token-block granularity of prefix-cache
                               pages (0 = engine cannot resume from a
                               cached prefix — recurrent state is not
                               positional).
      ``supports_speculative`` the engine can draft cheap tokens
                               (``draft``), score them exactly in one
                               chunk-shaped launch (``verify_chunk``) and
                               rewind rejected cache writes
                               (``rollback``) — requires rewindable
                               positional state, so it holds exactly
                               where chunked prefill does (DESIGN.md
                               §Speculative-decoding).

    Methods mirror the lifecycle: ``prefill`` (whole prompt → batch-1
    cache), ``prefill_chunk`` (one chunk against a reserved pool lane),
    ``insert`` (batch-1 cache → lane), ``bulk_insert`` (one
    :class:`ExistingPrefix` → many lanes), ``extract`` (lane → batch-1
    cache, for interning), ``decode_step`` (advance every lane one token
    AND sample, in one dispatch), ``evict`` (retire a lane).
    ``sample_first`` seeds a lane from prefill logits through the same
    sampler the decode step uses; ``set_sampling_state`` writes the
    lane's in-pool PRNG schedule at activation.

    Fault contract (DESIGN.md §Fault-tolerance): after ``decode_step``
    the engine publishes ``last_ok`` (np bool [B]) — each lane's logit
    finiteness for THAT step, computed in-graph; engines without the
    guard simply never set it and the scheduler treats every lane as
    healthy. ``retry_step`` recomputes ONE quarantined lane's token with
    degraded features (LOP disabled) against a pool already rewound by
    ``rollback``, leaving every other lane's state untouched.
    """

    supports_chunked: bool
    exact_length_prefill: bool
    has_image_prefix: bool
    state_kind: str
    chunk_tokens: int
    prefix_block: int
    supports_speculative: bool

    def init_pool(self, n_slots: int): ...

    def prefix_len(self, req: GenerateRequest) -> int: ...

    def prefill(self, tokens, true_len, kw): ...

    def prefill_chunk(self, pool, slot, tokens, start, seq_end, activate,
                      kw): ...

    def insert(self, pool, slot, req_cache): ...

    def bulk_insert(self, pool, slots, prefix: ExistingPrefix,
                    active: bool = False): ...

    def extract(self, pool, slot): ...

    def decode_step(self, pool, tokens, temperature, top_k, top_p): ...

    def retry_step(self, pool, slot, tokens, temperature, top_k,
                   top_p): ...

    def draft(self, pool, tokens, temperature, top_k, top_p): ...

    def verify_chunk(self, pool, slot, tokens, start): ...

    def rollback(self, pool, slot, n: int): ...

    def sample_block(self, logits, sampling: "SamplingParams",
                     first_step: int): ...

    def evict(self, pool, slot): ...

    def sample_first(self, logits, sampling: SamplingParams,
                     seed_step: int = 0) -> int: ...

    def set_sampling_state(self, pool, slot, seed: int, step: int): ...


_STATE_KINDS = {"dense": "paged-kv", "moe": "paged-kv", "vlm": "paged-kv",
                "hybrid": "hybrid", "ssm": "recurrent",
                "encdec": "paged-kv+cross"}


class PooledEngine:
    """:class:`InferenceEngine` over the slot-paged serving stack.

    Owns the quantized params, the jit caches (one prefill compile per
    shape bucket, one chunk compile per chunk shape, one fused
    decode+sample step) and the capability declarations for ``cfg``'s
    family — the ONLY place in the serving control plane where family
    names appear. The decode step fuses
    :func:`repro.serving.engine.serve_step` with the batched sampler
    (:mod:`repro.serving.sampling`) so sampling adds no extra dispatch.
    """

    def __init__(self, cfg, qp, *, max_len: int, use_lop: bool = True,
                 chunk_tokens: int | None = None,
                 draft_layers: int | None = None,
                 draft_k: int | None = None,
                 shape_log: str | None = None):
        import jax.numpy as jnp  # local alias for the jitted closures

        self.cfg = cfg
        self.qp = qp
        self.max_len = max_len
        self.use_lop = use_lop
        if shape_log is not None:
            # log-and-sweep sidecar (DESIGN.md §Autotuning): every
            # distinct kernel dispatch shape this engine traces is
            # recorded so `python -m repro.kernels.autotune --from-log`
            # can sweep the shapes production traffic actually serves
            from repro.kernels import autotune as _tune
            _tune.start_shape_log(shape_log)
        self.chunk_tokens = chunk_tokens or cfg.lop_block
        # speculative draft knobs: layer-stack prefix depth and degraded
        # LOP selection budget (None = config's serving budget)
        self.draft_layers = min(cfg.n_layers, max(1, (
            draft_layers if draft_layers is not None
            else cfg.n_layers // 2)))
        self.draft_k = 1 if draft_k is None else max(1, draft_k)
        # ---- capability declarations (family → behaviour, once) ----
        self.supports_chunked = cfg.family in ("dense", "vlm")
        self.exact_length_prefill = cfg.family in ("hybrid", "ssm",
                                                   "encdec", "moe")
        self.has_image_prefix = cfg.family == "vlm"
        self.state_kind = _STATE_KINDS[cfg.family]
        # prefix pages are lop_block-aligned (cache pages already are),
        # and resume rides the chunked (start, kv_len) carry — so prefix
        # caching exists exactly where chunked prefill does
        self.prefix_block = cfg.lop_block if self.supports_chunked else 0
        # speculation needs rewindable positional state AND a chunk-shaped
        # verify launch — exactly the chunked-prefill families
        self.supports_speculative = self.supports_chunked

        self.prefill_compiles = 0
        self._fns: dict = {}
        self._jnp = jnp

        def step_and_sample(qp_, pool, tokens, temp, tk, tp, fadd):
            # the PRNG schedule lives in the pool: seed is per-request,
            # sample_step counts the lane's emissions — advanced in-graph
            # for active lanes, so a cloned/migrated lane samples its
            # same-seed token stream with no host round-trip. ``fadd``
            # is the fault-injection offset (zeros in production) and
            # ``ok`` the per-lane logit-finiteness guard — both ride the
            # same compile, so fault tolerance costs one add + reduce
            logits, pool = serve_step(cfg, qp_, pool, tokens,
                                      use_lop=use_lop)
            logits, ok = guard_logits(logits, fadd)
            seeds, steps = pool["seed"], pool["sample_step"]
            toks = sample_with_seed(logits, seeds, steps, temp, tk, tp)
            pool = dict(pool)
            adv = (pool["active"].astype(jnp.int32) if "active" in pool
                   else jnp.int32(1))
            pool["sample_step"] = steps + adv
            return toks, ok, pool

        def step_greedy(qp_, pool, tokens, fadd):
            # all-greedy fast path: skip the sampler's sorts/softmax/
            # categorical entirely — bitwise the sampler's greedy branch
            # (both are argmax over the same logits); sample_step is not
            # advanced (greedy lanes never read it, and any lane that
            # later needs it is re-seeded at activation)
            logits, pool = serve_step(cfg, qp_, pool, tokens,
                                      use_lop=use_lop)
            logits, ok = guard_logits(logits, fadd)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), ok, pool

        def retry_one(qp_, pool, slot, tokens, temp, tk, tp, fadd,
                      sampled):
            # single-lane RECOVERY step: the faulted lane — already
            # rewound bitwise by rollback_slot — recomputes its token
            # with the LOP screen disabled (exact dense attention, the
            # bottom rung before giving the lane up) while every other
            # lane's state is frozen behind a masked active vector.
            # sample_step advances only on the sampled path, mirroring
            # the batched step's greedy/sampled asymmetry.
            act = pool["active"]
            only = act & (jnp.arange(act.shape[0]) == slot)
            pool = dict(pool)
            pool["active"] = only
            seeds, steps = pool["seed"], pool["sample_step"]
            logits, pool = serve_step(cfg, qp_, pool, tokens,
                                      use_lop=False)
            logits, ok = guard_logits(logits, fadd)
            if sampled:
                toks = sample_with_seed(logits, seeds, steps, temp, tk,
                                        tp)
            else:
                toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            pool = dict(pool)
            if sampled:
                pool["sample_step"] = steps + only.astype(jnp.int32)
            pool["active"] = act
            return toks, ok, pool

        def set_sampling(pool, slot, seed, step):
            pool = dict(pool)
            pool["seed"] = pool["seed"].at[slot].set(seed)
            pool["sample_step"] = pool["sample_step"].at[slot].set(step)
            return pool

        d_layers, d_k = self.draft_layers, self.draft_k

        def draft_and_sample(qp_, pool, tokens, temp, tk, tp):
            # speculative draft twin of step_and_sample: truncated layer
            # stack + degraded LOP budget, same in-pool PRNG schedule —
            # draft token i for a lane at emission count e samples at
            # step e+i-1, the SAME key verify re-samples that position
            # with, so a correct draft distribution maximizes agreement
            seeds, steps = pool["seed"], pool["sample_step"]
            logits, pool = draft_step(cfg, qp_, pool, tokens,
                                      draft_layers=d_layers, draft_k=d_k,
                                      use_lop=use_lop)
            toks = sample_with_seed(logits, seeds, steps, temp, tk, tp)
            pool = dict(pool)
            adv = (pool["active"].astype(jnp.int32) if "active" in pool
                   else jnp.int32(1))
            pool["sample_step"] = steps + adv
            return toks, pool

        def draft_greedy(qp_, pool, tokens):
            logits, pool = draft_step(cfg, qp_, pool, tokens,
                                      draft_layers=d_layers, draft_k=d_k,
                                      use_lop=use_lop)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), pool

        def retry_sampled(qp_, pool, slot, tokens, temp, tk, tp, fadd):
            return retry_one(qp_, pool, slot, tokens, temp, tk, tp, fadd,
                             True)

        def retry_greedy(qp_, pool, slot, tokens, fadd):
            return retry_one(qp_, pool, slot, tokens, None, None, None,
                             fadd, False)

        self._decode_fn = jax.jit(step_and_sample, donate_argnums=(1,))
        self._decode_greedy_fn = jax.jit(step_greedy, donate_argnums=(1,))
        # recovery retries compile lazily — a fault-free run never pays
        self._retry_fn = jax.jit(retry_sampled, donate_argnums=(1,))
        self._retry_greedy_fn = jax.jit(retry_greedy, donate_argnums=(1,))
        self._draft_fn = jax.jit(draft_and_sample, donate_argnums=(1,))
        self._draft_greedy_fn = jax.jit(draft_greedy, donate_argnums=(1,))
        self._rollback_fn = jax.jit(_cache.rollback_slot,
                                    donate_argnums=(0,))
        self._sample_fn = jax.jit(sample_with_seed)
        self._insert_fn = jax.jit(_cache.insert_slot, donate_argnums=(0,))
        self._bulk_insert_fn = jax.jit(
            lambda pool, slots, c, act: _cache.bulk_insert(pool, slots, c,
                                                           active=act),
            donate_argnums=(0,))
        self._extract_fn = jax.jit(_cache.extract_slot)
        self._evict_fn = jax.jit(_cache.evict_slot, donate_argnums=(0,))
        self._sampling_state_fn = jax.jit(set_sampling, donate_argnums=(0,))

    # ---------------- pool ----------------

    def init_pool(self, n_slots: int):
        return _cache.init_cache_pool(self.cfg, n_slots, self.max_len)

    def prefix_len(self, req: GenerateRequest) -> int:
        """Cache positions the request occupies before its text tokens."""
        if self.has_image_prefix and req.patches is not None:
            return len(req.patches)
        return 0

    # ---------------- prefill ----------------

    def _kw_key(self, kw) -> tuple:
        return tuple(sorted((k, v.shape) for k, v in kw.items()))

    def prefill(self, tokens, true_len, kw):
        """Whole-prompt prefill → (last logits [B, V], batch-1 cache).
        Compiles once per (padded length, extra-input shapes)."""
        key = ("prefill", tokens.shape[1]) + self._kw_key(kw)
        fn = self._fns.get(key)
        if fn is None:
            cfg, use_lop, max_len = self.cfg, self.use_lop, self.max_len
            fn = jax.jit(lambda qp, t, tl, kw_: prefill(
                cfg, qp, t, max_len=max_len, use_lop=use_lop, true_len=tl,
                **kw_))
            self._fns[key] = fn
            self.prefill_compiles += 1
        jnp = self._jnp
        return fn(self.qp, jnp.asarray(tokens), jnp.int32(true_len), kw)

    def prefill_chunk(self, pool, slot, tokens, start, seq_end, activate,
                      kw):
        """One fixed-shape chunk against the reserved lane ``slot``:
        extract → chunk forward → partial insert (``active`` flips live
        on the final chunk). Compiles once per (chunk width, extras)."""
        key = ("chunk", tokens.shape[1]) + self._kw_key(kw)
        fn = self._fns.get(key)
        if fn is None:
            cfg = self.cfg

            def run(qp, pool_, slot_, toks, start_, seq_end_, activate_,
                    kw_):
                lane = _cache.extract_slot(pool_, slot_)
                logits, lane = prefill_chunk(cfg, qp, toks, lane,
                                             start=start_, seq_end=seq_end_,
                                             **kw_)
                pool_ = _cache.insert_slot(pool_, slot_, lane,
                                           active=activate_)
                return logits, pool_

            fn = jax.jit(run, donate_argnums=(1,))
            self._fns[key] = fn
            self.prefill_compiles += 1
        jnp = self._jnp
        return fn(self.qp, pool, jnp.int32(slot), jnp.asarray(tokens),
                  jnp.int32(start), jnp.int32(seq_end),
                  jnp.asarray(activate), kw)

    def insert(self, pool, slot, req_cache):
        return self._insert_fn(pool, self._jnp.int32(slot), req_cache)

    def bulk_insert(self, pool, slots, prefix: ExistingPrefix,
                    active: bool = False):
        """Clone one :class:`ExistingPrefix` into lanes ``slots`` (int
        vector) — a single scatter per cache leaf, so N prefix hits cost
        one dispatch. Lanes land ``active=False`` by default: they are
        mid-prefill reservations that resume chunked prefill at
        ``prefix.common_len``. Compiles once per (lane count, prefix
        capacity) pair."""
        jnp = self._jnp
        return self._bulk_insert_fn(
            pool, jnp.asarray(np.asarray(slots, np.int32)), prefix.cache,
            jnp.asarray(bool(active)))

    def extract(self, pool, slot):
        """Batch-1 copy of lane ``slot`` (non-donating — the pool stays
        live); what the scheduler interns into the prefix store."""
        return self._extract_fn(pool, self._jnp.int32(slot))

    # ---------------- decode / evict ----------------

    def decode_step(self, pool, tokens, temperature, top_k, top_p):
        """Advance every active lane one token and sample it — ONE jitted
        dispatch (serve_step + batched sampler). → (tokens [B] i32, pool).
        Each lane's PRNG seed/step are read from the pool's sampling-state
        leaves and advanced in-graph. Inactive lanes' samples are garbage
        the scheduler never reads. When every lane is greedy (the default
        serving configuration) the sampler is skipped for a bare argmax
        step — bitwise the same tokens at the pre-API decode cost.

        Fault guard: the step computes each lane's logit-finiteness mask
        in-graph (``guard_logits``) and publishes it as ``self.last_ok``
        (np bool [B]); a lane marked False was poisoned THIS step — its
        sampled token is garbage and the scheduler must quarantine +
        recover it (``retry_step``) instead of emitting. An active
        :mod:`repro.serving.faults` plan injects NaN rows (and slow-step
        sleeps) here; with no plan the offset is zeros."""
        jnp = self._jnp
        n = np.asarray(tokens).shape[0]
        fadd = _faults.decode_fault_add(n)
        fadd = jnp.asarray(np.zeros((n,), np.float32) if fadd is None
                           else fadd)
        if np.all(np.asarray(temperature) <= 0.0):
            toks, ok, pool = self._decode_greedy_fn(self.qp, pool,
                                                    jnp.asarray(tokens),
                                                    fadd)
        else:
            toks, ok, pool = self._decode_fn(
                self.qp, pool, jnp.asarray(tokens),
                jnp.asarray(temperature), jnp.asarray(top_k),
                jnp.asarray(top_p), fadd)
        self.last_ok = np.asarray(ok)
        return np.asarray(toks), pool

    def retry_step(self, pool, slot, tokens, temperature, top_k, top_p):
        """Recovery twin of :meth:`decode_step` for ONE quarantined lane
        (DESIGN.md §Fault-tolerance). Preconditions: the lane's faulted
        append was rewound bitwise (``rollback``), so its cache state is
        exactly pre-step. Recomputes the lane's token with the LOP screen
        disabled — exact dense attention, the degradation ladder's next
        rung — while the other lanes' state is frozen behind a masked
        active vector (their lengths, K/V and PRNG steps do not move).
        → (tokens [B] i32, ok [B] bool, pool); only row ``slot`` is
        meaningful. A sticky injected fault still poisons the retry —
        ``ok[slot]`` False means the lane is beyond recovery."""
        jnp = self._jnp
        n = np.asarray(tokens).shape[0]
        fadd = _faults.retry_fault_add(n)
        fadd = jnp.asarray(np.zeros((n,), np.float32) if fadd is None
                           else fadd)
        if np.all(np.asarray(temperature) <= 0.0):
            toks, ok, pool = self._retry_greedy_fn(
                self.qp, pool, jnp.int32(slot), jnp.asarray(tokens), fadd)
        else:
            toks, ok, pool = self._retry_fn(
                self.qp, pool, jnp.int32(slot), jnp.asarray(tokens),
                jnp.asarray(temperature), jnp.asarray(top_k),
                jnp.asarray(top_p), fadd)
        return np.asarray(toks), np.asarray(ok), pool

    # ---------------- speculative decoding ----------------

    def draft(self, pool, tokens, temperature, top_k, top_p):
        """One degraded-cost draft step over every active lane — the
        speculative proposer (truncated layer stack at ``draft_layers``,
        LOP selection pinched to ``draft_k`` blocks), batched like
        :meth:`decode_step` and sampled through the same in-pool PRNG
        schedule. Cache writes are provisional: verify overwrites them,
        :meth:`rollback` rewinds the rejected tail. → (tokens [B], pool).
        """
        jnp = self._jnp
        if np.all(np.asarray(temperature) <= 0.0):
            toks, pool = self._draft_greedy_fn(self.qp, pool,
                                               jnp.asarray(tokens))
        else:
            toks, pool = self._draft_fn(
                self.qp, pool, jnp.asarray(tokens),
                jnp.asarray(temperature), jnp.asarray(top_k),
                jnp.asarray(top_p))
        return np.asarray(toks), pool

    def verify_chunk(self, pool, slot, tokens, start):
        """Score γ+1 positions of lane ``slot`` exactly, in ONE
        chunk-shaped launch. ``tokens`` [1, γ+1] = [t_last, d_1..d_γ] at
        stream positions [start, start+γ+1); the full-stack K/V for all
        of them is (re)written through the bitwise ``(start, kv_len)``
        chunk carry — overwriting every provisional draft row — and the
        lane's length lands at ``start+γ+1``. Returns logits [1, γ+1, V]
        (row i targets position start+i+1) and the pool. Advances the
        lane's in-pool PRNG step by 1: with the γ draft advances and the
        ``rollback`` rewind of γ−j rejected tokens, a lane that accepts
        j drafts nets +j+1 — exactly its emission count. Compiles once
        per verify width."""
        key = ("verify", tokens.shape[1])
        fn = self._fns.get(key)
        if fn is None:
            cfg, use_lop = self.cfg, self.use_lop

            def run(qp, pool_, slot_, toks, start_):
                lane = _cache.extract_slot(pool_, slot_)
                width = toks.shape[1]
                logits, lane = prefill_chunk(
                    cfg, qp, toks, lane, start=start_,
                    seq_end=start_ + width, all_logits=True)
                pool_ = _cache.insert_slot(pool_, slot_, lane, active=True)
                pool_ = dict(pool_)
                pool_["sample_step"] = \
                    pool_["sample_step"].at[slot_].add(1)
                return logits, pool_

            fn = jax.jit(run, donate_argnums=(1,))
            self._fns[key] = fn
            self.prefill_compiles += 1
        jnp = self._jnp
        logits, pool = fn(self.qp, pool, jnp.int32(slot),
                          jnp.asarray(tokens), jnp.int32(start))
        return np.asarray(logits), pool

    def rollback(self, pool, slot, n: int):
        """Rewind lane ``slot`` by ``n`` rejected speculative tokens —
        :func:`repro.serving.cache.rollback_slot` under jit (lengths −n,
        rejected K/V/scale/feature rows zeroed, PRNG step −n). One
        compile serves every (slot, n)."""
        jnp = self._jnp
        return self._rollback_fn(pool, jnp.int32(slot), jnp.int32(n))

    def sample_block(self, logits, sampling: SamplingParams,
                     first_step: int):
        """Sample every row of verify logits [C, V] (or [1, C, V]) under
        one request's policy — row i at PRNG step ``first_step + i``, the
        same per-emission key schedule the lane's decode steps use, so
        the accepted sampled stream is the non-speculative stream.
        → np.int32 [C]."""
        sp = sampling or GREEDY
        rows = np.asarray(logits)
        if rows.ndim == 3:
            rows = rows[0]
        c = rows.shape[0]
        toks = self._sample_fn(
            rows, np.full((c,), sp.seed, np.int32),
            first_step + np.arange(c, dtype=np.int32),
            np.full((c,), sp.temperature, np.float32),
            np.full((c,), sp.top_k, np.int32),
            np.full((c,), sp.top_p, np.float32))
        return np.asarray(toks)

    def evict(self, pool, slot):
        return self._evict_fn(pool, self._jnp.int32(slot))

    def set_sampling_state(self, pool, slot, seed: int, step: int):
        """Write lane ``slot``'s in-pool PRNG schedule (at activation:
        ``step=1`` — the prefill-seeded first token was emission 0,
        sampled host-side by :meth:`sample_first`)."""
        jnp = self._jnp
        return self._sampling_state_fn(pool, jnp.int32(slot),
                                       jnp.int32(seed), jnp.int32(step))

    def sample_first(self, logits, sampling: SamplingParams,
                     seed_step: int = 0) -> int:
        """Sample a request's first token from its prefill logits [B=1, V]
        through the SAME jitted sampler path as every later token (key
        schedule step ``seed_step``, normally 0)."""
        sp = sampling or GREEDY
        tok = self._sample_fn(
            logits[:1], np.asarray([sp.seed], np.int32),
            np.asarray([seed_step], np.int32),
            np.asarray([sp.temperature], np.float32),
            np.asarray([sp.top_k], np.int32),
            np.asarray([sp.top_p], np.float32))
        return int(tok[0])
