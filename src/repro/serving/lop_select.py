"""LOP screen → comparison-free block top-K selection (batched serving form).

Wraps the core selector (:mod:`repro.core.lop`) for the engine's decode
shapes: scores arrive per (batch, kv-head, group-head), selection is at
*block* granularity (paper: "only those candidate blocks are requested"),
and the output is the (block_idx, gate_tokens) scalar-prefetch contract of
the decode kernels.

Scalar-prefetch contract (DESIGN.md §Fused-decode-kernel)
---------------------------------------------------------
``select_blocks`` emits, per selection set, ``block_idx`` int32 [K] plus
``gate_tokens`` int32 [3K] = [gate(0/1) ‖ end ‖ start] — gate says the
candidate is live, and tokens [start, end) inside its block survive the
cache-length suffix cut and the SWA-window prefix cut. This is exactly
what rides ahead of a Pallas grid as scalar prefetch: the single-kv-head
micro-kernel (:func:`repro.kernels.int8_attention.sparse_decode_attention`)
consumes it verbatim via ``PrefetchScalarGridSpec``, and the fused batched
kernel (:mod:`repro.kernels.decode_attention`) re-derives the same ranks,
gates and intervals *in kernel* from its prefetched ``new_len``/
``pos_offset`` scalars — mirroring this module op for op (same bucketized
selector, same ``n_buckets``) so the jnp oracle and the fused kernel pick
identical candidate sets. Change one side only in lockstep with the other.

Slot-paged pools reuse the same masking contract: a retired or empty lane
is passed with ``new_len == 0``, which makes :func:`token_valid_mask` all
false, every screened score INT32_MIN, and every selected block fully
gated off (its live interval [start, end) is empty) — stale bytes from a
previous occupant can never leak into the softmax of the next one.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.lop import (DEFAULT_N_BUCKETS, block_reduce_scores,
                            comparison_free_topk)

INT32_MIN = jnp.iinfo(jnp.int32).min


def token_valid_mask(m: int, new_len: jax.Array, window: int,
                     pos_offset: int = 0) -> jax.Array:
    """[B, M] bool — cache positions visible to the current query.

    ``pos_offset`` maps local shard positions to global (SP path).
    """
    pos = pos_offset + jnp.arange(m)[None, :]
    valid = pos < new_len[:, None]
    if window:
        valid &= pos >= (new_len[:, None] - window)
    return valid


def select_blocks(scores: jax.Array, new_len: jax.Array, *, block: int,
                  k_keep: int, window: int = 0,
                  n_buckets: int = DEFAULT_N_BUCKETS,
                  block_offset: int = 0):
    """scores int32 [B, Hkv, G, M]; new_len int32 [B] →
    (block_idx [B,Hkv,G,K], gate_tokens [B,Hkv,G,3K] = [gate ‖ end ‖ start]).

    ``block_offset`` shifts block ids to global numbering when scoring an
    M-shard (the SP quota-sharded path). ``n_buckets`` defaults to
    :data:`repro.core.lop.DEFAULT_N_BUCKETS`, shared with the fused
    kernel's in-kernel selector — both sides derive their emission order
    from the same :func:`repro.core.lop.comparison_free_rank`, so they
    pick identical candidate sets by construction; override ``n_buckets``
    only in lockstep with the kernel call.
    """
    b, hkv, g, m = scores.shape
    nb = m // block
    valid = token_valid_mask(m, new_len, window,
                             pos_offset=block_offset * block)
    s_masked = jnp.where(valid[:, None, None, :], scores, INT32_MIN)
    blk = block_reduce_scores(s_masked, block)            # [B,Hkv,G,NB]
    blk_valid = jnp.any(valid.reshape(b, nb, block), -1)  # [B,NB]
    blk_valid = jnp.broadcast_to(blk_valid[:, None, None, :],
                                 (b, hkv, g, nb))

    flat_s = blk.reshape(-1, nb)
    flat_v = blk_valid.reshape(-1, nb)
    idx, gate = jax.vmap(
        lambda s, v: comparison_free_topk(s, k_keep, n_buckets=n_buckets,
                                          valid=v))(flat_s, flat_v)
    idx = idx.reshape(b, hkv, g, k_keep)
    gate = gate.reshape(b, hkv, g, k_keep)

    # live interval [start, end) inside each selected block
    blk_start = (idx + block_offset) * block              # global token pos
    len_b = new_len[:, None, None, None]
    end = jnp.clip(len_b - blk_start, 0, block)
    if window:
        start = jnp.clip(len_b - window - blk_start, 0, block)
    else:
        start = jnp.zeros_like(end)
    gate_tokens = jnp.concatenate(
        [gate.astype(jnp.int32), end, start], axis=-1)    # [B,Hkv,G,3K]
    return idx, gate_tokens


def k_keep_blocks(cfg, m: int) -> int:
    """Static K (blocks kept) for a capacity-M cache: ⌈keep·M/block⌉."""
    nb = m // cfg.lop_block
    return max(1, int(round(cfg.lop_keep * nb)))
