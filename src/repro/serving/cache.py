"""Serving caches: slot-paged int8 KV + absmax scales + packed LOP features.

The KV cache follows the paper's memory layout insight: exact keys/values in
int8 (absmax barrier), plus the 4-bit (sgn‖LO) *feature cache* the LOP screen
reads instead of the exact keys — the screen touches M·d/2 bytes while exact
attention touches only the K selected candidate blocks.

Capacity is block-aligned (``lop_block``) so candidate fetches stay
contiguous. Recurrent families cache their state instead ("KV cache of
seq_len" = recurrent state for SSM — DESIGN.md §6).

Slot-paged pool (continuous batching)
-------------------------------------
``init_cache_pool`` allocates the same tree for ``n_slots`` persistent
*decode lanes* plus a per-lane ``active`` mask. The lifecycle managed by
:mod:`repro.serving.scheduler` is::

    admit    a queued request once a lane is free,
    prefill  it — chunked families interleave one fixed-shape chunk per
             serve cycle (extract_slot → prefill_chunk → partial
             insert_slot with ``active=False``); the legacy path runs the
             whole prompt at once (length-bucketed compile) into a
             batch-1 cache,
    insert   that cache into the lane (``insert_slot``, one
             ``dynamic_update_slice`` per leaf) while the other lanes
             keep decoding; the final chunk's insert activates the lane
             and its logits seed the first token through the per-request
             sampler (:mod:`repro.serving.sampling`),
    decode   all active lanes together; inactive lanes are masked out of
             the LOP screen, block top-K and cache writes,
    evict    the lane on EOS/max-len (``evict_slot``) — the lane's bytes go
             stale but every read is masked by per-slot ``lengths``, so the
             next occupant sees a logically fresh lane.

Stale bytes above a lane's ``lengths`` are harmless by construction: the
LOP screen masks them to INT32_MIN before block reduction and exact
attention masks them to −∞ before the softmax, which is also why
evict→insert reuse is bit-identical to a zero-initialised lane.
``evict_slot`` additionally zeroes the lane's packed LOP feature rows so
a later prefix-clone lands in a lane bit-identical to a fresh pool.

The pool also carries the per-lane *sampling state* (``seed``,
``sample_step``) as cache leaves, so the fused decode+sample step reads
its PRNG schedule straight from the pool — a cloned or migrated lane
samples correctly with no host round-trip (DESIGN.md §Prefix-caching).

Prefix caching (shared prompts cost one prefill)
------------------------------------------------
:class:`PrefixStore` interns computed prefill state keyed by token-block
hash chains: block ``k`` of a prompt is keyed by
``blake2b(parent_key ‖ tokens[k·B:(k+1)·B])``, so equal prompt prefixes
— and only equal prefixes — share a chain of nodes, each holding that
block's *cache pages* (the K/V **and** packed LOP feature rows sliced
from a batch-1 prefill at the block's token range). ``bulk_insert``
clones one assembled prefix into many pool lanes in a single scatter;
the scheduler then resumes chunked prefill from the cached block
boundary via the existing bitwise ``(start, kv_len)`` chunk-carry
contract (DESIGN.md §Prefix-caching).
"""

from __future__ import annotations

import hashlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving import faults as _faults


def round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def attn_cache_zeros(cfg, batch: int, capacity: int):
    hkv, dh = cfg.n_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((batch, hkv, capacity, dh), jnp.int8),
        "v": jnp.zeros((batch, hkv, capacity, dh), jnp.int8),
        "k_scale": jnp.zeros((batch, hkv, capacity), jnp.float32),
        "v_scale": jnp.zeros((batch, hkv, capacity), jnp.float32),
        "feat": jnp.zeros((batch, hkv, capacity, dh // 2), jnp.uint8),
    }


def _stack(tree, n: int):
    return jax.tree.map(
        lambda a: jnp.zeros((n, *a.shape), a.dtype), tree)


def init_cache(cfg, batch: int, max_len: int, *, align: int | None = None):
    """Zero cache sized for ``max_len`` tokens (+1 block of decode slack).

    ``align`` (default lop_block) also aligns capacity to the SP shard
    count × block so every M-shard is block-aligned.
    """
    cap = round_up(max_len + 1, align or cfg.lop_block)
    cache = {"lengths": jnp.zeros((batch,), jnp.int32)}

    if cfg.family in ("dense", "moe", "vlm"):
        cache["layers"] = _stack(attn_cache_zeros(cfg, batch, cap),
                                 cfg.n_layers)
    elif cfg.family == "hybrid":
        n_sb = cfg.n_layers // cfg.attn_every
        n_mamba = cfg.attn_every - 1
        cache["blocks"] = {
            "attn": _stack(attn_cache_zeros(cfg, batch, cap), n_sb),
            "mamba": {
                "ssm": jnp.zeros((n_sb, n_mamba, batch, cfg.d_inner,
                                  cfg.mamba_d_state), jnp.float32),
                "conv": jnp.zeros((n_sb, n_mamba, batch, cfg.mamba_conv - 1,
                                   cfg.d_inner), jnp.float32),
            },
        }
    elif cfg.family == "ssm":
        cache["layers"] = {
            "wkv": jnp.zeros((cfg.n_layers, batch, cfg.n_heads, cfg.hd,
                              cfg.hd), jnp.float32),
            "x_tm": jnp.zeros((cfg.n_layers, batch, 1, cfg.d_model),
                              jnp.float32),
            "x_cm": jnp.zeros((cfg.n_layers, batch, 1, cfg.d_model),
                              jnp.float32),
        }
    elif cfg.family == "encdec":
        cross_cap = round_up(cfg.cross_ctx, align or cfg.lop_block)
        cache["layers"] = _stack(attn_cache_zeros(cfg, batch, cap),
                                 cfg.n_layers)
        cache["cross"] = _stack(attn_cache_zeros(cfg, batch, cross_cap),
                                cfg.n_layers)
        cache["cross_len"] = jnp.zeros((batch,), jnp.int32)
    else:
        raise ValueError(cfg.family)
    return cache


def _leaf_spec(path, *, batch_axes="dp", seq_axes="sp"):
    """Logical axes of one cache leaf, *excluding* stacked leading dims.

    Every spec starts at the batch/slot axis, so the slot axis index of a
    leaf is ``leaf.ndim - len(_leaf_spec(path))`` (used by ``insert_slot``).
    """
    name = path[-1]
    if name in ("k", "v", "feat"):
        return (batch_axes, None, seq_axes, None)
    if name in ("k_scale", "v_scale"):
        return (batch_axes, None, seq_axes)
    if name in ("lengths", "cross_len", "active", "seed", "sample_step"):
        return (None,)
    if name == "ssm":
        return (batch_axes, "tp", None)
    if name == "conv":
        return (batch_axes, None, "tp")
    if name == "wkv":
        return (batch_axes, "tp", None, None)
    if name in ("x_tm", "x_cm"):
        return (batch_axes, None, None)
    raise KeyError(path)


def cache_pspecs(cfg, cache, *, batch_axes="dp", seq_axes="sp"):
    """Logical-axis tree for the cache (M sequence-sharded, batch over dp).

    Attention caches shard the token axis over the model axis (SP) — the
    quota-sharded LOP selection in :mod:`repro.distributed.sp_decode` works
    per M-shard. Recurrent state shards its inner dim over the model axis.
    The per-slot ``lengths``/``active`` vectors stay replicated.
    """
    def walk(path, node):
        if isinstance(node, dict):
            return {k: walk(path + (k,), v) for k, v in node.items()}
        spec = _leaf_spec(path, batch_axes=batch_axes, seq_axes=seq_axes)
        # stacked leading dims (layers / superblocks / per-block sublayers)
        extra = node.ndim - len(spec)
        return (None,) * extra + spec

    return walk((), cache)


# ---------------------------------------------------------------------------
# Slot-paged pool ops (continuous batching)
# ---------------------------------------------------------------------------

def slot_axis(path, leaf) -> int:
    """Index of the slot (batch) axis in a cache leaf at ``path``."""
    return leaf.ndim - len(_leaf_spec(path))


def seq_axis(path, leaf) -> int:
    """Index of the token (sequence) axis in a cache leaf at ``path``.

    Defined only for positional caches (K/V/scales/features); recurrent
    state has no token axis, which is also why prefix pages are undefined
    for it.
    """
    spec = _leaf_spec(path)
    if "sp" not in spec:
        raise ValueError(f"cache leaf {path} has no token axis (recurrent "
                         f"state) — prefix pages are undefined for it")
    return leaf.ndim - len(spec) + spec.index("sp")


def init_cache_pool(cfg, n_slots: int, max_len: int, *,
                    align: int | None = None):
    """Slot-paged pool: ``n_slots`` persistent decode lanes, all inactive.

    Identical tree to :func:`init_cache` (so ``serve_step`` runs on it
    unchanged) plus a per-lane ``active`` mask that the engine threads
    through the LOP screen, block top-K and cache writes, and the
    per-lane sampling state (``seed``, ``sample_step``) the fused
    decode+sample step reads in-graph — the PRNG schedule travels with
    the lane, so clones/migrations need no host round-trip to sample.
    """
    pool = init_cache(cfg, n_slots, max_len, align=align)
    pool["active"] = jnp.zeros((n_slots,), jnp.bool_)
    pool["seed"] = jnp.zeros((n_slots,), jnp.int32)
    pool["sample_step"] = jnp.zeros((n_slots,), jnp.int32)
    return pool


def pool_capacity(pool) -> int:
    """Token capacity M of the pool's attention lanes (0 if attention-free)."""
    caps = []

    def walk(path, node):
        if isinstance(node, dict):
            for k, v in node.items():
                walk(path + (k,), v)
        elif path[-1] == "k" and "cross" not in path:
            spec = _leaf_spec(path)
            caps.append(node.shape[node.ndim - len(spec) + 2])

    walk((), pool)
    return caps[0] if caps else 0


def insert_slot(pool, slot, req_cache, active=True):
    """Write a single-request (batch-1) prefill cache into lane ``slot``.

    One ``dynamic_update_slice`` per leaf at that leaf's slot axis — the
    other lanes are untouched, so insertion composes with donated buffers
    in a jit'd decode loop. ``slot`` may be a traced scalar (one compile
    serves every lane). The request cache's token capacity may be smaller
    than the pool's; positions above it go stale and are masked by
    ``lengths``.

    ``active`` (static bool or traced scalar) is the *partial-insert*
    switch for chunked prefill: a mid-prefill lane is written back with
    ``active=False`` after every chunk — its K/V for [0, lengths) are
    real, but the decode step must not advance it — and the final chunk
    flips it live. The scheduler keeps such a lane out of its free-lane
    deque (note: :func:`free_slots` reports by ``active`` alone and does
    NOT know about reservations — DESIGN.md §Chunked-prefill).
    """
    def walk(path, dst, src):
        if isinstance(dst, dict):
            return {k: walk(path + (k,), dst[k], src[k]) if k in src
                    else dst[k] for k in dst}
        ax = slot_axis(path, dst)
        start = (0,) * ax + (slot,) + (0,) * (dst.ndim - ax - 1)
        return jax.lax.dynamic_update_slice(dst, src, start)

    new = walk((), {k: v for k, v in pool.items() if k != "active"},
               req_cache)
    new["active"] = pool["active"].at[slot].set(active)
    return new


def extract_slot(pool, slot):
    """Batch-1 view of lane ``slot`` — the inverse of :func:`insert_slot`.

    One ``dynamic_slice`` per leaf (``slot`` may be traced). Chunked
    prefill round-trips extract → ``prefill_chunk`` → partial
    ``insert_slot`` once per chunk, so the in-flight prompt's K/V lives
    in the pool between chunks rather than in host-side side state.
    """
    def walk(path, node):
        if isinstance(node, dict):
            return {k: walk(path + (k,), v) for k, v in node.items()
                    if k != "active"}
        ax = slot_axis(path, node)
        start = (0,) * ax + (slot,) + (0,) * (node.ndim - ax - 1)
        sizes = node.shape[:ax] + (1,) + node.shape[ax + 1:]
        return jax.lax.dynamic_slice(node, start, sizes)

    return walk((), pool)


def bulk_insert(pool, slots, req_cache, active=True):
    """Clone ONE batch-1 cache into MANY lanes — one scatter per leaf.

    ``slots`` is an int32 ``[N]`` vector of distinct lane indices; the
    size-1 slot axis of ``req_cache`` broadcasts across them, so a shared
    prefix computed once lands in every hit lane of an admit batch in a
    single dispatch (K/V pages AND the packed LOP feature rows — the
    sparse screen stays consistent with the exact keys it summarizes).
    Leaves smaller than the pool's along any non-slot axis (a prefix
    cache's token capacity is its own block-aligned length) write their
    own extent; positions above it keep the lane's previous bytes, which
    are zero for feature rows (``evict_slot``) and stale-masked
    everywhere else. Dst keys missing from ``req_cache`` (``seed``,
    ``sample_step``, per-lane vectors the prefix does not carry) keep
    their pool values, like :func:`insert_slot`.

    ``active`` follows :func:`insert_slot`'s partial-insert contract:
    prefix clones land with ``active=False`` — the lanes are mid-prefill
    reservations that resume chunked prefill from the cached boundary.
    """
    def walk(path, dst, src):
        if isinstance(dst, dict):
            return {k: walk(path + (k,), dst[k], src[k]) if k in src
                    else dst[k] for k in dst}
        ax = slot_axis(path, dst)
        idx = tuple(
            slots if i == ax
            else slice(0, src.shape[i]) if src.shape[i] != dst.shape[i]
            else slice(None)
            for i in range(dst.ndim))
        return dst.at[idx].set(src, unique_indices=True)

    new = walk((), {k: v for k, v in pool.items() if k != "active"},
               req_cache)
    new["active"] = pool["active"].at[slots].set(active)
    return new


def evict_slot(pool, slot):
    """Retire lane ``slot``: mark inactive, zero its length AND its packed
    LOP feature rows.

    The K/V bytes are left stale — every consumer masks by
    ``lengths``/``active``, and the next ``insert_slot`` overwrites them.
    The 4-bit feature rows are zeroed because the LOP screen reads them
    *before* its length mask folds the scores away: the masking makes a
    previous occupant's ghost features logically invisible, but zeroing
    restores the lane to its pool-init bit pattern, so a later
    prefix-clone (which writes only the prefix's rows) screens against
    exactly what a fresh pool would.
    """
    def walk(path, node):
        if isinstance(node, dict):
            return {k: walk(path + (k,), v) for k, v in node.items()}
        if path[-1] != "feat":
            return node
        ax = slot_axis(path, node)
        return node.at[(slice(None),) * ax + (slot,)].set(0)

    pool = walk((), pool)
    pool["active"] = pool["active"].at[slot].set(False)
    pool["lengths"] = pool["lengths"].at[slot].set(0)
    return pool


# positional (per-token) attention-cache leaves — the rows a speculative
# write touches and a rollback must rewind
_POSITIONAL_KEYS = ("k", "v", "k_scale", "v_scale", "feat")


def rollback_slot(pool, slot, n):
    """Rewind lane ``slot`` by ``n`` speculative tokens.

    The inverse of ``n`` cache appends, for the speculative-decoding
    verify/reject cycle (DESIGN.md §Speculative-decoding): the lane's
    ``lengths`` drops by ``n`` and the rejected token rows
    ``[lengths - n, lengths)`` of every positional self-attention leaf —
    K/V, their absmax scales AND the packed LOP feature rows — are
    zeroed, restoring the lane bit-for-bit to its pool-init pattern at
    those positions (stale-masking alone would make the rows logically
    invisible, but bitwise lane equality is what the rollback property
    test pins). The per-lane PRNG ``sample_step`` rewinds by ``n`` too,
    so a sampled lane's key schedule stays aligned with its emission
    count — rolling back ``n`` of γ speculative tokens leaves the lane
    identical to having decoded γ−n tokens. Cross-attention pages and
    recurrent state are untouched (the encoder memory is never
    speculative; recurrent state cannot rewind, which is why engines
    without paged KV do not declare ``supports_speculative``).

    ``slot`` and ``n`` may be traced (one compile serves every lane and
    every rejection count); ``n`` clamps to the lane's length.
    """
    old_len = pool["lengths"][slot]
    new_len = jnp.maximum(old_len - n, 0)

    def walk(path, node):
        if isinstance(node, dict):
            return {k: walk(path + (k,), v) for k, v in node.items()}
        if path[-1] not in _POSITIONAL_KEYS or "cross" in path:
            return node
        sax = slot_axis(path, node)
        qax = seq_axis(path, node)
        start = (0,) * sax + (slot,) + (0,) * (node.ndim - sax - 1)
        sizes = node.shape[:sax] + (1,) + node.shape[sax + 1:]
        lane = jax.lax.dynamic_slice(node, start, sizes)
        pos = jnp.arange(lane.shape[qax])
        dead = (pos >= new_len) & (pos < old_len)
        shape = [1] * lane.ndim
        shape[qax] = lane.shape[qax]
        lane = jnp.where(dead.reshape(shape), jnp.zeros((), node.dtype),
                         lane)
        return jax.lax.dynamic_update_slice(node, lane, start)

    pool = walk((), dict(pool))
    pool["lengths"] = pool["lengths"].at[slot].set(new_len)
    if "sample_step" in pool:
        pool["sample_step"] = pool["sample_step"].at[slot].set(
            jnp.maximum(pool["sample_step"][slot] - n, 0))
    return pool


# ``free_slot`` is eviction under its queue-side name: a lane freed for the
# next admission. Kept as an alias so scheduler code reads naturally.
free_slot = evict_slot


def free_slots(pool) -> list[int]:
    """Host-side list of lanes currently free for admission (syncs)."""
    return [int(i) for i in
            np.flatnonzero(~np.asarray(pool["active"]))]


# ---------------------------------------------------------------------------
# Prefix store (hash-chain interning of computed prefill pages)
# ---------------------------------------------------------------------------

# per-lane vectors are not positional pages — the prefix carries lengths
# explicitly and never touches a lane's sampling state
_PER_LANE_KEYS = ("lengths", "cross_len", "active", "seed", "sample_step")


def _chain_key(parent_key: bytes, block_tokens: np.ndarray) -> bytes:
    """Hash-chain key of one token block given its parent's key."""
    h = hashlib.blake2b(parent_key, digest_size=16)
    h.update(np.ascontiguousarray(block_tokens, np.int32).tobytes())
    return h.digest()


def _page_checksum(pages) -> bytes:
    """Content checksum of one node's pages (DESIGN.md §Fault-tolerance).

    blake2b over every leaf's dtype, shape and raw bytes in sorted-path
    order — taken at intern time, re-verified on match, so a page that
    rots AFTER interning (bit flips, bad DMA) is caught before
    ``bulk_insert`` would fan the corruption into every hit lane.
    """
    h = hashlib.blake2b(digest_size=16)

    def walk(path, node):
        if isinstance(node, dict):
            for k in sorted(node):
                walk(path + (k,), node[k])
            return
        arr = np.asarray(node)
        h.update(repr((path, str(arr.dtype), arr.shape)).encode())
        h.update(np.ascontiguousarray(arr).tobytes())

    walk((), pages)
    return h.digest()


def _flip_one_bit(pages, rng):
    """Flip one deterministic bit somewhere in a page tree (the injected
    post-intern corruption of :mod:`repro.serving.faults`)."""
    leaves, treedef = jax.tree.flatten(pages)
    i = int(rng.integers(0, len(leaves)))
    arr = np.asarray(leaves[i]).copy()
    flat = arr.view(np.uint8).reshape(-1)
    byte = int(rng.integers(0, flat.size))
    flat[byte] ^= np.uint8(1 << int(rng.integers(0, 8)))
    leaves[i] = jnp.asarray(arr)
    return jax.tree.unflatten(treedef, leaves)


def _slice_pages(cache, lo: int, hi: int):
    """Token range [lo, hi) of every positional leaf of a batch-1 cache."""
    def walk(path, node):
        if isinstance(node, dict):
            return {k: walk(path + (k,), v) for k, v in node.items()
                    if k not in _PER_LANE_KEYS}
        ax = seq_axis(path, node)
        return node[(slice(None),) * ax + (slice(lo, hi),)]

    return walk((), cache)


def _concat_pages(trees):
    """Concatenate per-block page trees along each leaf's token axis."""
    def walk(path, nodes):
        if isinstance(nodes[0], dict):
            return {k: walk(path + (k,), [n[k] for n in nodes])
                    for k in nodes[0]}
        if len(nodes) == 1:
            return nodes[0]
        return jnp.concatenate(nodes, axis=seq_axis(path, nodes[0]))

    return walk((), trees)


class _PrefixNode:
    """One interned token block: its pages + its place in the radix chain.

    ``refs`` is the node's child count — a parent's pages are live as
    long as any longer chain extends through it, so only childless
    (``refs == 0``) nodes are eviction candidates.
    """

    __slots__ = ("key", "parent", "tokens", "n_tokens", "pages",
                 "children", "last_use", "checksum")

    def __init__(self, key, parent, tokens, n_tokens, pages,
                 checksum=None):
        self.key = key
        self.parent = parent
        self.tokens = tokens
        self.n_tokens = n_tokens           # cumulative tokens through here
        self.pages = pages
        self.children: dict = {}
        self.last_use = 0
        self.checksum = checksum           # blake2b of pages at intern

    @property
    def refs(self) -> int:
        return len(self.children)


class PrefixStore:
    """Hash/radix-keyed intern table over block-aligned cache pages.

    Host-side control structure (the pages themselves stay on device):
    block ``k`` of a prompt is keyed by
    ``blake2b(parent_key ‖ int32 tokens of block k)`` — a chain, so two
    prompts share node ``k`` iff their first ``(k+1)·block`` tokens are
    equal. Stored tokens are compared on every walk, so a hash collision
    degrades to a miss rather than resuming from someone else's prefill.

    ``match`` finds the longest *strict*-prefix chain of a prompt (at
    least one suffix token must remain to produce first-token logits);
    ``insert`` interns a computed batch-1 prefill's pages block by block
    (existing nodes are shared, not rewritten — the chunk-carry contract
    makes recomputed pages bitwise equal to the interned ones);
    ``assemble`` concatenates a chain's pages back into a batch-1 cache
    for :func:`bulk_insert`.

    Eviction is ref-counted LRU against ``max_tokens``: only childless
    nodes (``refs == 0``) retire, oldest ``last_use`` first, so a chain
    ages out leaf-to-root and a hot prefix's ancestry is never torn out
    from under it. Matching bumps the whole ancestry's recency. Clones
    happen synchronously at admit time, so an in-flight request never
    holds a store reference across serve cycles.
    """

    def __init__(self, block: int, *, max_tokens: int | None = None):
        assert block > 0
        self.block = int(block)
        self.max_tokens = max_tokens
        self._root = _PrefixNode(b"", None, None, 0, None)
        self._tick = 0
        self.cached_tokens = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.checksum_failures = 0

    def _walk_chain(self, tokens: np.ndarray, n_blocks: int):
        """Deepest existing node along ``tokens``'s first ``n_blocks``."""
        node = self._root
        for k in range(n_blocks):
            blk = tokens[k * self.block:(k + 1) * self.block]
            child = node.children.get(_chain_key(node.key, blk))
            if child is None or not np.array_equal(child.tokens, blk):
                break
            node = child
        return node

    def match(self, tokens) -> tuple[int, _PrefixNode | None]:
        """Longest interned strict prefix of ``tokens``.

        → ``(n_tokens, node)`` — the number of cached prompt tokens (a
        multiple of ``block``, always < ``len(tokens)``) and the chain
        node to clone from, or ``(0, None)`` on a miss. Bumps the
        matched ancestry's LRU recency.

        Every matched node's pages are re-verified against the checksum
        taken at intern time: a corrupted node (and the subtree hanging
        off it — its descendants resume from the corrupt pages) is
        dropped and the match truncates to the last clean ancestor, so
        corruption degrades to a shorter hit or a cold prefill instead
        of being cloned into every sharer. Raises
        :class:`repro.serving.faults.PrefixLookupError` when an active
        fault plan injects a store outage on this call — the scheduler
        treats it as a miss.
        """
        tokens = np.asarray(tokens, np.int32)
        if _faults.lookup_fails():
            raise _faults.PrefixLookupError(
                "injected prefix-store lookup failure")
        self._tick += 1
        node = self._walk_chain(tokens, max(0, (len(tokens) - 1)
                                           // self.block))
        node = self._verify_chain(node)
        if node is self._root:
            self.misses += 1
            return 0, None
        n = node
        while n is not self._root:
            n.last_use = self._tick
            n = n.parent
        self.hits += 1
        return node.n_tokens, node

    def _verify_chain(self, node: _PrefixNode) -> _PrefixNode:
        """Checksum the ancestry root→``node``; on the first mismatch
        drop that node's subtree and truncate the match to its parent."""
        chain = []
        n = node
        while n is not self._root:
            chain.append(n)
            n = n.parent
        for n in reversed(chain):
            if _page_checksum(n.pages) != n.checksum:
                self.checksum_failures += 1
                self._drop_subtree(n)
                return n.parent
        return node

    def _drop_subtree(self, node: _PrefixNode) -> None:
        """Remove ``node`` and every descendant from the store."""
        dropped = 1 + sum(1 for _ in self._iter_nodes(node))
        del node.parent.children[node.key]
        node.pages = None
        self.cached_tokens -= dropped * self.block

    def missing(self, tokens) -> bool:
        """True if interning ``tokens`` would create at least one node —
        the cheap pre-check that saves the lane extraction on re-inserts
        of an already-cached prefix."""
        tokens = np.asarray(tokens, np.int32)
        nb = len(tokens) // self.block
        return self._walk_chain(tokens, nb).n_tokens < nb * self.block

    def insert(self, tokens, cache) -> _PrefixNode | None:
        """Intern the block-aligned prefix of a computed prefill.

        ``tokens`` (length a multiple of ``block``; pass
        ``prompt[:plen // block * block]``) must be the first tokens the
        batch-1 ``cache`` was prefilled with. Existing chain nodes are
        reused; new blocks slice their pages out of ``cache``. Returns
        the chain's deepest node (None when ``tokens`` spans no block).
        """
        tokens = np.asarray(tokens, np.int32)
        nb = len(tokens) // self.block
        assert nb * self.block == len(tokens), \
            "insert() takes a block-aligned prefix"
        node = self._root
        self._tick += 1
        for k in range(nb):
            lo, hi = k * self.block, (k + 1) * self.block
            blk = tokens[lo:hi]
            key = _chain_key(node.key, blk)
            child = node.children.get(key)
            if child is not None:
                if not np.array_equal(child.tokens, blk):
                    break                  # hash collision: stop interning
                child.last_use = self._tick
                node = child
                continue
            pages = _slice_pages(cache, lo, hi)
            child = _PrefixNode(key, node, blk, hi, pages,
                                checksum=_page_checksum(pages))
            # injected post-intern rot: the checksum above was taken on
            # the clean pages, so the next match's verify catches this
            rng = _faults.page_corruption_rng()
            if rng is not None:
                child.pages = _flip_one_bit(child.pages, rng)
            child.last_use = self._tick
            node.children[key] = child
            self.cached_tokens += self.block
            node = child
        self._evict_cold()
        return None if node is self._root else node

    def assemble(self, node: _PrefixNode):
        """Chain pages root→``node`` as a batch-1 cache for
        :func:`bulk_insert` (token capacity = ``node.n_tokens``)."""
        chain = []
        n = node
        while n is not self._root:
            chain.append(n.pages)
            n = n.parent
        chain.reverse()
        cache = _concat_pages(chain)
        cache["lengths"] = jnp.full((1,), node.n_tokens, jnp.int32)
        return cache

    def _iter_nodes(self, node=None):
        node = node if node is not None else self._root
        for child in node.children.values():
            yield child
            yield from self._iter_nodes(child)

    def _evict_cold(self) -> None:
        """Retire cold childless nodes until under the token budget."""
        if self.max_tokens is None:
            return
        while self.cached_tokens > self.max_tokens:
            leaves = [n for n in self._iter_nodes() if not n.refs]
            if not leaves:
                break
            victim = min(leaves, key=lambda n: n.last_use)
            del victim.parent.children[victim.key]
            victim.pages = None
            self.cached_tokens -= self.block
            self.evictions += 1

    def check_invariants(self) -> None:
        """Structural invariants, asserted after every serve step under
        ``REPRO_PARANOID=1`` (DESIGN.md §Fault-tolerance): parent/child
        linkage and cumulative token counts are consistent, live nodes
        hold pages, ``cached_tokens`` equals the node count × block, and
        the token budget is only exceeded when every node is pinned by a
        child reference (the ref-counted eviction contract)."""
        n_nodes = 0
        for node in self._iter_nodes():
            assert node.parent.children.get(node.key) is node, \
                "prefix node detached from its parent"
            assert node.n_tokens == node.parent.n_tokens + self.block, \
                "prefix chain token count is not cumulative"
            assert node.pages is not None, "live prefix node lost its pages"
            n_nodes += 1
        assert self.cached_tokens == n_nodes * self.block, (
            f"cached_tokens={self.cached_tokens} but store holds "
            f"{n_nodes} blocks of {self.block}")
        if self.max_tokens is not None \
                and self.cached_tokens > self.max_tokens:
            assert all(n.refs for n in self._iter_nodes()), \
                "store over budget with evictable (childless) nodes"
