"""Serving caches: int8 KV + per-token absmax scales + packed LOP features.

The KV cache follows the paper's memory layout insight: exact keys/values in
int8 (absmax barrier), plus the 4-bit (sgn‖LO) *feature cache* the LOP screen
reads instead of the exact keys — the screen touches M·d/2 bytes while exact
attention touches only the K selected candidate blocks.

Capacity is block-aligned (``lop_block``) so candidate fetches stay
contiguous. Recurrent families cache their state instead ("KV cache of
seq_len" = recurrent state for SSM — DESIGN.md §6).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def attn_cache_zeros(cfg, batch: int, capacity: int):
    hkv, dh = cfg.n_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((batch, hkv, capacity, dh), jnp.int8),
        "v": jnp.zeros((batch, hkv, capacity, dh), jnp.int8),
        "k_scale": jnp.zeros((batch, hkv, capacity), jnp.float32),
        "v_scale": jnp.zeros((batch, hkv, capacity), jnp.float32),
        "feat": jnp.zeros((batch, hkv, capacity, dh // 2), jnp.uint8),
    }


def _stack(tree, n: int):
    return jax.tree.map(
        lambda a: jnp.zeros((n, *a.shape), a.dtype), tree)


def init_cache(cfg, batch: int, max_len: int, *, align: int | None = None):
    """Zero cache sized for ``max_len`` tokens (+1 block of decode slack).

    ``align`` (default lop_block) also aligns capacity to the SP shard
    count × block so every M-shard is block-aligned.
    """
    cap = round_up(max_len + 1, align or cfg.lop_block)
    cache = {"lengths": jnp.zeros((batch,), jnp.int32)}

    if cfg.family in ("dense", "moe", "vlm"):
        cache["layers"] = _stack(attn_cache_zeros(cfg, batch, cap),
                                 cfg.n_layers)
    elif cfg.family == "hybrid":
        n_sb = cfg.n_layers // cfg.attn_every
        n_mamba = cfg.attn_every - 1
        cache["blocks"] = {
            "attn": _stack(attn_cache_zeros(cfg, batch, cap), n_sb),
            "mamba": {
                "ssm": jnp.zeros((n_sb, n_mamba, batch, cfg.d_inner,
                                  cfg.mamba_d_state), jnp.float32),
                "conv": jnp.zeros((n_sb, n_mamba, batch, cfg.mamba_conv - 1,
                                   cfg.d_inner), jnp.float32),
            },
        }
    elif cfg.family == "ssm":
        cache["layers"] = {
            "wkv": jnp.zeros((cfg.n_layers, batch, cfg.n_heads, cfg.hd,
                              cfg.hd), jnp.float32),
            "x_tm": jnp.zeros((cfg.n_layers, batch, 1, cfg.d_model),
                              jnp.float32),
            "x_cm": jnp.zeros((cfg.n_layers, batch, 1, cfg.d_model),
                              jnp.float32),
        }
    elif cfg.family == "encdec":
        cross_cap = round_up(cfg.cross_ctx, align or cfg.lop_block)
        cache["layers"] = _stack(attn_cache_zeros(cfg, batch, cap),
                                 cfg.n_layers)
        cache["cross"] = _stack(attn_cache_zeros(cfg, batch, cross_cap),
                                cfg.n_layers)
        cache["cross_len"] = jnp.zeros((batch,), jnp.int32)
    else:
        raise ValueError(cfg.family)
    return cache


def cache_pspecs(cfg, cache, *, batch_axes="dp", seq_axes="sp"):
    """Logical-axis tree for the cache (M sequence-sharded, batch over dp).

    Attention caches shard the token axis over the model axis (SP) — the
    quota-sharded LOP selection in :mod:`repro.distributed.sp_decode` works
    per M-shard. Recurrent state shards its inner dim over the model axis.
    """
    def spec_for(path, a):
        name = path[-1]
        if name in ("k", "v", "feat"):
            return (batch_axes, None, seq_axes, None)
        if name in ("k_scale", "v_scale"):
            return (batch_axes, None, seq_axes)
        if name in ("lengths", "cross_len"):
            return (None,)
        if name == "ssm":
            return (batch_axes, "tp", None)
        if name == "conv":
            return (batch_axes, None, "tp")
        if name == "wkv":
            return (batch_axes, "tp", None, None)
        if name in ("x_tm", "x_cm"):
            return (batch_axes, None, None)
        raise KeyError(path)

    def walk(path, node):
        if isinstance(node, dict):
            return {k: walk(path + (k,), v) for k, v in node.items()}
        spec = spec_for(path, node)
        # stacked leading dims (layers / superblocks / per-block sublayers)
        extra = node.ndim - len(spec)
        return (None,) * extra + spec

    return walk((), cache)
