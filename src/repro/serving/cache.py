"""Serving caches: slot-paged int8 KV + absmax scales + packed LOP features.

The KV cache follows the paper's memory layout insight: exact keys/values in
int8 (absmax barrier), plus the 4-bit (sgn‖LO) *feature cache* the LOP screen
reads instead of the exact keys — the screen touches M·d/2 bytes while exact
attention touches only the K selected candidate blocks.

Capacity is block-aligned (``lop_block``) so candidate fetches stay
contiguous. Recurrent families cache their state instead ("KV cache of
seq_len" = recurrent state for SSM — DESIGN.md §6).

Slot-paged pool (continuous batching)
-------------------------------------
``init_cache_pool`` allocates the same tree for ``n_slots`` persistent
*decode lanes* plus a per-lane ``active`` mask. The lifecycle managed by
:mod:`repro.serving.scheduler` is::

    admit    a queued request once a lane is free,
    prefill  it — chunked families interleave one fixed-shape chunk per
             serve cycle (extract_slot → prefill_chunk → partial
             insert_slot with ``active=False``); the legacy path runs the
             whole prompt at once (length-bucketed compile) into a
             batch-1 cache,
    insert   that cache into the lane (``insert_slot``, one
             ``dynamic_update_slice`` per leaf) while the other lanes
             keep decoding; the final chunk's insert activates the lane
             and its logits seed the first token through the per-request
             sampler (:mod:`repro.serving.sampling`),
    decode   all active lanes together; inactive lanes are masked out of
             the LOP screen, block top-K and cache writes,
    evict    the lane on EOS/max-len (``evict_slot``) — the lane's bytes go
             stale but every read is masked by per-slot ``lengths``, so the
             next occupant sees a logically fresh lane.

Stale bytes above a lane's ``lengths`` are harmless by construction: the
LOP screen masks them to INT32_MIN before block reduction and exact
attention masks them to −∞ before the softmax, which is also why
evict→insert reuse is bit-identical to a zero-initialised lane.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def attn_cache_zeros(cfg, batch: int, capacity: int):
    hkv, dh = cfg.n_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((batch, hkv, capacity, dh), jnp.int8),
        "v": jnp.zeros((batch, hkv, capacity, dh), jnp.int8),
        "k_scale": jnp.zeros((batch, hkv, capacity), jnp.float32),
        "v_scale": jnp.zeros((batch, hkv, capacity), jnp.float32),
        "feat": jnp.zeros((batch, hkv, capacity, dh // 2), jnp.uint8),
    }


def _stack(tree, n: int):
    return jax.tree.map(
        lambda a: jnp.zeros((n, *a.shape), a.dtype), tree)


def init_cache(cfg, batch: int, max_len: int, *, align: int | None = None):
    """Zero cache sized for ``max_len`` tokens (+1 block of decode slack).

    ``align`` (default lop_block) also aligns capacity to the SP shard
    count × block so every M-shard is block-aligned.
    """
    cap = round_up(max_len + 1, align or cfg.lop_block)
    cache = {"lengths": jnp.zeros((batch,), jnp.int32)}

    if cfg.family in ("dense", "moe", "vlm"):
        cache["layers"] = _stack(attn_cache_zeros(cfg, batch, cap),
                                 cfg.n_layers)
    elif cfg.family == "hybrid":
        n_sb = cfg.n_layers // cfg.attn_every
        n_mamba = cfg.attn_every - 1
        cache["blocks"] = {
            "attn": _stack(attn_cache_zeros(cfg, batch, cap), n_sb),
            "mamba": {
                "ssm": jnp.zeros((n_sb, n_mamba, batch, cfg.d_inner,
                                  cfg.mamba_d_state), jnp.float32),
                "conv": jnp.zeros((n_sb, n_mamba, batch, cfg.mamba_conv - 1,
                                   cfg.d_inner), jnp.float32),
            },
        }
    elif cfg.family == "ssm":
        cache["layers"] = {
            "wkv": jnp.zeros((cfg.n_layers, batch, cfg.n_heads, cfg.hd,
                              cfg.hd), jnp.float32),
            "x_tm": jnp.zeros((cfg.n_layers, batch, 1, cfg.d_model),
                              jnp.float32),
            "x_cm": jnp.zeros((cfg.n_layers, batch, 1, cfg.d_model),
                              jnp.float32),
        }
    elif cfg.family == "encdec":
        cross_cap = round_up(cfg.cross_ctx, align or cfg.lop_block)
        cache["layers"] = _stack(attn_cache_zeros(cfg, batch, cap),
                                 cfg.n_layers)
        cache["cross"] = _stack(attn_cache_zeros(cfg, batch, cross_cap),
                                cfg.n_layers)
        cache["cross_len"] = jnp.zeros((batch,), jnp.int32)
    else:
        raise ValueError(cfg.family)
    return cache


def _leaf_spec(path, *, batch_axes="dp", seq_axes="sp"):
    """Logical axes of one cache leaf, *excluding* stacked leading dims.

    Every spec starts at the batch/slot axis, so the slot axis index of a
    leaf is ``leaf.ndim - len(_leaf_spec(path))`` (used by ``insert_slot``).
    """
    name = path[-1]
    if name in ("k", "v", "feat"):
        return (batch_axes, None, seq_axes, None)
    if name in ("k_scale", "v_scale"):
        return (batch_axes, None, seq_axes)
    if name in ("lengths", "cross_len", "active"):
        return (None,)
    if name == "ssm":
        return (batch_axes, "tp", None)
    if name == "conv":
        return (batch_axes, None, "tp")
    if name == "wkv":
        return (batch_axes, "tp", None, None)
    if name in ("x_tm", "x_cm"):
        return (batch_axes, None, None)
    raise KeyError(path)


def cache_pspecs(cfg, cache, *, batch_axes="dp", seq_axes="sp"):
    """Logical-axis tree for the cache (M sequence-sharded, batch over dp).

    Attention caches shard the token axis over the model axis (SP) — the
    quota-sharded LOP selection in :mod:`repro.distributed.sp_decode` works
    per M-shard. Recurrent state shards its inner dim over the model axis.
    The per-slot ``lengths``/``active`` vectors stay replicated.
    """
    def walk(path, node):
        if isinstance(node, dict):
            return {k: walk(path + (k,), v) for k, v in node.items()}
        spec = _leaf_spec(path, batch_axes=batch_axes, seq_axes=seq_axes)
        # stacked leading dims (layers / superblocks / per-block sublayers)
        extra = node.ndim - len(spec)
        return (None,) * extra + spec

    return walk((), cache)


# ---------------------------------------------------------------------------
# Slot-paged pool ops (continuous batching)
# ---------------------------------------------------------------------------

def slot_axis(path, leaf) -> int:
    """Index of the slot (batch) axis in a cache leaf at ``path``."""
    return leaf.ndim - len(_leaf_spec(path))


def init_cache_pool(cfg, n_slots: int, max_len: int, *,
                    align: int | None = None):
    """Slot-paged pool: ``n_slots`` persistent decode lanes, all inactive.

    Identical tree to :func:`init_cache` (so ``serve_step`` runs on it
    unchanged) plus a per-lane ``active`` mask that the engine threads
    through the LOP screen, block top-K and cache writes.
    """
    pool = init_cache(cfg, n_slots, max_len, align=align)
    pool["active"] = jnp.zeros((n_slots,), jnp.bool_)
    return pool


def pool_capacity(pool) -> int:
    """Token capacity M of the pool's attention lanes (0 if attention-free)."""
    caps = []

    def walk(path, node):
        if isinstance(node, dict):
            for k, v in node.items():
                walk(path + (k,), v)
        elif path[-1] == "k" and "cross" not in path:
            spec = _leaf_spec(path)
            caps.append(node.shape[node.ndim - len(spec) + 2])

    walk((), pool)
    return caps[0] if caps else 0


def insert_slot(pool, slot, req_cache, active=True):
    """Write a single-request (batch-1) prefill cache into lane ``slot``.

    One ``dynamic_update_slice`` per leaf at that leaf's slot axis — the
    other lanes are untouched, so insertion composes with donated buffers
    in a jit'd decode loop. ``slot`` may be a traced scalar (one compile
    serves every lane). The request cache's token capacity may be smaller
    than the pool's; positions above it go stale and are masked by
    ``lengths``.

    ``active`` (static bool or traced scalar) is the *partial-insert*
    switch for chunked prefill: a mid-prefill lane is written back with
    ``active=False`` after every chunk — its K/V for [0, lengths) are
    real, but the decode step must not advance it — and the final chunk
    flips it live. The scheduler keeps such a lane out of its free-lane
    deque (note: :func:`free_slots` reports by ``active`` alone and does
    NOT know about reservations — DESIGN.md §Chunked-prefill).
    """
    def walk(path, dst, src):
        if isinstance(dst, dict):
            return {k: walk(path + (k,), dst[k], src[k]) if k in src
                    else dst[k] for k in dst}
        ax = slot_axis(path, dst)
        start = (0,) * ax + (slot,) + (0,) * (dst.ndim - ax - 1)
        return jax.lax.dynamic_update_slice(dst, src, start)

    new = walk((), {k: v for k, v in pool.items() if k != "active"},
               req_cache)
    new["active"] = pool["active"].at[slot].set(active)
    return new


def extract_slot(pool, slot):
    """Batch-1 view of lane ``slot`` — the inverse of :func:`insert_slot`.

    One ``dynamic_slice`` per leaf (``slot`` may be traced). Chunked
    prefill round-trips extract → ``prefill_chunk`` → partial
    ``insert_slot`` once per chunk, so the in-flight prompt's K/V lives
    in the pool between chunks rather than in host-side side state.
    """
    def walk(path, node):
        if isinstance(node, dict):
            return {k: walk(path + (k,), v) for k, v in node.items()
                    if k != "active"}
        ax = slot_axis(path, node)
        start = (0,) * ax + (slot,) + (0,) * (node.ndim - ax - 1)
        sizes = node.shape[:ax] + (1,) + node.shape[ax + 1:]
        return jax.lax.dynamic_slice(node, start, sizes)

    return walk((), pool)


def evict_slot(pool, slot):
    """Retire lane ``slot``: mark inactive, zero its length.

    The lane's K/V/feature bytes are left stale — every consumer masks by
    ``lengths``/``active``, and the next ``insert_slot`` overwrites them.
    """
    pool = dict(pool)
    pool["active"] = pool["active"].at[slot].set(False)
    pool["lengths"] = pool["lengths"].at[slot].set(0)
    return pool


# ``free_slot`` is eviction under its queue-side name: a lane freed for the
# next admission. Kept as an alias so scheduler code reads naturally.
free_slot = evict_slot


def free_slots(pool) -> list[int]:
    """Host-side list of lanes currently free for admission (syncs)."""
    return [int(i) for i in
            np.flatnonzero(~np.asarray(pool["active"]))]
