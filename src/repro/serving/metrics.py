"""Observability core for the serving stack (DESIGN.md §Serving-frontend).

A process-wide registry of counters / gauges / fixed-bucket histograms
with Prometheus text exposition, plus the two helpers the rest of the
repo shares:

- :func:`percentile` / :func:`summarize` — THE percentile computation.
  ``launch/serve.py``, ``benchmarks/prefill_interleave.py`` and
  ``benchmarks/table1_e2e.py`` each used to carry their own copy; they
  all route here now, so a p99 means the same thing in every report.
- :class:`StageTimer` — per-request span recorder for the
  queue → prefill-chunks → decode (→ spec draft/verify) lifecycle the
  scheduler threads through (one timer per request, ``clock``-agnostic
  so virtual-clock tests stay deterministic).

Design constraints, in order:

- stdlib + numpy only (the HTTP frontend must not grow dependencies);
- instruments are *mergeable*: fixed bucket bounds and monotone
  counters mean two registries (e.g. per-worker, the future
  disaggregated pool) combine by addition (:meth:`MetricsRegistry.merge`);
- one place owns the metric NAMES (:func:`scheduler_instruments`,
  :func:`http_instruments`), so the synthetic driver and the HTTP
  server export identical series and dashboards don't fork.

Thread-safety: every mutation takes a per-registry lock. The scheduler
pump thread and the asyncio loop both write; ``/metrics`` renders from
either.
"""

from __future__ import annotations

import re
import threading
import time

import numpy as np

# Prometheus exposition rules: metric names [a-zA-Z_:][a-zA-Z0-9_:]*,
# label names [a-zA-Z_][a-zA-Z0-9_]*
_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")

# latency buckets (seconds): spans micro-benchmark decode steps (ms) up
# to chunked prefills of long prompts; fixed so histograms merge
DEFAULT_TIME_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
                        0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0)


# ---------------------------------------------------------------------------
# Shared percentile helper (the dedupe target)
# ---------------------------------------------------------------------------

def percentile(values, q: float) -> float:
    """Linear-interpolation percentile (numpy's default), as one float.

    Empty input yields NaN instead of raising — absent traffic renders
    as a NaN row, not a crashed report. NaN inputs propagate (numpy
    semantics), matching the previous inline copies bit-for-bit.
    """
    arr = np.asarray(list(values), np.float64)
    if arr.size == 0:
        return float("nan")
    return float(np.percentile(arr, q))


def summarize(values, qs=(50, 90, 99), prefix: str = "") -> dict:
    """``{f"{prefix}p{q}": percentile(values, q)}`` over ``qs``."""
    vals = list(values)
    return {f"{prefix}p{q}": percentile(vals, q) for q in qs}


# ---------------------------------------------------------------------------
# Instruments
# ---------------------------------------------------------------------------

class _Child:
    """One labeled series of a family. Subclasses hold the value(s)."""

    def __init__(self, family: "_Family", label_values: tuple):
        self._family = family
        self._lock = family._lock
        self.label_values = label_values


class Counter(_Child):
    def __init__(self, family, label_values):
        super().__init__(family, label_values)
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        assert n >= 0, f"counter {self._family.name} decremented by {n}"
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        return self._value


class Gauge(_Child):
    def __init__(self, family, label_values):
        super().__init__(family, label_values)
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    def dec(self, n: float = 1.0) -> None:
        self.inc(-n)

    @property
    def value(self) -> float:
        return self._value


class Histogram(_Child):
    """Fixed-bucket histogram: per-bucket counts + sum + count.

    Buckets are upper bounds (``le``); export renders them cumulative
    with a trailing ``+Inf`` per the Prometheus text format.
    """

    def __init__(self, family, label_values):
        super().__init__(family, label_values)
        self.buckets = family.buckets
        self.counts = [0] * (len(self.buckets) + 1)   # last = overflow
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            i = 0
            while i < len(self.buckets) and v > self.buckets[i]:
                i += 1
            self.counts[i] += 1
            self.sum += v
            self.count += 1

    @property
    def value(self) -> float:
        """A histogram's scalar read is its ``_sum`` (matches the
        exported ``<name>_sum`` series)."""
        return self.sum

    def quantile(self, q: float) -> float:
        """Bucket-interpolated quantile estimate in [0, 1] rank space —
        a cheap server-side p50/p99 for reports; exact percentiles come
        from :func:`percentile` over raw samples."""
        if self.count == 0:
            return float("nan")
        target = q * self.count
        seen = 0
        lo = 0.0
        for i, ub in enumerate(self.buckets):
            nxt = seen + self.counts[i]
            if nxt >= target and self.counts[i]:
                frac = (target - seen) / self.counts[i]
                return lo + frac * (ub - lo)
            seen = nxt
            lo = ub
        return self.buckets[-1] if self.buckets else float("nan")


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class _Family:
    """One named metric: fixed kind + label names, N labeled children."""

    def __init__(self, registry, kind: str, name: str, help_: str,
                 label_names: tuple, buckets=None):
        assert _NAME_RE.match(name), f"bad metric name {name!r}"
        assert all(_LABEL_RE.match(l) for l in label_names), label_names
        self.kind = kind
        self.name = name
        self.help = help_
        self.label_names = tuple(label_names)
        self.buckets = tuple(buckets) if buckets is not None else ()
        self._lock = registry._lock
        self._children: dict[tuple, _Child] = {}

    def labels(self, **kv) -> _Child:
        assert set(kv) == set(self.label_names), (
            f"{self.name}: labels {sorted(kv)} != declared "
            f"{sorted(self.label_names)}")
        key = tuple(str(kv[l]) for l in self.label_names)
        child = self._children.get(key)
        if child is None:
            with self._lock:
                child = self._children.setdefault(
                    key, _KINDS[self.kind](self, key))
        return child

    # label-less families proxy the child API on the family itself
    def _default(self) -> _Child:
        assert not self.label_names, (
            f"{self.name} has labels {self.label_names}; call .labels()")
        return self.labels()

    def inc(self, n: float = 1.0) -> None:
        self._default().inc(n)

    def dec(self, n: float = 1.0) -> None:
        self._default().dec(n)

    def set(self, v: float) -> None:
        self._default().set(v)

    def observe(self, v: float) -> None:
        self._default().observe(v)

    @property
    def value(self) -> float:
        return self._default().value


class MetricsRegistry:
    """Mutable collection of metric families, rendered by :meth:`render`.

    Families are created idempotently: asking twice for the same name
    returns the same family (kind/labels must agree). ``REGISTRY`` below
    is the process-wide default the server exports on ``/metrics``;
    tests and the synthetic driver build private registries so runs
    don't bleed into each other.
    """

    def __init__(self):
        self._lock = threading.RLock()
        self._families: dict[str, _Family] = {}

    def _family(self, kind, name, help_, labels, buckets=None) -> _Family:
        with self._lock:
            fam = self._families.get(name)
            if fam is not None:
                assert fam.kind == kind and \
                    fam.label_names == tuple(labels), (
                    f"{name} re-registered as {kind}{tuple(labels)}, was "
                    f"{fam.kind}{fam.label_names}")
                return fam
            fam = _Family(self, kind, name, help_, tuple(labels), buckets)
            self._families[name] = fam
            return fam

    def counter(self, name, help_="", labels=()) -> _Family:
        return self._family("counter", name, help_, labels)

    def gauge(self, name, help_="", labels=()) -> _Family:
        return self._family("gauge", name, help_, labels)

    def histogram(self, name, help_="", labels=(),
                  buckets=DEFAULT_TIME_BUCKETS) -> _Family:
        return self._family("histogram", name, help_, labels, buckets)

    def value(self, name: str, labels: dict | None = None) -> float:
        """Current value of a counter/gauge series (0.0 if the series
        never fired — a counter that never incremented reads 0)."""
        fam = self._families.get(name)
        if fam is None:
            return 0.0
        key = tuple(str((labels or {})[l]) for l in fam.label_names)
        child = fam._children.get(key)
        return child.value if child is not None else 0.0

    def merge(self, other: "MetricsRegistry") -> None:
        """Fold ``other`` into this registry: counters and histogram
        counts add, gauges take ``other``'s latest value. Bucket bounds
        must agree — that is what "fixed-bucket, mergeable" buys."""
        with self._lock, other._lock:
            for name, ofam in other._families.items():
                fam = self._family(ofam.kind, name, ofam.help,
                                   ofam.label_names, ofam.buckets or None)
                if fam.kind == "histogram":
                    assert fam.buckets == ofam.buckets, (
                        f"{name}: bucket bounds differ — unmergeable")
                for key, ochild in ofam._children.items():
                    kv = dict(zip(fam.label_names, key))
                    child = fam.labels(**kv)
                    if fam.kind == "counter":
                        child._value += ochild._value
                    elif fam.kind == "gauge":
                        child._value = ochild._value
                    else:
                        child.sum += ochild.sum
                        child.count += ochild.count
                        for i, c in enumerate(ochild.counts):
                            child.counts[i] += c

    # ---------------- Prometheus text exposition ----------------

    def render(self) -> str:
        """Prometheus text format 0.0.4: HELP/TYPE per family, one line
        per series; histograms render cumulative ``_bucket`` series plus
        ``_sum``/``_count``."""
        out = []
        with self._lock:
            for name in sorted(self._families):
                fam = self._families[name]
                out.append(f"# HELP {name} {fam.help}")
                out.append(f"# TYPE {name} {fam.kind}")
                for key in sorted(fam._children):
                    child = fam._children[key]
                    base = _labels_str(fam.label_names, key)
                    if fam.kind in ("counter", "gauge"):
                        out.append(f"{name}{base} {_fmt(child.value)}")
                        continue
                    cum = 0
                    for i, ub in enumerate(child.buckets):
                        cum += child.counts[i]
                        le = _labels_str(fam.label_names + ("le",),
                                         key + (_fmt(ub),))
                        out.append(f"{name}_bucket{le} {cum}")
                    cum += child.counts[-1]
                    le = _labels_str(fam.label_names + ("le",),
                                     key + ("+Inf",))
                    out.append(f"{name}_bucket{le} {cum}")
                    out.append(f"{name}_sum{base} {_fmt(child.sum)}")
                    out.append(f"{name}_count{base} {child.count}")
        return "\n".join(out) + "\n"


def _fmt(v: float) -> str:
    f = float(v)
    return str(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


def _labels_str(names: tuple, values: tuple) -> str:
    if not names:
        return ""
    esc = [str(v).replace("\\", r"\\").replace('"', r'\"')
           .replace("\n", r"\n") for v in values]
    inner = ",".join(f'{n}="{v}"' for n, v in zip(names, esc))
    return "{" + inner + "}"


#: process-wide default registry — the HTTP server exports this on
#: ``/metrics``; library code should take a registry parameter and only
#: default to this.
REGISTRY = MetricsRegistry()


# ---------------------------------------------------------------------------
# Per-request stage timer
# ---------------------------------------------------------------------------

#: lifecycle stages, in order (spec stages only under spec decoding)
STAGES = ("queue", "prefill", "decode")


class StageTimer:
    """Accumulates wall-time per lifecycle stage for ONE request.

    The scheduler drives it: ``enter("queue")`` at submit, ``to()`` on
    each transition, ``finish()`` at retirement — the result is a
    ``{stage: seconds}`` dict whose values sum to the request's
    in-system time. Entering the same stage twice accumulates (chunked
    prefill re-enters "prefill" per chunk if the caller wants per-chunk
    granularity; the scheduler uses one span per stage). ``clock`` is
    injectable so virtual-clock schedulers produce deterministic spans.
    """

    def __init__(self, clock=time.monotonic):
        self._clock = clock
        self._stage: str | None = None
        self._t0 = 0.0
        self.spans: dict[str, float] = {}

    def enter(self, stage: str) -> None:
        if self._stage is not None:
            self._close()
        self._stage = stage
        self._t0 = self._clock()

    def to(self, stage: str) -> None:
        self.enter(stage)

    def _close(self) -> None:
        dt = self._clock() - self._t0
        self.spans[self._stage] = self.spans.get(self._stage, 0.0) + dt
        self._stage = None

    def finish(self) -> dict[str, float]:
        if self._stage is not None:
            self._close()
        return self.spans


# ---------------------------------------------------------------------------
# The shared metric names (driver + HTTP server export these identically)
# ---------------------------------------------------------------------------

class _Namespace:
    def __init__(self, **kv):
        self.__dict__.update(kv)


def scheduler_instruments(registry: MetricsRegistry) -> _Namespace:
    """Bind the scheduler's instrument set on ``registry``.

    One function owns the names so ``launch/serve.py`` and
    ``serving/frontend`` cannot drift apart (the metric-names table in
    DESIGN.md §Serving-frontend mirrors this list).
    """
    return _Namespace(
        requests=registry.counter(
            "repro_requests_total",
            "requests retired, by finish reason", labels=("outcome",)),
        shed=registry.counter(
            "repro_requests_shed_total",
            "submits rejected at the admission bound"),
        deadline=registry.counter(
            "repro_deadline_expired_total",
            "requests retired past their deadline_ms budget"),
        fault_events=registry.counter(
            "repro_fault_events_total",
            "non-finite-logit detections (decode guard + spec verify)"),
        fault_recoveries=registry.counter(
            "repro_fault_recoveries_total",
            "rollback+retry recoveries that succeeded"),
        fault_finishes=registry.counter(
            "repro_fault_finishes_total",
            "lanes retired with reason fault (retry also failed)"),
        tokens=registry.counter(
            "repro_tokens_generated_total", "tokens emitted to requests"),
        prefill_tokens=registry.counter(
            "repro_prefill_tokens_total",
            "prompt tokens, by whether they were computed or served "
            "from the prefix store", labels=("source",)),
        queue_depth=registry.gauge(
            "repro_queue_depth", "requests waiting for a lane"),
        active_lanes=registry.gauge(
            "repro_active_lanes", "decode lanes currently occupied"),
        stage_seconds=registry.histogram(
            "repro_request_stage_seconds",
            "per-request wall time by lifecycle stage",
            labels=("stage",)),
        ttft=registry.histogram(
            "repro_request_ttft_seconds",
            "arrival to first emitted token"),
        itl=registry.histogram(
            "repro_request_itl_seconds", "inter-token decode gaps"),
        e2e=registry.histogram(
            "repro_request_e2e_seconds", "arrival to retirement"),
        prefill_chunk=registry.histogram(
            "repro_prefill_chunk_seconds",
            "one engine.prefill_chunk launch"),
        decode_step=registry.histogram(
            "repro_decode_step_seconds",
            "one batched engine.decode_step launch"),
        spec_draft=registry.histogram(
            "repro_spec_draft_seconds",
            "one batched engine.draft launch"),
        spec_verify=registry.histogram(
            "repro_spec_verify_seconds",
            "one engine.verify_chunk launch"),
    )


def http_instruments(registry: MetricsRegistry) -> _Namespace:
    """Bind the HTTP frontend's instrument set on ``registry``."""
    return _Namespace(
        requests=registry.counter(
            "repro_http_requests_total",
            "HTTP requests served, by route and status code",
            labels=("route", "code")),
        in_flight=registry.gauge(
            "repro_http_in_flight", "HTTP requests currently being served"),
        disconnects=registry.counter(
            "repro_http_client_disconnects_total",
            "streaming requests whose client went away mid-stream"),
    )
