"""Jit-compatible batched token sampling for the serving stack.

One sampler serves every decode lane of the slot pool AND the lockstep
reference path (DESIGN.md §Serving-API). The contract that makes
pool-vs-lockstep token equivalence testable per
:class:`repro.serving.api.SamplingParams`:

  * **Greedy fast path.** ``temperature <= 0`` lanes return
    ``argmax(logits)`` — bitwise the pre-API scheduler behaviour, so a
    default (greedy) request reproduces historical tokens exactly.
  * **Lane-local PRNG schedule.** The key for a request's *i*-th
    generated token (0-based; the prefill-seeded first token is i = 0)
    is ``fold_in(PRNGKey(seed), i)`` — a function of the request's own
    ``seed`` and its own emission count only, never of the batch
    composition or the slot index. A seeded request therefore decodes
    the same tokens whether it runs alone or shares the
    continuous-batching pool (the sampling analogue of the slot-pool
    greedy-equivalence invariant).
  * **Row-local math.** Every op (argmax, per-row sort, cumsum,
    categorical) reduces over the vocab axis of its own row, so a
    lane's sample is independent of the other lanes' logits.

Filtering follows the usual serving convention: temperature scales the
logits first, then top-k and top-p restrict the support, then one
categorical draw. Ties at the top-k/top-p cutoff value are *kept*
(threshold comparisons are ``>=``), which can admit a few extra tokens
on exactly-tied logits — deterministic, and irrelevant to the
distribution-sanity guarantees the tests pin.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# temperatures at or below this sample greedily (exact argmax)
GREEDY_EPS = 0.0


def lane_keys(seeds: jax.Array, steps: jax.Array) -> jax.Array:
    """Per-lane PRNG keys [B, 2] from (request seed, emission index).

    ``fold_in(PRNGKey(seed), step)`` — lane-local by construction (see
    module docstring). Jit/vmap-compatible; both operands may be traced.
    """
    def one(seed, step):
        return jax.random.fold_in(jax.random.PRNGKey(seed), step)

    return jax.vmap(one)(seeds.astype(jnp.uint32), steps.astype(jnp.uint32))


def _mask_top_k(logits: jax.Array, top_k: jax.Array) -> jax.Array:
    """Keep each row's k largest logits (k <= 0 disables). Traced per-lane
    k via the k-th-largest value as a threshold; ties at it are kept."""
    v = logits.shape[-1]
    desc = jnp.sort(logits, axis=-1)[:, ::-1]
    kk = jnp.clip(top_k, 1, v).astype(jnp.int32)
    thresh = jnp.take_along_axis(desc, kk[:, None] - 1, axis=-1)
    keep = (logits >= thresh) | (top_k[:, None] <= 0)
    return jnp.where(keep, logits, -jnp.inf)


def _mask_top_p(logits: jax.Array, top_p: jax.Array) -> jax.Array:
    """Nucleus filter: smallest prefix of the sorted distribution with
    cumulative mass >= p (p >= 1 disables). A token is kept iff the mass
    strictly before it is < p, so the crossing token always survives and
    the support is never empty."""
    desc = jnp.sort(logits, axis=-1)[:, ::-1]
    probs = jax.nn.softmax(desc, axis=-1)
    before = jnp.cumsum(probs, axis=-1) - probs
    # clamp p away from 0 so the top-1 token (mass-before 0) always
    # survives — p <= 0 degenerates to greedy-on-the-nucleus, not an
    # empty support
    keep_desc = before < jnp.maximum(top_p, 1e-9)[:, None]
    cutoff = jnp.min(jnp.where(keep_desc, desc, jnp.inf), axis=-1,
                     keepdims=True)
    keep = (logits >= cutoff) | (top_p[:, None] >= 1.0)
    return jnp.where(keep, logits, -jnp.inf)


def sample_tokens(logits: jax.Array, keys: jax.Array, temperature: jax.Array,
                  top_k: jax.Array, top_p: jax.Array) -> jax.Array:
    """Batched per-lane sampling. → int32 [B].

    logits      f32 [B, V]   next-token logits (one row per lane)
    keys        uint32 [B, 2] per-lane PRNG keys (:func:`lane_keys`)
    temperature f32 [B]      <= 0 → greedy argmax for that lane
    top_k       int32 [B]    <= 0 → disabled
    top_p       f32 [B]      >= 1 → disabled

    Lanes are independent rows; retired/garbage lanes sample harmlessly
    (their token is never read by the scheduler).
    """
    logits = logits.astype(jnp.float32)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits / jnp.maximum(temperature, 1e-6)[:, None]
    scaled = _mask_top_k(scaled, top_k)
    scaled = _mask_top_p(scaled, top_p)
    drawn = jax.vmap(jax.random.categorical)(keys, scaled).astype(jnp.int32)
    return jnp.where(temperature > GREEDY_EPS, drawn, greedy)


def sample_with_seed(logits: jax.Array, seeds: jax.Array, steps: jax.Array,
                     temperature: jax.Array, top_k: jax.Array,
                     top_p: jax.Array) -> jax.Array:
    """:func:`sample_tokens` with the key schedule applied in-graph —
    the single entry both the fused decode step and the first-token
    (prefill-logits) sample go through, so pooled and lockstep lanes
    draw from identical keys."""
    return sample_tokens(logits, lane_keys(seeds, steps), temperature,
                         top_k, top_p)
