"""Continuous-batching scheduler over the slot-paged cache pool.

The serving layer's control plane: a FIFO request queue feeding ``n_slots``
persistent decode lanes (:func:`repro.serving.cache.init_cache_pool`). The
lifecycle per request is

    admit → prefill → insert → decode → evict

  admit    — a queued request is taken once a lane is free; the other lanes
             keep decoding in the meantime.
  prefill  — the request runs alone (batch 1) through ``engine.prefill``.
             Prompts are right-padded to a power-of-two *length bucket* so
             compilation is bounded to a handful of shapes instead of one
             per distinct prompt length; ``true_len`` keeps the padded
             positions out of the logits and the cache length. Recurrent
             families (hybrid/ssm) integrate state over every position, so
             they use exact-length buckets (one compile per length).
  insert   — the batch-1 cache is written into the free lane with one
             ``dynamic_update_slice`` per leaf (``insert_slot``), and the
             prefill's argmax becomes the lane's first generated token.
  decode   — one jit'd ``serve_step`` advances *all* active lanes; retired
             lanes are masked out of the LOP screen, block top-K and cache
             writes by the per-slot ``active`` mask.
  evict    — on EOS or the request's token budget the lane is retired
             (``evict_slot``) and immediately reusable; stale bytes are
             masked by ``lengths`` so the next occupant is unaffected.

Determinism note: lanes are independent through every attention/FFN path,
so a request decodes the same tokens whether it shares the pool or runs
alone (``lockstep_generate``) — the equivalence the tests pin down. The
exception is MoE capacity dropping, which ranks tokens across the batch;
with a generous ``capacity_factor`` the paths agree, but bit-exactness is
only guaranteed for dense/vlm/recurrent families.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.cache import (evict_slot, init_cache_pool, insert_slot,
                                 pool_capacity)
from repro.serving.engine import prefill, serve_step


@dataclass
class Request:
    """One generation request entering the queue."""
    rid: int
    prompt: np.ndarray                 # int32 [prompt_len]
    max_new_tokens: int
    eos_id: int | None = None
    arrival: float | None = None       # driver-set; default stamps submit()
    frames: np.ndarray | None = None   # encdec audio frames [S_enc, D]
    patches: np.ndarray | None = None  # vlm patch embeds [n_img, D]


@dataclass
class RequestResult:
    """Completed request: emitted tokens + latency breakdown."""
    rid: int
    prompt_len: int
    tokens: list[int] = field(default_factory=list)
    t_arrival: float = 0.0
    t_admit: float = 0.0               # prefill started (lane granted)
    t_first: float = 0.0               # first token emitted (TTFT end)
    t_done: float = 0.0
    finish_reason: str = ""            # "eos" | "length"

    @property
    def ttft(self) -> float:
        return self.t_first - self.t_arrival

    @property
    def latency(self) -> float:
        return self.t_done - self.t_arrival


@dataclass
class _Lane:
    """Host-side state of one occupied decode lane."""
    result: RequestResult
    remaining: int
    eos_id: int | None


def pow2_bucket(n: int, *, lo: int = 16, hi: int | None = None) -> int:
    """Smallest power-of-two ≥ n (clamped to [lo, hi]) — the prefill
    compilation bucket. A few buckets cover every prompt length, bounding
    recompiles regardless of traffic mix."""
    b = lo
    while b < n:
        b *= 2
    return min(b, hi) if hi is not None else b


class Scheduler:
    """Continuous-batching engine front-end (greedy decoding).

    Drives the admit → prefill → insert → decode → evict lifecycle over a
    slot-paged pool. ``step()`` advances every active lane one token and
    returns the requests that completed; ``admit()`` fills free lanes from
    the queue. The driver (``launch/serve.py``) interleaves the two.
    """

    def __init__(self, cfg, qp, *, n_slots: int, max_len: int,
                 use_lop: bool = True, bucket_min: int = 16,
                 clock=time.monotonic):
        self.cfg = cfg
        self.qp = qp
        self.n_slots = n_slots
        self.max_len = max_len
        self.use_lop = use_lop
        self.bucket_min = bucket_min
        self.clock = clock
        self.pool = init_cache_pool(cfg, n_slots, max_len)
        self.capacity = pool_capacity(self.pool)
        # encdec: cross-attention lanes have their own (cross_ctx) capacity
        self.cross_capacity = (self.pool["cross"]["k"].shape[3]
                               if "cross" in self.pool else 0)

        self.queue: deque[Request] = deque()
        self.lanes: list[_Lane | None] = [None] * n_slots
        self._free: deque[int] = deque(range(n_slots))
        # pending next-token per lane, fed to the next decode step
        self._next_tok = np.zeros((n_slots, 1), np.int32)
        self.results: list[RequestResult] = []
        self.prefill_compiles = 0

        self._prefill_fns: dict[int, object] = {}
        self._step_fn = jax.jit(
            lambda qp, c, t: serve_step(cfg, qp, c, t, use_lop=use_lop),
            donate_argnums=(1,))
        self._insert_fn = jax.jit(insert_slot, donate_argnums=(0,))
        self._evict_fn = jax.jit(evict_slot, donate_argnums=(0,))

    # ---------------- queue ----------------

    def submit(self, req: Request) -> None:
        # attention-free pools (capacity 0: recurrent state only) have no
        # token-capacity bound — only the prompt buffer limits them
        need = len(req.prompt) + req.max_new_tokens
        if self.cfg.family == "vlm" and req.patches is not None:
            need += len(req.patches)   # image prefix occupies cache slots
        assert not self.capacity or need <= self.capacity, (
            f"request {req.rid} needs {need} tokens but pool capacity is "
            f"{self.capacity}")
        assert req.frames is None or len(req.frames) <= \
            self.cross_capacity, (
            f"request {req.rid} has {len(req.frames)} encoder frames but "
            f"the pool's cross capacity is {self.cross_capacity}")
        if req.arrival is None:
            req.arrival = self.clock()
        self.queue.append(req)

    @property
    def n_active(self) -> int:
        return sum(l is not None for l in self.lanes)

    def has_work(self) -> bool:
        return bool(self.queue) or self.n_active > 0

    # ---------------- admit / prefill / insert ----------------

    def _bucket(self, prompt_len: int) -> int:
        if self.cfg.family in ("hybrid", "ssm", "encdec"):
            # recurrent state integrates every position; encdec frames tie
            # the compile to the prompt anyway → exact-length, no padding
            return prompt_len
        return pow2_bucket(prompt_len, lo=self.bucket_min,
                           hi=self.max_len)

    def _prefill_for(self, key):
        fn = self._prefill_fns.get(key)
        if fn is None:
            cfg, use_lop, max_len = self.cfg, self.use_lop, self.max_len
            fn = jax.jit(lambda qp, t, tl, kw: prefill(
                cfg, qp, t, max_len=max_len, use_lop=use_lop, true_len=tl,
                **kw))
            self._prefill_fns[key] = fn
            self.prefill_compiles += 1
        return fn

    def admit(self) -> int:
        """Admit queued requests into free lanes. Returns #admitted."""
        n = 0
        while self.queue and self._free:
            req = self.queue.popleft()
            slot = self._free.popleft()
            plen = len(req.prompt)
            bucket = max(self._bucket(plen), plen)
            t_admit = self.clock()
            padded = np.zeros((1, bucket), np.int32)
            padded[0, :plen] = req.prompt
            kw = {}
            true_len = plen
            if req.frames is not None:
                kw["frames"] = jnp.asarray(req.frames)[None]
            if req.patches is not None:
                kw["patches"] = jnp.asarray(req.patches)[None]
                true_len += len(req.patches)   # image prefix precedes text
            key = (bucket,) + tuple(sorted(
                (k, v.shape) for k, v in kw.items()))
            logits, req_cache = self._prefill_for(key)(
                self.qp, jnp.asarray(padded), jnp.int32(true_len), kw)
            self.pool = self._insert_fn(self.pool, jnp.int32(slot),
                                        req_cache)
            first = int(jnp.argmax(logits[0]))
            res = RequestResult(rid=req.rid, prompt_len=plen,
                                tokens=[first], t_arrival=req.arrival,
                                t_admit=t_admit, t_first=self.clock())
            lane = _Lane(result=res, remaining=req.max_new_tokens - 1,
                         eos_id=req.eos_id)
            self.lanes[slot] = lane
            self._next_tok[slot, 0] = first
            if (req.eos_id is not None and first == req.eos_id) \
                    or lane.remaining <= 0:
                self._finish(slot, "eos" if req.eos_id is not None
                             and first == req.eos_id else "length")
            n += 1
        return n

    # ---------------- decode / evict ----------------

    def step(self) -> list[RequestResult]:
        """One decode step over every active lane; returns completions."""
        if self.n_active == 0:
            return []
        logits, self.pool = self._step_fn(
            self.qp, self.pool, jnp.asarray(self._next_tok))
        toks = np.asarray(jnp.argmax(logits, -1)).astype(np.int32)
        done = []
        for slot, lane in enumerate(self.lanes):
            if lane is None:
                continue
            tok = int(toks[slot])
            lane.result.tokens.append(tok)
            lane.remaining -= 1
            self._next_tok[slot, 0] = tok
            if lane.eos_id is not None and tok == lane.eos_id:
                done.append(self._finish(slot, "eos"))
            elif lane.remaining <= 0:
                done.append(self._finish(slot, "length"))
        return done

    def _finish(self, slot: int, reason: str) -> RequestResult:
        lane = self.lanes[slot]
        lane.result.t_done = self.clock()
        lane.result.finish_reason = reason
        self.pool = self._evict_fn(self.pool, jnp.int32(slot))
        self.lanes[slot] = None
        self._free.append(slot)
        self._next_tok[slot, 0] = 0
        self.results.append(lane.result)
        return lane.result

    def run_to_completion(self) -> list[RequestResult]:
        """Drain queue + lanes (all requests already submitted)."""
        while self.has_work():
            self.admit()
            self.step()
        return self.results


# jitted lockstep entry points, cached per (cfg, use_lop, max_len) so the
# N-request verify replay compiles each shape once, not once per request
_LOCKSTEP_FNS: dict = {}


def _lockstep_fns(cfg, use_lop: bool, max_len: int):
    key = (cfg, use_lop, max_len)
    fns = _LOCKSTEP_FNS.get(key)
    if fns is None:
        fns = (jax.jit(lambda qp, t, kw: prefill(
                   cfg, qp, t, max_len=max_len, use_lop=use_lop, **kw)),
               jax.jit(lambda qp, c, t: serve_step(cfg, qp, c, t,
                                                   use_lop=use_lop),
                       donate_argnums=(1,)))
        _LOCKSTEP_FNS[key] = fns
    return fns


def lockstep_generate(cfg, qp, prompt, max_new_tokens: int, *,
                      max_len: int, use_lop: bool = True,
                      eos_id: int | None = None, frames=None,
                      patches=None) -> list[int]:
    """Single-request lockstep reference path: prefill + greedy decode.

    ``max_len`` must match the pool's (same cache capacity → same LOP
    block top-K budget) for token-exact agreement with the scheduler.
    """
    prefill_fn, step = _lockstep_fns(cfg, use_lop, max_len)
    kw = {}
    if frames is not None:
        kw["frames"] = jnp.asarray(frames)[None]
    if patches is not None:
        kw["patches"] = jnp.asarray(patches)[None]
    logits, cache = prefill_fn(qp, jnp.asarray(prompt)[None], kw)
    toks = [int(jnp.argmax(logits[0]))]
    while len(toks) < max_new_tokens and (eos_id is None
                                          or toks[-1] != eos_id):
        logits, cache = step(qp, cache,
                             jnp.asarray([[toks[-1]]], jnp.int32))
        toks.append(int(jnp.argmax(logits[0])))
    return toks
