"""Continuous-batching scheduler over the typed engine protocol.

The serving layer's control plane: a FIFO request queue feeding ``n_slots``
persistent decode lanes. The scheduler speaks ONLY the
:class:`repro.serving.api.InferenceEngine` protocol — every
family-specific behaviour (chunked vs run-to-completion prefill, exact
vs pow2-bucketed compile lengths, image prefixes) is a *capability the
engine declares*, not a name the scheduler checks (DESIGN.md
§Serving-API). The lifecycle per request is

    admit → prefill → insert → decode → evict

  admit    — a queued request is taken once a lane is free; the other lanes
             keep decoding in the meantime. Cancelled requests are dropped
             before they ever touch a lane. With the prefix cache on
             (chunked engines, default), the prompt is matched against
             the :class:`repro.serving.cache.PrefixStore` hash chain:
             hits clone the cached pages into their reserved lanes —
             grouped per prefix node, ONE ``engine.bulk_insert`` scatter
             per group — and plan chunks for the uncached suffix only,
             so a shared prompt costs one prefill plus per-request
             suffixes (DESIGN.md §Prefix-caching).
  prefill  — two regimes (DESIGN.md §Chunked-prefill), selected by
             ``engine.supports_chunked``:

             *chunked*: the prompt is split into fixed-size token chunks
             (``engine.chunk_tokens``) and ONE chunk is advanced per
             ``step()``, interleaved with the running decode batch.
             Each chunk round-trips ``engine.prefill_chunk`` (extract →
             forward → partial insert, ``active=False``) so the
             in-flight K/V lives in the reserved lane; the final chunk
             activates it and seeds the first token through the sampler.
             A prefix-hit lane starts its chunk grid at the cached block
             boundary (``start = cached_len`` — the same bitwise
             ``(start, kv_len)`` carry every later chunk uses), and a
             finished prompt's block-aligned pages are interned back
             into the store at activation.

             *run-to-completion*: the request runs alone (batch 1)
             through ``engine.prefill``. Engines declaring
             ``exact_length_prefill`` (recurrent state, MoE routers,
             encoder-tied compiles) get exact-length compiles; others
             get pow2 buckets.
  insert   — the batch-1 cache is written into the lane
             (``engine.insert``).
  decode   — ONE ``engine.decode_step`` advances *all* active lanes and
             samples their next tokens in the same dispatch
             (:mod:`repro.serving.sampling`: greedy argmax fast path,
             per-lane temperature/top-k/top-p with lane-local PRNG
             keys). Tokens stream to each request's ``on_token``
             callback as they are emitted.
  evict    — on EOS, a stop-sequence hit, the token budget, or
             cancellation the lane is retired (``engine.evict``) and
             immediately reusable.

Determinism note: lanes are independent through every attention/FFN path
and the sampler's key schedule is lane-local
(:mod:`repro.serving.sampling`), so a request decodes the same tokens
whether it shares the pool, prefills in chunks, or runs alone
(:func:`lockstep_generate`, the batch-1 reference implementation of the
same protocol) — greedy bitwise, sampled same-seed identical; the
equivalence ``tests/test_serving_api.py`` pins down. The exception is
MoE capacity dropping, which ranks tokens across the batch; with a
generous ``capacity_factor`` the paths agree, but bit-exactness is only
guaranteed for dense/vlm/recurrent families.
"""

from __future__ import annotations

import os
import time
from collections import deque
from dataclasses import dataclass, replace

import jax.numpy as jnp
import numpy as np

from repro.serving import metrics as _metrics
from repro.serving.api import (GREEDY, ExistingPrefix, FinishedRequest,
                               GenerateRequest, PooledEngine, SamplingParams,
                               StepResult)
from repro.serving.cache import PrefixStore, pool_capacity
from repro.serving.faults import PrefixLookupError

# Back-compat names — the typed API in repro.serving.api is the source of
# truth; the old scheduler-local dataclasses are these aliases now.
Request = GenerateRequest
RequestResult = FinishedRequest


@dataclass
class _Lane:
    """Host-side state of one occupied decode lane."""
    req: GenerateRequest
    tokens: list                       # emitted tokens, in order
    remaining: int                     # budget left after `tokens`
    t_admit: float
    t_first: float
    token_times: list                  # clock() stamp per emitted token
    cached_len: int = 0                # prompt tokens cloned from the store
    no_spec: bool = False              # drafting disabled (watchdog/fault)
    zero_accept_rounds: int = 0        # consecutive 0-accept spec rounds


@dataclass
class _Prefill:
    """Host-side state of one lane mid-way through chunked prefill."""
    slot: int
    req: GenerateRequest
    chunks: list                       # [1, C_k] int32 token chunks
    starts: list                       # global stream position of chunk k
    seq_ends: list                     # true end written after chunk k
    t_admit: float
    next_chunk: int = 0
    cached_len: int = 0                # prefix tokens the lane resumes past


def pow2_bucket(n: int, *, lo: int = 16, hi: int | None = None) -> int:
    """Smallest power-of-two ≥ n (clamped to [lo, hi]) — the prefill
    compilation bucket of the run-to-completion path. A few buckets cover
    every prompt length, bounding recompiles regardless of traffic mix."""
    b = lo
    while b < n:
        b *= 2
    return min(b, hi) if hi is not None else b


class Scheduler:
    """Continuous-batching front-end over an :class:`InferenceEngine`.

    Drives the admit → prefill → insert → decode → evict lifecycle over a
    slot-paged pool. ``step()`` advances ONE prefill chunk of the oldest
    mid-prefill lane (chunked regime), then every active decode lane one
    sampled token, and returns the requests that completed; ``admit()``
    fills free lanes from the queue. The driver (``launch/serve.py``)
    interleaves the two.

    ``engine`` may be any protocol implementation; by default a
    :class:`repro.serving.api.PooledEngine` is built from ``(cfg, qp)``.
    ``chunked=None`` (default) enables chunked prefill when the engine
    declares ``supports_chunked``; ``False`` forces run-to-completion
    prefill everywhere (the ablation baseline in
    ``benchmarks/prefill_interleave.py``).

    ``prefix_cache=None`` (default) enables prefix caching when chunked
    prefill is on and the engine declares a ``prefix_block``; ``False``
    disables it (the cache-off arm of ``benchmarks/prefix_cache.py``).
    ``prefix_cache_tokens`` bounds the store's interned pages (default
    4× the pool's token capacity — prefix pages trade against slot-pool
    pressure, not unboundedly). Matching is skipped for requests with an
    image prefix (patch embeddings shift every text position, so token
    chains would alias distinct streams).

    Fault tolerance (DESIGN.md §Fault-tolerance): ``max_queue`` bounds
    the admit queue — a submit past the bound is load-shed immediately
    (reason ``"shed"``, reject-newest) instead of growing the queue
    without bound; ``None`` keeps the legacy unbounded FIFO. Requests
    carrying ``deadline_ms`` are retired with reason ``"deadline"`` at
    admit, between prefill chunks and per decode sweep. A lane whose
    decode logits go non-finite is quarantined, rewound bitwise
    (``engine.rollback``) and retried once through the engine's no-LOP
    recovery step — reason ``"fault"`` only if the retry fails too.
    ``spec_watchdog`` disables drafting for a lane after that many
    consecutive zero-accept speculative rounds. With ``REPRO_PARANOID=1``
    in the environment, :meth:`check_invariants` runs after every step.
    """

    def __init__(self, cfg, qp, *, n_slots: int, max_len: int,
                 use_lop: bool = True, bucket_min: int = 16,
                 chunked: bool | None = None, chunk_tokens: int | None = None,
                 prefix_cache: bool | None = None,
                 prefix_cache_tokens: int | None = None,
                 spec_decode: bool = False, gamma: int = 4,
                 draft_layers: int | None = None, draft_k: int | None = None,
                 max_queue: int | None = None, spec_watchdog: int = 3,
                 clock=time.monotonic, engine=None, metrics=None):
        if engine is not None:
            # an injected engine owns its own configuration — reject
            # overrides that would otherwise be silently ignored
            assert chunk_tokens is None, \
                "pass chunk_tokens to the engine, not the Scheduler, " \
                "when injecting one"
            assert draft_layers is None and draft_k is None, \
                "pass draft_layers/draft_k to the engine, not the " \
                "Scheduler, when injecting one"
            use_lop = getattr(engine, "use_lop", use_lop)
        self.engine = engine if engine is not None else PooledEngine(
            cfg, qp, max_len=max_len, use_lop=use_lop,
            chunk_tokens=chunk_tokens, draft_layers=draft_layers,
            draft_k=draft_k)
        self.cfg = getattr(self.engine, "cfg", cfg)
        self.n_slots = n_slots
        self.max_len = max_len
        self.use_lop = use_lop
        self.bucket_min = bucket_min
        self.clock = clock
        self.pool = self.engine.init_pool(n_slots)
        self.capacity = pool_capacity(self.pool)
        # cross-attention lanes have their own (cross_ctx) capacity
        self.cross_capacity = (self.pool["cross"]["k"].shape[3]
                               if "cross" in self.pool else 0)
        self.chunked = ((chunked is None or chunked)
                        and self.engine.supports_chunked)
        self.chunk_tokens = self.engine.chunk_tokens
        # speculative decoding rides the engine's declared capability —
        # an engine without rewindable positional state (or a chunked
        # verify path) silently degrades to plain decode
        if spec_decode:
            assert gamma >= 1, f"spec_decode needs gamma >= 1, got {gamma}"
        self.spec = bool(spec_decode) and getattr(
            self.engine, "supports_speculative", False)
        self.gamma = gamma
        self.prefix_store: PrefixStore | None = None
        if self.chunked and getattr(self.engine, "prefix_block", 0) \
                and (prefix_cache is None or prefix_cache):
            self.prefix_store = PrefixStore(
                self.engine.prefix_block,
                max_tokens=(prefix_cache_tokens
                            if prefix_cache_tokens is not None
                            else 4 * self.capacity))

        self.queue: deque[GenerateRequest] = deque()
        self.lanes: list[_Lane | None] = [None] * n_slots
        self._free: deque[int] = deque(range(n_slots))
        self._prefilling: deque[_Prefill] = deque()
        # pending next-token per lane, fed to the next decode step
        self._next_tok = np.zeros((n_slots, 1), np.int32)
        self.results: list[FinishedRequest] = []
        # interleaving telemetry (benchmarks/prefill_interleave.py):
        # decode steps taken while some prompt was mid-prefill, and
        # whole-prompt prefills that ran while decode lanes sat idle
        self.interleaved_decode_steps = 0
        self.full_prefill_stalls = 0
        # prefix-cache telemetry (benchmarks/prefix_cache.py): hit counts,
        # prompt tokens served from interned pages vs actually computed
        self.prefix_hits = 0
        self.prefix_hit_tokens = 0
        self.prefill_tokens_computed = 0
        self.prefill_tokens_served = 0
        # speculative-decoding telemetry (benchmarks/spec_decode.py):
        # full-model launches = decode_launches + spec_verify_launches;
        # draft_launches are the degraded-cost proposer steps
        self.spec_rounds = 0
        self.spec_drafted = 0          # draft tokens proposed
        self.spec_accepted = 0         # drafts that matched verify
        self.spec_emitted = 0          # tokens emitted by spec rounds
        self.spec_verify_launches = 0
        self.draft_launches = 0
        self.decode_launches = 0       # plain (non-spec) decode steps
        # fault-tolerance knobs + telemetry (DESIGN.md §Fault-tolerance)
        self.max_queue = max_queue
        self.spec_watchdog = spec_watchdog
        self.shed_count = 0            # submits rejected at the bound
        self.queue_depth_peak = 0
        self.deadline_count = 0        # requests retired past deadline
        self.fault_events = 0          # non-finite-logit detections
        self.fault_recoveries = 0      # rollback+retry that succeeded
        self.fault_finishes = 0        # lanes retired with reason "fault"
        self.fault_rids: set = set()   # rids a fault recovery touched
        self.prefix_lookup_failures = 0
        self.spec_watchdog_trips = 0
        self.paranoid = os.environ.get("REPRO_PARANOID") == "1"
        # structured telemetry (DESIGN.md §Serving-frontend): the same
        # events as the plain int attributes above, published onto a
        # metrics registry so the synthetic driver and the HTTP server
        # export identical series; per-request StageTimers record the
        # queue → prefill → decode spans under the scheduler's clock
        self.metrics = metrics if metrics is not None else _metrics.REGISTRY
        self._m = _metrics.scheduler_instruments(self.metrics)
        self._timers: dict = {}

    @property
    def prefill_compiles(self) -> int:
        return self.engine.prefill_compiles

    # ---------------- queue ----------------

    def submit(self, req: GenerateRequest) -> bool:
        # attention-free pools (capacity 0: recurrent state only) have no
        # token-capacity bound — only the prompt buffer limits them
        need = (len(req.prompt) + req.max_new_tokens
                + self.engine.prefix_len(req))
        assert not self.capacity or need <= self.capacity, (
            f"request {req.rid} needs {need} tokens but pool capacity is "
            f"{self.capacity}")
        if self.spec and self.capacity:
            # speculative rounds transiently write up to γ+1 rows past a
            # lane's committed length; `_spec_gamma` shrinks γ toward the
            # capacity boundary and a lane whose last row is the final
            # capacity position falls back to plain decode — that
            # fallback needs the lane's LAST committed write (position
            # need−1) in bounds, which is the bound above. Assert the
            # clamp's own precondition at admit so an off-by-γ overflow
            # fails loudly here, not as cache corruption mid-round.
            gam = req.sampling.gamma if req.sampling else 0
            assert gam >= 0, (
                f"request {req.rid}: sampling.gamma must be >= 0 "
                f"(0 = scheduler default), got {gam}")
        assert req.frames is None or len(req.frames) <= \
            self.cross_capacity, (
            f"request {req.rid} has {len(req.frames)} encoder frames but "
            f"the pool's cross capacity is {self.cross_capacity}")
        if req.arrival is None:
            req = replace(req, arrival=self.clock())
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            # load shedding, reject-newest: overload answers immediately
            # with reason "shed" instead of queueing unboundedly — the
            # queued requests keep their admission order and their
            # deadlines stay meetable
            self.shed_count += 1
            self._m.shed.inc()
            self._record_abort(req, reason="shed")
            return False
        timer = _metrics.StageTimer(self.clock)
        timer.enter("queue")
        self._timers[req.rid] = timer
        self.queue.append(req)
        self.queue_depth_peak = max(self.queue_depth_peak, len(self.queue))
        self._m.queue_depth.set(len(self.queue))
        return True

    @property
    def n_active(self) -> int:
        return sum(l is not None for l in self.lanes)

    @property
    def n_prefilling(self) -> int:
        return len(self._prefilling)

    def has_work(self) -> bool:
        return bool(self.queue) or bool(self._prefilling) \
            or self.n_active > 0

    # ---------------- admit / prefill / insert ----------------

    def _bucket(self, prompt_len: int) -> int:
        if self.engine.exact_length_prefill:
            return prompt_len
        return pow2_bucket(prompt_len, lo=self.bucket_min,
                           hi=self.max_len)

    def _plan_chunks(self, req: GenerateRequest, skip: int = 0):
        """Host-side chunk grid of one prompt (fixed C-token shapes).

        The final chunk is right-padded to the same C so every chunk of
        every prompt hits ONE compiled shape; ``seq_end`` keeps the pad
        out of ``lengths`` and the causal mask keeps it out of every real
        query row. Only when the padded end would spill past the pool
        capacity (a near-capacity prompt) does the tail fall back to its
        exact length.

        ``skip`` (a prefix-cache hit: a block-aligned count of prompt
        tokens already in the lane) plans chunks for the suffix
        ``[skip, plen)`` only — the first chunk starts at the cached
        boundary, the same traced ``(start, kv_len)`` carry every
        non-first chunk already uses, so the compiled chunk shape is
        unchanged.
        """
        plen = len(req.prompt)
        prefix = self.engine.prefix_len(req)
        c = self.chunk_tokens
        n = max(1, -(-(plen - skip) // c))
        chunks, starts, seq_ends = [], [], []
        for k in range(n):
            lo, hi = skip + k * c, min(plen, skip + k * c + c)
            width = c
            if self.capacity and prefix + lo + c > self.capacity:
                width = hi - lo                 # near-capacity exact tail
            buf = np.zeros((1, width), np.int32)
            buf[0, :hi - lo] = req.prompt[lo:hi]
            chunks.append(buf)
            starts.append(prefix + lo if (k or skip) else 0)
            seq_ends.append(prefix + hi)
        return chunks, starts, seq_ends

    def admit(self) -> int:
        """Admit queued requests into free lanes. Returns #admitted.

        Chunked regime: the lane is *reserved* and the prompt's chunk grid
        queued — no forward pass runs here; ``step()`` advances one chunk
        per cycle. Prompts matching the prefix store plan their uncached
        suffix only; the matched pages are cloned after the admit sweep,
        grouped per prefix node so N hits on one prefix cost ONE
        ``bulk_insert`` scatter. Run-to-completion regime: the whole
        prompt prefills synchronously (stalling any active decode lanes —
        counted in ``full_prefill_stalls``) and the lane activates
        immediately. Cancelled queue entries retire without touching a
        lane.
        """
        n = 0
        clones: dict = {}          # prefix node key -> (node, [slots])
        while self.queue and self._free:
            req = self.queue.popleft()
            reason = self._abort_reason(req)
            if reason:
                # deadline enforcement point 1 of 3: at admit — a request
                # that expired queued never takes a lane from a live one
                if reason == "deadline":
                    self.deadline_count += 1
                self._record_abort(req, reason=reason)
                continue
            slot = self._free.popleft()
            plen = len(req.prompt)
            timer = self._timers.get(req.rid)
            if timer is not None:
                timer.to("prefill")
            if self.chunked:
                skip, node = 0, None
                if self.prefix_store is not None \
                        and not self.engine.prefix_len(req):
                    try:
                        skip, node = self.prefix_store.match(req.prompt)
                    except PrefixLookupError:
                        # store outage: degrade to a cold prefill — the
                        # request costs more, it does not fail
                        self.prefix_lookup_failures += 1
                        skip, node = 0, None
                chunks, starts, seq_ends = self._plan_chunks(req, skip=skip)
                self._prefilling.append(_Prefill(
                    slot=slot, req=req, chunks=chunks, starts=starts,
                    seq_ends=seq_ends, t_admit=self.clock(),
                    cached_len=skip))
                if node is not None:
                    clones.setdefault(node.key, (node, []))[1].append(slot)
                    self.prefix_hits += 1
                    self.prefix_hit_tokens += skip
                self.prefill_tokens_computed += plen - skip
                self.prefill_tokens_served += plen
                self._m.prefill_tokens.labels(source="computed") \
                    .inc(plen - skip)
                self._m.prefill_tokens.labels(source="cached").inc(skip)
                n += 1
                continue
            if self.n_active:
                self.full_prefill_stalls += 1
            bucket = max(self._bucket(plen), plen)
            t_admit = self.clock()
            padded = np.zeros((1, bucket), np.int32)
            padded[0, :plen] = req.prompt
            kw = {}
            true_len = plen + self.engine.prefix_len(req)
            if req.frames is not None:
                kw["frames"] = jnp.asarray(req.frames)[None]
            if self.engine.prefix_len(req):
                kw["patches"] = jnp.asarray(req.patches)[None]
            self.prefill_tokens_computed += plen
            self.prefill_tokens_served += plen
            self._m.prefill_tokens.labels(source="computed").inc(plen)
            logits, req_cache = self.engine.prefill(padded, true_len, kw)
            self.pool = self.engine.insert(self.pool, slot, req_cache)
            self._start_lane(slot, req, logits, t_admit)
            n += 1
        for node, slots in clones.values():
            prefix = ExistingPrefix(cache=self.prefix_store.assemble(node),
                                    common_len=node.n_tokens)
            self.pool = self.engine.bulk_insert(
                self.pool, np.asarray(slots, np.int32), prefix)
        self._m.queue_depth.set(len(self.queue))
        return n

    def _start_lane(self, slot: int, req: GenerateRequest, logits,
                    t_admit: float, done: list | None = None,
                    cached_len: int = 0) -> None:
        """Prefill finished: seed the lane with the prompt's sampled first
        token (index 0 of the request's key schedule) and write the lane's
        PRNG state (seed, next step index) into the pool."""
        sp = req.sampling or GREEDY
        first = self.engine.sample_first(logits, sp)
        self.pool = self.engine.set_sampling_state(self.pool, slot,
                                                   sp.seed, 1)
        now = self.clock()
        timer = self._timers.get(req.rid)
        if timer is not None:
            timer.to("decode")
        if req.arrival is not None:
            self._m.ttft.observe(now - req.arrival)
        lane = _Lane(req=req, tokens=[first],
                     remaining=req.max_new_tokens - 1,
                     t_admit=t_admit, t_first=now, token_times=[now],
                     cached_len=cached_len)
        self.lanes[slot] = lane
        self._m.active_lanes.set(self.n_active)
        self._next_tok[slot, 0] = first
        reason = self._token_reason(lane, first)   # evaluated exactly once
        self._emit(lane, first, 0, reason)
        if reason is not None:
            result = self._finish(slot, reason)
            if done is not None:
                done.append(result)

    def _step_prefill(self, done: list) -> bool:
        """Advance ONE chunk of the oldest mid-prefill lane."""
        if not self._prefilling:
            return False
        pf = self._prefilling[0]
        k = pf.next_chunk
        final = k == len(pf.chunks) - 1
        kw = {}
        if k == 0 and self.engine.prefix_len(pf.req):
            kw["patches"] = jnp.asarray(pf.req.patches)[None]
        t0 = self.clock()
        logits, self.pool = self.engine.prefill_chunk(
            self.pool, pf.slot, pf.chunks[k], pf.starts[k], pf.seq_ends[k],
            final, kw)
        self._m.prefill_chunk.observe(self.clock() - t0)
        pf.next_chunk += 1
        if final:
            self._prefilling.popleft()
            self._intern_prefix(pf)
            self._start_lane(pf.slot, pf.req, logits, pf.t_admit, done,
                             cached_len=pf.cached_len)
        return True

    def _intern_prefix(self, pf: _Prefill) -> None:
        """Intern a finished prompt's block-aligned pages into the store.

        Runs at activation, when the lane holds the whole prompt's K/V +
        LOP features. Chunk boundaries are bitwise-reproducible (the
        ``(start, kv_len)`` carry contract), so pages recomputed by a
        later miss are identical to the ones interned here — reuse is
        token-exact by construction. The ``missing`` pre-check keeps the
        common already-interned case free of a pool extract.
        """
        store = self.prefix_store
        if store is None or self.engine.prefix_len(pf.req):
            return
        n = (len(pf.req.prompt) // store.block) * store.block
        if not n or not store.missing(pf.req.prompt[:n]):
            return
        lane = self.engine.extract(self.pool, pf.slot)
        store.insert(pf.req.prompt[:n], lane)

    # ---------------- decode / evict ----------------

    def _token_reason(self, lane: _Lane, tok: int) -> str | None:
        """Finish reason after appending ``tok``, or None to continue.
        Precedence: eos > stop sequence > token budget."""
        req = lane.req
        if req.eos_id is not None and tok == req.eos_id:
            return "eos"
        for seq in req.stop:
            if len(seq) <= len(lane.tokens) \
                    and tuple(lane.tokens[-len(seq):]) == seq:
                return "stop"
        if lane.remaining <= 0:
            return "length"
        return None

    def _emit(self, lane: _Lane, tok: int, index: int,
              reason: str | None) -> None:
        """Stream one token to the request's ``on_token`` callback."""
        cb = lane.req.on_token
        if cb is not None:
            cb(StepResult(rid=lane.req.rid, token=tok, index=index,
                          finished=reason is not None,
                          finish_reason=reason or ""))

    def _expired(self, req: GenerateRequest) -> bool:
        """Whether ``req``'s latency budget (``deadline_ms``, measured
        from arrival) has run out."""
        if req.deadline_ms is None or req.arrival is None:
            return False
        return (self.clock() - req.arrival) * 1e3 > req.deadline_ms

    def _abort_reason(self, req: GenerateRequest) -> str | None:
        """Terminal reason forcing ``req`` out mid-flight, or None.
        Cancellation wins over deadline (the caller already gave up)."""
        if req.cancelled:
            return "cancelled"
        if self._expired(req):
            return "deadline"
        return None

    def _sweep_terminal(self, done: list) -> None:
        """Retire cancelled and deadline-expired requests wherever they
        are in the lifecycle: queued (never admitted), mid-prefill (lane
        released between chunks; its partial K/V goes stale like any
        evicted lane's), or decoding. Runs at the top of every serve
        cycle, which is what enforces deadlines between prefill chunks
        and per decode sweep."""
        if self.queue and any(self._abort_reason(r) for r in self.queue):
            kept: deque[GenerateRequest] = deque()
            for req in self.queue:
                reason = self._abort_reason(req)
                if reason:
                    if reason == "deadline":
                        self.deadline_count += 1
                    done.append(self._record_abort(req, reason=reason))
                else:
                    kept.append(req)
            self.queue = kept
            self._m.queue_depth.set(len(self.queue))
        if self._prefilling and any(self._abort_reason(p.req)
                                    for p in self._prefilling):
            kept_p: deque[_Prefill] = deque()
            for pf in self._prefilling:
                reason = self._abort_reason(pf.req)
                if reason:
                    if reason == "deadline":
                        self.deadline_count += 1
                    done.append(self._record_abort(pf.req,
                                                   t_admit=pf.t_admit,
                                                   reason=reason))
                    self._free.append(pf.slot)
                else:
                    kept_p.append(pf)
            self._prefilling = kept_p
        for slot, lane in enumerate(self.lanes):
            if lane is not None:
                reason = self._abort_reason(lane.req)
                if reason:
                    if reason == "deadline":
                        self.deadline_count += 1
                    done.append(self._finish(slot, reason))

    def _lane_kv_len(self, slot: int) -> int:
        """Committed cache length of lane ``slot``: positions [0, L) hold
        written K/V; the pending ``_next_tok`` will occupy position L."""
        lane = self.lanes[slot]
        return (self.engine.prefix_len(lane.req) + len(lane.req.prompt)
                + len(lane.tokens) - 1)

    def _spec_gamma(self) -> int:
        """This round's draft length: the min over active lanes of the
        per-request γ (``sampling.gamma``, 0 = scheduler default), the
        lane's remaining token budget, and its capacity headroom — the
        verify chunk writes γ+1 rows at [L, L+γ+1), so γ shrinks at the
        slot boundary (never past ``max_len``). Returns 0 when any lane
        can't speculate, falling the whole cycle back to plain decode."""
        g = None
        for slot, lane in enumerate(self.lanes):
            if lane is None:
                continue
            if lane.no_spec:
                # a faulted or watchdog-tripped lane never drafts again;
                # the whole cycle degrades to plain decode (the batched
                # draft/verify launches can't exclude one lane)
                return 0
            sp = lane.req.sampling or GREEDY
            lane_g = sp.gamma if sp.gamma > 0 else self.gamma
            lane_g = min(lane_g, lane.remaining)
            if self.capacity:
                lane_g = min(lane_g,
                             self.capacity - 1 - self._lane_kv_len(slot))
            g = lane_g if g is None else min(g, lane_g)
        return max(0, g or 0)

    def _spec_round(self, g: int, temps, tks, tps,
                    done: list) -> None:
        """One speculative cycle: γ batched draft steps propose tokens for
        every active lane, then ONE chunk-shaped verify launch per lane
        scores all γ+1 positions exactly; the agreeing prefix plus the
        verifier's bonus token are emitted and the rejected tail is
        rewound (DESIGN.md §Speculative-decoding).

        Greedy lanes emit exactly the plain-decode stream (verify logits
        are bitwise the decode logits through the chunk-carry contract);
        sampled lanes draw draft i and its verify row with the SAME
        lane-local key (emission-indexed PRNG schedule), so the emitted
        stream equals the non-speculative same-seed stream. Finish
        reasons (eos > stop > length) are evaluated per emitted token —
        a hit inside the accepted window evicts the lane mid-round and
        the tokens past it are dropped, exactly as plain decode would
        never have generated them.
        """
        self.spec_rounds += 1
        active = [s for s, l in enumerate(self.lanes) if l is not None]
        base_e = {s: len(self.lanes[s].tokens) for s in active}
        base_len = {s: self._lane_kv_len(s) for s in active}
        drafts: dict[int, list[int]] = {s: [] for s in active}
        cur = self._next_tok.copy()
        for _ in range(g):
            t0 = self.clock()
            toks, self.pool = self.engine.draft(self.pool, cur, temps,
                                                tks, tps)
            self._m.spec_draft.observe(self.clock() - t0)
            self.draft_launches += 1
            for s in active:
                d = int(toks[s])
                drafts[s].append(d)
                cur[s, 0] = d
        self.spec_drafted += g * len(active)

        for slot in active:
            lane = self.lanes[slot]
            start = base_len[slot]
            # the γ-clamp's guarantee, restated where a violation would
            # corrupt the lane: the verify writes rows [start, start+g+1)
            assert start + g + 1 <= self.capacity, (
                f"speculative verify would write past capacity "
                f"({start}+{g}+1 > {self.capacity})")
            block = np.concatenate(
                [self._next_tok[slot], np.asarray(drafts[slot], np.int32)]
            )[None, :]
            t0 = self.clock()
            logits, self.pool = self.engine.verify_chunk(
                self.pool, slot, block, start)
            self._m.spec_verify.observe(self.clock() - t0)
            self.spec_verify_launches += 1
            if not bool(np.isfinite(np.asarray(logits)).all()):
                # poisoned verify logits: rewind the whole round for this
                # lane (g drafts + 1 verify append) and retire it from
                # speculation — next cycle's plain decode recomputes the
                # token through the NaN-guard/retry path
                self.pool = self.engine.rollback(self.pool, slot, g + 1)
                lane.no_spec = True
                self.fault_events += 1
                self._m.fault_events.inc()
                self.fault_rids.add(lane.req.rid)
                continue
            sp = lane.req.sampling or GREEDY
            targets = self.engine.sample_block(logits, sp, base_e[slot])
            j = 0
            while j < g and drafts[slot][j] == int(targets[j]):
                j += 1
            self.spec_accepted += j
            if j == 0:
                # drafting watchdog: a lane whose drafts are never
                # accepted is burning draft launches for nothing —
                # after ``spec_watchdog`` consecutive zero-accept rounds
                # it falls back to plain decode for good
                lane.zero_accept_rounds += 1
                if lane.zero_accept_rounds >= self.spec_watchdog:
                    lane.no_spec = True
                    self.spec_watchdog_trips += 1
            else:
                lane.zero_accept_rounds = 0
            finished = False
            for tok in (int(t) for t in targets[:j + 1]):
                abort = self._abort_reason(lane.req)
                if abort is not None:
                    # cancel/deadline fired mid-round: keep the tokens
                    # already committed this round, rewind the rest of
                    # the verify window, and retire the lane now
                    emitted = len(lane.tokens) - base_e[slot]
                    self.pool = self.engine.rollback(
                        self.pool, slot, g + 1 - emitted)
                    if abort == "deadline":
                        self.deadline_count += 1
                    done.append(self._finish(slot, abort))
                    finished = True
                    break
                idx = len(lane.tokens)
                lane.tokens.append(tok)
                lane.token_times.append(self.clock())
                lane.remaining -= 1
                self._next_tok[slot, 0] = tok
                self.spec_emitted += 1
                reason = self._token_reason(lane, tok)
                self._emit(lane, tok, idx, reason)
                if reason is not None:
                    done.append(self._finish(slot, reason))
                    finished = True
                    break
            if not finished and j < g:
                # rewind the rejected tail: lengths start+g+1 → start+j+1
                # (a finished lane was evicted — nothing to rewind)
                self.pool = self.engine.rollback(self.pool, slot, g - j)

    def _append_token(self, slot: int, tok: int, done: list) -> None:
        """Commit one emitted token to lane ``slot``: record it, stream
        it, and retire the lane if it hit a finish reason."""
        lane = self.lanes[slot]
        idx = len(lane.tokens)
        lane.tokens.append(tok)
        lane.token_times.append(self.clock())
        lane.remaining -= 1
        self._next_tok[slot, 0] = tok
        reason = self._token_reason(lane, tok)
        self._emit(lane, tok, idx, reason)
        if reason is not None:
            done.append(self._finish(slot, reason))

    def _recover_lane(self, slot: int, temps, tks, tps,
                      done: list) -> None:
        """Non-finite logits on lane ``slot`` this decode step: the
        recovery contract (DESIGN.md §Fault-tolerance). The poisoned
        append is rewound bitwise (``engine.rollback`` — K/V, scales,
        LOP features, PRNG step), drafting is permanently disabled for
        the lane, and the token is recomputed once through the engine's
        single-lane no-LOP retry. Only if the retry's logits are ALSO
        non-finite does the lane give up with reason ``"fault"`` (its
        tokens so far are delivered)."""
        lane = self.lanes[slot]
        self.fault_events += 1
        self._m.fault_events.inc()
        self.fault_rids.add(lane.req.rid)
        lane.no_spec = True
        self.pool = self.engine.rollback(self.pool, slot, 1)
        toks, ok, self.pool = self.engine.retry_step(
            self.pool, slot, self._next_tok, temps, tks, tps)
        if not bool(ok[slot]):
            self.pool = self.engine.rollback(self.pool, slot, 1)
            self.fault_finishes += 1
            self._m.fault_finishes.inc()
            done.append(self._finish(slot, "fault"))
            return
        self.fault_recoveries += 1
        self._m.fault_recoveries.inc()
        self._append_token(slot, int(toks[slot]), done)

    def step(self) -> list[FinishedRequest]:
        """One serve cycle: terminal sweep (cancellations + deadlines) +
        ≤1 prefill chunk + one sampled decode step over every active lane
        (or, in speculative mode, one draft-γ/verify round); returns
        completions. Under ``REPRO_PARANOID=1`` the invariant checker
        runs after every cycle."""
        done = self._step_inner()
        if self.paranoid:
            self.check_invariants()
        return done

    def _step_inner(self) -> list[FinishedRequest]:
        done: list[FinishedRequest] = []
        self._sweep_terminal(done)
        prefilling = self._step_prefill(done)
        if self.n_active == 0:
            return done
        if prefilling or self._prefilling:
            self.interleaved_decode_steps += 1
        temps = np.zeros(self.n_slots, np.float32)
        tks = np.zeros(self.n_slots, np.int32)
        tps = np.ones(self.n_slots, np.float32)
        for slot, lane in enumerate(self.lanes):
            if lane is None:
                continue
            sp = lane.req.sampling or GREEDY
            temps[slot] = sp.temperature
            tks[slot] = sp.top_k
            tps[slot] = sp.top_p
        if self.spec:
            g = self._spec_gamma()
            if g >= 1:
                self._spec_round(g, temps, tks, tps, done)
                return done
        t0 = self.clock()
        toks, self.pool = self.engine.decode_step(
            self.pool, self._next_tok, temps, tks, tps)
        self._m.decode_step.observe(self.clock() - t0)
        self.decode_launches += 1
        # per-lane logit-finiteness guard published by the engine (None:
        # an engine without the guard — every lane treated healthy)
        ok = getattr(self.engine, "last_ok", None)
        for slot, lane in enumerate(self.lanes):
            if lane is None:
                continue
            if ok is not None and not bool(ok[slot]):
                self._recover_lane(slot, temps, tks, tps, done)
                continue
            self._append_token(slot, int(toks[slot]), done)
        return done

    def _finish(self, slot: int, reason: str) -> FinishedRequest:
        lane = self.lanes[slot]
        res = FinishedRequest(
            rid=lane.req.rid, prompt_len=len(lane.req.prompt),
            tokens=lane.tokens, finish_reason=reason,
            t_arrival=lane.req.arrival, t_admit=lane.t_admit,
            t_first=lane.t_first, t_done=self.clock(),
            token_times=lane.token_times, cached_len=lane.cached_len)
        self.pool = self.engine.evict(self.pool, slot)
        self.lanes[slot] = None
        self._free.append(slot)
        self._next_tok[slot, 0] = 0
        self.results.append(res)
        self._m.active_lanes.set(self.n_active)
        self._publish_finish(res, reason)
        return res

    def _record_abort(self, req: GenerateRequest, t_admit: float = 0.0,
                      reason: str = "cancelled") -> FinishedRequest:
        """A request retired before emitting any token (cancelled,
        deadline-expired, or load-shed)."""
        now = self.clock()
        res = FinishedRequest(
            rid=req.rid, prompt_len=len(req.prompt), tokens=[],
            finish_reason=reason,
            t_arrival=req.arrival if req.arrival is not None else now,
            t_admit=t_admit or now, t_first=now, t_done=now,
            token_times=[])
        self.results.append(res)
        self._publish_finish(res, reason)
        return res

    def _publish_finish(self, res: FinishedRequest, reason: str) -> None:
        """Registry side of retirement: finish-reason counters, the
        request's stage spans, and its latency observations — the same
        numbers the int attributes / FinishedRequest fields carry, as
        exported series (DESIGN.md §Serving-frontend)."""
        self._m.requests.labels(outcome=reason).inc()
        if reason == "deadline":
            self._m.deadline.inc()
        self._m.tokens.inc(len(res.tokens))
        self._m.e2e.observe(res.latency)
        for gap in res.itl:
            self._m.itl.observe(gap)
        timer = self._timers.pop(res.rid, None)
        if timer is not None:
            for stage, span in timer.finish().items():
                self._m.stage_seconds.labels(stage=stage).observe(span)

    # ---------------- invariants (REPRO_PARANOID=1) ----------------

    def check_invariants(self) -> None:
        """Cross-check host bookkeeping against device state — the
        contracts every fault-recovery path must preserve (DESIGN.md
        §Fault-tolerance). Runs after every ``step()`` under
        ``REPRO_PARANOID=1``; cheap enough for CI chaos runs (a few
        scalar pulls per cycle, no page reads).

        - slot partition: every slot is exactly one of occupied (a live
          lane), reserved (mid-chunked-prefill) or free
        - the pool's ``active`` mask equals the occupied set (reserved
          lanes stay inactive until their final chunk)
        - per-lane ``lengths`` stay within pool capacity, and an occupied
          lane's device length equals its host-side committed length
          (prefix + prompt + emissions − 1 pending)
        - PRNG-step monotonicity: a sampled lane's ``sample_step`` is
          non-negative and equals its emission count, so rollback/retry
          cycles net to exactly the tokens delivered (greedy lanes never
          read their counter and are exempt)
        - the prefix store's own structural invariants hold
        """
        occupied = {s for s, l in enumerate(self.lanes) if l is not None}
        reserved = {pf.slot for pf in self._prefilling}
        free = set(self._free)
        assert occupied.isdisjoint(reserved) and occupied.isdisjoint(free) \
            and reserved.isdisjoint(free), (
            f"slot sets overlap: occupied={occupied} reserved={reserved} "
            f"free={free}")
        assert len(free) == len(self._free), "duplicate slots in free list"
        assert occupied | reserved | free == set(range(self.n_slots)), (
            f"slot partition incomplete: occupied={occupied} "
            f"reserved={reserved} free={free} n_slots={self.n_slots}")
        if "active" in self.pool:
            dev_active = {int(s) for s in
                          np.flatnonzero(np.asarray(self.pool["active"]))}
            assert dev_active == occupied, (
                f"pool active mask {dev_active} != occupied lanes "
                f"{occupied}")
        lengths = np.asarray(self.pool["lengths"])
        if self.capacity:
            assert int(lengths.max(initial=0)) <= self.capacity, (
                f"lane length {int(lengths.max())} exceeds pool capacity "
                f"{self.capacity}")
            for slot in occupied:
                want = self._lane_kv_len(slot)
                assert int(lengths[slot]) == want, (
                    f"slot {slot}: device length {int(lengths[slot])} != "
                    f"host committed length {want}")
        if "sample_step" in self.pool:
            steps = np.asarray(self.pool["sample_step"])
            assert int(steps.min(initial=0)) >= 0, (
                f"negative sample_step: {steps}")
            for slot in occupied:
                lane = self.lanes[slot]
                sp = lane.req.sampling
                if sp is None or sp.temperature <= 0.0:
                    continue        # greedy lanes never read the counter
                assert int(steps[slot]) == len(lane.tokens), (
                    f"slot {slot}: sample_step {int(steps[slot])} != "
                    f"emissions {len(lane.tokens)} — a rollback/retry "
                    f"desynced the PRNG schedule")
        if self.prefix_store is not None:
            self.prefix_store.check_invariants()

    def run_to_completion(self) -> list[FinishedRequest]:
        """Drain queue + lanes (all requests already submitted)."""
        while self.has_work():
            self.admit()
            self.step()
        return self.results


# ---------------------------------------------------------------------------
# Lockstep reference path — the batch-1 implementation of the same protocol
# ---------------------------------------------------------------------------

# engines cached per (cfg, use_lop, max_len) so the N-request verify replay
# compiles each shape once, not once per request; the jitted closures take
# qp as an argument, so the cached engine is re-pointed per call
_REF_ENGINES: dict = {}


def _ref_engine(cfg, qp, use_lop: bool, max_len: int) -> PooledEngine:
    key = (cfg, use_lop, max_len)
    eng = _REF_ENGINES.get(key)
    if eng is None:
        eng = PooledEngine(cfg, qp, max_len=max_len, use_lop=use_lop)
        _REF_ENGINES[key] = eng
    eng.qp = qp
    return eng


def lockstep_generate(cfg, qp, prompt, max_new_tokens: int, *,
                      max_len: int, use_lop: bool = True,
                      eos_id: int | None = None, frames=None,
                      patches=None, sampling: SamplingParams | None = None,
                      stop=(), on_token=None, cancel=None,
                      engine=None) -> list:
    """Single-request reference path: whole-prompt prefill + decode,
    driven through the SAME :class:`InferenceEngine` protocol and the
    same sampler as the pooled scheduler — per
    :class:`SamplingParams`, greedy requests reproduce the pool
    bitwise and seeded requests draw from identical lane-local keys.

    ``max_len`` must match the pool's (same cache capacity → same LOP
    block top-K budget AND the same prefill-attention operand shapes the
    chunked path sees) for token-exact agreement with the scheduler.
    """
    eng = engine if engine is not None else _ref_engine(cfg, qp, use_lop,
                                                        max_len)
    sp = sampling or GREEDY
    req = GenerateRequest(rid=-1, prompt=np.asarray(prompt),
                          max_new_tokens=max_new_tokens, eos_id=eos_id,
                          sampling=sp, stop=stop, on_token=on_token,
                          cancel=cancel, frames=frames, patches=patches)
    kw = {}
    true_len = len(req.prompt) + eng.prefix_len(req)
    if frames is not None:
        kw["frames"] = jnp.asarray(frames)[None]
    if eng.prefix_len(req):
        kw["patches"] = jnp.asarray(patches)[None]
    logits, cache = eng.prefill(np.asarray(prompt)[None], true_len, kw)
    # the batch-1 cache carries the same PRNG leaves the pool does: seed +
    # next step index (1 — index 0 is the prefill's sample_first draw)
    cache = dict(cache)
    cache["seed"] = jnp.full((1,), sp.seed, jnp.int32)
    cache["sample_step"] = jnp.ones((1,), jnp.int32)
    toks: list = []

    def append(tok: int) -> str | None:
        toks.append(tok)
        if req.eos_id is not None and tok == req.eos_id:
            reason = "eos"
        elif any(len(s) <= len(toks) and tuple(toks[-len(s):]) == s
                 for s in req.stop):
            reason = "stop"
        elif len(toks) >= max_new_tokens:
            reason = "length"
        else:
            reason = None
        if on_token is not None:
            on_token(StepResult(rid=req.rid, token=tok, index=len(toks) - 1,
                                finished=reason is not None,
                                finish_reason=reason or ""))
        return reason

    reason = append(eng.sample_first(logits, sp))
    sp_arrs = (np.asarray([sp.temperature], np.float32),
               np.asarray([sp.top_k], np.int32),
               np.asarray([sp.top_p], np.float32))
    while reason is None and not req.cancelled:
        temps, tks, tps = sp_arrs
        nxt, cache = eng.decode_step(
            cache, np.asarray([[toks[-1]]], np.int32), temps, tks, tps)
        reason = append(int(nxt[0]))
    return toks
