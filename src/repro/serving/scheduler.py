"""Continuous-batching scheduler over the slot-paged cache pool.

The serving layer's control plane: a FIFO request queue feeding ``n_slots``
persistent decode lanes (:func:`repro.serving.cache.init_cache_pool`). The
lifecycle per request is

    admit → prefill → insert → decode → evict

  admit    — a queued request is taken once a lane is free; the other lanes
             keep decoding in the meantime.
  prefill  — two regimes (DESIGN.md §Chunked-prefill):

             *chunked* (dense/vlm, the default): the prompt is split into
             fixed-size token chunks (``chunk_tokens``, default
             ``lop_block``) and ONE chunk is advanced per ``step()``,
             interleaved with the running decode batch — decode lanes
             never stall behind a long prompt, and prefill compiles
             collapse from one-per-pow2-bucket to one fixed chunk shape.
             Each chunk round-trips extract_slot → ``engine.prefill_chunk``
             → partial ``insert_slot`` (``active=False``), so the
             in-flight K/V lives in the reserved lane; the final chunk
             activates it and its argmax becomes the first token.

             *run-to-completion* (moe/hybrid/ssm/encdec): the request
             runs alone (batch 1) through ``engine.prefill``. Recurrent
             families (hybrid/ssm) integrate state over every position,
             encdec ties the compile to its encoder frames, and MoE
             routers rank tokens per forward call — all three use
             exact-length compiles (one per distinct prompt length; for
             MoE this also keeps pad tokens out of the router, which
             would otherwise shift per-group expert capacity).
  insert   — the batch-1 cache is written into the lane with one
             ``dynamic_update_slice`` per leaf (``insert_slot``).
  decode   — one jit'd ``serve_step`` advances *all* active lanes; retired
             lanes are masked out of the LOP screen, block top-K and cache
             writes by the per-slot ``active`` mask; mid-prefill lanes are
             inactive and therefore skipped the same way.
  evict    — on EOS or the request's token budget the lane is retired
             (``evict_slot``) and immediately reusable; stale bytes are
             masked by ``lengths`` so the next occupant is unaffected.

Determinism note: lanes are independent through every attention/FFN path,
and a chunked prefill is bit-identical per query row to the whole-prompt
prefill (both run :func:`repro.kernels.ops.prefill_attention` over the
same capacity-padded cache — DESIGN.md §Chunked-prefill), so a request
decodes the same tokens whether it shares the pool, prefills in chunks,
or runs alone (``lockstep_generate``) — the equivalence the tests pin
down. The exception is MoE capacity dropping, which ranks tokens across
the batch; with a generous ``capacity_factor`` the paths agree, but
bit-exactness is only guaranteed for dense/vlm/recurrent families.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.cache import (evict_slot, extract_slot, init_cache_pool,
                                 insert_slot, pool_capacity)
from repro.serving.engine import prefill, prefill_chunk, serve_step

# Families whose prompts are split into fixed-shape chunks and interleaved
# with decode. moe is excluded: the router ranks tokens per forward call,
# so splitting a prompt regroups its capacity competition (same class of
# caveat as the batch-determinism note above); hybrid/ssm carry recurrent
# state (no chunk-carry without threading it); encdec couples the compile
# to its encoder frames.
CHUNKED_FAMILIES = ("dense", "vlm")


@dataclass
class Request:
    """One generation request entering the queue."""
    rid: int
    prompt: np.ndarray                 # int32 [prompt_len]
    max_new_tokens: int
    eos_id: int | None = None
    arrival: float | None = None       # driver-set; default stamps submit()
    frames: np.ndarray | None = None   # encdec audio frames [S_enc, D]
    patches: np.ndarray | None = None  # vlm patch embeds [n_img, D]


@dataclass
class RequestResult:
    """Completed request: emitted tokens + latency breakdown."""
    rid: int
    prompt_len: int
    tokens: list[int] = field(default_factory=list)
    t_arrival: float = 0.0
    t_admit: float = 0.0               # prefill started (lane granted)
    t_first: float = 0.0               # first token emitted (TTFT end)
    t_done: float = 0.0
    finish_reason: str = ""            # "eos" | "length"

    @property
    def ttft(self) -> float:
        return self.t_first - self.t_arrival

    @property
    def latency(self) -> float:
        return self.t_done - self.t_arrival


@dataclass
class _Lane:
    """Host-side state of one occupied decode lane."""
    result: RequestResult
    remaining: int
    eos_id: int | None


@dataclass
class _Prefill:
    """Host-side state of one lane mid-way through chunked prefill."""
    slot: int
    req: Request
    chunks: list[np.ndarray]           # [1, C_k] int32 token chunks
    starts: list[int]                  # global stream position of chunk k
    seq_ends: list[int]                # true end written after chunk k
    t_admit: float
    next_chunk: int = 0


def pow2_bucket(n: int, *, lo: int = 16, hi: int | None = None) -> int:
    """Smallest power-of-two ≥ n (clamped to [lo, hi]) — the prefill
    compilation bucket of the run-to-completion path. A few buckets cover
    every prompt length, bounding recompiles regardless of traffic mix."""
    b = lo
    while b < n:
        b *= 2
    return min(b, hi) if hi is not None else b


class Scheduler:
    """Continuous-batching engine front-end (greedy decoding).

    Drives the admit → prefill → insert → decode → evict lifecycle over a
    slot-paged pool. ``step()`` advances ONE prefill chunk of the oldest
    mid-prefill lane (chunked regime), then every active decode lane one
    token, and returns the requests that completed; ``admit()`` fills free
    lanes from the queue. The driver (``launch/serve.py``) interleaves the
    two.

    ``chunked=None`` (default) enables chunked prefill for the families in
    :data:`CHUNKED_FAMILIES`; ``False`` forces run-to-completion prefill
    everywhere (the pre-chunking behaviour, kept for the interleaving
    ablation in ``benchmarks/prefill_interleave.py``).
    """

    def __init__(self, cfg, qp, *, n_slots: int, max_len: int,
                 use_lop: bool = True, bucket_min: int = 16,
                 chunked: bool | None = None, chunk_tokens: int | None = None,
                 clock=time.monotonic):
        self.cfg = cfg
        self.qp = qp
        self.n_slots = n_slots
        self.max_len = max_len
        self.use_lop = use_lop
        self.bucket_min = bucket_min
        self.clock = clock
        self.pool = init_cache_pool(cfg, n_slots, max_len)
        self.capacity = pool_capacity(self.pool)
        # encdec: cross-attention lanes have their own (cross_ctx) capacity
        self.cross_capacity = (self.pool["cross"]["k"].shape[3]
                               if "cross" in self.pool else 0)
        self.chunked = ((chunked is None or chunked)
                        and cfg.family in CHUNKED_FAMILIES)
        self.chunk_tokens = chunk_tokens or cfg.lop_block

        self.queue: deque[Request] = deque()
        self.lanes: list[_Lane | None] = [None] * n_slots
        self._free: deque[int] = deque(range(n_slots))
        self._prefilling: deque[_Prefill] = deque()
        # pending next-token per lane, fed to the next decode step
        self._next_tok = np.zeros((n_slots, 1), np.int32)
        self.results: list[RequestResult] = []
        self.prefill_compiles = 0
        # interleaving telemetry (benchmarks/prefill_interleave.py):
        # decode steps taken while some prompt was mid-prefill, and
        # whole-prompt prefills that ran while decode lanes sat idle
        self.interleaved_decode_steps = 0
        self.full_prefill_stalls = 0

        self._prefill_fns: dict = {}
        self._step_fn = jax.jit(
            lambda qp, c, t: serve_step(cfg, qp, c, t, use_lop=use_lop),
            donate_argnums=(1,))
        self._insert_fn = jax.jit(insert_slot, donate_argnums=(0,))
        self._evict_fn = jax.jit(evict_slot, donate_argnums=(0,))

    # ---------------- queue ----------------

    def submit(self, req: Request) -> None:
        # attention-free pools (capacity 0: recurrent state only) have no
        # token-capacity bound — only the prompt buffer limits them
        need = len(req.prompt) + req.max_new_tokens
        if self.cfg.family == "vlm" and req.patches is not None:
            need += len(req.patches)   # image prefix occupies cache slots
        assert not self.capacity or need <= self.capacity, (
            f"request {req.rid} needs {need} tokens but pool capacity is "
            f"{self.capacity}")
        assert req.frames is None or len(req.frames) <= \
            self.cross_capacity, (
            f"request {req.rid} has {len(req.frames)} encoder frames but "
            f"the pool's cross capacity is {self.cross_capacity}")
        if req.arrival is None:
            req.arrival = self.clock()
        self.queue.append(req)

    @property
    def n_active(self) -> int:
        return sum(l is not None for l in self.lanes)

    @property
    def n_prefilling(self) -> int:
        return len(self._prefilling)

    def has_work(self) -> bool:
        return bool(self.queue) or bool(self._prefilling) \
            or self.n_active > 0

    # ---------------- admit / prefill / insert ----------------

    def _bucket(self, prompt_len: int) -> int:
        if self.cfg.family in ("hybrid", "ssm", "encdec", "moe"):
            # recurrent state integrates every position; encdec frames tie
            # the compile to the prompt anyway; MoE routers rank tokens per
            # group, so pad tokens would shift expert capacity and break
            # the lockstep equivalence → exact-length, no padding
            return prompt_len
        return pow2_bucket(prompt_len, lo=self.bucket_min,
                           hi=self.max_len)

    def _prefill_for(self, key):
        fn = self._prefill_fns.get(key)
        if fn is None:
            cfg, use_lop, max_len = self.cfg, self.use_lop, self.max_len
            fn = jax.jit(lambda qp, t, tl, kw: prefill(
                cfg, qp, t, max_len=max_len, use_lop=use_lop, true_len=tl,
                **kw))
            self._prefill_fns[key] = fn
            self.prefill_compiles += 1
        return fn

    def _chunk_fn_for(self, key):
        fn = self._prefill_fns.get(key)
        if fn is None:
            cfg = self.cfg

            def run(qp, pool, slot, toks, start, seq_end, activate, kw):
                lane = extract_slot(pool, slot)
                logits, lane = prefill_chunk(cfg, qp, toks, lane,
                                             start=start, seq_end=seq_end,
                                             **kw)
                pool = insert_slot(pool, slot, lane, active=activate)
                return logits, pool

            fn = jax.jit(run, donate_argnums=(1,))
            self._prefill_fns[key] = fn
            self.prefill_compiles += 1
        return fn

    def _plan_chunks(self, req: Request):
        """Host-side chunk grid of one prompt (fixed C-token shapes).

        The final chunk is right-padded to the same C so every chunk of
        every prompt hits ONE compiled shape; ``seq_end`` keeps the pad
        out of ``lengths`` and the causal mask keeps it out of every real
        query row. Only when the padded end would spill past the pool
        capacity (a near-capacity prompt) does the tail fall back to its
        exact length.
        """
        plen = len(req.prompt)
        prefix = (len(req.patches)
                  if self.cfg.family == "vlm" and req.patches is not None
                  else 0)
        c = self.chunk_tokens
        n = max(1, -(-plen // c))
        chunks, starts, seq_ends = [], [], []
        for k in range(n):
            lo, hi = k * c, min(plen, k * c + c)
            width = c
            if self.capacity and prefix + lo + c > self.capacity:
                width = hi - lo                 # near-capacity exact tail
            buf = np.zeros((1, width), np.int32)
            buf[0, :hi - lo] = req.prompt[lo:hi]
            chunks.append(buf)
            starts.append(prefix + lo if k else 0)
            seq_ends.append(prefix + hi)
        return chunks, starts, seq_ends

    def admit(self) -> int:
        """Admit queued requests into free lanes. Returns #admitted.

        Chunked regime: the lane is *reserved* and the prompt's chunk grid
        queued — no forward pass runs here; ``step()`` advances one chunk
        per cycle. Run-to-completion regime: the whole prompt prefills
        synchronously (stalling any active decode lanes — counted in
        ``full_prefill_stalls``) and the lane activates immediately.
        """
        n = 0
        while self.queue and self._free:
            req = self.queue.popleft()
            slot = self._free.popleft()
            if self.chunked:
                chunks, starts, seq_ends = self._plan_chunks(req)
                self._prefilling.append(_Prefill(
                    slot=slot, req=req, chunks=chunks, starts=starts,
                    seq_ends=seq_ends, t_admit=self.clock()))
                n += 1
                continue
            if self.n_active:
                self.full_prefill_stalls += 1
            plen = len(req.prompt)
            bucket = max(self._bucket(plen), plen)
            t_admit = self.clock()
            padded = np.zeros((1, bucket), np.int32)
            padded[0, :plen] = req.prompt
            kw = {}
            true_len = plen
            if req.frames is not None:
                kw["frames"] = jnp.asarray(req.frames)[None]
            if req.patches is not None:
                kw["patches"] = jnp.asarray(req.patches)[None]
                true_len += len(req.patches)   # image prefix precedes text
            key = (bucket,) + tuple(sorted(
                (k, v.shape) for k, v in kw.items()))
            logits, req_cache = self._prefill_for(key)(
                self.qp, jnp.asarray(padded), jnp.int32(true_len), kw)
            self.pool = self._insert_fn(self.pool, jnp.int32(slot),
                                        req_cache)
            self._start_lane(slot, req, logits, t_admit)
            n += 1
        return n

    def _start_lane(self, slot: int, req: Request, logits, t_admit: float,
                    done: list | None = None) -> None:
        """Prefill finished: seed the lane with the prompt's argmax."""
        first = int(jnp.argmax(logits[0]))
        res = RequestResult(rid=req.rid, prompt_len=len(req.prompt),
                            tokens=[first], t_arrival=req.arrival,
                            t_admit=t_admit, t_first=self.clock())
        lane = _Lane(result=res, remaining=req.max_new_tokens - 1,
                     eos_id=req.eos_id)
        self.lanes[slot] = lane
        self._next_tok[slot, 0] = first
        if (req.eos_id is not None and first == req.eos_id) \
                or lane.remaining <= 0:
            result = self._finish(slot, "eos" if req.eos_id is not None
                                  and first == req.eos_id else "length")
            if done is not None:
                done.append(result)

    def _step_prefill(self, done: list) -> bool:
        """Advance ONE chunk of the oldest mid-prefill lane."""
        if not self._prefilling:
            return False
        pf = self._prefilling[0]
        k = pf.next_chunk
        final = k == len(pf.chunks) - 1
        kw = {}
        if k == 0 and self.cfg.family == "vlm" and pf.req.patches is not None:
            kw["patches"] = jnp.asarray(pf.req.patches)[None]
        key = ("chunk", pf.chunks[k].shape[1]) + tuple(sorted(
            (k2, v2.shape) for k2, v2 in kw.items()))
        logits, self.pool = self._chunk_fn_for(key)(
            self.qp, self.pool, jnp.int32(pf.slot),
            jnp.asarray(pf.chunks[k]), jnp.int32(pf.starts[k]),
            jnp.int32(pf.seq_ends[k]), jnp.asarray(final), kw)
        pf.next_chunk += 1
        if final:
            self._prefilling.popleft()
            self._start_lane(pf.slot, pf.req, logits, pf.t_admit, done)
        return True

    # ---------------- decode / evict ----------------

    def step(self) -> list[RequestResult]:
        """One serve cycle: ≤1 prefill chunk + one decode step over every
        active lane; returns completions."""
        done: list[RequestResult] = []
        prefilling = self._step_prefill(done)
        if self.n_active == 0:
            return done
        if prefilling or self._prefilling:
            self.interleaved_decode_steps += 1
        logits, self.pool = self._step_fn(
            self.qp, self.pool, jnp.asarray(self._next_tok))
        toks = np.asarray(jnp.argmax(logits, -1)).astype(np.int32)
        for slot, lane in enumerate(self.lanes):
            if lane is None:
                continue
            tok = int(toks[slot])
            lane.result.tokens.append(tok)
            lane.remaining -= 1
            self._next_tok[slot, 0] = tok
            if lane.eos_id is not None and tok == lane.eos_id:
                done.append(self._finish(slot, "eos"))
            elif lane.remaining <= 0:
                done.append(self._finish(slot, "length"))
        return done

    def _finish(self, slot: int, reason: str) -> RequestResult:
        lane = self.lanes[slot]
        lane.result.t_done = self.clock()
        lane.result.finish_reason = reason
        self.pool = self._evict_fn(self.pool, jnp.int32(slot))
        self.lanes[slot] = None
        self._free.append(slot)
        self._next_tok[slot, 0] = 0
        self.results.append(lane.result)
        return lane.result

    def run_to_completion(self) -> list[RequestResult]:
        """Drain queue + lanes (all requests already submitted)."""
        while self.has_work():
            self.admit()
            self.step()
        return self.results


# jitted lockstep entry points, cached per (cfg, use_lop, max_len) so the
# N-request verify replay compiles each shape once, not once per request
_LOCKSTEP_FNS: dict = {}


def _lockstep_fns(cfg, use_lop: bool, max_len: int):
    key = (cfg, use_lop, max_len)
    fns = _LOCKSTEP_FNS.get(key)
    if fns is None:
        fns = (jax.jit(lambda qp, t, kw: prefill(
                   cfg, qp, t, max_len=max_len, use_lop=use_lop, **kw)),
               jax.jit(lambda qp, c, t: serve_step(cfg, qp, c, t,
                                                   use_lop=use_lop),
                       donate_argnums=(1,)))
        _LOCKSTEP_FNS[key] = fns
    return fns


def lockstep_generate(cfg, qp, prompt, max_new_tokens: int, *,
                      max_len: int, use_lop: bool = True,
                      eos_id: int | None = None, frames=None,
                      patches=None) -> list[int]:
    """Single-request lockstep reference path: whole-prompt prefill +
    greedy decode.

    ``max_len`` must match the pool's (same cache capacity → same LOP
    block top-K budget AND the same prefill-attention operand shapes the
    chunked path sees) for token-exact agreement with the scheduler.
    """
    prefill_fn, step = _lockstep_fns(cfg, use_lop, max_len)
    kw = {}
    if frames is not None:
        kw["frames"] = jnp.asarray(frames)[None]
    if patches is not None:
        kw["patches"] = jnp.asarray(patches)[None]
    logits, cache = prefill_fn(qp, jnp.asarray(prompt)[None], kw)
    toks = [int(jnp.argmax(logits[0]))]
    while len(toks) < max_new_tokens and (eos_id is None
                                          or toks[-1] != eos_id):
        logits, cache = step(qp, cache,
                             jnp.asarray([[toks[-1]]], jnp.int32))
        toks.append(int(jnp.argmax(logits[0])))
    return toks
