"""The HTTP frontend proper: scheduler pump thread + asyncio endpoints.

Threading model (DESIGN.md §Serving-frontend)
---------------------------------------------
The :class:`repro.serving.scheduler.Scheduler` is single-threaded by
design (host bookkeeping + jax dispatch), so exactly ONE dedicated
thread — the :class:`SchedulerPump` — owns it. The asyncio event loop
never touches scheduler state directly:

  loop → pump   a thread-safe submission queue carries
                ``(GenerateRequest, Future)`` pairs; the pump calls
                ``sched.submit`` and resolves the future with the
                admission verdict (``False`` = load-shed → HTTP 429)
  pump → loop   ``on_token`` callbacks and per-request done events fire
                on the pump thread and are marshalled into per-request
                ``asyncio.Queue`` channels via
                ``loop.call_soon_threadsafe``
  loop → pump   a client disconnect calls ``CancelToken.cancel()`` — a
                plain flag read by the scheduler's terminal sweep, safe
                from any thread

The pump loop is the same admit/step cycle ``run_to_completion`` drives,
plus inbox draining; when the scheduler is idle it blocks on the inbox
(bounded poll) instead of spinning.

Request lifecycle over the wire: JSON body → frozen
:class:`~repro.serving.api.SamplingParams` / ``GenerateRequest``;
``stream: true`` answers ``text/event-stream`` and emits one SSE frame
per token plus a final ``data: [DONE]``; a deadline expiring mid-stream
emits an ``event: error`` frame carrying 504 semantics (the status line
is long gone); overload answers 429 with ``Retry-After`` before any
lane is touched. Token sequences over HTTP are byte-identical to
:func:`repro.serving.scheduler.lockstep_generate` — the transport adds
no sampling state (``tests/test_http_frontend.py`` pins this).
"""

from __future__ import annotations

import asyncio
import itertools
import queue
import threading
from concurrent.futures import Future

import numpy as np

from repro.serving import metrics as _metrics
from repro.serving.api import CancelToken, GenerateRequest, SamplingParams
from repro.serving.frontend import http as _http
from repro.serving.frontend.http import (BadRequest, error_body, send_json,
                                         send_text, sse_event, sse_head)

#: finish reasons that mean the request ran to a natural end
NATURAL = ("eos", "stop", "length")
_STOP = object()          # inbox sentinel waking the pump to exit


class SchedulerPump(threading.Thread):
    """The one thread that owns the scheduler.

    ``submit()`` (any thread) enqueues a request and returns a
    :class:`concurrent.futures.Future` resolving to the admission
    verdict; an optional ``done_cb`` fires (on the pump thread) with the
    :class:`~repro.serving.api.FinishedRequest` when the request retires
    by ANY path — natural finish, shed, deadline, cancel or fault — by
    watching the scheduler's results watermark, so no retirement path
    needs its own notification plumbing.
    """

    def __init__(self, sched, *, idle_poll_s: float = 0.02):
        super().__init__(name="scheduler-pump", daemon=True)
        self.sched = sched
        self.idle_poll_s = idle_poll_s
        self.inbox: queue.Queue = queue.Queue()
        self.error: BaseException | None = None
        self._stopping = threading.Event()
        self._done_cbs: dict = {}
        self._results_seen = 0

    def submit(self, req: GenerateRequest, done_cb=None) -> Future:
        fut: Future = Future()
        self.inbox.put((req, fut, done_cb))
        return fut

    def stop(self) -> None:
        self._stopping.set()
        self.inbox.put(_STOP)

    # ---------------- pump loop (the only scheduler toucher) ----------

    def run(self) -> None:
        try:
            while not self._stopping.is_set():
                moved = self._drain_inbox(block=not self.sched.has_work())
                self.sched.admit()
                if self.sched.has_work():
                    self.sched.step()
                self._dispatch_done()
                if not moved and not self.sched.has_work() \
                        and self._stopping.is_set():
                    break
        except BaseException as e:                     # noqa: BLE001
            # a poisoned scheduler must fail the pending futures loudly,
            # not hang every in-flight HTTP request
            self.error = e
            while True:
                try:
                    item = self.inbox.get_nowait()
                except queue.Empty:
                    break
                if item is not _STOP:
                    item[1].set_exception(e)
            raise

    def _drain_inbox(self, *, block: bool) -> bool:
        moved = False
        timeout = self.idle_poll_s if block else None
        while True:
            try:
                item = (self.inbox.get(timeout=timeout) if block
                        else self.inbox.get_nowait())
            except queue.Empty:
                return moved
            block = False
            if item is _STOP:
                return moved
            req, fut, done_cb = item
            if done_cb is not None:
                self._done_cbs[req.rid] = done_cb
            try:
                accepted = self.sched.submit(req)
            except BaseException as e:                 # noqa: BLE001
                self._done_cbs.pop(req.rid, None)
                fut.set_exception(e)
                continue
            fut.set_result(accepted)
            moved = True

    def _dispatch_done(self) -> None:
        results = self.sched.results
        while self._results_seen < len(results):
            res = results[self._results_seen]
            self._results_seen += 1
            cb = self._done_cbs.pop(res.rid, None)
            if cb is not None:
                cb(res)


class HttpFrontend:
    """Asyncio HTTP server bridging sockets to the scheduler pump."""

    def __init__(self, sched, *, model_name: str | None = None,
                 registry=None, default_max_tokens: int = 16):
        self.sched = sched
        self.model = model_name or getattr(sched.cfg, "name", "repro")
        self.registry = registry if registry is not None else sched.metrics
        self.default_max_tokens = default_max_tokens
        self.pump = SchedulerPump(sched)
        self._m = _metrics.http_instruments(self.registry)
        self._rids = itertools.count(1)
        self._server: asyncio.AbstractServer | None = None
        self._loop: asyncio.AbstractEventLoop | None = None
        self._closing: asyncio.Event | None = None
        self.port: int | None = None

    # ---------------- lifecycle ----------------

    async def start(self, host: str = "127.0.0.1", port: int = 0) -> int:
        """Bind and start serving; returns the bound port (``port=0``
        picks a free one — how tests and the benchmark run)."""
        self._loop = asyncio.get_running_loop()
        self._closing = asyncio.Event()
        self.pump.start()
        self._server = await asyncio.start_server(self._handle, host, port)
        self.port = self._server.sockets[0].getsockname()[1]
        return self.port

    async def serve_forever(self) -> None:
        await self._closing.wait()
        await self.stop()

    def close(self) -> None:
        """Thread-safe shutdown request (unblocks ``serve_forever``)."""
        if self._loop is not None and self._closing is not None:
            self._loop.call_soon_threadsafe(self._closing.set)

    async def stop(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None
        if self.pump.is_alive():
            self.pump.stop()
            await asyncio.to_thread(self.pump.join, 10.0)

    # ---------------- connection handling ----------------

    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        self._m.in_flight.inc()
        route, code = "unknown", 500
        try:
            try:
                req = await _http.read_request(reader)
            except BadRequest as e:
                route, code = "malformed", 400
                await send_json(writer, 400,
                                error_body(400, "bad_request", str(e)))
                return
            if req is None:
                route, code = "empty", 0
                return
            route = req.path
            code = await self._route(req, reader, writer)
        except (ConnectionError, asyncio.CancelledError):
            code = 0                  # client went away; nothing to send
        finally:
            self._m.in_flight.dec()
            self._m.requests.labels(route=route, code=code).inc()
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionError, asyncio.CancelledError):
                pass

    async def _route(self, req, reader, writer) -> int:
        if req.path == "/healthz" and req.method == "GET":
            healthy = self.pump.is_alive() and self.pump.error is None
            await send_json(writer, 200 if healthy else 500, {
                "status": "ok" if healthy else "error",
                "model": self.model,
                "active_lanes": self.sched.n_active,
                "prefilling": self.sched.n_prefilling,
                "queue_depth": len(self.sched.queue),
            })
            return 200 if healthy else 500
        if req.path == "/metrics" and req.method == "GET":
            await send_text(writer, 200, self.registry.render(),
                            ctype="text/plain; version=0.0.4; "
                                  "charset=utf-8")
            return 200
        if req.path == "/v1/models" and req.method == "GET":
            await send_json(writer, 200, {
                "object": "list",
                "data": [{"id": self.model, "object": "model",
                          "owned_by": "repro",
                          "family": getattr(self.sched.cfg, "family",
                                            "unknown")}],
            })
            return 200
        if req.path == "/v1/completions":
            if req.method != "POST":
                await send_json(writer, 405, error_body(
                    405, "method_not_allowed", "use POST"))
                return 405
            return await self._completions(req, reader, writer)
        await send_json(writer, 404, error_body(
            404, "not_found", f"no route {req.path}"))
        return 404

    # ---------------- POST /v1/completions ----------------

    def _parse_completion(self, body: dict) -> dict:
        """JSON body → validated GenerateRequest fields. The wire
        contract speaks token ids (the repo has no tokenizer): ``prompt``
        is a list of ints in [0, vocab)."""
        if not isinstance(body, dict):
            raise BadRequest("body must be a JSON object")
        prompt = body.get("prompt")
        if not isinstance(prompt, list) or not prompt or \
                not all(isinstance(t, int) and not isinstance(t, bool)
                        for t in prompt):
            raise BadRequest("prompt must be a non-empty list of token ids")
        vocab = int(getattr(self.sched.cfg, "vocab", 0))
        if vocab and not all(0 <= t < vocab for t in prompt):
            raise BadRequest(f"prompt token out of range [0, {vocab})")
        max_tokens = body.get("max_tokens", self.default_max_tokens)
        if not isinstance(max_tokens, int) or isinstance(max_tokens, bool) \
                or max_tokens < 1:
            raise BadRequest("max_tokens must be an int >= 1")
        cap = self.sched.capacity
        if cap and len(prompt) + max_tokens > cap:
            raise BadRequest(
                f"prompt ({len(prompt)}) + max_tokens ({max_tokens}) "
                f"exceeds the pool capacity ({cap})")
        try:
            sp = SamplingParams(
                temperature=float(body.get("temperature", 0.0)),
                top_k=int(body.get("top_k", 0)),
                top_p=float(body.get("top_p", 1.0)),
                seed=int(body.get("seed", 0)),
                gamma=int(body.get("gamma", 0)))
        except (TypeError, ValueError) as e:
            raise BadRequest(f"bad sampling params: {e}") from e
        if sp.temperature < 0 or sp.top_k < 0 or not 0 < sp.top_p <= 1 \
                or sp.gamma < 0:
            raise BadRequest("sampling params out of range")
        stop = body.get("stop", ())
        if stop and (not isinstance(stop, list)
                     or not all(isinstance(s, list)
                                and all(isinstance(t, int) for t in s)
                                for s in stop)):
            raise BadRequest("stop must be a list of token-id lists")
        eos_id = body.get("eos_id")
        if eos_id is not None and (not isinstance(eos_id, int)
                                   or isinstance(eos_id, bool)):
            raise BadRequest("eos_id must be an int")
        deadline_ms = body.get("deadline_ms")
        if deadline_ms is not None and (
                not isinstance(deadline_ms, (int, float))
                or isinstance(deadline_ms, bool) or deadline_ms <= 0):
            raise BadRequest("deadline_ms must be a positive number")
        return {"prompt": prompt, "max_tokens": max_tokens, "sampling": sp,
                "stop": tuple(tuple(s) for s in stop), "eos_id": eos_id,
                "deadline_ms": deadline_ms,
                "stream": bool(body.get("stream", False))}

    async def _completions(self, req, reader, writer) -> int:
        try:
            spec = self._parse_completion(req.json())
        except BadRequest as e:
            await send_json(writer, 400,
                            error_body(400, "bad_request", str(e)))
            return 400

        rid = next(self._rids)
        chan: asyncio.Queue = asyncio.Queue()
        loop = self._loop
        cancel = CancelToken()
        on_token = None
        if spec["stream"]:
            def on_token(sr):
                loop.call_soon_threadsafe(chan.put_nowait, ("token", sr))

        def on_done(res):
            loop.call_soon_threadsafe(chan.put_nowait, ("done", res))

        greq = GenerateRequest(
            rid=rid, prompt=np.asarray(spec["prompt"], np.int32),
            max_new_tokens=spec["max_tokens"], eos_id=spec["eos_id"],
            sampling=spec["sampling"], stop=spec["stop"],
            on_token=on_token, cancel=cancel,
            deadline_ms=spec["deadline_ms"])
        fut = self.pump.submit(greq, on_done)
        try:
            accepted = await asyncio.wrap_future(fut)
        except AssertionError as e:
            await send_json(writer, 400,
                            error_body(400, "bad_request", str(e)))
            return 400
        if not accepted:
            # PR 9 admission control end-to-end: bounded queue → an
            # immediate 429, never a hang; Retry-After is advisory
            await send_json(writer, 429, error_body(
                429, "overloaded", "queue is full, retry later"),
                extra=("Retry-After: 1",))
            return 429

        # client-disconnect watch: the request body is fully consumed,
        # so ANY further read completing means EOF/reset → cancel the
        # lane (its slot frees on the scheduler's next terminal sweep)
        watcher = asyncio.create_task(
            self._watch_disconnect(reader, cancel))
        try:
            if spec["stream"]:
                return await self._stream(writer, rid, chan, cancel)
            return await self._unary(writer, rid, chan)
        finally:
            watcher.cancel()

    async def _watch_disconnect(self, reader, cancel: CancelToken) -> None:
        try:
            await reader.read(1)
        except (ConnectionError, asyncio.CancelledError):
            pass
        else:
            self._m.disconnects.inc()
        cancel.cancel()

    def _chunk(self, rid: int, sr) -> dict:
        return {"id": f"cmpl-{rid}",
                "object": "text_completion.chunk",
                "model": self.model,
                "choices": [{"index": 0, "token": sr.token,
                             "token_index": sr.index,
                             "finish_reason": sr.finish_reason or None}]}

    async def _stream(self, writer, rid, chan, cancel) -> int:
        """SSE streaming: headers go out with (not before) the first
        event, so a request retired before any token still gets a real
        status line (504 deadline / 500 fault) instead of an empty
        200 stream."""
        kind, payload = await chan.get()
        if kind == "done" and payload.finish_reason not in NATURAL:
            reason = payload.finish_reason
            if reason == "deadline":
                await send_json(writer, 504, error_body(
                    504, "deadline_expired",
                    "deadline_ms elapsed before the first token"))
                return 504
            if reason == "cancelled":
                return 0
            await send_json(writer, 500, error_body(
                500, "generation_fault", f"request retired: {reason}"))
            return 500
        writer.write(sse_head())
        try:
            while True:
                if kind == "token":
                    writer.write(sse_event(self._chunk(rid, payload)))
                    await writer.drain()
                    if payload.finished:
                        break
                elif kind == "done":
                    reason = payload.finish_reason
                    if reason in NATURAL:
                        break        # final token frame already sent
                    if reason == "cancelled":
                        return 0     # client is gone; nothing to say
                    # mid-stream retirement (deadline/fault): the status
                    # line was 200 long ago — signal in-band per the SSE
                    # contract, then end the stream cleanly
                    code = 504 if reason == "deadline" else 500
                    writer.write(sse_event(
                        error_body(code, reason, f"request retired "
                                   f"mid-stream: {reason}"),
                        event="error"))
                    await writer.drain()
                    break
                kind, payload = await chan.get()
            writer.write(sse_event("[DONE]"))
            await writer.drain()
        except ConnectionError:
            cancel.cancel()
            self._m.disconnects.inc()
            return 0
        return 200

    async def _unary(self, writer, rid, chan) -> int:
        while True:
            kind, res = await chan.get()
            if kind == "done":
                break
        reason = res.finish_reason
        if reason in NATURAL:
            await send_json(writer, 200, {
                "id": f"cmpl-{rid}", "object": "text_completion",
                "model": self.model,
                "choices": [{"index": 0, "tokens": list(res.tokens),
                             "finish_reason": reason}],
                "usage": {"prompt_tokens": res.prompt_len,
                          "completion_tokens": len(res.tokens),
                          "cached_prompt_tokens": res.cached_len}})
            return 200
        if reason == "cancelled":
            return 0
        code = 504 if reason == "deadline" else 500
        await send_json(writer, code, error_body(
            code, reason, f"request retired: {reason}"))
        return code


# ---------------------------------------------------------------------------
# Thread-hosted server (tests, sanity smoke, the HTTP benchmark)
# ---------------------------------------------------------------------------

class ThreadedServer:
    """Handle on a frontend running in its own event-loop thread."""

    def __init__(self, frontend: HttpFrontend, thread: threading.Thread):
        self.frontend = frontend
        self.thread = thread

    @property
    def port(self) -> int:
        return self.frontend.port

    def close(self) -> None:
        self.frontend.close()
        self.thread.join(timeout=15.0)


def serve_threaded(sched, *, host: str = "127.0.0.1", port: int = 0,
                   **kw) -> ThreadedServer:
    """Start an :class:`HttpFrontend` on a daemon thread; returns once
    the socket is bound (``.port`` is live). The caller's thread stays
    free — how tests, ``scripts/sanity_serving.py`` and
    ``benchmarks/http_serving.py`` drive a real loopback server."""
    frontend = HttpFrontend(sched, **kw)
    started = threading.Event()
    failure: list = []

    def main() -> None:
        async def body():
            try:
                await frontend.start(host, port)
            except BaseException as e:                 # noqa: BLE001
                failure.append(e)
                raise
            finally:
                started.set()
            await frontend.serve_forever()
        asyncio.run(body())

    thread = threading.Thread(target=main, name="http-frontend",
                              daemon=True)
    thread.start()
    started.wait(timeout=30.0)
    if failure:
        raise failure[0]
    assert frontend.port is not None, "frontend failed to bind"
    return ThreadedServer(frontend, thread)
