"""Production HTTP serving front-end (DESIGN.md §Serving-frontend).

An asyncio HTTP/1.1 server — stdlib only, no new dependencies — that
exposes the typed serving API (:mod:`repro.serving.api`) over the wire:

  ``POST /v1/completions``  OpenAI-style completion; ``"stream": true``
                            streams tokens as Server-Sent Events
  ``GET /v1/models``        the served model
  ``GET /healthz``          liveness + lane/queue occupancy
  ``GET /metrics``          Prometheus text (:mod:`repro.serving.metrics`)

The scheduler is pumped from a dedicated thread
(:class:`~repro.serving.frontend.server.SchedulerPump`); the asyncio
loop and the pump communicate through a thread-safe submission queue and
``loop.call_soon_threadsafe`` token delivery — the JSON body maps onto a
frozen :class:`~repro.serving.api.GenerateRequest`, ``on_token`` becomes
SSE chunks, and a client disconnect becomes
:meth:`~repro.serving.api.CancelToken.cancel`.
"""

from repro.serving.frontend.http import Request, read_request, sse_event
from repro.serving.frontend.server import (HttpFrontend, SchedulerPump,
                                           serve_threaded)

__all__ = ["HttpFrontend", "SchedulerPump", "serve_threaded",
           "Request", "read_request", "sse_event"]
