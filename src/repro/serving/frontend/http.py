"""Minimal HTTP/1.1 + SSE plumbing over asyncio streams (stdlib only).

Just enough protocol for the serving endpoints: request-line + headers +
``Content-Length`` body parsing, JSON and Server-Sent-Event response
writers, one request per connection (every response carries
``Connection: close`` — curl, the benchmark and the tests all open a
connection per request, and closing is what delimits an SSE stream with
no ``Content-Length``).
"""

from __future__ import annotations

import asyncio
import json
from dataclasses import dataclass, field

#: request body cap — a completions body is a token list, not a payload
MAX_BODY_BYTES = 8 * 1024 * 1024
MAX_HEADER_LINE = 64 * 1024

REASONS = {200: "OK", 400: "Bad Request", 404: "Not Found",
           405: "Method Not Allowed", 408: "Request Timeout",
           413: "Payload Too Large", 429: "Too Many Requests",
           500: "Internal Server Error", 504: "Gateway Timeout"}


class BadRequest(Exception):
    """Malformed HTTP or JSON — answered with a 400 and a close."""


@dataclass
class Request:
    method: str
    path: str
    headers: dict = field(default_factory=dict)
    body: bytes = b""

    def json(self):
        try:
            return json.loads(self.body.decode("utf-8") or "{}")
        except (ValueError, UnicodeDecodeError) as e:
            raise BadRequest(f"body is not valid JSON: {e}") from e


async def read_request(reader: asyncio.StreamReader) -> Request | None:
    """Parse one request off the stream; ``None`` on a clean EOF (the
    client connected and went away without sending anything)."""
    try:
        line = await reader.readline()
    except (ConnectionError, asyncio.LimitOverrunError):
        return None
    if not line:
        return None
    if len(line) > MAX_HEADER_LINE:
        raise BadRequest("request line too long")
    parts = line.decode("latin-1").strip().split(" ")
    if len(parts) != 3 or not parts[2].startswith("HTTP/"):
        raise BadRequest(f"malformed request line {line!r}")
    method, path, _version = parts
    headers: dict[str, str] = {}
    while True:
        hline = await reader.readline()
        if hline in (b"\r\n", b"\n", b""):
            break
        if len(hline) > MAX_HEADER_LINE:
            raise BadRequest("header line too long")
        name, sep, value = hline.decode("latin-1").partition(":")
        if not sep:
            raise BadRequest(f"malformed header {hline!r}")
        headers[name.strip().lower()] = value.strip()
    body = b""
    if "content-length" in headers:
        try:
            n = int(headers["content-length"])
        except ValueError as e:
            raise BadRequest("bad Content-Length") from e
        if n < 0 or n > MAX_BODY_BYTES:
            raise BadRequest(f"Content-Length {n} out of bounds")
        if n:
            try:
                body = await reader.readexactly(n)
            except asyncio.IncompleteReadError as e:
                raise BadRequest("body shorter than Content-Length") from e
    return Request(method=method, path=path.split("?", 1)[0],
                   headers=headers, body=body)


def response_head(code: int, ctype: str, *, length: int | None = None,
                  extra: tuple = ()) -> bytes:
    lines = [f"HTTP/1.1 {code} {REASONS.get(code, 'Unknown')}",
             f"Content-Type: {ctype}",
             "Connection: close"]
    if length is not None:
        lines.append(f"Content-Length: {length}")
    lines.extend(extra)
    return ("\r\n".join(lines) + "\r\n\r\n").encode("latin-1")


async def send_json(writer: asyncio.StreamWriter, code: int, obj,
                    *, extra: tuple = ()) -> None:
    body = (json.dumps(obj) + "\n").encode("utf-8")
    writer.write(response_head(code, "application/json", length=len(body),
                               extra=extra) + body)
    await writer.drain()


async def send_text(writer: asyncio.StreamWriter, code: int, text: str,
                    ctype: str = "text/plain; charset=utf-8") -> None:
    body = text.encode("utf-8")
    writer.write(response_head(code, ctype, length=len(body)) + body)
    await writer.drain()


def sse_head() -> bytes:
    """SSE response head: no Content-Length — the close delimits."""
    return response_head(200, "text/event-stream",
                         extra=("Cache-Control: no-cache",))


def sse_event(data, event: str | None = None) -> bytes:
    """One SSE frame: optional ``event:`` line + ``data:`` payload.
    ``data`` is JSON-encoded unless it is already a string (the
    ``[DONE]`` sentinel)."""
    payload = data if isinstance(data, str) else json.dumps(data)
    head = f"event: {event}\n" if event else ""
    return (head + f"data: {payload}\n\n").encode("utf-8")


def error_body(code: int, kind: str, message: str) -> dict:
    """OpenAI-style error envelope."""
    return {"error": {"type": kind, "code": code, "message": message}}
