"""Serving runtime: quantized weights, slot-paged KV/LOP cache pool,
prefill + decode engine, typed serving API, continuous-batching scheduler.

The cross-layer contract is :mod:`repro.serving.api` (DESIGN.md
§Serving-API): frozen request/sampling/result dataclasses plus the
:class:`~repro.serving.api.InferenceEngine` protocol the scheduler speaks.
Lifecycle (see :mod:`repro.serving.scheduler`): admit → prefill → insert →
decode → evict over ``n_slots`` persistent decode lanes, with per-lane
sampling (:mod:`repro.serving.sampling`) and streaming token delivery.

Observability lives in :mod:`repro.serving.metrics` (DESIGN.md
§Serving-metrics) and the HTTP front-end in
:mod:`repro.serving.frontend` (DESIGN.md §Serving-frontend) — addressed
by module path, not re-exported here.
"""

from repro.serving.api import (GREEDY, CancelToken, FinishedRequest,
                               GenerateRequest, InferenceEngine,
                               PooledEngine, SamplingParams, StepResult)
from repro.serving.cache import (evict_slot, extract_slot, free_slot,
                                 free_slots, init_cache, init_cache_pool,
                                 insert_slot, pool_capacity)
from repro.serving.engine import prefill, prefill_chunk, serve_step
from repro.serving.quantize import quantize_params
from repro.serving.sampling import lane_keys, sample_tokens, sample_with_seed
from repro.serving.scheduler import (Request, RequestResult, Scheduler,
                                     lockstep_generate)
