"""Serving runtime: quantized weights, KV/LOP caches, prefill + decode."""

from repro.serving.cache import init_cache
from repro.serving.engine import prefill, serve_step
from repro.serving.quantize import quantize_params
