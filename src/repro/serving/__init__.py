"""Serving runtime: quantized weights, slot-paged KV/LOP cache pool,
prefill + decode engine, continuous-batching scheduler.

Lifecycle (see :mod:`repro.serving.scheduler`): admit → prefill → insert →
decode → evict over ``n_slots`` persistent decode lanes.
"""

from repro.serving.cache import (evict_slot, extract_slot, free_slot,
                                 free_slots, init_cache, init_cache_pool,
                                 insert_slot, pool_capacity)
from repro.serving.engine import prefill, prefill_chunk, serve_step
from repro.serving.quantize import quantize_params
from repro.serving.scheduler import (Request, RequestResult, Scheduler,
                                     lockstep_generate)
