"""Serving engine: prefill + one-token decode for every family.

This is the paper's full pipeline on TPU terms (DESIGN.md §2):

  prefill   — BitLinear projections (TINT) → rope → absmax barrier → ONE
              fused attention dispatch (:func:`repro.kernels.ops.
              prefill_attention`): batched causal int8 flash attention
              over the capacity-padded cache with f32 online-softmax
              carry; K/V/LOP-feature cache written per layer. Two entry
              shapes share the op: :func:`prefill` (whole prompt) and
              :func:`prefill_chunk` (one fixed-size chunk of a prompt
              against the cache written so far — the chunked-prefill
              tentpole, DESIGN.md §Chunked-prefill), bit-identical per
              query row by construction.
  decode    — one token: project/rope/quantize, append to cache, then ONE
              fused attention dispatch (:func:`repro.kernels.ops.
              decode_attention`): the LOP screen over the 4-bit feature
              cache, the comparison-free block top-K, and exact int8
              attention over the K candidate blocks run as a single
              batched head-pipelined kernel spanning every (batch,
              kv-head) lane — then BitLinear FFN/MoE.

Projections dispatch through the fused TINT entries (DESIGN.md
§TINT-projection-fusion): a decoder layer's non-attention hot path is
THREE dispatches — fused QKV (one packed weight, per-column dequant),
the O projection, and the whole FFN (gate·up → in-VMEM re-barrier →
down) — each running the absmax barrier, the packed-ternary GEMM and
the epilogue inside one kernel, so no f32 activation or int32
accumulator round-trips HBM between them. MoE layers run every
expert's FFN as ONE grouped dispatch (expert = grid axis). The fused
entry owns the barrier dtype: attention outputs feed ``qlinear``
directly, with no caller-side ``astype`` re-cast.

Attention-free layers (Mamba/RWKV) carry recurrent state instead. With an
active mesh the decode attention runs the SP quota-sharded core
(:mod:`repro.distributed.sp_decode`) — the cache's token axis lives sharded
across the model axis; each shard calls the same fused kernel with its
``pos_offset`` and softmax stats merge flash-decoding style.

Beyond-paper decode variants (group-shared selection, integer-domain
prefill logits) are ``ModelConfig`` fields pinned once per entry call by
:func:`repro.configs.base.resolve_decode_flags`; the legacy
``REPRO_GQA_SHARED_SELECT`` / ``REPRO_INT8_LOGITS`` env flags remain as
fallbacks for unset fields.

Slot-paged decode: when the cache carries a per-lane ``active`` mask (a
:func:`repro.serving.cache.init_cache_pool` pool), ``serve_step`` decodes
only the live lanes — inactive lanes are screened out of the LOP selection
(effective length 0), skipped by the cache append, emit zero attention
output, and keep their ``lengths`` frozen. This is what lets the scheduler
admit/retire individual requests mid-flight without recompiling the step.

These functions are the compute layer under the typed serving API
(DESIGN.md §Serving-API): :class:`repro.serving.api.PooledEngine` wraps
them behind the ``InferenceEngine`` protocol, fusing ``serve_step`` with
the per-lane batched sampler into one jitted decode+sample dispatch; the
scheduler and drivers never call these entry points directly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import resolve_decode_flags
from repro.core.lop import lop_features, pack_features
from repro.core.qlinear import qlinear, qlinear_split
from repro.core.quantization import quantize
from repro.distributed.partitioning import current_mesh, shard
from repro.kernels import ops
from repro.models import rwkv6
from repro.models.layers import (embedding_apply, head_apply, norm_apply,
                                 rope)
from repro.models.mamba import mamba_decode_step, mamba_forward
from repro.models.moe import ffn_apply, moe_apply
from repro.serving.cache import init_cache, round_up
from repro.serving.lop_select import k_keep_blocks

NEG_INF = -1e30


def _layer_scan(body, x, xs):
    """Layer-stack scan with dry-run accounting unroll."""
    from repro.models.scan_utils import accounting_unroll
    length = jax.tree.leaves(xs)[0].shape[0]
    return jax.lax.scan(body, x, xs, unroll=accounting_unroll(length))


def _q(x, axis=-1):
    qt = quantize(x, axis=axis)
    return qt.values, qt.scale


def _shard_batch(x, *rest):
    """Constrain batch over dp only when it divides (long_500k has B=1)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    dp = int(mesh.shape.get("data", 1)) * int(mesh.shape.get("pod", 1))
    if x.shape[0] % dp == 0:
        return shard(x, "dp", *rest)
    return x


# ===========================================================================
# Attention layer — prefill (whole prompt and chunked, one fused dispatch)
# ===========================================================================

def _project_qkv(cfg, lp, h, src=None):
    b, s, _ = h.shape
    src = h if src is None else src
    skv = src.shape[1]
    if "wqkv" in lp:
        # fused-at-deployment QKV: ONE dispatch (barrier + ternary GEMM +
        # per-column dequant inside the kernel), split is a free view
        q, k, v = qlinear_split(lp["wqkv"], h,
                                (cfg.q_dim, cfg.kv_dim, cfg.kv_dim))
    else:
        q = qlinear(lp["wq"], h)
        k = qlinear(lp["wk"], src)
        v = qlinear(lp["wv"], src)
    q = q.reshape(b, s, cfg.n_heads, cfg.hd)
    k = k.reshape(b, skv, cfg.n_kv_heads, cfg.hd)
    v = v.reshape(b, skv, cfg.n_kv_heads, cfg.hd)
    return q, k, v


def _quantize_kv(k, v):
    """[B, S, Hkv, dh] f32 → int8 caches in [B, Hkv, S, ...] layout."""
    ki, ksc = _q(k)
    vi, vsc = _q(v)
    ki = ki.transpose(0, 2, 1, 3)
    vi = vi.transpose(0, 2, 1, 3)
    ksc = ksc[..., 0].transpose(0, 2, 1)
    vsc = vsc[..., 0].transpose(0, 2, 1)
    feat = pack_features(lop_features(ki))
    return ki, vi, ksc, vsc, feat


def _pad_cache(arr, cap: int, axis: int = 2):
    pad = [(0, 0)] * arr.ndim
    pad[axis] = (0, cap - arr.shape[axis])
    return jnp.pad(arr, pad)


def attn_prefill(cfg, lp, h, *, capacity: int):
    """→ (attn_out [B,S,D], cache_layer). Caches K/V/features at [0, S).

    The whole prompt is one maximal chunk: K/V/features are written into
    the capacity-padded cache first and attention runs over THAT cache
    through :func:`repro.kernels.ops.prefill_attention` (``q_offset=0``,
    ``kv_len=S``) — the same op, operand shapes and masking as
    :func:`attn_prefill_chunk`, which is what makes chunked prefill
    bit-identical per query row (DESIGN.md §Chunked-prefill).
    """
    b, s, _ = h.shape
    q, k, v = _project_qkv(cfg, lp, h)
    positions = jnp.arange(s)[None, :]
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    qi, qsc = _q(q)
    ki, vi, ksc, vsc, feat = _quantize_kv(k, v)
    qi = qi.transpose(0, 2, 1, 3)                        # [B, H, S, dh]
    qsc = qsc[..., 0].transpose(0, 2, 1)

    cache_l = {
        "k": _pad_cache(ki, capacity), "v": _pad_cache(vi, capacity),
        "k_scale": _pad_cache(ksc, capacity), "v_scale": _pad_cache(vsc,
                                                                    capacity),
        "feat": _pad_cache(feat, capacity),
    }
    # dense/vlm must attend the FULL capacity so chunked rows (which see
    # the pool lane at capacity) stay bitwise equal; run-to-completion
    # families (moe/hybrid/encdec self-attn) never chunk, so their
    # attention view trims to the prompt's block roundup — cost scales
    # with S, not pool capacity
    m_att = capacity if cfg.family in ("dense", "vlm") \
        else min(capacity, round_up(s, cfg.lop_block))
    o = ops.prefill_attention(
        qi, qsc, cache_l["k"][:, :, :m_att], cache_l["v"][:, :, :m_att],
        cache_l["k_scale"][:, :, :m_att], cache_l["v_scale"][:, :, :m_att],
        jnp.full((b,), s, jnp.int32), causal=True,
        window=cfg.swa_window, int8_logits=bool(cfg.int8_logits))
    o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.q_dim)
    out = qlinear(lp["wo"], o)
    return out, cache_l


def _write_chunk(cl, ki, vi, ksc, vsc, feat, start):
    """Write a C-token quantized chunk into the cache at [start, start+C).

    One ``dynamic_update_slice`` per leaf at the (possibly traced) chunk
    start — the cache-pool analogue of the per-token ``_write_token``.
    Padded tail tokens of a final chunk land here too; they sit above
    ``lengths`` and are stale-masked like every other dead byte
    (DESIGN.md §Chunked-prefill partial-insert invariants).
    """
    def wr(arr, val):
        return jax.lax.dynamic_update_slice(
            arr, val, (0, 0, start) + (0,) * (arr.ndim - 3))

    cl = dict(cl)
    cl["k"] = wr(cl["k"], ki)
    cl["v"] = wr(cl["v"], vi)
    cl["feat"] = wr(cl["feat"], feat)
    cl["k_scale"] = wr(cl["k_scale"], ksc)
    cl["v_scale"] = wr(cl["v_scale"], vsc)
    return cl


def attn_prefill_chunk(cfg, lp, h, cl, *, start, kv_len):
    """One C-token prefill chunk against an existing cache layer.

    h [B, C, D] are the chunk's hidden states at global positions
    [start, start+C); ``cl`` holds every earlier chunk's K/V at
    [0, start). The chunk's quantized K/V/features are written at
    [start, start+C) and its queries attend causally over [0, kv_len)
    through the same fused dispatch as :func:`attn_prefill` — the
    chunk-carry is the cache itself plus (start, kv_len); no softmax
    state crosses chunk boundaries (it lives in the kernel's VMEM
    scratch within one call).
    """
    b, c, _ = h.shape
    q, k, v = _project_qkv(cfg, lp, h)
    positions = start + jnp.arange(c)[None, :]
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    qi, qsc = _q(q)
    ki, vi, ksc, vsc, feat = _quantize_kv(k, v)
    qi = qi.transpose(0, 2, 1, 3)                        # [B, H, C, dh]
    qsc = qsc[..., 0].transpose(0, 2, 1)

    cl = _write_chunk(cl, ki, vi, ksc, vsc, feat, start)
    o = ops.prefill_attention(
        qi, qsc, cl["k"], cl["v"], cl["k_scale"], cl["v_scale"], kv_len,
        q_offset=start, causal=True, window=cfg.swa_window,
        int8_logits=bool(cfg.int8_logits))
    o = o.transpose(0, 2, 1, 3).reshape(b, c, cfg.q_dim)
    out = qlinear(lp["wo"], o)
    return out, cl


def build_cross_cache(cfg, lp, enc, capacity: int):
    """Quantize encoder memory through this layer's K/V projections.

    A fused ``wkv`` node (quantize-time KV fusion for cross-attention —
    both consume the encoder memory) projects K and V in one dispatch.
    """
    b, s, _ = enc.shape
    if "wkv" in lp:
        k, v = qlinear_split(lp["wkv"], enc, (cfg.kv_dim, cfg.kv_dim))
    else:
        k = qlinear(lp["wk"], enc)
        v = qlinear(lp["wv"], enc)
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.hd)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.hd)
    ki, vi, ksc, vsc, feat = _quantize_kv(k, v)
    return {
        "k": _pad_cache(ki, capacity), "v": _pad_cache(vi, capacity),
        "k_scale": _pad_cache(ksc, capacity),
        "v_scale": _pad_cache(vsc, capacity),
        "feat": _pad_cache(feat, capacity),
    }


def cross_attn_prefill(cfg, lp, h, cross_cache, cross_len, kv_max=None):
    """Decoder-side cross attention over a prequantized encoder cache.

    ``kv_max`` (static) trims the attention view of the cross cache to
    the encoder length's block roundup — encdec never chunks, so the
    cost scales with the actual frames, not ``cross_ctx`` capacity.
    """
    b, s, _ = h.shape
    m = kv_max or cross_cache["k"].shape[2]
    q = qlinear(lp["wq"], h).reshape(b, s, cfg.n_heads, cfg.hd)
    qi, qsc = _q(q)
    qi = qi.transpose(0, 2, 1, 3)
    qsc = qsc[..., 0].transpose(0, 2, 1)
    o = ops.prefill_attention(
        qi, qsc, cross_cache["k"][:, :, :m], cross_cache["v"][:, :, :m],
        cross_cache["k_scale"][:, :, :m], cross_cache["v_scale"][:, :, :m],
        cross_len, causal=False, int8_logits=bool(cfg.int8_logits))
    o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.q_dim)
    return qlinear(lp["wo"], o)


# ===========================================================================
# Attention layer — decode (LOP sparse / dense baseline / SP-sharded)
# ===========================================================================

def lop_decode_attention(cfg, qi, qsc, cl, new_len, *, window: int,
                         use_lop: bool = True, k_keep: int | None = None):
    """Local (non-SP) decode attention core — one fused-kernel dispatch.

    qi int8 [B, H, dh]; qsc f32 [B, H, 1]; cl = cache layer; new_len [B].
    → f32 [B, H, dh].

    The dense baseline, the LOP screen → comparison-free block top-K →
    exact candidate attention, and group-shared selection all route
    through :func:`repro.kernels.ops.decode_attention`: one batched
    kernel whose grid spans every (batch, kv-head) lane, replacing the
    per-head ``lop_screen``/``sparse_decode`` small-kernel dispatch under
    a triple ``vmap`` (DESIGN.md §Fused-decode-kernel). Retired slot-pool
    lanes arrive with ``new_len == 0`` and emit exactly zero.

    ``k_keep`` overrides the config's kept-block budget — the speculative
    draft pass degrades the screen to a smaller K than serving decode
    uses (DESIGN.md §Speculative-decoding); ``None`` keeps the config
    policy.
    """
    cfg = resolve_decode_flags(cfg)
    m = cl["k"].shape[2]
    if k_keep is None:
        k_keep = k_keep_blocks(cfg, m)
    return ops.decode_attention(
        qi, qsc, cl["k"], cl["v"], cl["k_scale"], cl["v_scale"], cl["feat"],
        new_len, block=cfg.lop_block,
        k_keep=max(1, min(k_keep, m // cfg.lop_block)),
        window=window, use_lop=use_lop,
        shared_select=bool(cfg.gqa_shared_select))


def _write_token(cl, ki, vi, ksc, vsc, feat, lengths, active=None):
    """Append one quantized token per sequence at its own position.

    With ``active`` given, retired/empty slots keep their lane untouched
    (the write is computed and discarded — branch-free under vmap).
    """
    ok = jnp.ones_like(lengths, bool) if active is None else active

    def wr(arr, val, pos, ok_):
        # arr [Hkv, M, d]; val [Hkv, d]
        upd = jax.lax.dynamic_update_slice(
            arr, val[:, None], (0, pos) + (0,) * (arr.ndim - 2))
        return jnp.where(ok_, upd, arr)

    def wr_scale(arr, val, pos, ok_):
        upd = jax.lax.dynamic_update_slice(arr, val[:, None], (0, pos))
        return jnp.where(ok_, upd, arr)

    cl = dict(cl)
    cl["k"] = jax.vmap(wr)(cl["k"], ki, lengths, ok)
    cl["v"] = jax.vmap(wr)(cl["v"], vi, lengths, ok)
    cl["feat"] = jax.vmap(wr)(cl["feat"], feat, lengths, ok)
    cl["k_scale"] = jax.vmap(wr_scale)(cl["k_scale"], ksc[..., 0], lengths,
                                       ok)
    cl["v_scale"] = jax.vmap(wr_scale)(cl["v_scale"], vsc[..., 0], lengths,
                                       ok)
    return cl


def attn_decode(cfg, lp, h, cl, lengths, *, use_lop=True, sp_axes=None,
                active=None, k_keep=None):
    """One-token self-attention with cache append. h [B, 1, D].

    ``active`` [B] bool masks slot-paged lanes: inactive lanes get effective
    length 0 (nothing valid for the LOP screen / block top-K), no cache
    write, and zero attention output. ``k_keep`` degrades the LOP
    selection budget (speculative draft pass); ``None`` = config policy.
    """
    b = h.shape[0]
    q, k, v = _project_qkv(cfg, lp, h)
    positions = lengths[:, None]
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    qi, qsc = _q(q[:, 0])                                # [B, H, dh]
    ki, ksc = _q(k[:, 0])                                # [B, Hkv, dh]
    vi, vsc = _q(v[:, 0])
    feat = pack_features(lop_features(ki))
    new_len = lengths + 1
    if active is not None:
        new_len = jnp.where(active, new_len, 0)

    if sp_axes:
        from repro.distributed.sp_decode import sp_decode_attention
        out, cl = sp_decode_attention(
            cfg, qi, qsc, ki, vi, ksc, vsc, feat, cl, lengths,
            window=cfg.swa_window, use_lop=use_lop and cfg.use_lop,
            sp_axes=sp_axes, active=active)
    else:
        cl = _write_token(cl, ki, vi, ksc, vsc, feat, lengths, active)
        out = lop_decode_attention(cfg, qi, qsc, cl, new_len,
                                   window=cfg.swa_window,
                                   use_lop=use_lop and cfg.use_lop,
                                   k_keep=k_keep)
    if active is not None:
        out = jnp.where(active[:, None, None], out, 0.0)
    out = qlinear(lp["wo"], out.reshape(b, 1, cfg.q_dim))
    return out, cl


def cross_attn_decode(cfg, lp, h, cross_cl, cross_len, *, use_lop=True,
                      sp_axes=None):
    """One-token cross-attention (no cache write)."""
    b = h.shape[0]
    q = qlinear(lp["wq"], h).reshape(b, cfg.n_heads, cfg.hd)
    qi, qsc = _q(q)
    if sp_axes:
        from repro.distributed.sp_decode import sp_decode_attention
        out, _ = sp_decode_attention(
            cfg, qi, qsc, None, None, None, None, None, cross_cl, cross_len,
            window=0, use_lop=use_lop and cfg.use_lop, sp_axes=sp_axes,
            write=False)
    else:
        out = lop_decode_attention(cfg, qi, qsc, cross_cl, cross_len,
                                   window=0, use_lop=use_lop and cfg.use_lop)
    return qlinear(lp["wo"], out.reshape(b, 1, cfg.q_dim))


# ===========================================================================
# Layer bodies
# ===========================================================================

def _mlp(cfg, lp, x):
    h = norm_apply(lp["ln2"], x, cfg.norm)
    if "moe" in lp:
        y, _ = moe_apply(cfg, lp["moe"], h)
    else:
        y = ffn_apply(cfg, lp["ffn"], h)
    return x + y


def _decoder_layer_prefill(cfg, lp, x, *, capacity, enc=None, cross_cap=None,
                           cross_len=None):
    x = _shard_batch(x)
    h = norm_apply(lp["ln1"], x, cfg.norm)
    attn_out, cache_l = attn_prefill(cfg, lp["attn"], h, capacity=capacity)
    x = x + attn_out
    out = {"self": cache_l}
    if enc is not None:
        cross_cache = build_cross_cache(cfg, lp["xattn"], enc, cross_cap)
        h = norm_apply(lp["ln_x"], x, cfg.norm)
        x = x + cross_attn_prefill(
            cfg, lp["xattn"], h, cross_cache, cross_len,
            kv_max=min(cross_cap, round_up(enc.shape[1], cfg.lop_block)))
        out["cross"] = cross_cache
    return _mlp(cfg, lp, x), out


def _decoder_layer_decode(cfg, lp, x, cl, lengths, *, use_lop, sp_axes,
                          cross_cl=None, cross_len=None, active=None,
                          k_keep=None):
    x = _shard_batch(x)
    h = norm_apply(lp["ln1"], x, cfg.norm)
    attn_out, new_cl = attn_decode(cfg, lp["attn"], h, cl, lengths,
                                   use_lop=use_lop, sp_axes=sp_axes,
                                   active=active, k_keep=k_keep)
    x = x + attn_out
    if cross_cl is not None:
        h = norm_apply(lp["ln_x"], x, cfg.norm)
        x = x + cross_attn_decode(cfg, lp["xattn"], h, cross_cl, cross_len,
                                  use_lop=use_lop, sp_axes=sp_axes)
    return _mlp(cfg, lp, x), new_cl


def _mamba_layer_prefill(cfg, lp, x):
    x = _shard_batch(x)
    h = norm_apply(lp["ln1"], x, cfg.norm)
    y, state = mamba_forward(cfg, lp["mamba"], h)
    return _mlp(cfg, lp, x + y), state


def _mamba_layer_decode(cfg, lp, x, state):
    x = _shard_batch(x)
    h = norm_apply(lp["ln1"], x, cfg.norm)
    y, state = mamba_decode_step(cfg, lp["mamba"], h, state)
    return _mlp(cfg, lp, x + y), state


def _rwkv_layer(cfg, lp, x, st):
    """Works for both prefill (T=S, zero states in st) and decode (T=1)."""
    x = _shard_batch(x)
    h = norm_apply(lp["ln1"], x, cfg.norm)
    y, x_tm, wkv = rwkv6.rwkv_time_mix(cfg, lp["tm"], h, st["x_tm"],
                                       st["wkv"])
    x = x + y
    h = norm_apply(lp["ln2"], x, cfg.norm)
    y, x_cm = rwkv6.rwkv_channel_mix(cfg, lp["tm"], h, st["x_cm"])
    return x + y, {"wkv": wkv, "x_tm": x_tm, "x_cm": x_cm}


# ===========================================================================
# Drivers
# ===========================================================================

def _embed(cfg, qp, tokens, patches=None):
    x = embedding_apply(qp["embed"], tokens)
    if cfg.family == "vlm" and patches is not None:
        proj = patches.astype(x.dtype) @ qp["projector"]["w"]
        x = jnp.concatenate([proj, x], axis=1)
    return x


def _logits(cfg, qp, x_last):
    x = norm_apply(qp["ln_f"], x_last, cfg.norm)
    return head_apply(qp["head"], x)


def prefill(cfg, qp, tokens, *, frames=None, patches=None, max_len=None,
            use_lop=True, sp_axes=None, cache_align=None, true_len=None):
    """Full-sequence forward writing the cache. → (last logits [B,V], cache).

    ``max_len`` sizes the cache capacity (defaults to the prompt length +
    one decode block of slack); ``cache_align`` aligns capacity for SP
    sharding (must match ``init_cache``'s align).

    ``true_len`` (scalar, may be traced) supports length-bucketed prefill
    compilation: ``tokens`` is right-padded to a bucket length and
    ``true_len`` marks the real sequence end — the cache length is set to
    it and the returned logits come from position ``true_len - 1``. Exact
    for causal-attention families (pad tokens can never attend backward
    into the answer row); recurrent families (hybrid/ssm) must pass
    unpadded prompts since their state integrates every position.
    """
    cfg = resolve_decode_flags(cfg)
    b = tokens.shape[0]
    x = _embed(cfg, qp, tokens, patches)
    s_total = x.shape[1]
    max_len = max(max_len if max_len is not None else 0, s_total)
    cap = round_up(max_len + 1, cache_align or cfg.lop_block)
    if true_len is None:
        true_len = s_total

    if cfg.family in ("dense", "moe", "vlm"):
        def body(x, lp):
            x, out = _decoder_layer_prefill(cfg, lp, x, capacity=cap)
            return x, out["self"]

        x, layers_cache = _layer_scan(body, x, qp["layers"])
        cache = {"lengths": jnp.full((b,), true_len, jnp.int32),
                 "layers": layers_cache}
    elif cfg.family == "hybrid":
        def body(x, bp):
            outs_m = []
            attn_cache = None
            for j in range(cfg.attn_every):
                sub = bp[f"sub{j}"]
                if cfg.is_attn_layer(j):
                    x, out = _decoder_layer_prefill(cfg, sub, x, capacity=cap)
                    attn_cache = out["self"]
                else:
                    x, st = _mamba_layer_prefill(cfg, sub, x)
                    outs_m.append(st)
            stacked = jax.tree.map(lambda *a: jnp.stack(a), *outs_m)
            return x, {"attn": attn_cache, "mamba": stacked}

        x, blocks = _layer_scan(body, x, qp["blocks"])
        cache = {"lengths": jnp.full((b,), true_len, jnp.int32),
                 "blocks": blocks}
    elif cfg.family == "ssm":
        zeros = {
            "wkv": jnp.zeros((b, cfg.n_heads, cfg.hd, cfg.hd), jnp.float32),
            "x_tm": jnp.zeros((b, 1, cfg.d_model), jnp.float32),
            "x_cm": jnp.zeros((b, 1, cfg.d_model), jnp.float32),
        }

        def body(x, lp):
            x, st = _rwkv_layer(cfg, lp, x, zeros)
            return x, st

        x, layers_cache = _layer_scan(body, x, qp["layers"])
        cache = {"lengths": jnp.full((b,), true_len, jnp.int32),
                 "layers": layers_cache}
    elif cfg.family == "encdec":
        assert frames is not None
        enc = frames.astype(jnp.float32)
        enc_cap = round_up(max(cfg.cross_ctx, enc.shape[1]),
                           cache_align or cfg.lop_block)

        def enc_body(e, lp):
            e = _shard_batch(e)
            h = norm_apply(lp["ln1"], e, cfg.norm)
            q, k, v = _project_qkv(cfg, lp["attn"], h)
            qi, qsc = _q(q)
            ki, vi, ksc, vsc, _ = _quantize_kv(k, v)
            o = ops.prefill_attention(
                qi.transpose(0, 2, 1, 3), qsc[..., 0].transpose(0, 2, 1),
                ki, vi, ksc, vsc,
                jnp.full((e.shape[0],), e.shape[1], jnp.int32),
                causal=False, int8_logits=bool(cfg.int8_logits))
            o = o.transpose(0, 2, 1, 3).reshape(e.shape[0], e.shape[1],
                                                cfg.q_dim)
            e = e + qlinear(lp["attn"]["wo"], o)
            return _mlp(cfg, lp, e), None

        enc, _ = _layer_scan(enc_body, enc, qp["enc_layers"])
        enc = norm_apply(qp["ln_enc"], enc, cfg.norm)
        cross_len = jnp.full((b,), enc.shape[1], jnp.int32)

        def body(x, lp):
            x, out = _decoder_layer_prefill(cfg, lp, x, capacity=cap,
                                            enc=enc, cross_cap=enc_cap,
                                            cross_len=cross_len)
            return x, out

        x, outs = _layer_scan(body, x, qp["layers"])
        cache = {"lengths": jnp.full((b,), true_len, jnp.int32),
                 "layers": outs["self"], "cross": outs["cross"],
                 "cross_len": cross_len}
    else:
        raise ValueError(cfg.family)

    x_last = jax.lax.dynamic_index_in_dim(x, true_len - 1, axis=1,
                                          keepdims=False)
    logits = _logits(cfg, qp, x_last)
    return logits, cache


def prefill_chunk(cfg, qp, tokens, cache, *, start, seq_end, patches=None,
                  all_logits=False):
    """One fixed-shape chunk of chunked prefill. → (logits [B,V], cache).

    With ``all_logits=True`` the returned logits are [B, C, V] — one row
    per chunk position — instead of the single ``seq_end - 1`` row. This
    is the speculative-decoding verify call (DESIGN.md
    §Speculative-decoding): the chunk carries [t_last, d_1..d_γ], every
    row is scored exactly through the same fused prefill dispatch that
    decode is bitwise-pinned against, and row i is the target
    distribution for the token after position start+i.

    tokens [B, C] cover global stream positions [start, start+C) (for vlm
    the stream is [image prefix ‖ text] and the first chunk additionally
    carries ``patches``, so its embedded length is n_img + C at
    ``start = 0``). ``cache`` holds every earlier chunk's K/V at
    [0, start); this call writes positions [start, start+C) per layer and
    sets ``lengths = seq_end`` — the true end of the written prompt so
    far, which trails start+C only on a right-padded final chunk. The
    returned logits come from stream position ``seq_end - 1`` and are
    meaningful on the final chunk only (they seed the first decode
    token). ``start`` and ``seq_end`` may be traced, so ONE compile
    serves every chunk index of every prompt at this chunk shape.

    ``start`` need not begin at 0 for a lane's FIRST call: a prefix-cache
    hit (:class:`repro.serving.api.ExistingPrefix`) clones interned pages
    covering [0, start) and resumes here — bitwise the same carry as any
    later chunk, so a hit decodes token-identically to a cold prefill
    (DESIGN.md §Prefix-caching).

    Supported for the causal-attention families whose per-token compute
    is independent of how the prompt is split (dense, vlm, and — router
    caveats aside, DESIGN.md §Chunked-prefill — moe). Recurrent families
    (hybrid/ssm) integrate state over every position and encdec couples
    the compile to the encoder frames; they keep whole-prompt prefill.
    """
    cfg = resolve_decode_flags(cfg)
    if cfg.family not in ("dense", "moe", "vlm"):
        raise ValueError(f"chunked prefill is undefined for family "
                         f"{cfg.family!r} (needs causal attention with "
                         f"split-invariant per-token compute)")
    b = tokens.shape[0]
    x = _embed(cfg, qp, tokens, patches)
    c_total = x.shape[1]
    kv_len = jnp.full((b,), start + c_total, jnp.int32)

    def body(x, inp):
        lp, cl = inp
        x = _shard_batch(x)
        h = norm_apply(lp["ln1"], x, cfg.norm)
        attn_out, ncl = attn_prefill_chunk(cfg, lp["attn"], h, cl,
                                           start=start, kv_len=kv_len)
        return _mlp(cfg, lp, x + attn_out), ncl

    x, layers_cache = _layer_scan(body, x, (qp["layers"], cache["layers"]))
    new_cache = dict(cache)
    new_cache["layers"] = layers_cache
    new_cache["lengths"] = jnp.full((b,), seq_end, jnp.int32)
    if all_logits:
        return _logits(cfg, qp, x), new_cache
    idx = jnp.clip(seq_end - 1 - start, 0, c_total - 1)
    x_last = jax.lax.dynamic_index_in_dim(x, idx, axis=1, keepdims=False)
    logits = _logits(cfg, qp, x_last)
    return logits, new_cache


def serve_step(cfg, qp, cache, tokens, *, use_lop=True, sp_axes=None):
    """One decode step. tokens [B, 1] → (logits [B, V], updated cache).

    A slot-paged pool (``"active"`` in the cache) decodes only live lanes:
    inactive lanes write nothing, keep their ``lengths``, and their logits
    are meaningless (the scheduler never reads them).
    """
    cfg = resolve_decode_flags(cfg)
    lengths = cache["lengths"]
    active = cache.get("active")
    x = _embed(cfg, qp, tokens)
    new_cache = dict(cache)

    if cfg.family in ("dense", "moe", "vlm"):
        def body(x, inp):
            lp, cl = inp
            x, ncl = _decoder_layer_decode(cfg, lp, x, cl, lengths,
                                           use_lop=use_lop, sp_axes=sp_axes,
                                           active=active)
            return x, ncl

        x, layers_cache = _layer_scan(body, x, (qp["layers"],
                                              cache["layers"]))
        new_cache["layers"] = layers_cache
    elif cfg.family == "hybrid":
        def body(x, inp):
            bp, bc = inp
            new_m = []
            mi = 0
            attn_cache = None
            for j in range(cfg.attn_every):
                sub = bp[f"sub{j}"]
                if cfg.is_attn_layer(j):
                    x, attn_cache = _decoder_layer_decode(
                        cfg, sub, x, bc["attn"], lengths, use_lop=use_lop,
                        sp_axes=sp_axes, active=active)
                else:
                    st = jax.tree.map(lambda a: a[mi], bc["mamba"])
                    x, st = _mamba_layer_decode(cfg, sub, x, st)
                    new_m.append(st)
                    mi += 1
            stacked = jax.tree.map(lambda *a: jnp.stack(a), *new_m)
            return x, {"attn": attn_cache, "mamba": stacked}

        x, blocks = _layer_scan(body, x, (qp["blocks"], cache["blocks"]))
        new_cache["blocks"] = blocks
    elif cfg.family == "ssm":
        def body(x, inp):
            lp, st = inp
            x, st = _rwkv_layer(cfg, lp, x, st)
            return x, st

        x, layers_cache = _layer_scan(body, x, (qp["layers"],
                                              cache["layers"]))
        new_cache["layers"] = layers_cache
    elif cfg.family == "encdec":
        def body(x, inp):
            lp, cl, xcl = inp
            x, ncl = _decoder_layer_decode(
                cfg, lp, x, cl, lengths, use_lop=use_lop, sp_axes=sp_axes,
                cross_cl=xcl, cross_len=cache["cross_len"], active=active)
            return x, ncl

        x, layers_cache = _layer_scan(
            body, x, (qp["layers"], cache["layers"], cache["cross"]))
        new_cache["layers"] = layers_cache
    else:
        raise ValueError(cfg.family)

    new_cache["lengths"] = lengths + (1 if active is None
                                      else active.astype(jnp.int32))
    logits = _logits(cfg, qp, x[:, -1])
    return logits, new_cache


def guard_logits(logits, fault_add=None):
    """Fault-injection + detection point of the decode hot path
    (DESIGN.md §Fault-tolerance). Adds a per-lane offset to the logits
    (zeros in production; NaN/inf rows when a
    :mod:`repro.serving.faults` plan is injecting) and computes the
    per-lane finiteness mask in-graph — one cheap reduction, no [B, V]
    host transfer. → (logits [B, V], ok [B] bool). A lane with
    ``ok=False`` must not have its sampled token emitted: the sample of
    a non-finite row is garbage; the scheduler quarantines the lane,
    rewinds its cache append bitwise (``rollback_slot``) and retries
    through the engine's no-LOP recovery step.
    """
    if fault_add is not None:
        logits = logits + fault_add[:, None]
    ok = jnp.all(jnp.isfinite(logits), axis=-1)
    return logits, ok


def draft_step(cfg, qp, cache, tokens, *, draft_layers: int,
               draft_k: int | None = None, use_lop=True):
    """One degraded-cost speculative DRAFT step. tokens [B, 1] →
    (logits [B, V], updated cache).

    The self-speculative predictor (DESIGN.md §Speculative-decoding):
    runs only the first ``draft_layers`` decoder layers — same weights,
    same per-layer cache lanes, same ``_decoder_layer_decode`` body as
    :func:`serve_step` — with the LOP selection budget optionally pinched
    to ``draft_k`` kept blocks, then projects through the SHARED logits
    head. No separate draft model: the truncated stack + sparser screen
    IS the cheap model.

    Cache discipline mirrors ``serve_step``: the drafted token's K/V/
    scale/LOP-feature rows are appended at position ``lengths`` for the
    first ``draft_layers`` layers only and ``lengths`` advances per
    active lane — provisional state that the verify call
    (:func:`prefill_chunk` with ``all_logits=True``) OVERWRITES for every
    layer at those same positions, and
    :func:`repro.serving.cache.rollback_slot` rewinds for rejected
    tokens. Between draft and verify the cache is transiently
    inconsistent (layers ≥ draft_layers hold zeros at the drafted
    positions); the scheduler never reads it in that window.

    Dense/vlm only — the families that declare ``supports_speculative``
    (a truncated scan needs a uniform causal layer stack, and the verify
    side needs chunked prefill).
    """
    cfg = resolve_decode_flags(cfg)
    if cfg.family not in ("dense", "vlm"):
        raise ValueError(f"speculative draft is undefined for family "
                         f"{cfg.family!r} (needs a uniform causal layer "
                         f"stack and a chunked-prefill verify path)")
    lengths = cache["lengths"]
    active = cache.get("active")
    x = _embed(cfg, qp, tokens)
    new_cache = dict(cache)
    full_layers = cache["layers"]
    head_qp = jax.tree.map(lambda a: a[:draft_layers], qp["layers"])
    head_cl = jax.tree.map(lambda a: a[:draft_layers], full_layers)

    def body(x, inp):
        lp, cl = inp
        x, ncl = _decoder_layer_decode(cfg, lp, x, cl, lengths,
                                       use_lop=use_lop, sp_axes=None,
                                       active=active, k_keep=draft_k)
        return x, ncl

    x, upd = _layer_scan(body, x, (head_qp, head_cl))
    new_cache["layers"] = jax.tree.map(
        lambda u, f: jnp.concatenate([u, f[draft_layers:]], axis=0),
        upd, full_layers)
    new_cache["lengths"] = lengths + (1 if active is None
                                      else active.astype(jnp.int32))
    logits = _logits(cfg, qp, x[:, -1])
    return logits, new_cache
