"""Convert trained master weights → the serving (deployment) format.

Every eligible projection becomes the TINT stream format: packed 2-bit
ternary codes (4 weights/byte in HBM) + one absmean scale γ — the paper's
~8× weight-memory reduction vs bf16. Embedding/head/norms/router/conv/SSM
tensors stay high-precision (BitNet's convention), as do projections whose
reduction dim is too small to pack (< 4-aligned, e.g. Mamba's tiny dt_proj
in reduced configs).

Stacked layer weights [L, k, n] pack to [L, k//4, n] (scale [L, 1, 1]) so
the serving stack still scans. Packed dicts carry no static shape metadata
(ints would become scan-traced leaves); ``k`` is re-derived from
``packed.shape`` at apply time (see :mod:`repro.core.qlinear`).
"""

from __future__ import annotations

import jax

from repro.core.qlinear import is_packed, qlinear, qlinear_expert  # noqa: F401 (re-export)
from repro.core.ternary import pack_ternary, ternary_quantize

# param-path names that stay high-precision even when 2-D
_KEEP_FP = ("head", "projector", "router", "mu", "mu_c", "u",
            "A_log", "D", "conv_w", "conv_b", "w_base", "ln_x", "table")
_EXPERT_NAMES = ("w_gate", "w_up", "w_down")


def _quantize_linear(w: jax.Array):
    """w [..., k, n] f32 → {"packed": uint8 [..., k//4, n], "scale": f32}."""
    lead = w.shape[:-2]
    k, n = w.shape[-2:]
    w2 = w.reshape(-1, k, n)

    def one(wi):
        wt, gamma = ternary_quantize(wi)
        return pack_ternary(wt), gamma.reshape(())

    packed, scale = jax.vmap(one)(w2)
    return {"packed": packed.reshape(*lead, k // 4, n),
            "scale": scale.reshape(*lead, 1, 1)}


def _eligible(name: str, k: int, quant: str) -> bool:
    return quant == "ternary" and name not in _KEEP_FP and k % 4 == 0 and k >= 16


def quantize_params(cfg, params):
    """Training param tree → serving tree (same structure, linears packed)."""
    def walk(path, node):
        if isinstance(node, dict):
            if "w" in node and not isinstance(node["w"], dict):
                name = path[-1] if path else ""
                if _eligible(name, node["w"].shape[-2], cfg.quant):
                    out = _quantize_linear(node["w"])
                    if "b" in node:
                        out["b"] = node["b"]
                    return out
                return dict(node)
            return {key: walk(path + (key,), val)
                    for key, val in node.items()}
        # raw arrays: MoE expert stacks [L, E, k, n] quantize as well
        if (node.ndim >= 2 and path and path[-1] in _EXPERT_NAMES
                and _eligible(path[-1], node.shape[-2], cfg.quant)):
            return _quantize_linear(node)
        return node

    return walk((), params)
