"""Convert trained master weights → the serving (deployment) format.

Every eligible projection becomes the TINT stream format: packed 2-bit
ternary codes (4 weights/byte in HBM) + one absmean scale γ — the paper's
~8× weight-memory reduction vs bf16. Embedding/head/norms/router/conv/SSM
tensors stay high-precision (BitNet's convention), as do projections whose
reduction dim is too small to pack (< 4-aligned, e.g. Mamba's tiny dt_proj
in reduced configs).

Projection-group fusion (DESIGN.md §TINT-projection-fusion)
-----------------------------------------------------------
Deployment is also where projection groups fuse into single packed
weights so one kernel dispatch replaces several:

  * self-attention ``{"wq","wk","wv","wo"}`` → ``{"wqkv", "wo"}`` — the
    QKV codes concatenate along the output axis; the node's ``scale`` is
    a per-column γ row (each column keeps its own projection's scalar γ,
    so the fused dequant is bitwise the per-projection dequant),
  * cross-attention ``xattn`` → ``{"wq", "wkv", "wo"}`` (K and V both
    consume the encoder memory; Q consumes the decoder stream, so it
    stays its own dispatch),
  * FFN ``{"w_gate","w_up","w_down"}`` → ``{"gu_packed", "gu_scale",
    "down_packed", "down_scale"}`` — gate‖up codes share one stream and
    the down projection rides the SAME launch
    (:func:`repro.kernels.ops.ffn_fused`), hidden state never touching
    HBM,
  * MoE expert stacks [E, k, n] fuse the same way with a leading expert
    axis — the whole MoE layer's expert FFNs are ONE grouped dispatch.

``fuse=False`` keeps the legacy one-node-per-projection format (every
consumer still accepts it) — the dispatch-count baseline in
benchmarks/kernels_micro.py and the fused-vs-unfused equivalence tests.

Stacked layer weights [L, k, n] pack to [L, k//4, n] (scale [L, 1, 1]) so
the serving stack still scans. Packed dicts carry no static shape metadata
(ints would become scan-traced leaves); ``k`` and segment widths are
re-derived from ``packed.shape`` / the config at apply time (see
:mod:`repro.core.qlinear`).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.qlinear import (is_fused_ffn, is_packed, qlinear,  # noqa: F401 (re-export)
                                qlinear_expert)
from repro.core.ternary import pack_ternary, ternary_quantize

# param-path names that stay high-precision even when 2-D
_KEEP_FP = ("head", "projector", "router", "mu", "mu_c", "u",
            "A_log", "D", "conv_w", "conv_b", "w_base", "ln_x", "table")
_EXPERT_NAMES = ("w_gate", "w_up", "w_down")


def _quantize_linear(w: jax.Array):
    """w [..., k, n] f32 → {"packed": uint8 [..., k//4, n], "scale": f32}."""
    lead = w.shape[:-2]
    k, n = w.shape[-2:]
    w2 = w.reshape(-1, k, n)

    def one(wi):
        wt, gamma = ternary_quantize(wi)
        return pack_ternary(wt), gamma.reshape(())

    packed, scale = jax.vmap(one)(w2)
    out = {"packed": packed.reshape(*lead, k // 4, n),
           "scale": scale.reshape(*lead, 1, 1)}
    _check_packed(out, k)
    return out


def _check_packed(node, k: int) -> None:
    """Deployment-format invariants the fused kernels rely on: packed k
    is 4-aligned uint8 codes; scales are one scalar γ per code stream
    (a fused node broadcasts those scalars to a per-column row)."""
    n = node["packed"].shape[-1]
    assert node["packed"].dtype == jnp.uint8, node["packed"].dtype
    assert k % 4 == 0 and node["packed"].shape[-2] * 4 == k, \
        (node["packed"].shape, k)
    s = node["scale"]
    assert s.dtype == jnp.float32 and s.shape[-2] == 1 \
        and s.shape[-1] in (1, n), (s.dtype, s.shape, n)


def _concat_packed(parts):
    """Per-projection packed nodes → one fused node, γ per column."""
    packed = jnp.concatenate([p["packed"] for p in parts], axis=-1)
    scale = jnp.concatenate(
        [jnp.broadcast_to(p["scale"],
                          p["scale"].shape[:-1] + (p["packed"].shape[-1],))
         for p in parts], axis=-1)
    out = {"packed": packed, "scale": scale}
    _check_packed(out, packed.shape[-2] * 4)
    return out


def _eligible(name: str, k: int, quant: str) -> bool:
    return quant == "ternary" and name not in _KEEP_FP and k % 4 == 0 and k >= 16


def _quantize_node(name: str, node, quant: str):
    """One training linear dict {"w", "b"?} → serving node (packed or fp)."""
    if _eligible(name, node["w"].shape[-2], quant):
        out = _quantize_linear(node["w"])
        if "b" in node:
            out["b"] = node["b"]
        return out
    return dict(node)


def _fuse_attn(node, quant: str, fuse_q: bool):
    """Attention dict → fused serving dict, or None when ineligible."""
    names = ("wq", "wk", "wv") if fuse_q else ("wk", "wv")
    subs = [node.get(nm) for nm in names]
    if not all(isinstance(s, dict) and "w" in s
               and not isinstance(s["w"], dict) for s in subs):
        return None
    k = subs[0]["w"].shape[-2]
    if not all(s["w"].shape[-2] == k and _eligible(nm, k, quant)
               for nm, s in zip(names, subs)):
        return None
    has_b = ["b" in s for s in subs]
    if any(has_b) != all(has_b):
        return None
    fused = _concat_packed([_quantize_linear(s["w"]) for s in subs])
    if all(has_b):
        fused["b"] = jnp.concatenate([s["b"] for s in subs], axis=-1)
    out = {("wqkv" if fuse_q else "wkv"): fused}
    if not fuse_q:
        out["wq"] = _quantize_node("wq", node["wq"], quant)
    out["wo"] = _quantize_node("wo", node["wo"], quant)
    return out


def _fuse_ffn(node, quant: str):
    """FFN dict (dense {"w_*": {"w"}} or MoE raw [E, k, n] stacks + router)
    → whole-FFN serving node, or None when ineligible."""
    def _w(nm):
        sub = node.get(nm)
        if isinstance(sub, dict):
            return sub["w"] if "w" in sub and "b" not in sub else None
        return sub
    wu, wd = _w("w_up"), _w("w_down")
    if wu is None or wd is None:
        return None
    gated = "w_gate" in node
    wg = _w("w_gate") if gated else None
    if gated and wg is None:
        return None
    d, f = wu.shape[-2], wd.shape[-2]
    if not (_eligible("w_up", d, quant) and _eligible("w_down", f, quant)
            and wu.shape[-1] == f and (not gated or wg.shape[-2:] ==
                                       wu.shape[-2:])):
        return None
    parts = ([_quantize_linear(wg)] if gated else []) \
        + [_quantize_linear(wu)]
    gu = _concat_packed(parts)
    down = _quantize_linear(wd)
    out = {key: val for key, val in node.items() if key not in
           ("w_gate", "w_up", "w_down")}           # router etc. stay fp
    out.update({"gu_packed": gu["packed"], "gu_scale": gu["scale"],
                "down_packed": down["packed"],
                "down_scale": down["scale"]})
    return out


def quantize_params(cfg, params, *, fuse: bool = True):
    """Training param tree → serving tree (same structure, linears packed).

    ``fuse=True`` (the default) additionally fuses projection groups —
    QKV / cross-KV / gate·up·down / grouped experts — into single packed
    streams so each group is one kernel dispatch (module docstring).
    """
    def walk(path, node):
        if isinstance(node, dict):
            name = path[-1] if path else ""
            if fuse and name in ("attn", "xattn"):
                fused = _fuse_attn(node, cfg.quant, fuse_q=name == "attn")
                if fused is not None:
                    # unrecognized attention extras (q/k norms, sinks, …)
                    # walk through unchanged-structure quantization
                    out = {key: walk(path + (key,), val)
                           for key, val in node.items()
                           if key not in ("wq", "wk", "wv", "wo")}
                    out.update(fused)
                    return out
            if fuse and "w_up" in node and "w_down" in node:
                fused = _fuse_ffn(node, cfg.quant)
                if fused is not None:
                    return fused
            if "w" in node and not isinstance(node["w"], dict):
                return _quantize_node(path[-1] if path else "", node,
                                      cfg.quant)
            return {key: walk(path + (key,), val)
                    for key, val in node.items()}
        # raw arrays: MoE expert stacks [L, E, k, n] quantize as well
        if (node.ndim >= 2 and path and path[-1] in _EXPERT_NAMES
                and _eligible(path[-1], node.shape[-2], cfg.quant)):
            return _quantize_linear(node)
        return node

    return walk((), params)
