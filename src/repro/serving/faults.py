"""Deterministic fault injection for the serving stack.

Real edge serving treats degraded operation as a first-class mode: logits
go non-finite (overflowed accumulators, bad DMA), cache pages rot, a
remote prefix store times out, a step stalls. This module makes every one
of those failure modes *reproducible in CI*: a frozen, seeded
:class:`FaultPlan` names exactly which engine call / lane / store
operation misbehaves, and :func:`inject` activates it for a scoped region
of code. The detection + recovery machinery it exercises lives in
:mod:`repro.serving.scheduler` (NaN guard → ``rollback_slot`` → no-LOP
retry), :mod:`repro.serving.cache` (per-page checksums → cold-prefill
fallback) and :mod:`repro.serving.api` (the injection points themselves)
— DESIGN.md §Fault-tolerance.

Injection is keyed by *call counters*, not wall time: the N-th
``decode_step`` dispatch, the N-th store insert, the N-th store lookup.
Two runs of the same request trace under the same plan therefore inject
at identical points, which is what makes the chaos test's bitwise
determinism assertion possible.

No plan active (the default) costs one ``is None`` check per injection
point — the production path stays untouched.
"""

from __future__ import annotations

import time
from contextlib import contextmanager
from dataclasses import dataclass, field

import numpy as np


class PrefixLookupError(RuntimeError):
    """An injected prefix-store lookup failure (a store outage). The
    scheduler degrades the request to a cold prefill and counts it."""


@dataclass(frozen=True)
class FaultPlan:
    """One deterministic failure schedule.

    ``nan_logits``      {(decode_call, lane)}: that lane's decode logits
                        go non-finite on that engine dispatch (a
                        transient corruption — the no-LOP retry
                        recomputes it cleanly).
    ``sticky_nan_lanes`` {lane}: that lane's logits are non-finite on
                        EVERY dispatch including the recovery retry, so
                        the lane finishes with reason ``"fault"``.
    ``page_bitflips``   {insert_call}: the prefix-store node interned by
                        that ``PrefixStore.insert`` call gets one bit
                        flipped in its pages AFTER its checksum is taken
                        (post-intern rot — the checksum catches it at the
                        next match).
    ``lookup_failures`` {match_call}: that ``PrefixStore.match`` call
                        raises :class:`PrefixLookupError`.
    ``slow_steps``      {decode_call}: that decode dispatch sleeps
                        ``slow_s`` seconds first (deadline pressure).
    """
    seed: int = 0
    nan_logits: frozenset = frozenset()
    sticky_nan_lanes: frozenset = frozenset()
    page_bitflips: frozenset = frozenset()
    lookup_failures: frozenset = frozenset()
    slow_steps: frozenset = frozenset()
    slow_s: float = 0.0

    @staticmethod
    def random(seed: int, *, n_decode_calls: int, n_lanes: int,
               nan_events: int = 2, sticky_lanes: int = 0,
               page_flips: int = 1, lookup_fails: int = 1,
               slow_steps: int = 0, slow_s: float = 0.0) -> "FaultPlan":
        """A seeded random plan over a trace of ``n_decode_calls``
        batched decode dispatches — same seed, same plan, bit for bit."""
        rng = np.random.default_rng(seed)

        def pick(n, hi):
            n = min(n, hi)
            return frozenset(int(x) for x in
                             rng.choice(hi, size=n, replace=False)) \
                if n > 0 and hi > 0 else frozenset()

        nan = frozenset(
            (int(c), int(rng.integers(0, n_lanes)))
            for c in rng.choice(max(1, n_decode_calls),
                                size=min(nan_events, n_decode_calls),
                                replace=False)) if nan_events else frozenset()
        return FaultPlan(
            seed=seed, nan_logits=nan,
            sticky_nan_lanes=pick(sticky_lanes, n_lanes),
            page_bitflips=pick(page_flips, 8),
            lookup_failures=pick(lookup_fails, 16),
            slow_steps=pick(slow_steps, max(1, n_decode_calls)),
            slow_s=slow_s)


@dataclass
class _FaultState:
    """Mutable per-``inject`` bookkeeping: call counters + telemetry."""
    plan: FaultPlan
    decode_calls: int = 0
    insert_calls: int = 0
    match_calls: int = 0
    injected_nan: int = 0
    injected_flips: int = 0
    injected_lookup_failures: int = 0
    injected_slow: int = 0


_STATE: _FaultState | None = None


def active() -> FaultPlan | None:
    """The plan in scope, or None (the production fast path)."""
    return _STATE.plan if _STATE is not None else None


def state() -> _FaultState | None:
    """Injection telemetry for the current scope (tests/benchmarks)."""
    return _STATE


@contextmanager
def inject(plan: FaultPlan):
    """Activate ``plan`` for the enclosed serve trace. Re-entrant use is
    rejected — nested plans would make call counters ambiguous."""
    global _STATE
    assert _STATE is None, "fault plans do not nest"
    _STATE = _FaultState(plan)
    try:
        yield _STATE
    finally:
        _STATE = None


# ---------------------------------------------------------------------------
# Injection points (called by PooledEngine / PrefixStore)
# ---------------------------------------------------------------------------


def decode_fault_add(n_lanes: int):
    """Per-lane logit offset for the NEXT batched decode dispatch, or
    None when no plan is active. Advances the decode-call counter and
    sleeps the planned slow-step delay. NaN rows mark injected faults —
    the engine adds the vector to the logits before sampling, and the
    in-graph finiteness guard (``repro.serving.engine.guard_logits``)
    reports them to the scheduler."""
    st = _STATE
    if st is None:
        return None
    call = st.decode_calls
    st.decode_calls += 1
    if call in st.plan.slow_steps and st.plan.slow_s > 0:
        st.injected_slow += 1
        time.sleep(st.plan.slow_s)
    add = np.zeros((n_lanes,), np.float32)
    for lane in st.plan.sticky_nan_lanes:
        if lane < n_lanes:
            add[lane] = np.nan
            st.injected_nan += 1
    for (c, lane) in st.plan.nan_logits:
        if c == call and lane < n_lanes:
            add[lane] = np.nan
            st.injected_nan += 1
    return add


def retry_fault_add(n_lanes: int):
    """Logit offset for a RECOVERY retry dispatch: only sticky lanes stay
    faulted (the transient (call, lane) events never re-fire — the retry
    recomputes clean), so a sticky lane's retry also fails and the lane
    finishes with reason ``"fault"``. Does not advance call counters."""
    st = _STATE
    if st is None or not st.plan.sticky_nan_lanes:
        return None
    add = np.zeros((n_lanes,), np.float32)
    for lane in st.plan.sticky_nan_lanes:
        if lane < n_lanes:
            add[lane] = np.nan
    return add


def page_corruption_rng():
    """For the NEXT ``PrefixStore.insert`` call: a seeded Generator to
    pick the flipped bit with, or None. Advances the insert counter."""
    st = _STATE
    if st is None:
        return None
    call = st.insert_calls
    st.insert_calls += 1
    if call not in st.plan.page_bitflips:
        return None
    st.injected_flips += 1
    return np.random.default_rng((st.plan.seed, call))


def lookup_fails() -> bool:
    """Whether the NEXT ``PrefixStore.match`` call should raise
    :class:`PrefixLookupError`. Advances the match counter."""
    st = _STATE
    if st is None:
        return False
    call = st.match_calls
    st.match_calls += 1
    if call in st.plan.lookup_failures:
        st.injected_lookup_failures += 1
        return True
    return False
