"""Distributed runtime: partitioning rules, collectives, fault tolerance."""

from repro.distributed.partitioning import (current_mesh, dp_axes, fsdp_axes,
                                            logical_to_pspec, shard,
                                            tree_pspecs, use_mesh)
