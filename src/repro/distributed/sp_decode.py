"""Sequence-parallel LOP decode attention (shard_map core).

The production decode path (DESIGN.md §5): the KV/feature cache's token axis
is sharded across the ``model`` axis (SP), because GQA kv-head counts (8, 12,
40…) don't divide a 16-way axis but block-aligned token shards always do.

Per M-shard, each rank:
  1. writes the new token if it owns position ``lengths[b]``,
  2. runs the LOP screen over its LOCAL 4-bit feature shard,
  3. selects a local **quota** of ⌈K/nshards⌉ candidate blocks with the
     comparison-free selector (beyond-paper adaptation: per-shard quotas
     keep selection collective-free and perfectly load-balanced — every
     rank gathers the same number of blocks, so no stragglers),
  4. computes *unnormalized* softmax stats (m, ℓ, acc) over its candidates,
  5. merges stats across shards flash-decoding style (pmax + psum).

Total candidates = nshards·⌈K/nshards⌉ ≈ K; recall vs the paper's global
top-K is validated in tests/test_distributed.py.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.lop import pot
from repro.core.qlinear import is_packed  # noqa: F401 (doc cross-ref)
from repro.distributed.partitioning import current_mesh, dp_axes, shard_map
from repro.serving.lop_select import (k_keep_blocks, select_blocks,
                                      token_valid_mask)

NEG_INF = -1e30


def _screen_local(qi, feat):
    """qi int8 [B,Hkv,G,dh]; feat uint8 [B,Hkv,M_loc,dh//2] → int32 scores."""
    from repro.kernels import ops
    return jax.vmap(jax.vmap(ops.lop_screen))(qi, feat)


def _gather_blocks(arr, idx, block):
    """arr [B,Hkv,M,...] , idx [B,Hkv,G,K] → [B,Hkv,G,K*block,...]."""
    b, hkv, m = arr.shape[:3]
    k = idx.shape[-1]
    blocks = arr.reshape(b, hkv, m // block, block, *arr.shape[3:])

    def per_bh(blocks_bh, idx_bh):                       # [NB,block,...],[G,K]
        return blocks_bh[idx_bh]                         # [G,K,block,...]

    out = jax.vmap(jax.vmap(per_bh))(blocks, idx)
    return out.reshape(b, hkv, idx.shape[2], k * block, *arr.shape[3:])


def _sparse_stats(cfg, qi, qsc, cl, idx, gate_tokens, block, g: int):
    """Unnormalized softmax stats over the selected candidate blocks.

    idx/gate_tokens have G'=G (per-q-head, paper-faithful) or G'=1
    (group-shared selection — one gather per KV head).
    → m [B,Hkv,G,1], l [B,Hkv,G,1], acc [B,Hkv,G,dh].
    """
    b, hkv, gsel, dh = (*idx.shape[:3], cl["k"].shape[-1])
    k = idx.shape[-1]
    sm = dh ** -0.5
    k_sel = _gather_blocks(cl["k"], idx, block)          # [B,Hkv,G',K*bl,dh]
    v_sel = _gather_blocks(cl["v"], idx, block)
    ks_sel = _gather_blocks(cl["k_scale"], idx, block)   # [B,Hkv,G',K*bl]
    vs_sel = _gather_blocks(cl["v_scale"], idx, block)

    qg = qi.reshape(b, hkv, g, dh)
    if gsel == 1:
        s = jnp.einsum("bhgd,bhkd->bhgk", qg, k_sel[:, :, 0],
                       preferred_element_type=jnp.int32).astype(jnp.float32)
        s = s * qsc.reshape(b, hkv, g, 1) * ks_sel[:, :, 0][:, :, None] * sm
    else:
        s = jnp.einsum("bhgd,bhgkd->bhgk", qg, k_sel,
                       preferred_element_type=jnp.int32).astype(jnp.float32)
        s = s * qsc.reshape(b, hkv, g, 1) * ks_sel * sm

    gate = gate_tokens[..., :k] > 0                      # [B,Hkv,G',K]
    end = gate_tokens[..., k:2 * k]
    start = gate_tokens[..., 2 * k:]
    t = jnp.arange(block)[None, None, None, None, :]
    live = ((t >= start[..., None]) & (t < end[..., None])
            & gate[..., None])                           # [B,Hkv,G',K,block]
    live = live.reshape(b, hkv, gsel, k * block)   # broadcasts when G'=1
    s = jnp.where(live, s, NEG_INF)

    m = jnp.max(s, axis=-1, keepdims=True)
    m_safe = jnp.maximum(m, -1e29)                       # all-masked shards
    p = jnp.exp(s - m_safe)
    p = jnp.where(s <= NEG_INF / 2, 0.0, p)
    l = jnp.sum(p, axis=-1, keepdims=True)
    vf = v_sel.astype(jnp.float32) * vs_sel[..., None]
    if gsel == 1:
        acc = jnp.einsum("bhgk,bhkd->bhgd", p, vf[:, :, 0])
    else:
        acc = jnp.einsum("bhgk,bhgkd->bhgd", p, vf)
    return m, l, acc


def _dense_stats(cfg, qi, qsc, cl, new_len, window, offset):
    """No-LOP baseline: stats over the full local M shard."""
    b, hkv, m, dh = cl["k"].shape
    g = qi.shape[1] // hkv
    sm = dh ** -0.5
    qg = qi.reshape(b, hkv, g, dh)
    s = jnp.einsum("bhgd,bhmd->bhgm", qg, cl["k"],
                   preferred_element_type=jnp.int32).astype(jnp.float32)
    s = s * qsc.reshape(b, hkv, g, 1) * cl["k_scale"][:, :, None, :] * sm
    valid = token_valid_mask(m, new_len, window, pos_offset=offset)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    mx = jnp.max(s, axis=-1, keepdims=True)
    m_safe = jnp.maximum(mx, -1e29)
    p = jnp.exp(s - m_safe)
    p = jnp.where(s <= NEG_INF / 2, 0.0, p)
    l = jnp.sum(p, axis=-1, keepdims=True)
    vf = cl["v"].astype(jnp.float32) * cl["v_scale"][..., None]
    acc = jnp.einsum("bhgm,bhmd->bhgd", p, vf)
    return mx, l, acc


def _write_token_local(cl, ki, vi, ksc, vsc, feat, lengths, offset, m_loc,
                       active=None):
    """Masked per-rank cache append (only the owner shard writes; retired
    slot-pool lanes never write)."""
    local = lengths - offset                              # [B]
    ok = (local >= 0) & (local < m_loc)
    if active is not None:
        ok &= active
    pos = jnp.clip(local, 0, m_loc - 1)

    def wr(arr, val, p_, ok_):
        # arr [Hkv, M, d]; val [Hkv, d]
        upd = jax.lax.dynamic_update_slice(
            arr, val[:, None], (0, p_) + (0,) * (arr.ndim - 2))
        return jnp.where(ok_, upd, arr)

    def wr_scale(arr, val, p_, ok_):
        upd = jax.lax.dynamic_update_slice(arr, val[:, None], (0, p_))
        return jnp.where(ok_, upd, arr)

    cl = dict(cl)
    cl["k"] = jax.vmap(wr)(cl["k"], ki, pos, ok)
    cl["v"] = jax.vmap(wr)(cl["v"], vi, pos, ok)
    cl["feat"] = jax.vmap(wr)(cl["feat"], feat, pos, ok)
    cl["k_scale"] = jax.vmap(wr_scale)(cl["k_scale"], ksc[..., 0], pos, ok)
    cl["v_scale"] = jax.vmap(wr_scale)(cl["v_scale"], vsc[..., 0], pos, ok)
    return cl


def sp_decode_attention(cfg, qi, qsc, ki, vi, ksc, vsc, feat, cl, lengths, *,
                        window: int, use_lop: bool, sp_axes: tuple,
                        write: bool = True, active=None):
    """SP decode attention over an M-sharded cache layer.

    qi int8 [B, H, dh]; qsc [B, H, 1]; ki/vi int8 [B, Hkv, dh] (new token);
    cl cache layer (token axis sharded over ``sp_axes``); lengths [B];
    active [B] bool (slot-pool lanes; None = all live).
    → (out f32 [B, H, dh], new cache layer).
    """
    mesh = current_mesh()
    assert mesh is not None, "sp decode requires an active mesh"
    b, h, dh = qi.shape
    if active is None:
        active = jnp.ones((b,), jnp.bool_)
    hkv = cl["k"].shape[1]
    m_global = cl["k"].shape[2]
    nshards = math.prod(int(mesh.shape[a]) for a in sp_axes)
    m_loc = m_global // nshards
    block = cfg.lop_block
    k_keep = max(1, -(-k_keep_blocks(cfg, m_global) // nshards))  # quota

    bdp = dp_axes(mesh)
    batch_ax = bdp if b % math.prod(int(mesh.shape[a]) for a in bdp) == 0 \
        else None
    # leftover dp axes that are not consumed by batch or M sharding stay
    # replicated inside the region
    cache_spec = {
        "k": P(batch_ax, None, sp_axes, None),
        "v": P(batch_ax, None, sp_axes, None),
        "k_scale": P(batch_ax, None, sp_axes),
        "v_scale": P(batch_ax, None, sp_axes),
        "feat": P(batch_ax, None, sp_axes, None),
    }
    rep2 = P(batch_ax, None, None)
    rep1 = P(batch_ax)

    def body(qi, qsc, ki, vi, ksc, vsc, feat_new, cl, lengths, act):
        # shard rank along the sp axes → global token offset of this shard
        ridx = jnp.int32(0)
        for a in sp_axes:
            ridx = ridx * mesh.shape[a] + jax.lax.axis_index(a)
        offset = ridx * m_loc
        if write:
            cl = _write_token_local(cl, ki, vi, ksc, vsc, feat_new, lengths,
                                    offset, m_loc, active=act)
        new_len = lengths + (1 if write else 0)
        # retired lanes see an empty cache (nothing valid to screen/select)
        new_len = jnp.where(act, new_len, 0)

        if use_lop:
            import os
            qg = qi.reshape(qi.shape[0], hkv, h // hkv, dh)
            scores = _screen_local(qg, cl["feat"])
            if os.environ.get("REPRO_GQA_SHARED_SELECT") == "1":
                scores = jnp.max(scores, axis=2, keepdims=True)
            idx, gate_tokens = select_blocks(
                scores, new_len, block=block, k_keep=k_keep, window=window,
                block_offset=offset // block)
            m, l, acc = _sparse_stats(cfg, qi, qsc, cl, idx, gate_tokens,
                                      block, g=h // hkv)
        else:
            m, l, acc = _dense_stats(cfg, qi, qsc, cl, new_len, window,
                                     offset)

        # flash-decoding merge across M shards
        m_g = jax.lax.pmax(m, sp_axes)
        w = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * w, sp_axes)
        acc_g = jax.lax.psum(acc * w, sp_axes)
        out = acc_g / jnp.maximum(l_g, 1e-20)
        return out.reshape(qi.shape[0], h, dh), cl

    new_tok_spec2 = P(batch_ax, None, None)
    in_specs = (new_tok_spec2, new_tok_spec2, new_tok_spec2, new_tok_spec2,
                new_tok_spec2, new_tok_spec2,
                new_tok_spec2 if feat is not None else None,
                cache_spec, rep1, rep1)
    out_specs = (rep2, cache_spec)

    if not write:
        # cross-attention: no new token operands
        def body_nw(qi, qsc, cl, lengths, act):
            return body(qi, qsc, None, None, None, None, None, cl, lengths,
                        act)

        fn = shard_map(body_nw, mesh=mesh,
                           in_specs=(new_tok_spec2, new_tok_spec2,
                                     cache_spec, rep1, rep1),
                           out_specs=out_specs, check_vma=False)
        return fn(qi, qsc, cl, lengths, active)

    fn = shard_map(body, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False)
    return fn(qi, qsc, ki, vi, ksc, vsc, feat, cl, lengths, active)
