"""Sequence-parallel LOP decode attention (shard_map core).

The production decode path (DESIGN.md §5): the KV/feature cache's token axis
is sharded across the ``model`` axis (SP), because GQA kv-head counts (8, 12,
40…) don't divide a 16-way axis but block-aligned token shards always do.

Per M-shard, each rank:
  1. writes the new token if it owns position ``lengths[b]``,
  2. runs the SAME fused decode kernel as the local path
     (:func:`repro.kernels.ops.decode_attention`) over its local shard,
     passing ``pos_offset = rank · M_local`` so validity masking and the
     candidate live-intervals land on global token positions, and a local
     **quota** of ⌈K/nshards⌉ candidate blocks (beyond-paper adaptation:
     per-shard quotas keep selection collective-free and perfectly
     load-balanced — every rank gathers the same number of blocks, so no
     stragglers),
  3. merges the kernel's *unnormalized* softmax stats (m, ℓ, out·ℓ) across
     shards flash-decoding style (pmax + psum).

The screen → select → exact pipeline itself is not duplicated here — it
lives once, inside the fused kernel / its jnp oracle (DESIGN.md
§Fused-decode-kernel). Total candidates = nshards·⌈K/nshards⌉ ≈ K; recall
vs the paper's global top-K is validated in tests/test_distributed.py.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import resolve_decode_flags
from repro.core.qlinear import is_packed  # noqa: F401 (doc cross-ref)
from repro.distributed.partitioning import current_mesh, dp_axes, shard_map
from repro.kernels import ops
from repro.serving.lop_select import k_keep_blocks


def _write_token_local(cl, ki, vi, ksc, vsc, feat, lengths, offset, m_loc,
                       active=None):
    """Masked per-rank cache append (only the owner shard writes; retired
    slot-pool lanes never write)."""
    local = lengths - offset                              # [B]
    ok = (local >= 0) & (local < m_loc)
    if active is not None:
        ok &= active
    pos = jnp.clip(local, 0, m_loc - 1)

    def wr(arr, val, p_, ok_):
        # arr [Hkv, M, d]; val [Hkv, d]
        upd = jax.lax.dynamic_update_slice(
            arr, val[:, None], (0, p_) + (0,) * (arr.ndim - 2))
        return jnp.where(ok_, upd, arr)

    def wr_scale(arr, val, p_, ok_):
        upd = jax.lax.dynamic_update_slice(arr, val[:, None], (0, p_))
        return jnp.where(ok_, upd, arr)

    cl = dict(cl)
    cl["k"] = jax.vmap(wr)(cl["k"], ki, pos, ok)
    cl["v"] = jax.vmap(wr)(cl["v"], vi, pos, ok)
    cl["feat"] = jax.vmap(wr)(cl["feat"], feat, pos, ok)
    cl["k_scale"] = jax.vmap(wr_scale)(cl["k_scale"], ksc[..., 0], pos, ok)
    cl["v_scale"] = jax.vmap(wr_scale)(cl["v_scale"], vsc[..., 0], pos, ok)
    return cl


def sp_decode_attention(cfg, qi, qsc, ki, vi, ksc, vsc, feat, cl, lengths, *,
                        window: int, use_lop: bool, sp_axes: tuple,
                        write: bool = True, active=None):
    """SP decode attention over an M-sharded cache layer.

    qi int8 [B, H, dh]; qsc [B, H, 1]; ki/vi int8 [B, Hkv, dh] (new token);
    cl cache layer (token axis sharded over ``sp_axes``); lengths [B];
    active [B] bool (slot-pool lanes; None = all live).
    → (out f32 [B, H, dh], new cache layer).
    """
    mesh = current_mesh()
    assert mesh is not None, "sp decode requires an active mesh"
    cfg = resolve_decode_flags(cfg)
    b, h, dh = qi.shape
    if active is None:
        active = jnp.ones((b,), jnp.bool_)
    m_global = cl["k"].shape[2]
    nshards = math.prod(int(mesh.shape[a]) for a in sp_axes)
    m_loc = m_global // nshards
    block = cfg.lop_block
    k_keep = max(1, -(-k_keep_blocks(cfg, m_global) // nshards))  # quota

    bdp = dp_axes(mesh)
    batch_ax = bdp if b % math.prod(int(mesh.shape[a]) for a in bdp) == 0 \
        else None
    # leftover dp axes that are not consumed by batch or M sharding stay
    # replicated inside the region
    cache_spec = {
        "k": P(batch_ax, None, sp_axes, None),
        "v": P(batch_ax, None, sp_axes, None),
        "k_scale": P(batch_ax, None, sp_axes),
        "v_scale": P(batch_ax, None, sp_axes),
        "feat": P(batch_ax, None, sp_axes, None),
    }
    rep2 = P(batch_ax, None, None)
    rep1 = P(batch_ax)

    def body(qi, qsc, ki, vi, ksc, vsc, feat_new, cl, lengths, act):
        # shard rank along the sp axes → global token offset of this shard
        ridx = jnp.int32(0)
        for a in sp_axes:
            ridx = ridx * mesh.shape[a] + jax.lax.axis_index(a)
        offset = ridx * m_loc
        if write:
            cl = _write_token_local(cl, ki, vi, ksc, vsc, feat_new, lengths,
                                    offset, m_loc, active=act)
        new_len = lengths + (1 if write else 0)
        # retired lanes see an empty cache (nothing valid to screen/select)
        new_len = jnp.where(act, new_len, 0)

        # the same fused kernel as the local path, shifted to this shard's
        # global positions; stats come back unnormalized for the merge
        out, m, l = ops.decode_attention(
            qi, qsc, cl["k"], cl["v"], cl["k_scale"], cl["v_scale"],
            cl["feat"], new_len, block=block, k_keep=k_keep, window=window,
            use_lop=use_lop, shared_select=bool(cfg.gqa_shared_select),
            pos_offset=offset, return_stats=True)

        # flash-decoding merge across M shards (out·ℓ recovers the raw
        # accumulator; empty shards carry m = −inf, ℓ = 0)
        m_g = jax.lax.pmax(m, sp_axes)
        w = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * w, sp_axes)
        acc_g = jax.lax.psum(out * (l * w), sp_axes)
        out = acc_g / jnp.maximum(l_g, 1e-20)
        return out, cl

    new_tok_spec2 = P(batch_ax, None, None)
    in_specs = (new_tok_spec2, new_tok_spec2, new_tok_spec2, new_tok_spec2,
                new_tok_spec2, new_tok_spec2,
                new_tok_spec2 if feat is not None else None,
                cache_spec, rep1, rep1)
    out_specs = (rep2, cache_spec)

    if not write:
        # cross-attention: no new token operands
        def body_nw(qi, qsc, cl, lengths, act):
            return body(qi, qsc, None, None, None, None, None, cl, lengths,
                        act)

        fn = shard_map(body_nw, mesh=mesh,
                           in_specs=(new_tok_spec2, new_tok_spec2,
                                     cache_spec, rep1, rep1),
                           out_specs=out_specs, check_vma=False)
        return fn(qi, qsc, cl, lengths, active)

    fn = shard_map(body, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False)
    return fn(qi, qsc, ki, vi, ksc, vsc, feat, cl, lengths, active)
