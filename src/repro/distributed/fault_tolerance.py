"""Fault tolerance: preemption, stragglers, elastic re-mesh.

At thousand-node scale the framework must assume (i) SIGTERM preemptions,
(ii) slow outlier hosts, (iii) permanent device loss. The pieces:

  * :class:`PreemptionHandler` — converts SIGTERM/SIGINT into a flag the
    training loop polls; the loop checkpoints and exits cleanly.
  * :class:`StragglerMonitor` — rolling per-step latency stats; flags
    outliers (> μ + k·σ over a window) so the orchestrator can drain the
    slow host and trigger a re-mesh.
  * :func:`plan_elastic_mesh` — given the surviving device count, the
    largest usable (data × model) mesh keeping the model axis intact
    (TP degree is baked into layer shardings; DP shrinks elastically).
  * :func:`elastic_restart` — rebuild mesh from survivors + reload the last
    complete checkpoint; the data pipeline is deterministic in (step, host),
    so resumed training is bit-reproducible modulo the lost step.

Tested by simulation in tests/test_distributed.py (device loss = restricting
the visible device list).
"""

from __future__ import annotations

import collections
import signal
import statistics
import time

import jax
import numpy as np


class PreemptionHandler:
    """SIGTERM/SIGINT → cooperative checkpoint-and-exit flag."""

    def __init__(self, signals=(signal.SIGTERM,)):
        self._requested = False
        self._installed = []
        for sig in signals:
            try:
                prev = signal.signal(sig, self._handle)
                self._installed.append((sig, prev))
            except ValueError:            # not on main thread (tests)
                pass

    def _handle(self, signum, frame):
        self._requested = True

    @property
    def preempted(self) -> bool:
        return self._requested

    def restore(self):
        for sig, prev in self._installed:
            signal.signal(sig, prev)


class StragglerMonitor:
    """Rolling window of per-step durations with outlier detection."""

    def __init__(self, window: int = 50, threshold_sigma: float = 3.0,
                 min_steps: int = 10):
        self.window = window
        self.sigma = threshold_sigma
        self.min_steps = min_steps
        self.times = collections.deque(maxlen=window)
        self.flagged: list[tuple[int, float]] = []
        self._step = 0

    def record(self, duration_s: float) -> bool:
        """Record a step time; returns True if this step is a straggler."""
        self._step += 1
        is_straggler = False
        if len(self.times) >= self.min_steps:
            mu = statistics.fmean(self.times)
            sd = statistics.pstdev(self.times) or 1e-9
            if duration_s > mu + self.sigma * sd:
                is_straggler = True
                self.flagged.append((self._step, duration_s))
        self.times.append(duration_s)
        return is_straggler

    def summary(self) -> dict:
        return {
            "steps": self._step,
            "mean_s": statistics.fmean(self.times) if self.times else 0.0,
            "flagged": list(self.flagged),
        }


def plan_elastic_mesh(n_devices: int, *, model: int = 16,
                      pod: int | None = None) -> tuple:
    """Largest (data, model) [or (pod, data, model)] mesh from survivors.

    The model (TP) axis is preserved — layer shardings depend on it; the
    data axis absorbs the loss. Returns the mesh shape tuple.
    """
    if n_devices < model:
        raise RuntimeError(
            f"{n_devices} devices cannot sustain model axis {model}")
    if pod:
        per_pod = n_devices // pod
        data = per_pod // model
        if data < 1:
            return plan_elastic_mesh(n_devices, model=model, pod=None)
        return (pod, data, model)
    return (n_devices // model, model)


def make_elastic_mesh(devices=None, *, model: int = 16, multi_pod=False):
    """Build the largest healthy mesh from an explicit device list."""
    devices = list(devices if devices is not None else jax.devices())
    shape = plan_elastic_mesh(len(devices), model=model,
                              pod=2 if multi_pod else None)
    n_used = int(np.prod(shape))
    dev_array = np.asarray(devices[:n_used]).reshape(shape)
    names = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.sharding.Mesh(dev_array, names)


def elastic_restart(ckpt_dir: str, tree_like, surviving_devices, *,
                    model: int = 16):
    """Device loss recovery: new mesh from survivors + last good step.

    Returns (mesh, tree, step, extra). Resharding onto the new mesh happens
    when the caller re-places the host arrays with the new shardings.
    """
    from repro.checkpoint.store import load_checkpoint
    mesh = make_elastic_mesh(surviving_devices, model=model)
    tree, step, extra = load_checkpoint(ckpt_dir, tree_like)
    return mesh, tree, step, extra
