"""Logical-axis partitioning (DP / FSDP / TP / EP / SP) — DESIGN.md §5.

Model code annotates tensors with *logical* axes; this module resolves them
against the active mesh:

  ``dp``    data parallel — batch dim; ``("data",)`` single-pod,
            ``("pod", "data")`` multi-pod.
  ``fsdp``  ZeRO-3 parameter/optimizer sharding — same mesh axes as ``dp``
            (parameters are all-gathered per scan step by XLA).
  ``tp``    tensor parallel — ``("model",)``: attention heads, FFN hidden,
            vocab, expert-internal dims.
  ``ep``    expert parallel — ``("model",)`` when n_experts divides the axis.
  ``sp``    sequence parallel — ``("model",)``: KV-cache / sequence dim for
            decode and long-context attention.

When no mesh is active every annotation is a no-op, so the exact same model
code runs single-device tests and 512-chip dry-runs.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

_state = threading.local()


def current_mesh() -> Mesh | None:
    return getattr(_state, "mesh", None)


@contextmanager
def use_mesh(mesh: Mesh | None):
    """Activate a mesh for logical-axis resolution (and as jax's mesh ctx)."""
    prev = current_mesh()
    _state.mesh = mesh
    try:
        if mesh is not None:
            with mesh:
                yield mesh
        else:
            yield None
    finally:
        _state.mesh = prev


def dp_axes(mesh: Mesh | None = None) -> tuple:
    mesh = mesh or current_mesh()
    if mesh is not None and "pod" in mesh.axis_names:
        return ("pod", "data")
    return ("data",)


fsdp_axes = dp_axes


def _resolve_axis(logical, mesh: Mesh | None):
    """logical axis name (or None / tuple of mesh axes) → mesh axes entry."""
    if logical is None:
        return None
    if logical == "dp" or logical == "fsdp":
        return dp_axes(mesh)
    if logical in ("tp", "ep", "sp"):
        return "model"
    # raw mesh axis names pass through ("data", "model", "pod", tuples)
    return logical


def logical_to_pspec(axes: tuple, mesh: Mesh | None = None) -> P:
    """("fsdp", "tp") → PartitionSpec(("data",), "model") etc."""
    mesh = mesh or current_mesh()
    return P(*[_resolve_axis(a, mesh) for a in axes])


def shard(x: jax.Array, *axes) -> jax.Array:
    """Constrain ``x`` to the resolved logical spec (no-op without a mesh).

    An axis entry may be a logical name, a raw mesh axis, or None; trailing
    dims may be omitted (treated as None).
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = logical_to_pspec(tuple(axes) + (None,) * (x.ndim - len(axes)), mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` across JAX versions.

    Newer JAX exposes it at top level with ``check_vma``; 0.4.x only has
    ``jax.experimental.shard_map.shard_map`` whose equivalent knob is
    ``check_rep``.
    """
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)


def is_spec_leaf(t) -> bool:
    """Logical-axis tuples are leaves; NamedTuples (pytree nodes) are not."""
    return (isinstance(t, tuple) and not hasattr(t, "_fields")) or t is None


def tree_pspecs(spec_tree, mesh: Mesh | None = None):
    """Map a tree of logical-axis tuples → tree of PartitionSpecs."""
    mesh = mesh or current_mesh()
    return jax.tree.map(
        lambda axes: logical_to_pspec(axes, mesh),
        spec_tree, is_leaf=is_spec_leaf,
    )


def named_shardings(spec_tree, mesh: Mesh | None = None):
    """Tree of logical-axis tuples → tree of NamedShardings (for jit args)."""
    mesh = mesh or current_mesh()
    return jax.tree.map(
        lambda axes: NamedSharding(mesh, logical_to_pspec(axes, mesh)),
        spec_tree, is_leaf=is_spec_leaf,
    )
