"""Ring collective matmul (compute/communication overlap).

The TPU expression of the paper's head-level pipelining idea at pod scale
(DESIGN.md §2 C4): instead of a blocking all-reduce after a row-parallel
matmul, partial products circulate a ``ppermute`` ring in chunks — chunk
``c``'s hop overlaps with chunk ``c+1``'s matmul, hiding ICI latency behind
MXU work. XLA's latency-hiding scheduler interleaves the independent chunk
streams.

``ring_reduce_matmul(x, w)`` computes ``Y = Σᵢ Xᵢ @ Wᵢ`` (X, W sharded on
the contraction dim over ``axis_name``) and is numerically identical to
``psum(x_loc @ w_loc)`` — equality is tested on an 8-device host mesh.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _axis_size(axis_name) -> int:
    """``jax.lax.axis_size`` with a fallback for JAX versions without it
    (``psum(1, axis)`` is the classic idiom — it constant-folds to the
    static axis size inside the mapped region)."""
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(axis_name)
    return jax.lax.psum(1, axis_name)


def ring_reduce_matmul(x_loc: jax.Array, w_loc: jax.Array, axis_name: str,
                       *, chunks: int = 4) -> jax.Array:
    """x_loc [B, k_loc] @ w_loc [k_loc, n] summed over the mesh axis.

    The local matmul is split into ``chunks`` column chunks of the output;
    each finished chunk starts circulating the ring while the next chunk is
    still on the MXU.
    """
    n_ranks = _axis_size(axis_name)
    n = w_loc.shape[-1]
    chunks = min(chunks, n)
    assert n % chunks == 0
    cw = n // chunks
    perm = [(i, (i + 1) % n_ranks) for i in range(n_ranks)]

    outs = []
    for c in range(chunks):
        partial = x_loc @ w_loc[:, c * cw:(c + 1) * cw]   # local chunk
        acc = partial
        for _ in range(n_ranks - 1):
            # the hop of chunk c overlaps with chunk c+1's matmul above
            acc = jax.lax.ppermute(acc, axis_name, perm) + partial
        outs.append(acc)
    return jnp.concatenate(outs, axis=-1)


def allgather_matmul(x_loc: jax.Array, w_loc: jax.Array,
                     axis_name: str) -> jax.Array:
    """Y_loc = AllGather(X) @ W_loc without materializing the full gather.

    x_loc [b_loc, k] (sharded on batch), w_loc [k, n_loc] (sharded on
    columns): each rank streams the other ranks' activation blocks around
    the ring, multiplying as blocks arrive. → [b_loc · n_ranks? no —
    Y partial rows [b_loc*n_ranks, n_loc] assembled ring-rotated]:
    returns [B, n_loc] with B = b_loc × n_ranks in ring order.
    """
    n_ranks = _axis_size(axis_name)
    me = jax.lax.axis_index(axis_name)
    perm = [(i, (i + 1) % n_ranks) for i in range(n_ranks)]
    b_loc = x_loc.shape[0]

    out = jnp.zeros((b_loc * n_ranks, w_loc.shape[-1]), x_loc.dtype)
    cur = x_loc
    for t in range(n_ranks):
        y = cur @ w_loc                            # block from rank (me-t)%n
        row = ((me - t) % n_ranks) * b_loc
        out = jax.lax.dynamic_update_slice(out, y, (row, 0))
        if t < n_ranks - 1:
            cur = jax.lax.ppermute(cur, axis_name, perm)  # overlaps next @
    return out
