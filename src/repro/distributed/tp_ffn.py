"""Tensor parallelism for the fused serving FFN: shard the f axis.

The fused whole-FFN kernel (DESIGN.md §TINT-projection-fusion) computes
act(x·Wg)·(x·Wu) → in-VMEM absmax re-barrier → ·Wd in ONE launch, which
removed the legacy TP resharding point between the up and down
projections — every device computed the full hidden block. This module
restores tensor parallelism for the serving FFN (the ROADMAP item): a
``shard_map`` wrapper splits the hidden **f axis** across the model
axis, so each rank runs the SAME fused kernel over its own contiguous
f-shard — its slice of the gate‖up columns and the matching rows of the
down stream — and the partial down outputs ``psum`` back together
(dequantization is linear in the integer accumulator, so the sum of
per-shard dequantized partials is the full projection).

Layout: ``gu_packed [..., d//4, 2f]`` concatenates gate columns ‖ up
columns, so a naive split of the last axis would hand the first ranks
only gate columns. The wrapper views it as ``[..., d//4, segs, f]``
(segs = 2 gated, 1 ungated) and shards the trailing f axis — each rank
gets the SAME contiguous feature block of *both* streams, matching its
``down_packed`` row shard (packed rows r cover hidden features
4r..4r+3, so row-sharding by equal contiguous blocks lines up exactly).

Numerics caveat (recorded in DESIGN.md §Serving-API): the kernel's
hidden re-barrier runs per rank, so the absmax is over the rank's f/n
features instead of all f — a *finer* quantization grouping, not the
single-device grouping. Output therefore matches the unsharded kernel
bitwise only at model-axis size 1; at n > 1 it agrees to int8
quantization noise (the subprocess check bounds the relative error).
The SP decode path has no such caveat — attention scales are per token,
not sharded.

Opt-in is explicit (mirroring ``sp_axes`` for decode attention): wrap
the serving call in :func:`use_ffn_tp` under an active mesh; without
the context (or without a mesh, or when f does not divide) every
consumer falls back to the single-launch path unchanged — dry-runs and
single-device tests are untouched.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.partitioning import current_mesh, shard_map
from repro.kernels import ops

_state = threading.local()


@contextmanager
def use_ffn_tp(axis: str = "model"):
    """Enable f-axis FFN sharding over mesh axis ``axis`` for the scope.
    Consumers (``core/qlinear.ffn_node_apply``) pick it up when a mesh
    is active and the shapes divide; otherwise they fall back."""
    prev = getattr(_state, "axis", None)
    _state.axis = axis
    try:
        yield
    finally:
        _state.axis = prev


def ffn_tp_axis() -> str | None:
    return getattr(_state, "axis", None)


def ffn_fused_tp(x, gu_packed, gu_scale, down_packed, down_scale, *,
                 gated: bool, act: str, mesh=None, axis: str = "model"):
    """The whole-FFN fused dispatch, f-sharded over ``mesh[axis]``.

    Same operands and result as :func:`repro.kernels.ops.ffn_fused`
    (leading expert dims ride along untouched); each rank launches the
    fused kernel on its f-shard and the down partials ``psum``.
    """
    mesh = mesh or current_mesh()
    assert mesh is not None and axis in mesh.axis_names, (mesh, axis)
    segs = 2 if gated else 1
    f = down_packed.shape[-2] * 4
    assert gu_packed.shape[-1] == segs * f, (gu_packed.shape, f, gated)

    # view gate‖up as [..., d//4, segs, f] so sharding the trailing axis
    # gives every rank a matching contiguous feature block of BOTH streams
    gu4 = gu_packed.reshape(*gu_packed.shape[:-1], segs, f)
    gs4 = jnp.broadcast_to(
        gu_scale.astype(jnp.float32),
        (*gu_scale.shape[:-1], segs * f)).reshape(
        *gu_scale.shape[:-1], segs, f)

    def spec(ndim: int, shard_at: int) -> P:
        entries = [None] * ndim
        entries[shard_at] = axis
        return P(*entries)

    rep = P()

    def body(x_, gu4_, gs4_, dn_, ds_):
        f_l = gu4_.shape[-1]
        gu_l = gu4_.reshape(*gu4_.shape[:-2], segs * f_l)
        gs_l = gs4_.reshape(*gs4_.shape[:-2], segs * f_l)
        part = ops.ffn_fused(x_, gu_l, gs_l, dn_, ds_, gated=gated,
                             act=act)
        return jax.lax.psum(part, axis)

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(rep, spec(gu4.ndim, gu4.ndim - 1),
                  spec(gs4.ndim, gs4.ndim - 1),
                  spec(down_packed.ndim, down_packed.ndim - 2), rep),
        out_specs=rep, check_vma=False)
    return fn(x, gu4, gs4, down_packed, down_scale)


def maybe_shard_f(node, x, *, gated: bool, act: str):
    """Route a fused-FFN node through the f-sharded path when the
    :func:`use_ffn_tp` opt-in is active, a mesh with the axis exists and
    the down-stream rows divide; else return None (caller falls back)."""
    axis = ffn_tp_axis()
    if axis is None:
        return None
    mesh = current_mesh()
    if mesh is None or axis not in mesh.axis_names:
        return None
    n = int(mesh.shape[axis])
    if n <= 0 or node["down_packed"].shape[-2] % n:
        return None
    return ffn_fused_tp(x, node["gu_packed"], node["gu_scale"],
                        node["down_packed"], node["down_scale"],
                        gated=gated, act=act, mesh=mesh, axis=axis)
