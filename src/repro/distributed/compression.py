"""Gradient compression: int8 all-reduce with error feedback.

The absmax-barrier discipline applied to the *gradient* collective: ranks
agree on a shared per-tensor scale (pmax of local absmax — one tiny f32
all-reduce), quantize to int8, psum in int32, dequantize once. Error
feedback accumulates the local quantization residual into the next step so
the compression bias vanishes over time (convergence parity is tested on a
toy model in tests/test_distributed.py).

Used inside shard_map data-parallel regions, where the gradient collective
is explicit (under plain pjit XLA owns the all-reduce and there is nothing
to intercept — that trade-off is recorded in DESIGN.md §5).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

INT8_MAX = 127.0


def compressed_psum(x: jax.Array, axis_name, err: jax.Array):
    """int8-compressed psum of ``x`` over ``axis_name`` with error feedback.

    → (psum result ≈ Σ x, new local error state).
    """
    xf = x.astype(jnp.float32) + err
    amax_local = jnp.max(jnp.abs(xf))
    amax = jax.lax.pmax(amax_local, axis_name)          # shared scale
    scale = jnp.maximum(amax, 1e-12) / INT8_MAX
    q = jnp.clip(jnp.round(xf / scale), -INT8_MAX, INT8_MAX)
    new_err = xf - q * scale                            # local residual
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale, new_err


def compressed_psum_tree(grads, axis_name, err_tree):
    """Tree version. → (summed grads, new error tree)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(err_tree)
    outs = [compressed_psum(g, axis_name, e)
            for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]))


def init_error_state(grads_like):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                        grads_like)
