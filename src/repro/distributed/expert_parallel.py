"""Expert parallelism: all-to-all token dispatch under shard_map.

The TP-inside-experts default (:mod:`repro.models.moe`) always divides, but
when ``n_experts`` divides the model axis (granite: 32/16, jamba: 16/16)
true EP is available: each rank owns E/n experts, tokens travel to their
experts via ``all_to_all`` and return after the expert FFN — the classic
Switch/GShard schedule expressed in shard_map.

Numerically equivalent to the dense-dispatch reference (same router, same
capacity rule per *local* group); equality is tested on an 8-device host
mesh in tests/test_distributed.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.partitioning import shard_map
from repro.models.moe import _dispatch_group  # reference router/dispatch


def ep_moe_apply(cfg, p, x, mesh, *, axis: str = "model",
                 token_axes=("data",)):
    """MoE with expert-parallel all-to-all. x [B, S, d] (batch over dp).

    Requires cfg.n_experts % mesh.shape[axis] == 0.
    """
    n_ep = int(mesh.shape[axis])
    e = cfg.n_experts
    assert e % n_ep == 0, (e, n_ep)
    e_loc = e // n_ep

    def body(p_loc, x_loc):
        b, s, d = x_loc.shape
        t = b * s
        flat = x_loc.reshape(t, d)
        cap = int(max(t * cfg.top_k / e * cfg.capacity_factor, cfg.top_k))

        # local routing + capacity-bucketed dispatch (reference logic)
        logits = flat.astype(jnp.float32) @ p_loc["router"]
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, gate_idx = jax.lax.top_k(probs, cfg.top_k)
        gate_vals = gate_vals / jnp.maximum(
            jnp.sum(gate_vals, -1, keepdims=True), 1e-9)
        choice = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)
        flat_c = choice.reshape(t * cfg.top_k, e)
        pos = jnp.cumsum(flat_c, axis=0) - flat_c
        pos = jnp.sum(pos.reshape(t, cfg.top_k, e) * choice, -1)
        keep = pos < cap
        disp = (jax.nn.one_hot(pos, cap, dtype=flat.dtype)[:, :, None, :]
                * choice[..., None].astype(flat.dtype)
                * keep[..., None, None].astype(flat.dtype))
        disp = jnp.sum(disp, axis=1)                       # [T, E, cap]
        comb = disp * jnp.sum(
            gate_vals[:, :, None, None] * choice[..., None].astype(flat.dtype)
            * keep[..., None, None].astype(flat.dtype), axis=1)

        xe = jnp.einsum("tec,td->ecd", disp, flat)         # [E, cap, d]
        # ---- all-to-all: send each expert's bucket to its owner rank ----
        # a2a(tiled=False): split axis removed, receive axis inserted at
        # concat position → [e_loc, cap, n_src, d]
        xe = jax.lax.all_to_all(xe.reshape(n_ep, e_loc, cap, d), axis,
                                split_axis=0, concat_axis=2, tiled=False)
        xe = xe.transpose(0, 2, 1, 3).reshape(e_loc, n_ep * cap, d)

        # ---- local expert FFN (weights: only this rank's e_loc experts) ---
        def ffn(w, h):
            return jnp.einsum("ecd,edf->ecf", h, w)

        if cfg.gated_ffn:
            h = jax.nn.silu(ffn(p_loc["w_gate"], xe)) * ffn(p_loc["w_up"],
                                                            xe)
        else:
            h = jax.nn.gelu(ffn(p_loc["w_up"], xe))
        ye = ffn(p_loc["w_down"], h)                       # [e_loc, n·cap, d]

        # ---- return trip: chunk j goes back to token-owner rank j ----
        ye = ye.reshape(e_loc, n_ep, cap, d).transpose(1, 0, 2, 3)
        # → received [e_loc, cap, n_src(=expert-block owner), d]
        ye = jax.lax.all_to_all(ye, axis, split_axis=0, concat_axis=2,
                                tiled=False)
        ye = ye.transpose(2, 0, 1, 3).reshape(e, cap, d)
        y = jnp.einsum("tec,ecd->td", comb, ye)
        return y.reshape(b, s, d).astype(x_loc.dtype)

    pspec = {
        "router": P(),
        "w_gate": P(axis, None, None),
        "w_up": P(axis, None, None),
        "w_down": P(axis, None, None),
    }
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(pspec, P(token_axes, None, None)),
        out_specs=P(token_axes, None, None), check_vma=False)
    return fn(p, x)
