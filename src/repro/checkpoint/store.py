"""Self-contained sharded checkpoint store (orbax is not available offline).

Layout::

    <dir>/step_<N>/proc_<i>.npz      one shard per host process
    <dir>/step_<N>/manifest.json     written LAST — a step directory without
                                     a manifest is garbage by definition

Atomicity: shards land in ``step_<N>.tmp/``; the manifest is written inside
and the directory is atomically renamed. A crash mid-save leaves only a
``.tmp`` directory that restore ignores and the next save overwrites —
restart always sees the last *complete* step (the fault-tolerance contract).

Arrays are fetched via ``jax.device_get`` on fully-addressable values; on a
multi-host pod each process saves only its addressable shards (the manifest
records the process count so restore re-validates the topology).
"""

from __future__ import annotations

import json
import os
import shutil
import time

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = [jax.tree_util.keystr(path) for path, _ in flat]
    vals = [v for _, v in flat]
    return keys, vals, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree, *, extra: dict | None
                    = None, process_index: int = 0, process_count: int = 1,
                    keep: int = 3) -> str:
    """Save ``tree`` (any pytree of arrays) for ``step``. Returns the path."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)

    keys, vals, _ = _flatten(tree)
    arrays = {f"a{i}": np.asarray(jax.device_get(v))
              for i, v in enumerate(vals)}
    np.savez(os.path.join(tmp, f"proc_{process_index}.npz"), **arrays)

    if process_index == 0:
        manifest = {
            "step": step,
            "keys": keys,
            "process_count": process_count,
            "time": time.time(),
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                       # the atomic commit
        _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(_list_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


def _list_steps(ckpt_dir: str):
    steps = []
    if not os.path.isdir(ckpt_dir):
        return steps
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
                steps.append(int(name[5:]))
    return steps


def latest_step(ckpt_dir: str) -> int | None:
    steps = _list_steps(ckpt_dir)
    return max(steps) if steps else None


def load_checkpoint(ckpt_dir: str, tree_like, *, step: int | None = None,
                    process_index: int = 0):
    """Restore into the structure of ``tree_like`` (shapes re-validated).

    Returns (tree, step, extra). Raises FileNotFoundError when no complete
    checkpoint exists.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    keys, vals, treedef = _flatten(tree_like)
    if manifest["keys"] != keys:
        raise ValueError("checkpoint/model structure mismatch: "
                         f"{set(manifest['keys']) ^ set(keys)}")
    data = np.load(os.path.join(path, f"proc_{process_index}.npz"))
    out = []
    for i, (k, like) in enumerate(zip(keys, vals)):
        arr = data[f"a{i}"]
        if hasattr(like, "shape") and tuple(arr.shape) != tuple(like.shape):
            raise ValueError(f"shape mismatch for {k}: "
                             f"{arr.shape} vs {like.shape}")
        out.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, out)
    return tree, step, manifest["extra"]
