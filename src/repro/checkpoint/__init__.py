"""Sharded numpy checkpoint store with atomic manifests."""

from repro.checkpoint.store import (latest_step, load_checkpoint,
                                    save_checkpoint)
