"""Training/prefill attention (bf16/f32 path) with head-level streaming.

The exact-attention compute here is the *chunked* (flash-style) schedule:
queries stream in chunks while the f32 softmax reductions stay fused with the
logit tiles — the jnp expression of the paper's "reductions overlap with
linear tiles". The serving path (int8 + LOP screen) lives in
:mod:`repro.serving.engine` and the Pallas kernels.

Sharding: heads go to the ``model`` axis when divisible; otherwise the query
*sequence* is sharded (SP) — this keeps every assigned arch (12-head whisper,
40-head qwen32b, 56-head llava) legal on a 16-way model axis.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.partitioning import current_mesh, shard
from repro.models.layers import linear_apply, linear_init, rope

NEG_INF = -1e30


def _model_axis_size() -> int:
    mesh = current_mesh()
    if mesh is None:
        return 1
    return mesh.shape.get("model", 1)


def shard_heads_or_seq(x: jax.Array, n_heads: int) -> jax.Array:
    """x [B, S, H, dh] → head-sharded when H divides the model axis.

    Non-divisible head counts are left for the chunk-row SP sharding inside
    :func:`chunked_attention` (constraining S here would make the chunk
    scan slice a sharded axis — involuntary resharding per step).
    """
    m = _model_axis_size()
    if n_heads % m == 0:
        return shard(x, "dp", None, "tp", None)
    return x


def chunked_attention(q, k, v, *, causal: bool, window: int = 0,
                      q_offset: int = 0, chunk: int = 512,
                      softmax_scale: float | None = None) -> jax.Array:
    """Chunked exact attention with GQA.

    q [B, Sq, H, dh]; k/v [B, Skv, Hkv, dh] (H % Hkv == 0) → [B, Sq, H, dh].
    ``window > 0`` applies a sliding-window (SWA) causal mask.
    ``q_offset`` is the absolute position of q[0] (prefill continuation).

    GQA keys/values are repeated to the flat H dim so the head axis stays
    shardable end-to-end (a (Hkv, G) split would break TP head sharding —
    SPMD falls back to full replication). When H doesn't divide the model
    axis, the chunk's query rows are SP-sharded instead.
    """
    import os
    b, sq, h, dh = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    if softmax_scale is None:
        softmax_scale = dh ** -0.5
    # accounting probes raise the chunk so unrolling stays tractable —
    # tiling is flop/byte-invariant, so the differential stays exact
    chunk = int(os.environ.get("REPRO_ATTN_CHUNK", chunk))
    if hkv != h:
        k = jnp.repeat(k, h // hkv, axis=2)
        v = jnp.repeat(v, h // hkv, axis=2)
    head_sharded = h % _model_axis_size() == 0
    if head_sharded:
        k = shard(k, "dp", None, "tp", None)
        v = shard(v, "dp", None, "tp", None)

    chunk = min(chunk, sq)
    pad = (-sq) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = q.shape[1] // chunk
    qc = q.reshape(b, nc, chunk, h, dh).transpose(1, 0, 2, 3, 4)

    kpos = jnp.arange(skv)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    def body(_, args):
        qi, ci = args                                    # [B, C, H, dh]
        if head_sharded:
            qi = shard(qi, "dp", None, "tp", None)
        else:
            qi = shard(qi, "dp", "sp", None, None)
        logits = jnp.einsum("bqhd,bkhd->bhqk", qi.astype(jnp.float32),
                            kf) * softmax_scale
        qpos = q_offset + ci * chunk + jnp.arange(chunk)
        mask = jnp.ones((chunk, skv), bool)
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if window:
            mask &= (qpos[:, None] - kpos[None, :]) < window
        logits = jnp.where(mask[None, None], logits, NEG_INF)
        p = jax.nn.softmax(logits, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", p, vf)
        return None, o.astype(q.dtype)

    from repro.models.scan_utils import accounting_unroll
    _, oc = jax.lax.scan(body, None, (qc, jnp.arange(nc)),
                         unroll=accounting_unroll(nc))
    o = oc.transpose(1, 0, 2, 3, 4).reshape(b, nc * chunk, h, dh)
    return o[:, :sq]


# ---------------------------------------------------------------------------
# Full attention block (projections + rope + attention + output proj)
# ---------------------------------------------------------------------------

def attention_init(key, cfg):
    keys = jax.random.split(key, 4)
    d = cfg.d_model
    p, s = {}, {}
    p["wq"], s["wq"] = linear_init(keys[0], d, cfg.q_dim, bias=cfg.qkv_bias)
    p["wk"], s["wk"] = linear_init(keys[1], d, cfg.kv_dim, bias=cfg.qkv_bias)
    p["wv"], s["wv"] = linear_init(keys[2], d, cfg.kv_dim, bias=cfg.qkv_bias)
    p["wo"], s["wo"] = linear_init(keys[3], cfg.q_dim, d, spec=("tp", "fsdp"))
    return p, s


def attention_apply(cfg, p, x, *, kv_x=None, causal=True, positions=None,
                    use_rope=True, chunk_carry: bool = False,
                    q_offset: int = 0):
    """Self-attention (kv_x=None), cross-attention, or chunk-carry.

    x [B, S, D] → [B, S, D]. Projections are BitLinear under QAT.

    Chunk-carry (the float-path mirror of the engine's chunked prefill,
    DESIGN.md §Chunked-prefill) is an explicit opt-in: when ``x`` is a
    *suffix chunk* of a longer self-attention stream, pass the full
    stream (prefix ‖ chunk) as ``kv_x`` with ``chunk_carry=True`` and
    the chunk's absolute start as ``q_offset`` — keys rope at positions
    [0, S_kv), queries at [q_offset, q_offset + S), and the causal/SWA
    mask runs in global positions, so the chunk rows equal the same rows
    of one full-stream call. Without the flag, ``kv_x`` keeps its
    original invariant: plain cross-attention (non-causal, no rope,
    no window), whatever ``use_rope`` says.
    """
    b, sq, _ = x.shape
    src = x if kv_x is None else kv_x
    skv = src.shape[1]
    self_like = kv_x is None or chunk_carry

    q = linear_apply(p["wq"], x, quant=cfg.quant)
    k = linear_apply(p["wk"], src, quant=cfg.quant)
    v = linear_apply(p["wv"], src, quant=cfg.quant)
    q = q.reshape(b, sq, cfg.n_heads, cfg.hd)
    k = k.reshape(b, skv, cfg.n_kv_heads, cfg.hd)
    v = v.reshape(b, skv, cfg.n_kv_heads, cfg.hd)

    if use_rope and self_like:
        if positions is None:
            positions = q_offset + jnp.arange(sq)[None, :]
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, jnp.arange(skv)[None, :] if chunk_carry else positions,
                 cfg.rope_theta)

    q = shard_heads_or_seq(q, cfg.n_heads)
    k = shard_heads_or_seq(k, cfg.n_kv_heads)
    v = shard_heads_or_seq(v, cfg.n_kv_heads)

    o = chunked_attention(q, k, v, causal=causal and self_like,
                          window=cfg.swa_window if self_like else 0,
                          q_offset=q_offset)
    o = o.reshape(b, sq, cfg.q_dim)
    return linear_apply(p["wo"], o, quant=cfg.quant)
