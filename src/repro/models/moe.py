"""FFN and token-choice top-k Mixture-of-Experts.

MoE dispatch is capacity-bucketed einsum dispatch over token *groups*
(scanned), so the one-hot dispatch tensor stays ``[group, E, capacity]`` —
small and transient — instead of ``[tokens, E, capacity]``. Expert weights
are sharded TP-inside-expert (``[E, d, ff]`` with ff on the model axis),
which divides for every assigned expert count; a shard_map all-to-all EP
variant lives in :mod:`repro.distributed.expert_parallel`.

All expert projections are BitLinear under the ternary flow (BitNet applies
to every weight projection — MoE experts included); the router stays f32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.qlinear import (ffn_node_apply, is_fused_ffn, is_packed,
                                qlinear_expert)
from repro.core.ternary import ste_ternary
from repro.distributed.partitioning import shard
from repro.models.layers import linear_apply, linear_init


# ---------------------------------------------------------------------------
# Dense FFN (gated silu, or plain gelu for whisper)
# ---------------------------------------------------------------------------

def ffn_init(key, cfg):
    keys = jax.random.split(key, 3)
    d, f = cfg.d_model, cfg.d_ff
    p, s = {}, {}
    if cfg.gated_ffn:
        p["w_gate"], s["w_gate"] = linear_init(keys[0], d, f)
        p["w_up"], s["w_up"] = linear_init(keys[1], d, f)
    else:
        p["w_up"], s["w_up"] = linear_init(keys[1], d, f)
    p["w_down"], s["w_down"] = linear_init(keys[2], f, d, spec=("tp", "fsdp"))
    return p, s


def ffn_apply(cfg, p, x):
    if is_fused_ffn(p):
        # serving format: the whole FFN (gate·up → in-VMEM absmax barrier
        # → down) is ONE fused dispatch — bitwise the unfused chain below
        return ffn_node_apply(p, x, gated=cfg.gated_ffn,
                              act="silu" if cfg.gated_ffn else "gelu")
    if cfg.gated_ffn:
        h = jax.nn.silu(linear_apply(p["w_gate"], x, quant=cfg.quant))
        h = h * linear_apply(p["w_up"], x, quant=cfg.quant)
    else:
        h = jax.nn.gelu(linear_apply(p["w_up"], x, quant=cfg.quant))
    h = shard(h, "dp", None, "tp")
    return linear_apply(p["w_down"], h, quant=cfg.quant)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def moe_init(key, cfg):
    keys = jax.random.split(key, 4)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    scale = d ** -0.5
    p = {
        "router": jax.random.normal(keys[0], (d, e), jnp.float32) * scale,
        "w_gate": jax.random.normal(keys[1], (e, d, f), jnp.float32) * scale,
        "w_up": jax.random.normal(keys[2], (e, d, f), jnp.float32) * scale,
        "w_down": jax.random.normal(keys[3], (e, f, d), jnp.float32)
                  * (f ** -0.5),
    }
    s = {
        "router": (None, None),
        "w_gate": (None, "fsdp", "tp"),
        "w_up": (None, "fsdp", "tp"),
        "w_down": (None, "tp", "fsdp"),
    }
    return p, s


def _expert_linear(w, x, quant: str):
    """x [E, C, d_in] @ w [E, d_in, d_out].

    Serving format (packed dict) → integer-domain qlinear; training format
    (raw array) → plain einsum (STE fake-quant + dtype cast happen ONCE per
    layer in :func:`_prepare_expert_weights`, outside the group scan).
    """
    if is_packed(w):
        return qlinear_expert(w, x)
    # hillclimb flag: bf16 accumulation keeps the expert weight-grad
    # all-reduce in bf16 (halves the dominant collective of MoE training)
    import os
    pref = (None if os.environ.get("REPRO_BF16_EXPERT_ACC") == "1"
            else jnp.float32)
    return jnp.einsum("ecd,edf->ecf", x, w,
                      preferred_element_type=pref).astype(x.dtype)


def _prepare_expert_weights(cfg, p, act_dtype):
    """Hoist per-layer expert-weight work out of the group scan:

    QAT fake-quant (STE) once, cast to the activation dtype (so the FSDP
    all-gather moves bf16, not f32 master weights), and constrain to the
    gathered TP layout — the scan body then closes over loop-INVARIANT
    gathered weights instead of re-gathering every group step.
    """
    p = dict(p)
    for name in ("w_gate", "w_up", "w_down"):
        if name not in p or is_packed(p[name]):
            continue
        w = p[name]
        if cfg.quant == "ternary":
            w = ste_ternary(w.reshape(-1, w.shape[-1])).reshape(w.shape)
        w = w.astype(act_dtype)
        p[name] = shard(w, None, None, "tp")
    return p


def _dispatch_group(cfg, p, x):
    """One token group [T, d] → MoE output [T, d] + aux losses."""
    t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    # capacity floor min(t, 8) keeps tiny decode groups drop-free
    cap = int(max(-(-t * k * cfg.capacity_factor // e), k, min(t, 8)))

    logits = x.astype(jnp.float32) @ p["router"]                  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)                 # [T, k]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    # position of each (token, choice) within its expert's capacity bucket
    choice_mask = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)    # [T, k, E]
    flat = choice_mask.reshape(t * k, e)
    pos = jnp.cumsum(flat, axis=0) - flat                         # pre-count
    pos = jnp.sum(pos.reshape(t, k, e) * choice_mask, -1)         # [T, k]
    keep = pos < cap                                              # overflow drop

    # dispatch one-hot [T, E, cap]
    disp = (jax.nn.one_hot(pos, cap, dtype=x.dtype)[:, :, None, :]
            * choice_mask[..., None].astype(x.dtype)
            * keep[..., None, None].astype(x.dtype))              # [T,k,E,cap]
    disp = jnp.sum(disp, axis=1)                                  # [T, E, cap]
    comb = disp * jnp.sum(
        gate_vals[:, :, None, None] * choice_mask[..., None].astype(x.dtype)
        * keep[..., None, None].astype(x.dtype), axis=1)          # weighted

    xe = jnp.einsum("tec,td->ecd", disp, x)                       # [E, cap, d]
    if is_fused_ffn(p):
        # serving format: every expert's gate·up → barrier → down runs in
        # ONE grouped dispatch (expert = grid axis of the fused kernel)
        ye = ffn_node_apply(p, xe, gated=cfg.gated_ffn,
                            act="silu" if cfg.gated_ffn else "gelu")
    else:
        if cfg.gated_ffn:
            h = jax.nn.silu(_expert_linear(p["w_gate"], xe, cfg.quant))
            h = h * _expert_linear(p["w_up"], xe, cfg.quant)
        else:
            h = jax.nn.gelu(_expert_linear(p["w_up"], xe, cfg.quant))
        h = shard(h, None, None, "tp")
        ye = _expert_linear(p["w_down"], h, cfg.quant)            # [E, cap, d]
    y = jnp.einsum("tec,ecd->td", comb, ye)

    # load-balancing aux loss (Switch-style)
    frac_tokens = jnp.mean(jnp.sum(choice_mask, 1).astype(jnp.float32), 0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac_tokens / k * frac_probs)
    return y.astype(x.dtype), aux


def moe_apply(cfg, p, x):
    """x [B, S, d] → ([B, S, d], aux_loss). Groups of ``moe_group`` tokens.

    The group scan must iterate an UNSHARDED axis (scanning a dp-sharded
    axis makes SPMD gather every slice). Tokens are regrouped so each scan
    step takes ``moe_group/dp`` tokens from EVERY data shard: the step's
    token dim stays dp-sharded, the step axis is replicated.
    """
    import math

    from repro.distributed.partitioning import current_mesh, dp_axes

    b, s, d = x.shape
    p = _prepare_expert_weights(cfg, p, x.dtype)
    flat = x.reshape(b * s, d)
    t = flat.shape[0]

    mesh = current_mesh()
    dp = (math.prod(int(mesh.shape[a]) for a in dp_axes(mesh))
          if mesh is not None else 1)
    if t % dp != 0:
        dp = 1                                     # tiny/odd batch: local
    t_loc = t // dp
    grp_loc = max(1, min(cfg.moe_group // dp, t_loc))
    pad_loc = (-t_loc) % grp_loc
    if pad_loc:
        flat = (flat.reshape(dp, t_loc, d) if dp > 1 else flat[None])
        flat = jnp.pad(flat, ((0, 0), (0, pad_loc), (0, 0)))
        flat = flat.reshape(dp * (t_loc + pad_loc), d)
        t_loc = t_loc + pad_loc
    steps = t_loc // grp_loc

    # [dp·T_loc, d] → [steps, dp, grp_loc, d]: the step axis is unsharded
    # (scannable), the dp axis is a *batched* dim — each data shard
    # dispatches its own grp_loc tokens with zero cross-shard traffic.
    groups = flat.reshape(dp, steps, grp_loc, d).transpose(1, 0, 2, 3)
    groups = shard(groups, None, "dp", None, None)

    def body(_, xg):                                   # xg [dp, grp_loc, d]
        y, aux = jax.vmap(lambda g: _dispatch_group(cfg, p, g))(xg)
        return None, (y, jnp.mean(aux))

    from repro.models.scan_utils import accounting_unroll
    _, (ys, auxs) = jax.lax.scan(body, None, groups,
                                 unroll=accounting_unroll(steps))
    y = ys.transpose(1, 0, 2, 3).reshape(dp, t_loc, d)
    y = y[:, : t // dp].reshape(t, d)
    return y.reshape(b, s, d), jnp.mean(auxs)
