"""Chunked sequential scan with per-chunk rematerialization.

Recurrent families (Mamba, RWKV6) need a scan over time whose AD residuals
would otherwise be O(T × state). Chunking the scan and checkpointing the
chunk body caps the saved residuals at O(T/chunk × state) while the backward
pass recomputes each chunk transiently — the same memory discipline the
layer-level remat applies to the stack.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp


def accounting_unroll(length: int) -> int:
    """Scan unroll factor for dry-run *cost accounting* variants.

    XLA's cost_analysis counts while-loop bodies ONCE (verified in
    EXPERIMENTS.md §Dry-run); the differential-costing variants set
    ``REPRO_UNROLL_SCANS=1`` so structural scans (layers, attention chunks,
    MoE groups) unroll and every body is counted. Token-level recurrences
    (Mamba/RWKV) stay scanned — their flop share is <1% (audited in
    DESIGN.md §Roofline-accounting).
    """
    return length if os.environ.get("REPRO_UNROLL_SCANS") == "1" else 1


def chunked_scan(body, carry, xs, *, chunk: int = 64, remat: bool = True):
    """``lax.scan(body, carry, xs)`` in remat'd chunks.

    xs: pytree with a shared leading time axis T (padded here if needed —
    body must tolerate trailing garbage steps ONLY if T % chunk != 0 and the
    caller slices ys; we instead pad and slice internally, so body runs on
    padded steps with the final carry taken at step T).
    """
    t = jax.tree.leaves(xs)[0].shape[0]
    chunk = min(chunk, t)
    pad = (-t) % chunk

    if pad:
        # run the clean prefix in chunks, the ragged tail unchunked
        head = jax.tree.map(lambda a: a[: t - (t % chunk)], xs)
        tail = jax.tree.map(lambda a: a[t - (t % chunk):], xs)
        carry, ys_head = chunked_scan(body, carry, head, chunk=chunk,
                                      remat=remat) if t >= chunk else (carry,
                                                                       None)
        carry, ys_tail = jax.lax.scan(body, carry, tail)
        if ys_head is None:
            return carry, ys_tail
        ys = jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=0),
                          ys_head, ys_tail)
        return carry, ys

    nc = t // chunk

    def chunk_body(c, xc):
        return jax.lax.scan(body, c, xc)

    f = jax.checkpoint(chunk_body) if remat else chunk_body
    xs_c = jax.tree.map(
        lambda a: a.reshape(nc, chunk, *a.shape[1:]), xs)
    carry, ys_c = jax.lax.scan(f, carry, xs_c)
    ys = jax.tree.map(
        lambda a: a.reshape(nc * chunk, *a.shape[2:]), ys_c)
    return carry, ys


def stacked_init(layer_init, key, n: int, *args, **kwargs):
    """vmap a per-layer init over ``n`` keys → params stacked on axis 0.

    Returns (stacked_params, pspecs_with_leading_None).
    """
    keys = jax.random.split(key, n)
    params = jax.vmap(lambda k: layer_init(k, *args, **kwargs)[0])(keys)
    _, pspecs = layer_init(keys[0], *args, **kwargs)
    pspecs = jax.tree.map(lambda s: (None, *s), pspecs,
                          is_leaf=lambda x: isinstance(x, tuple))
    return params, pspecs
