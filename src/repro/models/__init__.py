"""Architecture zoo: one functional model family per module.

Every family exposes ``init_params(cfg, key)`` → (params, pspecs) and a
``forward(cfg, params, ...)`` training/inference path built from the paper's
quantized flow (BitLinear projections + absmax barrier + LOP attention).
"""

from repro.models.transformer import forward_train, init_params
