"""Model assembly: decoder stacks, Jamba superblocks, RWKV stacks, enc-dec,
VLM prefix — all under one ``init_params`` / ``forward_train`` API.

Stacks are ``lax.scan`` over layer-stacked params (compile-time compact for
the 80-cell dry-run) with per-layer remat (training memory discipline).
Heterogeneous Jamba layers scan over *superblocks* of ``attn_every`` layers
whose internal pattern (1 attention + 7 Mamba, MoE on odd positions) repeats
exactly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.distributed.partitioning import shard
from repro.models import rwkv6
from repro.models.attention import attention_apply, attention_init
from repro.models.layers import (embedding_apply, embedding_init, head_apply,
                                 linear_init, norm_apply, norm_init)
from repro.models.mamba import mamba_forward, mamba_init
from repro.models.moe import ffn_apply, ffn_init, moe_apply, moe_init
from repro.models.scan_utils import stacked_init


# ---------------------------------------------------------------------------
# Layer init/apply (homogeneous decoder / encoder layers)
# ---------------------------------------------------------------------------

def decoder_layer_init(key, cfg, *, moe: bool, cross: bool = False):
    keys = jax.random.split(key, 3)
    p, s = {}, {}
    p["ln1"], s["ln1"] = norm_init(cfg.d_model, cfg.norm)
    p["attn"], s["attn"] = attention_init(keys[0], cfg)
    if cross:
        p["ln_x"], s["ln_x"] = norm_init(cfg.d_model, cfg.norm)
        p["xattn"], s["xattn"] = attention_init(keys[1], cfg)
    p["ln2"], s["ln2"] = norm_init(cfg.d_model, cfg.norm)
    if moe:
        p["moe"], s["moe"] = moe_init(keys[2], cfg)
    else:
        p["ffn"], s["ffn"] = ffn_init(keys[2], cfg)
    return p, s


def decoder_layer_apply(cfg, p, x, *, positions, causal=True, cross_kv=None,
                        chunk_ctx=None):
    """One decoder layer. ``chunk_ctx`` is the float-path chunk-carry
    (DESIGN.md §Chunked-prefill): when ``x`` is the suffix chunk of a
    longer stream, pass the full pre-layer stream (prefix ‖ chunk) — the
    layer norms it through the same ln1 and lets the chunk's queries
    attend the whole context at their global offset, so the output equals
    the same rows of a full-stream call (no KV cache needed in the
    training/eval path)."""
    x = shard(x, "dp", None, None)
    h = norm_apply(p["ln1"], x, cfg.norm)
    if chunk_ctx is None:
        x = x + attention_apply(cfg, p["attn"], h, positions=positions,
                                causal=causal)
    else:
        hk = norm_apply(p["ln1"], chunk_ctx, cfg.norm)
        x = x + attention_apply(cfg, p["attn"], h, kv_x=hk, causal=causal,
                                positions=positions, chunk_carry=True,
                                q_offset=chunk_ctx.shape[1] - x.shape[1])
    if cross_kv is not None:
        h = norm_apply(p["ln_x"], x, cfg.norm)
        x = x + attention_apply(cfg, p["xattn"], h, kv_x=cross_kv,
                                use_rope=False)
    h = norm_apply(p["ln2"], x, cfg.norm)
    if "moe" in p:
        y, aux = moe_apply(cfg, p["moe"], h)
    else:
        y, aux = ffn_apply(cfg, p["ffn"], h), jnp.float32(0)
    return x + y, aux


# ---------------------------------------------------------------------------
# Jamba superblock
# ---------------------------------------------------------------------------

def superblock_init(key, cfg):
    n = cfg.attn_every
    keys = jax.random.split(key, n)
    p, s = {}, {}
    for j in range(n):
        moe = cfg.is_moe_layer(j)
        sub_p, sub_s = {}, {}
        sub_p["ln1"], sub_s["ln1"] = norm_init(cfg.d_model, cfg.norm)
        if cfg.is_attn_layer(j):
            sub_p["attn"], sub_s["attn"] = attention_init(keys[j], cfg)
        else:
            sub_p["mamba"], sub_s["mamba"] = mamba_init(keys[j], cfg)
        sub_p["ln2"], sub_s["ln2"] = norm_init(cfg.d_model, cfg.norm)
        kj = jax.random.fold_in(keys[j], 1)
        if moe:
            sub_p["moe"], sub_s["moe"] = moe_init(kj, cfg)
        else:
            sub_p["ffn"], sub_s["ffn"] = ffn_init(kj, cfg)
        p[f"sub{j}"], s[f"sub{j}"] = sub_p, sub_s
    return p, s


def superblock_apply(cfg, p, x, *, positions):
    aux_total = jnp.float32(0)
    for j in range(cfg.attn_every):
        sub = p[f"sub{j}"]
        x = shard(x, "dp", None, None)
        h = norm_apply(sub["ln1"], x, cfg.norm)
        if "attn" in sub:
            x = x + attention_apply(cfg, sub["attn"], h, positions=positions)
        else:
            y, _ = mamba_forward(cfg, sub["mamba"], h)
            x = x + y
        h = norm_apply(sub["ln2"], x, cfg.norm)
        if "moe" in sub:
            y, aux = moe_apply(cfg, sub["moe"], h)
            aux_total = aux_total + aux
        else:
            y = ffn_apply(cfg, sub["ffn"], h)
        x = x + y
    return x, aux_total


# ---------------------------------------------------------------------------
# RWKV layer
# ---------------------------------------------------------------------------

def rwkv_layer_init(key, cfg):
    p, s = {}, {}
    p["ln1"], s["ln1"] = norm_init(cfg.d_model, cfg.norm)
    p["tm"], s["tm"] = rwkv6.rwkv_init(key, cfg)
    p["ln2"], s["ln2"] = norm_init(cfg.d_model, cfg.norm)
    return p, s


def rwkv_layer_apply(cfg, p, x):
    b = x.shape[0]
    x = shard(x, "dp", None, None)
    zeros_prev = jnp.zeros((b, 1, cfg.d_model), x.dtype)
    state0 = jnp.zeros((b, cfg.n_heads, cfg.hd, cfg.hd), jnp.float32)
    h = norm_apply(p["ln1"], x, cfg.norm)
    y, _, _ = rwkv6.rwkv_time_mix(cfg, p["tm"], h, zeros_prev, state0)
    x = x + y
    h = norm_apply(p["ln2"], x, cfg.norm)
    y, _ = rwkv6.rwkv_channel_mix(cfg, p["tm"], h, zeros_prev)
    return x + y


# ---------------------------------------------------------------------------
# Full-model init
# ---------------------------------------------------------------------------

def init_params(cfg, key):
    """Returns (params, pspecs) for any family."""
    keys = jax.random.split(key, 8)
    p, s = {}, {}
    p["embed"], s["embed"] = embedding_init(keys[0], cfg.vocab_padded,
                                            cfg.d_model)
    p["ln_f"], s["ln_f"] = norm_init(cfg.d_model, cfg.norm)
    p["head"], s["head"] = linear_init(keys[1], cfg.d_model, cfg.vocab_padded)

    if cfg.family in ("dense", "moe", "vlm"):
        moe = cfg.family == "moe"
        p["layers"], s["layers"] = stacked_init(
            functools.partial(decoder_layer_init, cfg=cfg, moe=moe),
            keys[2], cfg.n_layers)
        if cfg.family == "vlm":
            p["projector"], s["projector"] = linear_init(
                keys[3], cfg.d_model, cfg.d_model, spec=("fsdp", "tp"))
    elif cfg.family == "hybrid":
        assert cfg.n_layers % cfg.attn_every == 0
        p["blocks"], s["blocks"] = stacked_init(
            functools.partial(superblock_init, cfg=cfg),
            keys[2], cfg.n_layers // cfg.attn_every)
    elif cfg.family == "ssm":
        p["layers"], s["layers"] = stacked_init(
            functools.partial(rwkv_layer_init, cfg=cfg),
            keys[2], cfg.n_layers)
    elif cfg.family == "encdec":
        p["enc_layers"], s["enc_layers"] = stacked_init(
            functools.partial(decoder_layer_init, cfg=cfg, moe=False),
            keys[2], cfg.n_encoder_layers)
        p["ln_enc"], s["ln_enc"] = norm_init(cfg.d_model, cfg.norm)
        p["layers"], s["layers"] = stacked_init(
            functools.partial(decoder_layer_init, cfg=cfg, moe=False,
                              cross=True),
            keys[3], cfg.n_layers)
    else:
        raise ValueError(cfg.family)
    return p, s


# ---------------------------------------------------------------------------
# Forward (training path: QAT BitLinear everywhere, f32 reductions)
# ---------------------------------------------------------------------------

def _scan_stack(body, x, stacked, *, remat: bool = True):
    """Scan ``body(x, layer_params) → (x, aux)`` over layer-stacked params."""
    from repro.models.scan_utils import accounting_unroll

    def step(carry, lp):
        x, aux = carry
        x, a = body(x, lp)
        return (x, aux + a), None

    step_fn = jax.checkpoint(step) if remat else step
    length = jax.tree.leaves(stacked)[0].shape[0]
    (x, aux), _ = jax.lax.scan(step_fn, (x, jnp.float32(0)), stacked,
                               unroll=accounting_unroll(length))
    return x, aux


def forward_train(cfg, params, tokens, *, frames=None, patches=None,
                  remat: bool = True):
    """→ (logits [B, T_text, vocab_padded], moe_aux).

    tokens [B, T]; frames [B, S_audio, D] (encdec stub frontend);
    patches [B, n_img, D] (vlm stub vision tower).

    The residual stream runs in ``cfg.act_dtype`` (bf16 in production);
    norms/softmax/loss reductions stay f32 per the absmax-barrier
    discipline; master params are f32 and cast at use.
    """
    act_dtype = jnp.dtype(cfg.act_dtype)
    x = embedding_apply(params["embed"], tokens).astype(act_dtype)
    b, t = tokens.shape
    positions = jnp.arange(t)[None, :]
    aux = jnp.float32(0)

    if cfg.family in ("dense", "moe"):
        body = lambda x, lp: decoder_layer_apply(cfg, lp, x,
                                                 positions=positions)
        x, aux = _scan_stack(body, x, params["layers"], remat=remat)
    elif cfg.family == "vlm":
        assert patches is not None
        proj = patches.astype(x.dtype) @ params["projector"]["w"].astype(
            x.dtype)
        x = jnp.concatenate([proj, x], axis=1)
        positions = jnp.arange(x.shape[1])[None, :]
        body = lambda x, lp: decoder_layer_apply(cfg, lp, x,
                                                 positions=positions)
        x, aux = _scan_stack(body, x, params["layers"], remat=remat)
        x = x[:, patches.shape[1]:]                     # text positions only
    elif cfg.family == "hybrid":
        body = lambda x, bp: superblock_apply(cfg, bp, x, positions=positions)
        x, aux = _scan_stack(body, x, params["blocks"], remat=remat)
    elif cfg.family == "ssm":
        body = lambda x, lp: (rwkv_layer_apply(cfg, lp, x), jnp.float32(0))
        x, aux = _scan_stack(body, x, params["layers"], remat=remat)
    elif cfg.family == "encdec":
        assert frames is not None
        enc = frames.astype(x.dtype)
        enc_pos = jnp.arange(enc.shape[1])[None, :]
        enc_body = lambda e, lp: decoder_layer_apply(
            cfg, lp, e, positions=enc_pos, causal=False)
        enc, _ = _scan_stack(enc_body, enc, params["enc_layers"], remat=remat)
        enc = norm_apply(params["ln_enc"], enc, cfg.norm)
        body = lambda x, lp: decoder_layer_apply(cfg, lp, x,
                                                 positions=positions,
                                                 cross_kv=enc)
        x, aux = _scan_stack(body, x, params["layers"], remat=remat)
    else:
        raise ValueError(cfg.family)

    x = norm_apply(params["ln_f"], x, cfg.norm)
    logits = head_apply(params["head"], x)
    logits = shard(logits, "dp", None, "tp")
    return logits, aux
