"""RWKV6 ("Finch") — attention-free token-mix with data-dependent decay.

Per head h with state S ∈ R^{dh×dh}:

    y_t = r_t · (S_{t-1} + diag(u)·k_t v_tᵀ)
    S_t = diag(w_t)·S_{t-1} + k_t v_tᵀ,   w_t = exp(-exp(w_base + LoRA(m_w)))

Training/prefill use the chunked remat scan; decode is one state update —
"KV cache of seq_len" for this family IS the recurrent state (DESIGN.md §6).
LOP is inapplicable (no attention, nothing to screen); every projection is
still BitLinear under the ternary flow.

TP: heads shard over the model axis (state [B, H/tp, dh, dh]).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.partitioning import shard
from repro.models.layers import linear_apply, linear_init
from repro.models.scan_utils import chunked_scan

W_LORA = 64


def rwkv_init(key, cfg):
    keys = jax.random.split(key, 10)
    d, f, h, dh = cfg.d_model, cfg.d_ff, cfg.n_heads, cfg.hd
    p, sp = {}, {}
    for i, name in enumerate(("wr", "wk", "wv", "wg")):
        p[name], sp[name] = linear_init(keys[i], d, d)
    p["wo"], sp["wo"] = linear_init(keys[4], d, d, spec=("tp", "fsdp"))
    # token-shift lerp coefficients (static mus; rwkv6's data-dep lerp is
    # carried by the decay LoRA below)
    p["mu"] = jnp.full((5, d), 0.5, jnp.float32)        # r,k,v,g,w
    sp["mu"] = (None, None)
    # data-dependent decay: w = exp(-exp(w_base + m_w @ A @ B))
    p["w_base"] = jnp.zeros((d,), jnp.float32) - 4.0
    p["w_lora_a"], sp["w_lora_a"] = linear_init(keys[5], d, W_LORA,
                                                spec=("fsdp", None))
    p["w_lora_b"], sp["w_lora_b"] = linear_init(keys[6], W_LORA, d,
                                                spec=(None, "tp"))
    p["u"] = jax.random.normal(keys[7], (h, dh), jnp.float32) * 0.1
    p["ln_x"] = jnp.ones((d,), jnp.float32)             # per-head groupnorm
    sp.update({"w_base": ("tp",), "u": (None, None), "ln_x": (None,)})
    # channel mix
    p["mu_c"] = jnp.full((2, d), 0.5, jnp.float32)      # r, k
    sp["mu_c"] = (None, None)
    p["wk_c"], sp["wk_c"] = linear_init(keys[8], d, f)
    p["wv_c"], sp["wv_c"] = linear_init(keys[9], f, d, spec=("tp", "fsdp"))
    p["wr_c"], sp["wr_c"] = linear_init(keys[0], d, d)
    return p, sp


def _group_norm(x, gamma, h, dh, eps=1e-5):
    """Per-head layer norm of y [B, H, dh] (rwkv's ln_x)."""
    mu = jnp.mean(x, -1, keepdims=True)
    var = jnp.var(x, -1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return y * gamma.reshape(h, dh)


def _time_mix_inputs(cfg, p, x, x_prev):
    """Token-shift mixes + projections for the whole sequence.

    x [B, T, D]; x_prev [B, 1, D] (token before x[0]). Returns r,k,v,g,w
    shaped [B, T, H, dh] (w per-channel decay in (0,1)).
    """
    b, t, d = x.shape
    h, dh = cfg.n_heads, cfg.hd
    shifted = jnp.concatenate([x_prev.astype(x.dtype), x[:, :-1]], axis=1)
    mixes = [x + p["mu"][i].astype(x.dtype) * (shifted - x)
             for i in range(5)]
    r = linear_apply(p["wr"], mixes[0], quant=cfg.quant)
    k = linear_apply(p["wk"], mixes[1], quant=cfg.quant)
    v = linear_apply(p["wv"], mixes[2], quant=cfg.quant)
    g = jax.nn.silu(linear_apply(p["wg"], mixes[3], quant=cfg.quant))
    lora = linear_apply(
        p["w_lora_b"],
        jnp.tanh(linear_apply(p["w_lora_a"], mixes[4], quant=cfg.quant)),
        quant=cfg.quant)
    w = jnp.exp(-jnp.exp(p["w_base"] + lora))           # [B, T, D] in (0,1)
    to_heads = lambda a: shard(a.reshape(b, t, h, dh), "dp", None, "tp", None)
    return tuple(map(to_heads, (r, k, v, w))) + (g,)


def _wkv_step(u):
    def body(s, inp):
        r_t, k_t, v_t, w_t = inp                        # [B, H, dh]
        kv = k_t[..., :, None] * v_t[..., None, :]      # [B, H, dh, dh]
        y = jnp.einsum("bhk,bhkv->bhv", r_t, s + u[..., None] * kv)
        s = w_t[..., :, None] * s + kv
        return s, y
    return body


def rwkv_time_mix(cfg, p, x, x_prev, state, *, chunk: int = 64):
    """x [B,T,D], x_prev [B,1,D], state [B,H,dh,dh] → (out, last_x, state)."""
    b, t, d = x.shape
    h, dh = cfg.n_heads, cfg.hd
    r, k, v, w, g = _time_mix_inputs(cfg, p, x, x_prev)
    xs = tuple(a.transpose(1, 0, 2, 3) for a in (r, k, v, w))
    state, ys = chunked_scan(_wkv_step(p["u"]), state, xs, chunk=chunk)
    y = ys.transpose(1, 0, 2, 3)                        # [B, T, H, dh]
    y = _group_norm(y, p["ln_x"], h, dh)
    y = (y.reshape(b, t, d) * g).astype(x.dtype)
    out = linear_apply(p["wo"], y, quant=cfg.quant)
    return out, x[:, -1:], state


def rwkv_channel_mix(cfg, p, x, x_prev):
    """x [B,T,D] → (out, last_x)."""
    shifted = jnp.concatenate([x_prev.astype(x.dtype), x[:, :-1]], axis=1)
    m_r = x + p["mu_c"][0].astype(x.dtype) * (shifted - x)
    m_k = x + p["mu_c"][1].astype(x.dtype) * (shifted - x)
    k = jnp.square(jax.nn.relu(linear_apply(p["wk_c"], m_k, quant=cfg.quant)))
    k = shard(k, "dp", None, "tp")
    r = jax.nn.sigmoid(linear_apply(p["wr_c"], m_r, quant=cfg.quant))
    out = (r * linear_apply(p["wv_c"], k, quant=cfg.quant)).astype(x.dtype)
    return out, x[:, -1:]


def rwkv_state_shape(cfg, batch: int):
    """Decode-state ShapeDtypeStructs (per layer)."""
    return {
        "wkv": jax.ShapeDtypeStruct(
            (batch, cfg.n_heads, cfg.hd, cfg.hd), jnp.float32),
        "x_tm": jax.ShapeDtypeStruct((batch, 1, cfg.d_model), jnp.float32),
        "x_cm": jax.ShapeDtypeStruct((batch, 1, cfg.d_model), jnp.float32),
    }
