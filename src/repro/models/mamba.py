"""Mamba (selective SSM) block — the non-attention layer of Jamba.

Training/prefill run the selective scan as a chunked sequential recurrence
(``chunked_scan``): dt/B/C are projected for the whole sequence (cheap), the
O(T) state recurrence carries ``h [B, Di, S]`` and per-chunk remat caps AD
residuals. Decode is a single-step state update.

TP: the inner dim Di is sharded over the model axis (depthwise conv, A, D,
dt all per-channel → embarrassingly TP); in/out projections are the usual
column/row-parallel pair.

LOP/KV-cache machinery is inapplicable here (no KV cache — DESIGN.md
§Arch-applicability); the ternary BitLinear flow still covers in/out/x/dt
projections.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.partitioning import shard
from repro.models.layers import linear_apply, linear_init
from repro.models.scan_utils import chunked_scan


def dt_rank(cfg) -> int:
    return -(-cfg.d_model // 16)


def mamba_init(key, cfg):
    keys = jax.random.split(key, 6)
    d, di, s, ck = cfg.d_model, cfg.d_inner, cfg.mamba_d_state, cfg.mamba_conv
    r = dt_rank(cfg)
    p, sp = {}, {}
    p["in_proj"], sp["in_proj"] = linear_init(keys[0], d, 2 * di)
    p["x_proj"], sp["x_proj"] = linear_init(keys[1], di, r + 2 * s,
                                            spec=("tp", None))
    p["dt_proj"], sp["dt_proj"] = linear_init(keys[2], r, di,
                                              spec=(None, "tp"), bias=True)
    p["conv_w"] = jax.random.normal(keys[3], (ck, di), jnp.float32) * 0.1
    p["conv_b"] = jnp.zeros((di,), jnp.float32)
    # S4-style A init: -[1..S] per channel
    p["A_log"] = jnp.log(jnp.broadcast_to(
        jnp.arange(1, s + 1, dtype=jnp.float32), (di, s)))
    p["D"] = jnp.ones((di,), jnp.float32)
    p["out_proj"], sp["out_proj"] = linear_init(keys[5], di, d,
                                                spec=("tp", "fsdp"))
    sp.update({"conv_w": (None, "tp"), "conv_b": ("tp",),
               "A_log": ("tp", None), "D": ("tp",)})
    return p, sp


def _causal_conv(x, conv_w, conv_b, conv_state=None):
    """Depthwise causal conv. x [B, T, Di]; conv_w [ck, Di].

    conv_state [B, ck-1, Di] (decode) prepends history; returns (y, new_state).
    """
    ck = conv_w.shape[0]
    if conv_state is None:
        xp = jnp.pad(x, ((0, 0), (ck - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([conv_state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * conv_w[i] for i in range(ck))
    new_state = xp[:, xp.shape[1] - (ck - 1):]
    return y + conv_b, new_state


def _ssm_inputs(cfg, p, u):
    """Project u [B, T, D] → (x, z, dt, B_ssm, C_ssm) for the scan."""
    s = cfg.mamba_d_state
    r = dt_rank(cfg)
    xz = linear_apply(p["in_proj"], u, quant=cfg.quant)
    x, z = jnp.split(xz, 2, axis=-1)                    # [B, T, Di] each
    x = shard(x, "dp", None, "tp")
    return x, z, s, r


def _ssm_project(cfg, p, x):
    s = cfg.mamba_d_state
    r = dt_rank(cfg)
    x_dbl = linear_apply(p["x_proj"], x, quant=cfg.quant)
    dt, b_ssm, c_ssm = jnp.split(x_dbl, [r, r + s], axis=-1)
    dt = jax.nn.softplus(linear_apply(p["dt_proj"], dt, quant=cfg.quant))
    return dt, b_ssm, c_ssm                             # [B,T,Di],[B,T,S]×2


def _scan_step(a_log, d_resid):
    def body(h, inp):
        x_t, z_t, dt_t, b_t, c_t = inp
        # h [B, Di, S]; discretize: h = exp(dt·A)·h + dt·x·B
        da = jnp.exp(dt_t[..., None] * (-jnp.exp(a_log)))      # [B, Di, S]
        h = da * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bds,bs->bd", h, c_t) + d_resid * x_t
        return h, y
    return body


def mamba_forward(cfg, p, u, *, chunk: int = 64):
    """Training/prefill pass. u [B, T, D] → (y [B, T, D], final_state)."""
    b, t, _ = u.shape
    di, s = cfg.d_inner, cfg.mamba_d_state
    x, z, _, _ = _ssm_inputs(cfg, p, u)
    x, conv_state = _causal_conv(x, p["conv_w"], p["conv_b"])
    x = jax.nn.silu(x)
    dt, b_ssm, c_ssm = _ssm_project(cfg, p, x)

    xs = (x.transpose(1, 0, 2), z.transpose(1, 0, 2), dt.transpose(1, 0, 2),
          b_ssm.transpose(1, 0, 2), c_ssm.transpose(1, 0, 2))
    h0 = jnp.zeros((b, di, s), jnp.float32)
    h, ys = chunked_scan(_scan_step(p["A_log"], p["D"]), h0, xs, chunk=chunk)
    y = ys.transpose(1, 0, 2)                           # [B, T, Di]
    y = y * jax.nn.silu(z)
    out = linear_apply(p["out_proj"], y.astype(u.dtype), quant=cfg.quant)
    return out, {"ssm": h, "conv": conv_state}


def mamba_decode_step(cfg, p, u, state):
    """One-token decode. u [B, 1, D]; state {ssm [B,Di,S], conv [B,ck-1,Di]}.

    Returns (y [B, 1, D], new_state).
    """
    x, z, _, _ = _ssm_inputs(cfg, p, u)
    x, conv_state = _causal_conv(x, p["conv_w"], p["conv_b"], state["conv"])
    x = jax.nn.silu(x)
    dt, b_ssm, c_ssm = _ssm_project(cfg, p, x)
    body = _scan_step(p["A_log"], p["D"])
    h, y = body(state["ssm"], (x[:, 0], z[:, 0], dt[:, 0],
                               b_ssm[:, 0], c_ssm[:, 0]))
    y = y[:, None] * jax.nn.silu(z)
    out = linear_apply(p["out_proj"], y.astype(u.dtype), quant=cfg.quant)
    return out, {"ssm": h, "conv": conv_state}


def mamba_state_shape(cfg, batch: int):
    """ShapeDtypeStructs of the decode state (for cache allocation)."""
    return {
        "ssm": jax.ShapeDtypeStruct(
            (batch, cfg.d_inner, cfg.mamba_d_state), jnp.float32),
        "conv": jax.ShapeDtypeStruct(
            (batch, cfg.mamba_conv - 1, cfg.d_inner), jnp.float32),
    }
