"""Shared building blocks: BitLinear, norms, embeddings, rotary.

Parameters are plain nested dicts of arrays; every ``*_init`` returns
``(params, pspecs)`` where ``pspecs`` mirrors the param tree with tuples of
*logical* axes (resolved by :mod:`repro.distributed.partitioning`).

The quantized flow follows the paper: projections are BitLinear (absmean
ternary weights × absmax int8 activations, trained with STE); embeddings,
norms, router and the LM head stay high-precision (BitNet's convention).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.qlinear import is_packed, qlinear
from repro.core.quantization import rmsnorm
from repro.core.ternary import bitlinear_qat


# ---------------------------------------------------------------------------
# Linear / BitLinear
# ---------------------------------------------------------------------------

def linear_init(key, d_in: int, d_out: int, *, bias: bool = False,
                spec=("fsdp", "tp"), dtype=jnp.float32):
    w = jax.random.normal(key, (d_in, d_out), dtype) * (d_in ** -0.5)
    params = {"w": w}
    pspecs = {"w": spec}
    if bias:
        params["b"] = jnp.zeros((d_out,), dtype)
        pspecs["b"] = (spec[-1],)
    return params, pspecs


def linear_apply(params, x, *, quant: str):
    """Linear dispatch on param format:

      * serving nodes (``{"packed", "scale"}``) → the fused TINT entry
        (absmax barrier + packed-ternary GEMM + dequant epilogue in ONE
        dispatch, DESIGN.md §TINT-projection-fusion — so the same model
        code serves quantized weights),
      * training nodes (``{"w"}``) → QAT BitLinear (``quant="ternary"``)
        or plain matmul (``"bf16"``).
    """
    if is_packed(params):
        return qlinear(params, x)
    if quant == "ternary":
        y = bitlinear_qat(x, params["w"])
    else:
        y = x @ params["w"].astype(x.dtype)
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# Norms (f32 reductions per the absmax barrier discipline)
# ---------------------------------------------------------------------------

def norm_init(d: int, kind: str, dtype=jnp.float32):
    if kind == "rmsnorm":
        return {"g": jnp.ones((d,), dtype)}, {"g": (None,)}
    return ({"g": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)},
            {"g": (None,), "b": (None,)})


def norm_apply(params, x, kind: str, eps: float = 1e-6):
    if kind == "rmsnorm":
        return rmsnorm(x, params["g"], eps)
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["g"] + params["b"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------

def embedding_init(key, vocab_padded: int, d: int, dtype=jnp.float32):
    e = jax.random.normal(key, (vocab_padded, d), dtype) * 0.02
    return {"table": e}, {"table": ("tp", "fsdp")}


def embedding_apply(params, tokens):
    return jnp.take(params["table"], tokens, axis=0)


def head_apply(params, x):
    """LM head (high-precision): [..., d] @ [d, V] → logits."""
    return x @ params["w"]


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x [..., S, H, dh] (dh even), positions [..., S] → rotated x."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs      # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]                            # [..., S, 1, half]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin,
                           xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)
