"""Ternary weights (BitNet b1.58) and the TINT-core adaptation (paper §II-A).

The ASIC's TINT core streams *packed 2-bit ternary codes* into a
multiplier-free select-accumulate array. On TPU the multiplier-free part is
moot (the MXU does int8 dots natively); what transfers is the packed code
stream: weights live in HBM as 2-bit codes (4 per byte) and are unpacked to
int8 inside VMEM by the Pallas kernel (``repro.kernels.ternary_matmul``),
cutting HBM weight traffic 4× vs int8 / 8× vs bf16 — precisely the resource
that bounds decode.

This module provides the pure-jnp reference semantics: absmean ternary
quantization (BitNet b1.58), 2-bit pack/unpack, and the BitLinear forward in
both inference (integer-domain) and QAT (STE) flavours.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.quantization import (QuantizedTensor, dequantize, int8_matmul,
                                     quantize, ste_quantize)

EPS = 1e-5

# 2-bit code assignment:  0 -> 0b00,  +1 -> 0b01,  -1 -> 0b10  (0b11 unused)
_CODE_ZERO, _CODE_POS, _CODE_NEG = 0, 1, 2


class TernaryWeight(NamedTuple):
    """Ternary weight in packed form: 2-bit codes, 4 per byte, packed along
    the *reduction* (first) axis so the kernel unpacks contiguous k-blocks."""

    packed: jax.Array   # uint8 [k//4, n]
    scale: jax.Array    # f32 scalar or [1, n] (per-channel variant)
    shape: tuple        # original (k, n)


def ternary_quantize(w: jax.Array, per_channel: bool = False):
    """BitNet b1.58 absmean quantization.

    γ = mean|W| ;  Wt = clip(round(W / γ), -1, +1).  Returns (Wt int8, γ).
    ``per_channel=True`` is a beyond-paper variant (per-output-channel γ).
    """
    w = w.astype(jnp.float32)
    axis = 0 if per_channel else None
    gamma = jnp.maximum(jnp.mean(jnp.abs(w), axis=axis, keepdims=True), EPS)
    wt = jnp.clip(jnp.round(w / gamma), -1, 1).astype(jnp.int8)
    return wt, gamma.astype(jnp.float32)


def ste_ternary(w: jax.Array, per_channel: bool = False) -> jax.Array:
    """QAT forward value for weights: dequantized ternary, identity gradient."""
    wt, gamma = ternary_quantize(w, per_channel=per_channel)
    wq = (wt.astype(jnp.float32) * gamma).astype(w.dtype)
    return w + jax.lax.stop_gradient(wq - w)


# ---------------------------------------------------------------------------
# 2-bit packing (the TINT code stream)
# ---------------------------------------------------------------------------

def pack_ternary(wt: jax.Array) -> jax.Array:
    """Pack int8 ternary values {-1,0,+1} [k, n] → uint8 codes [k//4, n].

    Code j of a byte holds row ``4*i + j``; k must be a multiple of 4
    (pad upstream).
    """
    k, n = wt.shape
    assert k % 4 == 0, f"k={k} must be a multiple of 4 (pad before packing)"
    codes = jnp.where(wt > 0, _CODE_POS, jnp.where(wt < 0, _CODE_NEG, _CODE_ZERO))
    codes = codes.astype(jnp.uint8).reshape(k // 4, 4, n)
    return (codes[:, 0] | (codes[:, 1] << 2) | (codes[:, 2] << 4)
            | (codes[:, 3] << 6))


def unpack_ternary(packed: jax.Array, k: int) -> jax.Array:
    """Unpack uint8 codes [k//4, n] → int8 ternary [k, n]."""
    kp, n = packed.shape
    assert kp * 4 == k
    parts = [(packed >> (2 * j)) & 0x3 for j in range(4)]
    codes = jnp.stack(parts, axis=1).reshape(k, n)
    return (jnp.where(codes == _CODE_POS, 1, 0)
            - jnp.where(codes == _CODE_NEG, 1, 0)).astype(jnp.int8)


def make_ternary_weight(w: jax.Array, per_channel: bool = False) -> TernaryWeight:
    wt, gamma = ternary_quantize(w, per_channel=per_channel)
    return TernaryWeight(packed=pack_ternary(wt), scale=gamma, shape=w.shape)


# ---------------------------------------------------------------------------
# BitLinear forwards (reference semantics; kernels provide the fast path)
# ---------------------------------------------------------------------------

def bitlinear_infer(xq: QuantizedTensor, tw: TernaryWeight) -> jax.Array:
    """Inference BitLinear: int8 activations × ternary weights → f32.

    The entire GEMM runs in the integer domain (TINT semantics); one fused
    dequantization by (activation scale × weight γ) at the output side.
    """
    wt = unpack_ternary(tw.packed, tw.shape[0])
    return int8_matmul(xq, wt, tw.scale)


def bitlinear_qat(x: jax.Array, w: jax.Array,
                  per_channel: bool = False) -> jax.Array:
    """Training BitLinear (BitNet): STE-quantized activations and weights.

    Forward ≡ ternary×int8 semantics; backward flows straight through, so
    autodiff trains the latent full-precision master weights. The matmul
    runs in the activation dtype (bf16 in production) with f32 accumulation
    — master weights stay f32 and are cast at use (MaxText-style).
    """
    xq = ste_quantize(x)                       # per-token absmax int8
    wq = ste_ternary(w, per_channel=per_channel).astype(x.dtype)
    return jnp.dot(xq, wq, preferred_element_type=jnp.float32).astype(x.dtype)


def bitlinear_ref(x: jax.Array, tw: TernaryWeight) -> jax.Array:
    """Convenience: f32/bf16 in → quantize (barrier) → integer GEMM → f32."""
    return bitlinear_infer(quantize(x), tw)


def memory_footprint_bytes(shape: tuple, fmt: str) -> int:
    """Weight storage model used by the benchmarks (paper's 7-8× claim)."""
    k, n = shape
    return {
        "bf16": 2 * k * n,
        "int8": k * n,
        "ternary_packed": (k // 4) * n + 4,   # 2 bit/weight + one f32 scale
    }[fmt]
