"""Leading-One Prediction (LOP) predictive sparse attention — paper §III-A.

The surrogate score

    ŝ(q,k) = Σᵢ sgn(qᵢ)·sgn(kᵢ)·2^(LO(qᵢ)+LO(kᵢ)),   LO(x) = ⌊log₂|x|⌋

is *exactly* the dot product of power-of-two-rounded vectors
``pot(x) = sgn(x)·2^LO(|x|)`` — the key TPU-native observation: the ASIC's
barrel-shift ExpAdd array becomes an int8 MXU matmul against a 4-bit packed
feature cache (sgn‖LO per element, two per byte → the feature cache reads
half the bytes of the exact int8 keys).

Selection is *comparison-free* (paper's bucketized k-degree selector [6]):
scores are bucketized, a high-to-low prefix scan finds the cut bin where the
cumulative count first reaches K, and indices are emitted without any
pairwise comparator tree. We keep the paper's *block* granularity ("only
those candidate blocks are requested") so KV fetches stay contiguous and
TPU-aligned.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# LO field values 0..6 encode ⌊log₂|x|⌋ for |x| ∈ [1,127]; 7 encodes x == 0.
LO_ZERO = 7


def leading_one(x: jax.Array) -> jax.Array:
    """⌊log₂|x|⌋ for int8 magnitudes, exactly, without floats.

    |x| ∈ [1,127] → LO ∈ [0,6];  x == 0 → LO_ZERO (7).
    """
    v = jnp.abs(x.astype(jnp.int32))
    lo = ((v >= 2).astype(jnp.int32) + (v >= 4) + (v >= 8)
          + (v >= 16) + (v >= 32) + (v >= 64))
    return jnp.where(v == 0, LO_ZERO, lo).astype(jnp.int32)


def pot(x: jax.Array) -> jax.Array:
    """Power-of-two rounding: sgn(x)·2^LO(|x|) as int8 (0 stays 0, max ±64)."""
    lo = leading_one(x)
    mag = jnp.where(lo == LO_ZERO, 0, jnp.left_shift(1, jnp.minimum(lo, 6)))
    return (jnp.sign(x.astype(jnp.int32)) * mag).astype(jnp.int8)


def lop_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """Surrogate scores ŝ = pot(q)·pot(k)ᵀ in int32 (multiplier-free on the
    ASIC; an int8 MXU matmul here).  q: [..., d], k: [..., M, d] → [..., M]."""
    qp, kp = pot(q), pot(k)
    return jnp.einsum("...d,...md->...m", qp, kp,
                      preferred_element_type=jnp.int32)


# ---------------------------------------------------------------------------
# 4-bit (sgn‖LO) feature packing — the LOP feature cache
# ---------------------------------------------------------------------------

def lop_features(x: jax.Array) -> jax.Array:
    """Per-element 4-bit feature nibble: (sgn_bit << 3) | LO.  int8 storage of
    the nibble is the reference layout; `pack_features` halves it."""
    lo = leading_one(x)
    sgn = (x < 0).astype(jnp.int32)
    return ((sgn << 3) | lo).astype(jnp.uint8)


def features_to_pot(feat: jax.Array) -> jax.Array:
    """Decode nibbles back to pot() int8 values."""
    lo = (feat & 0x7).astype(jnp.int32)
    sgn = ((feat >> 3) & 0x1).astype(jnp.int32)
    mag = jnp.where(lo == LO_ZERO, 0, jnp.left_shift(1, jnp.minimum(lo, 6)))
    return ((1 - 2 * sgn) * mag).astype(jnp.int8)


def pack_features(feat: jax.Array) -> jax.Array:
    """Pack nibble features [..., d] (d even) → uint8 [..., d//2]."""
    lo_nib = feat[..., 0::2]
    hi_nib = feat[..., 1::2]
    return (lo_nib | (hi_nib << 4)).astype(jnp.uint8)


def unpack_features(packed: jax.Array) -> jax.Array:
    """uint8 [..., d//2] → nibble features [..., d]."""
    lo_nib = packed & 0xF
    hi_nib = (packed >> 4) & 0xF
    return jnp.stack([lo_nib, hi_nib], axis=-1).reshape(
        packed.shape[:-1] + (packed.shape[-1] * 2,)).astype(jnp.uint8)


# ---------------------------------------------------------------------------
# Comparison-free top-K (bucketized histogram / prefix-scan selector)
# ---------------------------------------------------------------------------

# One bucket count shared by every selector instance: the oracle-side
# `serving/lop_select.select_blocks` and the in-kernel selector of
# `kernels/decode_attention` must bucketize identically to pick identical
# candidate sets.
DEFAULT_N_BUCKETS = 64


def _cumsum_lanes(x: jax.Array) -> jax.Array:
    """Inclusive prefix sum along the LANE axis of a 2-D (sublane, lane)
    tile, expressed as one f32 MXU dot against an upper-triangular ones
    matrix — the Mosaic-friendly retile of ``jnp.cumsum(x, -1)``.

    ``cumsum[r, j] = Σ_{i ≤ j} x[r, i] = (x @ T)[r, j]`` with
    ``T[i, j] = (i ≤ j)``. Counts are integers far below 2²⁴, so the f32
    accumulation is exact and the result is bitwise the integer cumsum.
    """
    m = x.shape[-1]
    rows = jax.lax.broadcasted_iota(jnp.int32, (m, m), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (m, m), 1)
    tri = (rows <= cols).astype(jnp.float32)
    out = jax.lax.dot(x.astype(jnp.float32), tri,
                      preferred_element_type=jnp.float32)
    return out.astype(jnp.int32)


def comparison_free_rank(s: jax.Array, k: int,
                         n_buckets: int = DEFAULT_N_BUCKETS) -> jax.Array:
    """Emission ranks of the bucketized selector: f32 [R, M] → int32 [R, M].

    THE single implementation of the comparison-free selection order —
    `comparison_free_topk` (jnp oracle side) and the fused decode kernel
    (`kernels/decode_attention`, where this runs *inside* the Pallas body)
    both derive from it, so they cannot drift apart. Scores of −inf (or
    any non-finite) are invalid and never selected. Per row:

    1. bucketize scores into ``n_buckets`` linear ranges,
    2. per-bucket ≥-counts + cut bin where the high-to-low cumulative
       count first reaches K,
    3. entries above the cut bin rank first in ascending index order, then
       the cut bin fills the remainder (the ASIC's k-wide priority
       encoders).

    ``rank < k`` ⇔ selected; everything else gets the sentinel M + k + 1.

    Every op keeps 2-D (sublane, lane) shape so Mosaic can tile it on
    real TPU: the histogram's high-to-low cumulative count is computed
    directly as ``cnt_ge[r, b] = #{m : bucket[r, m] ≥ b}`` — a static
    loop over the ``n_buckets`` lanes of [R, M] broadcast-compares
    (replacing the old rank-3 [R, M, n_buckets] one-hot + flat cumsum) —
    and the index-order prefix sums run as f32 MXU dots against a
    triangular ones matrix (:func:`_cumsum_lanes`). All counts are exact
    in f32 (≪ 2²⁴), so the ranks are bitwise the flat-op ranks.
    """
    m = s.shape[-1]
    finite = jnp.isfinite(s)
    smin = jnp.min(jnp.where(finite, s, jnp.inf), -1, keepdims=True)
    smax = jnp.max(jnp.where(finite, s, -jnp.inf), -1, keepdims=True)
    span = jnp.maximum(smax - smin, 1e-9)
    bucket = jnp.clip(((s - smin) / span * n_buckets).astype(jnp.int32),
                      0, n_buckets - 1)
    bucket = jnp.where(finite, bucket, -1)          # invalid → below range

    # high-to-low cumulative count per bucket, 2-D throughout: one
    # [R, 1] lane-reduction per (static) bucket id
    cnt_ge = jnp.concatenate(
        [jnp.sum((bucket >= b).astype(jnp.int32), -1, keepdims=True)
         for b in range(n_buckets)], axis=-1)        # [R, n_buckets]
    reach = cnt_ge >= k
    bin_ids = jax.lax.broadcasted_iota(jnp.int32, reach.shape, 1)
    cut = jnp.where(jnp.any(reach, -1, keepdims=True),
                    jnp.max(jnp.where(reach, bin_ids, -1), -1, keepdims=True),
                    0)                               # [R, 1]

    above = bucket > cut
    at_cut = bucket == cut
    n_above = jnp.sum(above.astype(jnp.int32), -1, keepdims=True)
    rank_above = _cumsum_lanes(above.astype(jnp.float32)) - 1
    rank_cut = n_above + _cumsum_lanes(at_cut.astype(jnp.float32)) - 1
    big = m + k + 1
    rank = jnp.where(above, rank_above,
                     jnp.where(at_cut, rank_cut, big))
    return jnp.where(rank < k, rank, big).astype(jnp.int32)


def comparison_free_topk(scores: jax.Array, k: int,
                         n_buckets: int = DEFAULT_N_BUCKETS,
                         valid: jax.Array | None = None):
    """Select the top-k indices of ``scores`` [M] without pairwise compares.

    Emission order comes from :func:`comparison_free_rank`; this wrapper
    scatters the ranked indices into a dense [k] list. Returns
    (indices [k] int32, gate [k] bool).  With ``valid`` given, invalid
    positions never get selected.
    """
    m = scores.shape[-1]
    s = scores.astype(jnp.float32)
    if valid is not None:
        s = jnp.where(valid, s, -jnp.inf)
    rank = comparison_free_rank(s[None, :], k, n_buckets)[0]
    sel = rank < k
    out = jnp.zeros((k,), jnp.int32).at[jnp.where(sel, rank, k)].set(
        jnp.arange(m, dtype=jnp.int32), mode="drop")
    gate = jnp.arange(k) < jnp.minimum(jnp.sum(sel.astype(jnp.int32)), k)
    return out, gate


def block_reduce_scores(scores: jax.Array, block: int,
                        mode: str = "max") -> jax.Array:
    """Token scores [..., M] → block scores [..., M//block] (paper fetches
    candidate *blocks*, keeping KV reads contiguous)."""
    *lead, m = scores.shape
    assert m % block == 0, f"M={m} not a multiple of block={block}"
    s = scores.reshape(*lead, m // block, block)
    return jnp.max(s, axis=-1) if mode == "max" else jnp.sum(s, axis=-1)


def exact_topk(scores: jax.Array, k: int):
    """Comparator-based reference selector (oracle for recall tests)."""
    _, idx = jax.lax.top_k(scores, k)
    return idx


def kv_traffic_bytes(m: int, d: int, k: int, *, packed_features: bool = True,
                     with_lop: bool = True) -> int:
    """KV bytes fetched per (head, query) — the Fig. 8 traffic model.

    Without LOP: read all M keys + M values (int8).  With LOP: read the
    feature cache (4-bit packed → d/2 bytes/key) + K exact keys + K values.
    """
    if not with_lop:
        return 2 * m * d
    feat = m * (d // 2 if packed_features else d)
    return feat + 2 * k * d
