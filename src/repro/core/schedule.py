"""Head-level streaming schedule (paper §III-B) — TPU adaptation.

The ASIC's one-head-offset pipeline (TINT computes Q/K/V for head h+1 while
BoothFlex runs attention for head h) exists to avoid materializing all-head
Q/K/V in SRAM. The XLA analogue: express MHA as a `lax.scan` over head
*groups* whose body fuses projection → attention → partial output projection.
No full [B, S, 3·H·d] buffer ever exists; peak live activation is one head
group. The conventional schedule (materialize all heads, then attend) is kept
as the ablation baseline for the Fig. 9 benchmark.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def materialized_mha(x, wq, wk, wv, wo, *, n_heads: int, head_dim: int,
                     attn_fn):
    """Conventional schedule: compute Q/K/V for ALL heads, then attention.

    x [B,S,D]; wq/wk/wv [D, H*d]; wo [H*d, D]; attn_fn(q,k,v)->o per head
    batch. Used as the ablation baseline (extra round of writes/re-reads).
    """
    b, s, dm = x.shape
    q = (x @ wq).reshape(b, s, n_heads, head_dim)
    k = (x @ wk).reshape(b, s, n_heads, head_dim)
    v = (x @ wv).reshape(b, s, n_heads, head_dim)
    o = attn_fn(q, k, v)                              # [B,S,H,d]
    return o.reshape(b, s, n_heads * head_dim) @ wo


def streamed_mha(x, wq, wk, wv, wo, *, n_heads: int, head_dim: int,
                 attn_fn, group: int = 1):
    """Head-level streaming: scan over head groups; each step projects one
    group, attends, and accumulates its slice of the output projection.

    Peak live Q/K/V = one group instead of H heads; the output is accumulated
    output-stationary, matching the paper's OS dataflow.
    """
    b, s, dm = x.shape
    assert n_heads % group == 0
    n_steps = n_heads // group
    gd = group * head_dim

    wq_g = wq.reshape(dm, n_steps, gd).transpose(1, 0, 2)
    wk_g = wk.reshape(dm, n_steps, gd).transpose(1, 0, 2)
    wv_g = wv.reshape(dm, n_steps, gd).transpose(1, 0, 2)
    wo_g = wo.reshape(n_steps, gd, dm)

    def body(acc, ws):
        wq_h, wk_h, wv_h, wo_h = ws
        q = (x @ wq_h).reshape(b, s, group, head_dim)
        k = (x @ wk_h).reshape(b, s, group, head_dim)
        v = (x @ wv_h).reshape(b, s, group, head_dim)
        o = attn_fn(q, k, v).reshape(b, s, gd)
        return acc + o @ wo_h, None

    acc0 = jnp.zeros((b, s, dm), x.dtype)
    acc, _ = jax.lax.scan(body, acc0, (wq_g, wk_g, wv_g, wo_g))
    return acc


def standard_softmax_attention(q, k, v, *, causal: bool = True):
    """Per-head-batch attention used by both schedules: q/k/v [B,S,H,d]."""
    d = q.shape[-1]
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) / (d ** 0.5)
    if causal:
        s = q.shape[1]
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)
