"""Absmax quantization barrier (paper §III-C).

The paper standardizes every cross-core interface as an
``(integer vector, single scale)`` pair: the per-vector absmax is itself a
vector-wide reduction, so it doubles as the synchronization barrier between a
producing linear tile stream and the consuming core. We express that contract
as a first-class :class:`QuantizedTensor` pytree — int8 values plus an f32
scale per *vector* (last axis by default) — and keep all reductions
(absmax, RMSNorm sum-of-squares, softmax max/sum-exp) in f32 while the linear
algebra stays in the integer domain.

Training uses the straight-through estimator (STE) so the same modules serve
BitNet-style quantization-aware training.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

INT8_MAX = 127.0
EPS = 1e-5


class QuantizedTensor(NamedTuple):
    """(integer vector, single scale) pair — the paper's cross-core interface.

    ``values`` is int8 with shape [..., d]; ``scale`` is f32 with shape
    [..., 1] such that ``dequantize(qt) ≈ values * scale``.
    """

    values: jax.Array  # int8
    scale: jax.Array   # f32, broadcastable to values

    @property
    def shape(self):
        return self.values.shape

    @property
    def dtype(self):
        return self.values.dtype


def absmax_scale(x: jax.Array, axis: int = -1) -> jax.Array:
    """Per-vector absmax reduction α = maxᵢ|xᵢ| / 127 (the barrier)."""
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    return jnp.maximum(amax, EPS).astype(jnp.float32) / INT8_MAX


def quantize(x: jax.Array, axis: int = -1) -> QuantizedTensor:
    """Quantize once per vector after the absmax reduction completes."""
    scale = absmax_scale(x, axis=axis)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -INT8_MAX, INT8_MAX)
    return QuantizedTensor(values=q.astype(jnp.int8), scale=scale)


def dequantize(qt: QuantizedTensor, dtype=jnp.float32) -> jax.Array:
    """Single output-side dequantization at the consumer."""
    return (qt.values.astype(jnp.float32) * qt.scale).astype(dtype)


def fake_quantize(x: jax.Array, axis: int = -1) -> jax.Array:
    """Quantize→dequantize in the input dtype (QAT forward value)."""
    return dequantize(quantize(x, axis=axis), dtype=x.dtype)


def ste_quantize(x: jax.Array, axis: int = -1) -> jax.Array:
    """Straight-through estimator: forward = fake-quantized, grad = identity."""
    return x + jax.lax.stop_gradient(fake_quantize(x, axis=axis) - x)


def int8_matmul(xq: QuantizedTensor, wq_values: jax.Array,
                w_scale: jax.Array) -> jax.Array:
    """Integer-domain GEMM with fused output dequantization.

    ``xq.values [..., k] @ wq_values [k, n]`` accumulated in int32, then one
    dequantization by the product of scales (paper Fig. 6: "dequantization
    fused at the consumer").
    """
    acc = jax.lax.dot_general(
        xq.values, wq_values,
        dimension_numbers=(((xq.values.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    return acc.astype(jnp.float32) * xq.scale * w_scale


def rmsnorm_reduction(x: jax.Array) -> jax.Array:
    """Sum-of-squares reduction for RMSNorm (kept in f32, overlappable)."""
    return jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)


def rmsnorm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    ms = rmsnorm_reduction(x)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(ms + eps)
    return (y * gamma.astype(jnp.float32)).astype(x.dtype)


def online_softmax_stats(logits: jax.Array, axis: int = -1):
    """Running-max and sum-of-exponentials (the paper's softmax reductions)."""
    m = jnp.max(logits, axis=axis, keepdims=True)
    s = jnp.sum(jnp.exp(logits - m), axis=axis, keepdims=True)
    return m, s
