"""Predictive sparse attention (paper §III-A system view).

Decode-time flow per (batch, head):

    1. **screen**  — surrogate scores over all M cached tokens from the 4-bit
       LOP feature cache (multiplier-free on the ASIC; int8 pot-dot here),
    2. **select**  — comparison-free top-K at *block* granularity, so the KV
       fetches the memory system sees are short contiguous reads,
    3. **gather**  — fetch only the K candidate blocks of exact int8 K/V,
    4. **exact**   — softmax attention confined to the candidates
       (f32 reductions per the absmax barrier; integer GEMMs).

Average KV traffic scales with K rather than M: ×(1 − K/M) reduction,
no retraining (the screen only reorders which keys are *read*).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import lop
from repro.core.quantization import online_softmax_stats

NEG_INF = -1e30


def _gather_blocks(x: jax.Array, block_idx: jax.Array, block: int) -> jax.Array:
    """x [M, ...] , block_idx [nb] → [nb*block, ...] contiguous candidate rows."""
    m = x.shape[0]
    xb = x.reshape(m // block, block, *x.shape[1:])
    return xb[block_idx].reshape(block_idx.shape[0] * block, *x.shape[1:])


def _single_head_sparse_attention(q, k_cache, v_cache, feat_cache, valid,
                                  *, k_blocks: int, block: int,
                                  n_buckets: int, softmax_scale: float):
    """q [d], caches [M, d] (int8) / feat [M, d] nibbles, valid [M] bool."""
    m, d = k_cache.shape

    # 1. screen — pot-dot surrogate from the feature cache
    qp = lop.pot(q)
    kp = lop.features_to_pot(feat_cache)
    s_hat = jnp.einsum("d,md->m", qp, kp, preferred_element_type=jnp.int32)

    # 2. comparison-free block top-K
    blk_valid = jnp.any(valid.reshape(m // block, block), axis=-1)
    blk_scores = lop.block_reduce_scores(
        jnp.where(valid, s_hat, jnp.iinfo(jnp.int32).min), block)
    blk_idx, blk_gate = lop.comparison_free_topk(
        blk_scores, k_blocks, n_buckets=n_buckets, valid=blk_valid)

    # 3. gather only the candidate blocks (contiguous reads)
    k_sel = _gather_blocks(k_cache, blk_idx, block)      # [K, d] int8
    v_sel = _gather_blocks(v_cache, blk_idx, block)      # [K, d] int8
    tok_valid = (_gather_blocks(valid[:, None], blk_idx, block)[:, 0]
                 & jnp.repeat(blk_gate, block))

    # 4. exact attention confined to candidates (int8 GEMMs, f32 reductions)
    logits = jnp.einsum("d,kd->k", q, k_sel,
                        preferred_element_type=jnp.int32).astype(jnp.float32)
    logits = logits * softmax_scale
    logits = jnp.where(tok_valid, logits, NEG_INF)
    mx, se = online_softmax_stats(logits)
    p = jnp.exp(logits - mx) / se
    return jnp.einsum("k,kd->d", p, v_sel.astype(jnp.float32))


@partial(jax.jit, static_argnames=("k_blocks", "block", "n_buckets"))
def predictive_sparse_attention(q, k_cache, v_cache, feat_cache, valid,
                                *, k_blocks: int, block: int = 64,
                                n_buckets: int = 64,
                                softmax_scale: float | None = None):
    """Batched decode attention with the LOP screen.

    q          int8   [B, H, d]      (one new token per sequence)
    k_cache    int8   [B, Hkv, M, d]
    v_cache    int8   [B, Hkv, M, d]
    feat_cache uint8  [B, Hkv, M, d] (nibble features; pack separately in HBM)
    valid      bool   [B, M]
    → f32 [B, H, d]  (still scaled by q/k/v scales at the caller)
    """
    b, h, d = q.shape
    hkv = k_cache.shape[1]
    group = h // hkv
    if softmax_scale is None:
        softmax_scale = 1.0 / (d ** 0.5)

    fn = partial(_single_head_sparse_attention, k_blocks=k_blocks, block=block,
                 n_buckets=n_buckets, softmax_scale=softmax_scale)
    # vmap: heads share the kv-head cache within a GQA group
    q_g = q.reshape(b, hkv, group, d)
    per_kv = jax.vmap(jax.vmap(fn, in_axes=(0, None, None, None, None)),
                      in_axes=(0, 0, 0, 0, None))      # over kv heads
    per_b = jax.vmap(per_kv, in_axes=(0, 0, 0, 0, 0))  # over batch
    out = per_b(q_g, k_cache, v_cache, feat_cache, valid)
    return out.reshape(b, h, d)


@partial(jax.jit, static_argnames=())
def dense_reference_attention(q, k_cache, v_cache, valid,
                              softmax_scale: float | None = None):
    """No-LOP oracle: exact attention over all M cached tokens."""
    b, h, d = q.shape
    hkv = k_cache.shape[1]
    group = h // hkv
    if softmax_scale is None:
        softmax_scale = 1.0 / (d ** 0.5)
    q_g = q.reshape(b, hkv, group, d)
    logits = jnp.einsum("bhgd,bhmd->bhgm", q_g, k_cache,
                        preferred_element_type=jnp.int32).astype(jnp.float32)
    logits = logits * softmax_scale
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgm,bhmd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, h, d)
