"""VitaLLM core: ternary quantized flow + LOP predictive sparse attention."""

from repro.core.quantization import (QuantizedTensor, absmax_scale, dequantize,
                                     fake_quantize, int8_matmul, quantize,
                                     rmsnorm, ste_quantize)
from repro.core.ternary import (TernaryWeight, bitlinear_infer, bitlinear_qat,
                                bitlinear_ref, make_ternary_weight,
                                pack_ternary, ternary_quantize, unpack_ternary)
from repro.core.lop import (comparison_free_topk, exact_topk, kv_traffic_bytes,
                            leading_one, lop_features, lop_scores,
                            pack_features, pot, unpack_features)
from repro.core.sparse_attention import (dense_reference_attention,
                                         predictive_sparse_attention)
from repro.core.schedule import materialized_mha, streamed_mha
