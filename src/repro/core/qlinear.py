"""Serving-format linears: one fused dispatch per projection group.

A "packed" linear node is ``{"packed": uint8 [k//4, n], "scale", "b"?}`` —
the deployment format produced by
:func:`repro.serving.quantize.quantize_params`. ``scale`` is the absmean γ:
scalar ``[1, 1]`` for a single projection, or a per-column row ``[1, n]``
when several projections share one packed weight (fused QKV / KV — each
column carries its segment's γ, so the fused dequant is bitwise the
per-projection scalar dequant).

Every packed apply routes through the fused entries in
:mod:`repro.kernels.ops` (DESIGN.md §TINT-projection-fusion): the absmax
barrier, the packed-2-bit ternary GEMM and the dequant/bias/activation
epilogue run as ONE dispatch — the paper's cross-core contract (quantize
once per vector, integer-domain GEMM, one output-side dequant) with the
barrier *inside* the kernel instead of a jnp round-trip through HBM.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops


def is_packed(node) -> bool:
    return isinstance(node, dict) and "packed" in node


def is_fused_ffn(node) -> bool:
    """A whole-FFN serving node (gate‖up + down streams, one dispatch)."""
    return isinstance(node, dict) and "gu_packed" in node


def qlinear(node, x: jax.Array) -> jax.Array:
    """x f32/bf16 [..., k] → f32 [..., n] — one fused dispatch."""
    if is_packed(node):
        return ops.qlinear_fused(x, node["packed"], node["scale"],
                                 node.get("b"))
    y = x.astype(jnp.float32) @ node["w"].astype(jnp.float32)
    if "b" in node:
        y = y + node["b"]
    return y


def qlinear_split(node, x: jax.Array, widths) -> tuple:
    """Fused multi-projection node → per-projection outputs.

    One dispatch computes the concatenated output; the split is a free
    view. ``widths`` are the static segment sizes (e.g. (q_dim, kv_dim,
    kv_dim) for a fused QKV node) — re-derived from the config at the
    call site, since packed nodes carry no static metadata.
    """
    y = qlinear(node, x)
    outs, off = [], 0
    for w in widths:
        outs.append(y[..., off:off + w])
        off += w
    assert off == y.shape[-1], (widths, y.shape)
    return tuple(outs)


def ffn_node_apply(node, x: jax.Array, *, gated: bool, act: str) -> jax.Array:
    """Whole-FFN serving node → one dispatch (act(x·Wg)·(x·Wu) → barrier
    → ·Wd). Expert-stacked nodes ([E, ...] leaves with x [E, C, d]) run
    every expert in the same launch. Under the explicit
    :func:`repro.distributed.tp_ffn.use_ffn_tp` opt-in (active mesh, f
    divides) the dispatch is f-sharded across the model axis — one
    fused launch per rank + psum of the down partials."""
    from repro.distributed import tp_ffn
    y = tp_ffn.maybe_shard_f(node, x, gated=gated, act=act)
    if y is not None:
        return y
    return ops.ffn_fused(x, node["gu_packed"], node["gu_scale"],
                         node["down_packed"], node["down_scale"],
                         gated=gated, act=act)


def qlinear_expert(node, x: jax.Array) -> jax.Array:
    """Per-expert linear: x [E, C, k]; node packed [E, k//4, n] (or fp w).

    The packed path is a grouped expert GEMM — expert is a grid axis of
    one fused launch (barrier + GEMM + dequant), not a vmap of one
    pallas_call per expert.
    """
    if is_packed(node):
        return ops.qlinear_fused(x, node["packed"], node["scale"])
    return jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                      node["w"].astype(jnp.float32))
