"""Serving-format linear: absmax barrier → TINT integer GEMM → fused dequant.

A "packed" linear node is ``{"packed": uint8 [k//4, n], "scale": f32 [1,1],
"b"?}`` — the deployment format produced by
:func:`repro.serving.quantize.quantize_params`. ``qlinear`` implements the
paper's cross-core contract: quantize once per vector (the barrier), run the
GEMM entirely in the integer domain, dequantize once at the output by
(activation scale × weight γ).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantization import quantize
from repro.core.ternary import TernaryWeight
from repro.kernels import ops


def is_packed(node) -> bool:
    return isinstance(node, dict) and "packed" in node


def qlinear(node, x: jax.Array) -> jax.Array:
    """x f32/bf16 [..., k] → f32 [..., n]."""
    if is_packed(node):
        k = node["packed"].shape[-2] * 4
        n = node["packed"].shape[-1]
        xq = quantize(x)                                   # the barrier
        tw = TernaryWeight(packed=node["packed"], scale=1.0, shape=(k, n))
        acc = ops.ternary_matmul(xq.values, tw)
        y = acc.astype(jnp.float32) * xq.scale * node["scale"].reshape(())
    else:
        y = x.astype(jnp.float32) @ node["w"].astype(jnp.float32)
    if "b" in node:
        y = y + node["b"]
    return y


def qlinear_expert(node, x: jax.Array) -> jax.Array:
    """Per-expert linear: x [E, C, k]; node packed [E, k//4, n] (or fp w)."""
    if is_packed(node):
        k = node["packed"].shape[-2] * 4

        def one(xe, pe, se):
            xq = quantize(xe)
            tw = TernaryWeight(packed=pe, scale=1.0, shape=(k, pe.shape[-1]))
            acc = ops.ternary_matmul(xq.values, tw)
            return acc.astype(jnp.float32) * xq.scale * se.reshape(())

        return jax.vmap(one)(x, node["packed"], node["scale"])
    return jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                      node["w"].astype(jnp.float32))
