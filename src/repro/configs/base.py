"""Model/config system: every assigned architecture is a ``ModelConfig``.

Families
--------
  dense   — decoder-only transformer (GQA/MHA, gated FFN)
  moe     — decoder-only with token-choice top-k MoE FFN
  hybrid  — Jamba-style Mamba+attention interleave (1 attn per ``attn_every``)
            with MoE every ``moe_every`` layers
  ssm     — RWKV6 (attention-free; token-mix recurrence + channel-mix)
  encdec  — Whisper-style encoder-decoder (stub audio frontend)
  vlm     — LLaVA-style decoder backbone with stub patch-embedding prefix

Quantization: ``quant="ternary"`` runs the paper's BitNet b1.58 flow — all
weight projections are BitLinear (absmean ternary weights, absmax int8
activations); embeddings/head/norms stay high-precision (BitNet's own
convention). ``quant="bf16"`` is the unquantized baseline.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 → d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_group: int = 2048       # tokens per dispatch group (scanned)
    # --- attention extras ---
    swa_window: int = 0         # 0 = full attention
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    # --- hybrid (Jamba) ---
    attn_every: int = 0         # 1 attention layer per this many (rest Mamba)
    moe_every: int = 0          # MoE FFN every this many layers (rest dense)
    mamba_d_state: int = 16
    mamba_expand: int = 2
    mamba_conv: int = 4
    # --- encdec (Whisper) ---
    n_encoder_layers: int = 0
    cross_ctx: int = 1500       # encoder frames visible to the decoder cache
    # --- vlm (LLaVA) ---
    n_img_tokens: int = 0       # stub patch embeddings prepended per sample
    # --- quantized flow / LOP ---
    quant: str = "ternary"      # ternary | bf16
    lop_block: int = 128        # KV candidate-block granularity (tokens)
    lop_keep: float = 0.125     # K/M — fraction of blocks kept by the screen
    use_lop: bool = True        # False for attention-free archs (rwkv6)
    # --- beyond-paper decode variants (DESIGN.md §Perf-variants) ---
    # Explicit kernel parameters of the fused decode path. ``None`` defers
    # to the legacy REPRO_GQA_SHARED_SELECT / REPRO_INT8_LOGITS env flags,
    # resolved ONCE at the engine entry (resolve_decode_flags) — never
    # inside traced inner functions.
    gqa_shared_select: bool | None = None  # one candidate set per KV head
    int8_logits: bool | None = None        # integer-domain QKᵀ in prefill
    # --- misc ---
    norm: str = "rmsnorm"       # rmsnorm | layernorm
    gated_ffn: bool = True      # silu-gated (False → gelu MLP, whisper)
    dtype: str = "float32"      # master param dtype (training)
    act_dtype: str = "bfloat16"  # activation/compute dtype (training)

    # ---------------- derived ----------------
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.hd

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.hd

    @property
    def vocab_padded(self) -> int:
        """Vocab padded so TP over the model axis always divides."""
        return -(-self.vocab // 256) * 256

    @property
    def d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    def is_attn_layer(self, i: int) -> bool:
        if self.family == "ssm":
            return False
        if self.family == "hybrid":
            # Jamba: one attention layer per `attn_every` block (offset mid-block)
            return i % self.attn_every == self.attn_every // 2
        return True

    def is_moe_layer(self, i: int) -> bool:
        if self.n_experts == 0:
            return False
        if self.moe_every:
            return i % self.moe_every == 1
        return True

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


def resolve_decode_flags(cfg: "ModelConfig") -> "ModelConfig":
    """Pin the beyond-paper decode variants to concrete booleans.

    Config fields are the source of truth; a ``None`` field falls back to
    the matching environment flag for backwards compatibility. Called once
    at the engine entry points (``prefill`` / ``serve_step`` /
    ``sp_decode_attention``) so no traced inner function ever consults
    ``os.environ`` — the flags flow through the code as explicit
    ``ModelConfig`` state and land in the fused decode kernel as static
    parameters.
    """
    if cfg.gqa_shared_select is not None and cfg.int8_logits is not None:
        return cfg
    import os
    shared = cfg.gqa_shared_select
    int8l = cfg.int8_logits
    if shared is None:
        shared = os.environ.get("REPRO_GQA_SHARED_SELECT") == "1"
    if int8l is None:
        int8l = os.environ.get("REPRO_INT8_LOGITS") == "1"
    return cfg.replace(gqa_shared_select=shared, int8_logits=int8l)


# ---------------------------------------------------------------------------
# Input shapes (assigned shape set — seq_len × global_batch)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# smoke-scale variants of the same shapes (CPU tests)
SMOKE_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 64, 2, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 128, 2, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 128, 2, "decode"),
    "long_500k": ShapeConfig("long_500k", 256, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) per the brief's skip rules."""
    if shape.name == "long_500k":
        if cfg.family in ("ssm", "hybrid"):
            return True, ""
        return False, ("long_500k needs sub-quadratic attention; "
                       f"{cfg.name} is full-attention — skipped per brief "
                       "(noted in DESIGN.md §Arch-applicability)")
    return True, ""


def text_len(cfg: ModelConfig, seq_len: int, kind: str) -> int:
    """Token length of the *decoder text stream* for a given cell seq_len."""
    if cfg.family == "encdec":
        # seq_len counts audio frames; decoder text is seq_len/4 (DESIGN §6)
        return max(seq_len // 4, 8)
    if cfg.family == "vlm" and kind in ("train", "prefill"):
        return max(seq_len - cfg.n_img_tokens, 8)
    return seq_len


def input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    Returns a dict matching the kwargs of the corresponding step function
    (train_step / prefill / serve_step). No device allocation.
    """
    b, s = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        t = text_len(cfg, s, "train")
        specs = {"tokens": sds((b, t), jnp.int32),
                 "labels": sds((b, t), jnp.int32)}
        if cfg.family == "encdec":
            specs["frames"] = sds((b, s, cfg.d_model), jnp.bfloat16)
        if cfg.family == "vlm":
            specs["patches"] = sds((b, cfg.n_img_tokens, cfg.d_model),
                                   jnp.bfloat16)
        return specs
    if shape.kind == "prefill":
        t = text_len(cfg, s, "prefill")
        specs = {"tokens": sds((b, t), jnp.int32)}
        if cfg.family == "encdec":
            specs["frames"] = sds((b, s, cfg.d_model), jnp.bfloat16)
        if cfg.family == "vlm":
            specs["patches"] = sds((b, cfg.n_img_tokens, cfg.d_model),
                                   jnp.bfloat16)
        return specs
    # decode: one new token against a cache of seq_len (cache passed separately)
    return {"tokens": sds((b, 1), jnp.int32)}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, ModelConfig] = {}
_LOADED = False


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _load_all()
    return _REGISTRY[name]


def all_configs() -> dict[str, ModelConfig]:
    _load_all()
    return dict(_REGISTRY)


def _load_all():
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    # import side-effect registers each arch
    from repro.configs import (bitnet_3b, granite_moe_1b_a400m,  # noqa: F401
                               jamba_1_5_large_398b, llava_next_34b,
                               mistral_nemo_12b, mixtral_8x22b, qwen1_5_110b,
                               qwen1_5_32b, rwkv6_1_6b, stablelm_1_6b,
                               whisper_small)


ASSIGNED = [
    "mixtral-8x22b", "granite-moe-1b-a400m", "whisper-small",
    "jamba-1.5-large-398b", "llava-next-34b", "qwen1.5-32b", "stablelm-1.6b",
    "mistral-nemo-12b", "qwen1.5-110b", "rwkv6-1.6b",
]
