"""granite-moe-1b-a400m [moe] — 32 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]."""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    head_dim=64,
    n_experts=32,
    top_k=8,
))

REDUCED = CONFIG.replace(
    name="granite-moe-1b-a400m-reduced", n_layers=3, d_model=96, n_heads=4,
    n_kv_heads=2, d_ff=64, vocab=512, head_dim=24, n_experts=8, top_k=4,
    moe_group=64, lop_block=32)
