"""Architecture configs — one module per assigned architecture.

``get_config(name)`` resolves the ``--arch`` ids from the brief;
``all_configs()`` returns the full registry (assigned archs + the paper's
own bitnet-3b).
"""

from repro.configs.base import (ASSIGNED, SHAPES, SMOKE_SHAPES, ModelConfig,
                                ShapeConfig, all_configs, get_config,
                                input_specs, register, shape_applicable,
                                text_len)
