"""mistral-nemo-12b [dense] — 128k ctx
[hf:mistralai/Mistral-Nemo-Base-2407; hf]."""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mistral-nemo-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=131072,
    head_dim=128,              # q_dim 4096 ≠ d_model (Nemo convention)
    rope_theta=1_000_000.0,
))

REDUCED = CONFIG.replace(
    name="mistral-nemo-12b-reduced", n_layers=3, d_model=128, n_heads=4,
    n_kv_heads=2, d_ff=256, vocab=512, head_dim=32, lop_block=32)
