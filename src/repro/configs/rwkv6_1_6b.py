"""rwkv6-1.6b [ssm] — Finch, data-dependent decay [arXiv:2404.05892;
unverified].

Attention-free: LOP predictive sparse attention is **inapplicable** (no KV
cache to screen — DESIGN.md §Arch-applicability); the ternary BitLinear flow
still applies to every projection (r/k/v/g/w, output, channel-mix).
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,                # time-mix heads (head size 64)
    n_kv_heads=32,
    d_ff=7168,
    vocab=65536,
    head_dim=64,
    use_lop=False,
))

REDUCED = CONFIG.replace(
    name="rwkv6-1.6b-reduced", n_layers=3, d_model=96, n_heads=4,
    n_kv_heads=4, d_ff=192, vocab=512, head_dim=24)
