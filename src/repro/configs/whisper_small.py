"""whisper-small [audio] — enc-dec, conv frontend (stub)
[arXiv:2212.04356; unverified].

Backbone-only per the brief: ``input_specs()`` provides precomputed frame
embeddings [B, n_frames, d_model]; the conv frontend is a stub. Adaptation
note (DESIGN.md): positions are handled by rotary embeddings instead of
Whisper's learned/sinusoidal tables so the backbone supports the assigned
stress shapes (32k decode cache ≫ the model's nominal 448 ctx).
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-small",
    family="encdec",
    n_layers=12,               # decoder layers
    n_encoder_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=51865,
    head_dim=64,
    norm="layernorm",
    gated_ffn=False,           # whisper MLP is gelu, non-gated
    cross_ctx=1500,
))

REDUCED = CONFIG.replace(
    name="whisper-small-reduced", n_layers=2, n_encoder_layers=2, d_model=96,
    n_heads=4, n_kv_heads=4, d_ff=192, vocab=512, head_dim=24, cross_ctx=64,
    lop_block=32)
