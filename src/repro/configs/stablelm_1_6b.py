"""stablelm-1.6b [dense] — [hf:stabilityai/stablelm-2-1_6b; unverified]."""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=5632,
    vocab=100352,
    head_dim=64,
    norm="layernorm",
))

REDUCED = CONFIG.replace(
    name="stablelm-1.6b-reduced", n_layers=3, d_model=96, n_heads=4,
    n_kv_heads=4, d_ff=192, vocab=512, head_dim=24, lop_block=32)
