"""llava-next-34b [vlm] — anyres tiling
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified].

Backbone-only per the brief: the vision tower is a stub — ``input_specs()``
provides precomputed anyres patch embeddings [B, n_img_tokens, d_model]
prepended to the text stream.
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab=64000,
    head_dim=128,
    n_img_tokens=2880,         # anyres: base 576 + 4 tiles × 576
    rope_theta=5_000_000.0,
))

REDUCED = CONFIG.replace(
    name="llava-next-34b-reduced", n_layers=3, d_model=128, n_heads=4,
    n_kv_heads=2, d_ff=256, vocab=512, head_dim=32, n_img_tokens=16,
    lop_block=32)
