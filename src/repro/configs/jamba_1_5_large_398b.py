"""jamba-1.5-large-398b [hybrid] — Mamba+attn 1:7 interleave, MoE 16e top-2
[arXiv:2403.19887; hf]."""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab=65536,
    head_dim=128,
    n_experts=16,
    top_k=2,
    attn_every=8,              # 1 attention layer per 8 (1:7 Mamba ratio)
    moe_every=2,
    mamba_d_state=16,
    mamba_expand=2,
    mamba_conv=4,
))

REDUCED = CONFIG.replace(
    name="jamba-1.5-large-398b-reduced", n_layers=8, d_model=128, n_heads=4,
    n_kv_heads=2, d_ff=256, vocab=512, head_dim=32, n_experts=4, top_k=2,
    attn_every=4, moe_every=2, moe_group=64, lop_block=32)
