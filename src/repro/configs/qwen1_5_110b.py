"""qwen1.5-110b [dense] — QKV bias [hf:Qwen/Qwen1.5-0.5B; hf]."""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen1.5-110b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=49152,
    vocab=152064,
    head_dim=128,
    qkv_bias=True,
))

REDUCED = CONFIG.replace(
    name="qwen1.5-110b-reduced", n_layers=3, d_model=128, n_heads=4,
    n_kv_heads=2, d_ff=256, vocab=512, head_dim=32, lop_block=32)
