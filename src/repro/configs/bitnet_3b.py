"""bitnet-3b — the paper's own model: BitNet b1.58 3B [arXiv:2402.17764].

LLaMA-3B-shaped (26L, d 3200, 32H, ffn 8640) with every projection a
BitLinear; the silicon prototype (Table I) decodes this model at
72.46 tokens/s. This config drives the Table I / Fig 8 / Fig 9 benchmark
reproductions and the paper-faithful baseline of §Perf.
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="bitnet-3b",
    family="dense",
    n_layers=26,
    d_model=3200,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8640,
    vocab=32000,
    head_dim=100,
))

REDUCED = CONFIG.replace(
    name="bitnet-3b-reduced", n_layers=3, d_model=128, n_heads=4,
    n_kv_heads=4, d_ff=256, vocab=512, head_dim=32, lop_block=32)
