"""mixtral-8x22b [moe] — 8 experts top-2, SWA [arXiv:2401.04088; hf]."""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=32768,
    head_dim=128,
    n_experts=8,
    top_k=2,
    swa_window=4096,
    rope_theta=1_000_000.0,
))

REDUCED = CONFIG.replace(
    name="mixtral-8x22b-reduced", n_layers=4, d_model=128, n_heads=4,
    n_kv_heads=2, d_ff=256, vocab=512, head_dim=32, n_experts=4, top_k=2,
    swa_window=64, moe_group=64, lop_block=32)
