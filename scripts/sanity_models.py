"""Quick manual sanity: init + forward for every reduced arch config."""
import importlib
import time

import jax
import jax.numpy as jnp
import numpy as np

MODULES = [
    "mixtral_8x22b", "granite_moe_1b_a400m", "whisper_small",
    "jamba_1_5_large_398b", "llava_next_34b", "qwen1_5_32b", "stablelm_1_6b",
    "mistral_nemo_12b", "qwen1_5_110b", "rwkv6_1_6b", "bitnet_3b",
]

from repro.models.transformer import forward_train, init_params

key = jax.random.PRNGKey(0)
B, T = 2, 32
for mod_name in MODULES:
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    cfg = mod.REDUCED
    t0 = time.time()
    params, pspecs = init_params(cfg, key)
    # pspec tree must mirror params
    pl = jax.tree.leaves(params)
    sl = jax.tree.leaves(pspecs, is_leaf=lambda x: isinstance(x, tuple))
    assert len(pl) == len(sl), (cfg.name, len(pl), len(sl))
    for arr, spec in zip(pl, sl):
        assert len(spec) == arr.ndim, (cfg.name, arr.shape, spec)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab)
    kwargs = {}
    if cfg.family == "encdec":
        kwargs["frames"] = jax.random.normal(key, (B, 2 * T, cfg.d_model))
    if cfg.family == "vlm":
        kwargs["patches"] = jax.random.normal(key, (B, cfg.n_img_tokens,
                                                    cfg.d_model))
    logits, aux = jax.jit(
        lambda p, t, **kw: forward_train(cfg, p, t, **kw))(params, tokens,
                                                           **kwargs)
    n_params = sum(int(np.prod(a.shape)) for a in pl)
    assert logits.shape == (B, T, cfg.vocab_padded), (cfg.name, logits.shape)
    assert np.isfinite(np.asarray(logits)).all(), cfg.name
    print(f"{cfg.name:38s} ok  params={n_params:>9,}  "
          f"aux={float(aux):.3f}  {time.time()-t0:.1f}s")
print("ALL MODEL SANITY OK")
