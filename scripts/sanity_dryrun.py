"""Dry-run machinery sanity: reduced configs × smoke shapes × real meshes.

Exercises the exact build/lower/compile path of launch/dryrun.py with tiny
models so bugs surface in seconds, not hours.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import importlib
import time

import jax

from repro.configs.base import SMOKE_SHAPES, ShapeConfig
from repro.distributed.partitioning import use_mesh
from repro.launch.dryrun import (build_decode_cell, build_prefill_cell,
                                 build_train_cell)
from repro.launch.mesh import make_production_mesh

MODULES = [
    "mixtral_8x22b", "granite_moe_1b_a400m", "whisper_small",
    "jamba_1_5_large_398b", "llava_next_34b", "qwen1_5_32b",
    "rwkv6_1_6b",
]

# smoke shapes large enough to shard over 16×16 but still tiny
SHAPES = {
    "train": ShapeConfig("train_4k", 256, 32, "train"),
    "prefill": ShapeConfig("prefill_32k", 512, 32, "prefill"),
    "decode": ShapeConfig("decode_32k", 2048, 32, "decode"),
}

for multi_pod in (False, True):
    mesh = make_production_mesh(multi_pod=multi_pod)
    print(f"=== mesh {mesh.shape} ===")
    for mod_name in MODULES:
        mod = importlib.import_module(f"repro.configs.{mod_name}")
        cfg = mod.REDUCED.replace(d_model=256, n_heads=8, n_kv_heads=4,
                                  head_dim=32, d_ff=512, vocab=2048)
        if cfg.family == "ssm":
            cfg = cfg.replace(n_kv_heads=8)  # rwkv: kv unused, keep H=heads
        for kind, shape in SHAPES.items():
            t0 = time.time()
            with use_mesh(mesh):
                if kind == "train":
                    fn, args, _ = build_train_cell(cfg, shape, mesh)
                elif kind == "prefill":
                    fn, args, _ = build_prefill_cell(cfg, shape, mesh)
                else:
                    fn, args, _ = build_decode_cell(cfg, shape, mesh)
                compiled = fn.lower(*args).compile()
            mem = compiled.memory_analysis()
            print(f"  {cfg.name:34s} {kind:8s} ok {time.time()-t0:5.1f}s "
                  f"temp={mem.temp_size_in_bytes/2**20:.1f}MiB")
print("DRYRUN MACHINERY OK")
