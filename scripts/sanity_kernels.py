"""Quick manual sanity for the Pallas kernels (interpret mode on CPU)."""
import jax
import numpy as np
import jax.numpy as jnp

from repro.core.lop import lop_features, pack_features, pot
from repro.core.quantization import quantize
from repro.core.ternary import make_ternary_weight
from repro.kernels import ops, ref

rng = np.random.default_rng(0)

# --- ternary matmul ---
x = jnp.asarray(rng.integers(-127, 128, size=(48, 512)).astype(np.int8))
w = jnp.asarray(rng.normal(size=(512, 256)).astype(np.float32)) * 0.02
tw = make_ternary_weight(w)
y_k = ops.ternary_matmul(x, tw, impl="pallas")
y_r = ops.ternary_matmul(x, tw, impl="ref")
assert (np.asarray(y_k) == np.asarray(y_r)).all(), "ternary matmul mismatch"
print("ternary_matmul kernel == ref (exact int32)")

# --- fused projection (barrier + GEMM + dequant in ONE dispatch) ---
xf = jnp.asarray(rng.normal(size=(4, 512)).astype(np.float32))
sc = jnp.asarray(tw.scale, jnp.float32).reshape(1, 1)


def _unfused(xx):
    xq = quantize(xx)
    acc = ops.ternary_matmul(xq.values, tw, impl="ref")
    return acc.astype(jnp.float32) * xq.scale * sc.reshape(())


y_u = jax.jit(_unfused)(xf)
for impl in ("ref", "pallas"):
    y_f = jax.jit(lambda a, impl=impl: ops.qlinear_fused(
        a, tw.packed, sc, impl=impl))(xf)
    assert (np.asarray(y_f) == np.asarray(y_u)).all(), \
        f"fused qlinear ({impl}) not bitwise vs unfused"
print("qlinear_fused ref == pallas == unfused chain (bitwise)")

# --- fused whole-FFN (gate·up → in-VMEM re-barrier → down) ---
d_m, d_f = 256, 384
twg = make_ternary_weight(
    jnp.asarray(rng.normal(size=(d_m, d_f)).astype(np.float32)) * 0.05)
twu = make_ternary_weight(
    jnp.asarray(rng.normal(size=(d_m, d_f)).astype(np.float32)) * 0.05)
twd = make_ternary_weight(
    jnp.asarray(rng.normal(size=(d_f, d_m)).astype(np.float32)) * 0.05)
gu_p = jnp.concatenate([twg.packed, twu.packed], -1)
gu_s = jnp.concatenate(
    [jnp.broadcast_to(jnp.asarray(t.scale, jnp.float32).reshape(1, 1),
                      (1, d_f)) for t in (twg, twu)], -1)
d_s = jnp.asarray(twd.scale, jnp.float32).reshape(1, 1)
xm = jnp.asarray(rng.normal(size=(3, d_m)).astype(np.float32))


def _ffn_unfused(xx):
    def lin(t, h):
        hq = quantize(h)
        acc = ops.ternary_matmul(hq.values, t, impl="ref")
        return acc.astype(jnp.float32) * hq.scale * jnp.asarray(
            t.scale, jnp.float32).reshape(())
    h = jax.nn.silu(lin(twg, xx)) * lin(twu, xx)
    return lin(twd, h)


y_u = jax.jit(_ffn_unfused)(xm)
for impl in ("ref", "pallas"):
    y_f = jax.jit(lambda a, impl=impl: ops.ffn_fused(
        a, gu_p, gu_s, twd.packed, d_s, gated=True, act="silu",
        impl=impl))(xm)
    assert (np.asarray(y_f) == np.asarray(y_u)).all(), \
        f"fused ffn ({impl}) not bitwise vs unfused"
print("ffn_fused ref == pallas == unfused gate/up/down chain (bitwise)")

# --- lop screen ---
q = jnp.asarray(rng.integers(-127, 128, size=(12, 128)).astype(np.int8))
kc = jnp.asarray(rng.integers(-127, 128, size=(1024, 128)).astype(np.int8))
feat = pack_features(lop_features(kc))
s_k = ops.lop_screen(q, feat, impl="pallas")
s_r = ops.lop_screen(q, feat, impl="ref")
assert (np.asarray(s_k) == np.asarray(s_r)).all(), "lop screen mismatch"
# identity vs direct pot-dot
s_d = jnp.einsum("gd,md->gm", pot(q).astype(jnp.int32), pot(kc).astype(jnp.int32))
assert (np.asarray(s_k) == np.asarray(s_d)).all(), "lop identity broken"
print("lop_scores kernel == ref == pot-dot identity")

# --- flash prefill ---
S, D = 512, 128
qi = jnp.asarray(rng.integers(-60, 61, size=(S, D)).astype(np.int8))
ki = jnp.asarray(rng.integers(-60, 61, size=(S, D)).astype(np.int8))
vi = jnp.asarray(rng.integers(-60, 61, size=(S, D)).astype(np.int8))
qs = jnp.asarray(rng.uniform(0.5, 2.0, size=(S, 1)).astype(np.float32)) * 0.01
ks = jnp.asarray(rng.uniform(0.5, 2.0, size=(S, 1)).astype(np.float32)) * 0.01
vs = jnp.asarray(rng.uniform(0.5, 2.0, size=(S, 1)).astype(np.float32)) * 0.01
sm = 1.0 / np.sqrt(D)
for causal in (True, False):
    o_k = ops.flash_prefill(qi, ki, vi, qs, ks, vs, softmax_scale=sm,
                            causal=causal, impl="pallas")
    o_r = ops.flash_prefill(qi, ki, vi, qs, ks, vs, softmax_scale=sm,
                            causal=causal, impl="ref")
    err = float(jnp.max(jnp.abs(o_k - o_r)))
    print(f"flash_prefill causal={causal} max abs err: {err:.2e}")
    assert err < 1e-3

# --- sparse decode ---
M, BLK, NB, G = 1024, 128, 4, 6
kcache = jnp.asarray(rng.integers(-60, 61, size=(M, D)).astype(np.int8))
vcache = jnp.asarray(rng.integers(-60, 61, size=(M, D)).astype(np.int8))
kscale = jnp.asarray(rng.uniform(0.5, 2.0, size=(M, 1)).astype(np.float32)) * 0.01
vscale = jnp.asarray(rng.uniform(0.5, 2.0, size=(M, 1)).astype(np.float32)) * 0.01
qg = jnp.asarray(rng.integers(-60, 61, size=(G, D)).astype(np.int8))
qscale = jnp.asarray(rng.uniform(0.5, 2.0, size=(G, 1)).astype(np.float32)) * 0.01
bidx = jnp.asarray([0, 3, 5, 7], dtype=jnp.int32)
# [gate ‖ end ‖ start] per the scalar-prefetch contract (lop_select.py)
gate_tokens = jnp.asarray([1, 1, 1, 0,            # gates
                           BLK, BLK, 100, 0,      # live-interval ends
                           0, 0, 0, 0], dtype=jnp.int32)   # starts
o_k = ops.sparse_decode(qg, kcache, vcache, qscale, kscale, vscale, bidx,
                        gate_tokens, block=BLK, softmax_scale=sm, impl="pallas")
o_r = ops.sparse_decode(qg, kcache, vcache, qscale, kscale, vscale, bidx,
                        gate_tokens, block=BLK, softmax_scale=sm, impl="ref")
err = float(jnp.max(jnp.abs(o_k - o_r)))
print(f"sparse_decode max abs err: {err:.2e}")
assert err < 1e-3

# --- fused batched decode (the serving decode entry) ---
B, H, HKV = 2, 8, 2
qb = jnp.asarray(rng.integers(-60, 61, size=(B, H, D)).astype(np.int8))
qbs = jnp.asarray(rng.uniform(0.005, 0.02, size=(B, H, 1)).astype(np.float32))
kb = jnp.asarray(rng.integers(-60, 61, size=(B, HKV, M, D)).astype(np.int8))
vb = jnp.asarray(rng.integers(-60, 61, size=(B, HKV, M, D)).astype(np.int8))
kbs = jnp.asarray(rng.uniform(0.005, 0.02, size=(B, HKV, M)).astype(np.float32))
vbs = jnp.asarray(rng.uniform(0.005, 0.02, size=(B, HKV, M)).astype(np.float32))
featb = pack_features(lop_features(kb))
new_len = jnp.asarray([M - 100, 0], jnp.int32)      # lane 1 retired
for use_lop in (True, False):
    o_k = ops.decode_attention(qb, qbs, kb, vb, kbs, vbs, featb, new_len,
                               block=BLK, k_keep=3, use_lop=use_lop,
                               impl="pallas")
    o_r = ops.decode_attention(qb, qbs, kb, vb, kbs, vbs, featb, new_len,
                               block=BLK, k_keep=3, use_lop=use_lop,
                               impl="ref")
    err = float(jnp.max(jnp.abs(o_k - o_r)))
    print(f"decode_attention use_lop={use_lop} max abs err: {err:.2e}")
    assert err < 1e-3
    assert float(jnp.max(jnp.abs(o_k[1]))) == 0.0, "retired lane leaked"

# --- fused batched prefill (the serving prefill entry) ---
C = 16
qp_ = jnp.asarray(rng.integers(-60, 61, size=(B, H, C, D)).astype(np.int8))
qps = jnp.asarray(rng.uniform(0.005, 0.02, size=(B, H, C)).astype(np.float32))
kv_len = jnp.asarray([M - 100, 0], jnp.int32)       # lane 1 empty
o_k = ops.prefill_attention(qp_, qps, kb, vb, kbs, vbs, kv_len,
                            q_offset=M - 100 - C, causal=True,
                            impl="pallas")
o_r = ops.prefill_attention(qp_, qps, kb, vb, kbs, vbs, kv_len,
                            q_offset=M - 100 - C, causal=True, impl="ref")
err = float(jnp.max(jnp.abs(o_k - o_r)))
print(f"prefill_attention max abs err: {err:.2e}")
assert err < 1e-3
assert float(jnp.max(jnp.abs(o_k[1]))) == 0.0, "empty prefill lane leaked"
# chunk-carry: two half chunks == the whole chunk, bitwise
halves = [ops.prefill_attention(
    qp_[:, :, i * 8:(i + 1) * 8], qps[:, :, i * 8:(i + 1) * 8], kb, vb,
    kbs, vbs, kv_len, q_offset=M - 100 - C + i * 8, causal=True,
    impl="pallas") for i in range(2)]
assert (np.asarray(jnp.concatenate(halves, 2)) == np.asarray(o_k)).all(), \
    "chunk-carry not bitwise"
print("prefill_attention chunked == whole (bitwise)")
print("ALL KERNEL SANITY OK")
