"""Quick manual sanity for core modules (not a pytest file)."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (quantize, dequantize, make_ternary_weight,
                        bitlinear_ref, bitlinear_qat, pot, lop_scores,
                        comparison_free_topk, exact_topk,
                        predictive_sparse_attention, dense_reference_attention,
                        materialized_mha, streamed_mha, lop_features,
                        pack_features, unpack_features)
from repro.core.schedule import standard_softmax_attention
from repro.core.lop import features_to_pot

rng = np.random.default_rng(0)

# quantize roundtrip
x = jnp.asarray(rng.normal(size=(4, 64)).astype(np.float32))
q = quantize(x)
err = jnp.max(jnp.abs(dequantize(q) - x))
print("quant max err:", err, "(scale max:", float(jnp.max(q.scale)), ")")
assert err < float(jnp.max(q.scale)) * 0.51 + 1e-6

# ternary matmul ref vs fp
w = jnp.asarray(rng.normal(size=(64, 32)).astype(np.float32)) * 0.05
tw = make_ternary_weight(w)
y_ref = bitlinear_ref(x, tw)
y_fp = x @ w
cos = jnp.sum(y_ref * y_fp) / (jnp.linalg.norm(y_ref) * jnp.linalg.norm(y_fp))
print("bitlinear cos sim vs fp:", cos)
assert cos > 0.85

# qat grad flows
g = jax.grad(lambda w_: jnp.sum(bitlinear_qat(x, w_) ** 2))(w)
assert np.isfinite(np.asarray(g)).all() and float(jnp.max(jnp.abs(g))) > 0
print("qat grad ok", float(jnp.max(jnp.abs(g))))

# LOP identity: surrogate == dot of pot vectors, and features roundtrip
qi = jnp.asarray(rng.integers(-127, 128, size=(8,)).astype(np.int8))
ki = jnp.asarray(rng.integers(-127, 128, size=(16, 8)).astype(np.int8))
s = lop_scores(qi, ki)
s_manual = (pot(qi).astype(np.int32)[None] * pot(ki).astype(np.int32)).sum(-1)
assert (np.asarray(s) == np.asarray(s_manual)).all()
f = lop_features(ki)
assert (np.asarray(features_to_pot(f)) == np.asarray(pot(ki))).all()
assert (np.asarray(unpack_features(pack_features(f))) == np.asarray(f)).all()
print("lop identity + feature roundtrip ok")

# comparison-free topk recall vs exact
sc = jnp.asarray(rng.normal(size=(512,)).astype(np.float32))
idx, gate = comparison_free_topk(sc, 32, n_buckets=64)
ex = set(np.asarray(exact_topk(sc, 32)).tolist())
got = set(np.asarray(idx)[np.asarray(gate)].tolist())
rec = len(ex & got) / 32
print("cf-topk recall vs exact:", rec)
assert rec >= 0.5  # bucketized is approximate at ties; should be high typically

# sparse attention close to dense when K = all blocks
B, H, Hkv, M, D = 2, 4, 2, 256, 32
qa = jnp.asarray(rng.integers(-40, 40, size=(B, H, D)).astype(np.int8))
kc = jnp.asarray(rng.integers(-40, 40, size=(B, Hkv, M, D)).astype(np.int8))
vc = jnp.asarray(rng.integers(-40, 40, size=(B, Hkv, M, D)).astype(np.int8))
fc = lop_features(kc)
valid = jnp.arange(M)[None, :] < jnp.asarray([[200], [256]])[:, 0:1]
valid = jnp.broadcast_to(jnp.arange(M)[None, :], (B, M)) < jnp.asarray([200, 256])[:, None]
o_all = predictive_sparse_attention(qa, kc, vc, fc, valid, k_blocks=M // 64, block=64)
o_ref = dense_reference_attention(qa, kc, vc, valid)
print("sparse(K=all) vs dense max abs diff:", float(jnp.max(jnp.abs(o_all - o_ref))))
assert float(jnp.max(jnp.abs(o_all - o_ref))) < 1e-2

o_k2 = predictive_sparse_attention(qa, kc, vc, fc, valid, k_blocks=2, block=64)
rel = float(jnp.linalg.norm(o_k2 - o_ref) / jnp.linalg.norm(o_ref))
print("sparse(K=2/4 blocks) rel err:", rel)

# schedules agree
Bm, S, Dm, Hh, hd = 2, 16, 64, 4, 16
xm = jnp.asarray(rng.normal(size=(Bm, S, Dm)).astype(np.float32))
ws = [jnp.asarray(rng.normal(size=(Dm, Hh * hd)).astype(np.float32)) * 0.1 for _ in range(3)]
wo = jnp.asarray(rng.normal(size=(Hh * hd, Dm)).astype(np.float32)) * 0.1
y1 = materialized_mha(xm, *ws, wo, n_heads=Hh, head_dim=hd, attn_fn=standard_softmax_attention)
y2 = streamed_mha(xm, *ws, wo, n_heads=Hh, head_dim=hd, attn_fn=standard_softmax_attention, group=2)
print("schedule max diff:", float(jnp.max(jnp.abs(y1 - y2))))
assert float(jnp.max(jnp.abs(y1 - y2))) < 1e-3
print("ALL CORE SANITY OK")
