"""§Perf hillclimb driver: run a cell variant and diff it against baseline.

    PYTHONPATH=src python scripts/hillclimb.py <arch> <shape> <variant-name>
        [--env FLAG=1 ...] [--set key=value ...]

Baseline = experiments/dryrun/<arch>__<shape>__sp.json; the variant lands in
experiments/perf/<arch>__<shape>__<variant>.json and the delta on each
roofline term is printed.
"""
import os
import sys

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")
os.environ.setdefault("TF_CPP_MIN_LOG_LEVEL", "2")

import argparse
import json


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("arch")
    ap.add_argument("shape")
    ap.add_argument("variant")
    ap.add_argument("--env", action="append", default=[])
    ap.add_argument("--set", action="append", default=[], dest="sets")
    ap.add_argument("--n-micro", type=int, default=None)
    args = ap.parse_args()

    for kv in args.env:
        k, v = kv.split("=", 1)
        os.environ[k] = v

    from repro.configs.base import get_config
    from repro.launch.dryrun import run_cell

    cfg = get_config(args.arch)
    for kv in args.sets:
        k, v = kv.split("=", 1)
        cur = getattr(cfg, k)
        cfg = cfg.replace(**{k: type(cur)(eval(v))
                             if not isinstance(cur, str) else v})

    res = run_cell(args.arch, args.shape, cfg=cfg, verbose=False,
                   n_micro=args.n_micro)
    os.makedirs("experiments/perf", exist_ok=True)
    out_path = (f"experiments/perf/{args.arch}__{args.shape}__"
                f"{args.variant}.json")
    res["variant"] = {"name": args.variant, "env": args.env,
                      "sets": args.sets}
    with open(out_path, "w") as f:
        json.dump(res, f, indent=1)

    base_path = f"experiments/dryrun/{args.arch}__{args.shape}__sp.json"
    with open(base_path) as f:
        base = json.load(f)
    rb, rv = base["roofline"], res["roofline"]
    print(f"== {args.arch} × {args.shape} :: {args.variant}")
    for k in ("compute_s", "memory_s", "collective_s"):
        b, v = rb[k], rv[k]
        delta = (v - b) / abs(b) * 100 if b else float("nan")
        print(f"  {k:14s} {b:10.4f} → {v:10.4f}  ({delta:+.1f}%)")
    print(f"  dominant       {rb['dominant']} → {rv['dominant']}")
    print(f"  bound_s        {rb['bound_s']:.4f} → {rv['bound_s']:.4f} "
          f"({(rv['bound_s']-rb['bound_s'])/rb['bound_s']*100:+.1f}%)")
    print(f"  useful_frac    {rb['useful_fraction']:.3f} → "
          f"{rv['useful_fraction']:.3f}")
    print(f"  peak mem       {base['memory']['peak_estimate_bytes']/2**30:.1f}"
          f" → {res['memory']['peak_estimate_bytes']/2**30:.1f} GiB")


if __name__ == "__main__":
    main()
