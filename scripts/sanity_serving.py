"""Serving consistency sanity: prefill(S)+decode(1) == prefill(S+1),
plus a typed-API smoke check (streaming + sampled generation).

``--http-smoke`` runs the HTTP front-end smoke instead (DESIGN.md
§Serving-frontend): start a loopback server, stream a completion over a
real socket, check it against lockstep, scrape ``/metrics``, shut down.
scripts/ci_tier1.sh runs both modes.

With lop_keep=1.0 the LOP screen selects every valid block, so the sparse
decode path must agree with the dense prefill path bit-for-bit (modulo f32
accumulation order). The API smoke drives the scheduler through the
InferenceEngine protocol with per-request SamplingParams: a greedy and a
seeded sampled request stream their tokens through on_token, and both
must match their lockstep replays token-for-token (DESIGN.md
§Serving-API).
"""
import importlib
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import init_params
from repro.serving.engine import prefill, serve_step
from repro.serving.quantize import quantize_params


def http_smoke() -> None:
    """Loopback-port server smoke: start -> stream -> scrape -> stop."""
    import json
    import socket

    from repro.configs.bitnet_3b import REDUCED
    from repro.serving.frontend import serve_threaded
    from repro.serving.metrics import MetricsRegistry
    from repro.serving.scheduler import Scheduler, lockstep_generate

    cfg = REDUCED
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    qp = quantize_params(cfg, params)
    registry = MetricsRegistry()
    sched = Scheduler(cfg, qp, n_slots=2, max_len=40, metrics=registry)
    srv = serve_threaded(sched, model_name=cfg.name, registry=registry)
    print(f"http smoke: server up on 127.0.0.1:{srv.port}")

    def request(method, path, body=None):
        payload = json.dumps(body).encode() if body is not None else b""
        s = socket.create_connection(("127.0.0.1", srv.port), timeout=300)
        s.sendall(f"{method} {path} HTTP/1.1\r\nHost: s\r\n"
                  f"Content-Length: {len(payload)}\r\n\r\n".encode()
                  + payload)
        raw = b""
        while True:
            chunk = s.recv(65536)
            if not chunk:
                break
            raw += chunk
        s.close()
        head, _, body = raw.partition(b"\r\n\r\n")
        return int(head.split(b" ")[1]), body

    try:
        status, body = request("GET", "/healthz")
        assert status == 200 and json.loads(body)["status"] == "ok"

        prompt = np.random.default_rng(2).integers(
            0, cfg.vocab, (10,)).astype(np.int32)
        status, body = request("POST", "/v1/completions", {
            "prompt": [int(t) for t in prompt], "max_tokens": 6,
            "stream": True})
        assert status == 200, status
        tokens = []
        for frame in body.decode().split("\n\n"):
            for line in frame.split("\n"):
                if line.startswith("data: ") and line[6:] != "[DONE]":
                    tokens.append(
                        json.loads(line[6:])["choices"][0]["token"])
        assert "data: [DONE]" in body.decode(), "stream never closed"
        ref = lockstep_generate(cfg, qp, prompt, 6, max_len=40)
        assert tokens == ref, (tokens, ref)
        print(f"http smoke: streamed {len(tokens)} tokens == lockstep")

        status, body = request("GET", "/metrics")
        text = body.decode()
        assert status == 200
        for needle in ('repro_requests_total{outcome="length"} 1',
                       "repro_request_stage_seconds_bucket",
                       "repro_http_requests_total"):
            assert needle in text, needle
        print("http smoke: /metrics exports stage histograms + counters")
    finally:
        srv.close()
    assert not srv.frontend.pump.is_alive(), "pump survived shutdown"
    print("HTTP SERVING SMOKE OK")


if "--http-smoke" in sys.argv:
    http_smoke()
    raise SystemExit(0)

MODULES = [
    "mixtral_8x22b", "granite_moe_1b_a400m", "whisper_small",
    "jamba_1_5_large_398b", "llava_next_34b", "qwen1_5_32b", "stablelm_1_6b",
    "mistral_nemo_12b", "qwen1_5_110b", "rwkv6_1_6b", "bitnet_3b",
]

key = jax.random.PRNGKey(0)
B, S = 2, 24

for mod_name in MODULES:
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    cfg = mod.REDUCED.replace(lop_keep=1.0, capacity_factor=8.0)
    params, _ = init_params(cfg, key)
    qp = quantize_params(cfg, params)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0,
                                cfg.vocab)
    kwargs = {}
    if cfg.family == "encdec":
        kwargs["frames"] = jax.random.normal(key, (B, 48, cfg.d_model))
    if cfg.family == "vlm":
        kwargs["patches"] = jax.random.normal(key, (B, cfg.n_img_tokens,
                                                    cfg.d_model))

    logits_full, _ = prefill(cfg, qp, tokens, max_len=S + 2, **kwargs)
    logits_pre, cache = prefill(cfg, qp, tokens[:, :S], max_len=S + 2,
                                **kwargs)
    logits_dec, cache2 = serve_step(cfg, qp, cache, tokens[:, S:S + 1])

    err = float(jnp.max(jnp.abs(logits_dec - logits_full)))
    ref = float(jnp.max(jnp.abs(logits_full))) + 1e-9
    print(f"{cfg.name:38s} prefill+decode vs full: max abs err "
          f"{err:.2e} (rel {err/ref:.2e})")
    assert np.isfinite(np.asarray(logits_dec)).all(), cfg.name
    assert err / ref < 2e-2, (cfg.name, err, ref)
    expect_len = S + 1 + (cfg.n_img_tokens if cfg.family == "vlm" else 0)
    assert int(cache2["lengths"][0]) == expect_len

    # sparse decode (keep < 1) stays finite and close-ish
    cfg_sp = mod.REDUCED.replace(lop_keep=0.5, capacity_factor=8.0)
    if cfg_sp.family != "ssm":
        logits_sp, _ = serve_step(cfg_sp, qp, cache, tokens[:, S:S + 1])
        rel = float(jnp.linalg.norm(logits_sp - logits_full)
                    / (jnp.linalg.norm(logits_full) + 1e-9))
        print(f"{'':38s} lop_keep=0.5 rel err {rel:.3f}")
        assert np.isfinite(np.asarray(logits_sp)).all()

# ---------------------------------------------------------------------------
# Typed serving API smoke: streaming callback + sampled generation
# ---------------------------------------------------------------------------

from repro.configs.bitnet_3b import REDUCED as BITNET_R
from repro.serving.api import GenerateRequest, SamplingParams
from repro.serving.scheduler import Scheduler, lockstep_generate

cfg = BITNET_R
params, _ = init_params(cfg, key)
qp = quantize_params(cfg, params)
rng = np.random.default_rng(2)
prompts = [rng.integers(0, cfg.vocab, (n,)).astype(np.int32)
           for n in (10, 23)]
sps = [SamplingParams(),                                     # greedy
       SamplingParams(temperature=0.9, top_k=8, seed=13)]    # sampled
streamed: dict = {0: [], 1: []}
sched = Scheduler(cfg, qp, n_slots=2, max_len=40)
for rid, (p, sp) in enumerate(zip(prompts, sps)):
    sched.submit(GenerateRequest(
        rid=rid, prompt=p, max_new_tokens=6, sampling=sp,
        on_token=lambda sr: streamed[sr.rid].append(sr)))
results = sched.run_to_completion()
for rid, (p, sp) in enumerate(zip(prompts, sps)):
    res = next(r for r in results if r.rid == rid)
    srs = streamed[rid]
    assert [sr.token for sr in srs] == res.tokens, rid
    assert [sr.index for sr in srs] == list(range(len(res.tokens)))
    assert srs[-1].finished and not any(sr.finished for sr in srs[:-1])
    ref = lockstep_generate(cfg, qp, p, 6, max_len=40, sampling=sp)
    assert res.tokens == ref, (rid, res.tokens, ref)
    mode = "greedy" if sp.greedy else f"T={sp.temperature} seed={sp.seed}"
    print(f"api smoke rid {rid} ({mode}): {len(res.tokens)} tokens "
          f"streamed in order, pool == lockstep")

# ---------------------------------------------------------------------------
# Speculative-decoding smoke: γ=4 draft/verify must reproduce lockstep
# ---------------------------------------------------------------------------
# With use_lop=False the one-chunk verify is argmax-identical to plain
# decode, so every emitted token — greedy and seeded sampled alike — must
# match the lockstep replay exactly (DESIGN.md §Speculative-decoding).

spec = Scheduler(cfg, qp, n_slots=2, max_len=40, use_lop=False,
                 spec_decode=True, gamma=4)
for rid, (p, sp) in enumerate(zip(prompts, sps)):
    spec.submit(GenerateRequest(rid=rid, prompt=p, max_new_tokens=6,
                                sampling=sp))
spec_results = spec.run_to_completion()
assert spec.spec_rounds > 0, "speculative path never ran"
for rid, (p, sp) in enumerate(zip(prompts, sps)):
    res = next(r for r in spec_results if r.rid == rid)
    ref = lockstep_generate(cfg, qp, p, 6, max_len=40, use_lop=False,
                            sampling=sp)
    assert res.tokens == ref, (rid, res.tokens, ref)
rate = spec.spec_accepted / max(1, spec.spec_drafted)
print(f"spec smoke (γ=4): {spec.spec_rounds} rounds, accept rate "
      f"{rate:.2f}, {spec.spec_verify_launches} verifies, "
      f"{spec.decode_launches} plain decodes — spec == lockstep")

print("ALL SERVING SANITY OK")
