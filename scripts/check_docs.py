#!/usr/bin/env python
"""Docs honesty check: internal links and referenced paths must resolve.

    python scripts/check_docs.py [files...]

Defaults to README.md, DESIGN.md, ROADMAP.md, CHANGES.md. Three rules:

  1. every relative markdown link target ``[text](path#anchor)`` must
     exist on disk (http(s) links are not fetched);
  2. every backtick-quoted repo path that *looks* like a file
     (contains "/" and ends in a known extension, or is a top-level
     *.md / *.sh / *.py) must exist — either from the repo root or via
     the docs' ``src/repro``-relative shorthand (``core/lop.py``) — so
     the README's paper-section → module map cannot drift from the tree;
  3. every hyphenated DESIGN.md section reference (``§Chunked-prefill``
     style — paper-numbered refs like ``§2`` stay informal) must name a
     section that actually exists: its anchor has to appear in a
     DESIGN.md heading line, either as the heading itself
     (``## §Chunked-prefill``) or inline (``(§Roofline-accounting)``,
     bare ``Fused-decode-kernel``).

Exit code 1 with a per-file report if anything dangles; the CI runs this
after the test suite (scripts/ci_tier1.sh).
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
DEFAULT_FILES = ["README.md", "DESIGN.md", "ROADMAP.md", "CHANGES.md"]

MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
CODE_SPAN = re.compile(r"`([^`\n]+)`")
PATH_EXTS = (".py", ".md", ".sh", ".txt", ".json", ".yaml", ".yml")
# §Chunked-prefill-style anchors; a bare §2 / §III paper ref has no hyphen
SECTION_REF = re.compile(r"§([A-Za-z0-9]+(?:-[A-Za-z0-9]+)+)")


def _design_anchors() -> set[str]:
    """Hyphenated anchor names present in DESIGN.md heading lines, whether
    the heading IS the anchor (``## §Chunked-prefill``), carries it inline
    (``(§Roofline-accounting)``), or names it bare
    (``## Fused-decode-kernel (...)``)."""
    anchors: set[str] = set()
    design = ROOT / "DESIGN.md"
    if not design.exists():
        return anchors
    for line in design.read_text().splitlines():
        if not line.startswith("#"):
            continue
        anchors.update(SECTION_REF.findall(line))
        anchors.update(re.findall(r"\b([A-Za-z0-9]+(?:-[A-Za-z0-9]+)+)\b",
                                  line))
    return anchors


def _is_pathlike(span: str) -> bool:
    """A backtick span we hold to existing on disk."""
    if any(ch in span for ch in " ()[]{}<>=*,:$"):
        return False
    if not span.endswith(PATH_EXTS):
        return False
    # bare filenames are claims only when they name top-level docs/scripts;
    # module-ish spans like ``ops.py`` alone stay informal
    return "/" in span or (ROOT / span).suffix == ".md"


def check_file(path: Path, anchors: set[str]) -> list[str]:
    errors = []
    text = path.read_text()
    for m in MD_LINK.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if rel and not (path.parent / rel).exists():
            errors.append(f"dangling link: ({target})")
    for m in CODE_SPAN.finditer(text):
        span = m.group(1)
        if _is_pathlike(span) and not (ROOT / span).exists() \
                and not (ROOT / "src" / "repro" / span).exists():
            errors.append(f"referenced path missing: `{span}`")
    for name in sorted(set(SECTION_REF.findall(text))):
        if name not in anchors:
            errors.append(f"§{name} has no DESIGN.md section heading")
    return errors


def main(argv: list[str]) -> int:
    files = [Path(a) for a in argv] if argv else \
        [ROOT / f for f in DEFAULT_FILES]
    failed = 0
    anchors = _design_anchors()
    for f in files:
        if not f.exists():
            print(f"check_docs: {f} does not exist")
            failed += 1
            continue
        try:
            label = f.resolve().relative_to(ROOT)
        except ValueError:          # CLI arg outside the repo root
            label = f
        errs = check_file(f, anchors)
        for e in errs:
            print(f"check_docs: {label}: {e}")
        failed += len(errs)
    if failed:
        print(f"check_docs: {failed} problem(s)")
        return 1
    print(f"check_docs: OK ({len(files)} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
