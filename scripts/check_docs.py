#!/usr/bin/env python
"""Docs honesty check: internal links and referenced paths must resolve.

    python scripts/check_docs.py [files...]

Defaults to README.md, DESIGN.md, ROADMAP.md, CHANGES.md. Two rules:

  1. every relative markdown link target ``[text](path#anchor)`` must
     exist on disk (http(s) links are not fetched);
  2. every backtick-quoted repo path that *looks* like a file
     (contains "/" and ends in a known extension, or is a top-level
     *.md / *.sh / *.py) must exist — either from the repo root or via
     the docs' ``src/repro``-relative shorthand (``core/lop.py``) — so
     the README's paper-section → module map cannot drift from the tree.

Exit code 1 with a per-file report if anything dangles; the CI runs this
after the test suite (scripts/ci_tier1.sh).
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
DEFAULT_FILES = ["README.md", "DESIGN.md", "ROADMAP.md", "CHANGES.md"]

MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
CODE_SPAN = re.compile(r"`([^`\n]+)`")
PATH_EXTS = (".py", ".md", ".sh", ".txt", ".json", ".yaml", ".yml")


def _is_pathlike(span: str) -> bool:
    """A backtick span we hold to existing on disk."""
    if any(ch in span for ch in " ()[]{}<>=*,:$"):
        return False
    if not span.endswith(PATH_EXTS):
        return False
    # bare filenames are claims only when they name top-level docs/scripts;
    # module-ish spans like ``ops.py`` alone stay informal
    return "/" in span or (ROOT / span).suffix == ".md"


def check_file(path: Path) -> list[str]:
    errors = []
    text = path.read_text()
    for m in MD_LINK.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if rel and not (path.parent / rel).exists():
            errors.append(f"dangling link: ({target})")
    for m in CODE_SPAN.finditer(text):
        span = m.group(1)
        if _is_pathlike(span) and not (ROOT / span).exists() \
                and not (ROOT / "src" / "repro" / span).exists():
            errors.append(f"referenced path missing: `{span}`")
    return errors


def main(argv: list[str]) -> int:
    files = [Path(a) for a in argv] if argv else \
        [ROOT / f for f in DEFAULT_FILES]
    failed = 0
    for f in files:
        if not f.exists():
            print(f"check_docs: {f} does not exist")
            failed += 1
            continue
        try:
            label = f.resolve().relative_to(ROOT)
        except ValueError:          # CLI arg outside the repo root
            label = f
        errs = check_file(f)
        for e in errs:
            print(f"check_docs: {label}: {e}")
        failed += len(errs)
    if failed:
        print(f"check_docs: {failed} problem(s)")
        return 1
    print(f"check_docs: OK ({len(files)} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
