"""Aggregate experiments/dryrun/*.json into the §Dry-run / §Roofline tables
(markdown), printed to stdout and written to experiments/roofline_table.md."""
import glob
import json
import os

ORDER = ["mixtral-8x22b", "granite-moe-1b-a400m", "whisper-small",
         "jamba-1.5-large-398b", "llava-next-34b", "qwen1.5-32b",
         "stablelm-1.6b", "mistral-nemo-12b", "qwen1.5-110b", "rwkv6-1.6b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt_s(x):
    if x is None:
        return "—"
    if x < 0:
        return "≈0*"   # linear-extrapolation noise on a near-zero term
    if x < 1e-3:
        return f"{x*1e6:.0f}µs"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def fmt_b(x):
    for unit, div in (("GiB", 2**30), ("MiB", 2**20), ("KiB", 2**10)):
        if x >= div:
            return f"{x/div:.2f}{unit}"
    return f"{x:.0f}B"


def main(out_dir="experiments/dryrun"):
    cells = {}
    for path in glob.glob(os.path.join(out_dir, "*.json")):
        with open(path) as f:
            d = json.load(f)
        cells[(d["arch"], d["shape"], d["mesh"])] = d

    lines = []
    lines.append("## §Dry-run (compile status, per-device memory)\n")
    lines.append("| arch | shape | 16×16 | 2×16×16 | peak mem/dev | "
                 "compile s |")
    lines.append("|---|---|---|---|---|---|")
    for arch in ORDER:
        for shape in SHAPES:
            sp = cells.get((arch, shape, "pod16x16"))
            mp = cells.get((arch, shape, "pod2x16x16"))
            if sp is None and mp is None:
                continue
            st = lambda c: ("—" if c is None else
                            {"ok": "✓", "skip": "skip", "fail": "✗"}[
                                c["status"]])
            mem = (fmt_b(sp["memory"]["peak_estimate_bytes"])
                   if sp and sp["status"] == "ok" else "—")
            comp = (f"{sp['compile_s']:.0f}"
                    if sp and sp["status"] == "ok" else "—")
            lines.append(f"| {arch} | {shape} | {st(sp)} | {st(mp)} | "
                         f"{mem} | {comp} |")

    lines.append("\n## §Roofline (single-pod, differential-costed)\n")
    lines.append("| arch | shape | compute | memory | collective | "
                 "dominant | MODEL_FLOPs/chip | useful frac | "
                 "roofline frac |")
    lines.append("|---|---|---|---|---|---|---|---|---|")
    for arch in ORDER:
        for shape in SHAPES:
            c = cells.get((arch, shape, "pod16x16"))
            if c is None or c["status"] != "ok":
                continue
            r = c["roofline"]
            diff = c.get("differential")
            if diff:
                useful = f"{r.get('useful_fraction', 0):.2f}"
                frac = f"{r.get('roofline_fraction', 0):.4f}"
                dom = r["dominant"].replace("_s", "")
            else:
                # fast-pass cell: scan bodies counted once — raw terms are
                # NOT roofline-comparable (marked †, fractions suppressed)
                useful = "—"
                frac = "—"
                dom = r["dominant"].replace("_s", "") + "†"
            lines.append(
                f"| {arch} | {shape} | {fmt_s(r['compute_s'])} | "
                f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
                f"{dom} | "
                f"{r.get('model_flops', 0):.2e} | {useful} | {frac} |")
    lines.append("\n† = differential costing pending for this cell "
                 "(loop bodies counted once; see DESIGN.md §8).")

    text = "\n".join(lines) + "\n"
    print(text)
    with open("experiments/roofline_table.md", "w") as f:
        f.write(text)


if __name__ == "__main__":
    main()
