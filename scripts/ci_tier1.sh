#!/usr/bin/env bash
# Tier-1 verify (ROADMAP.md): the exact command the driver runs.
# Usage: scripts/ci_tier1.sh [extra pytest args...]
#
# Deterministic tests must pass even without the dev extras installed
# (property-based modules importorskip hypothesis); install
# requirements-dev.txt to run the full property suite.
#
# After the main suite, the kernel test modules AND the serving-API tests
# re-run under BOTH dispatch arms — REPRO_KERNEL_IMPL=ref (jnp oracles) and
# REPRO_KERNEL_IMPL=pallas (interpret-mode Pallas kernels) — so neither
# side of the ops.py dispatch can rot while the other stays green, and the
# sampler's pool-vs-lockstep equivalence holds on both.
set -euo pipefail
cd "$(dirname "$0")/.."

if ! python -c "import hypothesis" 2>/dev/null; then
    echo "ci_tier1: hypothesis not installed — property-based tests will" \
         "skip (pip install -r requirements-dev.txt for full coverage)" >&2
fi

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python -m pytest -x -q "$@"

KERNEL_TESTS="tests/test_kernels.py tests/test_decode_attention.py \
tests/test_prefill_attention.py tests/test_qlinear_fused.py \
tests/test_serving_api.py tests/test_prefix_cache.py \
tests/test_spec_decode.py tests/test_autotune.py \
tests/test_bench_trajectory.py tests/test_faults.py \
tests/test_metrics.py tests/test_http_frontend.py"
for impl in ref pallas; do
    echo "ci_tier1: kernel tests under REPRO_KERNEL_IMPL=${impl}" >&2
    REPRO_KERNEL_IMPL="${impl}" python -m pytest -x -q ${KERNEL_TESTS}
    # chaos smoke with every-step invariant auditing: 200 mixed-fate
    # requests under seeded fault injection must terminate cleanly and
    # bitwise-reproduce on both dispatch arms (DESIGN.md §Fault-tolerance)
    echo "ci_tier1: REPRO_PARANOID chaos smoke under REPRO_KERNEL_IMPL=${impl}" >&2
    REPRO_PARANOID=1 REPRO_KERNEL_IMPL="${impl}" \
        python -m pytest -x -q tests/test_faults.py -k chaos
done

# HTTP front-end loopback smoke: start a real server, stream a completion
# over a socket, check it against lockstep, scrape /metrics, shut down
# (DESIGN.md §Serving-frontend)
echo "ci_tier1: HTTP serving smoke" >&2
python scripts/sanity_serving.py --http-smoke

# perf-gate static half: every BENCH leaf must map to a declared kernel and
# the autotune table (if present) must validate — no benchmarks, no sweep
echo "ci_tier1: benchmark coverage + tuning-table check" >&2
python -m benchmarks.run --check

# docs honesty: README/DESIGN/ROADMAP/CHANGES internal links and referenced
# paths must resolve (the paper-section → module map cannot drift)
echo "ci_tier1: markdown link/path check" >&2
python scripts/check_docs.py
