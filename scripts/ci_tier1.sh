#!/usr/bin/env bash
# Tier-1 verify (ROADMAP.md): the exact command the driver runs.
# Usage: scripts/ci_tier1.sh [extra pytest args...]
#
# Deterministic tests must pass even without the dev extras installed
# (property-based modules importorskip hypothesis); install
# requirements-dev.txt to run the full property suite.
set -euo pipefail
cd "$(dirname "$0")/.."

if ! python -c "import hypothesis" 2>/dev/null; then
    echo "ci_tier1: hypothesis not installed — property-based tests will" \
         "skip (pip install -r requirements-dev.txt for full coverage)" >&2
fi

PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" exec python -m pytest -x -q "$@"
