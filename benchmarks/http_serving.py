"""HTTP serving-path benchmark: TTFT/ITL over real loopback sockets.

Spins the production front-end (``repro.serving.frontend``) on an
ephemeral loopback port — real asyncio server, real scheduler-pump
thread, real HTTP parsing — then drives concurrent streaming
completions from socket clients and measures what a caller actually
sees: time-to-first-SSE-frame (TTFT including HTTP + queueing),
inter-frame gaps (ITL) and aggregate tokens/s. One response is replayed
through :func:`repro.serving.scheduler.lockstep_generate` to pin the
transport-adds-nothing guarantee, and the final ``/metrics`` scrape is
folded into the payload so the server's own counters ride the
trajectory gate too.

Raw series goes to ``BENCH_http.json``. On CPU the absolute times are
compile/dispatch-dominated; the structural leaves (request counts, SSE
frame counts, server counters) are exact.
"""

from __future__ import annotations

import json
import socket
import threading
import time

N_REQUESTS = 6
GEN = 8
N_SLOTS = 2
MAX_LEN = 63            # pool capacity 64 with the reduced lop_block


def _setup():
    import jax

    from repro.configs.bitnet_3b import REDUCED
    from repro.models.transformer import init_params
    from repro.serving.metrics import MetricsRegistry
    from repro.serving.quantize import quantize_params
    from repro.serving.scheduler import Scheduler

    cfg = REDUCED
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    qp = quantize_params(cfg, params)
    registry = MetricsRegistry()
    sched = Scheduler(cfg, qp, n_slots=N_SLOTS, max_len=MAX_LEN,
                      max_queue=4 * N_REQUESTS, metrics=registry)
    return cfg, qp, sched, registry


def _prompts(cfg, n, *, seed=3):
    import numpy as np

    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab,
                         (int(rng.integers(6, 25)),)).astype(np.int32)
            for _ in range(n)]


def _stream_one(port, prompt, out):
    """One socket client: POST a streaming completion, stamp every SSE
    data frame's arrival. Fills ``out`` with tokens + times."""
    body = json.dumps({"prompt": [int(t) for t in prompt],
                       "max_tokens": GEN, "stream": True}).encode()
    s = socket.create_connection(("127.0.0.1", port), timeout=300)
    t_send = time.monotonic()
    s.sendall(b"POST /v1/completions HTTP/1.1\r\nHost: bench\r\n"
              b"Content-Length: %d\r\n\r\n%s" % (len(body), body))
    buf, tokens, stamps, done = b"", [], [], False
    while True:
        chunk = s.recv(65536)
        if not chunk:
            break
        buf += chunk
        while b"\n\n" in buf:
            frame, buf = buf.split(b"\n\n", 1)
            for line in frame.split(b"\n"):
                if not line.startswith(b"data: "):
                    continue
                data = line[6:].decode()
                if data == "[DONE]":
                    done = True
                    continue
                tokens.append(
                    json.loads(data)["choices"][0]["token"])
                stamps.append(time.monotonic())
    s.close()
    out.update(t_send=t_send, tokens=tokens, stamps=stamps, done=done)


def run():
    from repro.serving.frontend import serve_threaded
    from repro.serving.metrics import percentile
    from repro.serving.scheduler import lockstep_generate

    cfg, qp, sched, registry = _setup()
    srv = serve_threaded(sched, model_name=cfg.name, registry=registry)
    prompts = _prompts(cfg, N_REQUESTS)
    try:
        # warmup request off the clock: prefill/decode compiles
        warm: dict = {}
        _stream_one(srv.port, prompts[0], warm)
        assert warm["done"] and len(warm["tokens"]) == GEN, warm

        clients = [{} for _ in prompts]
        threads = [threading.Thread(target=_stream_one,
                                    args=(srv.port, p, out))
                   for p, out in zip(prompts, clients)]
        t0 = time.monotonic()
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=600)
        wall = time.monotonic() - t0

        assert all(c["done"] for c in clients), "a stream never finished"
        # the transport adds nothing: replay one stream through lockstep
        ref = lockstep_generate(cfg, qp, prompts[0], GEN, max_len=MAX_LEN)
        assert clients[0]["tokens"] == list(ref), (
            clients[0]["tokens"], ref)

        ttft = [c["stamps"][0] - c["t_send"] for c in clients]
        itl = [b - a for c in clients
               for a, b in zip(c["stamps"], c["stamps"][1:])]
        n_frames = sum(len(c["tokens"]) for c in clients)

        scrape = registry.render()
    finally:
        srv.close()

    payload = {
        "trace": {"n_requests": N_REQUESTS, "gen": GEN,
                  "n_slots": N_SLOTS, "arch": cfg.name},
        "http": {
            "ttft_p50_ms": percentile(ttft, 50) * 1e3,
            "ttft_p99_ms": percentile(ttft, 99) * 1e3,
            "itl_p50_ms": percentile(itl, 50) * 1e3,
            "itl_p99_ms": percentile(itl, 99) * 1e3,
            "wall_s": wall,
            "tokens_per_s": n_frames / max(wall, 1e-9),
            "requests_ok": sum(c["done"] for c in clients),
            "sse_frames": n_frames,
        },
        "server": {
            "requests_total": int(registry.value(
                "repro_requests_total", {"outcome": "length"})),
            "tokens_total": int(registry.value(
                "repro_tokens_generated_total")),
            "shed_total": int(registry.value(
                "repro_requests_shed_total")),
        },
    }
    with open("BENCH_http.json", "w") as fh:
        json.dump(payload, fh, indent=2)

    assert "repro_request_stage_seconds_bucket" in scrape
    return [
        ("http_serving/ttft_p50_ms", payload["http"]["ttft_p50_ms"],
         "send -> first SSE frame, loopback HTTP + queue + prefill"),
        ("http_serving/ttft_p99_ms", payload["http"]["ttft_p99_ms"],
         "tail TTFT under 3x slot contention"),
        ("http_serving/itl_p50_ms", payload["http"]["itl_p50_ms"],
         "SSE inter-frame gap (decode step + delivery)"),
        ("http_serving/itl_p99_ms", payload["http"]["itl_p99_ms"],
         "tail inter-frame gap"),
        ("http_serving/tokens_per_s", payload["http"]["tokens_per_s"],
         f"{N_REQUESTS} concurrent streams over {N_SLOTS} slots"),
        ("http_serving/requests_ok", payload["http"]["requests_ok"],
         "streams that reached [DONE] (all, or the bench fails)"),
        ("http_serving/server_tokens_total",
         payload["server"]["tokens_total"],
         "scheduler counter scraped from /metrics (warmup included)"),
    ]
