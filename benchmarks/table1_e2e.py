"""Table I reproduction: end-to-end decode/prefill throughput model.

The paper's silicon numbers (BitNet-3B @ 16 nm, 1 GHz, 0.8 V): 72.46 tok/s
decode, 0.88 s prefill (64 tokens), 120 KB SRAM, 59.12 mW. We rebuild the
*analytic* throughput model for (a) the paper's ASIC parameters and (b) one
TPU v5e chip running this framework's deployment format, from first
principles:

  decode is bandwidth-bound: tokens/s ≈ mem_bw / bytes_per_token, where
  bytes_per_token = packed ternary weights (N/4 B) + KV traffic
  (LOP: M·d/2 feature bytes + 2·K·d exact bytes per head... dominated by
  weights at edge batch=1).

Validating against the paper's own silicon: with the ASIC's effective DDR
bandwidth ≈ 2 GB/s (edge LPDDR class), 3B ternary weights = 0.75 GB/token
→ ~2.7 tok/s would be DDR-bound — the paper's 72.46 tok/s implies weight
residency/reuse across the pipeline plus their 26-38% utilization gains;
we therefore model the ASIC bound from its reported numbers and focus the
cross-check on *ratios* (LOP/KV) and on the v5e projection.
"""

from __future__ import annotations

import numpy as np

from repro.configs.bitnet_3b import CONFIG as BITNET

HBM_BW_V5E = 819e9
PEAK_INT8_V5E = 394e12


def decode_bytes_per_token(cfg, n_params: int, m_cache: int, batch: int,
                           *, with_lop: bool) -> float:
    """HBM bytes per generated token per sequence (weights amortized over
    the batch) for the deployment format."""
    weight_bytes = n_params / 4          # packed 2-bit ternary
    d = cfg.hd
    hkv, h = cfg.n_kv_heads, cfg.n_heads
    if with_lop:
        k_tokens = int(cfg.lop_keep * m_cache)
        kv = cfg.n_layers * hkv * (m_cache * d / 2          # feature screen
                                   + 2 * k_tokens * d)      # exact K/V
    else:
        kv = cfg.n_layers * hkv * 2 * m_cache * d
    return weight_bytes / batch + kv


def lane_utilization(gen_lens, *, lockstep: bool) -> float:
    """Fraction of lane-steps that emit a token for a batch of requests.

    Lockstep pads every request to the slowest one (a lane that finished
    early idles until the batch drains); the slot-paged scheduler refills a
    lane the step after it retires, so utilization is ~1 (one prefill-step
    bubble per admission, ignored in this model).
    """
    gen_lens = np.asarray(gen_lens, np.float64)
    if lockstep:
        return float(gen_lens.mean() / gen_lens.max())
    return 1.0


def run():
    cfg = BITNET
    n_params = 3.3e9
    m = 4096                     # cache length for the projection

    rows = []
    for batch in (1, 8, 64):
        for with_lop in (False, True):
            bpt = decode_bytes_per_token(cfg, n_params, m, batch,
                                         with_lop=with_lop)
            toks = HBM_BW_V5E / bpt
            rows.append((
                f"table1/v5e_decode_toks_b{batch}_"
                f"{'lop' if with_lop else 'dense'}",
                toks,
                f"bandwidth-bound tok/s/seq @M={m} (×{batch} seqs)"))

    # compute-bound prefill estimate (64 tokens, int8 MXU)
    prefill_flops = 2 * n_params * 64
    t_prefill = prefill_flops / PEAK_INT8_V5E
    rows.append(("table1/v5e_prefill64_s", t_prefill,
                 "paper ASIC: 0.88 s (64 tok); v5e compute bound"))
    rows.append(("table1/paper_decode_toks", 72.46, "paper silicon, Table I"))
    rows.append(("table1/weight_mem_GB", n_params / 4 / 1e9,
                 "packed ternary (7-8x smaller than bf16)"))

    # continuous batching: lane utilization under a mixed-length workload
    # (log-normal-ish generation lengths, the usual serving distribution)
    rng = np.random.default_rng(0)
    gen_lens = np.clip(rng.lognormal(5.0, 0.8, 256), 8, 2048)
    util_lock = lane_utilization(gen_lens, lockstep=True)
    util_cb = lane_utilization(gen_lens, lockstep=False)
    bpt = decode_bytes_per_token(cfg, n_params, m, 64, with_lop=True)
    base_toks = HBM_BW_V5E / bpt * 64
    rows.append(("table1/lane_util_lockstep", util_lock,
                 "mean(gen)/max(gen): idle lane-steps padding to slowest"))
    rows.append(("table1/lane_util_slot_paged", util_cb,
                 "slot-paged pool refills lanes as they retire"))
    # effective goodput: roofline tok/s × the fraction of lane-steps that
    # actually emit (lockstep idles lanes; slot-paged keeps them full)
    rows.append(("table1/v5e_decode_toks_b64_lop_lockstep_eff",
                 base_toks * util_lock / 64,
                 "per-seq goodput with lockstep lane idling"))
    rows.append(("table1/v5e_decode_toks_b64_lop_continuous",
                 base_toks * util_cb / 64,
                 f"per-seq goodput, slot-paged "
                 f"(×{util_cb / util_lock:.2f} vs lockstep on the same "
                 "mixed-length traffic)"))

    # inter-token latency alongside TTFT (the serving-API telemetry,
    # launch/serve.py reports the measured analogues): p50 is the pure
    # bandwidth-bound decode step; p99 is a step that shares its serve
    # cycle with one chunked-prefill chunk (the interleaving tax a lane
    # pays while another prompt prefills — DESIGN.md §Chunked-prefill)
    chunk = cfg.lop_block                       # chunk_tokens default
    bpt1 = decode_bytes_per_token(cfg, n_params, m, 64, with_lop=True)
    step_s = bpt1 * 64 / HBM_BW_V5E             # whole-batch decode step
    chunk_s = 2 * n_params * chunk / PEAK_INT8_V5E
    # modeled step series — 90% pure decode cycles, 10% cycles sharing
    # with a prefill chunk — reduced through the shared percentile
    # helper, the same reduction launch/serve.py applies to measured ITL
    from repro.serving.metrics import percentile
    itl_series = [step_s] * 90 + [step_s + chunk_s] * 10
    rows.append(("table1/v5e_itl_p50_ms", percentile(itl_series, 50) * 1e3,
                 "bandwidth-bound decode step (batch 64, LOP)"))
    rows.append(("table1/v5e_itl_p99_ms", percentile(itl_series, 99) * 1e3,
                 f"decode step sharing its cycle with a {chunk}-token "
                 "prefill chunk"))
    n_chunks = -(-64 // chunk)
    rows.append(("table1/v5e_ttft64_chunked_s",
                 n_chunks * (step_s + chunk_s),
                 f"64-token prompt TTFT under interleaving ({n_chunks} "
                 "chunked serve cycles; paper ASIC prefill64: 0.88 s)"))

    # slot-paged KV memory per lane (capacity M, int8 K/V + scales + feat)
    kv_lane = cfg.n_layers * cfg.n_kv_heads * m * (2 * cfg.hd    # K+V int8
                                                   + 8           # scales f32
                                                   + cfg.hd // 2)  # features
    rows.append(("table1/kv_bytes_per_slot_MB", kv_lane / 1e6,
                 f"per-lane pool footprint @M={m} (block-aligned pages)"))
    return rows
