"""Prefix-caching ablation: shared prompts cost one prefill.

Replays ONE shared-prefix arrival trace twice over the SAME engine (so
both arms hit warm jit caches): 8 of 10 requests share a 256-token
prompt prefix (a system prompt / few-shot template), arrivals staggered
so the first sharer's prefill is interned before the others land.

  * **cache on** — the scheduler matches each sharer against the
    :class:`repro.serving.cache.PrefixStore`, clones the interned pages
    (``bulk_insert``: K/V + packed LOP feature rows) and prefills only
    the suffix, so TTFT for a hit collapses to ~one chunk.
  * **cache off** — every prompt prefills cold (the pre-PR behaviour).

Reported: TTFT p50/p99 split hit vs miss, the hit-vs-cache-off TTFT
ratio over the SAME request ids (the ≥3× acceptance bar), prefill
tokens computed vs served, and store hit counters. Both arms must emit
identical greedy tokens (prefix reuse is pure scheduling). The raw
series goes to ``BENCH_prefix.json`` for run-over-run comparison. On
CPU absolute times are modest; the computed-token collapse and the
hit/miss ratio are the claim.
"""

from __future__ import annotations

import json

N_REQUESTS = 10
SHARED = 256          # shared prefix length (8 lop_block=32 pages)
REUSE_FRAC = 0.8      # rids 0..7 share; 8, 9 stay cold
GEN = 6
ARRIVAL_S = 0.25


def _engine():
    from repro.configs.bitnet_3b import REDUCED
    from repro.launch.serve import serve_loop  # noqa: F401 (import check)
    from repro.models.transformer import init_params
    from repro.serving.api import PooledEngine
    from repro.serving.quantize import quantize_params
    import jax

    cfg = REDUCED
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    qp = quantize_params(cfg, params)
    # one engine for warmup + both arms: max_len covers prefix + suffix +
    # generation, so every compile is shared
    return cfg, PooledEngine(cfg, qp, max_len=SHARED + 24 + GEN)


def _serve(engine, *, prefix_cache: bool, arrival: float = ARRIVAL_S,
           n_requests: int = N_REQUESTS, seed: int = 0):
    from repro.launch.serve import serve_loop

    return serve_loop(None, n_slots=4, n_requests=n_requests, min_prompt=8,
                      max_prompt=24, gen=GEN, arrival_period=arrival,
                      seed=seed, shared_prefix_tokens=SHARED,
                      prefix_reuse_frac=REUSE_FRAC,
                      prefix_cache=prefix_cache, engine=engine)


def run():
    import numpy as np

    cfg, engine = _engine()
    # warmup: compile chunk/decode/bulk-insert shapes off the clock
    _serve(engine, prefix_cache=True, arrival=0.05, n_requests=3, seed=9)

    on = _serve(engine, prefix_cache=True)
    off = _serve(engine, prefix_cache=False)

    # prefix reuse is pure scheduling: identical greedy tokens either way
    for rid, toks in on["tokens"].items():
        assert list(toks) == list(off["tokens"][rid]), rid
    hit_rids = [r.rid for r in on["results"] if r.cached_len]
    assert len(hit_rids) >= 6, f"expected most sharers to hit: {hit_rids}"
    # computed ≈ 1 shared prefill + per-request suffixes
    assert on["prefill_tokens_served"] - on["prefill_tokens_computed"] \
        == SHARED * len(hit_rids)
    assert off["prefill_tokens_computed"] == off["prefill_tokens_served"]

    # the acceptance ratio: hit TTFT vs the SAME rids prefilling cold
    ttft_on = np.asarray([r.ttft for r in on["results"]
                          if r.rid in hit_rids])
    ttft_off = np.asarray([r.ttft for r in off["results"]
                           if r.rid in hit_rids])
    ratio = float(np.median(ttft_off) / max(np.median(ttft_on), 1e-9))

    payload = {
        "trace": {"n_requests": N_REQUESTS, "shared_prefix_tokens": SHARED,
                  "prefix_reuse_frac": REUSE_FRAC, "gen": GEN,
                  "arrival_period_s": ARRIVAL_S, "arch": cfg.name},
        "cache_on": {k: on[k] for k in (
            "ttft_p50", "ttft_p99", "ttft_hit_p50", "ttft_hit_p99",
            "ttft_miss_p50", "ttft_miss_p99", "prefix_hits",
            "prefix_hit_tokens", "prefill_tokens_computed",
            "prefill_tokens_served", "tokens_per_s", "wall_s")},
        "cache_off": {k: off[k] for k in (
            "ttft_p50", "ttft_p99", "prefill_tokens_computed",
            "prefill_tokens_served", "tokens_per_s", "wall_s")},
        "ttft_hit_vs_cache_off_ratio": ratio,
        "ttft_per_request": {
            "cache_on": {r.rid: r.ttft for r in on["results"]},
            "cache_off": {r.rid: r.ttft for r in off["results"]},
            "cached_len": {r.rid: r.cached_len for r in on["results"]},
        },
    }
    with open("BENCH_prefix.json", "w") as fh:
        json.dump(payload, fh, indent=2)

    return [
        ("prefix_cache/ttft_hit_p50_ms", on["ttft_hit_p50"] * 1e3,
         "TTFT of prefix-hit requests (suffix-only prefill)"),
        ("prefix_cache/ttft_hit_p99_ms", on["ttft_hit_p99"] * 1e3,
         "tail TTFT of hits"),
        ("prefix_cache/ttft_miss_p50_ms", on["ttft_miss_p50"] * 1e3,
         "TTFT of cold prompts in the same run"),
        ("prefix_cache/ttft_cache_off_p50_ms", off["ttft_p50"] * 1e3,
         "same trace, store disabled"),
        ("prefix_cache/ttft_hit_vs_cache_off_ratio", ratio,
         "median cache-off / hit TTFT over hit rids (claim: >= 3)"),
        ("prefix_cache/prefix_hits", on["prefix_hits"],
         "requests served from interned pages"),
        ("prefix_cache/prefill_tokens_computed_cache_on",
         on["prefill_tokens_computed"],
         "~ 1 shared prefill + per-request suffixes"),
        ("prefix_cache/prefill_tokens_computed_cache_off",
         off["prefill_tokens_computed"], "every prompt cold"),
        ("prefix_cache/prefill_tokens_served",
         on["prefill_tokens_served"], "prompt tokens across the trace"),
        ("prefix_cache/tokens_per_s_cache_on", on["tokens_per_s"],
         "aggregate throughput"),
        ("prefix_cache/tokens_per_s_cache_off", off["tokens_per_s"],
         "aggregate throughput"),
    ]
