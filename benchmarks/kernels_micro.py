"""Kernel microbenchmarks: correctness + HBM-traffic models per kernel.

CPU wall times cover the *ref* path (what the dry-run traces); the Pallas
kernels are validated in interpret mode (bit-exact vs ref — see
tests/test_kernels.py) and their value on real TPU is the traffic model
reported here: packed ternary = 4× less weight HBM than int8, LOP feature
screen = 16× less than bf16 K reads.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lop import lop_features, pack_features
from repro.core.ternary import make_ternary_weight
from repro.kernels import ops


def _time(fn, *args, iters=10):
    jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def run():
    rng = np.random.default_rng(0)
    m, k, n = 256, 2048, 2048
    x = jnp.asarray(rng.integers(-127, 128, (m, k)), jnp.int8)
    w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32) * 0.02
    tw = make_ternary_weight(w)
    xf = x.astype(jnp.float32)

    t_tern = _time(jax.jit(lambda a: ops.ternary_matmul(a, tw, impl="ref")),
                   x)
    t_f32 = _time(jax.jit(lambda a: a @ w), xf)

    # LOP screen vs exact int8 scores over a big cache
    mcache, d = 8192, 128
    kc = jnp.asarray(rng.integers(-127, 128, (mcache, d)), jnp.int8)
    feat = pack_features(lop_features(kc))
    q = jnp.asarray(rng.integers(-127, 128, (16, d)), jnp.int8)
    t_screen = _time(jax.jit(lambda a: ops.lop_screen(a, feat, impl="ref")),
                     q)
    t_exact = _time(jax.jit(
        lambda a: jax.lax.dot(a, kc.T, preferred_element_type=jnp.int32)), q)

    rows = [
        ("kernels/ternary_matmul_ref_us", t_tern,
         f"{m}x{k}x{n} packed-2bit x int8"),
        ("kernels/f32_matmul_us", t_f32, "same GEMM in f32"),
        ("kernels/weight_bytes_packed", k * n // 4, "2 bit/weight"),
        ("kernels/weight_bytes_int8", k * n, "4x packed"),
        ("kernels/weight_bytes_bf16", 2 * k * n, "8x packed"),
        ("kernels/lop_screen_us", t_screen,
         f"{mcache}-token feature-cache screen"),
        ("kernels/exact_scores_us", t_exact, "exact int8 qk over cache"),
        ("kernels/screen_bytes", mcache * d // 2, "4-bit features"),
        ("kernels/exact_bytes", mcache * d, "int8 keys (2x screen)"),
    ]
    return rows
