"""Kernel microbenchmarks: correctness + HBM-traffic models per kernel.

CPU wall times cover the *ref* path (what the dry-run traces); the Pallas
kernels are validated in interpret mode (bit-exact vs ref — see
tests/test_kernels.py, tests/test_qlinear_fused.py) and their value on
real TPU is the traffic model reported here: packed ternary = 4× less
weight HBM than int8, LOP feature screen = 16× less than bf16 K reads.

Fused-vs-legacy projection dispatch
-----------------------------------
The projection path used to launch the absmax quantize, the standalone
``ternary_matmul`` kernel and the dequant/bias/activation as separate
dispatches per projection — 7+ per decoder layer (q, k, v, o, gate, up,
down), each round-tripping HBM. It is now ≤ 3 fused dispatches (QKV = 1,
O = 1, whole FFN = 1; a MoE layer's expert FFNs = 1 grouped dispatch).
This module keeps a local copy of the legacy per-projection dispatch and
reports both per-layer step costs plus the Pallas call-site count of each
path (jaxpr equation count — the portable proxy for kernel launch
boundaries, as in benchmarks/fig8_lop.py), emitting the numbers to
``BENCH_proj.json`` for the driver.
"""

from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lop import lop_features, pack_features
from repro.core.quantization import quantize
from repro.core.ternary import make_ternary_weight
from repro.kernels import ops


def _time(fn, *args, iters=10):
    jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def _count_pallas(jaxpr) -> int:
    """pallas_call equations per INVOCATION: recurse into call primitives
    (pjit/scan/...) so two same-shape projections count as two launches —
    a plain ``str(jaxpr).count`` would dedupe them to one shared subjaxpr."""
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            n += 1
        for val in eqn.params.values():
            vals = val if isinstance(val, (tuple, list)) else (val,)
            for v in vals:
                inner = getattr(v, "jaxpr", None)
                if inner is not None and hasattr(inner, "eqns"):
                    n += _count_pallas(inner)
                elif hasattr(v, "eqns"):
                    n += _count_pallas(v)
    return n


def _pallas_call_sites(fn, *args) -> int:
    """Kernel launch boundaries in the traced program (portable proxy)."""
    return _count_pallas(jax.make_jaxpr(fn)(*args).jaxpr)


def _legacy_qlinear(tw, x):
    """The pre-fusion projection chain: jnp absmax quantize → standalone
    ternary_matmul dispatch → jnp dequant (kept verbatim as baseline)."""
    xq = quantize(x)
    acc = ops.ternary_matmul(xq.values, tw, impl="pallas")
    return acc.astype(jnp.float32) * xq.scale * jnp.asarray(
        tw.scale, jnp.float32).reshape(())


def _layer_shapes(d=2048, hd=128, h=16, hkv=4, f=5632, m=4):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((m, d)), jnp.float32)
    tws = {
        "wq": make_ternary_weight(
            jnp.asarray(rng.standard_normal((d, h * hd)), jnp.float32) * 0.02),
        "wk": make_ternary_weight(
            jnp.asarray(rng.standard_normal((d, hkv * hd)), jnp.float32) * 0.02),
        "wv": make_ternary_weight(
            jnp.asarray(rng.standard_normal((d, hkv * hd)), jnp.float32) * 0.02),
        "wo": make_ternary_weight(
            jnp.asarray(rng.standard_normal((h * hd, d)), jnp.float32) * 0.02),
        "w_gate": make_ternary_weight(
            jnp.asarray(rng.standard_normal((d, f)), jnp.float32) * 0.02),
        "w_up": make_ternary_weight(
            jnp.asarray(rng.standard_normal((d, f)), jnp.float32) * 0.02),
        "w_down": make_ternary_weight(
            jnp.asarray(rng.standard_normal((f, d)), jnp.float32) * 0.02),
    }
    return x, tws, (d, hd, h, hkv, f)


def _fused_nodes(tws, dims):
    d, hd, h, hkv, f = dims

    def col(tw):
        return jnp.broadcast_to(
            jnp.asarray(tw.scale, jnp.float32).reshape(1, 1),
            (1, tw.shape[1]))

    qkv_packed = jnp.concatenate(
        [tws[k].packed for k in ("wq", "wk", "wv")], -1)
    qkv_scale = jnp.concatenate([col(tws[k]) for k in ("wq", "wk", "wv")],
                                -1)
    gu_packed = jnp.concatenate(
        [tws["w_gate"].packed, tws["w_up"].packed], -1)
    gu_scale = jnp.concatenate([col(tws["w_gate"]), col(tws["w_up"])], -1)
    return {
        "qkv": (qkv_packed, qkv_scale),
        "wo": (tws["wo"].packed,
               jnp.asarray(tws["wo"].scale, jnp.float32).reshape(1, 1)),
        "gu": (gu_packed, gu_scale),
        "down": (tws["w_down"].packed,
                 jnp.asarray(tws["w_down"].scale,
                             jnp.float32).reshape(1, 1)),
    }


def _run_projection_paths():
    x, tws, dims = _layer_shapes()
    d, hd, h, hkv, f = dims
    nodes = _fused_nodes(tws, dims)

    # both paths RETURN the K/V projections (a real layer consumes them
    # for the cache write) so XLA cannot dead-code-eliminate them and the
    # step costs cover all 7 projections
    def fused_layer(x):
        qkv = ops.qlinear_fused(x, *nodes["qkv"], impl="pallas")
        o = ops.qlinear_fused(qkv[:, : h * hd], *nodes["wo"],
                              impl="pallas")
        y = ops.ffn_fused(o, *nodes["gu"], *nodes["down"], gated=True,
                          act="silu", impl="pallas")
        return y, qkv[:, h * hd:]

    def legacy_layer(x):
        q = _legacy_qlinear(tws["wq"], x)
        k = _legacy_qlinear(tws["wk"], x)
        v = _legacy_qlinear(tws["wv"], x)
        o = _legacy_qlinear(tws["wo"], q)
        g = jax.nn.silu(_legacy_qlinear(tws["w_gate"], o))
        u = _legacy_qlinear(tws["w_up"], o)
        return _legacy_qlinear(tws["w_down"], g * u), k, v

    sites_fused = _pallas_call_sites(fused_layer, x)
    sites_legacy = _pallas_call_sites(legacy_layer, x)

    # CPU step cost on ref semantics (what the dry-run traces)
    def fused_ref(x):
        qkv = ops.qlinear_fused(x, *nodes["qkv"], impl="ref")
        o = ops.qlinear_fused(qkv[:, : h * hd], *nodes["wo"], impl="ref")
        y = ops.ffn_fused(o, *nodes["gu"], *nodes["down"], gated=True,
                          act="silu", impl="ref")
        return y, qkv[:, h * hd:]

    def legacy_ref(x):
        def lin(tw, xx):
            xq = quantize(xx)
            acc = ops.ternary_matmul(xq.values, tw, impl="ref")
            return acc.astype(jnp.float32) * xq.scale * jnp.asarray(
                tw.scale, jnp.float32).reshape(())
        q = lin(tws["wq"], x)
        k = lin(tws["wk"], x)
        v = lin(tws["wv"], x)
        o = lin(tws["wo"], q)
        g = jax.nn.silu(lin(tws["w_gate"], o))
        u = lin(tws["w_up"], o)
        return lin(tws["w_down"], g * u), k, v

    t_fused = _time(jax.jit(fused_ref), x)
    t_legacy = _time(jax.jit(legacy_ref), x)
    return {
        "proj_dispatches_fused": sites_fused,
        "proj_dispatches_legacy": sites_legacy,
        "proj_layer_step_fused_us": t_fused,
        "proj_layer_step_legacy_us": t_legacy,
        "shapes": {"d_model": d, "q_dim": h * hd, "kv_dim": hkv * hd,
                   "d_ff": f, "decode_rows": int(x.shape[0])},
    }


def run():
    rng = np.random.default_rng(0)
    m, k, n = 256, 2048, 2048
    x = jnp.asarray(rng.integers(-127, 128, (m, k)), jnp.int8)
    w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32) * 0.02
    tw = make_ternary_weight(w)
    xf = x.astype(jnp.float32)

    t_tern = _time(jax.jit(lambda a: ops.ternary_matmul(a, tw, impl="ref")),
                   x)
    t_f32 = _time(jax.jit(lambda a: a @ w), xf)

    # LOP screen vs exact int8 scores over a big cache
    mcache, d = 8192, 128
    kc = jnp.asarray(rng.integers(-127, 128, (mcache, d)), jnp.int8)
    feat = pack_features(lop_features(kc))
    q = jnp.asarray(rng.integers(-127, 128, (16, d)), jnp.int8)
    t_screen = _time(jax.jit(lambda a: ops.lop_screen(a, feat, impl="ref")),
                     q)
    t_exact = _time(jax.jit(
        lambda a: jax.lax.dot(a, kc.T, preferred_element_type=jnp.int32)), q)

    proj = _run_projection_paths()
    with open("BENCH_proj.json", "w") as fh:
        json.dump(proj, fh, indent=2)

    rows = [
        ("kernels/ternary_matmul_ref_us", t_tern,
         f"{m}x{k}x{n} packed-2bit x int8"),
        ("kernels/f32_matmul_us", t_f32, "same GEMM in f32"),
        ("kernels/weight_bytes_packed", k * n // 4, "2 bit/weight"),
        ("kernels/weight_bytes_int8", k * n, "4x packed"),
        ("kernels/weight_bytes_bf16", 2 * k * n, "8x packed"),
        ("kernels/lop_screen_us", t_screen,
         f"{mcache}-token feature-cache screen"),
        ("kernels/exact_scores_us", t_exact, "exact int8 qk over cache"),
        ("kernels/screen_bytes", mcache * d // 2, "4-bit features"),
        ("kernels/exact_bytes", mcache * d, "int8 keys (2x screen)"),
        ("kernels/proj_dispatches_fused", proj["proj_dispatches_fused"],
         "pallas_call sites, decoder-layer projections (target: 3)"),
        ("kernels/proj_dispatches_legacy", proj["proj_dispatches_legacy"],
         "pre-fusion per-projection dispatch (7)"),
        ("kernels/proj_layer_step_fused_us",
         proj["proj_layer_step_fused_us"],
         "per-layer projection step, fused entries (CPU ref semantics; "
         "the wide concat GEMM is cache-bound on CPU — the fused win is "
         "launches + HBM round-trips, realized on TPU)"),
        ("kernels/proj_layer_step_legacy_us",
         proj["proj_layer_step_legacy_us"],
         "per-layer projection step, legacy chain (CPU ref semantics)"),
    ]
    return rows
