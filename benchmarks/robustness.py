"""Fault-tolerance ablation: recovery latency, load shedding, deadlines.

Three arms over ONE shared engine (warm jit caches, DESIGN.md
§Fault-tolerance):

  * **recovery** — the same greedy trace runs clean and under a seeded
    :class:`repro.serving.faults.FaultPlan` injecting transient NaN
    logits; every fault is detected in-graph, rewound bitwise and
    retried with the LOP screen off. Reported: recovery latency per
    event (the faulted run's extra wall time over its recoveries, plus a
    directly-timed single ``retry_step`` dispatch) and the proof burden
    — both runs must emit identical tokens.
  * **overload** — 3× more requests than a bounded queue admits, all at
    t0: the shed rate is the bound doing its job (reject-newest, reason
    ``"shed"``), deterministic under a virtual clock.
  * **deadline** — every request carries a tight ``deadline_ms`` under a
    virtual clock advanced a fixed quantum per serve cycle: the
    deadline-hit ratio (requests finishing inside their budget) is the
    scheduler's enforcement at admit / between chunks / per sweep.

Raw series goes to ``BENCH_faults.json`` for the run-over-run trajectory
gate. Counts and ratios are exactly reproducible (virtual clock + seeded
plan); only the recovery-latency leaves are wall-clock noisy.
"""

from __future__ import annotations

import json
import time

N_REQUESTS = 12
GEN = 6
MAX_QUEUE = 8
OVERLOAD_REQUESTS = 24
DEADLINE_MS = 120.0
CYCLE_QUANTUM_S = 0.01     # virtual-clock advance per serve cycle


def _engine():
    from repro.configs.bitnet_3b import REDUCED
    from repro.models.transformer import init_params
    from repro.serving.api import PooledEngine
    from repro.serving.quantize import quantize_params
    import jax

    cfg = REDUCED
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    qp = quantize_params(cfg, params)
    # use_lop=False so the no-LOP recovery retry recomputes the SAME
    # token the un-faulted step would have — token equality is the proof
    return cfg, PooledEngine(cfg, qp, max_len=48 + GEN, use_lop=False)


def _requests(cfg, n, *, seed=3, deadline_ms=None):
    import numpy as np
    from repro.serving.api import GenerateRequest

    rng = np.random.default_rng(seed)
    return [GenerateRequest(
        rid=rid, prompt=rng.integers(0, cfg.vocab, (int(rng.integers(
            8, 25)),)).astype(np.int32), max_new_tokens=GEN,
        deadline_ms=deadline_ms) for rid in range(n)]


def _drive(cfg, engine, reqs, *, max_queue=None, virtual=False):
    """Run one trace to completion; virtual=True advances a fake clock a
    fixed quantum per cycle (deterministic deadlines)."""
    from repro.serving.scheduler import Scheduler

    t = [0.0]
    sched = Scheduler(cfg, engine.qp, n_slots=4, max_len=48 + GEN,
                      engine=engine, max_queue=max_queue,
                      **({"clock": lambda: t[0]} if virtual else {}))
    for r in reqs:
        sched.submit(r)
    while sched.has_work():
        sched.admit()
        sched.step()
        t[0] += CYCLE_QUANTUM_S
    return sched


def run():
    import numpy as np
    from repro.serving import faults

    cfg, engine = _engine()
    mk = lambda: _requests(cfg, N_REQUESTS)

    # warmup: compile prefill buckets / decode / retry off the clock
    _drive(cfg, engine, _requests(cfg, 3, seed=9))
    with faults.inject(faults.FaultPlan(nan_logits=frozenset({(1, 0)}))):
        _drive(cfg, engine, _requests(cfg, 3, seed=9))

    # ---- recovery arm: clean vs faulted, identical tokens required ----
    t0 = time.monotonic()
    clean = _drive(cfg, engine, mk())
    wall_clean = time.monotonic() - t0
    plan = faults.FaultPlan.random(17, n_decode_calls=24, n_lanes=4,
                                   nan_events=4)
    t0 = time.monotonic()
    with faults.inject(plan):
        faulted = _drive(cfg, engine, mk())
    wall_faulted = time.monotonic() - t0
    clean_toks = {r.rid: r.tokens for r in clean.results}
    for r in faulted.results:
        assert r.tokens == clean_toks[r.rid], (
            f"rid {r.rid}: recovery changed the stream")
    recoveries = max(1, faulted.fault_recoveries)
    recovery_ms = max(0.0, wall_faulted - wall_clean) / recoveries * 1e3

    # direct measure: one quarantine+retry round trip on a warm lane
    sched = _drive(cfg, engine, _requests(cfg, 1, seed=11))
    pool, toks = sched.pool, np.zeros((4, 1), np.int32)
    temps = np.zeros(4, np.float32)
    tks = np.zeros(4, np.int32)
    tps = np.ones(4, np.float32)
    t0 = time.monotonic()
    _, _, pool = engine.retry_step(pool, 0, toks, temps, tks, tps)
    retry_step_ms = (time.monotonic() - t0) * 1e3

    # ---- overload arm: bounded queue sheds the excess ----
    over = _drive(cfg, engine, _requests(cfg, OVERLOAD_REQUESTS, seed=5),
                  max_queue=MAX_QUEUE, virtual=True)
    shed_rate = over.shed_count / OVERLOAD_REQUESTS

    # ---- deadline arm: tight budgets under a virtual clock ----
    dl = _drive(cfg, engine,
                _requests(cfg, N_REQUESTS, seed=7, deadline_ms=DEADLINE_MS),
                virtual=True)
    deadline_hit_ratio = 1.0 - dl.deadline_count / N_REQUESTS

    payload = {
        "trace": {"n_requests": N_REQUESTS, "gen": GEN,
                  "overload_requests": OVERLOAD_REQUESTS,
                  "max_queue": MAX_QUEUE, "deadline_ms": DEADLINE_MS,
                  "nan_events": len(plan.nan_logits), "arch": cfg.name},
        "recovery": {
            "wall_clean_s": wall_clean,
            "wall_faulted_s": wall_faulted,
            "fault_events": faulted.fault_events,
            "fault_recoveries": faulted.fault_recoveries,
            "fault_finishes": faulted.fault_finishes,
            "recovery_ms_per_event": recovery_ms,
            "retry_step_ms": retry_step_ms,
        },
        "overload": {
            "shed_count": over.shed_count,
            "shed_rate": shed_rate,
            "queue_depth_peak": over.queue_depth_peak,
        },
        "deadline": {
            "deadline_count": dl.deadline_count,
            "deadline_hit_ratio": deadline_hit_ratio,
        },
    }
    with open("BENCH_faults.json", "w") as fh:
        json.dump(payload, fh, indent=2)

    return [
        ("robustness/fault_events", faulted.fault_events,
         "injected NaN faults that hit an active lane"),
        ("robustness/fault_recoveries", faulted.fault_recoveries,
         "rollback+retry recoveries (tokens proven identical to clean)"),
        ("robustness/recovery_ms_per_event", recovery_ms,
         "faulted-run wall overhead per recovery"),
        ("robustness/retry_step_ms", retry_step_ms,
         "one warm single-lane no-LOP retry dispatch"),
        ("robustness/shed_rate", shed_rate,
         f"{OVERLOAD_REQUESTS} requests into a {MAX_QUEUE}-deep queue"),
        ("robustness/queue_depth_peak", over.queue_depth_peak,
         "bounded admit queue high-water mark"),
        ("robustness/deadline_hit_ratio", deadline_hit_ratio,
         f"requests finishing inside {DEADLINE_MS:.0f} ms (virtual clock)"),
    ]
