"""Fig. 9 reproduction: scheduling ablations.

  * Head-level pipelining (paper: +54.31% MHA throughput): materialized
    Q/K/V-for-all-heads schedule vs the streamed head-group schedule. On
    the ASIC the win is overlap between TINT and BoothFlex; in XLA terms it
    is fusion + the absence of the bulk QKV round-trip — we measure wall
    time of both schedules and report peak intermediate size.
  * BoothFlex dual mode (paper: +25.17% FFN throughput, utilization
    0.51%→69.20%): one shared integer datapath for attention AND
    projections. The TPU analogue is dtype/layout uniformity — we measure
    the FFN with the same int8 flow as attention vs an fp32 FFN with
    format churn (quantize↔dequantize between every op).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantization import dequantize, quantize
from repro.core.schedule import (materialized_mha, standard_softmax_attention,
                                 streamed_mha)


def _time(fn, *args, iters=10):
    jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def run():
    rng = np.random.default_rng(0)
    b, s, d, h, hd = 2, 256, 512, 16, 32
    x = jnp.asarray(rng.standard_normal((b, s, d)), jnp.float32)
    ws = [jnp.asarray(rng.standard_normal((d, h * hd)), jnp.float32) * 0.05
          for _ in range(3)]
    wo = jnp.asarray(rng.standard_normal((h * hd, d)), jnp.float32) * 0.05

    mat = jax.jit(lambda x: materialized_mha(
        x, *ws, wo, n_heads=h, head_dim=hd,
        attn_fn=standard_softmax_attention))
    stream = jax.jit(lambda x: streamed_mha(
        x, *ws, wo, n_heads=h, head_dim=hd,
        attn_fn=standard_softmax_attention, group=2))

    t_mat = _time(mat, x)
    t_stream = _time(stream, x)
    # correctness coupling
    err = float(jnp.max(jnp.abs(mat(x) - stream(x))))
    assert err < 1e-3, err

    # BoothFlex-dual-mode analogue: uniform int8 flow vs format churn
    f = 2048
    w1 = jnp.asarray(rng.standard_normal((d, f)), jnp.float32) * 0.04
    w2 = jnp.asarray(rng.standard_normal((f, d)), jnp.float32) * 0.02

    def ffn_uniform(xq_vals, xq_scale):
        # stays in the integer domain end-to-end; one dequant at the output
        h1 = jax.lax.dot(xq_vals.reshape(-1, d), jnp.round(w1 * 32).astype(
            jnp.int8), preferred_element_type=jnp.int32)
        a = jax.nn.silu(h1.astype(jnp.float32) * xq_scale.reshape(-1, 1)
                        / 32)
        aq = quantize(a)
        h2 = jax.lax.dot(aq.values, jnp.round(w2 * 32).astype(jnp.int8),
                         preferred_element_type=jnp.int32)
        return h2.astype(jnp.float32) * aq.scale / 32

    def ffn_churn(x):
        # quantize↔dequantize round trip between every op (no shared format)
        q1 = quantize(x.reshape(-1, d))
        x1 = dequantize(q1)
        h1 = x1 @ w1
        q2 = quantize(jax.nn.silu(h1))
        x2 = dequantize(q2)
        return x2 @ w2

    xq = quantize(x.reshape(-1, d))
    t_uniform = _time(jax.jit(ffn_uniform), xq.values, xq.scale)
    t_churn = _time(jax.jit(ffn_churn), x)

    mha_gain = (t_mat / t_stream - 1) * 100
    ffn_gain = (t_churn / t_uniform - 1) * 100
    overall = (1 + mha_gain / 100) * (1 + ffn_gain / 100)
    return [
        ("fig9/mha_materialized_us", t_mat, "bulk QKV then attention"),
        ("fig9/mha_streamed_us", t_stream, "head-group streaming"),
        ("fig9/hlp_gain_pct", mha_gain, "paper: +54.31%"),
        ("fig9/ffn_uniform_int8_us", t_uniform, "shared integer datapath"),
        ("fig9/ffn_format_churn_us", t_churn, "per-op quant<->dequant"),
        ("fig9/dualmode_gain_pct", ffn_gain, "paper: +25.17% FFN"),
        ("fig9/overall_gain_est", overall, "paper: +38.17% overall"),
    ]
