"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig8]

Prints ``name,value,derived`` CSV (value is µs for *_us rows, else a
dimensionless/derived quantity per the row's note).
"""

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on module name")
    args = ap.parse_args()

    from benchmarks import (fig8_lop, fig9_schedule, kernels_micro,
                            prefill_interleave, prefix_cache, table1_e2e)
    modules = [
        ("fig8_lop", fig8_lop),
        ("fig9_schedule", fig9_schedule),
        ("table1_e2e", table1_e2e),
        ("kernels_micro", kernels_micro),
        ("prefill_interleave", prefill_interleave),
        ("prefix_cache", prefix_cache),
    ]
    print("name,value,derived")
    failed = 0
    for name, mod in modules:
        if args.only and args.only not in name:
            continue
        try:
            for row_name, value, note in mod.run():
                print(f"{row_name},{value:.4g},{note}")
        except Exception as e:   # noqa: BLE001
            print(f"{name},ERROR,{e!r}")
            failed += 1
    sys.exit(1 if failed else 0)


if __name__ == '__main__':
    main()
